"""Sharded, async, elastic checkpointing (no external deps).

Layout of a checkpoint directory::

    <dir>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, step, mesh note
        arrays/<idx>.npy    # one file per leaf (full logical array)

Properties needed at 1000+ nodes (DESIGN.md §6):

* **atomic**: written to ``step_X.tmp`` then renamed — a crash never leaves
  a half-checkpoint that restore could pick up;
* **async**: `save_async` snapshots device arrays to host then writes on a
  background thread — training continues during I/O;
* **elastic**: arrays are saved as *logical* (unsharded) tensors with the
  tree spec in the manifest; `restore` lays them onto ANY mesh via the
  current ShardingRules — restart on a different device count just works
  (tested 8 -> 4 devices);
* **retention**: keep the last N checkpoints, delete older ones.

On a real multi-host pod each host would write only the shards it owns
(jax.experimental.multihost_utils); single-process here, the full gather is
the correct degenerate case.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    manifest = {"step": step, "time": time.time(), "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(_tree_paths(tree)):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, "arrays", f"{i}.npy"), arr)
        manifest["leaves"].append(
            {"key": key, "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomicity boundary
    _retain(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread. One in-flight save at a time."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now

        def _write():
            save(self.ckpt_dir, step, host_tree, extra=extra, keep=self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int], like: Any, shardings: Any = None) -> tuple:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (optional pytree of NamedSharding)
    re-lays every leaf onto the current mesh — the elastic path."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {leaf["key"]: leaf for leaf in manifest["leaves"]}
    keys = [k for k, _ in _tree_paths(like)]
    leaves = []
    for k in keys:
        meta = by_key[k]
        arr = np.load(os.path.join(d, "arrays", f"{meta['index']}.npy"))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s, l: jax.device_put(
                a.astype(np.asarray(l).dtype if hasattr(l, "dtype") else a.dtype), s
            ),
            tree, shardings, like,
        )
    return tree, manifest


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
