"""Distributed epoch-fused sweep trajectory — one JSON record per device count.

    python benchmarks/bench_sweep.py <grid> <devices> [--json PATH]

Spawns itself with ``XLA_FLAGS=--xla_force_host_platform_device_count``
(device count locks at first JAX init). Measures the solve-side hot path of
the sharded preconditioner on the simulated mesh:

* communication per apply — collectives and wire bytes from the host
  epoch/read-set model (DESIGN.md §5.5), cross-checked against the
  compiled HLO (``repro.roofline.analysis``), vs the PR-3 per-level model;
* steady preconditioner-apply and distributed-GMRES wall times (single RHS
  and an 8-RHS batch riding the same collectives);
* serving warmup — ``warm_solve`` wall time and the first fresh-RHS solve
  latency after it (the "pre-warmed shape never pays the compile" number);
* the **ordering axis** (PR 5): modeled epochs/collectives/bytes per apply
  for natural vs RCM vs fusion-aware row ordering on the Poisson *and* a
  random matgen structure (quantifying the ROADMAP "2-3x fusion" item),
  plus measured steady apply latency and a bitwise-vs-single-device-
  permuted assert for every ordered Poisson solve.

``benchmarks/run.py --emit-json BENCH_sweep.json`` aggregates 1/2/8 devices
into the committed trajectory.
"""
import json
import os
import subprocess
import sys

if os.environ.get("_BENCH_SWEEP_CHILD") != "1" and __name__ == "__main__":
    d = sys.argv[2] if len(sys.argv) > 2 else "2"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
    env.setdefault("JAX_PLATFORMS", "cpu")  # don't probe for real TPUs
    env["_BENCH_SWEEP_CHILD"] = "1"
    sys.exit(subprocess.run([sys.executable, __file__] + sys.argv[1:], env=env).returncode)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np


def _model_axis(mat, band_rows: int, d: int) -> list:
    """Modeled sweep communication per ordering (host-only, nothing
    compiled — the same epoch/read-set model the HLO tests pin)."""
    from repro.core.ordering import make_ordering, permuted_system, sweep_comm_model
    from repro.core.symbolic import pilu1_symbolic

    out = []
    for name in ("natural", "rcm", "fusion"):
        ordering = make_ordering(mat, name, n_devices=d, band_rows=band_rows)
        mp = mat if ordering is None else permuted_system(mat, ordering)
        pat = pilu1_symbolic(mp)
        rec = sweep_comm_model(pat, band_rows, d)
        out.append({
            "ordering": name,
            "levels": rec["levels"],
            "epochs": rec["epochs"],
            "collectives_per_apply": rec["collectives_per_apply"],
            "bytes_per_apply": rec["bytes_per_apply"],
            "fill_nnz": pat.nnz,
        })
    return out


def measure(grid: int, band_rows: int = 16, batch: int = 8) -> dict:
    import jax

    from repro.core import matgen, poisson_2d
    from repro.core.ordering import make_ordering, permuted_system
    from repro.core.solvers import solve_sharded, solve_with_ilu, warm_solve
    from repro.roofline.analysis import (
        collective_bytes_per_device,
        collective_op_counts,
    )

    d = len(jax.devices())
    a = poisson_2d(grid)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n).astype(np.float32)
    bs = rng.standard_normal((batch, a.n)).astype(np.float32)

    # --- serving warmup: all compiles land here ---------------------------
    t0 = time.perf_counter()
    warm_solve(a, k=1, batch_sizes=(1, batch), band_rows=band_rows, tol=1e-6)
    warm_seconds = time.perf_counter() - t0

    # first fresh-RHS solve after warmup (the pre-warmed-shape latency)
    t0 = time.perf_counter()
    res, fact = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6)
    warm_first_solve = time.perf_counter() - t0
    assert res.converged

    # single-device comparison: bitwise-equal x; its first solve is NOT
    # warmed — the compile cost a cold process pays without warm_solve
    t0 = time.perf_counter()
    res1, _ = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False)
    single_unwarmed_first_solve = time.perf_counter() - t0
    bitwise = bool(np.array_equal(res.x.view(np.int32), res1.x.view(np.int32)))

    # --- steady state ------------------------------------------------------
    ap = fact.precond()
    reps = 20
    np.asarray(ap(b))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = ap(b)
    jax.block_until_ready(out)
    apply_steady = (time.perf_counter() - t0) / reps

    np.asarray(ap.batched(bs))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = ap.batched(bs)
    jax.block_until_ready(out)
    apply_batched_steady = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    solve_reps = 3
    for _ in range(solve_reps):
        r2, _ = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6, fact=fact)
    gmres_steady = (time.perf_counter() - t0) / solve_reps

    t0 = time.perf_counter()
    rb, _ = solve_sharded(a, bs, k=1, band_rows=band_rows, tol=1e-6, fact=fact)
    gmres_batched = time.perf_counter() - t0
    assert all(r.converged for r in rb)

    # --- ordering axis: model on two structures + measured Poisson latency -
    orderings = {
        "poisson": _model_axis(a, band_rows, d),
        "random": _model_axis(matgen(a.n, density=0.006, seed=3),
                              band_rows, d),
    }
    for rec in orderings["poisson"]:
        name = rec["ordering"]
        if name == "natural":
            o_apply, o_b, r_o = fact.precond(), b, res
        else:
            ordering = make_ordering(a, name, n_devices=d, band_rows=band_rows)
            r_o, o_fact = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6, ordering=ordering)
            o_apply = o_fact.precond()
            o_b = ordering.permute_vector(b)
        # ordered distributed solve == single-device solve of the same
        # permuted system (the PR's bitwise acceptance contract)
        ap_mat = a if name == "natural" else permuted_system(
            a, make_ordering(a, name, n_devices=d, band_rows=band_rows))
        r_1, _ = solve_with_ilu(ap_mat, o_b, k=1, tol=1e-6, use_pallas=False)
        x_sh = r_o.x if name == "natural" else r_o.x[
            make_ordering(a, name, n_devices=d, band_rows=band_rows).perm]
        rec["bitwise_equal_single_device_permuted"] = bool(
            np.array_equal(x_sh.view(np.int32), r_1.x.view(np.int32)))
        np.asarray(o_apply(o_b))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = o_apply(o_b)
        jax.block_until_ready(out)
        rec["precond_apply_steady_seconds"] = (time.perf_counter() - t0) / reps

    # --- communication model vs compiled HLO -------------------------------
    plan = ap.plan
    hlo = ap._engine.lower_sweep(1).compile().as_text()
    hlo_bytes = sum(collective_bytes_per_device(hlo).values())
    hlo_count = sum(collective_op_counts(hlo).values())
    return {
        "devices": d,
        "n": a.n,
        "grid": grid,
        "k": 1,
        "band_rows": band_rows,
        "batch": batch,
        "bitwise_equal_single_device": bitwise,
        "iterations": res.iterations,
        # communication per preconditioner apply
        "levels_unfused": plan.nl_levels + plan.nu_levels,
        "epochs": plan.l_sched.n_epochs + plan.u_sched.n_epochs,
        "collectives_per_apply": plan.sweep_collectives_per_apply(),
        "hlo_collectives_per_apply": hlo_count,
        "bytes_per_apply": plan.sweep_bytes_per_apply(),
        "hlo_bytes_per_apply": hlo_bytes,
        "bytes_per_apply_unfused_pr3": plan.sweep_bytes_per_apply_unfused(),
        "bytes_per_apply_batched": plan.sweep_bytes_per_apply(batch),
        # wall times (all D virtual devices time-slice one CPU)
        "warm_seconds": warm_seconds,
        "warm_first_solve_seconds": warm_first_solve,
        "single_device_unwarmed_first_solve_seconds": single_unwarmed_first_solve,
        "precond_apply_steady_seconds": apply_steady,
        "precond_apply_batched_seconds_per_rhs": apply_batched_steady / batch,
        "gmres_steady_seconds": gmres_steady,
        "gmres_batched_seconds_per_rhs": gmres_batched / batch,
        # ordering axis: natural vs rcm vs fusion on two structures
        "orderings": orderings,
    }


def main():
    grid = int(sys.argv[1]) if len(sys.argv) > 1 else 32
    out = None
    if "--json" in sys.argv:
        out = sys.argv[sys.argv.index("--json") + 1]
    m = measure(grid)
    text = json.dumps(m, indent=2)
    if out:
        with open(out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
