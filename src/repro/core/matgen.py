"""Diagonally-dominant sparse matrix generators.

The paper evaluates on matrices from ``matgen`` (a random generator of
diagonally dominant sparse matrices) plus one real-world matrix (SPARSKIT
Driven Cavity ``e40r3000``, incompressible Navier-Stokes). We reproduce:

* :func:`matgen` — random pattern with controlled density, values in
  ``[-1, 1]``, diagonal set to ``sum(|offdiag|) + margin`` so the matrix is
  strictly diagonally dominant (the paper's standing assumption).
* :func:`convection_diffusion_2d` — a structured nonsymmetric 9-point stencil
  used as an offline surrogate for e40r3000 (the SPARSKIT file is not
  redistributable into this container; density/row-degree are matched).
* :func:`poisson_2d` — 5-point Laplacian, the classical SPD test.
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix


def matgen(n: int, density: float, seed: int = 0, margin: float = 1.0) -> CSRMatrix:
    """Random strictly diagonally dominant matrix in CSR form.

    ``density`` counts all entries (diagonal included), matching the paper's
    reported densities (e.g. n=20K at density 0.003).
    """
    rng = np.random.default_rng(seed)
    per_row = max(int(round(density * n)) - 1, 0)  # off-diagonal entries/row
    indptr = np.zeros(n + 1, dtype=np.int64)
    all_cols = []
    all_vals = []
    for j in range(n):
        m = min(per_row, n - 1)
        if m > 0:
            # sample without replacement, excluding the diagonal
            cols = rng.choice(n - 1, size=m, replace=False).astype(np.int64)
            cols[cols >= j] += 1
            cols = np.sort(cols)
            vals = rng.uniform(-1.0, 1.0, size=m).astype(np.float32)
        else:
            cols = np.zeros(0, dtype=np.int64)
            vals = np.zeros(0, dtype=np.float32)
        diag = np.float32(np.abs(vals).sum() + margin)
        pos = np.searchsorted(cols, j)
        cols = np.insert(cols, pos, j)
        vals = np.insert(vals, pos, diag)
        all_cols.append(cols.astype(np.int32))
        all_vals.append(vals)
        indptr[j + 1] = indptr[j] + len(cols)
    return CSRMatrix(
        n=n,
        indptr=indptr,
        indices=np.concatenate(all_cols),
        data=np.concatenate(all_vals),
    )


def poisson_2d(nx: int) -> CSRMatrix:
    """5-point Laplacian on an nx*nx grid (SPD, diagonally dominant)."""
    import scipy.sparse as sp

    n = nx * nx
    main = 4.0 * np.ones(n)
    side = -np.ones(n - 1)
    side[np.arange(1, n) % nx == 0] = 0.0
    updown = -np.ones(n - nx)
    a = sp.diags(
        [main, side, side, updown, updown],
        [0, 1, -1, nx, -nx],
        format="csr",
        dtype=np.float32,
    )
    return CSRMatrix.from_scipy(a)


def convection_diffusion_2d(nx: int, reynolds: float = 40.0, seed: int = 1) -> CSRMatrix:
    """Nonsymmetric convection-diffusion 9-point stencil (e40r3000 surrogate).

    Driven-cavity matrices couple velocity/pressure unknowns with ~32
    entries/row; we mimic the nonsymmetry and bandwidth with a 9-point
    stencil plus a few random couplings, then enforce weak diagonal
    dominance the way preprocessing (e.g. MC64 scaling, [5] in the paper)
    would.
    """
    rng = np.random.default_rng(seed)
    n = nx * nx
    rows, cols, vals = [], [], []

    def add(r, c, v):
        rows.append(r)
        cols.append(c)
        vals.append(v)

    conv = reynolds / nx
    for y in range(nx):
        for x in range(nx):
            r = y * nx + x
            stencil = []
            for dy in (-1, 0, 1):
                for dx in (-1, 0, 1):
                    xx, yy = x + dx, y + dy
                    if 0 <= xx < nx and 0 <= yy < nx and (dx, dy) != (0, 0):
                        # upwinded convection makes it nonsymmetric
                        w = -1.0 + conv * (dx + 0.5 * dy) + 0.05 * rng.standard_normal()
                        stencil.append((yy * nx + xx, w))
            # sprinkle two long-range couplings per row (pressure-like)
            for _ in range(2):
                c = int(rng.integers(0, n))
                if c != r:
                    stencil.append((c, 0.1 * rng.standard_normal()))
            offsum = 0.0
            for c, w in stencil:
                add(r, c, w)
                offsum += abs(w)
            add(r, r, offsum + 1.0)
    import scipy.sparse as sp

    a = sp.csr_matrix((np.asarray(vals, np.float32), (rows, cols)), shape=(n, n))
    a.sum_duplicates()
    return CSRMatrix.from_scipy(a)
