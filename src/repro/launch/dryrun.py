import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count at first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

For each cell this:
  1. builds the production mesh (16x16, or 2x16x16 with --multi-pod),
  2. abstract-inits params/optimizer/caches (jax.eval_shape — no allocation),
  3. jits train_step / prefill_step / serve_step with the sharding rules,
  4. ``.lower().compile()`` — success is the deliverable,
  5. prints memory_analysis + cost_analysis and writes the roofline JSON.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax


def _lower_and_compile(cfg, shape_name, mesh, opts, microbatches):
    from repro.configs.base import SHAPES
    from repro.launch.sharding import ShardingRules
    from repro.models import model as M
    from repro.models.common import logical_mesh
    from repro.optim import adamw
    from repro.train.step import make_prefill_step, make_serve_step, make_train_step

    seq, gbatch, kind = SHAPES[shape_name]
    rules = ShardingRules(cfg, mesh)
    params_shapes = jax.eval_shape(lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    p_shard = rules.params_shardings(params_shapes)
    batch_specs = cfg.input_specs(shape_name)
    b_shard = rules.batch_shardings(batch_specs)

    with logical_mesh(mesh):
        if kind == "train":
            opt_shapes = jax.eval_shape(adamw.init, params_shapes)
            o_shard = rules.opt_shardings(opt_shapes, zero1=opts.get("zero1", False))
            step = make_train_step(cfg, adamw.AdamWConfig(), microbatches=microbatches)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shapes, opt_shapes, batch_specs)
        elif kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_shapes, batch_specs)
        else:  # decode
            cache_len = cfg.cache_len(shape_name)
            cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, gbatch, cache_len))
            c_shard = rules.cache_shardings(cache_shapes, gbatch)
            step = make_serve_step(cfg)
            in_sh = [p_shard, c_shard, b_shard["tokens"]]
            args = [params_shapes, cache_shapes, batch_specs["tokens"]]
            if cfg.family == "audio":
                in_sh.append(b_shard["frames"])
                args.append(batch_specs["frames"])
            jitted = jax.jit(
                step,
                in_shardings=tuple(in_sh),
                out_shardings=(None, None, c_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled, kind


def _build_cell(arch, shape_name, multi_pod, opts):
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if opts.get("remat"):
        cfg = dataclasses.replace(cfg, remat=opts["remat"])
    if opts.get("q_chunk"):
        cfg = dataclasses.replace(cfg, q_chunk=opts["q_chunk"], kv_chunk=opts["q_chunk"])
    if opts.get("window") and cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=opts["window"])
    if shape_name not in cfg.supported_shapes:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: 512k dense KV decode excluded "
                          "(DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    # ---- pass A: production form (scan over layers) -> compile + memory ---
    t0 = time.time()
    compiled_a, kind = _lower_and_compile(cfg, shape_name, mesh, opts, opts.get("microbatches", 1))
    t_a = time.time() - t0
    mem = compiled_a.memory_analysis()
    print(mem)  # proves it fits
    from repro.roofline.analysis import cost_analysis_dict

    ca_a = cost_analysis_dict(compiled_a)
    print({k: ca_a[k] for k in ("flops", "bytes accessed") if k in ca_a})

    # ---- pass B: cost form — unrolled 1-layer and 2-layer modules, prefix
    # attention, no grad-accumulation loop; per-layer costs extrapolated to
    # the full stack. XLA's cost_analysis counts while-loop bodies ONCE
    # (verified on this jax version), so the production scan form cannot be
    # used for the roofline and full unrolls are too slow to compile for
    # every cell; layer-homogeneous extrapolation is exact here.
    from repro.roofline.analysis import (
        analyze_costs, extract_costs, extrapolate_costs, model_flops,
        recurrent_scan_correction,
    )

    def cost_cfg(nl):
        kw = dict(scan_layers=False, attn_unroll=True, n_layers=nl)
        if cfg.block_types:
            kw["block_types"] = (cfg.block_types * nl)[:nl]
        if cfg.encoder_layers:
            kw["encoder_layers"] = nl
        return dataclasses.replace(cfg, **kw)

    t1 = time.time()
    if opts.get("skip_cost_pass"):
        costs = extract_costs(compiled_a)
    else:
        cb1, _ = _lower_and_compile(cost_cfg(1), shape_name, mesh, opts, 1)
        cb2, _ = _lower_and_compile(cost_cfg(2), shape_name, mesh, opts, 1)
        costs = extrapolate_costs(extract_costs(cb1), extract_costs(cb2), cfg.n_layers)
    t_b = time.time() - t1

    corr = recurrent_scan_correction(cfg, shape_name, int(mesh.devices.size))
    rep = analyze_costs(
        costs, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=int(mesh.devices.size),
        model_flops_global=model_flops(cfg, shape_name),
        corrections=corr,
        memory_stats={
            "argument_bytes": float(mem.argument_size_in_bytes),
            "output_bytes": float(mem.output_size_in_bytes),
            "temp_bytes": float(mem.temp_size_in_bytes),
            "alias_bytes": float(mem.alias_size_in_bytes),
        },
    )
    out = rep.to_json()
    out.update(
        status="ok", kind=kind, compile_a_s=round(t_a, 1), compile_b_s=round(t_b, 1),
        multi_pod=multi_pod, opts=opts, scan_correction=corr,
        memory_stats_production={
            "argument_bytes": float(mem.argument_size_in_bytes),
            "output_bytes": float(mem.output_size_in_bytes),
            "temp_bytes": float(mem.temp_size_in_bytes),
        },
        fits_hbm_16g=bool(
            (mem.temp_size_in_bytes + mem.argument_size_in_bytes) < 16e9
        ),
    )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all (arch x shape) cells")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default=None, choices=[None, "none", "dots", "full"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--skip-cost-pass", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    from repro.configs import ARCHS
    from repro.configs.base import SHAPES

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    opts = {"remat": args.remat, "zero1": args.zero1,
            "microbatches": args.microbatches, "q_chunk": args.q_chunk,
            "window": args.window, "skip_cost_pass": args.skip_cost_pass}
    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'pod2' if args.multi_pod else 'pod1'}"
        if args.tag:
            tag += f"__{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        print(f"=== {tag} ===", flush=True)
        try:
            res = _build_cell(arch, shape, args.multi_pod, opts)
        except Exception as e:
            traceback.print_exc()
            res = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        print(json.dumps({k: res.get(k) for k in
                          ("status", "bottleneck", "compute_s", "memory_s",
                           "collective_s", "useful_ratio", "fits_hbm_16g",
                           "compile_a_s", "compile_b_s", "reason", "error")}),
              flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
