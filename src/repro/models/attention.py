"""Attention: GQA with chunked (flash-style) online softmax, MLA, decode.

Design notes (see DESIGN.md §5):

* Training/prefill attention never materializes S×S scores: a static Python
  loop over query chunks runs a `lax.scan` over exactly the causal prefix of
  KV chunks (static trip count per q-chunk), so HLO FLOPs ≈ the causal
  optimum — this keeps `cost_analysis` honest for the roofline — and the
  working set stays O(chunk²).
* Sliding-window attention additionally *skips* KV chunks entirely below the
  window (static bound per q-chunk) — this is what makes hymba's 512k-token
  shape lowerable.
* Decode attends one query position against the cache with a length mask.
* MLA (DeepSeek) keeps the compressed KV (c_kv ‖ k_rope) as the cache and
  expands per-head K/V on the fly (train) or uses the absorbed form (decode).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, maybe_shard, mesh_axis_size, rope_angles

NEG_INF = -1e30


# --------------------------------------------------------------------------
# chunked causal attention (q: (B,S,H,D), k/v: (B,Skv,Hkv,D))
# --------------------------------------------------------------------------
def _attend_block(q, k, v, scale, mask):
    """One (q-chunk, kv-chunk) block. Returns (scores_max, exp_sum, out)."""
    # q (B,cq,H,D) k (B,ck,Hkv,D) -> group-broadcast
    B, cq, H, D = q.shape
    ck, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, cq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if mask is not None:
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return m, l, o


def chunked_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    positions_q=None,
    positions_kv=None,
    unroll_prefix: bool = False,
):
    """Flash-style attention. Shapes: q (B,S,H,D), k/v (B,Skv,Hkv,D)."""
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # value dim may differ (MLA)
    scale = 1.0 / math.sqrt(D)

    def _pick(size, c):  # largest divisor of `size` not exceeding c
        c = min(c, size)
        while size % c:
            c -= 1
        return c

    cq = _pick(S, q_chunk)
    ck = _pick(Skv, kv_chunk)
    nq, nk = S // cq, Skv // ck
    g = H // Hkv
    if positions_q is None:
        positions_q = jnp.arange(S)
    if positions_kv is None:
        positions_kv = jnp.arange(Skv)

    outs = []
    for qi in range(nq):
        qs = q[:, qi * cq : (qi + 1) * cq]
        pos_q = positions_q[qi * cq : (qi + 1) * cq]
        # static causal prefix: kv chunks 0..hi-1; sliding window skips lo
        hi = nk if not causal else min(nk, ((qi + 1) * cq + ck - 1) // ck)
        lo = 0
        if window is not None and causal:
            lo = max(0, (qi * cq - window) // ck)
        n_blocks = hi - lo

        if unroll_prefix:
            # cost-pass form: ONE statically-sliced prefix block per q chunk
            # (no lax.scan, so XLA cost_analysis counts every FLOP exactly).
            ks = k[:, lo * ck : hi * ck]
            vs = v[:, lo * ck : hi * ck]
            pos_k = positions_kv[lo * ck : hi * ck]
            mask = None
            if causal:
                mask = pos_q[None, :, None] >= pos_k[None, None, :]
                if window is not None:
                    mask &= pos_q[None, :, None] - pos_k[None, None, :] < window
                mask = jnp.broadcast_to(mask, (B, cq, (hi - lo) * ck))
            m_b, l_b, o_b = _attend_block(qs, ks, vs, scale, mask)
            o = o_b / jnp.maximum(l_b[..., None], 1e-30)
            outs.append(o.reshape(B, cq, H, Dv).astype(q.dtype))
            continue

        def kv_step(carry, kc):
            m_run, l_run, o_run = carry
            ks = jax.lax.dynamic_slice_in_dim(k, kc * ck, ck, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kc * ck, ck, axis=1)
            pos_k = jax.lax.dynamic_slice_in_dim(positions_kv, kc * ck, ck, axis=0)
            mask = None
            if causal:
                mask = pos_q[None, :, None] >= pos_k[None, None, :]
                if window is not None:
                    mask &= pos_q[None, :, None] - pos_k[None, None, :] < window
                mask = jnp.broadcast_to(mask, (B, cq, ck))
            m_b, l_b, o_b = _attend_block(qs, ks, vs, scale, mask)
            m_new = jnp.maximum(m_run, m_b)
            a1 = jnp.exp(m_run - m_new)
            a2 = jnp.exp(m_b - m_new)
            l_new = l_run * a1 + l_b * a2
            o_new = o_run * a1[..., None] + o_b * a2[..., None]
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, cq, Hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, Hkv, g), jnp.float32)
        o0 = jnp.zeros((B, cq, Hkv, g, Dv), jnp.float32)
        (m_f, l_f, o_f), _ = jax.lax.scan(kv_step, (m0, l0, o0), lo + jnp.arange(n_blocks))
        o = o_f / jnp.maximum(l_f[..., None], 1e-30)
        outs.append(o.reshape(B, cq, H, Dv).astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_attention(q, k_cache, v_cache, n_valid):
    """One-token decode: q (B,1,H,D) vs cache (B,L,Hkv,D).

    ``n_valid`` (B,) is the number of *written* slots. For ring-buffer
    (sliding-window) caches, L == window and wrapped slots are all valid —
    slot order is irrelevant because RoPE was applied at insertion and the
    softmax is permutation-invariant.
    """
    B, _, H, D = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(L)[None, :] < n_valid[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# standard GQA block params + apply
# --------------------------------------------------------------------------
def init_gqa(key, cfg, kg=None):
    from .common import KeyGen

    kg = kg or KeyGen(key)
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": dense_init(kg(), (d, H * hd), dt),
        "wk": dense_init(kg(), (d, Hkv * hd), dt),
        "wv": dense_init(kg(), (d, Hkv * hd), dt),
        "wo": dense_init(
            kg(),
            (H * hd, d),
            dt,
            scale=1.0 / math.sqrt(2 * cfg.n_layers * H * hd / d) / math.sqrt(d),
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    return p


def gqa_project_qkv(p, x, cfg, positions):
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.use_rope:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_attention(p, x, cfg, positions=None, cross_kv=None):
    """Full-sequence (train/prefill) GQA self-attention, or cross-attention
    when ``cross_kv`` carries raw encoder states (B, T, d) — projected here
    with this layer's wk/wv (no RoPE on cross)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(S)
    if cross_kv is not None:
        enc = cross_kv
        T = enc.shape[1]
        q = (x @ p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        q = q.reshape(B, S, H, hd)
        k = (enc @ p["wk"]).reshape(B, T, Hkv, hd)
        v = (enc @ p["wv"]).reshape(B, T, Hkv, hd)
        o = chunked_attention(q, k, v, causal=False,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                              unroll_prefix=cfg.attn_unroll)
        return o.reshape(B, S, -1) @ p["wo"]
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    tp = mesh_axis_size("model")
    dp = mesh_axis_size("pod") * mesh_axis_size("data")
    if cfg.n_heads % tp == 0 or tp == 1:
        q = maybe_shard(q, ("pod", "data"), None, "model", None)
    elif B % (dp * tp) == 0:
        # heads don't divide the model axis (smollm 9H, hymba 25H, ...):
        # instead of replicating the quadratic attention work on every TP
        # shard, re-shard the BATCH over (dp x model) for the attention
        # block — a cheap activation all-to-all for a tp-fold compute cut
        # (§Perf hillclimb #2).
        all_axes = ("pod", "data", "model")
        q = maybe_shard(q, all_axes, None, None, None)
        k = maybe_shard(k, all_axes, None, None, None)
        v = maybe_shard(v, all_axes, None, None, None)
    elif S % (tp * cfg.q_chunk) == 0:
        # batch too small to fold over model (prefill_32k: B=32 < dp*tp):
        # shard the query SEQUENCE over model instead — context parallelism;
        # K/V stay batch-sharded (each q-chunk block reads the causal
        # prefix; XLA gathers the small K/V, 2*S*Hkv*hd per layer).
        q = maybe_shard(q, ("pod", "data"), "model", None, None)
    o = chunked_attention(
        q, k, v, causal=True, window=cfg.sliding_window,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        positions_q=positions, positions_kv=positions,
        unroll_prefix=cfg.attn_unroll,
    )
    return o.reshape(B, S, -1) @ p["wo"]


def gqa_decode(p, x, cfg, cache, layer_cache_name="kv"):
    """One-token decode. cache dict: {k,v: (B,L,Hkv,hd), len: (B,)}. Returns
    (out, new_cache)."""
    B, S, d = x.shape
    assert S == 1
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["len"]  # (B,)
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(1, 1, H, hd)
        k = k + p["bk"].reshape(1, 1, Hkv, hd)
        v = v + p["bv"].reshape(1, 1, Hkv, hd)
    if cfg.use_rope:
        cos, sin = rope_angles(pos[:, None].astype(jnp.float32), hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    L = cache["k"].shape[1]
    slot = (pos % L)  # ring buffer (L == window) or plain append (L == max_len)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    n_valid = jnp.minimum(pos + 1, L)
    o = decode_attention(q, k_cache, v_cache, n_valid)
    out = o.reshape(B, 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache, "len": pos + 1}


# --------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# --------------------------------------------------------------------------
def init_mla(key, cfg):
    from .common import KeyGen

    kg = KeyGen(key)
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    r = cfg.mla_kv_lora
    dt = cfg.param_dtype
    return {
        "wq": dense_init(kg(), (d, H * (dn + dr)), dt),
        "w_dkv": dense_init(kg(), (d, r + dr), dt),
        "kv_norm": jnp.ones((r,), dt),
        "w_uk": dense_init(kg(), (r, H * dn), dt),
        "w_uv": dense_init(kg(), (r, H * dv), dt),
        "wo": dense_init(kg(), (H * dv, d), dt, scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def mla_attention(p, x, cfg, positions=None):
    """Training/prefill MLA: expand per-head K/V from the latent."""
    from .common import rms_norm

    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    r = cfg.mla_kv_lora
    if positions is None:
        positions = jnp.arange(S)
    q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv = x @ p["w_dkv"]  # (B,S,r+dr)
    c_kv, k_rope = ckv[..., :r], ckv[..., r:]
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, dn)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, dv)
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # single shared rope head
    k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, dr))
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = chunked_attention(
        qf, kf, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        positions_q=positions, positions_kv=positions,
        unroll_prefix=cfg.attn_unroll,
    )
    return o.reshape(B, S, H * dv) @ p["wo"]


def mla_decode(p, x, cfg, cache):
    """Absorbed-form decode: cache stores only (c_kv ‖ k_rope) — the MLA win."""
    from .common import rms_norm

    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    r = cfg.mla_kv_lora
    pos = cache["len"]
    q = (x @ p["wq"]).reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(pos[:, None].astype(jnp.float32), dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    ckv = x @ p["w_dkv"]
    c_new, kr_new = ckv[..., :r], ckv[..., r:]
    c_new = rms_norm(c_new, p["kv_norm"])
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin)[:, 0, 0]
    L = cache["c"].shape[1]
    bidx = jnp.arange(B)
    slot = pos % L
    c_cache = cache["c"].at[bidx, slot].set(c_new[:, 0])
    r_cache = cache["r"].at[bidx, slot].set(kr_new)
    # absorb W_uk into q: q_lat (B,1,H,r)
    w_uk = p["w_uk"].reshape(r, H, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    s_lat = jnp.einsum("bshr,blr->bshl", q_lat, c_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,bld->bshl", q_rope.astype(jnp.float32), r_cache.astype(jnp.float32))
    scale = 1.0 / math.sqrt(dn + dr)
    s = (s_lat + s_rope) * scale
    valid = jnp.arange(L)[None, :] < (pos + 1)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bshl,blr->bshr", pattn, c_cache.astype(jnp.float32))  # (B,1,H,r)
    w_uv = p["w_uv"].reshape(r, H, dv)
    o = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(B, 1, H * dv) @ p["wo"]
    return out, {"c": c_cache, "r": r_cache, "len": pos + 1}
