"""repro.checkpoint"""
