"""Preconditioned iterative solvers (JAX): GMRES(m), BiCGSTAB, CG.

These are the *consumers* of the ILU(k) preconditioner — the paper's point
is that preconditioning time dominates the solver as processors scale, so a
real system must include the solver to measure anything meaningful
(paper §I, §V-B).

All solvers take ``matvec`` (A·x) and ``precond`` (M^{-1}·x, identity if
None) as functions, run in float32, and report iteration counts + residual
history so tests/benches can reproduce the paper's "larger k => fewer
iterations" trade-off (Fig 5 discussion).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .planner import COL_SENTINEL


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray
    iterations: int
    residual: float
    converged: bool
    history: np.ndarray  # residual norm per (outer) iteration


def make_ell_matvec(cols: jnp.ndarray, vals: jnp.ndarray, n: int) -> Callable:
    """Row-major ELL SpMV — the jnp reference the Pallas kernel must match."""
    def matvec(x):
        xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
        gathered = xg[jnp.minimum(cols, n)]
        return jnp.sum(jnp.where(cols < COL_SENTINEL, vals * gathered, 0.0), axis=1)[:n]
    return matvec


def csr_to_ell_arrays(a):
    """CSRMatrix -> (cols, vals) sentinel-padded ELL arrays."""
    lens = np.diff(a.indptr)
    W = int(lens.max())
    cols = np.full((a.n, W), COL_SENTINEL, np.int32)
    vals = np.zeros((a.n, W), np.float32)
    for j in range(a.n):
        c, v = a.row(j)
        cols[j, : len(c)] = c
        vals[j, : len(v)] = v
    return jnp.asarray(cols), jnp.asarray(vals)


def _identity(x):
    return x


# --------------------------------------------------------------------------
# CG (SPD systems — e.g. the Poisson benchmark)
# --------------------------------------------------------------------------
def cg(matvec, b, precond=None, tol=1e-5, maxiter=500):
    M = precond or _identity
    b = jnp.asarray(b, jnp.float32)
    bnorm = jnp.linalg.norm(b)

    def body(carry):
        x, r, z, p, rz, it, _ = carry
        ap = matvec(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = M(r)
        rz_new = jnp.vdot(r, z)
        p = z + (rz_new / rz) * p
        return x, r, z, p, rz_new, it + 1, jnp.linalg.norm(r)

    def cond(carry):
        *_, it, rnorm = carry
        return (rnorm > tol * bnorm) & (it < maxiter)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = M(r0)
    carry = (x0, r0, z0, z0, jnp.vdot(r0, z0), jnp.int32(0), jnp.linalg.norm(r0))
    x, r, *_, it, rnorm = jax.lax.while_loop(cond, body, carry)
    rel = float(rnorm / bnorm)
    return SolveResult(np.asarray(x), int(it), rel, rel <= tol * 1.01, np.asarray([rel]))


# --------------------------------------------------------------------------
# BiCGSTAB (general nonsymmetric)
# --------------------------------------------------------------------------
def bicgstab(matvec, b, precond=None, tol=1e-5, maxiter=500):
    M = precond or _identity
    b = jnp.asarray(b, jnp.float32)
    bnorm = jnp.linalg.norm(b)

    def body(carry):
        x, r, rhat, p, v, rho, alpha, omega, it, _ = carry
        rho_new = jnp.vdot(rhat, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = r + beta * (p - omega * v)
        phat = M(p)
        v = matvec(phat)
        alpha = rho_new / jnp.vdot(rhat, v)
        s = r - alpha * v
        shat = M(s)
        t = matvec(shat)
        omega = jnp.vdot(t, s) / jnp.vdot(t, t)
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        return x, r, rhat, p, v, rho_new, alpha, omega, it + 1, jnp.linalg.norm(r)

    def cond(carry):
        *_, it, rnorm = carry
        return (rnorm > tol * bnorm) & (it < maxiter) & jnp.isfinite(rnorm)

    x0 = jnp.zeros_like(b)
    r0 = b
    carry = (
        x0, r0, r0, jnp.zeros_like(b), jnp.zeros_like(b),
        jnp.float32(1), jnp.float32(1), jnp.float32(1), jnp.int32(0), jnp.linalg.norm(r0),
    )
    out = jax.lax.while_loop(cond, body, carry)
    x, *_, it, rnorm = out
    rel = float(rnorm / bnorm)
    return SolveResult(np.asarray(x), int(it), rel, rel <= tol * 1.01, np.asarray([rel]))


# --------------------------------------------------------------------------
# Restarted GMRES(m) with right preconditioning
# --------------------------------------------------------------------------
def gmres(matvec, b, precond=None, restart=30, tol=1e-5, maxiter=20):
    """maxiter counts *outer* restarts. Solves A (M^{-1} u) = b, x = M^{-1} u."""
    M = precond or _identity
    b = jnp.asarray(b, jnp.float32)
    n = b.shape[0]
    bnorm = float(jnp.linalg.norm(b))
    m = restart

    @jax.jit
    def inner(x0):
        r0 = b - matvec(x0)
        beta = jnp.linalg.norm(r0)
        V = jnp.zeros((m + 1, n), jnp.float32).at[0].set(r0 / beta)
        H = jnp.zeros((m + 1, m), jnp.float32)

        def arnoldi(carry, j):
            V, H = carry
            w = matvec(M(V[j]))
            # modified Gram-Schmidt
            def mgs(i, wh):
                w, H = wh
                hij = jnp.vdot(V[i], w) * (i <= j)
                H = H.at[i, j].set(hij)
                return w - hij * V[i], H
            w, H = jax.lax.fori_loop(0, m + 1, lambda i, wh: mgs(i, wh), (w, H))
            hnext = jnp.linalg.norm(w)
            H = H.at[j + 1, j].set(hnext)
            V = V.at[j + 1].set(w / jnp.maximum(hnext, 1e-30))
            return (V, H), hnext

        (V, H), _ = jax.lax.scan(arnoldi, (V, H), jnp.arange(m))
        # solve min || beta e1 - H y ||
        e1 = jnp.zeros(m + 1, jnp.float32).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(H, e1, rcond=None)
        u = V[:m].T @ y
        x = x0 + M(u)
        rnorm = jnp.linalg.norm(b - matvec(x))
        return x, rnorm

    x = jnp.zeros_like(b)
    history = []
    it = 0
    rnorm = bnorm
    for it in range(1, maxiter + 1):
        x, rn = inner(x)
        rnorm = float(rn)
        history.append(rnorm / bnorm)
        if rnorm <= tol * bnorm:
            break
    rel = rnorm / bnorm
    return SolveResult(np.asarray(x), it * m, rel, rel <= tol * 1.01, np.asarray(history))


def solve_with_ilu(a, b, k=1, method="gmres", backend="jax", tol=1e-5,
                   band_rows=32, **kw):
    """End-to-end: factorize with ILU(k), then solve. Returns (SolveResult, fact)."""
    from .api import ilu
    from .triangular import make_triangular_solver

    cols, vals = csr_to_ell_arrays(a)
    matvec = make_ell_matvec(cols, vals, a.n)
    fact = None
    precond = None
    if k is not None:
        fact = ilu(a, k, backend=backend, band_rows=band_rows)
        precond = make_triangular_solver(fact.pattern, fact.vals)
    fn = {"gmres": gmres, "bicgstab": bicgstab, "cg": cg}[method]
    res = fn(matvec, jnp.asarray(b, jnp.float32), precond, tol=tol, **kw)
    return res, fact
