"""Symbolic ILU(k) (Phase I): levels, fills, PILU(1) equivalence."""
import numpy as np
import pytest

from repro.core import (
    matgen,
    pilu1_symbolic,
    poisson_2d,
    symbolic_ilu_k,
)
from repro.core.symbolic import symbolic_ilu_k_bruteforce


def _pattern_to_level_matrix(pat):
    INF = np.int64(10**9)
    out = np.full((pat.n, pat.n), INF, dtype=np.int64)
    for j in range(pat.n):
        cols, levs = pat.row(j)
        out[j, cols] = levs
    return out


@pytest.mark.parametrize("rule", ["sum", "max"])
@pytest.mark.parametrize("k", [0, 1, 2, 3])
def test_matches_bruteforce_random(k, rule):
    a = matgen(60, density=0.08, seed=k + 17)
    pat = symbolic_ilu_k(a, k, rule=rule)
    pat.validate()
    got = _pattern_to_level_matrix(pat)
    want = symbolic_ilu_k_bruteforce(a, k, rule=rule)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("k", [0, 1, 2])
def test_matches_bruteforce_poisson(k):
    a = poisson_2d(7)
    pat = symbolic_ilu_k(a, k)
    got = _pattern_to_level_matrix(pat)
    want = symbolic_ilu_k_bruteforce(a, k)
    np.testing.assert_array_equal(got, want)


def test_k0_is_pattern_of_a():
    a = matgen(80, density=0.05, seed=3)
    pat = symbolic_ilu_k(a, 0)
    assert pat.nnz == a.nnz
    np.testing.assert_array_equal(pat.indices, a.indices)
    assert np.all(pat.levels == 0)


def test_monotone_in_k():
    """Pattern(k) is a subset of pattern(k+1); levels never increase."""
    a = matgen(70, density=0.06, seed=5)
    prev = None
    for k in range(0, 4):
        lev = _pattern_to_level_matrix(symbolic_ilu_k(a, k))
        if prev is not None:
            assert np.all((prev < 10**9) <= (lev < 10**9)), "pattern must grow with k"
            both = (prev < 10**9)
            assert np.all(lev[both] <= prev[both])
        prev = lev


@pytest.mark.parametrize("rule", ["sum", "max"])
def test_pilu1_equals_general_k1(rule):
    """PILU(1) (paper SIV-F) must equal the general algorithm at k=1."""
    for seed in range(4):
        a = matgen(90, density=0.05, seed=seed)
        p_gen = symbolic_ilu_k(a, 1, rule=rule)
        p_fast = pilu1_symbolic(a, rule=rule)
        np.testing.assert_array_equal(p_gen.indptr, p_fast.indptr)
        np.testing.assert_array_equal(p_gen.indices, p_fast.indices)
        np.testing.assert_array_equal(p_gen.levels, p_fast.levels)


def test_pilu1_structured():
    a = poisson_2d(9)
    p_gen = symbolic_ilu_k(a, 1)
    p_fast = pilu1_symbolic(a)
    np.testing.assert_array_equal(p_gen.indices, p_fast.indices)
    np.testing.assert_array_equal(p_gen.levels, p_fast.levels)


def test_fill_grows_with_k_measured():
    """Fig 6 premise: fill count increases with k."""
    a = matgen(200, density=0.03, seed=11)
    nnz = [symbolic_ilu_k(a, k).nnz for k in range(4)]
    assert nnz == sorted(nnz)
    assert nnz[3] > nnz[0]
