"""Error-feedback gradient compression for cross-pod (DCN) all-reduces.

Top-k magnitude sparsification with local error feedback [Stich et al.] —
the distributed-optimization trick flagged in DESIGN.md §6 for the
``pod`` axis, where per-link bandwidth is ~10x below ICI. Off by default;
enabled per-run (``--compress-grads``) and in the multi-pod §Perf study.

Two forms:
* stateful: ``(grads, err) -> (compressed, new_err)`` — the real EF loop,
* stateless demo: ``ef_compress_tree(grads)`` — used inside one jitted step
  when the caller does not carry compressor state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_sparsify(g, frac: float = 0.05):
    """Keep the top-|frac| magnitude entries of g (flattened)."""
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(g.shape)


def ef_step(g, err, frac: float = 0.05):
    """One error-feedback step: compress (g + err), remember the residual."""
    acc = g.astype(jnp.float32) + err
    comp = topk_sparsify(acc, frac)
    return comp.astype(g.dtype), acc - comp


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress_tree(grads, frac: float = 0.05):
    """Stateless form (error term returned, not carried)."""
    outs = jax.tree.map(lambda g: ef_step(g, jnp.zeros(g.shape, jnp.float32), frac), grads,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    comp = jax.tree.map(lambda o: o[0], outs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], outs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, err


def int8_quantize(g):
    """Symmetric per-tensor int8 quantization (alternative compressor)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q, scale):
    return q.astype(jnp.float32) * scale
