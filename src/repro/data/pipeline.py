"""Deterministic synthetic token pipeline, host-sharded.

Generates language-model batches on the host with a counter-based PRNG, so:

* every (step, host) pair maps to a unique, reproducible batch slice —
  restart at step k regenerates exactly the batch stream from step k
  (checkpoint/restart determinism, DESIGN.md §6);
* each host materializes only its slice of the global batch
  (``host_index/host_count``), the way a multi-host pod feeds data;
* a background prefetch thread keeps ``prefetch`` batches ready.

Synthetic text = Zipf-distributed tokens with short-range structure
(repeat-previous with prob 0.2) — enough signal that training loss visibly
drops in the examples.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 host_index: int = 0, host_count: int = 1, seed: int = 1234):
        assert global_batch % host_count == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // host_count
        self.host_index = host_index
        self.host_count = host_count
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, self.host_index]))
        B, S = self.local_batch, self.seq_len
        zipf = rng.zipf(1.3, size=(B, S + 1))
        toks = np.minimum(zipf, self.vocab - 1).astype(np.int32)
        rep = rng.random((B, S + 1)) < 0.2
        for t in range(1, S + 1):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        return {"tokens": toks[:, :S], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a step-indexed source."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            batch = self.source.batch_at(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> Dict[str, np.ndarray]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
