"""repro.data"""
