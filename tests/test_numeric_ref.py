"""Numeric ILU(k) oracle (Phase II): correctness of the sequential sweep."""
import numpy as np
import pytest

from repro.core import (
    ilu_residual,
    matgen,
    numeric_ilu_dense_oracle,
    numeric_ilu_ref,
    poisson_2d,
    split_lu,
    symbolic_ilu_k,
)


@pytest.mark.parametrize("k", [0, 1, 2, 3])
def test_sparse_matches_dense_oracle(k):
    a = matgen(50, density=0.1, seed=k)
    pat = symbolic_ilu_k(a, k)
    got = numeric_ilu_ref(a, pat)
    dense = numeric_ilu_dense_oracle(a.to_dense(), pat.dense_mask())
    # bitwise: both paths are f32 mul-then-sub in the same order
    for j in range(pat.n):
        cols, _ = pat.row(j)
        s, e = pat.indptr[j], pat.indptr[j + 1]
        np.testing.assert_array_equal(got[s:e], dense[j, cols])


def test_full_pattern_is_exact_lu():
    """With k large enough the pattern fills completely -> exact LU."""
    rng = np.random.default_rng(0)
    n = 24
    dense = rng.uniform(-1, 1, size=(n, n)).astype(np.float32)
    dense += np.diag(np.abs(dense).sum(1) + 1).astype(np.float32)
    from repro.core import CSRMatrix

    a = CSRMatrix.from_dense(dense)
    pat = symbolic_ilu_k(a, n)  # full fill
    vals = numeric_ilu_ref(a, pat)
    L, U = split_lu(pat, vals)
    np.testing.assert_allclose((L @ U).toarray(), dense, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("k", [0, 1, 2])
def test_ilu_property_on_pattern(k):
    """(L@U)_ij == a_ij for every (i,j) in the filled pattern."""
    a = matgen(80, density=0.05, seed=7)
    pat = symbolic_ilu_k(a, k)
    vals = numeric_ilu_ref(a, pat)
    assert ilu_residual(a, pat, vals) < 5e-4


def test_poisson_ilu0_known_structure():
    a = poisson_2d(6)
    pat = symbolic_ilu_k(a, 0)
    vals = numeric_ilu_ref(a, pat)
    assert np.isfinite(vals).all()
    assert ilu_residual(a, pat, vals) < 1e-5


def test_diagonal_stays_nonzero():
    """Diagonal dominance => breakdown-free (paper SVI)."""
    for seed in range(3):
        a = matgen(120, density=0.04, seed=seed)
        pat = symbolic_ilu_k(a, 2)
        vals = numeric_ilu_ref(a, pat)
        diag = vals[pat.indptr[:-1] + pat.diag_ptr]
        assert np.all(np.abs(diag) > 1e-8)
