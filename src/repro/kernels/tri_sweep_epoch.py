"""Pallas TPU kernel: device-local epoch-fused wavefront sweep.

One launch runs every wavefront level of one *collective epoch* of the
band-partitioned triangular solve (see ``repro.core.triangular``
``ShardedTriangularEngine`` and DESIGN.md §5.5): the device-local sweep
vector ``[local slots | ingress halo | scratch]`` stays resident while the
epoch's levels scan over it — per level one gather, one masked lane-ordered
reduction, one contiguous ``dynamic_update_slice``. The collectives between
epochs stay outside the kernel (XLA owns the exchange); the kernel is
exactly the compute the device performs between two exchanges.

The kernel body deliberately *shares* its implementation with the jnp
engine path (``repro.core.triangular.epoch_sweep_jnp``, all reductions via
``masked_lane_sum``) so the two cannot drift: bit-identity with the
single-device sweep is by construction.

Caveat: this container runs the kernel in interpret mode
(``REPRO_PALLAS_INTERPRET=1``, the default); the sharded engine keeps the
jnp path as its default on CPU (one interpret-mode launch per epoch is an
interpreter round-trip per epoch — profitable only compiled on real TPU
hardware, where the epoch's levels fuse into one VMEM-resident launch).
``REPRO_DISABLE_PALLAS=1`` falls back to the shared jnp implementation.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(*refs, start, limit, has_diag):
    from repro.core.triangular import epoch_sweep_jnp

    if has_diag:
        x_ref, c_ref, v_ref, r_ref, d_ref, o_ref = refs
        diag = d_ref[...]
    else:
        x_ref, c_ref, v_ref, r_ref, o_ref = refs
        diag = None
    o_ref[...] = epoch_sweep_jnp(
        x_ref[...], c_ref[...], v_ref[...], r_ref[...], diag, start, limit
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("start", "limit", "interpret"))
def epoch_sweep(x, cols, vals, rhs, diag=None, *, start, limit, interpret=True):
    """Run one epoch's levels over the device-local sweep vector ``x``.

    ``cols``/``vals``: (L_e, maxr, W) local-address dependencies + values;
    ``rhs``: (L_e, maxr); ``diag``: (L_e, maxr) for the U sweep or None for
    the (unit-diagonal) L sweep; ``start``: the epoch's first write offset;
    ``limit``: the scratch address (mask bound). Returns the updated x.
    """
    args = (x, cols, vals, rhs) + (() if diag is None else (diag,))
    return pl.pallas_call(
        functools.partial(_kernel, start=start, limit=limit,
                          has_diag=diag is not None),
        in_specs=[pl.BlockSpec(a.shape, lambda *_, s=a.shape: (0,) * len(s))
                  for a in args],
        out_specs=pl.BlockSpec(x.shape, lambda *_: (0,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=resolve_interpret(interpret),
    )(*args)
