"""repro.optim"""
