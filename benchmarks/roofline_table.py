"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(pattern="experiments/dryrun/*.json"):
    cells = {}
    for f in sorted(glob.glob(pattern)):
        d = json.load(open(f))
        name = os.path.basename(f)[:-5]
        parts = name.split("__")
        tag = parts[3] if len(parts) > 3 else "baseline"
        cells[(d["arch"], d["shape"], parts[2], tag)] = d
    return cells


def fmt(v, nd=3):
    if v is None:
        return "—"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-3 or abs(v) >= 1e4:
            return f"{v:.2e}"
        return f"{v:.{nd}g}"
    return str(v)


def roofline_table(cells, pod="pod1", tag="baseline"):
    lines = [
        "| arch | shape | kind | compute s | memory s | collective s | bottleneck"
        " | MODEL_FLOPs | useful | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, p, t), d in sorted(cells.items()):
        if p != pod or t != tag:
            continue
        if d.get("status") == "skipped":
            lines.append(
                f"| {arch} | {shape} | — | — | — | — | skipped: sub-quadratic-only shape"
                " | — | — | — |"
            )
            continue
        if d.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | — | ERROR | | | | | | |")
            continue
        lines.append(
            "| {a} | {s} | {k} | {c} | {m} | {co} | **{b}** | {mf} | {u} | {f} |".format(
                a=arch, s=shape, k=d.get("kind", ""),
                c=fmt(d["compute_s"]), m=fmt(d["memory_s"]), co=fmt(d["collective_s"]),
                b=d["bottleneck"], mf=fmt(d["model_flops"]),
                u=fmt(d["useful_ratio"]), f="yes" if d.get("fits_hbm_16g") else "NO",
            )
        )
    return "\n".join(lines)


def dryrun_table(cells, pod="pod2"):
    lines = [
        "| arch | shape | status | args GB/dev | temps GB/dev | compile s |",
        "|---|---|---|---|---|---|",
    ]
    for (arch, shape, p, t), d in sorted(cells.items()):
        if p != pod or t != "baseline":
            continue
        if d.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | skipped (justified) | — | — | — |")
            continue
        ms = d.get("memory_stats_production", d.get("memory_stats", {}))
        lines.append(
            "| {a} | {s} | {st} | {arg} | {tmp} | {c} |".format(
                a=arch, s=shape, st=d["status"],
                arg=fmt(ms.get("argument_bytes", 0) / 1e9),
                tmp=fmt(ms.get("temp_bytes", 0) / 1e9),
                c=fmt(d.get("compile_a_s")),
            )
        )
    return "\n".join(lines)


if __name__ == "__main__":
    cells = load()
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        print(roofline_table(cells))
    elif which == "dryrun2":
        print(dryrun_table(cells, "pod2"))
    else:
        for key in sorted(cells):
            if key[3] != "baseline":
                d = cells[key]
                print(key, d.get("status"), "comp", fmt(d.get("compute_s")),
                      "mem", fmt(d.get("memory_s")), "coll", fmt(d.get("collective_s")),
                      "useful", fmt(d.get("useful_ratio")), "fits", d.get("fits_hbm_16g"))
