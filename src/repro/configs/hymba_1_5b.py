"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer, sliding
window attention (so long_500k lowers), ssm_state=16. [arXiv:2411.13676].

Adaptation note (DESIGN.md §8): Hymba keeps 3 global-attention layers; we
use SWA for all layers so the 512k decode cache stays bounded, and note the
deviation. 25 heads % 16 != 0 -> attention TP replicated.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_real=32001,
    rope_theta=10000.0,
    sliding_window=2048,
    hybrid_parallel_ssm=True,
    ssm_state=16,
    ssm_inner=1600,
    mlp_act="swiglu",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
