"""starcoder2-15b [dense] — GQA kv=4, RoPE, GELU. [arXiv:2402.19173]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_real=49152,
    rope_theta=100000.0,
    qkv_bias=True,
    mlp_act="gelu",
    norm="layernorm",
)
