"""Paper core: bit-compatible task-oriented parallel ILU(k) (TOP-ILU)."""

from .sparse import CSRMatrix, ELLMatrix, ILUPattern, split_lu  # noqa: F401
from .matgen import matgen, poisson_2d, convection_diffusion_2d  # noqa: F401
from .symbolic import symbolic_ilu_k, symbolic_ilu_k_ref, pilu1_symbolic  # noqa: F401
from .factor_plan import FactorPlan, build_factor_plan, factor_plan_for  # noqa: F401
from .numeric_ref import numeric_ilu_ref, numeric_ilu_dense_oracle, ilu_residual  # noqa: F401
from .ordering import (  # noqa: F401
    Ordering,
    choose_band_rows,
    fusion_aware_ordering,
    natural_ordering,
    permute_csr,
    rcm_ordering,
)
from .inverse_ref import (  # noqa: F401
    inverse_apply_ref,
    inverse_pattern_ref,
    inverse_values_ref,
)
from .inverse import (  # noqa: F401
    InversePrecondApply,
    ShardedInversePrecondApply,
    build_inverse_plan,
    inverse_comm_model,
    modeled_apply_cost,
    resolve_precond_method,
)
