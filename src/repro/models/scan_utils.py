"""Chunked-remat time scan for recurrent blocks (mamba / mLSTM / sLSTM).

A plain `lax.scan` over T timesteps saves every step's carry for the
backward pass — for mLSTM that is (T, B, H, hd, hd) f32, which is what blew
the 16 GiB budget on xlstm train_4k (EXPERIMENTS.md §4.8). Scanning over
T/chunk *chunks* with a rematerialized inner scan stores one carry per
chunk and recomputes inside the chunk: memory ÷ chunk, compute × ~2 on the
recurrence only — the classic sequence-dim gradient checkpoint.
"""
from __future__ import annotations

import jax
from jax import lax


def chunked_remat_scan(step, init, xs, chunk: int = 128):
    """Equivalent to ``lax.scan(step, init, xs)`` with chunked remat.

    xs: pytree with leading time axis T (equal across leaves). Falls back
    to a plain scan when T <= chunk or T % divisor behavior would pad.
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    c = min(chunk, T)
    while T % c:
        c -= 1
    if c <= 1 or c == T:
        return lax.scan(step, init, xs)
    n = T // c

    def chunk_body(carry, xs_chunk):
        return lax.scan(step, carry, xs_chunk)

    chunk_body = jax.checkpoint(chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    xs_r = jax.tree.map(lambda t: t.reshape(n, c, *t.shape[1:]), xs)
    carry, ys = lax.scan(chunk_body, init, xs_r)
    ys = jax.tree.map(lambda t: t.reshape(T, *t.shape[2:]), ys)
    return carry, ys
