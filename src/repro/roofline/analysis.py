"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the assignment:

    compute    = HLO_FLOPs_per_device            / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device            / HBM_bw_per_chip
    collective = collective_bytes_per_device     / link_bw_per_chip

`compiled.cost_analysis()` reports **per-device** FLOPs/bytes for SPMD
modules (verified empirically on this jax version), so no chip division is
needed. Collective bytes are parsed from the post-SPMD optimized HLO: for
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we take output-shape bytes and the replica-group size g
and apply the standard ring-algorithm wire models:

    all-gather        (g-1)/g * out_bytes
    all-reduce        2*(g-1)/g * out_bytes
    reduce-scatter    (g-1) * out_bytes        (out is the scattered shard)
    all-to-all        (g-1)/g * out_bytes
    collective-permute out_bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^ ]*,?\s*)+)\s*(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_per_device(hlo_text: str) -> Dict[str, float]:
    """Sum wire bytes per device by collective kind."""
    out: Dict[str, float] = {
        "all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # async pair: count only the -start
        nbytes = _shape_bytes(shapes)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            first = gm.group(1).split("},{")[0].strip("{}")
            g = len([t for t in first.split(",") if t.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        g = max(g, 1)
        if kind == "all-reduce":
            out[kind] += 2 * (g - 1) / g * nbytes
        elif kind == "all-gather":
            out[kind] += (g - 1) / g * nbytes
        elif kind == "reduce-scatter":
            out[kind] += (g - 1) * nbytes
        elif kind == "all-to-all":
            out[kind] += (g - 1) / g * nbytes
        else:  # collective-permute
            out[kind] += nbytes
    return out


def collective_op_counts(hlo_text: str) -> Dict[str, int]:
    """Count collective *ops* per device by kind (async pairs count once).

    The epoch-fused sweep asserts its collective count against the host
    epoch model with this — XLA cannot merge the exchanges (each epoch
    depends on the previous one), so the compiled count equals the
    schedule's.
    """
    out: Dict[str, int] = {
        "all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
        "all-to-all": 0, "collective-permute": 0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count only the -start
        out[m.group(2)] += 1
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    memory_stats: Dict[str, float]

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jax versions (older
    versions return ``[dict]``, jax>=0.4.3x a bare dict or list)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def extract_costs(compiled) -> Dict[str, float]:
    """Per-device flops / bytes / per-kind collective bytes of one module."""
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes_per_device(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        **{f"coll/{k}": v for k, v in coll.items()},
    }


def extrapolate_costs(
    c1: Dict[str, float], c2: Dict[str, float], n_layers: int
) -> Dict[str, float]:
    """Layer-homogeneous extrapolation: cost(L) = c1 + (L-1)*(c2-c1).

    c1/c2 are 1-layer/2-layer unrolled modules. Exact for stacks whose
    layers are identical (all ten assigned archs as configured)."""
    out = {}
    for k in c1:
        per_layer = c2[k] - c1[k]
        out[k] = c1[k] + (n_layers - 1) * max(per_layer, 0.0)
    return out


def analyze_costs(costs: Dict[str, float], *, arch: str, shape: str, mesh_name: str,
                  chips: int, model_flops_global: float, memory_stats: Dict[str, float],
                  corrections: Optional[Dict[str, float]] = None) -> RooflineReport:
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    if corrections:
        flops_dev += corrections.get("flops", 0.0)
        bytes_dev += corrections.get("bytes", 0.0)
    coll = {k.split("/", 1)[1]: v for k, v in costs.items() if k.startswith("coll/")}
    coll_total = sum(coll.values())
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_total / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_global / (flops_dev * chips) if flops_dev else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collective_bytes=coll_total, collective_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops_global,
        useful_ratio=useful, memory_stats=memory_stats,
    )


def recurrent_scan_correction(cfg, shape_name: str, chips: int) -> Dict[str, float]:
    """Analytic per-device FLOPs/bytes for time-step `lax.scan` recurrences
    (mamba / mLSTM / sLSTM), which XLA cost_analysis counts exactly once.

    Only the train/prefill shapes need this (decode is a single step, fully
    counted). Costs are per full sequence, batch-sharded over the dp axes.
    """
    from repro.configs.base import SHAPES

    seq, gbatch, kind = SHAPES[shape_name]
    if kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    # tokens per device (batch shards over dp; model axis replicates tokens)
    dp = max(chips // 16, 1)  # model axis is 16 on the production meshes
    tokens = seq * gbatch / dp
    mult = 3.0 if kind == "train" else 1.0  # fwd + ~2x bwd
    flops = 0.0
    bytes_ = 0.0
    if cfg.hybrid_parallel_ssm and cfg.ssm_state:
        di = (cfg.ssm_inner or cfg.d_model) / 16  # di sharded over model
        N = cfg.ssm_state
        per_tok = 9.0 * di * N
        flops += cfg.n_layers * per_tok * tokens
        bytes_ += cfg.n_layers * 8.0 * di * N * tokens  # state read+write f32
    if cfg.family == "ssm" and cfg.block_types:
        H = cfg.n_heads
        hd_m = 2 * cfg.d_model / H
        hd_s = cfg.d_model / H
        n_m = sum(1 for t in cfg.block_types if t == "m")
        n_s = len(cfg.block_types) - n_m
        flops += n_m * 5.0 * H * hd_m * hd_m * tokens
        bytes_ += n_m * 8.0 * H * hd_m * hd_m * tokens
        flops += n_s * (8.0 * H * hd_s * 4 * hd_s + 20.0 * cfg.d_model) * tokens
        bytes_ += n_s * 16.0 * cfg.d_model * tokens
    return {"flops": flops * mult, "bytes": bytes_ * mult}


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D for training (N=active params), 2*N*D for decode
    forward-only, per the assignment's definition."""
    from repro.configs.base import SHAPES

    seq, gbatch, kind = SHAPES[shape_name]
    counts = cfg.param_count()
    n_active = counts["active"]
    if kind == "train":
        return 6.0 * n_active * seq * gbatch
    if kind == "prefill":
        return 2.0 * n_active * seq * gbatch
    return 2.0 * n_active * 1 * gbatch  # decode: one token per sequence
