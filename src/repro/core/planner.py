"""Static planning layer — the bridge from Phase I to the device.

This module is the shared *plan* stage of the plan→compile→execute
pipeline (DESIGN.md §3): every schedule the executors consume is built
here (or from here) by the same vectorized primitives:

* :func:`wavefront_schedule` — the Kahn frontier scheduler. Given a static
  dependency edge list it groups items (rows, or bands) into level-major
  wavefronts: everything in wave ``t`` depends only on waves ``< t``, so a
  wave executes as one batched step. The triangular-solve plan
  (`repro.core.triangular.TriangularPlan`), the factorization plan
  (`repro.core.factor_plan.FactorPlan`), the vectorized symbolic frontier
  (`repro.core.symbolic`), and the band superstep schedule below are all
  instances of this one scheduler.
* :func:`pivot_gather_maps` — precomputed slot-space gathers for pivot
  application: for every (row, pivot) pair, the destination lane of each
  pivot-row tail entry inside the reduced row. This replaces the per-pivot
  ``searchsorted`` the numeric engines used to perform on device —
  O(1) gathers at run time, one vectorized host pass at plan time.

The paper organizes the matrix as *bands* of consecutive rows (§IV-A,
Fig 3); the *frontier* is the last completely-reduced row (Def 4.1); bands
are owned round-robin by nodes (static load balancing, §IV-D). On TPU
everything must be static-shaped, so :func:`make_plan` turns a symbolic
pattern (`ILUPattern`) into a :class:`NumericPlan`:

* padded ELL storage (``cols``/``diag_pos``) — static structure,
* per-row *band pivot offsets* ``pivot_start[j, b]`` = number of entries of
  row j strictly left of column ``b*band_rows`` (clipped to the diagonal),
* the precomputed pivot gather maps (``piv_rows``/``piv_dst``),
* the *band superstep schedule*: band-dependency wavefronts grouped by
  owning device, so independent bands factor concurrently and one
  collective per superstep replaces one broadcast per band,
* the *halo exchange schedule* (:func:`_halo_exchange_schedule`): the
  sharded-value layout — per-device local storage, halo row sets, and
  per-superstep egress/ingress maps so devices exchange only the finalized
  pivot rows another device actually consumes (DESIGN.md §5),
* static trip-count bounds and the device-major band permutation.

Because the pattern is planning output, column indices are *replicated*
device-side rather than communicated — the paper ships 8 bytes/entry
(column + value, §V-E); we ship 4 (value only). Recorded in §Perf.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .sparse import CSRMatrix, ILUPattern

#: Column sentinel for ELL padding. Must be larger than any valid column so
#: padded rows remain sorted.
COL_SENTINEL = np.int32(2**30)


# --------------------------------------------------------------------------
# shared vectorized scheduling primitives
# --------------------------------------------------------------------------
def expand_spans(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenate ``[arange(s, s+l) for s, l in zip(starts, lens)]`` without
    a Python loop (repeat/cumsum idiom)."""
    starts = np.asarray(starts, dtype=np.int64)
    lens = np.asarray(lens, dtype=np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    base = np.repeat(starts, lens)
    cum = np.cumsum(lens)
    within = np.arange(total, dtype=np.int64) - np.repeat(cum - lens, lens)
    return base + within


def wavefront_schedule(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Vectorized Kahn frontier over ``n`` items with edges ``dst`` waits on
    ``src``. Returns a level-major ``(n_levels, max_items)`` int32 table of
    item ids, ``n``-padded, items ascending within each wave.

    Wave ``t`` is exactly the set of items whose dependencies all resolved
    in waves ``< t`` (equal to the classical ``level[j] = 1 +
    max(level[deps])`` recursion), so the output matches the sequential
    per-item computation level for level.
    """
    if n == 0:
        return np.zeros((0, 1), dtype=np.int32)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    indeg = np.bincount(dst, minlength=n).astype(np.int64)
    order_e = np.argsort(src, kind="stable")
    src_s, dst_s = src[order_e], dst[order_e]
    starts = np.searchsorted(src_s, np.arange(n))
    ends = np.searchsorted(src_s, np.arange(n) + 1)
    level = np.zeros(n, dtype=np.int64)
    front = np.nonzero(indeg == 0)[0]
    lev = 0
    assigned = 0
    while front.size:
        level[front] = lev
        assigned += front.size
        elens = ends[front] - starts[front]
        total = int(elens.sum())
        if total:
            children = dst_s[expand_spans(starts[front], elens)]
            np.subtract.at(indeg, children, 1)
            cand = np.unique(children)
            front = cand[indeg[cand] == 0]
        else:
            front = np.zeros(0, dtype=np.int64)
        lev += 1
    if assigned != n:  # cyclic dependencies — impossible for triangular DAGs
        raise ValueError("dependency cycle in wavefront schedule")
    nlev = lev
    order = np.argsort(level, kind="stable")  # ids ascending within each level
    counts = np.bincount(level, minlength=nlev)
    maxr = max(int(counts.max()), 1)
    starts = np.zeros(nlev, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    out = np.full((nlev, maxr), n, dtype=np.int32)  # n = scratch id
    rank = np.arange(n) - starts[level[order]]
    out[level[order], rank] = order
    return out


def ragged_group(keys: np.ndarray, items: np.ndarray, n_groups: int, pad) -> tuple:
    """Pack ``items`` into an ``(n_groups, M)`` table by ``keys`` (``M`` =
    largest group, ``pad``-filled), items ascending within each group.
    Returns ``(table, counts)`` — the one ragged-ownership layout behind
    the factorization halo sets, the sweep epoch read sets, and the final
    output assembly."""
    keys = np.asarray(keys, np.int64)
    items = np.asarray(items, np.int64)
    cnt = np.bincount(keys, minlength=n_groups)
    M = int(cnt.max(initial=0))
    start = np.zeros(n_groups, np.int64)
    np.cumsum(cnt[:-1], out=start[1:])
    table = np.full((n_groups, M), np.int64(pad), np.int64)
    if items.size:
        order = np.lexsort((items, keys))
        k_s, it_s = keys[order], items[order]
        table[k_s, np.arange(items.size) - start[k_s]] = it_s
    return table, cnt


def halo_positions(halo_sorted: np.ndarray, flat: np.ndarray, base: int,
                   scratch: int) -> np.ndarray:
    """Receiver scatter addresses: ``base`` + position of each ``flat``
    item in one device's sorted halo list, ``scratch`` when the item is
    absent from the halo or is payload padding (``flat < 0``)."""
    if halo_sorted.size == 0:
        return np.full(flat.shape, np.int64(scratch), np.int64)
    pos = np.searchsorted(halo_sorted, np.maximum(flat, 0))
    pos_c = np.minimum(pos, halo_sorted.size - 1)
    hit = (flat >= 0) & (pos < halo_sorted.size) & (halo_sorted[pos_c] == flat)
    return np.where(hit, base + pos_c, np.int64(scratch))


def wavefront_schedule_ell(dep_cols: np.ndarray, n: int) -> np.ndarray:
    """Wavefronts from sentinel-padded ELL dependency columns (lanes with
    ``dep_cols >= n`` carry no dependency)."""
    if n == 0:
        return np.zeros((0, 1), dtype=np.int32)
    valid = dep_cols < n
    dst, lane = np.nonzero(valid)
    src = dep_cols[dst, lane].astype(np.int64)
    return wavefront_schedule(src, dst, n)


def ell_from_pattern(pattern: ILUPattern, a: CSRMatrix, n_rows: int):
    """Vectorized scatter of A onto the filled pattern as padded ELL.

    Returns ``(cols, vals, diag_pos, row_len)`` with ``n_rows >= pattern.n``
    rows; rows past ``pattern.n`` are identity (unit diagonal) so divisions
    stay finite. ``cols`` is COL_SENTINEL-padded.
    """
    n = pattern.n
    rowlen = np.diff(pattern.indptr).astype(np.int64)
    W = max(int(rowlen.max(initial=0)), 1)
    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    pos = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[row_of]
    cols = np.full((n_rows, W), COL_SENTINEL, dtype=np.int32)
    vals = np.zeros((n_rows, W), dtype=np.float32)
    cols[row_of, pos] = pattern.indices
    # locate every A entry inside the (sorted, row-major) pattern
    big = np.int64(n_rows + 1)
    pkeys = row_of * big + pattern.indices.astype(np.int64)
    a_row_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(a.indptr))
    akeys = a_row_of * big + a.indices.astype(np.int64)
    apos = np.searchsorted(pkeys, akeys)
    assert np.array_equal(pkeys[apos], akeys), "A entry missing from pattern"
    vals[a_row_of, pos[apos]] = a.data
    diag_pos = np.zeros(n_rows, dtype=np.int32)
    row_len = np.zeros(n_rows, dtype=np.int32)
    diag_pos[:n] = pattern.diag_ptr
    row_len[:n] = rowlen
    if n_rows > n:
        pad = np.arange(n, n_rows)
        cols[pad, 0] = pad
        vals[pad, 0] = 1.0
        row_len[pad] = 1
    return cols, vals, diag_pos, row_len, pos[apos]


def pivot_gather_maps(cols: np.ndarray, diag_pos: np.ndarray):
    """Precomputed pivot gathers for the numeric engines.

    For every (row j, pivot lane p < diag_pos[j]) the pivot row id is the
    column value itself; ``dst[j, p, w]`` is the lane of row j that receives
    pivot row i's tail entry ``cols[i, w]`` (``W`` = dropped: not in row j's
    pattern, not strictly right of the pivot, or a padded lane).

    Returns ``(piv_rows (nr, MP) int32 [nr = scratch], piv_dlane (nr, MP)
    int32, dst (nr, MP, W) int32 in [0, W])``.
    """
    nr, W = cols.shape
    MP = max(int(diag_pos.max(initial=0)), 1)
    lanes = np.arange(MP)[None, :]
    pvalid = lanes < diag_pos[:, None]  # (nr, MP)
    piv_rows = np.where(pvalid, cols[:, :MP], nr).astype(np.int32)
    i_safe = np.minimum(piv_rows, nr - 1).astype(np.int64)
    piv_dlane = np.where(pvalid, diag_pos[i_safe], 0).astype(np.int32)
    # flat sorted keys of all valid ELL entries + their lane index
    valid = cols < COL_SENTINEL
    row_of, lane_of = np.nonzero(valid)
    big = np.int64(nr + 1)
    flat_keys = row_of.astype(np.int64) * big + cols[row_of, lane_of].astype(np.int64)
    # queries: every tail entry of every pivot row, keyed into the reduced row
    pivcols = cols[i_safe].astype(np.int64)  # (nr, MP, W)
    tail = pvalid[:, :, None] & (pivcols > i_safe[:, :, None]) & (pivcols < COL_SENTINEL)
    qkeys = np.where(
        tail, np.arange(nr, dtype=np.int64)[:, None, None] * big + pivcols, np.int64(-1)
    )
    qpos = np.searchsorted(flat_keys, qkeys.ravel())
    qpos_c = np.minimum(qpos, len(flat_keys) - 1)
    hit = (qpos < len(flat_keys)) & (flat_keys[qpos_c] == qkeys.ravel())
    dst = np.where(hit, lane_of[qpos_c], W).reshape(nr, MP, W).astype(np.int32)
    return piv_rows, piv_dlane, dst


def pivot_dst_flat(cols: np.ndarray, o_row: np.ndarray, o_piv: np.ndarray) -> np.ndarray:
    """Flat per-op destination-lane map for the pivot-op schedule.

    For op ``t`` (reduce row ``o_row[t]`` against pivot row ``o_piv[t]``),
    ``out[t, w]`` is the lane of the reduced row receiving pivot-row tail
    entry ``cols[o_piv[t], w]`` (``W`` = dropped: not in the reduced row's
    pattern, not strictly right of the pivot, or a padded lane). The last
    row (index ``n_ops``) is the all-dropped pad op. O(nnz(L)·W) memory —
    exact op count, no dense (rows × max-pivots) blowup.
    """
    n, W = cols.shape
    o_row = np.asarray(o_row, np.int64)
    o_piv = np.asarray(o_piv, np.int64)
    n_ops = o_row.size
    valid = cols < COL_SENTINEL
    row_idx, lane_idx = np.nonzero(valid)
    big = np.int64(n + 1)
    flat_keys = row_idx.astype(np.int64) * big + cols[row_idx, lane_idx].astype(np.int64)
    pivcols = cols[o_piv].astype(np.int64)  # (n_ops, W)
    tail = (pivcols > o_piv[:, None]) & (pivcols < COL_SENTINEL)
    qkeys = np.where(tail, o_row[:, None] * big + pivcols, np.int64(-1))
    qpos = np.searchsorted(flat_keys, qkeys.ravel())
    qpos_c = np.minimum(qpos, max(len(flat_keys) - 1, 0))
    hit = (qpos < len(flat_keys)) & (flat_keys[qpos_c] == qkeys.ravel())
    dst = np.where(hit, lane_idx[qpos_c], W).reshape(n_ops, W).astype(np.int32)
    return np.concatenate([dst, np.full((1, W), W, np.int32)], axis=0)


# --------------------------------------------------------------------------
# the banded numeric plan (TOP-ILU execution unit)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class NumericPlan:
    n: int  # original dimension
    n_pad: int
    width: int  # ELL width W
    band_rows: int  # R
    n_bands: int  # B (padded to a multiple of n_devices)
    n_devices: int  # D
    k: int

    cols: np.ndarray  # (n_pad, W) int32, COL_SENTINEL padded
    diag_pos: np.ndarray  # (n_pad,) int32
    row_len: np.ndarray  # (n_pad,) int32
    a_vals: np.ndarray  # (n_pad, W) f32 — A scattered on the pattern
    a_scatter_lane: np.ndarray  # (a.nnz,) int64 — lane of each A entry (refactorize)
    pivot_start: np.ndarray  # (n_pad, B+1) int32
    band_of_row: np.ndarray  # (n_pad,) int32

    max_pivots_per_band: int  # bound for inter-band partial reductions
    max_intra_pivots: int  # bound for finishing a band

    # --- precomputed pivot gathers (shared execute-layer contract) --------
    max_piv: int  # MP: bound on pivots per row (== max diag_pos)
    piv_rows: np.ndarray  # (n_pad, MP) int32, n_pad-padded
    piv_dlane: np.ndarray  # (n_pad, MP) int32
    piv_dst: np.ndarray  # (n_pad, MP, W) int32 in [0, W]; W = dropped

    # --- band superstep schedule (wavefronts over the band DAG) -----------
    n_supersteps: int
    bands_per_superstep: int  # max bands a single device owns in one superstep
    superstep_bands: np.ndarray  # (n_sup, D, MPD) int32 band ids, B-padded

    # --- sharded value layout + halo exchange schedule (DESIGN.md §5) ------
    # Per-device value state is ``[local | halo | scratch]``: ``s_loc`` rows
    # of band-local storage, ``halo_size`` slots of *finalized foreign pivot
    # rows this device actually consumes*, and one write-off scratch row.
    # All addresses below are device-local indices into that state.
    s_loc: int  # local value rows per device (= n_bands//D * band_rows)
    halo_size: int  # H: max foreign pivot rows any single device consumes
    egress_max: int  # E: max rows one device ships in one superstep
    halo_rows: np.ndarray  # (D, H) int64 global row ids per device, sorted
    piv_addr: np.ndarray  # (n_pad, MP) int32 device-local pivot-read address
    egress_idx: np.ndarray  # (n_sup, D, E) int32 local gather addrs (pad=scratch)
    ingress_idx: np.ndarray  # (n_sup, D, D, E) int32 receiver halo addrs (pad=scratch)

    # --- band sharding (device-major permutation) -------------------------
    @property
    def bands_per_device(self) -> int:
        return self.n_bands // self.n_devices

    # --- sharded-memory model (README §memory, DESIGN.md §5) --------------
    @property
    def state_rows(self) -> int:
        """Rows of the per-device value state: local + halo + scratch."""
        return self.s_loc + self.halo_size + 1

    def per_device_value_bytes(self) -> int:
        """f32 value bytes each device holds during factorization
        (``O(n_pad*W/D + halo)`` — the sharded layout)."""
        return self.state_rows * self.width * 4

    def replicated_value_bytes(self) -> int:
        """What the pre-sharding engine held per device (``n_pad*W`` + scratch)."""
        return (self.n_pad + 1) * self.width * 4

    def halo_bytes_per_superstep(self, broadcast: str = "gather") -> int:
        """Wire bytes per device per superstep of the halo exchange
        (ring-algorithm models, matching ``repro.roofline.analysis``):
        all-gather of one (E, W) payload per device, or E*W per ppermute hop
        for the explicit directed ring — both ``(D-1) * E * W * 4``."""
        d, e, w = self.n_devices, self.egress_max, self.width
        if d <= 1 or self.halo_size == 0:
            return 0
        return (d - 1) * e * w * 4  # same for "gather" and "ring"

    def replicated_bytes_per_superstep(self) -> int:
        """Wire bytes/device/superstep of the old full-band all-gather."""
        d = self.n_devices
        if d <= 1:
            return 0
        return (d - 1) * self.bands_per_superstep * self.band_rows * self.width * 4

    def egress_sizes(self) -> np.ndarray:
        """Exact egress rows per (superstep, device) — the payload the
        fori-loop engine pads to the global max ``E``. Feeds the
        pad-to-max-E histogram in ``benchmarks/bench_topilu.py`` so the
        tradeoff flagged in ROADMAP.md is measured, not guessed."""
        scratch = self.s_loc + self.halo_size
        return (self.egress_idx != scratch).sum(axis=2)

    def band_to_slot(self) -> np.ndarray:
        """slot index (device-major) for each band: band b -> device b%D, slot b//D."""
        b = np.arange(self.n_bands)
        return (b % self.n_devices) * self.bands_per_device + b // self.n_devices

    def rows_device_major(self, x: np.ndarray) -> np.ndarray:
        """Reorder a row-indexed array into device-major band order."""
        perm = self.band_to_slot()
        banded = x.reshape(self.n_bands, self.band_rows, *x.shape[1:])
        out = np.empty_like(banded)
        out[perm] = banded
        return out.reshape(x.shape)

    def rows_from_device_major(self, x: np.ndarray) -> np.ndarray:
        perm = self.band_to_slot()
        banded = x.reshape(self.n_bands, self.band_rows, *x.shape[1:])
        return banded[perm].reshape(x.shape)

    def scatter_values(self, a: CSRMatrix) -> np.ndarray:
        """New A values (same structure) -> fresh (n_pad, W) pattern values.

        The refactorization path: fill entries zero, padding rows identity,
        A entries re-read from ``a.data`` through the cached lane map — so
        cached engines never bake stale values in.
        """
        vals = np.zeros_like(self.a_vals)
        if self.n_pad > self.n:
            vals[self.n:, 0] = 1.0  # identity padding rows
        rowlen = np.diff(a.indptr)
        row_of = np.repeat(np.arange(a.n, dtype=np.int64), rowlen)
        vals[row_of, self.a_scatter_lane] = a.data
        return vals


def _band_superstep_schedule(pivot_start, band_of_row, n_bands, n_devices):
    """Wavefronts over the band-dependency DAG, grouped by owning device.

    Band ``b`` waits on band ``b'`` iff some row of ``b`` has a pivot in
    ``b'`` (strictly earlier band). Bands in the same superstep share no
    dependencies, so they factor concurrently; grouping members by owner
    ``b % D`` gives each device its static slice of every superstep.
    Returns ``(n_sup, D, MPD)`` int32, padded with ``n_bands``.
    """
    counts = np.diff(pivot_start, axis=1)  # (n_pad, B)
    n_pad = counts.shape[0]
    counts = counts.copy()
    counts[np.arange(n_pad), band_of_row] = 0  # intra-band handled in-band
    jj, bb = np.nonzero(counts > 0)
    pairs = np.unique(band_of_row[jj].astype(np.int64) * n_bands + bb)
    dst = pairs // n_bands
    src = pairs - dst * n_bands
    waves = wavefront_schedule(src, dst, n_bands)  # (n_sup, maxr), B-padded
    n_sup = waves.shape[0]
    s_of, col = np.nonzero(waves < n_bands)
    b = waves[s_of, col].astype(np.int64)
    owner = b % n_devices
    order = np.lexsort((b, owner, s_of))
    s_s, o_s, b_s = s_of[order], owner[order], b[order]
    key = s_s * n_devices + o_s
    head = np.ones(len(key), bool)
    head[1:] = key[1:] != key[:-1]
    gstart = np.nonzero(head)[0]
    glen = np.diff(np.append(gstart, len(key)))
    mpd = max(int(glen.max(initial=0)), 1)
    rank = np.arange(len(key)) - np.repeat(gstart, glen)
    out = np.full((n_sup, n_devices, mpd), n_bands, dtype=np.int32)
    out[s_s, o_s, rank] = b_s
    return out


def _halo_exchange_schedule(piv_rows, diag_pos, band_of_row, superstep_bands,
                            band_rows, n_bands, n_devices):
    """Sharded-value layout: halo sets + per-superstep egress/ingress maps.

    Each device stores only the value rows of the bands it owns
    (``s_loc = n_bands/D * band_rows``) plus a *halo* of finalized foreign
    pivot rows it actually consumes (precomputed here from the pivot edges
    and the band superstep schedule). Per superstep, a device *egresses*
    the rows it just finalized that some other device's halo needs; every
    receiver scatters the payload into its halo slots via the ingress map.
    Because band ``b`` is scheduled strictly after every band it reads, a
    halo row is always exchanged before its first use.

    Returns ``(s_loc, H, E, halo_rows (D,H), piv_addr (n_pad,MP),
    egress_idx (n_sup,D,E), ingress_idx (n_sup,D,D,E))`` with all addresses
    device-local into the ``[local | halo | scratch]`` state; the scratch
    row ``s_loc + H`` absorbs every padded read and write.
    """
    n_pad = band_of_row.shape[0]
    D, R, B = n_devices, band_rows, n_bands
    n_sup = superstep_bands.shape[0]
    s_loc = (B // D) * R

    band64 = band_of_row.astype(np.int64)
    loc_of_row = (band64 // D) * R + np.arange(n_pad, dtype=np.int64) % R

    # superstep each band finalizes in
    sup_of_band = np.zeros(B, np.int64)
    flat_b = superstep_bands.reshape(n_sup, -1).astype(np.int64)
    s_of, _ = np.nonzero(flat_b < B)
    sup_of_band[flat_b[flat_b < B]] = s_of

    # every (reduced row j, pivot row i) edge
    MP = piv_rows.shape[1]
    jj, pp = np.nonzero(np.arange(MP)[None, :] < diag_pos[:, None])
    ii = piv_rows[jj, pp].astype(np.int64)
    own_j = band64[jj] % D
    own_i = band64[ii] % D
    foreign = own_j != own_i

    # per-device halo: sorted unique foreign pivot rows
    pairs = np.unique(own_j[foreign] * np.int64(n_pad) + ii[foreign])
    h_dev = pairs // n_pad
    h_row = pairs % n_pad
    halo_rows, h_cnt = ragged_group(h_dev, h_row, D, n_pad)
    H = halo_rows.shape[1]
    h_start = np.zeros(D, np.int64)
    np.cumsum(h_cnt[:-1], out=h_start[1:])
    scratch = s_loc + H

    # device-local pivot-read address per (j, p): own rows at their local
    # slot, foreign rows at their halo slot, invalid lanes at the scratch row
    piv_addr = np.full((n_pad, MP), scratch, np.int32)
    same = ~foreign
    piv_addr[jj[same], pp[same]] = loc_of_row[ii[same]]
    if foreign.any():
        slot = np.searchsorted(pairs, own_j[foreign] * np.int64(n_pad) + ii[foreign])
        piv_addr[jj[foreign], pp[foreign]] = s_loc + (slot - h_start[own_j[foreign]])

    # egress: each needed row ships once, at its owner's finalize superstep
    er = np.unique(h_row) if pairs.size else np.zeros(0, np.int64)
    e_key = sup_of_band[band64[er]] * D + band64[er] % D
    egress_rows, _ = ragged_group(e_key, er, n_sup * D, -1)
    E = egress_rows.shape[1]
    egress_rows = egress_rows.reshape(n_sup, D, E)
    egress_idx = np.where(
        egress_rows >= 0, loc_of_row[np.maximum(egress_rows, 0)], np.int64(scratch)
    ).astype(np.int32)

    # ingress: receiver d scatters each payload row present in its halo
    ingress_idx = np.empty((n_sup, D, D, E), np.int32)
    flat_r = egress_rows.reshape(-1)
    for d in range(D):
        hr = halo_rows[d][: h_cnt[d]]
        ingress_idx[:, d] = halo_positions(hr, flat_r, s_loc, scratch).reshape(
            n_sup, D, E).astype(np.int32)
    return s_loc, H, E, halo_rows, piv_addr, egress_idx, ingress_idx


# --------------------------------------------------------------------------
# epoch/read-set schedule for device-grouped level-major sweeps (solve side)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SweepEpochSchedule:
    """Collective-epoch schedule for one device-grouped triangular sweep.

    The sweep's slot space is ``level × device × rank`` (slot ``s`` has
    level ``s // (D·maxr)``, owner ``(s // maxr) % D``, rank ``s % maxr``);
    each device keeps only its own column of that space — ``n_loc =
    nlev·maxr`` local slots — plus a *halo* of the ``H`` foreign slots it
    actually reads (exact read set, host-precomputed) and one scratch slot.

    Consecutive levels fuse into an **epoch** when every cross-device read
    they perform resolves in an *earlier* epoch; an epoch runs entirely
    device-locally and ends in ONE exchange of exactly the slots some other
    device reads downstream (``egress``/``ingress``, ragged per epoch — the
    epoch loop is unrolled, so payloads are exact, never padded to a global
    max). Epochs whose egress is empty skip the collective altogether.
    """

    n_levels: int
    n_devices: int
    maxr: int
    n_loc: int  # local slots per device (= n_levels * maxr)
    halo: int  # H: max foreign slots any single device reads
    epoch_bounds: np.ndarray  # (n_epochs + 1,) level boundaries
    halo_slots: np.ndarray  # (D, H) global slot ids per device, sorted
    cols_local: np.ndarray  # (D, nlev, maxr, W) device-local deps (pad -> scratch)
    egress: list  # per epoch: None (nothing read abroad) or (D, E_e) i32 local addrs
    ingress: list  # per epoch: None or (D, D, E_e) i32 halo addrs (pad -> scratch)
    egress_slots: list  # per epoch: None or (D, E_e) i64 global slots (pad -> -1)

    @property
    def n_epochs(self) -> int:
        return len(self.epoch_bounds) - 1

    @property
    def scratch(self) -> int:
        return self.n_loc + self.halo

    @property
    def n_slots(self) -> int:
        return self.n_levels * self.n_devices * self.maxr

    def exchange_count(self) -> int:
        """Collectives per sweep (epochs whose read set is non-empty)."""
        return sum(e is not None for e in self.egress)

    def exchanged_slot_count(self) -> int:
        """Σ_e E_e — padded payload slots shipped per device per sweep."""
        return sum(e.shape[1] for e in self.egress if e is not None)

    def slot_was_exchanged(self) -> np.ndarray:
        """(n_slots,) bool — slots already broadcast by an epoch exchange
        (an ``all_gather`` leaves them replicated on every device, so a
        final output assembly never needs to ship them again)."""
        out = np.zeros(self.n_slots, bool)
        for es in self.egress_slots:
            if es is not None:
                valid = es >= 0
                out[es[valid]] = True
        return out


def sweep_epoch_schedule(cols: np.ndarray, n_devices: int) -> SweepEpochSchedule:
    """Build the epoch/read-set schedule from global-slot dependency columns.

    ``cols`` is the ``(D, nlev, maxr, W)`` device-grouped level-major table
    of dependency *slots* (entries ``>= nlev·D·maxr`` are padding). For
    every level this computes exactly which finished slots each device
    reads from another device, fuses maximal runs of levels whose
    cross-device reads all come from earlier epochs (greedy left-to-right —
    optimal for contiguous grouping since dependencies only look backward),
    and emits the per-epoch exact egress/ingress maps.
    """
    D = n_devices
    _, nlev, maxr, _ = cols.shape
    assert cols.shape[0] == D
    n_slots = nlev * D * maxr
    n_loc = nlev * maxr
    cols64 = cols.astype(np.int64)
    valid = cols64 < n_slots
    lev_of = cols64 // (D * maxr)
    own_of = (cols64 // maxr) % D
    rank_of = cols64 % maxr
    reader = np.arange(D, dtype=np.int64)[:, None, None, None]
    cross = valid & (own_of != reader)

    # --- epoch boundaries: greedy maximal fusion --------------------------
    max_cross_src = np.full(nlev, -1, np.int64)
    d_i, l_i, r_i, w_i = np.nonzero(cross)
    if l_i.size:
        np.maximum.at(max_cross_src, l_i, lev_of[d_i, l_i, r_i, w_i])
    starts = [0] if nlev else []
    for lvl in range(1, nlev):
        if max_cross_src[lvl] >= starts[-1]:
            starts.append(lvl)
    epoch_bounds = np.asarray(starts + [nlev], np.int64)
    epoch_of_level = np.zeros(max(nlev, 1), np.int64)
    for e in range(len(starts)):
        epoch_of_level[epoch_bounds[e]:epoch_bounds[e + 1]] = e

    # --- per-device halo: sorted unique foreign slots actually read -------
    reader_b = np.broadcast_to(reader, cross.shape)
    pairs = np.unique(reader_b[cross] * np.int64(n_slots)
                      + cols64[cross]) if l_i.size else np.zeros(0, np.int64)
    h_dev = pairs // n_slots
    h_slot = pairs % n_slots
    halo_slots, h_cnt = ragged_group(h_dev, h_slot, D, n_slots)
    H = halo_slots.shape[1]
    h_start = np.zeros(D, np.int64)
    np.cumsum(h_cnt[:-1], out=h_start[1:])
    scratch = n_loc + H

    # --- device-local column remap: own slots at level*maxr + rank, ------
    # foreign slots at their halo position, padding at the scratch slot
    local_of_own = lev_of * maxr + rank_of
    cols_local = np.full(cols.shape, scratch, np.int64)
    same = valid & (own_of == reader)
    cols_local[same] = local_of_own[same]
    if pairs.size:
        q = reader_b * np.int64(n_slots) + cols64
        pos = np.searchsorted(pairs, q[cross])
        cols_local[cross] = n_loc + (pos - h_start[h_dev[pos]])
    cols_local = cols_local.astype(np.int32)

    # --- per-epoch exact egress/ingress -----------------------------------
    # a slot ships once, at the end of the epoch that produced it, iff some
    # other device reads it downstream (all its cross reads are in strictly
    # later epochs by the fusion rule)
    fr = np.unique(h_slot) if pairs.size else np.zeros(0, np.int64)
    egress, ingress, egress_slots = [], [], []
    for e in range(len(starts)):
        m = epoch_of_level[fr // (D * maxr)] == e if fr.size else np.zeros(0, bool)
        se = fr[m]
        if se.size == 0:
            egress.append(None)
            ingress.append(None)
            egress_slots.append(None)
            continue
        slots_e, _ = ragged_group((se // maxr) % D, se, D, -1)
        E = slots_e.shape[1]
        eg = np.where(slots_e >= 0,
                      (slots_e // (D * maxr)) * maxr + slots_e % maxr,
                      np.int64(scratch)).astype(np.int32)
        ing = np.empty((D, D, E), np.int32)
        flat = slots_e.reshape(-1)
        for d in range(D):
            hr = halo_slots[d][: h_cnt[d]]
            ing[d] = halo_positions(hr, flat, n_loc, scratch).reshape(D, E).astype(np.int32)
        egress.append(eg)
        ingress.append(ing)
        egress_slots.append(slots_e)

    return SweepEpochSchedule(
        n_levels=nlev, n_devices=D, maxr=maxr, n_loc=n_loc, halo=H,
        epoch_bounds=epoch_bounds, halo_slots=halo_slots,
        cols_local=cols_local, egress=egress, ingress=ingress,
        egress_slots=egress_slots,
    )


def make_plan(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int,
    n_devices: int = 1,
) -> NumericPlan:
    """Build the static numeric-phase plan from the filled pattern."""
    assert band_rows >= 1 and n_devices >= 1
    n = pattern.n
    # pad rows so that n_pad = B * R with B a multiple of D
    bands = -(-n // band_rows)
    bands = -(-bands // n_devices) * n_devices
    n_pad = bands * band_rows

    cols, vals, diag_pos, row_len, a_lane = ell_from_pattern(pattern, a, n_pad)
    W = cols.shape[1]

    # pivot_start[j, b] = #entries of row j with col < b*R, clipped to diag_pos
    valid = cols < COL_SENTINEL
    row_idx, lane_idx = np.nonzero(valid)
    entry_band = np.minimum(cols[row_idx, lane_idx].astype(np.int64) // band_rows, bands - 1)
    cnt = np.bincount(row_idx * bands + entry_band, minlength=n_pad * bands)
    cnt = cnt.reshape(n_pad, bands)
    ps = np.zeros((n_pad, bands + 1), dtype=np.int64)
    np.cumsum(cnt, axis=1, out=ps[:, 1:])
    pivot_start = np.minimum(ps, diag_pos[:, None].astype(np.int64)).astype(np.int32)

    band_of_row = (np.arange(n_pad) // band_rows).astype(np.int32)

    # static trip-count bounds
    counts = np.diff(pivot_start, axis=1)  # (n_pad, B)
    intra = counts[np.arange(n_pad), band_of_row]
    inter = counts.copy()
    inter[np.arange(n_pad), band_of_row] = 0
    max_intra = int(intra.max()) if n_pad else 0
    max_inter = int(inter.max()) if n_pad else 0

    piv_rows, piv_dlane, piv_dst = pivot_gather_maps(cols, diag_pos)
    sched = _band_superstep_schedule(pivot_start, band_of_row, bands, n_devices)
    s_loc, halo_size, egress_max, halo_rows, piv_addr, egress_idx, ingress_idx = (
        _halo_exchange_schedule(piv_rows, diag_pos, band_of_row, sched,
                                band_rows, bands, n_devices)
    )

    return NumericPlan(
        n=n,
        n_pad=n_pad,
        width=W,
        band_rows=band_rows,
        n_bands=bands,
        n_devices=n_devices,
        k=pattern.k,
        cols=cols,
        diag_pos=diag_pos,
        row_len=row_len,
        a_vals=vals,
        a_scatter_lane=a_lane,
        pivot_start=pivot_start,
        band_of_row=band_of_row,
        max_pivots_per_band=max(max_inter, 1),
        max_intra_pivots=max(max_intra, 1),
        max_piv=piv_rows.shape[1],
        piv_rows=piv_rows,
        piv_dlane=piv_dlane,
        piv_dst=piv_dst,
        n_supersteps=sched.shape[0],
        bands_per_superstep=sched.shape[2],
        superstep_bands=sched,
        s_loc=s_loc,
        halo_size=halo_size,
        egress_max=egress_max,
        halo_rows=halo_rows,
        piv_addr=piv_addr,
        egress_idx=egress_idx,
        ingress_idx=ingress_idx,
    )


def plan_comm_bytes_per_node(plan: NumericPlan, faithful: bool = True) -> int:
    """Paper §V-E communication model: ~8 bytes/final-entry per node.

    ``faithful=False`` counts the TPU variant (static structure replicated,
    values only -> 4 bytes/entry).
    """
    per_entry = 8 if faithful else 4
    nnz = int(np.sum(plan.row_len[: plan.n]))
    return per_entry * nnz
