"""Robust subprocess runner for multi-device / dry-run tests.

On this container (2 vCPU under a sandboxed kernel) a child process running
simulated-multi-device XLA occasionally stalls for minutes when its
stdout/stderr are OS pipes — the same command with file-backed IO completes
in seconds, reliably. So: redirect the child to temp files (read them back
afterwards) and retry once on a stall before failing. Keeps the tests
meaningful (a deterministic failure still fails twice) without letting a
scheduler hiccup burn a whole CI run.

Two isolation rules keep a failed attempt from poisoning the retry:

* every attempt gets **fresh** output files, rotated before the child
  starts — a child killed mid-write can never leave bytes in the next
  attempt's capture;
* the child runs in its own **process group** and the whole group is
  signalled on timeout, so grandchildren (benchmark drivers that spawn
  their own JAX subprocesses) cannot outlive the attempt and keep the CPU
  or the captured files busy into the retry.
"""
import os
import signal
import subprocess
import tempfile
import time


def _signal_group(proc, sig):
    """Deliver ``sig`` to the child's process group (fall back to the
    child alone if the group is already gone)."""
    try:
        os.killpg(proc.pid, sig)
    except (ProcessLookupError, PermissionError):
        try:
            proc.send_signal(sig)
        except ProcessLookupError:
            pass


def run_checked(cmd, env, timeout, tries=2):
    """Run ``cmd``; returns (returncode, stdout, stderr) of the last try.

    A try that exceeds ``timeout`` gets SIGABRT (so ``faulthandler`` dumps
    every thread's Python stack into the captured stderr), then SIGKILL —
    both delivered to the whole process group — then one retry with fresh
    output files; only a timeout triggers a retry — a nonzero exit returns
    immediately so assertion failures surface with their output.
    """
    env = dict(env)
    env.setdefault("PYTHONFAULTHANDLER", "1")
    last = None
    for attempt in range(tries):
        # fresh, rotated capture files per attempt: nothing a killed child
        # (or a straggling grandchild) wrote can leak into this attempt
        with tempfile.TemporaryFile() as out_f, tempfile.TemporaryFile() as err_f:
            proc = subprocess.Popen(cmd, env=env, stdout=out_f, stderr=err_f,
                                    start_new_session=True)
            try:
                rc = proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                _signal_group(proc, signal.SIGABRT)
                try:
                    proc.wait(timeout=15)
                except subprocess.TimeoutExpired:
                    _signal_group(proc, signal.SIGKILL)
                    proc.kill()
                    proc.wait()
                _signal_group(proc, signal.SIGKILL)  # reap any grandchildren
                time.sleep(0.2)  # let the final stderr writes land
                out_f.seek(0)
                err_f.seek(0)
                last = (-1, out_f.read().decode(errors="replace"),
                        err_f.read().decode(errors="replace")
                        + f"\n[test harness] timed out after {timeout}s "
                        f"(attempt {attempt + 1}/{tries})")
                continue
            out_f.seek(0)
            err_f.seek(0)
            return (rc, out_f.read().decode(errors="replace"),
                    err_f.read().decode(errors="replace"))
    return last
