"""Observability for the solve service: histograms, counters, compile watch.

Everything here is host-side bookkeeping designed around one consumer: the
JSON metrics snapshot (:meth:`ServiceMetrics.snapshot`) that the soak test
asserts a schema on and that ``benchmarks/bench_serve.py`` commits as part
of ``BENCH_serve.json``. Three kinds of signals:

* **Per-tenant latency** — log-spaced histogram buckets plus a bounded
  reservoir of raw observations so p50/p99 are exact for soak-sized runs
  (the histogram alone would quantize the p99 the acceptance bar pins).
* **Service counters** — queue depth (sampled per tick), coalesced-batch
  occupancy (real lanes / bucket lanes), cache hit/miss/evict/refactor
  counts, admission rejects by reason.
* **XLA compile counter** — a process-global listener on jax's
  ``/jax/core/compile/backend_compile_duration`` monitoring event. After
  warmup this number must go *flat*: any increment on the serving path
  means a request paid an XLA compile, which is exactly the failure mode
  the warm/bucketed architecture exists to prevent. ``CompileWatch.mark``
  / ``since_mark`` make "zero new compiles after warmup" a one-line assert.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Dict, List, Optional

# --------------------------------------------------------------------------
# XLA compile counter
# --------------------------------------------------------------------------
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_compile_lock = threading.Lock()
_compile_count = 0
_listener_installed = False


def _on_event_duration(name: str, *args, **kw) -> None:
    global _compile_count
    if name == _COMPILE_EVENT:
        with _compile_lock:
            _compile_count += 1


def install_compile_listener() -> None:
    """Idempotently register the process-global backend-compile listener.

    Must be installed before warmup for ``since_mark`` deltas to mean
    anything; installing twice is a no-op (jax keeps listeners forever, so
    a duplicate would double-count)."""
    global _listener_installed
    with _compile_lock:
        if _listener_installed:
            return
        _listener_installed = True
    import jax.monitoring

    jax.monitoring.register_event_duration_secs_listener(_on_event_duration)


def compile_count() -> int:
    """Total XLA backend compiles observed since the listener installed."""
    with _compile_lock:
        return _compile_count


class CompileWatch:
    """Snapshot-and-delta view of the process compile counter."""

    def __init__(self):
        install_compile_listener()
        self._mark = compile_count()

    def mark(self) -> int:
        """Reset the baseline (call when warmup finishes); returns it."""
        self._mark = compile_count()
        return self._mark

    def since_mark(self) -> int:
        return compile_count() - self._mark


# --------------------------------------------------------------------------
# Latency histogram
# --------------------------------------------------------------------------
class LatencyHistogram:
    """Log-spaced latency histogram with an exact-percentile reservoir.

    Buckets span 10 µs … ~100 s at 10 per decade (a fixed, snapshot-stable
    set). The reservoir keeps the most recent ``reservoir`` raw values so
    quantiles are exact over the window the soak measures; the bucket
    counts never saturate and cover the full history.
    """

    DECADES = (1e-5, 1e2)
    PER_DECADE = 10

    def __init__(self, reservoir: int = 100_000):
        ndec = int(round(math.log10(self.DECADES[1] / self.DECADES[0])))
        self.bounds = [
            self.DECADES[0] * 10 ** (i / self.PER_DECADE)
            for i in range(ndec * self.PER_DECADE + 1)
        ]
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.sum_seconds = 0.0
        self._raw: collections.deque = collections.deque(maxlen=reservoir)

    def observe(self, seconds: float) -> None:
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # first bound > value
            mid = (lo + hi) // 2
            if seconds < self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.total += 1
        self.sum_seconds += seconds
        self._raw.append(seconds)

    def quantile(self, q: float) -> float:
        """Exact quantile over the reservoir window (0 when empty)."""
        if not self._raw:
            return 0.0
        xs = sorted(self._raw)
        idx = min(int(q * len(xs)), len(xs) - 1)
        return xs[idx]

    def to_dict(self) -> dict:
        return {
            "count": self.total,
            "mean_seconds": (self.sum_seconds / self.total) if self.total else 0.0,
            "p50_seconds": self.quantile(0.50),
            "p99_seconds": self.quantile(0.99),
            "max_seconds": max(self._raw) if self._raw else 0.0,
            "bucket_bounds_seconds": self.bounds,
            "bucket_counts": list(self.counts),
        }


# --------------------------------------------------------------------------
# Service-wide metrics
# --------------------------------------------------------------------------
@dataclasses.dataclass
class BatchRecord:
    matrix_id: str
    real_lanes: int
    bucket: int
    solve_seconds: float


class ServiceMetrics:
    """All service counters + histograms, snapshotting to one JSON dict."""

    def __init__(self):
        self._lock = threading.Lock()
        self.started_at = time.time()
        self.tenant_latency: Dict[str, LatencyHistogram] = {}
        self.queue_depth_samples: List[int] = []
        self.max_queue_depth = 0
        self.batches: List[BatchRecord] = []
        self.requests_admitted = 0
        self.requests_completed = 0
        self.requests_failed = 0
        self.rejects_by_reason: Dict[str, int] = collections.defaultdict(int)
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_evictions = 0
        self.refactorizations = 0
        self.engines_shared = 0
        self.ticks = 0
        self.solve_seconds_total = 0.0
        self.compile_watch = CompileWatch()
        self.warmup_compiles = 0
        # robustness counters (breakdown/retry/degradation accounting);
        # defaultdict so new counter names need no schema change here —
        # bench_schema.py pins the set that BENCH_serve.json commits
        self.robustness: Dict[str, int] = collections.defaultdict(int)
        # tick-duration health: EWMA-based slow-tick detector (the
        # StragglerMonitor from runtime/fault — previously only used by
        # run_with_restarts) + an exact-percentile histogram
        from repro.runtime.fault import StragglerMonitor

        self.tick_monitor = StragglerMonitor(deadline_factor=3.0)
        self.tick_hist = LatencyHistogram(reservoir=10_000)

    # -- recording hooks (called by the service/cache/coalescer) ----------
    def record_admission(self, ok: bool, reason: Optional[str] = None) -> None:
        with self._lock:
            if ok:
                self.requests_admitted += 1
            else:
                self.rejects_by_reason[reason or "unknown"] += 1

    def record_queue_depth(self, depth: int) -> None:
        with self._lock:
            self.queue_depth_samples.append(depth)
            self.max_queue_depth = max(self.max_queue_depth, depth)

    def record_batch(self, matrix_id: str, real: int, bucket: int, seconds: float) -> None:
        with self._lock:
            self.batches.append(BatchRecord(matrix_id, real, bucket, seconds))
            self.solve_seconds_total += seconds

    def record_response(self, tenant: str, ok: bool, latency_seconds: float) -> None:
        with self._lock:
            if ok:
                self.requests_completed += 1
            else:
                self.requests_failed += 1
            hist = self.tenant_latency.get(tenant)
            if hist is None:
                hist = self.tenant_latency[tenant] = LatencyHistogram()
            hist.observe(latency_seconds)

    def record_cache(self, event: str, n: int = 1) -> None:
        with self._lock:
            if event == "hit":
                self.cache_hits += n
            elif event == "miss":
                self.cache_misses += n
            elif event == "evict":
                self.cache_evictions += n
            elif event == "refactor":
                self.refactorizations += n
            elif event == "engine_shared":
                self.engines_shared += n
            else:
                raise ValueError(f"unknown cache event {event!r}")

    def record_tick(self, seconds: Optional[float] = None) -> None:
        """Count a tick; with ``seconds`` also feed the slow-tick monitor
        (EWMA straggler detection) and the tick-duration histogram."""
        with self._lock:
            self.ticks += 1
            if seconds is not None:
                self.tick_monitor.observe(seconds)
                self.tick_hist.observe(seconds)

    def record_robustness(self, name: str, n: int = 1) -> None:
        """Bump a named robustness counter (breakdown_lanes, shift_retries,
        retry_recoveries, degraded_responses, deadline_expired,
        quarantined_batches, broken_factorizations, shifted_bindings,
        identity_fallbacks, rejected_updates, ...)."""
        with self._lock:
            self.robustness[name] += n

    def mark_warm(self) -> None:
        """End of warmup: pin the compile baseline. ``compiles_after_warmup``
        in every later snapshot counts only serving-path compiles."""
        with self._lock:
            self.warmup_compiles = compile_count()
        self.compile_watch.mark()

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        """One JSON-serializable dict of everything above — the schema the
        soak test and ``BENCH_serve.json`` pin."""
        with self._lock:
            occupancies = [b.real_lanes / b.bucket for b in self.batches if b.bucket]
            lanes = sum(b.real_lanes for b in self.batches)
            padded = sum(b.bucket - b.real_lanes for b in self.batches)
            qd = self.queue_depth_samples
            lookups = self.cache_hits + self.cache_misses
            return {
                "uptime_seconds": time.time() - self.started_at,
                "ticks": self.ticks,
                "requests": {
                    "admitted": self.requests_admitted,
                    "completed": self.requests_completed,
                    "failed": self.requests_failed,
                    "rejected_by_reason": dict(self.rejects_by_reason),
                },
                "queue": {
                    "depth_samples": len(qd),
                    "depth_mean": (sum(qd) / len(qd)) if qd else 0.0,
                    "depth_max": self.max_queue_depth,
                },
                "coalescing": {
                    "batches": len(self.batches),
                    "solved_lanes": lanes,
                    "padded_lanes": padded,
                    "occupancy_mean": (sum(occupancies) / len(occupancies)) if occupancies else 0.0,
                    "occupancy_min": min(occupancies) if occupancies else 0.0,
                    "solve_seconds_total": self.solve_seconds_total,
                },
                "cache": {
                    "hits": self.cache_hits,
                    "misses": self.cache_misses,
                    "hit_rate": (self.cache_hits / lookups) if lookups else 0.0,
                    "evictions": self.cache_evictions,
                    "refactorizations": self.refactorizations,
                    "engines_shared": self.engines_shared,
                },
                "compiles": {
                    "total": compile_count(),
                    "warmup": self.warmup_compiles,
                    "after_warmup": self.compile_watch.since_mark(),
                },
                "robustness": dict(self.robustness),
                "tick_health": {
                    "observed": self.tick_monitor.steps,
                    "slow_ticks": self.tick_monitor.slow_steps,
                    "deadline_factor": self.tick_monitor.deadline_factor,
                    "mean_seconds": (self.tick_hist.sum_seconds / self.tick_hist.total)
                    if self.tick_hist.total else 0.0,
                    "p99_seconds": self.tick_hist.quantile(0.99),
                },
                "tenants": {t: h.to_dict() for t, h in sorted(self.tenant_latency.items())},
            }
