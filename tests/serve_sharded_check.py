"""Subprocess body for the scaled-down *sharded* serve soak.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=<D> \
         JAX_PLATFORMS=cpu python tests/serve_sharded_check.py <n> <n_requests>

Exits 0 iff a SolveService over :class:`ShardedServeEngine` on this device
count survives a seeded burst mix (buckets 1/2/4) with:

* every admitted request completed ``ok`` with verdict ``converged``,
* zero serving-path XLA compiles after warmup,
* every response **bitwise-equal** to its solo ``solve_sharded`` on the
  same mesh, and
* the robustness/tick-health metrics sections present.

Deliberately small (n≈256, ~60 requests): the point is the engine wiring
and the bit-compat bar on 2/4 virtual devices, not throughput — the
single-device soak (test_serve_soak.py) carries the volume.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    n, n_requests = int(sys.argv[1]), int(sys.argv[2])
    import numpy as np
    import jax

    from repro.core.matgen import matgen
    from repro.core.solvers import solve_sharded
    from repro.serve import ServeConfig, SolveService, run_traffic

    d = len(jax.devices())
    assert d >= 2, f"expected multi-device, got {jax.devices()}"
    band_rows = 32
    a = matgen(n, density=min(0.02, 12.0 / n), seed=21)

    svc = SolveService(ServeConfig(sharded=True, band_rows=band_rows,
                                   buckets=(1, 2, 4), k=1, restart=8,
                                   maxiter=20))
    svc.register_matrix("m0", a)
    svc.warmup()
    assert svc.readyz()["ready"]

    result = run_traffic(svc, ["m0"], n_requests, seed=33,
                         tenants=("t0", "t1"), burst_max=4,
                         tol_choices=(1e-4, 1e-5))
    snap = svc.metrics_snapshot()   # BEFORE reference solves (they compile)

    assert snap["requests"]["admitted"] == n_requests
    assert snap["requests"]["completed"] == n_requests, snap["requests"]
    assert snap["requests"]["failed"] == 0
    assert snap["compiles"]["after_warmup"] == 0, (
        f"sharded serving path re-entered XLA after warmup: {snap['compiles']}")
    assert isinstance(snap["robustness"], dict)
    assert snap["tick_health"]["observed"] == snap["ticks"] > 0

    # bitwise fidelity vs the solo sharded solve (same mesh, same values);
    # one fact shared across references so the engine caches hit
    by_id = {r.request_id: r for r in result.responses}
    fact = None
    for rec in result.records:
        resp = by_id[rec.request_id]
        assert resp.ok and resp.verdict == "converged", (resp.error, resp.verdict)
        ref, fact = solve_sharded(a, rec.b, k=1, band_rows=band_rows,
                                  tol=rec.tol, restart=8, maxiter=20,
                                  fact=fact)
        assert np.array_equal(
            np.asarray(resp.x, np.float32).view(np.int32),
            np.asarray(ref.x, np.float32).view(np.int32)), (
            f"request {rec.request_id}: sharded serve response != solo "
            f"solve_sharded (bucket {resp.batch_lanes})")

    print(f"OK: sharded serve soak n={n} requests={n_requests} devices={d} "
          f"batches={snap['coalescing']['batches']} bitwise-equal")


if __name__ == "__main__":
    main()
