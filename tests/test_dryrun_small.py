"""Miniature dry-run in subprocesses: the sharding rules must lower+compile
reduced configs of every family on a (2,4) mesh. (The full 512-device
production dry-run is exercised by `python -m repro.launch.dryrun --all`;
its 40-cell results live in experiments/dryrun/ and EXPERIMENTS.md.)"""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "dryrun_small_check.py")

CASES = [
    ("smollm-135m", "train"),        # dense, replicated-attention path
    ("deepseek-v2-lite-16b", "train"),  # MLA + MoE(EP)
    ("qwen2-moe-a2.7b", "decode"),   # MoE expert padding + GQA decode
    ("hymba-1.5b", "decode"),        # hybrid attn+ssm, ring-buffer cache
    ("xlstm-125m", "train"),         # recurrent stack
    ("whisper-tiny", "decode"),      # enc-dec with cross-attention
    ("llava-next-mistral-7b", "prefill"),  # vlm stub merge
    ("starcoder2-15b", "prefill"),   # GQA kv<tp
]


@pytest.mark.parametrize("arch,kind", CASES)
def test_small_dryrun(arch, kind):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run(
        [sys.executable, SCRIPT, arch, kind],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert res.returncode == 0, f"stdout:{res.stdout}\nstderr:{res.stderr[-1500:]}"
    assert "OK" in res.stdout
