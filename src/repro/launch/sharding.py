"""Sharding rules: parameters, optimizer state, batches, KV caches.

Policy (DESIGN.md §5):

* TP over ``model``: attention q/o sharded on the head dim when
  ``H % tp == 0`` (k/v when ``Hkv % tp == 0``; otherwise replicated — the
  GQA kv<tp case, e.g. starcoder2), MLP hidden, MoE experts (EP when
  ``E % tp == 0``, expert-TP otherwise), vocab-sharded embeddings/head.
* DP over ``(pod, data)``: batches; ZeRO-1 additionally shards optimizer
  moments over ``data``.
* Decode caches: kv-head dim on ``model`` when divisible, else the cache
  *sequence* dim (distributed decode attention); batch on ``data`` when
  divisible.

Every rule is guarded by a divisibility check — a dim that does not divide
evenly falls back to replication rather than failing to lower.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import mesh_axis_sizes, tp_size


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def _dims(leaf) -> tuple:
    return tuple(leaf.shape)


def band_shardings(mesh: Mesh, specs: dict) -> dict:
    """NamedShardings for the band-sharded ILU pipeline (DESIGN.md §5).

    ``specs`` maps array name -> PartitionSpec (the output of
    ``repro.core.numeric_jax.plan_shard_specs``); placing the host arrays
    with these *before* the jitted shard_map runs means each device
    materializes only its own block — the value state, pivot tables, and
    halo schedules are never replicated across the mesh.
    """
    return {k: NamedSharding(mesh, p) for k, p in specs.items()}


def band_put(mesh: Mesh, axis: str, x, rank: int):
    """Place a rank-``rank`` host table sharded along its leading device
    axis (``P(axis, None, ...)``) — the placement every per-device schedule
    table of the sharded factorize/sweep pipeline uses, so no table is ever
    replicated across the mesh."""
    assert np.ndim(x) == rank, (np.ndim(x), rank)
    spec = P(axis, *([None] * (rank - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


class ShardingRules:
    def __init__(self, cfg, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = tp_size(mesh)
        self.axes = set(mesh.axis_names)
        self.dp_axes = tuple(a for a in ("pod", "data") if a in self.axes)

    # -- helpers -----------------------------------------------------------
    def _ok(self, size, axis="model") -> bool:
        n = mesh_axis_sizes(self.mesh).get(axis, 1)
        return size % n == 0 and n > 1

    def _dp_ok(self, size) -> bool:
        n = 1
        for a in self.dp_axes:
            n *= mesh_axis_sizes(self.mesh)[a]
        return n > 1 and size % n == 0

    def batch_spec(self, batch_size: int) -> P:
        if self._dp_ok(batch_size):
            return P(self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0])
        return P(None)

    # -- parameters ---------------------------------------------------------
    def param_spec(self, path: str, shape: tuple) -> P:
        cfg = self.cfg
        tp_heads = cfg.n_heads % self.tp == 0
        tp_kv = cfg.n_kv_heads % self.tp == 0
        r = len(shape)

        def last(on: bool):
            spec = [None] * r
            if on and self._ok(shape[-1]):
                spec[-1] = "model"
            return P(*spec)

        def second_last(on: bool):
            spec = [None] * r
            if on and self._ok(shape[-2]):
                spec[-2] = "model"
            return P(*spec)

        name = path.rsplit("/", 1)[-1]
        if name in ("embed",):
            return P("model", None) if self._ok(shape[0]) else P(None, None)
        if name in ("lm_head",):
            return last(True)
        if name in ("wq", "bq"):
            return last(tp_heads)
        if name in ("wk", "wv", "bk", "bv"):
            return last(tp_kv)
        if name == "wo":
            return second_last(tp_heads)
        # MLA
        if name in ("w_uk", "w_uv"):
            return last(tp_heads)
        if name == "w_dkv":
            return P(*([None] * r))
        # MoE expert banks: (L, E, d, f) / (L, E, f, d); gate replicated
        if "moe" in path and name in ("w_gate", "w_up", "w_down"):
            e_dim = r - 3  # E axis position (layers-stacked or not)
            if cfg.n_routed_experts and shape[e_dim] == cfg.n_routed_experts:
                if self._ok(cfg.n_routed_experts):
                    spec = [None] * r
                    spec[e_dim] = "model"
                    return P(*spec)  # EP
                # expert-TP: shard the hidden f dim
                f_dim = r - 1 if name in ("w_gate", "w_up") else r - 2
                if self._ok(shape[f_dim]):
                    spec = [None] * r
                    spec[f_dim] = "model"
                    return P(*spec)
                return P(*([None] * r))
        if name == "gate":
            return P(*([None] * r))
        # dense MLP (also MoE shared experts)
        if name in ("w_gate", "w_up"):
            return last(True)
        if name == "w_down":
            return second_last(True)
        # SSM
        if name in ("in_proj", "w_dt2"):
            return last(True)
        if name in ("out_proj", "w_dt1", "a_log", "d_skip", "dt_bias", "conv_w", "w_bc"):
            # di-indexed: shard the di dim where present
            spec = [None] * r
            for i, s in enumerate(shape):
                di = cfg.ssm_inner or cfg.d_model
                if s == di and self._ok(s):
                    spec[i] = "model"
                    break
            return P(*spec)
        # xLSTM
        if name in ("up", "w_gates"):
            return last(True)
        if name in ("wq_x", "wk_x", "wv_x"):
            return last(True)
        if name == "down":
            return second_last(True)
        if name in ("r_gates", "w_if"):
            return P(*([None] * r))
        # norms, biases, everything else: replicated
        return P(*([None] * r))

    def params_shardings(self, params_shapes) -> Any:
        def f(path, leaf):
            return NamedSharding(self.mesh, self.param_spec(_path_str(path), leaf.shape))

        return jax.tree_util.tree_map_with_path(f, params_shapes)

    # -- optimizer state -----------------------------------------------------
    def opt_shardings(self, params_shapes, zero1: bool = False) -> Any:
        """Moments follow params; ZeRO-1 additionally shards the first
        free (unsharded, divisible) dim over ``data``."""

        def f(path, leaf):
            spec = list(self.param_spec(_path_str(path), leaf.shape))
            while len(spec) < len(leaf.shape):
                spec.append(None)
            if zero1:
                dsize = mesh_axis_sizes(self.mesh).get("data", 1)
                for i, s in enumerate(leaf.shape):
                    if spec[i] is None and dsize > 1 and s % dsize == 0 and s >= dsize:
                        spec[i] = "data"
                        break
            return NamedSharding(self.mesh, P(*spec))

        return jax.tree_util.tree_map_with_path(f, params_shapes)

    # -- batches -------------------------------------------------------------
    def batch_shardings(self, batch_specs) -> Any:
        def f(path, leaf):
            b = leaf.shape[0]
            spec = [None] * len(leaf.shape)
            bs = self.batch_spec(b)
            spec[0] = bs[0] if len(bs) else None
            return NamedSharding(self.mesh, P(*spec))

        return jax.tree_util.tree_map_with_path(f, batch_specs)

    # -- decode caches ---------------------------------------------------------
    def cache_shardings(self, cache_shapes, batch: int) -> Any:
        cfg = self.cfg
        tp_kv = cfg.n_kv_heads % self.tp == 0 and self.tp > 1

        def f(path, leaf):
            p = _path_str(path)
            r = len(leaf.shape)
            spec = [None] * r
            name = p.rsplit("/", 1)[-1]
            # (L, B, ...) stacked caches: B at axis 1; xlstm states (B, ...)
            b_axis = 1 if r >= 2 and leaf.shape[0] == cfg.n_layers else 0
            if self._dp_ok(batch) and leaf.shape[b_axis] == batch:
                spec[b_axis] = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
            if name in ("k", "v", "cross_k", "cross_v"):  # (L,B,Lc,Hkv,hd)
                if tp_kv:
                    spec[3] = "model"
                elif self._ok(leaf.shape[2]):
                    spec[2] = "model"  # sequence-sharded decode attention
            elif name in ("c", "r"):  # MLA latent cache (L,B,Lc,r)
                if self._ok(leaf.shape[2]):
                    spec[2] = "model"
            elif name == "h" and r == 4:  # ssm state (L,B,di,N)
                if self._ok(leaf.shape[2]):
                    spec[2] = "model"
            elif r >= 3:  # xlstm matrix memories etc.
                for i in range(r - 1, b_axis, -1):
                    if self._ok(leaf.shape[i]):
                        spec[i] = "model"
                        break
            return NamedSharding(self.mesh, P(*spec))

        return jax.tree_util.tree_map_with_path(f, cache_shapes)

    def replicated(self, tree) -> Any:
        def f(leaf):
            return NamedSharding(self.mesh, P(*([None] * len(leaf.shape))))

        return jax.tree.map(f, tree)
