"""Fusion-aware row reordering — the permutation layer of the pipeline.

The TPILU(k) bit-compatibility contract is defined *relative to a chosen
row order*: the paper's parallel factorization reproduces sequential
ILU(k) of the matrix **as given**, bit for bit. That makes row order a
free lever — permute A once at plan time, run the entire
plan→compile→execute pipeline on the permuted system (where every
existing bitwise contract holds verbatim), and un/permute ``b``/``x`` at
the solve boundary. PR 4 measured why this matters: epoch fusion in the
distributed sweep is structure-bound (2-D Poisson row-major order leaves
an immediate cross-device read on almost every wavefront level, 188→128
epochs at D=2, while random patterns fuse 2-3x), so the ordering — not
the executor — is where the communication lives.

Three orderings plus a selection primitive:

* :func:`rcm_ordering` — reverse Cuthill-McKee: degree-sorted BFS from a
  pseudo-peripheral vertex, reversed. The classical fill-reducing /
  bandwidth-reducing baseline.
* :func:`fusion_aware_ordering` — the tentpole: grow ``D`` BFS
  subdomains over the symmetrized adjacency graph, sized exactly to the
  rows each device owns under the block-cyclic band ownership
  ``(row // band_rows) % D``, and map subdomain ``d``'s rows (in BFS
  order) onto device ``d``'s ownership positions. Dependencies then stay
  device-local except on subdomain frontiers, so whole runs of wavefront
  levels carry **no** cross-device read and fuse into one collective
  epoch (``planner.sweep_epoch_schedule``'s fusion rule).
* :func:`choose_band_rows` — block-cyclic band-ownership selection:
  score candidate ownership block sizes per-structure with the existing
  epoch/read-set model (:func:`sweep_comm_model` wraps
  ``triangular.build_sharded_triangular_plan`` — modeled epochs, then
  wire bytes, nothing compiled) and keep the cheapest.

Everything here is host-side planning: NumPy only, cached on the matrix
object (same lifetime rule as the solver/plan caches), and consumed by
``api.ilu`` / ``api.ilu_sharded`` / ``solvers.solve_with_ilu`` /
``solvers.solve_sharded`` through their ``ordering=`` parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from .planner import expand_spans
from .sparse import CSRMatrix


# --------------------------------------------------------------------------
# the permutation container + its matrix/vector boundary operations
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Ordering:
    """A row/column permutation of the linear system.

    ``perm[p]`` is the original row sitting at permuted position ``p``;
    ``iperm`` is the inverse (``iperm[perm[p]] == p``). The permuted
    system is ``A' = P A Pᵀ`` with ``A'[p, q] = A[perm[p], perm[q]]``, so
    ``A' (P x) = P b``: permute ``b`` going in, un-permute ``x`` coming
    out, and the solution of the original system is recovered exactly
    (a gather each way — no arithmetic, bitwise-neutral).
    """

    name: str
    perm: np.ndarray  # (n,) int64
    iperm: np.ndarray  # (n,) int64
    band_rows: Optional[int] = None  # ownership block the ordering targeted

    def __post_init__(self):
        self.perm = np.asarray(self.perm, np.int64)
        self.iperm = np.asarray(self.iperm, np.int64)

    @property
    def n(self) -> int:
        return int(self.perm.size)

    @property
    def is_natural(self) -> bool:
        return bool(np.array_equal(self.perm, np.arange(self.n)))

    def permute_matrix(self, a: CSRMatrix) -> CSRMatrix:
        return permute_csr(a, self.perm)

    def permute_vector(self, b):
        """b (…, n) in original order -> permuted order (pure gather)."""
        return np.asarray(b)[..., self.perm]

    def unpermute_vector(self, x):
        """x (…, n) in permuted order -> original order (pure gather)."""
        return np.asarray(x)[..., self.iperm]


def natural_ordering(n: int) -> Ordering:
    ar = np.arange(n, dtype=np.int64)
    return Ordering(name="natural", perm=ar, iperm=ar.copy())


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    perm = np.asarray(perm, np.int64)
    iperm = np.empty_like(perm)
    iperm[perm] = np.arange(perm.size, dtype=np.int64)
    return iperm


def _check_permutation(perm: np.ndarray, n: int) -> np.ndarray:
    """Validate a user-supplied permutation: length n, each of 0..n-1
    exactly once. A duplicate/out-of-range entry would otherwise flow into
    ``inverse_permutation``'s uninitialized slots and gather garbage —
    silently wrong solves, not an error."""
    perm = np.asarray(perm, np.int64)
    if perm.shape != (n,):
        raise ValueError(f"ordering: permutation shape {perm.shape} != ({n},)")
    if perm.size and (perm.min() < 0 or perm.max() >= n
                      or np.bincount(perm, minlength=n).max(initial=1) != 1):
        raise ValueError(
            "ordering: not a permutation of range(n) — duplicate or "
            "out-of-range entries")
    return perm


def permute_csr(a: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    """Symmetric row/column permutation ``A' = P A Pᵀ`` (vectorized).

    Row ``p`` of the result is row ``perm[p]`` of ``a`` with columns
    relabeled through the inverse permutation and re-sorted ascending
    (the CSR invariant every plan builder assumes). Values are copied
    bit-for-bit — a permutation never touches arithmetic.
    """
    perm = np.asarray(perm, np.int64)
    n = a.n
    assert perm.size == n, f"permutation length {perm.size} != n {n}"
    iperm = inverse_permutation(perm)
    rowlen = np.diff(a.indptr).astype(np.int64)
    new_rowlen = rowlen[perm]
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(new_rowlen, out=indptr[1:])
    src = expand_spans(a.indptr[perm], new_rowlen)
    cols = iperm[a.indices[src].astype(np.int64)]
    data = a.data[src]
    row_of = np.repeat(np.arange(n, dtype=np.int64), new_rowlen)
    order = np.lexsort((cols, row_of))
    return CSRMatrix(
        n=n,
        indptr=indptr,
        indices=cols[order].astype(np.int32),
        data=data[order].astype(np.float32),
    )


# --------------------------------------------------------------------------
# BFS machinery over the symmetrized structure
# --------------------------------------------------------------------------
def _sym_adjacency(a: CSRMatrix):
    """Symmetrized, diagonal-free adjacency of A's pattern as (ptr, nbrs).

    Neighbors are sorted ascending per vertex. Orderings must not depend
    on which triangle an entry happens to live in — the permuted matrix's
    L/U split is an *output* of the ordering, not an input.
    """
    n = a.n
    rowlen = np.diff(a.indptr).astype(np.int64)
    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    cols = a.indices.astype(np.int64)
    src = np.concatenate([row_of, cols])
    dst = np.concatenate([cols, row_of])
    off = src != dst
    key = np.unique(src[off] * n + dst[off])
    src_u = key // n
    nbrs = key - src_u * n
    cnt = np.bincount(src_u, minlength=n).astype(np.int64)
    ptr = np.zeros(n + 1, np.int64)
    np.cumsum(cnt, out=ptr[1:])
    return ptr, nbrs


def _bfs_component(ptr, nbrs, start, visited):
    """Degree-sorted BFS (Cuthill-McKee visit order) of one component.

    Appends levels as arrays; within each level vertices are sorted by
    (degree, id) — the classical CM tie-break. Marks ``visited``.
    """
    deg = np.diff(ptr)
    levels = [np.asarray([start], np.int64)]
    visited[start] = True
    frontier = levels[0]
    while True:
        flen = ptr[frontier + 1] - ptr[frontier]
        cand = nbrs[expand_spans(ptr[frontier], flen)]
        cand = np.unique(cand)  # sorted by id
        cand = cand[~visited[cand]]
        if cand.size == 0:
            return levels
        cand = cand[np.lexsort((cand, deg[cand]))]
        visited[cand] = True
        levels.append(cand)
        frontier = cand


def _pseudo_peripheral(ptr, nbrs, comp_seed, visited_template):
    """George–Liu style pseudo-peripheral vertex: start at a min-degree
    vertex and chase the farthest min-degree vertex until the BFS
    eccentricity stops growing (≤ a few restarts in practice)."""
    deg = np.diff(ptr)
    start = int(comp_seed)
    ecc = -1
    for _ in range(8):  # converges in 2-3 iterations on meshes
        vis = visited_template.copy()
        levels = _bfs_component(ptr, nbrs, start, vis)
        if len(levels) <= ecc:
            return start
        ecc = len(levels)
        last = levels[-1]
        start = int(last[np.argmin(deg[last])])
    return start


def _bfs_sequence(a: CSRMatrix) -> np.ndarray:
    """Whole-graph Cuthill-McKee visit sequence: every component BFS'd
    from a pseudo-peripheral vertex, components in ascending-seed order."""
    n = a.n
    ptr, nbrs = _sym_adjacency(a)
    visited = np.zeros(n, bool)
    out = []
    while True:
        unvisited = np.nonzero(~visited)[0]
        if unvisited.size == 0:
            break
        deg = np.diff(ptr)
        seed = unvisited[np.argmin(deg[unvisited])]
        start = _pseudo_peripheral(ptr, nbrs, seed, visited)
        out.extend(_bfs_component(ptr, nbrs, start, visited))
    return np.concatenate(out) if out else np.zeros(0, np.int64)


def rcm_ordering(a: CSRMatrix) -> Ordering:
    """Reverse Cuthill-McKee: the fill-reducing / bandwidth-reducing BFS
    baseline. ``perm[p]`` = the (n-1-p)-th vertex of the CM sequence."""
    perm = _bfs_sequence(a)[::-1].copy()
    return Ordering(name="rcm", perm=perm, iperm=inverse_permutation(perm))


# --------------------------------------------------------------------------
# fusion-aware ordering: BFS subdomains mapped onto band ownership
# --------------------------------------------------------------------------
def ownership_positions(n: int, band_rows: int, n_devices: int) -> list:
    """Row positions each device owns under block-cyclic band ownership.

    Device of position ``p`` is ``(p // band_rows) % n_devices`` — the
    same rule ``planner.make_plan`` and the sharded triangular plan use.
    Returns D ascending int64 arrays partitioning ``range(n)``.
    """
    idx = np.arange(n, dtype=np.int64)
    dev = (idx // band_rows) % n_devices
    return [idx[dev == d] for d in range(n_devices)]


def fusion_aware_ordering(
    a: CSRMatrix, n_devices: int, band_rows: Optional[int] = None
) -> Ordering:
    """Wavefront/fusion-aware ordering for a given band ownership.

    Grows ``D`` BFS subdomains over the symmetrized adjacency (one
    contiguous slice of the Cuthill-McKee visit sequence per device,
    sized exactly to the rows that device owns) and assigns subdomain
    ``d``'s rows — in BFS order — to device ``d``'s ownership positions,
    ascending. Every dependency between two rows of one subdomain is then
    device-local no matter which band it lands in, so cross-device reads
    happen only on subdomain frontiers: long runs of sweep levels carry
    no cross read at all and fuse into single collective epochs under
    ``planner.sweep_epoch_schedule``. With ``band_rows=None`` the
    ownership defaults to one block per device (``ceil(n / D)``) — the
    pure domain-decomposition layout.

    For ``n_devices == 1`` this degenerates to the plain BFS
    (Cuthill-McKee) ordering: there is nothing to fuse, but the banded
    profile it produces is still a better sweep structure than random.
    """
    n = a.n
    if band_rows is None:
        band_rows = max(-(-n // max(n_devices, 1)), 1)
    seq = _bfs_sequence(a)
    if n_devices <= 1:
        perm = seq
        return Ordering(name="fusion", perm=perm,
                        iperm=inverse_permutation(perm), band_rows=band_rows)
    positions = ownership_positions(n, band_rows, n_devices)
    perm = np.empty(n, np.int64)
    off = 0
    for pos_d in positions:
        take = pos_d.size
        perm[pos_d] = seq[off:off + take]
        off += take
    assert off == n
    return Ordering(name="fusion", perm=perm, iperm=inverse_permutation(perm), band_rows=band_rows)


# --------------------------------------------------------------------------
# model scoring: the existing sweep-epoch / halo models, nothing compiled
# --------------------------------------------------------------------------
def sweep_comm_model(pattern, band_rows: int, n_devices: int) -> dict:
    """Modeled solve-side communication of one preconditioner apply.

    Builds the structure-only sharded triangular plan (host NumPy; no
    value, no compile) and reads the epoch/read-set model off it — the
    same quantities ``tests/test_sharded_memory.py`` asserts equal to the
    compiled HLO, so scoring with them is scoring the real collectives.
    """
    from .triangular import build_sharded_triangular_plan

    return build_sharded_triangular_plan(pattern, band_rows, n_devices).comm_summary()


def factor_comm_model(a: CSRMatrix, pattern, band_rows: int, n_devices: int) -> dict:
    """Modeled factorization-side communication (halo-exchange schedule)."""
    from .planner import make_plan

    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=n_devices)
    return {
        "band_rows": int(band_rows),
        "n_devices": int(n_devices),
        "n_supersteps": int(plan.n_supersteps),
        "halo_bytes_per_superstep": int(plan.halo_bytes_per_superstep()),
        "per_device_value_bytes": int(plan.per_device_value_bytes()),
        "fill_nnz": int(pattern.nnz),
    }


def _ownership_candidates(n: int, n_devices: int) -> tuple:
    """Default block-size candidates: a x4 geometric ladder from 8 up,
    plus the one-block-per-device layout (block ownership)."""
    top = max(-(-n // max(n_devices, 1)), 1)
    cand = []
    r = 8
    while r < top:
        cand.append(r)
        r *= 4
    cand.append(top)
    return tuple(dict.fromkeys(cand))


def choose_band_rows(
    a: CSRMatrix,
    k: int,
    n_devices: int,
    candidates: Optional[Sequence[int]] = None,
    rule: str = "sum",
) -> tuple:
    """Block-cyclic band-ownership selection, scored before any compile.

    For each candidate ownership block size: build the fusion-aware
    ordering targeting it, run symbolic ILU(k) on the permuted structure,
    and score the sweep with :func:`sweep_comm_model`. Returns
    ``(best_ordering, scores)`` where ``scores`` maps block size to its
    model record and the winner minimizes ``(epochs, bytes_per_apply)``
    — fewest modeled collective epochs first, wire bytes as tie-break.
    """
    from .api import _symbolic

    candidates = _ownership_candidates(a.n, n_devices) if candidates is None \
        else tuple(candidates)
    scores = {}
    best = None
    best_key = None
    for r in candidates:
        ordering = fusion_aware_ordering(a, n_devices, band_rows=r)
        pattern = _symbolic(ordering.permute_matrix(a), k, rule)
        rec = sweep_comm_model(pattern, r, n_devices)
        scores[int(r)] = rec
        key = (rec["epochs"], rec["bytes_per_apply"])
        if best_key is None or key < best_key:
            best_key, best = key, ordering
    return best, scores


# --------------------------------------------------------------------------
# resolution + per-matrix caching (the api/solvers entry point)
# --------------------------------------------------------------------------
OrderingSpec = Union[None, str, Ordering, np.ndarray, Sequence[int]]

#: Ordering names accepted by every ``ordering=`` parameter.
ORDERING_NAMES = ("natural", "rcm", "fusion")


def make_ordering(
    a: CSRMatrix, spec: OrderingSpec, n_devices: int = 1,
    band_rows: Optional[int] = None,
) -> Optional[Ordering]:
    """Resolve an ``ordering=`` argument to an :class:`Ordering` (or None).

    ``None``/``"natural"`` mean the identity (returns None — callers skip
    the permutation entirely); ``"rcm"`` / ``"fusion"`` build the named
    ordering; an explicit permutation array or :class:`Ordering` passes
    through. Named orderings are cached on the matrix object keyed by
    ``(name, n_devices, band_rows)`` — same lifetime rule as every other
    per-matrix plan cache.
    """
    if spec is None or (isinstance(spec, str) and spec == "natural"):
        return None
    if isinstance(spec, Ordering):
        return None if spec.is_natural else spec
    if not isinstance(spec, str):
        perm = _check_permutation(spec, a.n)
        ordering = Ordering(name="custom", perm=perm, iperm=inverse_permutation(perm))
        return None if ordering.is_natural else ordering
    if spec not in ORDERING_NAMES:
        raise ValueError(
            f"unknown ordering {spec!r}: expected one of {ORDERING_NAMES}, "
            "an Ordering, or a permutation array")
    key = (spec, int(n_devices), None if band_rows is None else int(band_rows))
    try:
        store = a.__dict__.setdefault("_orderings", {})
    except AttributeError:  # exotic container without __dict__: no caching
        store = {}
    ordering = store.get(key)
    if ordering is None:
        if spec == "rcm":
            ordering = rcm_ordering(a)
        else:
            ordering = fusion_aware_ordering(a, n_devices, band_rows=band_rows)
        store[key] = ordering
    return ordering


def permuted_system(a: CSRMatrix, ordering: Ordering) -> CSRMatrix:
    """The permuted matrix ``P A Pᵀ``, cached on ``a`` keyed by the
    permutation's bytes — so repeated solves with one ordering reuse one
    permuted matrix object, and with it every plan/engine cache hanging
    off that object (factor plans, matvecs, compiled sweeps)."""
    try:
        store = a.__dict__.setdefault("_permuted", {})
    except AttributeError:
        return ordering.permute_matrix(a)
    key = ordering.perm.tobytes()
    ap = store.get(key)
    if ap is None:
        ap = store[key] = ordering.permute_matrix(a)
    return ap
