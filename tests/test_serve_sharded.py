"""Scaled-down sharded serve soak on 2/4 virtual devices.

Each case subprocesses ``serve_sharded_check.py`` (device count locks at
first JAX init): a SolveService over :class:`ShardedServeEngine`, seeded
bursty traffic across buckets 1/2/4, asserting zero post-warmup compiles
and every response bitwise-equal to its solo ``solve_sharded``.
"""
import os
import sys

import pytest

from subproc import run_checked

SCRIPT = os.path.join(os.path.dirname(__file__), "serve_sharded_check.py")


@pytest.mark.parametrize("devices", [2, 4])
def test_sharded_serve_soak(devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    rc, out, err = run_checked(
        [sys.executable, SCRIPT, "256", "60"], env=env, timeout=480)
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "bitwise-equal" in out
