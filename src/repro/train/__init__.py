"""repro.train"""
