"""llava-next-mistral-7b [vlm] — mistral-7b backbone; anyres vision tiling is
a STUB: input_specs provides projector-output patch embeddings directly.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_real=32000,
    rope_theta=1000000.0,
    mlp_act="swiglu",
    vision_patches=576,  # one anyres tile worth of projector outputs
)
