"""TOP-ILU — task-oriented parallel ILU(k) over a device mesh (paper §IV).

Maps the paper's distributed-memory algorithm onto JAX SPMD:

* bands → round-robin shards over the mesh axis (static load balancing,
  §IV-D; device ``d`` owns bands ``{b : b ≡ d (mod D)}``),
* the frontier loop → ``lax.fori_loop`` over bands inside one jitted step,
* the Fig-4 ring pipeline → a masked ``psum`` broadcast of each finished
  band (XLA lowers it to a ring collective) or an explicit ``ppermute``
  directed ring (``broadcast='ring'``),
* dynamic load balancing (master/worker) → intentionally absent from the
  SPMD fast path; the paper itself measures static LB as strictly better
  (Table I). It survives as the fault-tolerance reassignment path in
  ``repro.runtime``.

Unlike the paper we do *not* replicate the whole filled matrix per node:
because the symbolic pattern is static planning output on TPU, each device
stores only its owned bands plus one in-flight band buffer, and structure
(column indices) is never communicated (4 bytes/entry on the wire instead
of the paper's 8 — see §V-E and DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from .planner import NumericPlan, make_plan
from .numeric_jax import make_banded_factorizer, plan_device_arrays
from .sparse import CSRMatrix, ILUPattern

AXIS = "band"


def _values_to_csr_order(plan: NumericPlan, pattern: ILUPattern, vals_dm: np.ndarray) -> np.ndarray:
    """Device-major padded values -> CSR-aligned flat values."""
    vals_rm = plan.rows_from_device_major(np.asarray(vals_dm))
    out = np.zeros(pattern.nnz, dtype=np.float32)
    for j in range(pattern.n):
        s, e = pattern.indptr[j], pattern.indptr[j + 1]
        out[s:e] = vals_rm[j, : e - s]
    return out


def topilu_numeric(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int = 32,
    mesh: Optional[Mesh] = None,
    broadcast: str = "psum",
) -> np.ndarray:
    """Parallel numeric factorization. Returns CSR-aligned values.

    With ``mesh=None`` uses every available device on a 1-D mesh; pass an
    explicit 1-D mesh to control the device set.
    """
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (AXIS,))
    d = mesh.devices.size
    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=d)
    arrays = plan_device_arrays(plan)
    fac = make_banded_factorizer(plan, axis_name=AXIS if d > 1 else None, broadcast=broadcast)

    if d == 1:
        run = jax.jit(fac)
        vals = run(
            arrays["vals"], arrays["cols"], arrays["pivot_start"], arrays["band_of_row"],
            arrays["intra_start"], arrays["intra_count"], arrays["cols_all"], arrays["dpos_all"],
        )
        return _values_to_csr_order(plan, pattern, vals)

    shard = P(AXIS)
    rep = P()
    smapped = shard_map(
        functools.partial(fac),
        mesh=mesh,
        in_specs=(shard, shard, shard, shard, shard, shard, rep, rep),
        out_specs=shard,
        check_vma=False,
    )
    run = jax.jit(smapped)
    vals = run(
        arrays["vals"], arrays["cols"], arrays["pivot_start"], arrays["band_of_row"],
        arrays["intra_start"], arrays["intra_count"], arrays["cols_all"], arrays["dpos_all"],
    )
    return _values_to_csr_order(plan, pattern, np.asarray(vals))


def lower_topilu(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int,
    mesh: Mesh,
    broadcast: str = "psum",
):
    """AOT-lower the parallel factorization (for dry-runs / HLO inspection)."""
    d = mesh.devices.size
    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=d)
    arrays = plan_device_arrays(plan)
    fac = make_banded_factorizer(plan, axis_name=AXIS, broadcast=broadcast)
    smapped = shard_map(
        fac,
        mesh=mesh,
        in_specs=(P(AXIS),) * 6 + (P(), P()),
        out_specs=P(AXIS),
        check_vma=False,
    )
    args = [
        jax.ShapeDtypeStruct(arrays[k].shape, arrays[k].dtype)
        for k in ("vals", "cols", "pivot_start", "band_of_row", "intra_start", "intra_count", "cols_all", "dpos_all")
    ]
    return jax.jit(smapped).lower(*args), plan
