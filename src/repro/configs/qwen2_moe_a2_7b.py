"""qwen2-moe-a2.7b [moe] — 60 routed top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B]. 60 % 16 != 0, so expert parallelism falls back
to expert-TP on the model axis (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_real=151936,
    rope_theta=1000000.0,
    qkv_bias=True,
    n_routed_experts=60,
    n_shared_experts=4,
    moe_top_k=4,
    d_expert=1408,
    moe_norm_topk=False,
    mlp_act="swiglu",
)
