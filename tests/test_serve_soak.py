"""Deterministic seeded soak of the solve service.

One seeded :func:`repro.serve.run_traffic` run — thousands of requests,
four tenants, two resident matrices, mid-stream value updates and
malformed injections — then three audits over the full trail:

1. **Metrics schema**: the JSON snapshot has exactly the documented shape
   (this is the contract ``BENCH_serve.json`` and dashboards consume).
2. **Compile flatness**: ``compiles.after_warmup == 0`` — the serving
   path never re-enters XLA after :meth:`SolveService.warmup`, across
   every bucket size, coalescing mix, and background refactorization.
3. **Bitwise fidelity**: every response equals the solo
   ``solve_with_ilu(..., use_pallas=False)`` reference for the exact
   value version the request was admitted under.

The compile snapshot is taken *before* computing references — reference
solves compile their own engines and must not pollute the counter.
"""
import numpy as np
import pytest

from repro.core.matgen import matgen
from repro.core.solvers import solve_with_ilu
from repro.core.sparse import CSRMatrix
from repro.serve import ServeConfig, SolveService, run_traffic

N = 256
K = 1
RESTART = 8
MAXITER = 20
N_REQUESTS = 2000
SEED = 2026


def _metrics_schema_check(snap):
    assert set(snap) >= {"uptime_seconds", "ticks", "requests", "queue",
                         "coalescing", "cache", "compiles", "tenants"}
    req = snap["requests"]
    assert set(req) >= {"admitted", "completed", "failed", "rejected_by_reason"}
    assert isinstance(req["rejected_by_reason"], dict)
    q = snap["queue"]
    assert set(q) >= {"depth_samples", "depth_mean", "depth_max"}
    co = snap["coalescing"]
    assert set(co) >= {"batches", "solved_lanes", "padded_lanes",
                       "occupancy_mean", "occupancy_min", "solve_seconds_total"}
    ca = snap["cache"]
    assert set(ca) >= {"hits", "misses", "hit_rate", "evictions",
                       "refactorizations", "engines_shared"}
    cp = snap["compiles"]
    assert set(cp) >= {"total", "warmup", "after_warmup"}
    for tenant, hist in snap["tenants"].items():
        assert set(hist) >= {"count", "mean_seconds", "p50_seconds",
                             "p99_seconds", "max_seconds",
                             "bucket_bounds_seconds", "bucket_counts"}
        assert hist["count"] == sum(hist["bucket_counts"])
        assert hist["p50_seconds"] <= hist["p99_seconds"] <= hist["max_seconds"]


@pytest.mark.slow
def test_soak_seeded_traffic_bitwise_and_compile_flat():
    a0 = matgen(N, 0.02, seed=41)
    a1 = matgen(N, 0.02, seed=42)
    svc = SolveService(ServeConfig(buckets=(1, 2, 4, 8), restart=RESTART,
                                   maxiter=MAXITER, k=K))
    svc.register_matrix("acct-0/pressure", a0)
    svc.register_matrix("acct-1/pressure", a1)
    svc.warmup()

    # two value pushes per matrix, queued for run_traffic to inject
    updates = {
        "acct-0/pressure": [(a0.data * s).astype(np.float32) for s in (1.2, 0.9)],
        "acct-1/pressure": [(a1.data * s).astype(np.float32) for s in (1.1, 1.3)],
    }
    result = run_traffic(
        svc, ["acct-0/pressure", "acct-1/pressure"], N_REQUESTS, seed=SEED,
        tenants=("t0", "t1", "t2", "t3"), burst_max=8,
        malformed_prob=0.05, update_prob=0.02, update_values=updates)
    snap = svc.metrics_snapshot()   # BEFORE reference solves (they compile)

    # -- schema + accounting -------------------------------------------------
    _metrics_schema_check(snap)
    assert snap["requests"]["admitted"] == N_REQUESTS
    assert snap["requests"]["completed"] == N_REQUESTS
    assert snap["requests"]["failed"] == 0
    assert len(result.responses) == N_REQUESTS
    assert len(result.rejected) > 0          # malformed injections happened
    assert all(not r.ok for r in result.rejected)
    assert set(snap["tenants"]) == {"t0", "t1", "t2", "t3"}
    assert sum(h["count"] for h in snap["tenants"].values()) == N_REQUESTS

    # -- service-level SLO invariants ---------------------------------------
    assert snap["compiles"]["after_warmup"] == 0, (
        "serving path re-entered XLA after warmup: "
        f"{snap['compiles']}")
    assert snap["cache"]["hit_rate"] >= 0.9
    assert snap["cache"]["evictions"] == 0   # capacity 8, two residents
    n_updates = sum(len(v) for v in result.updates.values())
    assert snap["cache"]["refactorizations"] == n_updates
    assert n_updates > 0                     # updates actually fired
    assert snap["coalescing"]["occupancy_mean"] > 0.5

    # -- bitwise fidelity: every response == its solo reference -------------
    mats = {"acct-0/pressure": a0, "acct-1/pressure": a1}
    # version v matrices: v=1 is the registered data, v=1+i after update i;
    # one CSRMatrix object per (matrix, version) so reference engines cache
    ref_mats = {}
    for mid, a in mats.items():
        ref_mats[(mid, 1)] = a
        for i, data in enumerate(result.updates[mid]):
            ref_mats[(mid, 2 + i)] = CSRMatrix(
                n=a.n, indptr=a.indptr, indices=a.indices, data=data)

    by_id = {r.request_id: r for r in result.responses}
    checked = 0
    for rec in result.records:
        resp = by_id[rec.request_id]
        assert resp.ok, f"request {rec.request_id} failed: {resp.error}"
        assert resp.matrix_version == rec.expected_version, (
            "response solved against a different value version than the "
            "one pinned at admission")
        ref = ref_mats[(rec.matrix_id, rec.expected_version)]
        sol, _ = solve_with_ilu(ref, rec.b, k=K, tol=rec.tol,
                                restart=RESTART, use_pallas=False)
        np.testing.assert_array_equal(
            np.asarray(resp.x, np.float32).view(np.int32),
            np.asarray(sol.x, np.float32).view(np.int32),
            err_msg=(f"coalesced response for {rec.matrix_id} v"
                     f"{rec.expected_version} (lane of a {resp.batch_lanes}-"
                     "bucket) is not bitwise equal to its solo solve"))
        checked += 1
    assert checked == N_REQUESTS
