"""Preconditioned solvers: convergence + the paper's k-vs-iterations story."""
import numpy as np
import pytest

from repro.core import matgen, poisson_2d
from repro.core.solvers import solve_with_ilu


def _rhs(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _check_residual(a, res, b, tol=5e-4):
    ax = a.to_scipy() @ res.x
    rel = np.linalg.norm(ax - b) / np.linalg.norm(b)
    assert rel < tol, f"relative residual {rel}"


def test_gmres_with_ilu1_converges():
    a = matgen(200, density=0.03, seed=1)
    b = _rhs(a.n)
    res, fact = solve_with_ilu(a, b, k=1, method="gmres", tol=1e-5)
    assert res.converged
    _check_residual(a, res, b)
    assert fact.nnz >= a.nnz


def test_bicgstab_with_ilu1_converges():
    a = matgen(200, density=0.03, seed=2)
    b = _rhs(a.n, 3)
    res, _ = solve_with_ilu(a, b, k=1, method="bicgstab", tol=1e-5)
    assert res.converged
    _check_residual(a, res, b)


def test_cg_poisson_ilu_reduces_iterations():
    """The reason preconditioning exists: fewer iterations with ILU."""
    a = poisson_2d(16)
    b = _rhs(a.n, 4)
    plain, _ = solve_with_ilu(a, b, k=None, method="cg", tol=1e-5, maxiter=2000)
    pre, _ = solve_with_ilu(a, b, k=1, method="cg", tol=1e-5, maxiter=2000)
    assert pre.converged
    assert pre.iterations < plain.iterations, (pre.iterations, plain.iterations)


def test_higher_k_not_worse():
    """Paper SV-B: larger k => better preconditioner (<= iterations)."""
    a = poisson_2d(14)
    b = _rhs(a.n, 5)
    it = {}
    for k in (0, 2):
        res, _ = solve_with_ilu(a, b, k=k, method="cg", tol=1e-6, maxiter=2000)
        assert res.converged
        it[k] = res.iterations
    assert it[2] <= it[0], it


def test_bicgstab_parallel_factorization_same_convergence():
    """Bit-compatibility corollary: solver behaviour is identical when the
    preconditioner is computed by the banded parallel engine."""
    a = matgen(150, density=0.04, seed=6)
    b = _rhs(a.n, 7)
    r_seq, _ = solve_with_ilu(a, b, k=1, method="bicgstab", backend="oracle")
    r_par, _ = solve_with_ilu(a, b, k=1, method="bicgstab", backend="jax")
    assert r_seq.iterations == r_par.iterations
    np.testing.assert_array_equal(r_seq.x, r_par.x)


def test_csr_to_ell_vectorized_matches_row_loop():
    from repro.core.planner import COL_SENTINEL
    from repro.core.solvers import csr_to_ell_arrays

    a = matgen(90, density=0.06, seed=20)
    cols, vals = csr_to_ell_arrays(a)
    cols, vals = np.asarray(cols), np.asarray(vals)
    lens = np.diff(a.indptr)
    W = int(lens.max())
    want_c = np.full((a.n, W), COL_SENTINEL, np.int32)
    want_v = np.zeros((a.n, W), np.float32)
    for j in range(a.n):
        c, v = a.row(j)
        want_c[j, : len(c)] = c
        want_v[j, : len(v)] = v
    np.testing.assert_array_equal(cols, want_c)
    np.testing.assert_array_equal(vals, want_v)


def test_residual_history_recorded_per_iteration():
    """cg/bicgstab record one relative residual per iteration inside the
    device loop (the paper's Fig-5 style convergence curves)."""
    a = poisson_2d(12)
    b = _rhs(a.n, 8)
    for method in ("cg", "bicgstab"):
        res, _ = solve_with_ilu(a, b, k=1, method=method, tol=1e-5, maxiter=500)
        assert res.converged
        assert len(res.history) == res.iterations
        assert res.history[-1] == pytest.approx(res.residual, rel=1e-3)
        # preconditioned convergence should show an overall downward trend
        assert res.history[-1] < res.history[0]


def test_gmres_history_per_restart():
    a = matgen(200, density=0.03, seed=9)
    b = _rhs(a.n, 10)
    res, _ = solve_with_ilu(a, b, k=1, method="gmres", restart=10, maxiter=30)
    assert res.converged
    assert 1 <= len(res.history) <= 30
    assert res.history[-1] == pytest.approx(res.residual, rel=1e-3)


def test_gmres_batched_multi_rhs():
    """One factorization + one dispatch serves a stack of right-hand sides."""
    a = matgen(150, density=0.05, seed=11)
    B = np.stack([_rhs(a.n, s) for s in (1, 2, 3)])
    results, fact = solve_with_ilu(a, B, k=1, method="gmres", tol=1e-5)
    assert len(results) == 3
    A = a.to_scipy()
    for i, r in enumerate(results):
        assert r.converged
        rel = np.linalg.norm(A @ r.x - B[i]) / np.linalg.norm(B[i])
        assert rel < 5e-4
    # lanes match the single-RHS engine (same iteration counts, same answer
    # to solver tolerance)
    from repro.core.solvers import csr_to_ell_arrays, gmres, make_pallas_matvec

    cols, vals = csr_to_ell_arrays(a)
    matvec = make_pallas_matvec(cols, vals, a.n)
    single = gmres(matvec, B[0], fact.precond(), tol=1e-5)
    assert single.iterations == results[0].iterations
    np.testing.assert_allclose(results[0].x, single.x, rtol=1e-4, atol=1e-5)


def test_batched_rejects_non_gmres():
    a = matgen(60, density=0.08, seed=12)
    B = np.stack([_rhs(a.n, 1), _rhs(a.n, 2)])
    with pytest.raises(ValueError):
        solve_with_ilu(a, B, k=1, method="cg")


def test_batch_buckets_env(monkeypatch):
    """Serving batch buckets: env-configurable, ragged sizes round up, and
    batches beyond every bucket keep their exact size."""
    from repro.core.solvers import batch_buckets, bucket_batch

    monkeypatch.delenv("REPRO_BATCH_BUCKETS", raising=False)
    assert batch_buckets() == (1, 2, 4, 8, 16, 32, 64)
    assert bucket_batch(1) == 1
    assert bucket_batch(3) == 4
    assert bucket_batch(33) == 64
    assert bucket_batch(100) == 100  # past the largest bucket: exact
    monkeypatch.setenv("REPRO_BATCH_BUCKETS", "2, 6")
    assert batch_buckets() == (2, 6)
    assert bucket_batch(3) == 6
    assert bucket_batch(7) == 7


def test_factorization_caches_precond_and_solver():
    """The triangular plan/compiled apply must be built once per
    factorization and reused across solves (the PR-1 plan-cache layer)."""
    from repro.core.api import ilu

    a = matgen(80, density=0.07, seed=13)
    fact = ilu(a, 1, backend="oracle")
    p1 = fact.precond()
    p2 = fact.precond()
    assert p1 is p2
    b = _rhs(a.n, 14)
    x1 = fact.solve(b)
    x2 = fact.solve(b)
    np.testing.assert_array_equal(x1, x2)
    # batched apply shares the same plan and matches single applies bitwise
    B = np.stack([b, _rhs(a.n, 15)])
    xb = fact.solve(B)
    np.testing.assert_array_equal(xb[0], x1)
