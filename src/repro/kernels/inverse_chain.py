"""Pallas TPU kernel: fused incomplete-inverse apply — x = Z (W b).

The whole ``precond_method="inverse"`` apply in one kernel launch: two
back-to-back sentinel-padded ELL SpMVs (W then Z) with the intermediate
vector y = W b living entirely in VMEM — no HBM round-trip between the
factors, unlike two separate ``spmv_ell`` launches. Single block: both
gathers read the full intermediate vector, so rows are not tiled (the
wavefront-free apply is bandwidth-bound, not compute-bound; for n <= 2^20
f32 the operands fit VMEM comfortably).

The body delegates to ``repro.core.inverse.inverse_chain_jnp`` on values
read from the refs — kernel and jnp reference share one implementation, so
they are bit-identical to each other and to ``inverse_apply_ref`` by
construction (every reduction is a ``masked_lane_sum``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(w_cols_ref, w_vals_ref, z_cols_ref, z_vals_ref, b_ref, o_ref):
    from repro.core.inverse import inverse_chain_jnp

    o_ref[...] = inverse_chain_jnp(
        w_cols_ref[...], w_vals_ref[...], z_cols_ref[...], z_vals_ref[...],
        b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def inverse_chain(w_cols, w_vals, z_cols, z_vals, b, *, interpret=True):
    """w_cols/w_vals: (n, WI); z_cols/z_vals: (n, ZI); b: (n,). x = Z (W b)."""
    n = b.shape[0]
    assert w_cols.shape[0] == n and z_cols.shape[0] == n
    assert w_vals.shape == w_cols.shape and z_vals.shape == z_cols.shape
    whole = [pl.BlockSpec(a.shape, lambda *_, s=a.shape: (0,) * len(s))
             for a in (w_cols, w_vals, z_cols, z_vals, b)]
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=whole,
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(w_cols, w_vals, z_cols, z_vals, b)
