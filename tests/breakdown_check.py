"""Subprocess body for multi-device breakdown-ladder tests.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=<D> \
         JAX_PLATFORMS=cpu python tests/breakdown_check.py <n> <k> <band_rows>

Exits 0 iff, on this device count, for each breakdown fixture:

* the *unguarded* sharded factorization is flagged unhealthy by the
  on-device audit, and the audit is a pure read — the audited factor is
  bitwise identical to the sequential oracle of the (broken) matrix;
* ``on_breakdown="shift"`` settles on a shifted system whose sharded
  factor is **bitwise equal to the sequential oracle of that shifted
  matrix** (the ladder's bit-compat anchor);
* the settled health carries a per-band worst-pivot summary sized to the
  band count;
* ``solve_sharded(..., on_breakdown="shift")`` converges on a system the
  plain factorization would have filled with inf/NaN.

(Separate process because the device count is locked at first JAX init.)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    n, k, band_rows = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
    import numpy as np
    import jax

    from repro.core import numeric_ilu_ref, pilu1_symbolic, symbolic_ilu_k
    from repro.core.api import ilu_sharded
    from repro.core.guard import shifted_matrix
    from repro.core.matgen import singular_block_matrix, zero_diagonal_matrix
    from repro.core.solvers import solve_sharded

    d = len(jax.devices())
    density = min(0.08, 12.0 / n)
    fixtures = [
        ("singular", singular_block_matrix(n, density, seed=3)),
        ("zerodiag", zero_diagonal_matrix(n, density, seed=4, row=0)),
    ]
    for name, a in fixtures:
        pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)

        # 1) audit is a pure read: the unguarded factor of the broken
        # matrix still equals its own sequential oracle bitwise
        base = ilu_sharded(a, k, band_rows=band_rows, on_breakdown="ignore")
        assert base.health is not None and not base.health.ok, \
            f"{name}: audit failed to flag a broken factorization"
        want_base = numeric_ilu_ref(a, pat)
        got_base = base.values_csr()
        same = np.asarray(got_base).view(np.int32) == want_base.view(np.int32)
        # NaN payloads may differ across paths only where the oracle is
        # also non-finite; every finite entry must match bitwise
        finite = np.isfinite(want_base)
        assert same[finite].all(), \
            f"{name}: guarded-but-ignored factor != sequential oracle"

        # 2) the ladder's settled factor == sequential oracle of the
        # shifted matrix (the bit-compat anchor of the escalation path)
        fact = ilu_sharded(a, k, band_rows=band_rows, on_breakdown="shift")
        h = fact.health
        assert h.ok and h.shift > 0 and h.attempts > 1, \
            f"{name}: ladder did not settle on a shift ({h.summary()})"
        a_s = shifted_matrix(a, h.shift)
        want = numeric_ilu_ref(a_s, pat)
        got = np.asarray(fact.values_csr())
        assert np.array_equal(got.view(np.int32), want.view(np.int32)), \
            f"{name}: shifted sharded factor != sequential oracle of shifted matrix"

        # 3) per-band worst-pivot summary covers every band
        n_bands = -(-n // band_rows)
        assert h.band_worst_ratio is not None and len(h.band_worst_ratio) == n_bands, \
            f"{name}: band summary {h.band_worst_ratio!r} != {n_bands} bands"

        # 4) the guarded solve converges where the plain one NaNs — only
        # meaningful for fixtures whose *system* is nonsingular (the
        # singular block breaks ILU *and* the system itself: no solver
        # converges there; the ladder's job for it ends at the factor)
        if name != "singular":
            b = np.random.default_rng(11).standard_normal(n).astype(np.float32)
            r, _ = solve_sharded(a, b, k=k, band_rows=band_rows, tol=1e-5,
                                 maxiter=200, on_breakdown="shift", fact=fact)
            assert r.converged, f"{name}: shifted solve did not converge"
            assert np.isfinite(np.asarray(r.x)).all()
            assert r.report.shift == h.shift and r.report.verdict == "converged"

    print(f"OK: n={n} k={k} band_rows={band_rows} devices={d} "
          f"fixtures={','.join(f[0] for f in fixtures)} ladder bitwise-equal")


if __name__ == "__main__":
    main()
