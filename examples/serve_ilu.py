"""Production solve service, end to end on CPU: multi-tenant request
coalescing over the warm bucketed ILU(k)-preconditioned solver.

Registers two tenants' matrices (same sparsity structure — they share one
compiled engine and one factor plan), warms every bucket ahead of traffic,
then drives a seeded burst mix through admit → coalesce → bucketed
multi-RHS solve → scatter. Along the way one tenant pushes new matrix
values: the refactorization runs in the background and in-flight requests
keep solving the version they were admitted under. Ends with the two
service-level proofs:

* the XLA compile counter is **flat** after warmup (zero serving-path
  compiles across every batch shape and the value update), and
* a spot-checked response is **bitwise identical** to solving that
  request alone.

    python examples/serve_ilu.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import json

import numpy as np

from repro.core.matgen import matgen
from repro.core.solvers import solve_with_ilu
from repro.serve import ServeConfig, SolveService, run_traffic


def main():
    n = 256
    a_acme = matgen(n, 0.02, seed=7)
    # same structure, different values → engine + factor plan are shared
    a_initech = type(a_acme)(n=a_acme.n, indptr=a_acme.indptr,
                             indices=a_acme.indices,
                             data=(a_acme.data * 1.25).astype(np.float32))

    svc = SolveService(ServeConfig(buckets=(1, 2, 4, 8), restart=8, k=1))
    svc.register_matrix("acme/reservoir", a_acme)
    svc.register_matrix("initech/reservoir", a_initech)
    warm = svc.warmup()
    print("warmup (seconds per bucket):")
    for mid, per_bucket in warm.items():
        pretty = {b: round(s, 3) for b, s in per_bucket.items()}
        print(f"  {mid}: {pretty}")

    # seeded multi-tenant traffic; one value push for acme mid-stream
    updates = {"acme/reservoir": [(a_acme.data * 0.8).astype(np.float32)]}
    result = run_traffic(svc, ["acme/reservoir", "initech/reservoir"],
                         n_requests=200, seed=11, burst_max=8,
                         update_prob=0.25, update_values=updates)
    snap = svc.metrics_snapshot()

    print(f"\nserved {len(result.responses)} requests in "
          f"{snap['coalescing']['batches']} coalesced batches "
          f"(mean occupancy {snap['coalescing']['occupancy_mean']:.2f})")
    print(f"cache: hit rate {snap['cache']['hit_rate']:.2f}, "
          f"{snap['cache']['refactorizations']} refactorization(s), "
          f"{snap['cache']['engines_shared']} engine(s) shared by structure")
    print(f"compiles: {snap['compiles']['warmup']} during warmup, "
          f"{snap['compiles']['after_warmup']} after")
    assert snap["compiles"]["after_warmup"] == 0, "serving path re-entered XLA"

    for tenant, hist in sorted(snap["tenants"].items()):
        print(f"  {tenant}: n={hist['count']}  p50={hist['p50_seconds']*1e3:.1f}ms"
              f"  p99={hist['p99_seconds']*1e3:.1f}ms")

    # bit-compat spot check: a coalesced response vs its solo solve, on the
    # exact value version the request was admitted under
    rec = next(r for r in result.records
               if r.matrix_id == "acme/reservoir" and r.expected_version == 1)
    resp = next(r for r in result.responses if r.request_id == rec.request_id)
    ref, _ = solve_with_ilu(a_acme, rec.b, k=1, tol=rec.tol, restart=8,
                            use_pallas=False)
    same = np.array_equal(np.asarray(resp.x, np.float32).view(np.int32),
                          np.asarray(ref.x, np.float32).view(np.int32))
    print(f"\ncoalesced (bucket {resp.batch_lanes}) vs solo: "
          f"bitwise {'EQUAL' if same else 'DIFFERENT'}")
    assert same

    print("\nmetrics snapshot (what BENCH_serve.json embeds):")
    print(json.dumps({k: snap[k] for k in ("requests", "coalescing", "cache",
                                           "compiles")}, indent=2)[:600], "...")


if __name__ == "__main__":
    main()
