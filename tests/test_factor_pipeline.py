"""Plan→compile→execute factorization pipeline: bitwise regression suite.

The PR-2/PR-3 tentpole contract: every engine emitted from the
factorization plans — the single-device wavefront engine
(``backend="jax"``), the *sharded-value* band superstep TOP-ILU engine on
1, 2 or 4 devices — produces float32 factor values **exactly equal**
(int32 view) to the sequential oracle ``numeric_ilu_ref``, for both level
rules, across band sizes, while each device stores only its band-local
values + halo; the distributed precond/solve path matches the
single-device path bitwise; and the vectorized symbolic frontier equals
the per-row reference pattern-for-pattern. Multi-device cases run in
subprocesses (JAX locks the host device count at first init).
"""
import os
import sys

import numpy as np
import pytest

from subproc import run_checked

from repro.core import (
    matgen,
    numeric_ilu_ref,
    pilu1_symbolic,
    poisson_2d,
    symbolic_ilu_k,
    symbolic_ilu_k_ref,
)
from repro.core.api import ilu
from repro.core.factor_plan import build_factor_plan, factor_plan_for
from repro.core.top_ilu import topilu_numeric

MD_SCRIPT = os.path.join(os.path.dirname(__file__), "multidevice_check.py")


def _assert_bitwise(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    mism = np.nonzero(got.view(np.int32) != want.view(np.int32))[0]
    assert mism.size == 0, (
        f"{mism.size}/{want.size} entries differ bitwise; first={mism[:5]} "
        f"got={got[mism[:5]]} want={want[mism[:5]]}"
    )


def _pattern(a, k, rule):
    return pilu1_symbolic(a, rule=rule) if k == 1 else symbolic_ilu_k(a, k, rule=rule)


# --------------------------------------------------------------------------
# symbolic: vectorized frontier == per-row reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("rule", ["sum", "max"])
@pytest.mark.parametrize("k", [0, 1, 2, 3])
def test_symbolic_frontier_equals_reference(k, rule):
    for seed in (0, 1, 2):
        a = matgen(80, density=0.07, seed=seed + 13 * k)
        fast = symbolic_ilu_k(a, k, rule=rule)
        ref = symbolic_ilu_k_ref(a, k, rule=rule)
        np.testing.assert_array_equal(fast.indptr, ref.indptr)
        np.testing.assert_array_equal(fast.indices, ref.indices)
        np.testing.assert_array_equal(fast.levels, ref.levels)
        np.testing.assert_array_equal(fast.diag_ptr, ref.diag_ptr)


# --------------------------------------------------------------------------
# single-device engines vs the oracle, exact ==
# --------------------------------------------------------------------------
@pytest.mark.parametrize("rule", ["sum", "max"])
@pytest.mark.parametrize("k", [0, 1, 2])
def test_wavefront_engine_bitwise(k, rule):
    a = matgen(96, density=0.06, seed=7 * k + (rule == "max"))
    pat = _pattern(a, k, rule)
    want = numeric_ilu_ref(a, pat)
    _assert_bitwise(ilu(a, k, rule=rule, backend="jax").vals, want)


@pytest.mark.parametrize("band_rows", [8, 32])
@pytest.mark.parametrize("k", [0, 1, 2])
def test_superstep_engine_bitwise(k, band_rows):
    a = matgen(96, density=0.06, seed=10 * k + band_rows)
    pat = _pattern(a, k, "sum")
    want = numeric_ilu_ref(a, pat)
    _assert_bitwise(topilu_numeric(a, pat, band_rows=band_rows), want)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_factor_plan_engines_agree(use_pallas):
    """Pallas kernel and jnp engine share one implementation — exact ==."""
    a = poisson_2d(10)
    pat = pilu1_symbolic(a)
    want = numeric_ilu_ref(a, pat)
    plan = build_factor_plan(a, pat)
    _assert_bitwise(plan.factorize(use_pallas=use_pallas), want)


def test_structured_poisson_bitwise():
    a = poisson_2d(12)
    for k, rule in ((1, "sum"), (2, "sum"), (2, "max")):
        pat = _pattern(a, k, rule)
        want = numeric_ilu_ref(a, pat)
        _assert_bitwise(ilu(a, k, rule=rule, backend="jax").vals, want)
        _assert_bitwise(topilu_numeric(a, pat, band_rows=16), want)


# --------------------------------------------------------------------------
# plan/engine caching + refactorization
# --------------------------------------------------------------------------
def test_factor_plan_cached_on_matrix():
    a = matgen(64, density=0.08, seed=3)
    pat = pilu1_symbolic(a)
    p1 = factor_plan_for(a, pat)
    p2 = factor_plan_for(a, pat)
    assert p1 is p2
    assert p1.engine() is p1.engine()  # compiled engine cached on the plan


def test_refactorize_same_structure_new_values():
    """The serving pattern: same structure, new numbers — no replanning."""
    a = matgen(72, density=0.08, seed=5)
    pat = pilu1_symbolic(a)
    plan = build_factor_plan(a, pat)
    _assert_bitwise(plan.factorize(), numeric_ilu_ref(a, pat))
    import dataclasses

    a2 = dataclasses.replace(a, data=(a.data * 1.5 + 0.25).astype(np.float32))
    _assert_bitwise(plan.factorize(a2), numeric_ilu_ref(a2, pat))


def test_topilu_refactorize_updated_values_not_stale():
    """The cached sharded engine must re-read a.data on every call: an
    in-place value update followed by a refactorization yields the new
    factors, not the first call's."""
    a = matgen(72, density=0.08, seed=6)
    f1 = ilu(a, 1, backend="topilu", band_rows=8)
    a.data[:] = (a.data * 1.5 + 0.25).astype(np.float32)
    f2 = ilu(a, 1, backend="topilu", band_rows=8)
    _assert_bitwise(f2.vals, numeric_ilu_ref(a, f2.pattern))
    assert not np.array_equal(f2.vals.view(np.int32), f1.vals.view(np.int32))


# --------------------------------------------------------------------------
# end-to-end: solve_with_ilu unchanged vs the oracle-backend pipeline
# --------------------------------------------------------------------------
def test_solve_with_ilu_end_to_end_unchanged():
    from repro.core.solvers import solve_with_ilu

    a = poisson_2d(10)
    b = np.random.default_rng(0).standard_normal(a.n).astype(np.float32)
    res_jax, fact_jax = solve_with_ilu(a, b, k=1, backend="jax", tol=1e-6)
    res_orc, fact_orc = solve_with_ilu(a, b, k=1, backend="oracle", tol=1e-6)
    # identical factor values => identical preconditioner => identical solve
    _assert_bitwise(fact_jax.vals, fact_orc.vals)
    _assert_bitwise(res_jax.x, res_orc.x)
    assert res_jax.iterations == res_orc.iterations
    assert res_jax.converged


# --------------------------------------------------------------------------
# sharded factorization (1 device, in-process): device-resident output
# --------------------------------------------------------------------------
@pytest.mark.parametrize("rule", ["sum", "max"])
@pytest.mark.parametrize("k", [0, 1, 2])
def test_sharded_factorization_bitwise_single_device(k, rule):
    from repro.core.api import ilu_sharded

    a = matgen(96, density=0.06, seed=21 * k + (rule == "max"))
    pat = _pattern(a, k, rule)
    want = numeric_ilu_ref(a, pat)
    fact = ilu_sharded(a, k, rule=rule, band_rows=8)
    _assert_bitwise(fact.values_csr(), want)
    # sharded layout invariants hold even at D=1 (halo empty, all local)
    assert fact.plan.s_loc == fact.plan.n_pad
    assert fact.plan.halo_size == 0


def test_sharded_solve_matches_single_device():
    from repro.core.solvers import solve_sharded, solve_with_ilu

    a = poisson_2d(10)
    b = np.random.default_rng(2).standard_normal(a.n).astype(np.float32)
    r_ref, f_ref = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False)
    r_sh, f_sh = solve_sharded(a, b, k=1, tol=1e-6)
    _assert_bitwise(f_sh.values_csr(), f_ref.vals)
    _assert_bitwise(r_sh.x, r_ref.x)
    assert r_sh.converged and r_sh.iterations == r_ref.iterations


@pytest.mark.parametrize("k", [0, 1, 2])
def test_batched_sharded_solve_bitwise(k, monkeypatch):
    """Multi-RHS sharded solves: every column of a ragged (bucketed) batch
    must equal its per-column single-device solve bitwise — the padded
    vmap lanes are independent and sliced off."""
    from repro.core.solvers import bucket_batch, solve_sharded, solve_with_ilu

    monkeypatch.delenv("REPRO_BATCH_BUCKETS", raising=False)
    a = matgen(96, density=0.06, seed=31 + k)
    B = np.random.default_rng(3 + k).standard_normal((3, a.n)).astype(np.float32)
    assert bucket_batch(3) == 4  # ragged: rides the 4-bucket
    rs, fact = solve_sharded(a, B, k=k, band_rows=8, tol=1e-6)
    assert len(rs) == 3
    for i, r in enumerate(rs):
        r1, _ = solve_with_ilu(a, B[i], k=k, tol=1e-6, use_pallas=False)
        assert r.converged and r.iterations == r1.iterations
        _assert_bitwise(r.x, r1.x)
    # the batch shares the factorization and its cached precond
    rs2, fact2 = solve_sharded(a, B, k=k, band_rows=8, tol=1e-6, fact=fact)
    assert fact2 is fact
    for r, r2 in zip(rs, rs2):
        _assert_bitwise(r2.x, r.x)


def test_warm_solve_prepares_serving_buckets():
    """warm_solve pre-compiles the solve stack; a fresh RHS of a warmed
    bucket reuses the cached engines (identical bits, no new shapes)."""
    from repro.core.solvers import solve_sharded, solve_with_ilu, warm_solve

    a = poisson_2d(8)
    warm_solve(a, k=1, batch_sizes=(1, 2), band_rows=8, tol=1e-6)
    b = np.random.default_rng(5).standard_normal(a.n).astype(np.float32)
    r, fact = solve_sharded(a, b, k=1, band_rows=8, tol=1e-6)
    r1, _ = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False)
    assert r.converged
    _assert_bitwise(r.x, r1.x)
    # the sharded precond was AOT-warmed for the single-RHS shape
    assert 1 in fact.precond()._aot


# --------------------------------------------------------------------------
# multi-device engines (subprocess; exact == asserted by the check script).
# The sweep is the PR-3 acceptance contract: 1 vs 2 vs 4 devices, sharded
# value storage, bitwise equal to the oracle; 2-device cases also run the
# distributed precond+solve against the single-device path.
# --------------------------------------------------------------------------
def _run_md(devices, k, band_rows, broadcast="psum", solve=False, batch=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"  # don't probe for real TPUs (see test_topilu_multidevice)
    cmd = [sys.executable, MD_SCRIPT, "96", str(k), str(band_rows), broadcast]
    if solve:
        cmd.append("--solve")
    if batch:
        cmd.append("--batch")
    rc, out, err = run_checked(cmd, env=env, timeout=600)
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "bitwise-equal" in out


@pytest.mark.parametrize("k,band_rows", [(1, 8), (1, 32), (2, 8), (2, 32)])
def test_two_device_bitwise(k, band_rows):
    # the band_rows=8 cases also cover the ragged multi-RHS distributed solve
    _run_md(2, k, band_rows, solve=(band_rows == 8), batch=(band_rows == 8))


@pytest.mark.parametrize("k", [0, 1, 2])
def test_four_device_bitwise(k):
    # k=2 additionally runs the batched distributed solve on 4 devices
    _run_md(4, k, band_rows=8, solve=(k == 2), batch=(k == 2))
