"""Incomplete-inverse bit-compat across device counts and orderings.

Drives ``multidevice_check.py --inverse`` in a subprocess per device count
(JAX locks the host device count at first init): at D ∈ {1, 2, 4}, the
inverse factors and SpMV-chain applies of the permuted system — for
ordering ∈ {natural, rcm, fusion} × k ∈ {0, 1, 2} — must be bitwise-equal
to the single-threaded inverse oracle of the permuted matrix, and the
end-to-end ``solve_sharded(precond_method="inverse")`` (single + bucketed
multi-RHS) bitwise-equal to the single-device inverse solve.
"""
import os
import sys

import pytest

from subproc import run_checked

SCRIPT = os.path.join(os.path.dirname(__file__), "multidevice_check.py")


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_inverse_bitwise_across_devices(devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"  # no TPU probing in the child (see
    # test_topilu_multidevice.py for why this matters on CPU CI)
    rc, out, err = run_checked(
        [sys.executable, SCRIPT, "64", "1", "16", "psum", "--inverse"],
        env=env, timeout=420,
    )
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "bitwise-equal" in out
