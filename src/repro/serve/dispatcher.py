"""Async dispatcher: a background thread that runs the service's tick loop.

The synchronous shape (``submit`` … ``tick`` … read responses) is what the
tests drive; a deployment wants submits from tenant threads answered
without anyone calling ``tick``. :class:`Dispatcher` provides exactly that
hand-off:

* tenant threads call :meth:`submit` (same signature as
  ``SolveService.submit``) and block on ``SolveRequest.result()`` — the
  tick loop fires each request's ``done`` event via ``req.finish``;
* the dispatcher thread waits on a condition variable with a short timeout
  (so deadlines expire even with no new traffic), ticks while there is
  queued work, and parks when idle;
* :meth:`stop` is a clean shutdown: wake the thread, let it finish the
  in-flight tick, join. Requests still queued at stop time are drained by
  one final tick so nobody blocks forever.

The dispatcher deliberately owns **no** solver state — it is a thread and
a condition variable around ``service.tick()``; all batching, degradation,
and bit-compat behaviour stays in :class:`~repro.serve.service.SolveService`
(``tick`` is serialized by the service's own tick lock, so a stray manual
``tick()`` during dispatcher operation is safe, just pointless).
"""
from __future__ import annotations

import threading
from typing import Optional


class Dispatcher:
    """Background tick loop for a :class:`~repro.serve.service.SolveService`.

    Usage::

        with Dispatcher(svc) as d:
            req = d.submit("tenant", "m0", b)
            resp = req.result(timeout=30)
    """

    def __init__(self, service, idle_wait: float = 0.05):
        self.service = service
        self.idle_wait = float(idle_wait)
        self._cv = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.ticks_run = 0

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "Dispatcher":
        if self._thread is not None:
            raise RuntimeError("dispatcher already started")
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, name="serve-dispatcher", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 30.0) -> None:
        """Clean shutdown: wake the loop, finish in-flight work, join."""
        t = self._thread
        if t is None:
            return
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        t.join(timeout)
        self._thread = None
        # anything still queued (raced the shutdown) gets one final tick so
        # no submitter blocks forever on result()
        if len(self.service.queue):
            self.service.run_until_idle()

    def __enter__(self) -> "Dispatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    # -- tenant surface ----------------------------------------------------
    def submit(self, *args, **kw):
        """``SolveService.submit`` plus a wake-up: returns the pending
        request (block on ``.result()``) or the immediate failure response."""
        res = self.service.submit(*args, **kw)
        with self._cv:
            self._cv.notify_all()
        return res

    def notify(self) -> None:
        """Wake the loop early (e.g. after submitting via the service)."""
        with self._cv:
            self._cv.notify_all()

    # -- the loop ----------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                if self._stop:
                    return
                if not len(self.service.queue):
                    # bounded wait: deadlines must expire and stop() must
                    # land even if no submit ever notifies again
                    self._cv.wait(self.idle_wait)
                    if self._stop:
                        return
            if len(self.service.queue):
                try:
                    self.service.tick()
                except Exception:  # noqa: BLE001 — the loop must survive; the
                    # batch-level handlers already turned what they could
                    # into structured responses
                    pass
                self.ticks_run += 1
