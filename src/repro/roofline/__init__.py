"""repro.roofline"""
