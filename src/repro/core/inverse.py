"""Level-based incomplete inverse preconditioning — plan + engines (paper §V).

The execution-layer counterpart of ``repro.core.inverse_ref``: turn the
factorization into level-truncated approximate inverse factors ``W ~= L^{-1}``
and ``Z ~= U^{-1}`` once, so every preconditioner apply is the SpMV chain
``x = Z (W b)`` — two masked lane-ordered ELL products, no wavefront
recursion, and (sharded) no sweep epochs: the only collectives are the two
SpMV halo exchanges.

Plan -> compile -> execute, like every other stage:

* :func:`build_inverse_plan` (host, vectorized) reuses the already-computed
  level machinery of ``build_triangular_plan`` — the same strict-L/U ELL
  split and the same ``wavefront_schedule_ell`` wavefronts (computing W row
  i depends on exactly the rows the L sweep depends on) — and derives the
  truncated inverse sparsity from the oracle's min-plus closure
  (``inverse_pattern_ref``, the same fill-level rule as ILU(k)). It emits
  level-major gather tables so the value engine is one ``lax.scan``.
* :func:`inverse_values_jnp` computes the inverse values on device, one
  wavefront per scan step, every reduction through ``masked_lane_sum`` —
  bitwise equal to ``inverse_values_ref`` by construction.
* :class:`InversePrecondApply` / :class:`ShardedInversePrecondApply` are the
  drop-in ``PrecondApply`` counterparts behind the ``precond_method`` knob.

Bit-compat anchor: *not* the classical ILU(k) sweep (this is a different
approximation of M^{-1}) but the sequential NumPy oracle in
``inverse_ref.py`` — factors, applies, and solves must match it bitwise on
any device count (the paper-abstract contract for the inverse method).

``"auto"`` method selection extends the epoch/read-set sweep cost model
(``ShardedTriangularPlan.comm_summary`` / ``ordering.sweep_comm_model``)
with the SpMV-chain cost (:func:`inverse_comm_model`): the chain always
ships two full vector-slice gathers, the sweep ships exact read sets but
one collective per epoch — whichever modeled cost is lower wins.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .bitmath import masked_lane_sum
from .inverse_ref import inverse_pattern_ref
from .planner import COL_SENTINEL, wavefront_schedule_ell
from .sparse import ILUPattern


@dataclasses.dataclass
class InversePlan:
    """Inverse sparsity + level-major value-engine tables for both factors.

    ``w_cols``/``z_cols`` are the truncated inverse patterns (sentinel-padded
    ELL, diagonal included). The ``l_*``/``u_*`` tables drive
    :func:`inverse_values_jnp`: per (level, rank) row they carry the strict
    factor lanes (``*_f_cols``/``*_f_vals``), a flat gather address per
    (output lane, factor lane) product into the slot-major inverse storage
    (``*_addr``; misses point at the trailing zero slot), the unit
    right-hand side (``*_rhs``), and the row -> slot map (``*_slot``).
    """

    n: int
    k: int
    w_cols: np.ndarray  # (n, WI) int32
    z_cols: np.ndarray  # (n, ZI) int32
    l_f_cols: np.ndarray  # (nl, maxr_l, WL) int32 — global col ids (mask: < n)
    l_f_vals: np.ndarray  # (nl, maxr_l, WL) f32
    l_addr: np.ndarray  # (nl, maxr_l, WI, WL) int32 into W slot-flat storage
    l_rhs: np.ndarray  # (nl, maxr_l, WI) f32
    l_slot: np.ndarray  # (n,) int64 — row -> W slot
    u_f_cols: np.ndarray  # (nu, maxr_u, WU) int32
    u_f_vals: np.ndarray  # (nu, maxr_u, WU) f32
    u_addr: np.ndarray  # (nu, maxr_u, ZI, WU) int32 into Z slot-flat storage
    u_rhs: np.ndarray  # (nu, maxr_u, ZI) f32
    u_diag: np.ndarray  # (nu, maxr_u) f32, 1-padded
    u_slot: np.ndarray  # (n,) int64 — row -> Z slot

    @property
    def depth(self) -> int:
        """Wavefront depth paid once at value-computation time (the apply
        itself is depth 2 — one SpMV per factor)."""
        return self.l_f_cols.shape[0] + self.u_f_cols.shape[0]

    def nnz_inverse(self) -> int:
        return int((self.w_cols < self.n).sum() + (self.z_cols < self.n).sum())


def _factor_tables(levels: np.ndarray, f_cols: np.ndarray, f_vals: np.ndarray,
                   inv_cols: np.ndarray, n: int):
    """Level-major tables for one factor's inverse value sweep (vectorized).

    For row i at (level, rank), output lane t (inverse column j), factor
    lane s (dependency row m): the engine accumulates
    ``f_vals[i,s] * Winv[m,j]`` — ``addr[..., t, s]`` resolves (m, j) to its
    flat slot-major storage address, or to the trailing zero slot when the
    truncated pattern dropped (m, j) (the oracle's gathered 0.0).
    """
    from .triangular import _slot_of_row

    nlev, maxr = levels.shape
    WI = inv_cols.shape[1]
    pad = levels >= n
    rows = np.minimum(levels, max(n - 1, 0))
    fc = np.where(pad[:, :, None], COL_SENTINEL, f_cols[rows]).astype(np.int32)
    fv = np.where(pad[:, :, None], 0.0, f_vals[rows]).astype(np.float32)
    slot_of = _slot_of_row(levels, n)
    flat = nlev * maxr * WI

    # global (m, j) -> storage-address lookup over the stored inverse entries;
    # keys ascend (row-major over ascending-column rows) so searchsorted works
    valid = inv_cols < n
    rowm = np.broadcast_to(np.arange(n)[:, None], inv_cols.shape)
    lane = np.broadcast_to(np.arange(WI)[None, :], inv_cols.shape)
    keys = rowm[valid].astype(np.int64) * (n + 1) + inv_cols[valid]
    store = slot_of[rowm[valid]] * WI + lane[valid]

    m_all = fc[:, :, None, :].astype(np.int64)  # (nlev, maxr, 1, WF)
    j_all = np.where(pad[:, :, None], n, inv_cols[rows]).astype(np.int64)[..., None]
    ok = (m_all < n) & (j_all < n)
    q = np.where(ok, m_all * (n + 1) + j_all, 0)
    posn = np.searchsorted(keys, q)
    hit = ok & (posn < keys.size)
    hp = np.where(hit, posn, 0)
    hit &= keys[hp] == q
    addr = np.where(hit, store[hp], flat).astype(np.int32)

    rhs = ((inv_cols[rows] == rows[:, :, None]) & ~pad[:, :, None]).astype(np.float32)
    return fc, fv, addr, rhs, slot_of


def build_inverse_plan(pattern: ILUPattern, vals: np.ndarray, k=None) -> InversePlan:
    """Host planning: truncated inverse sparsity + level-major value tables.

    Reuses the triangular stack's primitives — ``_split_lu_ell`` for the
    strict factor ELL split and ``wavefront_schedule_ell`` for the level
    structure (the W/Z value dependencies are exactly the L/U sweep
    dependencies). ``k`` defaults to the pattern's fill level.
    """
    from .triangular import _split_lu_ell

    k = pattern.k if k is None else int(k)
    n = pattern.n
    vals = np.asarray(vals, np.float32)
    l_cols, l_vals, u_cols, u_vals, diag = _split_lu_ell(pattern, vals)
    w_cols, z_cols = inverse_pattern_ref(pattern, k)
    l_levels = wavefront_schedule_ell(l_cols, n)
    u_levels = wavefront_schedule_ell(u_cols, n)

    lf, lv, la, lr, ls = _factor_tables(l_levels, l_cols, l_vals, w_cols, n)
    uf, uv, ua, ur, us = _factor_tables(u_levels, u_cols, u_vals, z_cols, n)
    pad_u = u_levels >= n
    rows_u = np.minimum(u_levels, max(n - 1, 0))
    u_diag = np.where(pad_u, 1.0, diag[rows_u]).astype(np.float32)

    return InversePlan(
        n=n, k=k, w_cols=w_cols, z_cols=z_cols,
        l_f_cols=lf, l_f_vals=lv, l_addr=la, l_rhs=lr, l_slot=ls,
        u_f_cols=uf, u_f_vals=uv, u_addr=ua, u_rhs=ur, u_diag=u_diag, u_slot=us,
    )


def inverse_values_jnp(f_cols, f_vals, addr, rhs, diag, limit):
    """One factor's level-major inverse value sweep (bit anchor:
    ``inverse_values_ref``).

    Per wavefront: gather the already-computed inverse entries for every
    (row, output lane, factor lane) product, reduce over factor lanes in
    ascending column order through ``masked_lane_sum`` (mask: factor column
    < ``limit`` = n — identical lanes, identical order, identical +0.0
    masking as the sequential oracle), subtract from the unit RHS, divide by
    ``diag`` (U only), and write the wavefront's contiguous slot block.
    Returns the slot-major (n_slots, WI) value array.
    """
    nlev, maxr, WI, WF = addr.shape
    flat = nlev * maxr * WI

    def step(carry, inp):
        w, start = carry
        if diag is None:
            c, v, a, r = inp
        else:
            c, v, a, r, d = inp
        g = w[a]  # (maxr, WI, WF); misses land on the trailing zero slot
        cb = jnp.broadcast_to(c[:, None, :], a.shape)
        vb = jnp.broadcast_to(v[:, None, :], a.shape)
        y = r - masked_lane_sum(cb, vb, g, limit)
        if diag is not None:
            y = y / d[:, None]
        w = jax.lax.dynamic_update_slice(w, y.reshape(-1), (start,))
        return (w, start + maxr * WI), None

    inp = (f_cols, f_vals, addr, rhs) + (() if diag is None else (diag,))
    w0 = jnp.zeros(flat + 1, jnp.float32)
    (w, _), _ = jax.lax.scan(step, (w0, jnp.int32(0)), inp)
    return w[:flat].reshape(nlev * maxr, WI)


_values_exec = jax.jit(inverse_values_jnp, static_argnames=("limit",))


def compute_inverse_values(plan: InversePlan):
    """Both factors' inverse values on device: row-major ELL aligned with
    ``plan.w_cols``/``plan.z_cols``, pad lanes normalized to +0.0 (the
    engine's pad-lane arithmetic — e.g. 0/−diag — never escapes; the oracle
    leaves pads at 0.0 and so do we)."""
    n = plan.n
    w = _values_exec(jnp.asarray(plan.l_f_cols), jnp.asarray(plan.l_f_vals),
                     jnp.asarray(plan.l_addr), jnp.asarray(plan.l_rhs),
                     None, limit=n)
    w = jnp.where(jnp.asarray(plan.w_cols) < n, w[jnp.asarray(plan.l_slot)], 0.0)
    z = _values_exec(jnp.asarray(plan.u_f_cols), jnp.asarray(plan.u_f_vals),
                     jnp.asarray(plan.u_addr), jnp.asarray(plan.u_rhs),
                     jnp.asarray(plan.u_diag), limit=n)
    z = jnp.where(jnp.asarray(plan.z_cols) < n, z[jnp.asarray(plan.u_slot)], 0.0)
    return w, z


def inverse_chain_jnp(w_cols, w_vals, z_cols, z_vals, b):
    """x = Z (W b): the fused two-SpMV preconditioner apply (jnp reference).

    The Pallas kernel (``repro.kernels.inverse_chain``) runs this exact
    computation on values read from refs; both reduce via
    ``masked_lane_sum`` so they are bit-identical — to each other and to
    ``inverse_apply_ref``.
    """
    n = b.shape[0]
    b = b.astype(jnp.float32)
    y = masked_lane_sum(w_cols, w_vals, b[jnp.minimum(w_cols, n - 1)], COL_SENTINEL)
    return masked_lane_sum(z_cols, z_vals, y[jnp.minimum(z_cols, n - 1)], COL_SENTINEL)


class InversePrecondApply:
    """Cached, device-resident M^{-1} ~= Z W apply — ``PrecondApply``'s
    drop-in counterpart for ``precond_method="inverse"``.

    Builds the inverse plan once, computes the inverse values on device
    (one scan per factor — the wavefront chain is paid here, not per
    apply), and exposes the same surface as ``PrecondApply``:

    * ``apply(b)`` / ``__call__`` — jitted fused SpMV chain (the Pallas
      ``inverse_chain`` kernel with ``use_pallas=True``, else the
      bit-identical jnp reference), safe inside outer jitted code;
    * ``batched(B)`` — the chain ``vmap``-ped over a RHS stack;
    * ``warm(batch_sizes)`` — AOT compilation for the serving hot path.
    """

    def __init__(self, pattern: ILUPattern, vals: np.ndarray,
                 use_pallas: bool = True, k=None, plan: Optional[InversePlan] = None):
        self.plan = plan if plan is not None else build_inverse_plan(pattern, vals, k=k)
        self.n = self.plan.n
        self.w_cols = jnp.asarray(self.plan.w_cols)
        self.z_cols = jnp.asarray(self.plan.z_cols)
        self.w_vals, self.z_vals = compute_inverse_values(self.plan)
        # the ELL arrays ride as jit *arguments*, never closure constants:
        # constant-embedded operands let XLA fold/fuse the chain with
        # different rounding (observed 1-ulp drift), breaking the bitwise
        # anchor — runtime operands keep the compiled arithmetic fixed
        self._args = (self.w_cols, self.w_vals, self.z_cols, self.z_vals)
        if use_pallas:
            from repro.kernels import ops  # deferred: keep core importable alone

            def _raw(wc, wv, zc, zv, b):
                return ops.inverse_chain(wc, wv, zc, zv, b.astype(jnp.float32))
        else:
            def _raw(wc, wv, zc, zv, b):
                return inverse_chain_jnp(wc, wv, zc, zv, b.astype(jnp.float32))
        self._apply_fn = jax.jit(_raw)
        self._batched_fn = jax.jit(jax.vmap(_raw, in_axes=(None, None, None, None, 0)))
        self._aot = {}

    def _apply(self, b):
        return self._apply_fn(*self._args, b)

    def _batched(self, bs):
        return self._batched_fn(*self._args, bs)

    def __call__(self, b):
        ex = self._aot.get(1)
        if ex is not None and not isinstance(b, jax.core.Tracer):
            return ex(*self._args, jnp.asarray(b, jnp.float32))
        return self._apply(b)

    apply = __call__

    def batched(self, bs):
        """Apply to a (batch, n) stack. If ``warm`` prepared a bucket >=
        batch, the stack zero-pads to it (vmap lanes are independent)."""
        if isinstance(bs, jax.core.Tracer):
            return self._batched(bs)
        bs = jnp.asarray(bs, jnp.float32)
        nb = bs.shape[0]
        fit = [w for w in self._aot if w != 1 and w >= nb]
        if not fit:
            return self._batched(bs)
        tgt = min(fit)
        if tgt > nb:
            bs = jnp.concatenate([bs, jnp.zeros((tgt - nb, self.n), jnp.float32)])
        return self._aot[tgt](*self._args, bs)[:nb]

    def warm(self, batch_sizes=(1,)):
        """AOT-compile the chain for the given RHS batch sizes (1 = the
        single-RHS apply). Returns {batch_size: compile_seconds}."""
        import time

        from .api import enable_jit_cache

        enable_jit_cache()
        out = {}
        for nb in batch_sizes:
            t0 = time.perf_counter()
            if nb not in self._aot:
                if nb == 1:
                    sds = jax.ShapeDtypeStruct((self.n,), jnp.float32)
                    self._aot[1] = self._apply_fn.lower(*self._args, sds).compile()
                else:
                    sds = jax.ShapeDtypeStruct((nb, self.n), jnp.float32)
                    self._aot[nb] = self._batched_fn.lower(*self._args, sds).compile()
            out[nb] = time.perf_counter() - t0
        return out


class ShardedInversePrecondApply:
    """Row-block sharded M^{-1} ~= Z W apply: the distributed SpMV chain.

    The inverse values are computed once by the single-device engine (the
    bitwise anchor holds for any device count because the values *are* the
    single-device values) and the W/Z ELL blocks are then placed row-block
    sharded over the mesh's band axis. Each apply is two sharded SpMVs: a
    device reduces its own rows through ``masked_lane_sum`` (the same lanes
    in the same order as single-device, hence bitwise equal) and ONE
    ``all_gather`` per SpMV reassembles the replicated vector — the only
    collectives on the apply path. No sweep epochs, no read-set fusion, and
    the collective count is independent of wavefront depth: 2 per apply,
    amortized over the whole RHS batch (``batched``).
    """

    AXIS = "band"

    def __init__(self, pattern: ILUPattern, vals: np.ndarray, mesh, k=None,
                 base: Optional[InversePrecondApply] = None,
                 plan: Optional[InversePlan] = None):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.compat import shard_map

        if base is None:
            base = InversePrecondApply(pattern, vals, use_pallas=False, k=k, plan=plan)
        self.base = base
        self.plan = base.plan
        self.mesh = mesh
        self.n = n = base.n
        D = int(mesh.devices.size)
        self.n_devices = D
        rows_loc = -(-n // D)
        n_pad = rows_loc * D
        self._n_pad = n_pad
        ax = self.AXIS

        def pad_rows(cols, vals_):
            cols, vals_ = np.asarray(cols), np.asarray(vals_)
            if n_pad > n:
                cols = np.concatenate([cols, np.full(
                    (n_pad - n, cols.shape[1]), COL_SENTINEL, np.int32)])
                vals_ = np.concatenate([vals_, np.zeros((n_pad - n, vals_.shape[1]), np.float32)])
            return cols, vals_

        wc, wv = pad_rows(self.plan.w_cols, base.w_vals)
        zc, zv = pad_rows(self.plan.z_cols, base.z_vals)
        sh = NamedSharding(mesh, P(ax, None))
        self._args = tuple(jax.device_put(jnp.asarray(x), sh) for x in (wc, wv, zc, zv))

        def chain(wc, wv, zc, zv, b):
            def one(b1):
                y_loc = masked_lane_sum(wc, wv, b1[jnp.minimum(wc, n - 1)], COL_SENTINEL)
                # untiled (D, rows_loc) gather + reshape: row blocks are
                # contiguous in device order, so this is the (n_pad,) vector
                # — and unlike tiled=True its vmap batching is bit-stable
                y = jax.lax.all_gather(y_loc, ax).reshape(-1)
                x_loc = masked_lane_sum(zc, zv, y[jnp.minimum(zc, n_pad - 1)], COL_SENTINEL)
                x = jax.lax.all_gather(x_loc, ax).reshape(-1)
                return x[:n]
            return jax.vmap(one)(b.astype(jnp.float32))

        self._sm = jax.jit(shard_map(
            chain, mesh=mesh,
            in_specs=(P(ax, None), P(ax, None), P(ax, None), P(ax, None),
                      P(None, None)),
            out_specs=P(None, None), check_vma=False))
        self._aot = {}

    def _chain(self, b2):
        nb = b2.shape[0]
        ex = self._aot.get(nb)
        if ex is not None and not isinstance(b2, jax.core.Tracer):
            return ex(*self._args, b2)
        return self._sm(*self._args, b2)

    def __call__(self, b):
        if getattr(b, "ndim", 1) == 2:
            return self.batched(b)
        if isinstance(b, jax.core.Tracer):
            return self._chain(b[None, :])[0]
        b2 = jnp.asarray(np.asarray(b, np.float32).reshape(1, -1))
        return self._chain(b2)[0]

    apply = __call__

    def batched(self, bs):
        """Apply to a (nb, n) stack — both collectives carry the whole
        batch. A warmed bucket >= nb absorbs ragged batches by padding."""
        bs = bs if isinstance(bs, jax.core.Tracer) else jnp.asarray(bs, jnp.float32)
        nb = bs.shape[0]
        if not isinstance(bs, jax.core.Tracer):
            fit = [w for w in self._aot if w >= nb]
            if fit and nb not in self._aot:
                tgt = min(fit)
                bs = jnp.concatenate([bs, jnp.zeros((tgt - nb, self.n), jnp.float32)])
        return self._chain(bs)[:nb]

    def lower(self, nb: int = 1):
        """AOT-lower the chain for a (nb, n) batch (HLO inspection + warm)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        def sds(arr):
            return jax.ShapeDtypeStruct(arr.shape, arr.dtype, sharding=arr.sharding)

        b_s = jax.ShapeDtypeStruct(
            (nb, self.n), jnp.float32,
            sharding=NamedSharding(self.mesh, P(None, None)))
        return self._sm.lower(*[sds(a) for a in self._args], b_s)

    def warm(self, batch_sizes=(1,)):
        """AOT-compile the chain for the given RHS batch sizes."""
        import time

        from .api import enable_jit_cache

        enable_jit_cache()
        out = {}
        for nb in batch_sizes:
            t0 = time.perf_counter()
            if nb not in self._aot:
                self._aot[nb] = self.lower(nb).compile()
            out[nb] = time.perf_counter() - t0
        return out


# --------------------------------------------------------------------------
# the "auto" cost model: sweep epochs vs the SpMV chain
# --------------------------------------------------------------------------
# modeled fixed cost of one collective, in payload-byte equivalents — the
# latency term that makes many small epoch exchanges lose to two big
# vector-slice gathers (and a single cheap assembly beat them back)
AUTO_COLLECTIVE_COST_BYTES = 4096


def inverse_comm_model(n: int, n_devices: int, nb: int = 1) -> dict:
    """The SpMV-chain communication record, same schema as the sweep's
    ``comm_summary``: two all_gathers per apply, each shipping this device's
    ceil(n/D) vector slice to the D-1 others (ring model), amortized over
    the whole RHS batch."""
    D = int(n_devices)
    if D <= 1:
        return {"n_devices": 1, "collectives_per_apply": 0,
                "payload_slots_per_apply": 0, "bytes_per_apply": 0}
    rows_loc = -(-int(n) // D)
    return {
        "n_devices": D,
        "collectives_per_apply": 2,
        "payload_slots_per_apply": 2 * rows_loc,
        "bytes_per_apply": (D - 1) * 2 * rows_loc * 4 * nb,
    }


def modeled_apply_cost(summary: dict) -> int:
    """Scalar cost of one preconditioner apply from a communication record
    (sweep ``comm_summary`` or :func:`inverse_comm_model`): per-collective
    latency plus wire bytes."""
    return (summary["collectives_per_apply"] * AUTO_COLLECTIVE_COST_BYTES
            + summary["bytes_per_apply"])


def resolve_precond_method(method: str, pattern: Optional[ILUPattern] = None,
                           n_devices: int = 1, band_rows: int = 32,
                           sweep_summary: Optional[dict] = None) -> str:
    """Resolve ``precond_method`` ("sweep" | "inverse" | "auto").

    ``"auto"`` picks per matrix: single-device always sweeps (the exact
    apply, no collectives either way, fewer Krylov iterations); distributed,
    the modeled sweep cost (epoch collectives + exact read-set bytes, from
    ``comm_summary``) races the modeled SpMV-chain cost
    (:func:`inverse_comm_model`) and the cheaper apply wins. Pass
    ``sweep_summary`` to reuse an existing plan's record; otherwise one is
    modeled from ``pattern`` via ``ordering.sweep_comm_model``.
    """
    if method not in ("sweep", "inverse", "auto"):
        raise ValueError(f"precond_method must be 'sweep', 'inverse' or 'auto', got {method!r}")
    if method != "auto":
        return method
    if n_devices <= 1:
        return "sweep"
    if sweep_summary is None:
        from .ordering import sweep_comm_model

        sweep_summary = sweep_comm_model(pattern, band_rows, n_devices)
    n = pattern.n if pattern is not None else None
    inv = inverse_comm_model(n, n_devices)
    return ("inverse" if modeled_apply_cost(inv) < modeled_apply_cost(sweep_summary) else "sweep")
