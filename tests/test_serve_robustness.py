"""Graceful degradation in the serve path.

The acceptance bar from the issue: injected breakdowns (NaN RHS slipping
in post-admission, singular/zero-pivot matrices, a raising engine) never
crash the service and never poison co-batched lanes — each failing request
gets a structured error or degraded response, and **every healthy lane in
the same tick stays bitwise-equal to its solo solve**. Plus: deadlines,
health probes, the async dispatcher, and the robustness metrics schema.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.matgen import matgen, zero_diagonal_matrix
from repro.core.solvers import solve_with_ilu
from repro.serve import (
    AdmissionError,
    Dispatcher,
    ServeConfig,
    SolveRequest,
    SolveResponse,
    SolveService,
)

N = 48


def _svc(**kw):
    kw.setdefault("cache_capacity", 4)
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("restart", 8)
    return SolveService(ServeConfig(**kw))


def _rhs(n, seed):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _assert_bitwise_vs_solo(resp, a, b, tol=1e-5, restart=8, k=1):
    ref, _ = solve_with_ilu(a, b, k=k, tol=tol, restart=restart, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(resp.x, np.float32).view(np.int32),
                                  np.asarray(ref.x, np.float32).view(np.int32))


# ---------------------------------------------------------------------------
# lane-level quarantine
# ---------------------------------------------------------------------------
def test_nan_lane_fails_alone_healthy_lanes_bitwise():
    """A NaN RHS that slips past admission (mutated post-submit) classifies
    as a breakdown verdict: that request fails with a structured BREAKDOWN
    response after the shift retry also breaks down; its co-batched
    neighbours succeed bitwise-equal to their solo solves."""
    svc = _svc()
    a = matgen(N, 0.12, seed=1)
    svc.register_matrix("m0", a, k=1)
    good_bs = [_rhs(N, 10 + i) for i in range(3)]
    good = [svc.submit("t0", "m0", b) for b in good_bs]
    poisoned = svc.submit("t1", "m0", _rhs(N, 20))
    assert isinstance(poisoned, SolveRequest)
    poisoned.b = np.full(N, np.nan, np.float32)  # post-admission poisoning

    resps = {r.request_id: r for r in svc.tick()}
    bad = resps[poisoned.request_id]
    assert not bad.ok and bad.error_reason == "breakdown"
    assert bad.verdict == "breakdown"
    for req, b in zip(good, good_bs):
        r = resps[req.request_id]
        assert r.ok and r.verdict == "converged" and not r.degraded
        _assert_bitwise_vs_solo(r, a, b)
    snap = svc.metrics_snapshot()
    assert snap["robustness"]["breakdown_lanes"] == 1
    assert snap["robustness"]["shift_retries"] == 1
    assert svc.cache.entry("m0").pins == 0


def test_engine_raise_quarantines_to_solo_lanes():
    """An engine that raises on multi-lane batches but works solo: the
    batch quarantines, every request is re-dispatched alone and succeeds
    bitwise — nobody pays for the co-batching."""
    svc = _svc()
    a = matgen(N, 0.12, seed=2)
    svc.register_matrix("m0", a, k=1)
    engine = svc.cache.entry("m0").engine
    orig = engine.solve

    def flaky(binding, bs, tols):
        if np.asarray(bs).shape[0] > 1:
            raise RuntimeError("injected multi-lane failure")
        return orig(binding, bs, tols)

    engine.solve = flaky
    try:
        bs = [_rhs(N, 30 + i) for i in range(3)]
        reqs = [svc.submit(f"t{i}", "m0", b) for i, b in enumerate(bs)]
        resps = {r.request_id: r for r in svc.tick()}
        assert len(resps) == 3
        for req, b in zip(reqs, bs):
            r = resps[req.request_id]
            assert r.ok, r.error
            _assert_bitwise_vs_solo(r, a, b)
    finally:
        engine.solve = orig
    snap = svc.metrics_snapshot()
    assert snap["robustness"]["quarantined_batches"] == 1
    assert svc.cache.entry("m0").pins == 0


def test_solo_poison_fails_structured_survivors_redispatch():
    """One request whose lane makes the whole engine raise: quarantine
    re-dispatches everyone solo; survivors succeed, the poisoned one gets
    its own structured solve_failed."""
    svc = _svc()
    a = matgen(N, 0.12, seed=3)
    svc.register_matrix("m0", a, k=1)
    engine = svc.cache.entry("m0").engine
    orig = engine.solve

    def poisoned_engine(binding, bs, tols):
        if not np.isfinite(np.asarray(bs)).all():
            raise RuntimeError("poisoned lane blew up the kernel")
        return orig(binding, bs, tols)

    engine.solve = poisoned_engine
    try:
        good_bs = [_rhs(N, 40 + i) for i in range(2)]
        good = [svc.submit("t0", "m0", b) for b in good_bs]
        doomed = svc.submit("t1", "m0", _rhs(N, 50))
        doomed.b = np.full(N, np.inf, np.float32)
        resps = {r.request_id: r for r in svc.tick()}
        assert not resps[doomed.request_id].ok
        assert resps[doomed.request_id].error_reason == "solve_failed"
        for req, b in zip(good, good_bs):
            assert resps[req.request_id].ok
            _assert_bitwise_vs_solo(resps[req.request_id], a, b)
    finally:
        engine.solve = orig
    assert svc.metrics_snapshot()["robustness"]["quarantined_batches"] == 1


# ---------------------------------------------------------------------------
# degraded registration + responses
# ---------------------------------------------------------------------------
def test_breakdown_matrix_registers_shifted_and_serves_degraded():
    """Registering a matrix whose ILU(k) breaks down under
    on_breakdown="shift": the binding lands shifted, solves succeed, and
    responses are marked degraded with the shift α attached."""
    svc = _svc(on_breakdown="shift")
    a = zero_diagonal_matrix(N, 0.12, seed=4, row=0)
    svc.register_matrix("m0", a, k=1)
    binding = svc.cache.entry("m0").binding
    assert binding.shift > 0
    req = svc.submit("t0", "m0", _rhs(N, 60))
    (resp,) = svc.tick()
    assert resp.ok and resp.request_id == req.request_id
    assert resp.degraded and resp.shift == binding.shift
    assert np.isfinite(np.asarray(resp.x)).all()
    snap = svc.metrics_snapshot()
    assert snap["robustness"]["broken_factorizations"] == 1
    assert snap["robustness"]["shifted_bindings"] == 1
    assert snap["robustness"]["degraded_responses"] == 1


def test_breakdown_matrix_raises_at_register_when_policy_raise():
    svc = _svc(on_breakdown="raise")
    a = zero_diagonal_matrix(N, 0.12, seed=4, row=0)
    with pytest.raises(AdmissionError) as ei:
        svc.register_matrix("m0", a, k=1)
    assert ei.value.reason == "breakdown"
    assert "m0" not in svc.cache


def test_breaking_value_update_rejected_old_binding_serves():
    """A value push that breaks down under on_breakdown="raise" is
    rejected: the old binding keeps serving bitwise-correct."""
    svc = _svc(on_breakdown="raise")
    a = matgen(N, 0.12, seed=5)
    svc.register_matrix("m0", a, k=1)
    bad = a.data.copy()
    lo, hi = a.indptr[0], a.indptr[1]
    bad[lo + int(np.searchsorted(a.indices[lo:hi], 0))] = 0.0  # zero pivot
    t = svc.update_matrix_values("m0", bad)
    t.join()
    assert svc.cache.entry("m0").binding.version == 1  # swap refused
    b = _rhs(N, 70)
    svc.submit("t0", "m0", b)
    (resp,) = svc.tick()
    assert resp.ok and resp.matrix_version == 1
    _assert_bitwise_vs_solo(resp, a, b)
    assert svc.metrics_snapshot()["robustness"]["rejected_updates"] == 1


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_expired_before_dispatch():
    svc = _svc()
    a = matgen(N, 0.12, seed=6)
    svc.register_matrix("m0", a, k=1)
    late = svc.submit("t0", "m0", _rhs(N, 80), deadline_seconds=0.001)
    ok_b = _rhs(N, 81)
    fine = svc.submit("t1", "m0", ok_b)        # no deadline
    time.sleep(0.01)
    resps = {r.request_id: r for r in svc.tick()}
    assert not resps[late.request_id].ok
    assert resps[late.request_id].error_reason == "deadline_exceeded"
    assert resps[fine.request_id].ok
    _assert_bitwise_vs_solo(resps[fine.request_id], a, ok_b)
    assert svc.metrics_snapshot()["robustness"]["deadline_expired"] == 1
    assert svc.cache.entry("m0").pins == 0


def test_default_deadline_from_config_and_bad_deadline():
    svc = _svc(default_deadline_seconds=0.001)
    a = matgen(N, 0.12, seed=7)
    svc.register_matrix("m0", a, k=1)
    req = svc.submit("t0", "m0", _rhs(N, 82))
    assert req.deadline_seconds == 0.001
    time.sleep(0.01)
    (resp,) = svc.tick()
    assert not resp.ok and resp.error_reason == "deadline_exceeded"
    bad = svc.submit("t0", "m0", _rhs(N, 83), deadline_seconds=-2)
    assert isinstance(bad, SolveResponse) and bad.error_reason == "bad_deadline"


# ---------------------------------------------------------------------------
# probes + metrics schema
# ---------------------------------------------------------------------------
def test_probes_and_robustness_schema():
    svc = _svc()
    hz = svc.healthz()
    assert hz["ok"] and hz["resident_matrices"] == 0
    assert not svc.readyz()["ready"]            # nothing resident, not warm
    a = matgen(N, 0.12, seed=8)
    svc.register_matrix("m0", a, k=1)
    assert not svc.readyz()["ready"]            # resident but not warmed
    svc.warmup()
    assert svc.readyz()["ready"]
    svc.submit("t0", "m0", _rhs(N, 90))
    svc.tick()
    snap = svc.metrics_snapshot()
    assert isinstance(snap["robustness"], dict)
    th = snap["tick_health"]
    assert set(th) >= {"observed", "slow_ticks", "deadline_factor",
                       "mean_seconds", "p99_seconds"}
    assert th["observed"] == snap["ticks"] >= 1
    assert th["mean_seconds"] > 0.0


# ---------------------------------------------------------------------------
# async dispatcher
# ---------------------------------------------------------------------------
def test_dispatcher_mini_soak_bitwise_and_clean_shutdown():
    """Two tenant threads push 20 requests each through the dispatcher;
    every response arrives via result(), bitwise-equal to its solo solve;
    stop() joins cleanly and leaves nothing queued."""
    svc = _svc()
    a = matgen(N, 0.12, seed=9)
    svc.register_matrix("m0", a, k=1)
    svc.warmup()
    results = {}
    lock = threading.Lock()

    def tenant(tag, seed0):
        rng_seed = seed0
        for i in range(20):
            b = _rhs(N, rng_seed + i)
            req = disp.submit(tag, "m0", b, tol=1e-5)
            resp = req.result(timeout=60)
            with lock:
                results[req.request_id] = (b, resp)

    with Dispatcher(svc, idle_wait=0.01) as disp:
        threads = [threading.Thread(target=tenant, args=(f"t{j}", 100 * (j + 1)))
                   for j in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        assert disp.running
    assert not disp.running
    assert len(svc.queue) == 0
    assert len(results) == 40
    # snapshot before the reference solves: they compile their own engines
    # and must not pollute the serving-path counter
    assert svc.metrics_snapshot()["compiles"]["after_warmup"] == 0
    for b, resp in results.values():
        assert resp is not None and resp.ok
        _assert_bitwise_vs_solo(resp, a, b)


def test_dispatcher_stop_drains_queued_work():
    svc = _svc()
    a = matgen(N, 0.12, seed=11)
    svc.register_matrix("m0", a, k=1)
    disp = Dispatcher(svc)           # never started: queue work, stop drains
    disp.start()
    disp.stop()
    req = svc.submit("t0", "m0", _rhs(N, 120))
    disp2 = Dispatcher(svc)
    disp2.start()
    resp = req.result(timeout=60)
    disp2.stop()
    assert resp is not None and resp.ok
