"""whisper-tiny [audio] — enc-dec; conv frontend is a STUB: input_specs
provides precomputed frame embeddings (B, 1500, 384). [arXiv:2212.04356].

6 heads % 16 != 0 -> attention TP replicated; vocab padded 51865 -> 51968.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers (backbone driven by the assigned shapes)
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_real=51865,
    use_rope=False,  # learned/sinusoidal positions
    mlp_act="gelu",
    norm="layernorm",
    encoder_layers=4,
    encoder_seq=1500,
    tie_embeddings=True,
)
