"""KV-cache correctness: token-by-token decode must reproduce the logits of
a full-sequence forward pass (the strongest cache/positions/rope test)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

# families with exact decode parity: dense GQA, MLA (absorbed form), ssm
ARCHS = ["smollm-135m", "qwen1.5-0.5b", "deepseek-v2-lite-16b", "xlstm-125m"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=2, q_chunk=16, kv_chunk=16)
    if cfg.n_routed_experts:
        # capacity dropping legitimately depends on batch composition
        # (prefill routes T tokens, decode routes 1) — give every expert
        # full capacity so parity is exact
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_routed_experts))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_real, (B, S)), jnp.int32)

    full = M.forward(cfg, params, {"tokens": tokens})  # (B, S, V)

    cache = M.init_cache(cfg, B, cache_len=S)
    step = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
    got = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1])
        got.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(got, axis=1)  # (B, S, V)

    want = np.asarray(full, np.float32)
    # compare log-softmax (head scale-invariant comparison), generous f32 tol
    def lsm(x):
        x = x[..., : cfg.vocab_real]
        return x - np.max(x, axis=-1, keepdims=True)

    err = np.max(np.abs(lsm(got) - lsm(want)))
    assert err < 0.05, f"decode/forward mismatch: max err {err}"
    # and the argmax trajectory must agree everywhere
    np.testing.assert_array_equal(
        np.argmax(got[..., : cfg.vocab_real], -1),
        np.argmax(want[..., : cfg.vocab_real], -1),
    )
