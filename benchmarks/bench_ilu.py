"""Paper-table benchmarks for TOP-ILU. One function per table/figure.

All matrices are scaled to container time budgets (paper densities kept);
sequential phase times are MEASURED on this implementation, cluster
speedups come from the calibrated model in ``repro.core.perf_model``
(1-core container — see DESIGN.md §8.2). Quick mode shrinks sizes further.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    matgen,
    convection_diffusion_2d,
    numeric_ilu_ref,
    pilu1_symbolic,
    symbolic_ilu_k,
)
from repro.core.api import ilu
from repro.core.perf_model import (
    GIG_E, INFINIBAND, ClusterSpec, WorkloadStats, predict_times, speedup_curve,
)


def _measure(a, k):
    t0 = time.perf_counter()
    pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)
    t1 = time.perf_counter()
    numeric_ilu_ref(a, pat)
    t2 = time.perf_counter()
    return pat, t1 - t0, t2 - t1


def table1_load_balancing(quick=True):
    """Table I: dynamic vs static LB, k=2/3 — static wins at every P."""
    n = 2000 if quick else 8000
    a = matgen(n, density=0.0025 if quick else 0.001, seed=0)
    rows = []
    for k, cpus in ((2, 4), (3, 7), (3, 10)):
        pat, ts, tn = _measure(a, k)
        w = WorkloadStats(n=n, n_f=pat.nnz, t_symbolic=ts, t_numeric=tn,
                          n_bands=max(n // 64, 1), k=k)
        spec = ClusterSpec(bandwidth=GIG_E)
        dyn = predict_times(w, cpus, spec, dynamic_lb=True)
        sta = predict_times(w, cpus, spec, dynamic_lb=False)
        rows.append((n, "D", cpus, k, round(dyn["speedup"], 1)))
        rows.append((n, "S", cpus, k, round(sta["speedup"], 1)))
    return ("n,LB,cpus,k,speedup", rows,
            all(rows[i][4] <= rows[i + 1][4] for i in range(0, len(rows), 2)))


def fig6_symbolic_vs_numeric(quick=True):
    """Fig 6: the symbolic/numeric time ratio does not decrease with k."""
    sizes = [512, 1024] if quick else [1024, 2048, 4096, 8192]
    dens = {512: 0.073, 1024: 0.073, 2048: 0.036, 4096: 0.009, 8192: 0.002}
    rows = []
    for n in sizes:
        ratios = []
        for k in range(1, 4 if quick else 6):
            a = matgen(n, density=dens[n], seed=1)
            _, ts, tn = _measure(a, k)
            ratios.append(round(ts / max(tn, 1e-9), 3))
        rows.append((n, ratios, all(ratios[i + 1] >= ratios[i] * 0.5
                                    for i in range(len(ratios) - 1))))
    return ("n,sym/num ratios by k", rows)


def tables23_pilu1(quick=True):
    """Tables II/III: sequential vs PILU(1), k=1, paper-style densities."""
    cases = ([(2000, 0.01)] if quick else [(4000, 0.003), (8000, 0.001), (16000, 0.0006)])
    rows = []
    for n, dens in cases:
        a = matgen(n, density=dens, seed=2)
        pat, ts, tn = _measure(a, 1)
        w = WorkloadStats(n=n, n_f=pat.nnz, t_symbolic=ts, t_numeric=tn,
                          n_bands=max(n // 8, 1), k=1)
        for cpus in (30, 40, 50, 60):
            pred = predict_times(w, cpus, ClusterSpec(bandwidth=GIG_E))
            rows.append((n, cpus, pat.nnz, round(ts, 3), round(tn, 3), round(pred["speedup"], 1)))
    return ("n,cpus,final_entries,t_sym,t_num,predicted_speedup", rows)


def fig8_infiniband(quick=True):
    """Fig 8: more bandwidth (InfiniBand) extends scaling to 80-100 CPUs."""
    n = 2000 if quick else 16000
    a = matgen(n, density=0.01 if quick else 0.0006, seed=3)
    pat, ts, tn = _measure(a, 1)
    w = WorkloadStats(n=n, n_f=pat.nnz, t_symbolic=ts, t_numeric=tn, n_bands=max(n // 8, 1), k=1)
    ps = (20, 40, 60, 80, 100)
    ge = speedup_curve(w, ps, ClusterSpec(bandwidth=GIG_E))
    ib = speedup_curve(w, ps, ClusterSpec(bandwidth=INFINIBAND))
    better = all(ib[p] >= ge[p] for p in ps)
    peak_ge = max(ge, key=ge.get)
    peak_ib = max(ib, key=ib.get)
    return ("P,gigE,infiniband", [(p, round(ge[p], 1), round(ib[p], 1)) for p in ps],
            better, peak_ib >= peak_ge)


def fig9_grid_latency(quick=True):
    """Fig 9: inter-cluster latency degrades speedup gracefully."""
    n = 2000 if quick else 8000
    a = matgen(n, density=0.0046 if not quick else 0.01, seed=4)
    pat, ts, tn = _measure(a, 1)
    w = WorkloadStats(n=n, n_f=pat.nnz, t_symbolic=ts, t_numeric=tn, n_bands=max(n // 16, 1), k=1)
    rows = []
    for n_clusters, lat_ms in ((1, 0.0), (2, 17.0), (2, 24.0), (3, 17.0)):
        p = 100 if n_clusters == 1 else n_clusters * 50
        pred = predict_times(
            w, p, ClusterSpec(bandwidth=GIG_E, n_clusters=n_clusters,
                              inter_latency=lat_ms * 1e-3)
        )
        rows.append((f"{n_clusters}x{p//n_clusters}", lat_ms, round(pred["speedup"], 1)))
    monotone = rows[0][2] >= rows[1][2] >= rows[2][2]
    return ("clusters,latency_ms,speedup", rows, monotone)


def solver_engine(quick=True, n_rhs=4):
    """Device-resident preconditioned GMRES on the default solver problem
    (2-D Poisson, n≈16k full / n≈1k quick, ILU(1)).

    Measures what the paper says dominates at scale: preconditioner-apply
    latency and sustained GMRES iteration throughput. Returns a metrics
    dict (also serialized by ``run.py --emit-json``). ``first_solve``
    includes the one-time jit of the fused engine; ``steady_solve`` is what
    every later solve against the same factorization costs (the plan,
    device arrays, and compiled engine are all cached on it).
    """
    import jax.numpy as jnp

    from repro.core import poisson_2d
    from repro.core.solvers import csr_to_ell_arrays, gmres, gmres_batched, make_pallas_matvec

    nx = 32 if quick else 128
    a = poisson_2d(nx)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n).astype(np.float32)

    t0 = time.perf_counter()
    fact = ilu(a, 1, backend="oracle")
    t1 = time.perf_counter()
    cols, vals = csr_to_ell_arrays(a)
    matvec = make_pallas_matvec(cols, vals, a.n)
    precond = fact.precond()
    t2 = time.perf_counter()

    res = gmres(matvec, jnp.asarray(b), precond, tol=1e-5)
    t3 = time.perf_counter()
    reps = 3
    t4 = time.perf_counter()
    for r in range(reps):
        res = gmres(matvec, jnp.asarray(b), precond, tol=1e-5)
    t5 = time.perf_counter()
    steady = (t5 - t4) / reps

    # preconditioner-apply latency (the per-iteration hot path)
    bj = jnp.asarray(b)
    precond(bj).block_until_ready()
    t6 = time.perf_counter()
    for _ in range(50):
        out = precond(bj)
    out.block_until_ready()
    t7 = time.perf_counter()
    apply_s = (t7 - t6) / 50

    B = rng.standard_normal((n_rhs, a.n)).astype(np.float32)
    gmres_batched(matvec, jnp.asarray(B), precond, tol=1e-5)  # compile
    t8 = time.perf_counter()
    outs = gmres_batched(matvec, jnp.asarray(B), precond, tol=1e-5)
    t9 = time.perf_counter()

    return {
        "problem": {"kind": "poisson_2d", "n": a.n, "nnz": a.nnz, "k": 1,
                    "fill_nnz": fact.nnz, "tol": 1e-5, "restart": 30},
        "factorize_seconds": t1 - t0,
        "engine_build_seconds": t2 - t1,
        "gmres_first_solve_seconds": t3 - t2,  # includes one-time jit
        "gmres_steady_solve_seconds": steady,
        "gmres_iterations": res.iterations,
        "gmres_iters_per_sec": res.iterations / steady,
        "precond_apply_seconds": apply_s,
        "precond_applies_per_sec": 1.0 / apply_s,
        "batched_rhs": n_rhs,
        "batched_steady_seconds_per_rhs": (t9 - t8) / n_rhs,
        "batched_converged": all(o.converged for o in outs),
        "converged": res.converged,  # health flag — the harness always completes
        "residual": res.residual,
    }


def factorization(quick=True, sizes=None, k=1):
    """PR-2 tentpole metrics: the plan→compile→execute factorization
    pipeline on 2-D Poisson at n∈{4k,16k} (quick: {1k,4k}).

    Per size: vectorized symbolic, FactorPlan build, wavefront numeric
    engine (first call = includes the one-time jit; steady = what every
    refactorization of the same structure costs), and the sequential
    oracle for the speedup ratio + the bitwise check. Serialized by
    ``run.py --emit-json`` into BENCH_factor.json.
    """
    from repro.core import poisson_2d
    from repro.core.factor_plan import build_factor_plan

    if sizes is None:
        sizes = (32, 64) if quick else (64, 128)  # nx; n = nx^2
    out = {"bench": "factorization", "k": k, "cases": []}
    for nx in sizes:
        a = poisson_2d(nx)
        t0 = time.perf_counter()
        pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)
        t1 = time.perf_counter()
        plan = build_factor_plan(a, pat)
        t2 = time.perf_counter()
        plan.factorize()  # first call: one-time engine jit
        t3 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            vals = plan.factorize()
        t4 = time.perf_counter()
        t5 = time.perf_counter()
        want = numeric_ilu_ref(a, pat)
        t6 = time.perf_counter()
        steady = (t4 - t3) / reps
        out["cases"].append({
            "n": a.n, "nnz": a.nnz, "fill_nnz": pat.nnz,
            "rounds": plan.n_rounds, "max_ops": plan.max_ops,
            "symbolic_seconds": t1 - t0,
            "plan_build_seconds": t2 - t1,
            "numeric_first_seconds": t3 - t2,  # includes one-time jit
            "numeric_steady_seconds": steady,
            "oracle_numeric_seconds": t6 - t5,
            "steady_speedup_vs_oracle": (t6 - t5) / max(steady, 1e-9),
            "bitwise_equal_oracle": bool(
                np.array_equal(vals.view(np.int32), want.view(np.int32))
            ),
        })
    return out


def fig5_e40r3000(quick=True):
    """Fig 5: driven-cavity surrogate — parallel ILU(3)/ILU(6) both finish
    fast; ILU(6) is far more expensive sequentially."""
    nx = 40 if quick else 131  # 131^2 = 17161 ~ e40r3000's 17281
    a = convection_diffusion_2d(nx, seed=5)
    out = []
    for k in (3, 6) if not quick else (2, 3):
        pat, ts, tn = _measure(a, k)
        w = WorkloadStats(n=a.n, n_f=pat.nnz, t_symbolic=ts, t_numeric=tn,
                          n_bands=max(a.n // 32, 1), k=k)
        par = predict_times(w, 6, ClusterSpec(bandwidth=GIG_E))
        out.append((k, pat.nnz, round(ts + tn, 3), round(par["t_total"], 3)))
    seq_ratio = out[1][2] / max(out[0][2], 1e-9)
    par_ratio = out[1][3] / max(out[0][3], 1e-9)
    return ("k,entries,t_seq,t_par6", out, seq_ratio, par_ratio)
