"""Serve-layer trajectory: multi-tenant coalesced solves/sec (PR-8 tentpole).

Drives the production :class:`repro.serve.SolveService` with seeded
4-tenant traffic against an n=1024-class matrix — warmup, then a few
thousand coalesced solves with a mid-stream background value update —
and records the service-level acceptance numbers:

* end-to-end **solves/sec** (admission → coalesce → bucketed solve →
  scatter, ticks included) and raw solve-loop throughput,
* per-tenant p50/p99 latency and the mean batch solve time that should
  dominate it,
* the compile counter split at warmup (``after_warmup`` must be 0),
* cache hit rate + refactorization count,
* a seeded sample of responses re-solved solo
  (``solve_with_ilu(..., use_pallas=False)``) and compared **bitwise** on
  the exact value version each request was admitted under.

PR 9 adds two axes:

* ``robustness`` — a deterministic fault-injection segment (breakdown
  matrix registered under ``on_breakdown="shift"``, an expired deadline,
  a lane that goes non-finite mid-flight) recording the degradation
  counters (``shifted_bindings``, ``breakdown_lanes``, ``shift_retries``,
  ``deadline_expired``, ...) and that healthy traffic is unharmed.
* ``sharded`` — a scaled-down soak against :class:`ShardedServeEngine`
  on 2 and 4 virtual devices (one subprocess each — the host device
  count locks at first JAX init), with the same compile-flatness and
  bitwise-vs-solo bars.

Run via ``python -m benchmarks.run --emit-json BENCH_serve.json`` (which
spawns this file as a subprocess with a pinned CPU platform), or directly:

    JAX_PLATFORMS=cpu python benchmarks/bench_serve.py
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# the throughput configuration: matgen(1024, 0.004) converges in ~4 inner
# steps, so a right-sized restart (GMRES always runs the full masked
# restart window per outer iteration) is the solves/sec lever
N = 1024
DENSITY = 0.004
K = 1
RESTART = 4
MAXITER = 40
BUCKETS = (1, 2, 4, 8, 16, 32, 64)
TENANTS = ("t0", "t1", "t2", "t3")
BITWISE_SAMPLE = 24


def serve_trajectory(n_requests: int = 2000, seed: int = 17) -> dict:
    from repro.core.matgen import matgen
    from repro.core.solvers import solve_with_ilu
    from repro.core.sparse import CSRMatrix
    from repro.serve import ServeConfig, SolveService, run_traffic

    a = matgen(N, DENSITY, seed=5)
    svc = SolveService(ServeConfig(buckets=BUCKETS, restart=RESTART,
                                   maxiter=MAXITER, k=K))
    svc.register_matrix("m0", a)
    t0 = time.perf_counter()
    svc.warmup()
    warmup_seconds = time.perf_counter() - t0

    updates = {"m0": [(a.data * 1.1).astype(np.float32)]}
    t0 = time.perf_counter()
    result = run_traffic(svc, ["m0"], n_requests, seed=seed, tenants=TENANTS,
                         burst_max=max(BUCKETS), update_prob=0.01,
                         update_values=updates)
    wall = time.perf_counter() - t0
    snap = svc.metrics_snapshot()  # before reference solves (they compile)

    assert len(result.responses) == n_requests
    assert all(r.ok for r in result.responses)

    # seeded bitwise sample across value versions, buckets, lane positions
    rng = np.random.default_rng(seed)
    ref_mats = {1: a}
    for i, data in enumerate(result.updates["m0"]):
        ref_mats[2 + i] = CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices,
                                    data=data)
    by_id = {r.request_id: r for r in result.responses}
    sample = rng.choice(len(result.records), size=BITWISE_SAMPLE, replace=False)
    bitwise_ok = True
    for i in sample:
        rec = result.records[int(i)]
        resp = by_id[rec.request_id]
        ref, _ = solve_with_ilu(ref_mats[rec.expected_version], rec.b, k=K,
                                tol=rec.tol, restart=RESTART, maxiter=MAXITER,
                                use_pallas=False)
        bitwise_ok &= bool(np.array_equal(
            np.asarray(resp.x, np.float32).view(np.int32),
            np.asarray(ref.x, np.float32).view(np.int32)))

    co, ca, cp = snap["coalescing"], snap["cache"], snap["compiles"]
    lat = [snap["tenants"][t] for t in TENANTS]
    return {
        "n": N,
        "k": K,
        "restart": RESTART,
        "maxiter": MAXITER,
        "buckets": list(BUCKETS),
        "tenants": len(TENANTS),
        "requests": n_requests,
        "wall_seconds": wall,
        "solves_per_sec": n_requests / wall,
        "raw_solve_solves_per_sec": co["solved_lanes"] / co["solve_seconds_total"],
        "batches": co["batches"],
        "occupancy_mean": co["occupancy_mean"],
        "mean_batch_solve_seconds": co["solve_seconds_total"] / co["batches"],
        "warmup_seconds": warmup_seconds,
        "compiles_warmup": cp["warmup"],
        "compiles_after_warmup": cp["after_warmup"],
        "cache_hit_rate": ca["hit_rate"],
        "refactorizations": ca["refactorizations"],
        "p50_seconds": float(np.median([h["p50_seconds"] for h in lat])),
        "p99_seconds": float(max(h["p99_seconds"] for h in lat)),
        "per_tenant": [
            {"tenant": t, "count": snap["tenants"][t]["count"],
             "p50_seconds": snap["tenants"][t]["p50_seconds"],
             "p99_seconds": snap["tenants"][t]["p99_seconds"]}
            for t in TENANTS],
        "bitwise_equal_solo": bitwise_ok,
        "bitwise_checked": int(BITWISE_SAMPLE),
    }


#: counters every trajectory reports (0 when the fault never fired) so the
#: BENCH_serve.json schema can pin the robustness section shape
ROBUST_COUNTERS = ("broken_factorizations", "shifted_bindings",
                   "degraded_responses", "breakdown_lanes", "shift_retries",
                   "retry_recoveries", "deadline_expired",
                   "quarantined_batches", "identity_fallbacks",
                   "rejected_updates")


def robustness_trajectory(seed: int = 23) -> dict:
    """Deterministic fault-injection segment: every injected breakdown is
    absorbed by the degradation ladder, healthy traffic is untouched."""
    from repro.core.matgen import matgen, zero_diagonal_matrix
    from repro.serve import ServeConfig, SolveService

    n = 48
    rng = np.random.default_rng(seed)
    good = matgen(n, density=0.12, seed=7)
    fragile = zero_diagonal_matrix(n, 0.12, seed=4, row=0)  # zero pivot
    svc = SolveService(ServeConfig(buckets=(1, 2, 4), restart=8, k=K,
                                   on_breakdown="shift"))
    svc.register_matrix("good", good)
    svc.register_matrix("fragile", fragile)  # ladder shifts at register
    svc.warmup()

    def rhs():
        return rng.standard_normal(n).astype(np.float32)

    reqs = []
    for _ in range(6):
        reqs.append(("good", svc.submit("t0", "good", rhs())))
        reqs.append(("fragile", svc.submit("t1", "fragile", rhs())))
    svc.run_until_idle()

    # an already-expired deadline: swept before it can occupy a lane
    late = svc.submit("t0", "good", rhs(), deadline_seconds=1e-4)
    time.sleep(0.005)
    # a lane that goes non-finite mid-flight (post-admission poke — the
    # admission gate itself rejects non-finite b): fails alone, the
    # co-batched healthy lanes are unharmed
    poisoned = svc.submit("t0", "good", rhs())
    poisoned.b = np.full(n, np.nan, np.float32)
    survivors = [svc.submit("t1", "good", rhs()) for _ in range(2)]
    svc.run_until_idle()

    snap = svc.metrics_snapshot()

    def resp(r):
        return r.result(timeout=60)

    degraded_ok = all(resp(r).ok and resp(r).degraded and resp(r).shift > 0
                      for mid, r in reqs if mid == "fragile")
    healthy = [resp(r) for mid, r in reqs if mid == "good"]
    healthy += [resp(r) for r in survivors]
    late_resp, poisoned_resp = resp(late), resp(poisoned)
    assert not late_resp.ok and late_resp.error_reason == "deadline_exceeded"
    assert not poisoned_resp.ok and poisoned_resp.verdict == "breakdown"
    return {
        "n": n,
        "requests_ok": int(sum(r.ok for r in healthy)
                           + sum(resp(r).ok for mid, r in reqs
                                 if mid == "fragile")),
        "requests_failed": 2,  # the expired deadline + the poisoned lane
        "degraded_ok": bool(degraded_ok),
        "healthy_unaffected": bool(all(r.ok and not r.degraded
                                       for r in healthy)),
        "counters": {k: int(snap["robustness"].get(k, 0))
                     for k in ROBUST_COUNTERS},
    }


def sharded_trajectory(n: int = 256, n_requests: int = 60,
                       seed: int = 33) -> dict:
    """Scaled-down sharded serve soak on however many devices this process
    sees (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=D``)."""
    import jax

    from repro.core.matgen import matgen
    from repro.core.solvers import solve_sharded
    from repro.serve import ServeConfig, SolveService, run_traffic

    band_rows = 32
    a = matgen(n, density=min(0.02, 12.0 / n), seed=21)
    svc = SolveService(ServeConfig(sharded=True, band_rows=band_rows,
                                   buckets=(1, 2, 4), k=K, restart=8,
                                   maxiter=20))
    svc.register_matrix("m0", a)
    t0 = time.perf_counter()
    svc.warmup()
    warmup_seconds = time.perf_counter() - t0

    t0 = time.perf_counter()
    result = run_traffic(svc, ["m0"], n_requests, seed=seed,
                         tenants=("t0", "t1"), burst_max=4,
                         tol_choices=(1e-4, 1e-5))
    wall = time.perf_counter() - t0
    snap = svc.metrics_snapshot()  # before reference solves (they compile)
    assert all(r.ok for r in result.responses)

    rng = np.random.default_rng(seed)
    by_id = {r.request_id: r for r in result.responses}
    k_sample = min(12, len(result.records))
    sample = rng.choice(len(result.records), size=k_sample, replace=False)
    bitwise_ok, fact = True, None
    for i in sample:
        rec = result.records[int(i)]
        ref, fact = solve_sharded(a, rec.b, k=K, band_rows=band_rows,
                                  tol=rec.tol, restart=8, maxiter=20,
                                  fact=fact)
        bitwise_ok &= bool(np.array_equal(
            np.asarray(by_id[rec.request_id].x, np.float32).view(np.int32),
            np.asarray(ref.x, np.float32).view(np.int32)))

    co, cp = snap["coalescing"], snap["compiles"]
    return {
        "devices": len(jax.devices()),
        "n": n,
        "band_rows": band_rows,
        "requests": n_requests,
        "wall_seconds": wall,
        "solves_per_sec": n_requests / wall,
        "batches": co["batches"],
        "occupancy_mean": co["occupancy_mean"],
        "warmup_seconds": warmup_seconds,
        "compiles_after_warmup": cp["after_warmup"],
        "bitwise_equal_solo": bitwise_ok,
        "bitwise_checked": int(k_sample),
    }


def _sharded_case(devices: int, n: int = 256, n_requests: int = 60) -> dict:
    """One subprocess per device count: the host device count locks at
    first JAX init, and this parent already initialized jax."""
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--sharded",
         str(n), str(n_requests)],
        env=env, capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded serve bench D={devices} failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout)


if __name__ == "__main__":
    if "--sharded" in sys.argv:
        i = sys.argv.index("--sharded")
        print(json.dumps(sharded_trajectory(int(sys.argv[i + 1]),
                                            int(sys.argv[i + 2]))))
        sys.exit(0)
    n_requests = int(sys.argv[1]) if len(sys.argv) > 1 else 2000
    metrics = serve_trajectory(n_requests)
    metrics["robustness"] = robustness_trajectory()
    metrics["sharded"] = [_sharded_case(d) for d in (2, 4)]
    print(json.dumps(metrics))
