"""AdamW with cosine schedule, global-norm clipping — pure-function style.

State is a pytree {mu, nu, count}; moments live in f32 regardless of param
dtype (bf16-safe). ZeRO-1 sharding of mu/nu is applied at the jit boundary
by ``ShardingRules.opt_shardings`` — the math here is layout-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (c.min_lr_frac + (1 - c.min_lr_frac) * cos)


def init(params):
    def f32(p):
        return jnp.zeros_like(p, dtype=jnp.float32)

    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)))


def update(c: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(c, count)
    b1, b2 = c.beta1, c.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "count": count}, {"grad_norm": gnorm, "lr": lr}
