"""Triangular solves: exact substitution vs scipy, Jacobi variant."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.core import matgen, numeric_ilu_ref, poisson_2d, split_lu, symbolic_ilu_k
from repro.core.triangular import (
    build_triangular_plan,
    make_jacobi_triangular_solver,
    make_triangular_solver,
)


def _setup(n=80, k=1, seed=0):
    a = matgen(n, density=0.07, seed=seed)
    pat = symbolic_ilu_k(a, k)
    vals = numeric_ilu_ref(a, pat)
    return a, pat, vals


@pytest.mark.parametrize("k", [0, 1, 2])
def test_solve_matches_scipy(k):
    a, pat, vals = _setup(k=k)
    L, U = split_lu(pat, vals)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(a.n).astype(np.float32)
    want = spla.spsolve_triangular(U.tocsr(), spla.spsolve_triangular(L.tocsr(), b, lower=True), lower=False)
    solve = make_triangular_solver(pat, vals)
    got = np.asarray(solve(b))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_solve_poisson():
    a = poisson_2d(8)
    pat = symbolic_ilu_k(a, 1)
    vals = numeric_ilu_ref(a, pat)
    L, U = split_lu(pat, vals)
    b = np.ones(a.n, np.float32)
    want = spla.spsolve_triangular(U.tocsr(), spla.spsolve_triangular(L.tocsr(), b, lower=True), lower=False)
    got = np.asarray(make_triangular_solver(pat, vals)(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_wavefront_schedule_is_valid():
    """Every row appears exactly once; dependencies respect level order."""
    _, pat, vals = _setup(k=2)
    plan = build_triangular_plan(pat, vals)
    n = plan.n
    seen = plan.l_levels[plan.l_levels < n]
    assert sorted(seen.tolist()) == list(range(n))
    level_of = np.zeros(n, np.int64)
    for l in range(plan.l_levels.shape[0]):
        for r in plan.l_levels[l]:
            if r < n:
                level_of[r] = l
    for j in range(n):
        deps = plan.l_cols[j][plan.l_cols[j] < n]
        assert np.all(level_of[deps] < level_of[j])


def test_jacobi_converges_to_exact():
    a, pat, vals = _setup(k=1)
    b = np.random.default_rng(2).standard_normal(a.n).astype(np.float32)
    exact = np.asarray(make_triangular_solver(pat, vals)(b))
    plan = build_triangular_plan(pat, vals)
    depth = plan.l_levels.shape[0] + plan.u_levels.shape[0]
    approx = np.asarray(make_jacobi_triangular_solver(pat, vals, sweeps=depth + 2)(b))
    np.testing.assert_allclose(approx, exact, rtol=1e-4, atol=1e-4)
