"""Subprocess body for multi-device TOP-ILU tests.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
         python tests/multidevice_check.py <n> <k> <band_rows> <broadcast>

Exits 0 iff the multi-device TOP-ILU factorization is bitwise equal to the
sequential oracle. (Separate process because the device count is locked at
first JAX init.)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    n, k, band_rows, broadcast = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]), sys.argv[4]
    import numpy as np
    import jax

    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k, pilu1_symbolic
    from repro.core.top_ilu import topilu_numeric

    devs = jax.devices()
    assert len(devs) >= 2, f"expected multi-device, got {devs}"
    a = matgen(n, density=min(0.08, 12.0 / n), seed=42)
    pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)
    want = numeric_ilu_ref(a, pat)
    got = topilu_numeric(a, pat, band_rows=band_rows, broadcast=broadcast)
    mism = np.nonzero(got.view(np.int32) != want.view(np.int32))[0]
    if mism.size:
        print(f"FAIL: {mism.size}/{want.size} bitwise mismatches; first {mism[:5]}")
        print("got ", got[mism[:5]])
        print("want", want[mism[:5]])
        sys.exit(1)
    print(f"OK: n={n} k={k} band_rows={band_rows} broadcast={broadcast} "
          f"devices={len(devs)} nnz={pat.nnz} bitwise-equal")


if __name__ == "__main__":
    main()
