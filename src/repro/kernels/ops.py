"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, dtype policy, and the
``REPRO_DISABLE_PALLAS`` escape hatch (falls back to the jnp references —
useful for isolating kernel bugs and for platforms without Pallas).

On this container (CPU) the kernels execute with ``interpret=True``; on TPU
set ``REPRO_PALLAS_INTERPRET=0`` to compile them for real.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from . import inverse_chain as _ic
from . import panel_update as _pu
from . import spmv_ell as _sp
from . import tri_solve as _ts
from . import tri_solve_wavefront as _tw
from . import tri_sweep_epoch as _te
from . import ref as _ref

_DISABLED = os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1"


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def _pad2(x, m0, m1, fill=0.0):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=fill)
    return x


def panel_update(c, a, b, bm=256, bn=256, bk=128):
    """C - A @ B with automatic padding to block multiples."""
    if _DISABLED:
        return _ref.panel_update_ref(c, a, b)
    m, n = c.shape
    k = a.shape[1]
    bm_, bn_, bk_ = min(bm, max(m, 8)), min(bn, max(n, 8)), min(bk, max(k, 8))
    cp = _pad2(c, bm_, bn_)
    ap = _pad2(a, bm_, bk_)
    bp = _pad2(b, bk_, bn_)
    out = _pu.panel_update(cp, ap, bp, bm=bm_, bn=bn_, bk=bk_, interpret=_interpret())
    return out[:m, :n]


def trsm_right_upper(a, u, bm=256):
    """X = A @ U^{-1} (U upper-triangular)."""
    if _DISABLED:
        return _ref.trsm_right_upper_ref(a, u)
    m, bs = a.shape
    bm_ = min(bm, max(m, 8))
    ap = _pad2(a, bm_, bs)
    out = _ts.trsm_right_upper(ap, u, bm=bm_, interpret=_interpret())
    return out[:m]


def trsm_left_unit_lower(l, a, bn=256):
    """X = L^{-1} @ A (L unit-lower-triangular)."""
    if _DISABLED:
        return _ref.trsm_left_unit_lower_ref(l, a)
    bs, n = a.shape
    bn_ = min(bn, max(n, 8))
    ap = _pad2(a, bs, bn_)
    out = _ts.trsm_left_unit_lower(l, ap, bn=bn_, interpret=_interpret())
    return out[:, :n]


def factor_wavefront(op_row, op_lane, op_piv, op_dlane, op_dst, dst_flat, a_vals_ext):
    """Round-major pivot-op ILU(k) numeric factorization (bit-compatible)."""
    args = (op_row, op_lane, op_piv, op_dlane, op_dst, dst_flat, a_vals_ext)
    if _DISABLED:
        from repro.core.numeric_jax import factor_wavefront_sweeps_jnp

        return factor_wavefront_sweeps_jnp(*args)
    return _pu.factor_wavefront(*args, interpret=_interpret())


def tri_solve_wavefront(l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag, u_rhs_idx, out_perm, b):
    """Fused (LU)^{-1} b over level-major plan arrays (bit-compatible)."""
    args = (l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag, u_rhs_idx, out_perm, b)
    if _DISABLED:
        return _ref.tri_solve_wavefront_ref(*args)
    return _tw.tri_solve_wavefront(*args, interpret=_interpret())


def epoch_sweep(x, cols, vals, rhs, diag=None, *, start, limit):
    """Device-local levels of one sweep epoch over ``x`` (bit-compatible).

    The epoch-fused building block of the sharded preconditioner apply:
    the collectives between epochs stay outside; this is exactly the
    compute between two exchanges (DESIGN.md §5.5).
    """
    if _DISABLED:
        from repro.core.triangular import epoch_sweep_jnp

        return epoch_sweep_jnp(x, cols, vals, rhs, diag, start, limit)
    return _te.epoch_sweep(x, cols, vals, rhs, diag, start=start, limit=limit,
                           interpret=_interpret())


def inverse_chain(w_cols, w_vals, z_cols, z_vals, b):
    """x = Z (W b): the fused incomplete-inverse preconditioner apply."""
    if _DISABLED:
        from repro.core.inverse import inverse_chain_jnp

        return inverse_chain_jnp(w_cols, w_vals, z_cols, z_vals, b)
    return _ic.inverse_chain(w_cols, w_vals, z_cols, z_vals, b, interpret=_interpret())


def spmv_ell(cols, vals, x, bm=512):
    """y = A @ x for sentinel-padded ELL A."""
    if _DISABLED:
        return _ref.spmv_ell_ref(cols, vals, x)
    from repro.core.planner import COL_SENTINEL

    n, w = cols.shape
    bm_ = min(bm, max(n, 8))
    pad = (-n) % bm_
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)), constant_values=int(COL_SENTINEL))
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        x = jnp.pad(x, (0, pad))  # gathered only via masked lanes
    out = _sp.spmv_ell(cols, vals, x, bm=bm_, interpret=_interpret())
    return out[:n]
