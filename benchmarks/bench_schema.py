"""Checked-in schemas for the committed ``BENCH_*.json`` trajectories.

The README's perf tables are generated from these files; a malformed
trajectory commit used to break them silently. ``benchmarks/run.py
--smoke`` validates every committed trajectory against the schemas here,
so CI fails loudly instead.

Hand-rolled validation (the container deliberately has no ``jsonschema``):
a schema is a dict mirroring the JSON shape —

* a *type* (or tuple of types) validates a scalar leaf,
* a dict validates a dict: every schema key must be present (extra data
  keys are allowed — trajectories grow fields PR over PR),
* a one-element list ``[item_schema]`` validates a non-empty list,
  item-wise.

``bool`` leaves accept only real booleans (bool is not int here);
numeric leaves accept int/float but never bool.
"""
from __future__ import annotations

NUM = (int, float)

_SWEEP_CASE = {
    "devices": int,
    "n": int,
    "grid": int,
    "k": int,
    "band_rows": int,
    "batch": int,
    "bitwise_equal_single_device": bool,
    "iterations": int,
    "levels_unfused": int,
    "epochs": int,
    "collectives_per_apply": int,
    "hlo_collectives_per_apply": int,
    "bytes_per_apply": int,
    "hlo_bytes_per_apply": NUM,  # summed from per-op HLO estimates (float)
    "bytes_per_apply_unfused_pr3": int,
    "bytes_per_apply_batched": int,
    "warm_seconds": NUM,
    "warm_first_solve_seconds": NUM,
    "precond_apply_steady_seconds": NUM,
    "gmres_steady_seconds": NUM,
    "gmres_batched_seconds_per_rhs": NUM,
    # PR 5: the ordering axis — modeled epochs/bytes per (structure,
    # ordering) plus measured apply latency for the ordered Poisson solves
    "orderings": {
        "poisson": [{
            "ordering": str,
            "levels": int,
            "epochs": int,
            "collectives_per_apply": int,
            "bytes_per_apply": int,
            "fill_nnz": int,
            "precond_apply_steady_seconds": NUM,
            "bitwise_equal_single_device_permuted": bool,
        }],
        "random": [{
            "ordering": str,
            "levels": int,
            "epochs": int,
            "collectives_per_apply": int,
            "bytes_per_apply": int,
            "fill_nnz": int,
        }],
    },
}

_TOPILU_CASE = {
    "devices": int,
    "n": int,
    "grid": int,
    "k": int,
    "band_rows": int,
    "bitwise_equal_oracle": bool,
    "n_supersteps": int,
    "s_loc": int,
    "halo_size": int,
    "egress_max": int,
    "per_device_value_bytes": int,
    "replicated_value_bytes": int,
    "halo_bytes_per_superstep": int,
    "replicated_bytes_per_superstep": int,
    "factor_first_seconds": NUM,
    "factor_steady_seconds": NUM,
    "egress_pad_fraction": NUM,
    # PR 5: factorization-side ordering axis (model-only)
    "orderings": [{
        "ordering": str,
        "n_supersteps": int,
        "halo_bytes_per_superstep": int,
        "per_device_value_bytes": int,
        "fill_nnz": int,
    }],
}

_INVERSE_CASE = {
    "devices": int,
    "n": int,
    "grid": int,
    "k": int,
    "band_rows": int,
    "batch": int,
    "bitwise_equal_single_device": bool,
    "iterations_inverse": int,
    "iterations_sweep": int,
    "inverse_nnz": int,
    "factor_nnz": int,
    "value_depth": int,
    # both sides of the "auto" policy's modeled communication
    "sweep_collectives_per_apply": int,
    "sweep_bytes_per_apply": int,
    "inverse_collectives_per_apply": int,
    "inverse_bytes_per_apply": int,
    "modeled_cost_sweep": int,
    "modeled_cost_inverse": int,
    "auto_method": str,
    "warm_seconds": NUM,
    "inverse_apply_steady_seconds": NUM,
    "inverse_apply_batched_seconds_per_rhs": NUM,
    "sweep_ordering": str,
    "sweep_apply_steady_seconds": NUM,
    "gmres_steady_seconds": NUM,
    "random": {
        "n": int,
        "converged": bool,
        "iterations": int,
        "bitwise_equal_single_device": bool,
    },
}

_FACTOR_CASE = {
    "n": int,
    "nnz": int,
    "fill_nnz": int,
    "rounds": int,
    "max_ops": int,
    "symbolic_seconds": NUM,
    "plan_build_seconds": NUM,
    "numeric_first_seconds": NUM,
    "numeric_steady_seconds": NUM,
    "oracle_numeric_seconds": NUM,
    "steady_speedup_vs_oracle": NUM,
    "bitwise_equal_oracle": bool,
}

_SERVE_CASE = {
    "n": int,
    "k": int,
    "restart": int,
    "maxiter": int,
    "buckets": [int],
    "tenants": int,
    "requests": int,
    "wall_seconds": NUM,
    "solves_per_sec": NUM,
    "raw_solve_solves_per_sec": NUM,
    "batches": int,
    "occupancy_mean": NUM,
    "mean_batch_solve_seconds": NUM,
    "warmup_seconds": NUM,
    "compiles_warmup": int,
    "compiles_after_warmup": int,
    "cache_hit_rate": NUM,
    "refactorizations": int,
    "p50_seconds": NUM,
    "p99_seconds": NUM,
    "per_tenant": [{
        "tenant": str,
        "count": int,
        "p50_seconds": NUM,
        "p99_seconds": NUM,
    }],
    "bitwise_equal_solo": bool,
    "bitwise_checked": int,
    # PR 9: breakdown-hardened serving — fault-injection counters plus the
    # scaled-down sharded soak on 2/4 virtual devices
    "robustness": {
        "n": int,
        "requests_ok": int,
        "requests_failed": int,
        "degraded_ok": bool,
        "healthy_unaffected": bool,
        "counters": {
            "broken_factorizations": int,
            "shifted_bindings": int,
            "degraded_responses": int,
            "breakdown_lanes": int,
            "shift_retries": int,
            "retry_recoveries": int,
            "deadline_expired": int,
            "quarantined_batches": int,
            "identity_fallbacks": int,
            "rejected_updates": int,
        },
    },
    "sharded": [{
        "devices": int,
        "n": int,
        "band_rows": int,
        "requests": int,
        "wall_seconds": NUM,
        "solves_per_sec": NUM,
        "batches": int,
        "occupancy_mean": NUM,
        "warmup_seconds": NUM,
        "compiles_after_warmup": int,
        "bitwise_equal_solo": bool,
        "bitwise_checked": int,
    }],
}

#: filename -> schema of the committed trajectory
SCHEMAS = {
    "BENCH_serve.json": {
        "bench": str,
        "quick": bool,
        "metrics": _SERVE_CASE,
    },
    "BENCH_sweep.json": {
        "bench": str,
        "quick": bool,
        "metrics": {"grid": int, "cases": [_SWEEP_CASE]},
    },
    "BENCH_topilu.json": {
        "bench": str,
        "quick": bool,
        "metrics": {"grid": int, "cases": [_TOPILU_CASE]},
    },
    "BENCH_inverse.json": {
        "bench": str,
        "quick": bool,
        "metrics": {"grid": int, "cases": [_INVERSE_CASE]},
    },
    "BENCH_factor.json": {
        "bench": str,
        "quick": bool,
        "metrics": {"cases": [_FACTOR_CASE]},
        "solver_engine": {
            "precond_apply_seconds": NUM,
            "gmres_steady_solve_seconds": NUM,
            "gmres_first_solve_seconds": NUM,
            "converged": bool,
        },
    },
}


def _check(value, schema, path, errors):
    if isinstance(schema, dict):
        if not isinstance(value, dict):
            errors.append(f"{path}: expected object, got {type(value).__name__}")
            return
        for key, sub in schema.items():
            if key not in value:
                errors.append(f"{path}.{key}: missing")
            else:
                _check(value[key], sub, f"{path}.{key}", errors)
    elif isinstance(schema, list):
        if not isinstance(value, list):
            errors.append(f"{path}: expected list, got {type(value).__name__}")
            return
        if not value:
            errors.append(f"{path}: empty list")
            return
        for i, item in enumerate(value):
            _check(item, schema[0], f"{path}[{i}]", errors)
    else:  # a type or tuple of types
        if schema is bool:
            ok = isinstance(value, bool)
        elif isinstance(value, bool):  # bool must not satisfy numeric leaves
            ok = False
        else:
            ok = isinstance(value, schema)
        if not ok:
            want = getattr(schema, "__name__", schema)
            errors.append(f"{path}: expected {want}, got {type(value).__name__} ({value!r})")


def validate_payload(payload, name: str) -> list:
    """Validate a decoded trajectory against its schema. Returns errors."""
    if name not in SCHEMAS:
        return [f"{name}: no schema registered (known: {sorted(SCHEMAS)})"]
    errors: list = []
    _check(payload, SCHEMAS[name], name.removesuffix(".json"), errors)
    return errors


def validate_file(path: str) -> list:
    """Validate one committed trajectory file. Returns a list of errors."""
    import json
    import os

    name = os.path.basename(path)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{name}: unreadable ({e})"]
    return validate_payload(payload, name)
