"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M


def _batch(cfg, B=2, S=64, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_real, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_real, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_patches, cfg.d_model)) * 0.02, cfg.act_dtype
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.02, cfg.act_dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    loss = jax.jit(lambda p, b: M.loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    # random init ~ uniform over the real vocab
    assert float(loss) < np.log(cfg.vocab_real) + 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, key=1)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(lambda q: M.loss_fn(cfg, q, b))(p)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return loss, gnorm

    loss, gnorm = step(params, batch)
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    assert float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    B = 2
    cache = M.init_cache(cfg, B, cache_len=32)
    rng = np.random.default_rng(2)
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.02, cfg.act_dtype
        )

    def step(p, c, t):
        return M.decode_step(cfg, p, c, t, frames=frames)

    jstep = jax.jit(step)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_real, (B, 1)), jnp.int32)
    for it in range(3):
        logits, cache = jstep(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, :, : cfg.vocab_real], axis=-1).astype(jnp.int32)


def test_exact_configs_match_assignment():
    """Spot-check the published numbers (full configs, no instantiation)."""
    c = get_config("deepseek-v2-lite-16b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_real) == (27, 2048, 16, 102400)
    assert (c.n_routed_experts, c.moe_top_k, c.n_shared_experts, c.mla_kv_lora) == (64, 6, 2, 512)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.n_routed_experts, c.moe_top_k, c.n_shared_experts, c.d_expert) == (60, 4, 4, 1408)
    c = get_config("starcoder2-15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (40, 6144, 48, 4, 24576)
    c = get_config("stablelm-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_real) == (
        40, 5120, 32, 8, 13824, 100352,
    )
    c = get_config("smollm-135m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (30, 576, 9, 3)
    p = c.param_count()
    assert 1.0e8 < p["total"] < 1.8e8  # ~135M
    c = get_config("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.ssm_state) == (32, 1600, 25, 16)
    c = get_config("whisper-tiny")
    assert (c.n_layers, c.d_model, c.encoder_seq) == (4, 384, 1500)
    c = get_config("xlstm-125m")
    assert (c.n_layers, c.d_model, c.d_ff) == (12, 768, 0)
    assert len(c.block_types) == 12
