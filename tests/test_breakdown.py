"""Breakdown fixtures, the pivot guard, and the shifted-refactorization ladder.

The contract under test (DESIGN.md §12):

* the audit is a **pure read** — guarded and unguarded factors are bitwise
  identical; a healthy matrix's factorization is untouched by the guard;
* each breakdown fixture makes plain ILU(k) produce inf/NaN/zero pivots,
  the audit flags it, and ``on_breakdown="shift"`` settles on a shifted
  system whose factor is bitwise equal to the sequential oracle **of the
  shifted matrix**;
* ``on_breakdown="raise"`` raises with the offending row in the message;
* ``on_breakdown="fallback"`` with an exhausted ladder degrades to the
  identity preconditioner instead of failing;
* solver verdicts classify termination without perturbing the iterates.

Multi-device (2 and 4 virtual devices) runs via ``breakdown_check.py`` in a
subprocess (device count locks at first JAX init).
"""
import os
import sys

import numpy as np
import pytest

from subproc import run_checked

from repro.core import numeric_ilu_ref, pilu1_symbolic
from repro.core.api import ilu
from repro.core.guard import (
    BreakdownError,
    IdentityPrecondApply,
    audit_values,
    ladder_alphas,
    shifted_matrix,
)
from repro.core.matgen import (
    denormal_pivot_matrix,
    indefinite_matrix,
    matgen,
    singular_block_matrix,
    zero_diagonal_matrix,
)
from repro.core.solvers import VERDICTS, SolveReport, gmres, solve_with_ilu

SCRIPT = os.path.join(os.path.dirname(__file__), "breakdown_check.py")

FIXTURES = {
    "singular": lambda: singular_block_matrix(64, 0.1, seed=3),
    "zerodiag": lambda: zero_diagonal_matrix(64, 0.1, seed=4),
    "denormal": lambda: denormal_pivot_matrix(64, 0.1, seed=5),
}


def _diag_ok(a):
    for r in range(a.n):
        cols = a.indices[a.indptr[r]:a.indptr[r + 1]]
        assert r in cols, f"row {r} lacks a structural diagonal"


def test_fixtures_well_formed():
    """Every fixture keeps a structural diagonal (the shift is a pure value
    edit) and the intended defect: singular block / zero diag / subnormal
    row scale / indefinite diagonal."""
    for make in FIXTURES.values():
        _diag_ok(make())
    a = singular_block_matrix(64, 0.1, seed=3)
    assert a.indptr[2] == 4 and list(a.indices[:4]) == [0, 1, 0, 1]
    z = zero_diagonal_matrix(64, 0.1, seed=4, row=0)
    assert z.data[z.indptr[0] + np.searchsorted(
        z.indices[z.indptr[0]:z.indptr[1]], 0)] == 0.0
    d = denormal_pivot_matrix(64, 0.1, seed=5)
    lo, hi = d.indptr[0], d.indptr[1]
    piv = d.data[lo + np.searchsorted(d.indices[lo:hi], 0)]
    assert 0 < abs(float(piv)) < np.finfo(np.float32).tiny
    ind = indefinite_matrix(8)
    diags = [ind.data[ind.indptr[r] + np.searchsorted(
        ind.indices[ind.indptr[r]:ind.indptr[r + 1]], r)] for r in range(ind.n)]
    assert min(diags) < 0 < max(diags) or all(x < 4 for x in diags)
    _diag_ok(ind)


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_audit_flags_and_ladder_recovers(name):
    """Plain ILU(k) on the fixture is unhealthy; the ladder settles on a
    shift whose factor equals the sequential oracle of the shifted matrix
    bitwise.

    The denormal fixture anchors against the oracle *backend*: its rows
    carry subnormal values, and XLA's CPU backend flushes subnormal
    products to zero (FTZ) where numpy keeps them — a hardware-semantics
    boundary outside the bit-compat contract, which assumes normal-range
    arithmetic. The ladder/audit logic under test is backend-independent.
    """
    a = FIXTURES[name]()
    base = ilu(a, 1, backend="oracle", on_breakdown="ignore")
    assert base.health is not None and not base.health.ok
    assert base.health.worst_row >= 0

    backend = "oracle" if name == "denormal" else "jax"
    fact = ilu(a, 1, backend=backend, on_breakdown="shift")
    h = fact.health
    assert h.ok and h.shift > 0 and h.attempts > 1, h.summary()
    # the bit-compat anchor: shifted factor == sequential oracle of A+αD
    a_s = shifted_matrix(a, h.shift)
    want = numeric_ilu_ref(a_s, fact.pattern)
    assert np.array_equal(np.asarray(fact.vals).view(np.int32),
                          want.view(np.int32))
    # α follows the geometric ladder from the first rung
    assert h.shift in ladder_alphas()


def test_guard_is_a_pure_read_on_healthy_matrix():
    """A healthy factorization is bitwise identical with the guard on or
    off, and its health is clean."""
    a = matgen(64, 0.1, seed=6)
    f_off = ilu(a, 1, backend="jax", on_breakdown="ignore")
    f_on = ilu(a, 1, backend="jax", on_breakdown="raise")  # no raise: healthy
    assert f_on.health.ok and f_on.health.shift == 0.0
    assert f_on.health.attempts == 1
    assert np.array_equal(np.asarray(f_on.vals).view(np.int32),
                          np.asarray(f_off.vals).view(np.int32))


def test_raise_names_offending_row():
    a = zero_diagonal_matrix(64, 0.1, seed=4, row=0)
    with pytest.raises(BreakdownError) as ei:
        ilu(a, 1, backend="oracle", on_breakdown="raise")
    msg = str(ei.value)
    assert "row" in msg and ei.value.health is not None
    assert not ei.value.health.ok
    # the audit pinpoints a specific row in the message
    assert any(ch.isdigit() for ch in msg.split("row", 1)[1][:8])


def test_ladder_solve_converges_where_plain_nans():
    """End-to-end: the unguarded solve on the zero-diagonal fixture produces
    non-finite iterates; on_breakdown="shift" converges to a finite x with
    the shift recorded on the report."""
    a = zero_diagonal_matrix(64, 0.1, seed=4, row=0)
    b = np.random.default_rng(1).standard_normal(64).astype(np.float32)
    r_plain, _ = solve_with_ilu(a, b, k=1, tol=1e-5, maxiter=50,
                                use_pallas=False, on_breakdown="ignore")
    assert not r_plain.converged or not np.isfinite(np.asarray(r_plain.x)).all()
    r, fact = solve_with_ilu(a, b, k=1, tol=1e-5, maxiter=200,
                             use_pallas=False, on_breakdown="shift")
    assert r.converged and np.isfinite(np.asarray(r.x)).all()
    assert r.report.shift == fact.health.shift > 0
    assert r.verdict == "converged"


def test_indefinite_stagnates_then_shift_converges():
    """Indefiniteness is not breakdown: the Helmholtz-like fixture factors
    healthily at the default τ, but ILU(1)-preconditioned GMRES *stagnates*
    on it (the verdict catches what a bare converged-flag would miss).
    Raising ``pivot_tol`` makes the audit flag the small pivots, and the
    shift ladder turns stagnation into convergence — with the shifted
    factor still bitwise-anchored to the oracle of the shifted matrix."""
    a = indefinite_matrix(8)
    b = np.random.default_rng(2).standard_normal(a.n).astype(np.float32)
    plain = ilu(a, 1, backend="jax", on_breakdown="raise")  # default τ: healthy
    assert plain.health.ok and plain.health.shift == 0.0
    r0, _ = solve_with_ilu(a, b, k=1, tol=1e-5, maxiter=300, use_pallas=False)
    assert not r0.converged and r0.verdict == "stagnated"
    r, fact = solve_with_ilu(a, b, k=1, tol=1e-5, maxiter=300,
                             use_pallas=False, on_breakdown="shift",
                             pivot_tol=1e-2)
    assert r.converged and r.verdict == "converged"
    assert r.report.shift == fact.health.shift > 0
    want = numeric_ilu_ref(shifted_matrix(a, fact.health.shift), fact.pattern)
    assert np.array_equal(np.asarray(fact.vals).view(np.int32),
                          want.view(np.int32))


def test_identity_fallback_when_ladder_exhausted():
    """fallback + an empty ladder (max_shifts=0) degrades to the identity
    preconditioner: health.degraded, precond() applies M⁻¹ = I bitwise."""
    a = zero_diagonal_matrix(64, 0.1, seed=4, row=0)
    fact = ilu(a, 1, backend="jax", on_breakdown="fallback", max_shifts=0)
    assert fact.health.degraded and not fact.health.ok
    p = fact.precond(use_pallas=False)
    assert isinstance(p, IdentityPrecondApply)
    b = np.random.default_rng(2).standard_normal(64).astype(np.float32)
    assert np.array_equal(np.asarray(p(b), np.float32).view(np.int32),
                          b.view(np.int32))
    B = np.random.default_rng(3).standard_normal((4, 64)).astype(np.float32)
    assert np.array_equal(np.asarray(p.batched(B), np.float32).view(np.int32),
                          B.view(np.int32))


def test_audit_values_channels():
    """audit_values counts each defect in its own channel."""
    a = matgen(64, 0.1, seed=7)
    pat = pilu1_symbolic(a)
    vals = numeric_ilu_ref(a, pat)
    h = audit_values(pat, vals)
    assert h.ok and h.n == 64 and h.n_nonfinite == 0
    bad = np.asarray(vals).copy()
    bad[0] = np.nan
    h2 = audit_values(pat, bad)
    assert not h2.ok and h2.n_nonfinite == 1 and h2.first_nonfinite_row == 0


def test_shift_exhaustion_raises_with_flag():
    a = zero_diagonal_matrix(64, 0.1, seed=4, row=0)
    with pytest.raises(BreakdownError) as ei:
        ilu(a, 1, backend="oracle", on_breakdown="shift", max_shifts=0)
    assert ei.value.exhausted


# ---------------------------------------------------------------------------
# solver verdicts
# ---------------------------------------------------------------------------
def _healthy_setup(n=64, seed=8):
    from repro.core.solvers import csr_to_ell_arrays, make_ell_matvec

    a = matgen(n, 0.1, seed=seed)
    fact = ilu(a, 1, backend="jax")
    pre = fact.precond(use_pallas=False)
    cols, vals = csr_to_ell_arrays(a)
    return a, make_ell_matvec(cols, vals, a.n), pre


def test_verdict_converged_and_report():
    a, matvec, pre = _healthy_setup()
    b = np.random.default_rng(4).standard_normal(a.n).astype(np.float32)
    r = gmres(matvec, b, pre, tol=1e-5)
    assert r.verdict == "converged" and r.converged
    assert isinstance(r.report, SolveReport)
    assert r.report.iterations == r.iterations
    assert not r.report.degraded and r.report.shift == 0.0


def test_verdict_maxiter():
    a, matvec, pre = _healthy_setup()
    b = np.random.default_rng(5).standard_normal(a.n).astype(np.float32)
    r = gmres(matvec, b, pre, tol=1e-30, restart=2, maxiter=2)
    assert r.verdict in ("maxiter", "stagnated") and not r.converged


def test_verdict_breakdown_on_nonfinite_rhs():
    """A non-finite ‖b‖ classifies as breakdown immediately — this is the
    lane-quarantine trigger the serve layer keys on."""
    a, matvec, pre = _healthy_setup()
    b = np.full(a.n, np.nan, np.float32)
    r = gmres(matvec, b, pre, tol=1e-5, maxiter=5)
    assert r.verdict == "breakdown" and not r.converged


def test_verdict_zero_rhs_converges_at_zero_iters():
    a, matvec, pre = _healthy_setup()
    r = gmres(matvec, np.zeros(a.n, np.float32), pre, tol=1e-5)
    assert r.verdict == "converged" and r.iterations == 0


def test_verdicts_enumeration_stable():
    assert VERDICTS == ("running", "converged", "maxiter", "stagnated",
                        "breakdown", "diverged")


# ---------------------------------------------------------------------------
# multi-device: ladder bitwise vs the sequential oracle of the shifted matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("devices", [2, 4])
def test_ladder_multidevice_bitwise(devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    rc, out, err = run_checked(
        [sys.executable, SCRIPT, "96", "1", "8"], env=env, timeout=300)
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "ladder bitwise-equal" in out
