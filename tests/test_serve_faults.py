"""Fault injection for the solve service.

The contract under test: every fault fails exactly the request(s) it
belongs to — never the coalesced batch it would have ridden in, never
another tenant's requests, never the process. Scenarios from the issue:

* cache eviction while a solve is in flight,
* a matrix-value update racing an in-flight solve on the old factorization,
* malformed requests (wrong shape, unknown matrix_id, non-finite entries),
* a compatible group exceeding the largest bucket,
* an engine blowing up mid-batch (the one case that can take its whole
  batch down — but nothing outside it).
"""
import numpy as np
import pytest

from repro.core.matgen import matgen
from repro.core.solvers import solve_with_ilu
from repro.core.sparse import CSRMatrix
from repro.serve import ServeConfig, SolveRequest, SolveResponse, SolveService


def _svc(capacity=4, buckets=(1, 2, 4), restart=8):
    return SolveService(ServeConfig(cache_capacity=capacity, buckets=buckets,
                                    restart=restart))


def _rhs(n, seed):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _assert_bitwise_vs_solo(resp, a, b, tol, restart=8, k=1):
    ref, _ = solve_with_ilu(a, b, k=k, tol=tol, restart=restart, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(resp.x, np.float32).view(np.int32),
                                  np.asarray(ref.x, np.float32).view(np.int32))


def test_eviction_while_solve_in_flight():
    """A queued request pins its entry; eviction pressure takes the LRU
    *unpinned* entry instead, the in-flight solve completes bitwise-correct,
    and only later requests to the evicted matrix fail (their own error)."""
    svc = _svc(capacity=2)
    a0, a1, a2 = (matgen(48, 0.12, seed=s) for s in (1, 2, 3))
    svc.register_matrix("m0", a0, k=1)
    svc.register_matrix("m1", a1, k=1)

    b = _rhs(48, 0)
    req = svc.submit("tenant-a", "m0", b, tol=1e-5)   # pins m0
    assert isinstance(req, SolveRequest)
    svc.register_matrix("m2", a2, k=1)                # evicts m1 (unpinned LRU)
    assert "m1" not in svc.cache and "m0" in svc.cache

    resps = svc.tick()                                # in-flight solve lands
    assert len(resps) == 1 and resps[0].ok
    _assert_bitwise_vs_solo(resps[0], a0, b, 1e-5)

    late = svc.submit("tenant-b", "m1", _rhs(48, 1))  # only this one fails
    assert isinstance(late, SolveResponse) and not late.ok
    assert late.error_reason == "unknown_matrix"
    ok = svc.submit("tenant-b", "m2", _rhs(48, 2))
    assert isinstance(ok, SolveRequest)
    assert all(r.ok for r in svc.tick())


def test_value_update_racing_in_flight_solve():
    """A request admitted before a value push solves against the binding it
    pinned (the old factorization, bitwise), not the half-swapped new one;
    requests admitted after the swap get the new values (bitwise too)."""
    svc = _svc()
    a = matgen(48, 0.12, seed=5)
    svc.register_matrix("m0", a, k=1)
    b = _rhs(48, 3)

    req_old = svc.submit("t0", "m0", b, tol=1e-5)     # pins version 1
    t = svc.update_matrix_values("m0", (a.data * 1.3).astype(np.float32))
    t.join()                                           # update wins the race
    req_new = svc.submit("t1", "m0", b, tol=1e-5)     # pins version 2
    resps = {r.request_id: r for r in svc.run_until_idle()}

    r_old, r_new = resps[req_old.request_id], resps[req_new.request_id]
    assert r_old.ok and r_old.matrix_version == 1
    assert r_new.ok and r_new.matrix_version == 2
    _assert_bitwise_vs_solo(r_old, a, b, 1e-5)        # old values
    a_new = CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices,
                      data=(a.data * 1.3).astype(np.float32))
    _assert_bitwise_vs_solo(r_new, a_new, b, 1e-5)    # new values
    assert not np.array_equal(r_old.x, r_new.x)


def test_malformed_requests_fail_alone():
    """Wrong shape / unknown matrix / non-finite b / bad tol each reject at
    admission with their reason code while good requests coalesced around
    them are untouched."""
    svc = _svc()
    a = matgen(48, 0.12, seed=6)
    svc.register_matrix("m0", a, k=1)

    good1 = svc.submit("t0", "m0", _rhs(48, 4))
    bad_shape = svc.submit("t1", "m0", np.ones(50, np.float32))
    bad_nan = svc.submit("t2", "m0", np.full(48, np.nan, np.float32))
    bad_id = svc.submit("t3", "ghost", _rhs(48, 5))
    bad_tol = svc.submit("t0", "m0", _rhs(48, 6), tol=0.0)
    good2 = svc.submit("t1", "m0", _rhs(48, 7))

    for resp, reason in ((bad_shape, "bad_shape"), (bad_nan, "non_finite"),
                         (bad_id, "unknown_matrix"), (bad_tol, "bad_tol")):
        assert isinstance(resp, SolveResponse) and not resp.ok
        assert resp.error_reason == reason

    resps = svc.tick()
    assert sorted(r.request_id for r in resps) == sorted(
        [good1.request_id, good2.request_id])
    assert all(r.ok for r in resps)
    snap = svc.metrics_snapshot()
    assert snap["requests"]["completed"] == 2
    assert sum(snap["requests"]["rejected_by_reason"].values()) == 4


def test_queue_full_sheds_load_not_state():
    svc = SolveService(ServeConfig(buckets=(1, 2), restart=8, max_queue_depth=2))
    a = matgen(32, 0.15, seed=7)
    svc.register_matrix("m0", a, k=1)
    r1 = svc.submit("t0", "m0", _rhs(32, 1))
    r2 = svc.submit("t0", "m0", _rhs(32, 2))
    shed = svc.submit("t0", "m0", _rhs(32, 3))
    assert isinstance(shed, SolveResponse) and shed.error_reason == "queue_full"
    assert svc.cache.entry("m0").pins == 2  # shed request left no pin behind
    resps = svc.tick()
    assert {r.request_id for r in resps} == {r1.request_id, r2.request_id}
    assert all(r.ok for r in resps)
    assert svc.cache.entry("m0").pins == 0


def test_group_beyond_largest_bucket_chunks():
    """11 compatible requests with buckets (1,2,4): three batches (4+4+3→4),
    all solved in one tick, every response bitwise-correct."""
    svc = _svc(buckets=(1, 2, 4))
    a = matgen(48, 0.12, seed=8)
    svc.register_matrix("m0", a, k=1)
    bs = [_rhs(48, 100 + i) for i in range(11)]
    reqs = [svc.submit(f"t{i % 4}", "m0", b) for i, b in enumerate(bs)]
    resps = {r.request_id: r for r in svc.tick()}
    assert len(resps) == 11
    snap = svc.metrics_snapshot()
    assert snap["coalescing"]["batches"] == 3
    assert all(r.batch_lanes <= 4 for r in resps.values())
    for req, b in zip(reqs, bs):
        assert resps[req.request_id].ok
        _assert_bitwise_vs_solo(resps[req.request_id], a, b, 1e-5)


def test_engine_failure_fails_batch_not_process(monkeypatch):
    """An engine exception marks that batch's requests solve_failed and
    releases their pins; the service keeps serving other matrices."""
    svc = _svc()
    a0, a1 = matgen(48, 0.12, seed=9), matgen(40, 0.15, seed=10)
    svc.register_matrix("m0", a0, k=1)
    svc.register_matrix("m1", a1, k=1)

    def boom(binding, bs, tols):
        raise RuntimeError("injected engine failure")

    monkeypatch.setattr(svc.cache.entry("m0").engine, "solve", boom)
    doomed = svc.submit("t0", "m0", _rhs(48, 11))
    fine = svc.submit("t1", "m1", _rhs(40, 12))
    resps = {r.request_id: r for r in svc.tick()}

    assert not resps[doomed.request_id].ok
    assert resps[doomed.request_id].error_reason == "solve_failed"
    assert "injected engine failure" in resps[doomed.request_id].error
    assert resps[fine.request_id].ok
    assert svc.cache.entry("m0").pins == 0  # pins released on failure too
    # the service still serves m0 once the engine behaves again
    monkeypatch.undo()
    again = svc.submit("t0", "m0", _rhs(48, 13))
    assert svc.tick()[0].request_id == again.request_id


def test_update_does_not_block_other_tenants(monkeypatch):
    """While m0's refactorization is (artificially) slow, m1 solves keep
    landing — the value push never serializes the tick loop."""
    import time as _time

    svc = _svc()
    a0, a1 = matgen(48, 0.12, seed=14), matgen(48, 0.12, seed=15)
    svc.register_matrix("m0", a0, k=1)
    svc.register_matrix("m1", a1, k=1)
    svc.submit("t1", "m1", _rhs(48, 99))
    assert svc.tick()[0].ok          # compile m1's engine before the race

    orig = svc.cache._factorize

    def slow_factorize(host, pattern, a):
        _time.sleep(0.5)
        return orig(host, pattern, a)

    monkeypatch.setattr(svc.cache, "_factorize", slow_factorize)
    t = svc.update_matrix_values("m0", (a0.data * 1.1).astype(np.float32))
    b = _rhs(48, 16)
    svc.submit("t1", "m1", b)
    resps = svc.tick()              # completes while the refactor sleeps
    assert t.is_alive()
    assert len(resps) == 1 and resps[0].ok
    t.join()
    assert svc.cache.entry("m0").binding.version == 2
