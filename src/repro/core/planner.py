"""Static band/frontier planner — the bridge from Phase I to the device.

The paper organizes the matrix as *bands* of consecutive rows (§IV-A,
Fig 3); the *frontier* is the last completely-reduced row (Def 4.1); bands
are owned round-robin by nodes (static load balancing, §IV-D).

On TPU everything must be static-shaped, so this planner turns a symbolic
pattern (`ILUPattern`) into a :class:`NumericPlan`:

* padded ELL storage (``cols``/``diag_pos``) — static structure,
* per-row *band pivot offsets* ``pivot_start[j, b]`` = number of entries of
  row j strictly left of column ``b*band_rows`` (clipped to the diagonal),
  so the pivots of row j falling in band b occupy ELL positions
  ``[pivot_start[j,b], pivot_start[j,b+1])``,
* static trip-count bounds (``max_pivots_per_band``, ``max_intra_pivots``),
* the device-major band permutation used to shard bands round-robin.

Because the pattern is planning output, column indices are *replicated*
device-side rather than communicated — the paper ships 8 bytes/entry
(column + value, §V-E); we ship 4 (value only). Recorded in §Perf.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .sparse import CSRMatrix, ELLMatrix, ILUPattern

#: Column sentinel for ELL padding. Must be larger than any valid column so
#: padded rows remain sorted (device code uses ``searchsorted``).
COL_SENTINEL = np.int32(2**30)


@dataclasses.dataclass
class NumericPlan:
    n: int  # original dimension
    n_pad: int
    width: int  # ELL width W
    band_rows: int  # R
    n_bands: int  # B (padded to a multiple of n_devices)
    n_devices: int  # D
    k: int

    cols: np.ndarray  # (n_pad, W) int32, -1 padded
    diag_pos: np.ndarray  # (n_pad,) int32
    row_len: np.ndarray  # (n_pad,) int32
    a_vals: np.ndarray  # (n_pad, W) f32 — A scattered on the pattern
    pivot_start: np.ndarray  # (n_pad, B+1) int32
    band_of_row: np.ndarray  # (n_pad,) int32

    max_pivots_per_band: int  # bound for inter-band partial reductions
    max_intra_pivots: int  # bound for finishing a band

    # --- band sharding (device-major permutation) -------------------------
    @property
    def bands_per_device(self) -> int:
        return self.n_bands // self.n_devices

    def band_to_slot(self) -> np.ndarray:
        """slot index (device-major) for each band: band b -> device b%D, slot b//D."""
        b = np.arange(self.n_bands)
        return (b % self.n_devices) * self.bands_per_device + b // self.n_devices

    def rows_device_major(self, x: np.ndarray) -> np.ndarray:
        """Reorder a row-indexed array into device-major band order."""
        perm = self.band_to_slot()
        banded = x.reshape(self.n_bands, self.band_rows, *x.shape[1:])
        out = np.empty_like(banded)
        out[perm] = banded
        return out.reshape(x.shape)

    def rows_from_device_major(self, x: np.ndarray) -> np.ndarray:
        perm = self.band_to_slot()
        banded = x.reshape(self.n_bands, self.band_rows, *x.shape[1:])
        return banded[perm].reshape(x.shape)


def make_plan(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int,
    n_devices: int = 1,
) -> NumericPlan:
    """Build the static numeric-phase plan from the filled pattern."""
    assert band_rows >= 1 and n_devices >= 1
    n = pattern.n
    # pad rows so that n_pad = B * R with B a multiple of D
    bands = -(-n // band_rows)
    bands = -(-bands // n_devices) * n_devices
    n_pad = bands * band_rows

    ell = ELLMatrix.from_pattern(pattern, a, pad_rows_to=1)
    W = ell.width
    cols = np.full((n_pad, W), COL_SENTINEL, dtype=np.int32)
    vals = np.zeros((n_pad, W), dtype=np.float32)
    diag_pos = np.zeros(n_pad, dtype=np.int32)
    row_len = np.zeros(n_pad, dtype=np.int32)
    ell_cols = ell.cols.copy()
    ell_cols[ell_cols < 0] = COL_SENTINEL  # ELLMatrix pads with -1
    cols[: ell.n] = ell_cols
    vals[: ell.n] = ell.vals
    diag_pos[: ell.n] = ell.diag_pos
    row_len[: ell.n] = ell.row_len
    for j in range(ell.n, n_pad):  # identity padding rows
        cols[j, 0] = j
        vals[j, 0] = 1.0
        row_len[j] = 1

    # pivot_start[j, b] = #entries of row j with col < b*R, clipped to diag_pos
    boundaries = np.arange(bands + 1, dtype=np.int64) * band_rows
    pivot_start = np.zeros((n_pad, bands + 1), dtype=np.int32)
    for j in range(n_pad):
        m = int(row_len[j])
        ps = np.searchsorted(cols[j, :m].astype(np.int64), boundaries, side="left")
        pivot_start[j] = np.minimum(ps, diag_pos[j])

    band_of_row = (np.arange(n_pad) // band_rows).astype(np.int32)

    # static trip-count bounds
    counts = np.diff(pivot_start, axis=1)  # (n_pad, B)
    intra = counts[np.arange(n_pad), band_of_row]
    inter = counts.copy()
    inter[np.arange(n_pad), band_of_row] = 0
    max_intra = int(intra.max()) if n_pad else 0
    max_inter = int(inter.max()) if n_pad else 0

    return NumericPlan(
        n=n,
        n_pad=n_pad,
        width=W,
        band_rows=band_rows,
        n_bands=bands,
        n_devices=n_devices,
        k=pattern.k,
        cols=cols,
        diag_pos=diag_pos,
        row_len=row_len,
        a_vals=vals,
        pivot_start=pivot_start,
        band_of_row=band_of_row,
        max_pivots_per_band=max(max_inter, 1),
        max_intra_pivots=max(max_intra, 1),
    )


def plan_comm_bytes_per_node(plan: NumericPlan, faithful: bool = True) -> int:
    """Paper §V-E communication model: ~8 bytes/final-entry per node.

    ``faithful=False`` counts the TPU variant (static structure replicated,
    values only -> 4 bytes/entry).
    """
    per_entry = 8 if faithful else 4
    nnz = int(np.sum(plan.row_len[: plan.n]))
    return per_entry * nnz
