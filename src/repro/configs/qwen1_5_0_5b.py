"""qwen1.5-0.5b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_real=151936,
    rope_theta=1000000.0,
    qkv_bias=True,
    mlp_act="swiglu",
    tie_embeddings=True,
)
