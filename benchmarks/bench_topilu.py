"""Distributed sharded TOP-ILU trajectory — one JSON record per device count.

    python benchmarks/bench_topilu.py <grid> <devices> [--json PATH]

Spawns itself with ``XLA_FLAGS=--xla_force_host_platform_device_count``
(device count locks at first JAX init). Measures the sharded factorization
wall time on the simulated mesh and reports the per-device memory and the
per-superstep collective payload from the halo model, cross-checked against
the compiled HLO (``repro.roofline.analysis.collective_bytes_per_device``).
``benchmarks/run.py --emit-json BENCH_topilu.json`` aggregates 1/2/8
devices into the committed trajectory.
"""
import json
import os
import subprocess
import sys

if os.environ.get("_BENCH_TOPILU_CHILD") != "1" and __name__ == "__main__":
    d = sys.argv[2] if len(sys.argv) > 2 else "4"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
    env.setdefault("JAX_PLATFORMS", "cpu")  # don't probe for real TPUs
    env["_BENCH_TOPILU_CHILD"] = "1"
    sys.exit(subprocess.run([sys.executable, __file__] + sys.argv[1:], env=env).returncode)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np


def measure(grid: int, band_rows: int = 16) -> dict:
    import jax

    from repro.core import numeric_ilu_ref, pilu1_symbolic, poisson_2d
    from repro.core.top_ilu import lower_topilu, topilu_factor_sharded
    from repro.launch.mesh import make_band_mesh
    from repro.roofline.analysis import collective_bytes_per_device

    d = len(jax.devices())
    mesh = make_band_mesh()
    a = poisson_2d(grid)
    pat = pilu1_symbolic(a)
    want = numeric_ilu_ref(a, pat)

    t0 = time.perf_counter()
    fact = topilu_factor_sharded(a, pat, band_rows=band_rows, mesh=mesh)
    fact.loc_vals.block_until_ready()
    first = time.perf_counter() - t0
    got = fact.values_csr()
    bitwise = bool(np.array_equal(got.view(np.int32), want.view(np.int32)))

    # steady state: re-factorize on the already-compiled engine
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        f2 = topilu_factor_sharded(a, pat, band_rows=band_rows, mesh=mesh)
        f2.loc_vals.block_until_ready()
    steady = (time.perf_counter() - t0) / reps

    plan = fact.plan
    lowered, _ = lower_topilu(a, pat, band_rows, mesh)
    hlo_step = sum(collective_bytes_per_device(lowered.compile().as_text()).values())

    # pad-to-max-E histogram: the fori-loop engine ships a fixed (E, W)
    # payload every superstep; how much of it is padding on this workload?
    sizes = plan.egress_sizes()  # (n_sup, D) exact rows shipped
    hist = np.bincount(sizes.reshape(-1), minlength=plan.egress_max + 1)
    exact_rows = int(sizes.sum())
    padded_rows = plan.egress_max * sizes.size
    return {
        "devices": d,
        "n": a.n,
        "grid": grid,
        "k": 1,
        "band_rows": band_rows,
        "n_bands": plan.n_bands,
        "n_supersteps": plan.n_supersteps,
        "bitwise_equal_oracle": bitwise,
        "factor_first_seconds": first,
        "factor_steady_seconds": steady,
        "s_loc": plan.s_loc,
        "halo_size": plan.halo_size,
        "egress_max": plan.egress_max,
        "per_device_value_bytes": plan.per_device_value_bytes(),
        "replicated_value_bytes": plan.replicated_value_bytes(),
        "halo_bytes_per_superstep": plan.halo_bytes_per_superstep(),
        "replicated_bytes_per_superstep": plan.replicated_bytes_per_superstep(),
        "hlo_collective_bytes_per_superstep": hlo_step,
        "total_collective_bytes_per_device":
            plan.halo_bytes_per_superstep() * plan.n_supersteps,
        # per-superstep egress histogram: exact E per (step, device) vs the
        # global max the static loop pads to (ROADMAP "pad to max E" item)
        "egress_exact_rows": exact_rows,
        "egress_padded_rows": padded_rows,
        "egress_pad_fraction":
            1.0 - exact_rows / padded_rows if padded_rows else 0.0,
        "egress_size_histogram": {str(i): int(c) for i, c in enumerate(hist) if c},
        # ordering axis (PR 5, model-only — the halo model is exactly what
        # the HLO check above pins): factorization-side communication under
        # natural vs RCM vs fusion-aware row ordering
        "orderings": _ordering_axis(a, band_rows, d),
    }


def _ordering_axis(a, band_rows: int, d: int) -> list:
    """Modeled factorization communication per row ordering (host-only)."""
    from repro.core import pilu1_symbolic
    from repro.core.ordering import factor_comm_model, make_ordering, permuted_system

    out = []
    for name in ("natural", "rcm", "fusion"):
        ordering = make_ordering(a, name, n_devices=d, band_rows=band_rows)
        ap = a if ordering is None else permuted_system(a, ordering)
        pat = pilu1_symbolic(ap)
        rec = factor_comm_model(ap, pat, band_rows, d)
        out.append({
            "ordering": name,
            "n_supersteps": rec["n_supersteps"],
            "halo_bytes_per_superstep": rec["halo_bytes_per_superstep"],
            "per_device_value_bytes": rec["per_device_value_bytes"],
            "fill_nnz": rec["fill_nnz"],
        })
    return out


def main():
    grid = int(sys.argv[1]) if len(sys.argv) > 1 else 32
    out = None
    if "--json" in sys.argv:
        out = sys.argv[sys.argv.index("--json") + 1]
    m = measure(grid)
    text = json.dumps(m, indent=2)
    if out:
        with open(out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
