"""Structure-keyed, value-rebinding solve engines for the serve layer.

The multi-tenant cache problem: a tenant's matrix-value update must not
recompile anything, or the XLA compile counter climbs with tenant churn and
p99 is eventually paid by some request that drew the compile. The existing
solver engines bake factor/matrix values into the executable as closure
constants (fine for one matrix, fatal for a serving cache). This module
compiles ONE GMRES engine per *structure* (sparsity pattern + solver
knobs + bucket) in which every float operand — A's ELL values, the
level-major L/U sweep values, or the W/Z inverse-chain values — rides as a
runtime **argument**:

* value update ⇒ refactorize through the already-compiled ``FactorPlan``
  engine, re-scatter values host-side (``rebind_triangular_values`` /
  ``build_inverse_plan``), hand the new arrays to the same executable —
  zero XLA compiles end to end (:meth:`ServeEngine.bind` is pure data);
* two tenants with the same structure (common when tenants are shards of
  one model family) share one executable per bucket.

Bit-compat contract: the engine runs exactly the computation of the
single-request path — the same Pallas ELL SpMV, the same fused wavefront
sweep (or inverse SpMV chain), the same ``_gmres_core`` with its
fixed-topology ``bitmath`` reductions — ``vmap``-ped over (b, tol) lanes.
Values-as-arguments is the PR-6 idiom (constant-embedded operands let XLA
fold with different rounding; runtime operands keep the compiled
arithmetic fixed), so a lane's bits equal the same solve run alone. The
coalescing property test and the soak assert this, response by response.

``ShardedServeEngine`` adapts the same surface onto ``solve_sharded`` for
multi-device meshes. The sharded *sweep* already rebinds values as
arguments (``ShardedTriangularEngine``); the sharded SpMV and Krylov jits
are still closure-keyed, so a sharded rebind pre-warms its fresh engines in
the background refactor thread — compiles happen off the serving path,
though the counter records them (documented asymmetry, DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.sparse import CSRMatrix, ILUPattern

#: serving defaults — one place, shared by engines / service / bench
DEFAULT_RESTART = 30
DEFAULT_MAXITER = 20


@dataclasses.dataclass
class LaneResult:
    """Per-request outcome scattered out of a coalesced solve."""

    x: np.ndarray
    iterations: int
    residual: float
    converged: bool
    #: solver termination verdict (repro.core.solvers.VERDICTS) — the
    #: service's retry/quarantine policy keys on this
    verdict: str = ""


@dataclasses.dataclass
class EngineBinding:
    """One matrix *version* bound to an engine: pure device data, no code.

    ``value_args`` is the tuple the compiled run consumes; ``vals_csr``
    keeps the CSR-aligned factor values for audit/debug (host array).
    """

    version: int
    value_args: tuple
    vals_csr: np.ndarray
    bound_seconds: float
    #: the CSRMatrix this binding's *matvec* values came from — the
    #: shift-retry path refactors `A + α·diag(‖row‖₁)` from it while the
    #: solve keeps targeting this exact A (Manteuffel: shift the
    #: preconditioner, never the system)
    a: object = None
    #: diagonal shift α of the preconditioner factor (0 = unshifted)
    shift: float = 0.0
    #: True when this binding preconditions with the exact identity (the
    #: shift ladder exhausted under the cache's "fallback" policy)
    degraded: bool = False


def engine_fingerprint(a: CSRMatrix, pattern: ILUPattern, knobs: tuple) -> tuple:
    """Content key: same structure + same solver knobs ⇒ same engine.

    Hashes A's sparsity and the filled pattern (indices + levels — the
    factor structure), never values: two tenants with equal structure and
    different numbers share one compiled engine.
    """
    h = hashlib.sha1()
    h.update(a.indptr.tobytes())
    h.update(a.indices.tobytes())
    h.update(pattern.indptr.tobytes())
    h.update(pattern.indices.tobytes())
    h.update(pattern.levels.tobytes())
    return (a.n, pattern.k, h.hexdigest()) + knobs


class ServeEngine:
    """Single-device value-rebinding multi-RHS GMRES engine.

    Built once per (structure, ``precond_method``, restart/maxiter,
    ``use_pallas``); ``bind`` attaches a value version, ``solve`` runs a
    coalesced bucket, ``warm`` AOT-compiles the bucket set.
    """

    #: binding identity-valued factors through the compiled sweep applies
    #: M^{-1} = I exactly — the cache's last-resort "fallback" degradation
    supports_identity_fallback = True

    def __init__(self, a: CSRMatrix, pattern: ILUPattern, vals_csr: np.ndarray,
                 restart: int = DEFAULT_RESTART, maxiter: int = DEFAULT_MAXITER,
                 precond_method: str = "sweep", use_pallas: bool = True,
                 buckets: Optional[Sequence[int]] = None):
        import jax
        import jax.numpy as jnp

        from repro.core.solvers import _csr_to_ell_host, batch_buckets

        if precond_method not in ("sweep", "inverse"):
            raise ValueError(f"ServeEngine: unknown precond_method {precond_method!r}")
        self.n = a.n
        self.pattern = pattern
        self.restart = int(restart)
        self.maxiter = int(maxiter)
        self.precond_method = precond_method
        self.use_pallas = bool(use_pallas)
        self.buckets = tuple(batch_buckets() if buckets is None else sorted(buckets))
        self.fingerprint = engine_fingerprint(
            a, pattern, (precond_method, self.restart, self.maxiter, self.use_pallas))

        # --- A-side structure: ELL cols (constant) + the value scatter maps
        a_cols, _ = _csr_to_ell_host(a)
        self._a_ell_shape = a_cols.shape
        lens = np.diff(a.indptr)
        self._a_row_of = np.repeat(np.arange(a.n), lens)
        self._a_pos = np.arange(a.nnz, dtype=np.int64) - a.indptr[self._a_row_of]
        self._a_cols = jnp.asarray(a_cols)

        # --- preconditioner structure --------------------------------------
        if precond_method == "sweep":
            from repro.core.triangular import build_triangular_plan

            self._tri_plan = build_triangular_plan(pattern, vals_csr)
            d = self._tri_plan.device_arrays()
            self._p_static = {k: d[k] for k in
                              ("l_cols", "l_rhs_idx", "u_cols", "u_rhs_idx", "out_perm")}
        else:
            from repro.core.inverse import build_inverse_plan

            plan0 = build_inverse_plan(pattern, vals_csr, k=pattern.k)
            self._w_cols = jnp.asarray(plan0.w_cols)
            self._z_cols = jnp.asarray(plan0.z_cols)

        self._jit = jax.jit(self._make_run())
        self._aot = {}
        self._versions = 0

    # -- the compiled computation ------------------------------------------
    def _make_run(self):
        import jax
        import jax.numpy as jnp

        from repro.core.bitmath import masked_lane_sum
        from repro.core.planner import COL_SENTINEL
        from repro.core.solvers import _gmres_core

        n = self.n
        m, maxiter = self.restart, self.maxiter
        a_cols = self._a_cols
        if self.use_pallas:
            from repro.kernels import ops

        def run(vargs, bs, tols):
            # The SpMV always rides the jnp masked_lane_sum form here — the
            # same fixed-lane-order reduction the Pallas ELL kernel runs, so
            # it is bitwise identical to the solo Pallas matvec — because a
            # ``vmap`` of the interpret-mode pallas_call perturbs SpMV bits
            # (observed: ~1-ulp lane drift), while vmap of this form and of
            # the Pallas *triangular/inverse* kernels is bit-stable. The
            # batched sharded solver uses this form for the same reason.
            def matvec(x):
                xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
                gathered = xg[jnp.minimum(a_cols, n)]
                return masked_lane_sum(a_cols, vargs[0], gathered, COL_SENTINEL)[:n]

            if self.precond_method == "sweep":
                s = self._p_static
                _, l_vals, u_vals, u_diag = vargs

                if self.use_pallas:
                    def M(x):
                        return ops.tri_solve_wavefront(
                            s["l_cols"], l_vals, s["l_rhs_idx"], s["u_cols"],
                            u_vals, u_diag, s["u_rhs_idx"], s["out_perm"], x)
                else:
                    from repro.core.triangular import wavefront_sweeps_jnp

                    def M(x):
                        return wavefront_sweeps_jnp(
                            s["l_cols"], l_vals, s["l_rhs_idx"], s["u_cols"],
                            u_vals, u_diag, s["u_rhs_idx"], s["out_perm"], x)
            else:
                _, w_vals, z_vals = vargs
                wc, zc = self._w_cols, self._z_cols

                # always the Pallas chain: it is the vmap-bit-stable form of
                # the inverse apply (vmapping the raw jnp chain drifts ~1 ulp
                # — the mirror image of the SpMV case above), and it equals
                # the solo jnp chain bitwise
                from repro.kernels import ops as _ops

                def M(x):
                    return _ops.inverse_chain(wc, w_vals, zc, z_vals, x)

            def lane(b, t):
                return _gmres_core(matvec, M, b, m=m, tol=t, maxiter=maxiter)

            return jax.vmap(lane)(bs, tols)

        return run

    # -- value binding ------------------------------------------------------
    def bind(self, a: CSRMatrix, vals_csr: np.ndarray) -> EngineBinding:
        """Attach one value version: host-side scatter + device put, no
        compilation (the inverse method runs the already-compiled value
        sweep — same shapes, same executable)."""
        import jax.numpy as jnp

        t0 = time.perf_counter()
        a_vals = np.zeros(self._a_ell_shape, np.float32)
        a_vals[self._a_row_of, self._a_pos] = a.data
        if self.precond_method == "sweep":
            from repro.core.triangular import rebind_triangular_values

            lv, uv, ud = rebind_triangular_values(self._tri_plan, self.pattern, vals_csr)
            vargs = (jnp.asarray(a_vals), jnp.asarray(lv), jnp.asarray(uv), jnp.asarray(ud))
        else:
            from repro.core.inverse import build_inverse_plan, compute_inverse_values

            plan = build_inverse_plan(self.pattern, vals_csr, k=self.pattern.k)
            w_vals, z_vals = compute_inverse_values(plan)
            if w_vals.shape != self._w_cols.shape or z_vals.shape != self._z_cols.shape:
                raise ValueError("ServeEngine.bind: inverse pattern changed shape — "
                                 "values were bound against a different structure")
            vargs = (jnp.asarray(a_vals), w_vals, z_vals)
        self._versions += 1
        return EngineBinding(version=self._versions, value_args=vargs,
                             vals_csr=np.asarray(vals_csr, np.float32),
                             bound_seconds=time.perf_counter() - t0, a=a)

    def bind_degraded(self, a: CSRMatrix, shift: float, factorize) -> Optional[EngineBinding]:
        """One rung of the serve-side shift ladder: factor
        ``A + shift·diag(‖row‖₁)`` through ``factorize`` (the cache's
        already-compiled plan — same structure, zero compiles), audit it,
        and bind the shifted *sweep* values against the **original** A's
        matvec values. The solve still targets Ax=b; only M changes — and
        the bucketed executable is the very one the healthy path uses, so a
        retry costs a bind, never a compile. Returns None when this rung's
        factor is itself broken (the caller escalates α)."""
        from repro.core.guard import audit_values, shifted_matrix

        a_s = shifted_matrix(a, shift)
        vals_s = factorize(a_s)
        if not audit_values(self.pattern, vals_s).ok:
            return None
        binding = self.bind(a, vals_s)
        binding.shift = float(shift)
        return binding

    # -- solving ------------------------------------------------------------
    def bucket_for(self, nb: int) -> int:
        from repro.core.solvers import bucket_batch

        return bucket_batch(nb, self.buckets)

    def solve(self, binding: EngineBinding, bs: np.ndarray,
              tols: np.ndarray) -> List[LaneResult]:
        """Solve a coalesced (nb, n) stack with per-lane tolerances; pads to
        the nearest bucket, runs the one compiled engine, scatters per-lane
        results back. Padding lanes (zero RHS, tol 1) freeze immediately and
        are sliced off — they cannot touch a real lane's bits."""
        import jax.numpy as jnp

        bs = np.asarray(bs, np.float32)
        tols = np.asarray(tols, np.float32)
        nb = bs.shape[0]
        if bs.ndim != 2 or bs.shape[1] != self.n:
            raise ValueError(f"ServeEngine.solve: expected (nb, {self.n}), got {bs.shape}")
        if tols.shape != (nb,):
            raise ValueError(f"ServeEngine.solve: tols must be ({nb},), got {tols.shape}")
        tgt = self.bucket_for(nb)
        if tgt > nb:
            bs = np.concatenate([bs, np.zeros((tgt - nb, self.n), np.float32)])
            tols = np.concatenate([tols, np.ones(tgt - nb, np.float32)])
        ex = self._aot.get(tgt)
        fn = ex if ex is not None else self._jit
        x, rel, it, tot, hist, bnorm, verdict = fn(
            binding.value_args, jnp.asarray(bs), jnp.asarray(tols))
        from repro.core.solvers import VERDICTS

        x = np.asarray(x)
        rel = np.asarray(rel)
        tot = np.asarray(tot)
        verdict = np.asarray(verdict)
        return [
            LaneResult(x=x[i], iterations=int(tot[i]), residual=float(rel[i]),
                       converged=float(rel[i]) <= float(tols[i]) * 1.01,
                       verdict=VERDICTS[int(verdict[i])])
            for i in range(nb)
        ]

    def warm(self, binding: EngineBinding, buckets: Optional[Sequence[int]] = None) -> dict:
        """AOT-compile the engine for each bucket (serving warmup; with
        ``REPRO_JIT_CACHE`` set the executables persist across processes).
        Returns {bucket: seconds}."""
        import jax

        from repro.core.api import enable_jit_cache

        enable_jit_cache()
        out = {}
        for nb in buckets if buckets is not None else self.buckets:
            t0 = time.perf_counter()
            if nb not in self._aot:
                vargs_sds = tuple(
                    jax.ShapeDtypeStruct(v.shape, v.dtype) for v in binding.value_args)
                bs_sds = jax.ShapeDtypeStruct((nb, self.n), np.float32)
                tol_sds = jax.ShapeDtypeStruct((nb,), np.float32)
                self._aot[nb] = self._jit.lower(vargs_sds, bs_sds, tol_sds).compile()
            out[nb] = time.perf_counter() - t0
        return out


class ShardedServeEngine:
    """The same serve surface over the distributed stack (``solve_sharded``).

    Values still *rebind* (a new factorization swaps in behind the same
    tick loop), but the sharded SpMV/Krylov jits key on closure identity,
    so a rebind's fresh engines are pre-warmed inside :meth:`bind` — in the
    background refactor thread, never on the serving path. The sharded
    sweep itself reuses one compiled ``ShardedTriangularEngine`` across
    rebinds (values are arguments there), shared via the factorization's
    structure-keyed ``_shared`` store.
    """

    #: the sharded engine factors internally — it cannot bind caller-
    #: provided identity values, so ladder exhaustion rejects instead
    supports_identity_fallback = False

    def __init__(self, a: CSRMatrix, pattern: ILUPattern, vals_csr=None,
                 restart: int = DEFAULT_RESTART, maxiter: int = DEFAULT_MAXITER,
                 precond_method: str = "sweep", mesh=None, band_rows: int = 32,
                 k: Optional[int] = None, rule: str = "sum",
                 buckets: Optional[Sequence[int]] = None):
        from repro.core.solvers import batch_buckets
        from repro.core.top_ilu import band_mesh

        self.n = a.n
        self.pattern = pattern
        self.restart = int(restart)
        self.maxiter = int(maxiter)
        self.precond_method = precond_method
        self.mesh = band_mesh(mesh)
        self.band_rows = band_rows
        self.k = pattern.k if k is None else k
        self.rule = rule
        self.buckets = tuple(batch_buckets() if buckets is None else sorted(buckets))
        self.fingerprint = engine_fingerprint(
            a, pattern,
            ("sharded", precond_method, self.restart, self.maxiter, self.band_rows,
             tuple(d.id for d in self.mesh.devices.flat)))
        self._versions = 0
        self._prev_fact = None

    def bind(self, a: CSRMatrix, vals_csr=None) -> EngineBinding:
        """Factorize ``a`` on the mesh and pre-warm the fresh closure-keyed
        engines (one bucketed solve per bucket, off the serving path). The
        structure-keyed sweep engine carries over from the previous
        binding, so only the SpMV/Krylov jits recompile on a rebind."""
        from repro.core.api import ilu_sharded
        from repro.core.solvers import solve_sharded

        t0 = time.perf_counter()
        fact = ilu_sharded(a, self.k, rule=self.rule, band_rows=self.band_rows,
                           mesh=self.mesh, precond_method=self.precond_method,
                           on_breakdown="ignore")
        if self._prev_fact is not None:
            # same structure ⇒ the sharded triangular plan + compiled sweep
            # in `_shared` rebind to the new values without recompiling
            fact._shared = self._prev_fact._shared
        for nb in self.buckets:
            # warm the exact serving-path engine: per-lane tol ARRAY +
            # bucket=False (what solve() dispatches) — a scalar tol would
            # warm a different jit and leave serving to pay the compile
            zb = np.zeros((nb, self.n), np.float32)
            solve_sharded(a, zb, fact=fact, tol=np.ones(nb, np.float32),
                          bucket=False, restart=self.restart,
                          maxiter=self.maxiter, precond_method=self.precond_method)
        self._prev_fact = fact
        self._versions += 1
        binding = EngineBinding(
            version=self._versions, value_args=(a, fact),
            vals_csr=np.asarray(fact.values_csr(), np.float32),
            bound_seconds=time.perf_counter() - t0, a=a)
        return binding

    def bind_degraded(self, a: CSRMatrix, shift: float, factorize=None) -> Optional[EngineBinding]:
        """Shift-retry rung, sharded: refactor ``A + shift·diag(‖row‖₁)`` on
        the mesh (the shifted matrix adopts A's engine stores, so the
        factorization re-executes without re-planning), audit on device, and
        bind ``(original A, shifted fact)`` — the sharded matvec stays on A
        while the sweep reads the shifted factor. ``factorize`` is unused
        (the mesh path factors itself); the fresh closure-keyed Krylov jits
        pre-warm here, off the healthy serving path."""
        from repro.core.api import ilu_sharded
        from repro.core.guard import shifted_matrix
        from repro.core.solvers import solve_sharded

        a_s = shifted_matrix(a, shift)
        fact = ilu_sharded(a_s, self.k, rule=self.rule, band_rows=self.band_rows,
                           mesh=self.mesh, precond_method=self.precond_method,
                           on_breakdown="ignore")
        if self._prev_fact is not None:
            fact._shared = self._prev_fact._shared
        if not fact.health.ok:
            return None
        for nb in self.buckets:
            zb = np.zeros((nb, self.n), np.float32)
            solve_sharded(a, zb, fact=fact, tol=np.ones(nb, np.float32),
                          bucket=False, restart=self.restart,
                          maxiter=self.maxiter, precond_method=self.precond_method)
        self._versions += 1
        return EngineBinding(
            version=self._versions, value_args=(a, fact),
            vals_csr=np.asarray(fact.values_csr(), np.float32),
            bound_seconds=0.0, a=a, shift=float(shift))

    def bucket_for(self, nb: int) -> int:
        from repro.core.solvers import bucket_batch

        return bucket_batch(nb, self.buckets)

    def solve(self, binding: EngineBinding, bs: np.ndarray,
              tols: np.ndarray) -> List[LaneResult]:
        from repro.core.solvers import solve_sharded

        a, fact = binding.value_args
        bs = np.asarray(bs, np.float32)
        tols = np.asarray(tols, np.float32)
        nb = bs.shape[0]
        tgt = self.bucket_for(nb)
        if tgt > nb:
            bs = np.concatenate([bs, np.zeros((tgt - nb, self.n), np.float32)])
            tols = np.concatenate([tols, np.ones(tgt - nb, np.float32)])
        res, _ = solve_sharded(a, bs, fact=fact, tol=tols, bucket=False,
                               restart=self.restart, maxiter=self.maxiter,
                               precond_method=self.precond_method)
        return [
            LaneResult(x=r.x, iterations=r.iterations, residual=r.residual,
                       converged=r.converged, verdict=r.verdict)
            for r in res[:nb]
        ]

    def warm(self, binding: EngineBinding, buckets=None) -> dict:
        """Buckets are already warmed inside :meth:`bind` (the sharded
        engines key on the binding's closures); report zero-cost hits."""
        return {nb: 0.0 for nb in (buckets if buckets is not None else self.buckets)}
