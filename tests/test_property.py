"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import matgen, numeric_ilu_ref, pilu1_symbolic, symbolic_ilu_k
from repro.core.api import ilu
from repro.core.planner import make_plan


matrices = st.builds(
    matgen,
    n=st.integers(min_value=8, max_value=72),
    density=st.floats(min_value=0.03, max_value=0.25),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)


@given(a=matrices, k=st.integers(min_value=0, max_value=3))
@settings(max_examples=25, deadline=None)
def test_pattern_invariants(a, k):
    pat = symbolic_ilu_k(a, k)
    pat.validate()
    # A's pattern is always contained, with level 0
    for j in range(a.n):
        acols, _ = a.row(j)
        pcols, plevs = pat.row(j)
        pos = np.searchsorted(pcols, acols)
        assert np.all(pcols[pos] == acols)
        assert np.all(plevs[pos] == 0)
    # levels bounded by k
    assert pat.levels.max(initial=0) <= k


@given(a=matrices)
@settings(max_examples=15, deadline=None)
def test_pilu1_always_equals_general(a):
    g = symbolic_ilu_k(a, 1)
    f = pilu1_symbolic(a)
    np.testing.assert_array_equal(g.indices, f.indices)
    np.testing.assert_array_equal(g.levels, f.levels)


@given(a=matrices, k=st.integers(min_value=0, max_value=2),
       band_rows=st.integers(min_value=1, max_value=24))
@settings(max_examples=12, deadline=None)
def test_bitcompat_any_banding(a, k, band_rows):
    """The central theorem: band decomposition never changes a single bit."""
    pat = symbolic_ilu_k(a, k)
    want = numeric_ilu_ref(a, pat)
    got = ilu(a, k, backend="jax", band_rows=band_rows).vals
    np.testing.assert_array_equal(got.view(np.int32), want.view(np.int32))


@given(a=matrices, band_rows=st.integers(min_value=1, max_value=16),
       d=st.integers(min_value=1, max_value=6))
@settings(max_examples=15, deadline=None)
def test_planner_invariants(a, band_rows, d):
    pat = symbolic_ilu_k(a, 1)
    plan = make_plan(a, pat, band_rows=band_rows, n_devices=d)
    assert plan.n_bands % d == 0
    assert plan.n_pad == plan.n_bands * plan.band_rows
    assert plan.n_pad >= a.n
    # device-major permutation is a bijection
    x = np.arange(plan.n_pad, dtype=np.int64)
    rt = plan.rows_from_device_major(plan.rows_device_major(x))
    np.testing.assert_array_equal(rt, x)
    # pivot_start is monotone per row, bounded by diag
    assert np.all(np.diff(plan.pivot_start, axis=1) >= 0)
    assert np.all(plan.pivot_start[:, -1] <= plan.diag_pos)
