"""Triangular solves: exact substitution vs scipy, Jacobi variant."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.core import matgen, numeric_ilu_ref, poisson_2d, split_lu, symbolic_ilu_k
from repro.core.triangular import (
    build_triangular_plan,
    make_jacobi_triangular_solver,
    make_triangular_solver,
)


def _setup(n=80, k=1, seed=0):
    a = matgen(n, density=0.07, seed=seed)
    pat = symbolic_ilu_k(a, k)
    vals = numeric_ilu_ref(a, pat)
    return a, pat, vals


@pytest.mark.parametrize("k", [0, 1, 2])
def test_solve_matches_scipy(k):
    a, pat, vals = _setup(k=k)
    L, U = split_lu(pat, vals)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(a.n).astype(np.float32)
    want = spla.spsolve_triangular(
        U.tocsr(), spla.spsolve_triangular(L.tocsr(), b, lower=True), lower=False
    )
    solve = make_triangular_solver(pat, vals)
    got = np.asarray(solve(b))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_solve_poisson():
    a = poisson_2d(8)
    pat = symbolic_ilu_k(a, 1)
    vals = numeric_ilu_ref(a, pat)
    L, U = split_lu(pat, vals)
    b = np.ones(a.n, np.float32)
    want = spla.spsolve_triangular(
        U.tocsr(), spla.spsolve_triangular(L.tocsr(), b, lower=True), lower=False
    )
    got = np.asarray(make_triangular_solver(pat, vals)(b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_wavefront_schedule_is_valid():
    """Every row appears exactly once; dependencies respect level order."""
    _, pat, vals = _setup(k=2)
    plan = build_triangular_plan(pat, vals)
    n = plan.n
    seen = plan.l_levels[plan.l_levels < n]
    assert sorted(seen.tolist()) == list(range(n))
    level_of = np.zeros(n, np.int64)
    for l in range(plan.l_levels.shape[0]):
        for r in plan.l_levels[l]:
            if r < n:
                level_of[r] = l
    for j in range(n):
        deps = plan.l_cols[j][plan.l_cols[j] < n]
        assert np.all(level_of[deps] < level_of[j])


def test_wavefront_levels_match_sequential_recursion():
    """The vectorized Kahn frontier must reproduce the classical
    ``level[j] = 1 + max(level[deps])`` recursion exactly."""
    _, pat, vals = _setup(n=90, k=1, seed=3)
    plan = build_triangular_plan(pat, vals)
    n = plan.n
    for cols, levels, reverse in ((plan.l_cols, plan.l_levels, False),
                                  (plan.u_cols, plan.u_levels, True)):
        level = np.zeros(n, np.int64)
        order = range(n - 1, -1, -1) if reverse else range(n)
        for j in order:
            deps = cols[j][cols[j] < n]
            level[j] = 1 + max((level[i] for i in deps), default=-1)
        nlev = int(level.max()) + 1
        assert levels.shape[0] == nlev
        for l in range(nlev):
            want = np.nonzero(level == l)[0]
            got = levels[l][levels[l] < n]
            np.testing.assert_array_equal(np.sort(got), want)


def test_solver_bitwise_vs_sequential_numpy_substitution():
    """Independent oracle for the paper's bit-compatibility claim: a pure
    NumPy float32 row-by-row substitution in exact sequential order (lane
    order within each row, matching ``masked_lane_sum``) must agree *bitwise*
    with both the jnp reference solver and the fused Pallas apply. This
    oracle shares no code with the device implementations."""
    from repro.core.triangular import PrecondApply

    for seed, k in ((0, 1), (2, 2)):
        a, pat, vals = _setup(n=72, k=k, seed=seed)
        n = a.n
        b = np.random.default_rng(seed + 10).standard_normal(n).astype(np.float32)
        f32 = np.float32
        y = np.zeros(n, f32)
        x = np.zeros(n, f32)
        # forward sweep L y = b (unit diagonal), rows in order
        for j in range(n):
            s, e = pat.indptr[j], pat.indptr[j + 1]
            d = pat.diag_ptr[j]
            acc = f32(0.0)
            for c, v in zip(pat.indices[s:s + d], vals[s:s + d]):
                acc = f32(acc + f32(f32(v) * y[c]))
            y[j] = f32(b[j] - acc)
        # backward sweep U x = y, rows in reverse order
        for j in range(n - 1, -1, -1):
            s, e = pat.indptr[j], pat.indptr[j + 1]
            d = pat.diag_ptr[j]
            acc = f32(0.0)
            for c, v in zip(pat.indices[s + d + 1:e], vals[s + d + 1:e]):
                acc = f32(acc + f32(f32(v) * x[c]))
            x[j] = f32(f32(y[j] - acc) / f32(vals[s + d]))
        for solver in (make_triangular_solver(pat, vals), PrecondApply(pat, vals, use_pallas=True)):
            got = np.asarray(solver(b))
            np.testing.assert_array_equal(got.view(np.int32), x.view(np.int32))


def test_precond_apply_batched_bitwise():
    """vmap-ed applies must agree bitwise with one-at-a-time applies."""
    from repro.core.triangular import PrecondApply

    a, pat, vals = _setup(n=70, k=1, seed=4)
    apply = PrecondApply(pat, vals)
    B = np.random.default_rng(5).standard_normal((4, a.n)).astype(np.float32)
    got = np.asarray(apply.batched(B))
    want = np.stack([np.asarray(apply(B[i])) for i in range(4)])
    np.testing.assert_array_equal(got.view(np.int32), want.view(np.int32))


def test_precond_apply_warm_aot_bitwise():
    """AOT warmup must be behavior-invariant: warmed (bucketed) applies
    return exactly the bits of the unwarmed path, for the single-RHS shape
    and for a ragged batch padded up to a warmed bucket."""
    from repro.core.triangular import PrecondApply

    a, pat, vals = _setup(n=70, k=1, seed=6)
    apply = PrecondApply(pat, vals, use_pallas=False)
    b = np.random.default_rng(7).standard_normal(a.n).astype(np.float32)
    B = np.random.default_rng(8).standard_normal((3, a.n)).astype(np.float32)
    want1 = np.asarray(apply(b))
    wantB = np.asarray(apply.batched(B))
    secs = apply.warm((1, 4))
    assert set(secs) == {1, 4} and set(apply._aot) == {1, 4}
    got1 = np.asarray(apply(b))  # AOT executable
    gotB = np.asarray(apply.batched(B))  # ragged 3 -> bucket 4, sliced back
    np.testing.assert_array_equal(got1.view(np.int32), want1.view(np.int32))
    np.testing.assert_array_equal(gotB.view(np.int32), wantB.view(np.int32))
    assert gotB.shape == (3, a.n)
    # warming again is free (executables cached)
    assert apply.warm((4,))[4] < 0.5


def test_jacobi_converges_to_exact():
    a, pat, vals = _setup(k=1)
    b = np.random.default_rng(2).standard_normal(a.n).astype(np.float32)
    exact = np.asarray(make_triangular_solver(pat, vals)(b))
    plan = build_triangular_plan(pat, vals)
    depth = plan.l_levels.shape[0] + plan.u_levels.shape[0]
    approx = np.asarray(make_jacobi_triangular_solver(pat, vals, sweeps=depth + 2)(b))
    np.testing.assert_allclose(approx, exact, rtol=1e-4, atol=1e-4)
