"""TOP-ILU — task-oriented parallel ILU(k) over a device mesh (paper §IV).

Maps the paper's distributed-memory algorithm onto JAX SPMD, re-emitted
(PR 3) over the *sharded value layout* from the planner:

* bands → round-robin ownership over the mesh axis (static load balancing,
  §IV-D; device ``d`` owns bands ``{b : b ≡ d (mod D)}``),
* value storage → **sharded**: each device holds only its bands' values
  (``s_loc = n_pad/D`` rows) plus a halo of the finalized foreign pivot
  rows it actually consumes, precomputed on the host from the band
  superstep schedule (``planner._halo_exchange_schedule``). Nothing is
  replicated on the value path, so the largest solvable system scales with
  the *mesh*, not with one device's memory — the paper's §IV point,
* the frontier loop → ``lax.fori_loop`` over band-dependency *wavefronts*
  inside one jitted step: bands whose dependencies are satisfied factor
  concurrently, pulling pivot rows from local storage or the halo,
* the Fig-4 ring pipeline → ONE halo exchange per superstep — an XLA ring
  ``all_gather`` of each device's (E, W) egress payload (``broadcast=
  'psum'`` is accepted as the historical alias for this fast path) or an
  explicit ``ppermute`` directed ring (``broadcast='ring'``) — shipping
  only the pivot rows another device needs, instead of every finished band,
* dynamic load balancing (master/worker) → intentionally absent from the
  SPMD fast path; the paper itself measures static LB as strictly better
  (Table I). It survives as the fault-tolerance reassignment path in
  ``repro.runtime``.

Structure (column indices, destination-lane maps, the schedule itself) is
static planning output and never communicated: 4 bytes/entry on the wire
instead of the paper's 8 — see §V-E and DESIGN.md §5. The factorization
output stays device-resident as a :class:`ShardedILUFactorization`, whose
``precond()``/``solve`` consume the sharded values in place — distributed
solves never re-replicate L/U onto one device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from .planner import NumericPlan, make_plan
from .numeric_jax import (
    make_superstep_factorizer,
    plan_device_arrays,
    plan_shard_specs,
    plan_state_array,
)
from .sparse import CSRMatrix, ILUPattern

AXIS = "band"

_ARG_ORDER = ("state", "sched", "piv_addr", "piv_dlane", "piv_dst", "n_piv", "egress", "ingress")


def _values_to_csr_order(plan: NumericPlan, pattern: ILUPattern, vals_rm: np.ndarray) -> np.ndarray:
    """Padded row-major values -> CSR-aligned flat values (one gather)."""
    vals_rm = np.asarray(vals_rm)
    rowlen = np.diff(pattern.indptr).astype(np.int64)
    row_of = np.repeat(np.arange(pattern.n, dtype=np.int64), rowlen)
    lane = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[row_of]
    return vals_rm[row_of, lane].astype(np.float32)


def band_mesh(mesh: Optional[Mesh] = None) -> Mesh:
    """Default 1-D ``(band,)`` mesh over every available device."""
    if mesh is not None:
        return mesh
    from repro.launch.mesh import make_band_mesh

    return make_band_mesh()


@dataclasses.dataclass
class ShardedILUFactorization:
    """Device-resident sharded factorization output (DESIGN.md §5).

    ``loc_vals`` is a jax array of shape (D, s_loc, W) — the factored ELL
    values in device-major band order, sharded over the mesh's band axis so
    each device holds only its own (s_loc, W) block. The preconditioner
    apply (:meth:`precond`) and the distributed solve consume it in place;
    :meth:`values_csr` gathers to the host only when explicitly asked
    (tests / interop), it is not on any solve path.
    """

    a: CSRMatrix
    k: int
    pattern: ILUPattern
    plan: NumericPlan
    mesh: Mesh
    loc_vals: jax.Array  # (D, s_loc, W) f32, sharded over AXIS
    symbolic_seconds: float = 0.0
    numeric_seconds: float = 0.0
    # the row ordering the system was permuted with before factoring
    # (None = natural). ``a``/``pattern``/``loc_vals`` describe the
    # *permuted* system; ``solve`` un/permutes at the boundary, while
    # ``precond()`` stays in permuted row space (``solve_sharded`` owns
    # the boundary on that path).
    ordering: Optional[object] = None
    # how M^{-1} applies: "sweep" (epoch-scheduled triangular sweeps),
    # "inverse" (the incomplete-inverse SpMV chain — two collectives per
    # apply, no epochs), or "auto" (cost-modeled per matrix)
    precond_method: str = "sweep"
    # pivot-guard audit (core.guard.FactorHealth) — None when the guard was
    # bypassed; ``health.shift`` > 0 means this factorization describes the
    # diagonally shifted system, and ``health.degraded`` routes
    # ``precond()`` to the identity fallback
    health: Optional[object] = None
    # structure-keyed shared cache (the engine-store entry): the sharded
    # triangular plan + compiled sweep live here, so refactorizations of
    # the same structure rebind values to one compiled solve engine
    _shared: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)
    _preconds: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)

    @property
    def nnz(self) -> int:
        return self.pattern.nnz

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    def per_device_value_bytes(self) -> int:
        return self.plan.per_device_value_bytes()

    def values_csr(self) -> np.ndarray:
        """Gather the sharded factors to the host as CSR-aligned values."""
        dm = np.asarray(self.loc_vals).reshape(self.plan.n_pad, self.plan.width)
        return _values_to_csr_order(self.plan, self.pattern, self.plan.rows_from_device_major(dm))

    def _tri_plan(self):
        """The structure-keyed sharded triangular plan (built on demand)."""
        from .triangular import build_sharded_triangular_plan

        tp = self._shared.get("tri_plan")
        if tp is None:
            tp = self._shared["tri_plan"] = build_sharded_triangular_plan(
                self.pattern, self.plan.band_rows, self.n_devices)
        return tp

    def resolve_method(self, method: Optional[str] = None) -> str:
        """Resolve ``precond_method`` for this mesh: ``"auto"`` races the
        sweep plan's actual ``comm_summary`` (epoch collectives + exact
        read-set bytes) against the SpMV-chain model and returns the
        cheaper apply."""
        from .inverse import resolve_precond_method

        method = method if method is not None else self.precond_method
        summary = self._tri_plan().comm_summary() if method == "auto" else None
        return resolve_precond_method(method, self.pattern, self.n_devices,
                                      self.plan.band_rows, sweep_summary=summary)

    def precond(self, broadcast: str = "gather", method: Optional[str] = None):
        """Cached band-partitioned M^{-1} apply over the sharded values.

        ``method`` (default: this factorization's ``precond_method``) picks
        the engine. ``"sweep"`` →
        ``repro.core.triangular.ShardedPrecondApply``: L/U storage stays
        sharded and the sweep vector is device-local; communication follows
        the epoch/read-set schedule (DESIGN.md §5.5), with ``broadcast``
        choosing the XLA ``all_gather`` fast path (``"gather"``/``"psum"``)
        or the explicit ``ppermute`` ring (``"ring"``). The triangular plan
        and its compiled sweep are structure-keyed (shared across
        refactorizations); this factorization's values bind to them via one
        jitted on-device extract. ``"inverse"`` →
        ``repro.core.inverse.ShardedInversePrecondApply``: the truncated
        inverse SpMV chain, two collectives per apply regardless of
        wavefront depth (``broadcast`` is moot — both exchanges are plain
        all_gathers). ``"auto"`` races the two cost models."""
        if self.health is not None and self.health.degraded:
            # shift-ladder exhaustion under on_breakdown="fallback":
            # sweeping the broken factor would NaN every lane, so M^{-1}=I
            from .guard import IdentityPrecondApply

            return self._preconds.setdefault("identity", IdentityPrecondApply())
        method = self.resolve_method(method)
        if method == "inverse":
            if "inverse" not in self._preconds:
                from .inverse import ShardedInversePrecondApply

                self._preconds["inverse"] = ShardedInversePrecondApply(
                    self.pattern, self.values_csr(), self.mesh)
            return self._preconds["inverse"]
        if broadcast == "psum":
            broadcast = "gather"
        if broadcast not in self._preconds:
            from .triangular import ShardedPrecondApply, ShardedTriangularEngine

            tp = self._tri_plan()
            eng = self._shared.get(("tri_engine", broadcast))
            if eng is None:
                eng = self._shared[("tri_engine", broadcast)] = (
                    ShardedTriangularEngine(tp, self.mesh, broadcast=broadcast))
            self._preconds[broadcast] = ShardedPrecondApply(
                eng.plan, self.loc_vals, self.mesh, engine=eng)
        return self._preconds[broadcast]

    def solve(self, b: np.ndarray) -> np.ndarray:
        """Apply the preconditioner: L y = b then U x = y, distributed.
        With an ordering, ``b`` permutes in and ``x`` un-permutes out."""
        b = np.asarray(b, np.float32)
        if self.ordering is not None:
            b = self.ordering.permute_vector(b)
        out = np.asarray(self.precond()(b))
        if self.ordering is not None:
            out = self.ordering.unpermute_vector(out)
        return out

    def to_host(self):
        """Materialize as a host :class:`repro.core.api.ILUFactorization`."""
        from .api import ILUFactorization

        return ILUFactorization(
            a=self.a, k=self.k, pattern=self.pattern, vals=self.values_csr(),
            symbolic_seconds=self.symbolic_seconds,
            numeric_seconds=self.numeric_seconds, ordering=self.ordering,
            precond_method=self.precond_method)


def _sharded_inputs(plan: NumericPlan, mesh: Mesh, keys=None):
    """Place the factorizer inputs on the mesh, each sharded on its device
    axis (``launch.sharding.band_shardings``) so no array is replicated.
    ``keys`` restricts which arrays are built and placed."""
    from repro.launch.sharding import band_shardings

    arrays = plan_device_arrays(plan, keys=keys)
    shardings = band_shardings(mesh, plan_shard_specs(AXIS))
    return {k: jax.device_put(v, shardings[k]) for k, v in arrays.items()}


def _build_topilu_engine(a, pattern, band_rows, mesh, broadcast):
    """Structure-keyed engine-store entry: plan, compiled engine, placed
    *schedule* arrays (no values — the state is rebuilt per call), the
    state sharding, and a dict the solve-side engines cache into."""
    d = mesh.devices.size
    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=d)
    fac = make_superstep_factorizer(plan, axis_name=AXIS if d > 1 else None, broadcast=broadcast)
    static = tuple(k for k in _ARG_ORDER if k != "state")
    if d == 1:
        import jax.numpy as jnp

        fn = jax.jit(fac)
        state_sharding = None
        # commit the constant schedule tables to device once — numpy args
        # would re-transfer per cached-engine refactorization. The value
        # state is NOT placed here: it is rebuilt from a.data per call.
        arrays = plan_device_arrays(plan, keys=static)
        args = tuple(jnp.asarray(arrays[k]) for k in static)
    else:
        specs = plan_shard_specs(AXIS)
        fn = jax.jit(shard_map(
            fac,
            mesh=mesh,
            in_specs=tuple(specs[k] for k in _ARG_ORDER),
            out_specs=P(AXIS, None, None),
            check_vma=False,
        ))
        from repro.launch.sharding import band_shardings

        placed = _sharded_inputs(plan, mesh, keys=static)
        state_sharding = band_shardings(mesh, plan_shard_specs(AXIS))["state"]
        args = tuple(placed[k] for k in static)
    return dict(plan=plan, fn=fn, args=args, state_sharding=state_sharding, shared={})


def topilu_factor_sharded(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int = 32,
    mesh: Optional[Mesh] = None,
    broadcast: str = "psum",
) -> ShardedILUFactorization:
    """Parallel numeric factorization; output stays sharded on the mesh.

    The plan, the compiled shard_map engine, and the placed schedule arrays
    are memoized on the matrix object (same lifetime rule as
    ``factor_plan_for``: the cache dies with the matrix), keyed by pattern
    content, band size, mesh devices, and broadcast — repeated
    factorizations of the same configuration re-execute the cached engine
    instead of replanning and recompiling. The *value* state is rebuilt
    from ``a.data`` on every call, so refactorizing with updated values
    never reuses stale numbers.
    """
    mesh = band_mesh(mesh)
    from .factor_plan import _pattern_fingerprint

    key = ("topilu", _pattern_fingerprint(pattern), band_rows,
           tuple(dev.id for dev in mesh.devices.flat), broadcast)
    try:
        store = a.__dict__.setdefault("_topilu_engines", {})
    except AttributeError:  # exotic container without __dict__: no caching
        store = {}
    entry = store.get(key)
    if entry is None:
        entry = store[key] = _build_topilu_engine(a, pattern, band_rows, mesh, broadcast)
    plan = entry["plan"]
    state = plan_state_array(plan, a)
    if entry["state_sharding"] is not None:
        state = jax.device_put(state, entry["state_sharding"])
    return ShardedILUFactorization(
        a=a, k=pattern.k, pattern=pattern, plan=plan, mesh=mesh,
        loc_vals=entry["fn"](state, *entry["args"]),
        _shared=entry["shared"])


def topilu_numeric(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int = 32,
    mesh: Optional[Mesh] = None,
    broadcast: str = "psum",
) -> np.ndarray:
    """Parallel numeric factorization. Returns CSR-aligned host values.

    With ``mesh=None`` uses every available device on a 1-D mesh; pass an
    explicit 1-D mesh to control the device set. This is the host-gathering
    convenience wrapper; :func:`topilu_factor_sharded` keeps the output
    device-resident.
    """
    return topilu_factor_sharded(
        a, pattern, band_rows=band_rows, mesh=mesh, broadcast=broadcast
    ).values_csr()


def lower_topilu(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int,
    mesh: Mesh,
    broadcast: str = "psum",
):
    """AOT-lower the parallel factorization (for dry-runs / HLO inspection)."""
    d = mesh.devices.size
    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=d)
    arrays = plan_device_arrays(plan)
    specs = plan_shard_specs(AXIS)
    fac = make_superstep_factorizer(plan, axis_name=AXIS, broadcast=broadcast)
    smapped = shard_map(
        fac,
        mesh=mesh,
        in_specs=tuple(specs[k] for k in _ARG_ORDER),
        out_specs=P(AXIS, None, None),
        check_vma=False,
    )
    from repro.launch.sharding import band_shardings

    shardings = band_shardings(mesh, specs)
    args = [
        jax.ShapeDtypeStruct(arrays[k].shape, arrays[k].dtype, sharding=shardings[k])
        for k in _ARG_ORDER
    ]
    return jax.jit(smapped).lower(*args), plan
