"""Public API: ILU(k) preconditioning end-to-end.

    from repro.core.api import ilu
    fact = ilu(a, k=1, backend="jax")      # symbolic + numeric
    x = fact.solve(b)                      # apply M^{-1} (two triangular solves)

Backends:
  * ``oracle``   — sequential NumPy (the paper's sequential algorithm).
  * ``jax``      — single-device wavefront engine over a cached
                   ``FactorPlan`` (bit-compatible; ``band_rows`` ignored).
  * ``topilu``   — multi-device shard_map TOP-ILU over the band superstep
                   schedule (bit-compatible; bands of ``band_rows`` rows;
                   sharded value storage + halo exchange, DESIGN.md §5).

:func:`ilu_sharded` is the distributed entry point: same contract, but the
factor values stay device-resident/sharded and the preconditioner applies
in place (``ilu(backend="topilu")`` gathers the result to the host).

The whole ``factorize → precond → solve`` pipeline is plan→compile→execute
(DESIGN.md §3): each stage's plan and compiled engine are cached — the
``FactorPlan`` on the matrix, the ``PrecondApply`` on the factorization —
so repeated use retraces nothing.

``ordering=`` (both entry points) runs the pipeline on a symmetrically
permuted system ``P A Pᵀ`` (DESIGN.md §Ordering): ``"rcm"``, ``"fusion"``
(the fusion-aware subdomain layout from ``repro.core.ordering``), an
explicit permutation, or ``None``/``"natural"``. The permutation is
applied once at plan time and cached on the matrix; the factorization is
bitwise-equal to sequential ILU(k) of the *permuted* matrix, and
``solve`` un/permutes ``b``/``x`` at the boundary (pure gathers).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .sparse import CSRMatrix, ILUPattern, split_lu
from .symbolic import symbolic_ilu_k, pilu1_symbolic
from .numeric_ref import numeric_ilu_ref

_JIT_CACHE_DIR = None


def enable_jit_cache(path: str = None) -> bool:
    """Turn on jax's persistent compilation cache (idempotent per path).

    ``path`` defaults to the ``REPRO_JIT_CACHE`` environment variable; with
    neither set this is a no-op. An explicit ``path`` always takes effect —
    re-pointing the cache is allowed. Serving setups call it implicitly
    through every ``warm`` entry point (``PrecondApply.warm``,
    ``ShardedPrecondApply.warm``, ``solvers.warm_solve``), making first-use
    engine jits a once-per-machine cost instead of once-per-process.
    Returns True iff the cache is (now) enabled.
    """
    global _JIT_CACHE_DIR
    import os

    path = path or os.environ.get("REPRO_JIT_CACHE") or _JIT_CACHE_DIR
    if not path or path == _JIT_CACHE_DIR:
        return _JIT_CACHE_DIR is not None
    import jax

    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
    _JIT_CACHE_DIR = path
    return True


@dataclasses.dataclass
class ILUFactorization:
    """Host-side factorization. With an ordering, ``a``/``pattern``/``vals``
    all describe the *permuted* system ``P A Pᵀ`` (the bit-compat contract
    is relative to that row order); ``solve`` handles the boundary."""

    a: CSRMatrix
    k: int
    pattern: ILUPattern
    vals: np.ndarray  # CSR-aligned filled values
    symbolic_seconds: float
    numeric_seconds: float
    # the row ordering the system was permuted with (None = natural);
    # solve() permutes b / unpermutes x so callers stay in original space
    ordering: Optional["Ordering"] = None
    # how M^{-1} applies: "sweep" (the exact triangular sweeps), "inverse"
    # (the level-truncated incomplete-inverse SpMV chain, DESIGN.md §Inverse),
    # or "auto" (cost-modeled; single-device resolves to sweep)
    precond_method: str = "sweep"
    # pivot-guard audit of this factor (core.guard.FactorHealth). None only
    # when the guard was bypassed; ``health.shift`` > 0 means ``a``/``vals``
    # describe the diagonally shifted system the ladder settled on, and
    # ``health.degraded`` routes ``precond()`` to the identity fallback.
    health: Optional["FactorHealth"] = None
    # lazily built apply engines, keyed by (method, use_pallas) — the plan
    # + compiled apply are built once and reused across every
    # solve/restart/RHS batch against this factorization
    _preconds: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)

    def lu_matrices(self):
        return split_lu(self.pattern, self.vals)

    def precond(self, use_pallas: bool = True, method: Optional[str] = None):
        """The cached device-resident M^{-1} apply: ``PrecondApply`` for the
        sweep method, ``InversePrecondApply`` for the inverse chain.
        ``method`` defaults to the factorization's ``precond_method``."""
        from .inverse import resolve_precond_method

        if self.health is not None and self.health.degraded:
            # last rung of the fallback chain: sweeping a broken factor
            # would inject NaN into every iterate, so M^{-1} = I
            from .guard import IdentityPrecondApply

            return self._preconds.setdefault("identity", IdentityPrecondApply())

        method = resolve_precond_method(
            method if method is not None else self.precond_method,
            self.pattern, n_devices=1)
        key = (method, bool(use_pallas))
        if key not in self._preconds:
            if method == "inverse":
                from .inverse import InversePrecondApply

                self._preconds[key] = InversePrecondApply(
                    self.pattern, self.vals, use_pallas=key[1])
            else:
                from .triangular import PrecondApply

                self._preconds[key] = PrecondApply(self.pattern, self.vals, use_pallas=key[1])
        return self._preconds[key]

    def solve(self, b: np.ndarray) -> np.ndarray:
        """Apply the preconditioner: solve L y = b, then U x = y.

        Batched input (batch, n) is vmapped through the same cached plan.
        With an ordering, ``b`` is permuted in and ``x`` un-permuted out
        (pure gathers), so the caller stays in original row order."""
        apply = self.precond()
        b = np.asarray(b, np.float32)
        if self.ordering is not None:
            b = self.ordering.permute_vector(b)
        if np.ndim(b) == 2:
            out = np.asarray(apply.batched(b))
        else:
            out = np.asarray(apply(b))
        if self.ordering is not None:
            out = self.ordering.unpermute_vector(out)
        return out

    @property
    def nnz(self) -> int:
        return self.pattern.nnz


def _symbolic(a: CSRMatrix, k: int, rule: str):
    if k == 1:
        return pilu1_symbolic(a, rule=rule)  # PILU(1), paper §IV-F
    return symbolic_ilu_k(a, k, rule=rule)


def _resolve_ordering(a: CSRMatrix, ordering, n_devices: int, band_rows: int):
    """Resolve ``ordering=`` and return ``(system, Ordering-or-None)``.

    The permuted matrix is cached on ``a`` (``ordering.permuted_system``),
    so repeated calls with one ordering reuse one matrix object — and with
    it every plan/engine cache hanging off it."""
    from .ordering import make_ordering, permuted_system

    ord_ = make_ordering(a, ordering, n_devices=n_devices, band_rows=band_rows)
    if ord_ is None:
        return a, None
    return permuted_system(a, ord_), ord_


def ilu_sharded(
    a: CSRMatrix,
    k: int,
    rule: str = "sum",
    band_rows: int = 32,
    mesh=None,
    broadcast: str = "psum",
    ordering=None,
    precond_method: str = "sweep",
    on_breakdown: str = "raise",
    pivot_tol: Optional[float] = None,
    shift0: Optional[float] = None,
    max_shifts: Optional[int] = None,
):
    """Distributed factorization whose output **stays sharded on the mesh**
    (``repro.core.top_ilu.ShardedILUFactorization``): each device holds only
    its bands' factor values, the preconditioner applies in place, and
    ``values_csr()`` gathers to the host only on explicit request. Bitwise
    contract identical to every other backend. ``mesh=None`` builds a 1-D
    band mesh over all available devices. ``ordering=`` permutes the system
    once at plan time (``"fusion"`` targets this mesh's band ownership, so
    sweep epochs fuse — see ``repro.core.ordering``); the sharded factors
    then equal sequential ILU(k) of the permuted matrix bitwise, and
    ``solve`` un/permutes at the boundary.

    ``on_breakdown`` selects the pivot-guard policy (``core.guard``): every
    factorization is audited on-device (a pure read — guarded factors are
    bitwise identical to unguarded ones); on a breakdown the shift ladder
    refactors ``A + α·diag(‖row‖₁)`` through the *same* cached engines (the
    shifted matrix shares A's structure, so a rung is a value re-scatter,
    not a compile), and each shifted factor is bitwise-anchored to the
    sequential oracle of the shifted matrix."""
    from .guard import audit_sharded, run_ladder
    from .top_ilu import band_mesh, topilu_factor_sharded

    mesh = band_mesh(mesh)
    a, ord_ = _resolve_ordering(a, ordering, int(mesh.devices.size), band_rows)
    t0 = time.perf_counter()
    pattern = _symbolic(a, k, rule)
    t1 = time.perf_counter()

    def factor(mat):
        f = topilu_factor_sharded(mat, pattern, band_rows=band_rows,
                                  mesh=mesh, broadcast=broadcast)
        f.loc_vals.block_until_ready()
        return f

    _sysmat, fact, health = run_ladder(
        a, factor, lambda f: audit_sharded(f, pivot_tol), on_breakdown,
        shift0=shift0, max_shifts=max_shifts)
    fact.symbolic_seconds = t1 - t0
    fact.numeric_seconds = time.perf_counter() - t1
    fact.ordering = ord_
    fact.precond_method = precond_method
    fact.health = health
    return fact


def ilu(
    a: CSRMatrix,
    k: int,
    rule: str = "sum",
    backend: str = "jax",
    band_rows: int = 32,
    mesh=None,
    broadcast: str = "psum",
    ordering=None,
    precond_method: str = "sweep",
    on_breakdown: str = "raise",
    pivot_tol: Optional[float] = None,
    shift0: Optional[float] = None,
    max_shifts: Optional[int] = None,
) -> ILUFactorization:
    """``on_breakdown`` (``"raise"|"shift"|"fallback"|"ignore"``) is the
    pivot-guard policy — see ``core.guard`` and :func:`ilu_sharded`. The
    audit is a pure read of the finished factor, so a healthy factorization
    is bitwise unaffected by the guard; when the ladder engages, the
    returned factorization's ``a``/``vals`` describe the *shifted* system
    (``health.shift`` records α) and stay bitwise-anchored to the shifted
    matrix's sequential oracle."""
    if backend == "topilu":
        from .top_ilu import band_mesh

        mesh = band_mesh(mesh)
        n_dev = int(mesh.devices.size)
    else:
        n_dev = 1
    a, ord_ = _resolve_ordering(a, ordering, n_dev, band_rows)
    t0 = time.perf_counter()
    pattern = _symbolic(a, k, rule)
    t1 = time.perf_counter()

    # one numeric closure per backend: the ladder refactors shifted matrices
    # through it, and because the shifted matrix shares a's structure caches
    # (FactorPlan / TOP-ILU engine stores ride along by reference in
    # guard.shifted_matrix) a ladder rung re-executes without re-planning
    def numeric(mat):
        if backend == "oracle":
            return np.asarray(numeric_ilu_ref(mat, pattern), np.float32)
        if backend == "jax":
            from .factor_plan import factor_plan_for

            # plan + compiled engine are memoized on the matrix (FactorPlan);
            # repeated/updated-value factorizations skip planning and compile
            return np.asarray(factor_plan_for(mat, pattern).factorize(mat),
                              np.float32)
        if backend == "topilu":
            from .top_ilu import topilu_numeric

            return np.asarray(
                topilu_numeric(mat, pattern, band_rows=band_rows, mesh=mesh,
                               broadcast=broadcast), np.float32)
        raise ValueError(f"unknown backend {backend!r}")

    from .guard import audit_values, run_ladder

    sysmat, vals, health = run_ladder(
        a, numeric, lambda v: audit_values(pattern, v, pivot_tol),
        on_breakdown, shift0=shift0, max_shifts=max_shifts)
    t2 = time.perf_counter()
    return ILUFactorization(
        a=sysmat, k=k, pattern=pattern, vals=vals,
        symbolic_seconds=t1 - t0, numeric_seconds=t2 - t1, ordering=ord_,
        precond_method=precond_method, health=health,
    )
