"""Model/run configuration: one dataclass, ten architectures, four shapes.

``ModelConfig`` is the single source of truth consumed by models, the
trainer, the server and the dry-run. Every assigned architecture file in
this package exports ``CONFIG`` (exact published numbers) and the registry
in ``__init__`` maps ``--arch <id>`` to it.

Vocab sizes are padded to a multiple of 256 for model-axis divisibility;
``vocab_real`` keeps the published size for loss masking (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


def pad_vocab(v: int, mult: int = 256) -> int:
    return ((v + mult - 1) // mult) * mult


#: shape table: name -> (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass
class ModelConfig:
    arch: str
    family: str  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_real: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    use_rope: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    attention: str = "gqa"  # gqa | mla
    q_chunk: int = 1024
    kv_chunk: int = 1024

    # MLA (deepseek)
    mla_kv_lora: int = 512
    mla_nope_dim: int = 128
    mla_rope_dim: int = 64
    mla_v_dim: int = 128

    # MLP / MoE
    mlp_act: str = "swiglu"  # swiglu | gelu
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    moe_capacity_factor: float = 1.25
    moe_norm_topk: bool = True
    moe_aux_weight: float = 0.001
    # pad expert count to a multiple of this so EP divides the model axis
    # (§Perf hillclimb #1 iter 3: qwen2-moe 60 -> 64; padded experts get
    # -inf router logits and are never selected)
    moe_expert_pad: int = 16

    # SSM / hybrid / xlstm
    ssm_state: int = 0
    ssm_inner: int = 0
    block_types: Optional[List[str]] = None  # xlstm: ['m','s',...]
    hybrid_parallel_ssm: bool = False  # hymba: attn ‖ mamba heads

    # enc-dec (whisper) / vlm (llava) stubs
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frame count
    vision_patches: int = 0  # stub patch count

    # norms / misc
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    scan_layers: bool = True
    attn_unroll: bool = False  # cost-pass: prefix-sliced attention, no inner scan
    remat: str = "dots"  # none | dots | full
    param_dtype: object = jnp.bfloat16
    act_dtype: object = jnp.bfloat16

    # shapes this arch supports (long_500k only for sub-quadratic archs)
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    def __post_init__(self):
        if self.head_dim == 0:
            self.head_dim = self.d_model // self.n_heads

    @property
    def vocab(self) -> int:
        return pad_vocab(self.vocab_real)

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    # ---------------- parameter counting (for roofline MODEL_FLOPS) -------
    def param_count(self) -> Dict[str, int]:
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        if self.attention == "mla":
            attn = (
                d * self.n_heads * (self.mla_nope_dim + self.mla_rope_dim)
                + d * (self.mla_kv_lora + self.mla_rope_dim)
                + self.mla_kv_lora * self.n_heads * (self.mla_nope_dim + self.mla_v_dim)
                + self.n_heads * self.mla_v_dim * d
            )
        else:
            attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
                + self.n_heads * self.head_dim * d
        if self.n_routed_experts:
            ffn_r = self.n_routed_experts * 3 * d * self.d_expert + d * self.n_routed_experts
            ffn_s = 3 * d * (self.n_shared_experts * self.d_expert)
            ffn = ffn_r + ffn_s
            ffn_active = (self.moe_top_k + self.n_shared_experts) * 3 * d * self.d_expert \
                + d * self.n_routed_experts
        elif self.d_ff:
            nmat = 3 if self.mlp_act == "swiglu" else 2
            ffn = nmat * d * self.d_ff
            ffn_active = ffn
        else:
            ffn = ffn_active = 0
        if self.family == "ssm":  # xlstm blocks
            di = 2 * d
            m = d * 2 * di + 3 * di * di + di * 2 * self.n_heads + di * d
            s = d * 4 * d + d * 4 * (d // self.n_heads) + d * d
            n_m = sum(1 for t in (self.block_types or []) if t == "m") or L
            n_s = L - n_m
            blocks = n_m * m + n_s * s
            attn = 0
            ffn = ffn_active = 0
            per_layer_total = 0
            total = emb + head + blocks
            active = total
            return {"total": total, "active": active, "embedding": emb + head}
        ssm = 0
        if self.hybrid_parallel_ssm:
            di = self.ssm_inner or d
            ssm = d * di + di * 2 * self.ssm_state + di * (d // 16) * 2 + di * d
        per_layer = attn + ffn + ssm
        per_layer_active = attn + ffn_active + ssm
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
        total = emb + head + L * per_layer + enc
        active = emb + head + L * per_layer_active + enc
        return {"total": total, "active": active, "embedding": emb + head}

    # ---------------- shape/input specs -----------------------------------
    def input_specs(self, shape_name: str):
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        seq, gbatch, kind = SHAPES[shape_name]
        i32 = jnp.int32
        if kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((gbatch, seq), i32),
                "labels": jax.ShapeDtypeStruct((gbatch, seq), i32),
            }
            if self.family == "vlm":
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (gbatch, self.vision_patches, self.d_model), self.act_dtype
                )
            if self.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (gbatch, self.encoder_seq, self.d_model), self.act_dtype
                )
            return specs
        # decode: one new token against a seq-long cache
        specs = {"tokens": jax.ShapeDtypeStruct((gbatch, 1), i32)}
        if self.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (gbatch, self.encoder_seq, self.d_model), self.act_dtype
            )
        return specs

    def cache_len(self, shape_name: str) -> int:
        seq, _, _ = SHAPES[shape_name]
        if self.sliding_window is not None:
            return min(seq, self.sliding_window)
        return seq

    # ---------------- reduced variant for CPU smoke tests ------------------
    def reduced(self) -> "ModelConfig":
        c = dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_real=503,
            q_chunk=32,
            kv_chunk=32,
            param_dtype=jnp.float32,
            act_dtype=jnp.float32,
            remat="none",
        )
        if self.n_routed_experts:
            c = dataclasses.replace(
                c, n_routed_experts=8, moe_top_k=min(self.moe_top_k, 2),
                n_shared_experts=min(self.n_shared_experts, 1), d_expert=32,
                moe_expert_pad=4,
            )
        if self.attention == "mla":
            c = dataclasses.replace(
                c, mla_kv_lora=32, mla_nope_dim=16, mla_rope_dim=8,
                mla_v_dim=16, head_dim=24,
            )
        if self.sliding_window:
            c = dataclasses.replace(c, sliding_window=32)
        if self.ssm_state:
            c = dataclasses.replace(c, ssm_state=4, ssm_inner=64 if self.ssm_inner else 0)
        if self.block_types:
            c = dataclasses.replace(c, block_types=["m", "s"])
        if self.encoder_layers:
            c = dataclasses.replace(c, encoder_layers=2, encoder_seq=24)
        if self.vision_patches:
            c = dataclasses.replace(c, vision_patches=16)
        if self.family == "ssm":
            c = dataclasses.replace(c, n_kv_heads=4)
        return c
