"""BILU(k) — the MXU tile adaptation: LU property + preconditioner quality."""
import numpy as np
import pytest

from repro.core import CSRMatrix, matgen, poisson_2d
from repro.core.bilu import bilu, bilu_scalar_pattern, tile_adjacency


def test_tile_adjacency():
    a = matgen(40, density=0.1, seed=0)
    adj = tile_adjacency(a, bs=8)
    assert adj.n == 5
    assert adj.has_full_diagonal()
    dense = a.to_dense()
    adj_d = adj.to_dense()
    for i in range(5):
        for j in range(5):
            blk = dense[i * 8 : (i + 1) * 8, j * 8 : (j + 1) * 8]
            if np.any(blk) and i != j:
                assert adj_d[i, j] == 1.0


def test_bilu_full_pattern_is_exact_lu():
    """Dense tile pattern (k=n_tiles) -> exact no-pivot LU."""
    rng = np.random.default_rng(1)
    n = 32
    d = rng.standard_normal((n, n)).astype(np.float32)
    d += np.diag(np.abs(d).sum(1) + 1).astype(np.float32)
    a = CSRMatrix.from_dense(d)
    fact = bilu(a, k=8, bs=8)
    L, U = fact.to_dense_lu()
    np.testing.assert_allclose(L @ U, d, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("k", [0, 1])
def test_bilu_lu_property_on_tile_pattern(k):
    """(L@U)_ij == a_ij on every kept scalar position (ILU defining property)."""
    a = matgen(64, density=0.06, seed=2)
    fact = bilu(a, k=k, bs=16)
    L, U = fact.to_dense_lu()
    mask = bilu_scalar_pattern(fact)
    diff = np.abs(L @ U - a.to_dense())[mask]
    assert diff.max() < 5e-4, diff.max()


def test_bilu_supersets_scalar_ilu():
    """BILU(k) keeps every scalar ILU(k) position (it is >= as strong)."""
    from repro.core import symbolic_ilu_k

    a = matgen(48, density=0.08, seed=3)
    fact = bilu(a, k=1, bs=8)
    mask = bilu_scalar_pattern(fact)
    pat = symbolic_ilu_k(a, 1)
    for j in range(a.n):
        cols, _ = pat.row(j)
        assert mask[j, cols].all()


def test_bilu_preconditions_cg():
    """BILU-preconditioned CG beats unpreconditioned CG on Poisson."""
    import jax.numpy as jnp

    from repro.core.solvers import cg, csr_to_ell_arrays, make_ell_matvec

    a = poisson_2d(12)
    fact = bilu(a, k=0, bs=16)
    L, U = fact.to_dense_lu()
    import scipy.linalg as sla

    def precond(r):
        y = sla.solve_triangular(L, np.asarray(r, np.float64), lower=True, unit_diagonal=True)
        return jnp.asarray(sla.solve_triangular(U, y, lower=False), jnp.float32)

    cols, vals = csr_to_ell_arrays(a)
    mv = make_ell_matvec(cols, vals, a.n)
    b = np.ones(a.n, np.float32)
    # host preconditioner -> run the solver loop in python mode via maxiter steps
    plain = cg(mv, b, None, tol=1e-6, maxiter=800)
    # jax while_loop can't call back to scipy; do a python-side PCG here
    x = np.zeros(a.n, np.float32)
    r = b.copy()
    z = np.asarray(precond(r))
    p = z.copy()
    it = 0
    bnorm = np.linalg.norm(b)
    while np.linalg.norm(r) > 1e-6 * bnorm and it < 800:
        ap = np.asarray(mv(jnp.asarray(p)))
        rz = r @ z
        alpha = rz / (p @ ap)
        x += alpha * p
        r -= alpha * ap
        z = np.asarray(precond(r))
        beta = (r @ z) / rz
        p = z + beta * p
        it += 1
    assert np.linalg.norm(r) <= 1e-6 * bnorm * 1.1
    assert it < plain.iterations, (it, plain.iterations)
