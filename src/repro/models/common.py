"""Shared model building blocks: norms, RoPE, init, sharding helpers.

Models are plain pytrees-of-dicts + pure functions (no framework dep —
only jax/numpy are installed). Parameters are created by ``init_*`` helpers
and consumed by ``apply``-style functions.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------
# sharding helper: constrain only inside a `logical_mesh(mesh)` context
# --------------------------------------------------------------------------
_ACTIVE_MESH = None  # set by logical_mesh()


import contextlib


@contextlib.contextmanager
def logical_mesh(mesh):
    """Enter a mesh for both pjit lowering and `maybe_shard` constraints."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _ACTIVE_MESH = prev


def maybe_shard(x, *spec):
    """with_sharding_constraint that degrades to a no-op outside a mesh.

    ``spec`` entries may be None, an axis name, or a tuple of axis names;
    axis names missing from the active mesh are dropped.
    """
    mesh = _ACTIVE_MESH
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)

    def _filter(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    entries = [_filter(e) for e in spec]
    # rank-adapt: callers annotate (batch..., feature) — if x has fewer dims
    # (e.g. flattened tokens), drop leading batch entries; pad with None.
    if len(entries) > x.ndim:
        entries = entries[len(entries) - x.ndim :]
    while len(entries) < x.ndim:
        entries.append(None)
    # a mesh axis may appear at most once
    seen = set()
    for i, e in enumerate(entries):
        ax = e if isinstance(e, tuple) else (e,) if e else ()
        if any(a in seen for a in ax):
            entries[i] = None
        seen.update(ax)
    return jax.lax.with_sharding_constraint(x, P(*entries))


def batch_axes():
    """Logical batch sharding axes: ('pod','data') when multi-pod."""
    return ("pod", "data")


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active logical mesh (1 if absent)."""
    mesh = _ACTIVE_MESH
    if mesh is None or mesh.empty or name not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_angles(positions, dim, theta=10000.0):
    """positions (...,) -> cos,sin of shape (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Split-on-demand PRNG key supplier for nested init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (silu(x@Wg) * (x@Wu)) @ Wd."""
    g = jax.nn.silu(x @ w_gate)
    u = x @ w_up
    return (g * u) @ w_down


def cross_entropy_loss(logits, labels, vocab_real: int, ignore_id: int = -100):
    """Token-mean CE in f32; positions with ignore_id are masked; logits over
    padded vocab are masked to -inf above ``vocab_real``."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vocab_real < v:
        pad_mask = jnp.arange(v) >= vocab_real
        logits = jnp.where(pad_mask, -1e30, logits)
    valid = labels != ignore_id
    labels_c = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
