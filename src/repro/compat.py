"""Version-compatibility shims for the JAX API surface we depend on.

The codebase targets the modern spelling (``jax.shard_map`` with
``check_vma=``); older jax releases (<= 0.4.x) only ship
``jax.experimental.shard_map.shard_map`` whose replication-check kwarg is
``check_rep``. Import ``shard_map`` from here instead of from ``jax``.
"""
from __future__ import annotations

import functools

try:  # jax >= 0.6: public top-level API
    from jax import shard_map  # noqa: F401  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f=None, *, check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        if f is None:
            return lambda g: _shard_map(g, **kw)
        return _shard_map(f, **kw)


def make_mesh(devices, axis_names):
    """``jax.sharding.Mesh`` with Auto axis types when the installed jax
    supports them (>= 0.5), plain ``Mesh`` otherwise."""
    from jax.sharding import Mesh

    try:
        from jax.sharding import AxisType
    except ImportError:  # pragma: no cover - depends on installed jax
        return Mesh(devices, axis_names)
    return Mesh(devices, axis_names, axis_types=(AxisType.Auto,) * len(axis_names))


__all__ = ["shard_map", "make_mesh"]
