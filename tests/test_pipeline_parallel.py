"""Pipeline parallelism: GPipe schedule must match the sequential stack
exactly, forward and backward (subprocess with 4 simulated devices)."""
import os
import subprocess
import sys

SCRIPT = os.path.join(os.path.dirname(__file__), "pipeline_check.py")


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    res = subprocess.run(
        [sys.executable, SCRIPT], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert res.returncode == 0, f"stdout:{res.stdout}\nstderr:{res.stderr[-2000:]}"
    assert "PIPELINE OK" in res.stdout
