"""repro.runtime"""
