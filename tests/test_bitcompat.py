"""THE paper guarantee: parallel ILU(k) == sequential ILU(k), bitwise (SVI)."""
import numpy as np
import pytest

from repro.core import matgen, numeric_ilu_ref, poisson_2d, symbolic_ilu_k
from repro.core.api import ilu


@pytest.mark.parametrize("k", [0, 1, 2])
@pytest.mark.parametrize("band_rows", [1, 8, 32])
def test_jax_banded_bitwise_equals_oracle(k, band_rows):
    a = matgen(96, density=0.06, seed=10 * k + band_rows)
    pat = symbolic_ilu_k(a, k)
    want = numeric_ilu_ref(a, pat)
    fact = ilu(a, k, backend="jax", band_rows=band_rows)
    got = fact.vals
    # bitwise equality — not allclose
    assert got.dtype == want.dtype == np.float32
    mism = np.nonzero(got.view(np.int32) != want.view(np.int32))[0]
    assert mism.size == 0, (
        f"{mism.size}/{want.size} entries differ bitwise; first={mism[:5]} "
        f"got={got[mism[:5]]} want={want[mism[:5]]}"
    )


def test_jax_banded_bitwise_structured():
    a = poisson_2d(10)
    pat = symbolic_ilu_k(a, 2)
    want = numeric_ilu_ref(a, pat)
    got = ilu(a, 2, backend="jax", band_rows=16).vals
    np.testing.assert_array_equal(got.view(np.int32), want.view(np.int32))


def test_band_size_invariance():
    """Result must not depend on the band decomposition at all."""
    a = matgen(80, density=0.08, seed=3)
    ref = ilu(a, 1, backend="jax", band_rows=5).vals
    for br in (2, 7, 13, 80):
        got = ilu(a, 1, backend="jax", band_rows=br).vals
        np.testing.assert_array_equal(got.view(np.int32), ref.view(np.int32))
