"""Miniature dry-run in subprocesses: the sharding rules must lower+compile
reduced configs of every family on a (2,4) mesh. (The full 512-device
production dry-run is exercised by `python -m repro.launch.dryrun --all`;
its 40-cell results live in experiments/dryrun/ and EXPERIMENTS.md.)"""
import os
import sys

import pytest

from subproc import run_checked

SCRIPT = os.path.join(os.path.dirname(__file__), "dryrun_small_check.py")

CASES = [
    ("smollm-135m", "train"),        # dense, replicated-attention path
    ("deepseek-v2-lite-16b", "train"),  # MLA + MoE(EP)
    ("qwen2-moe-a2.7b", "decode"),   # MoE expert padding + GQA decode
    ("hymba-1.5b", "decode"),        # hybrid attn+ssm, ring-buffer cache
    ("xlstm-125m", "train"),         # recurrent stack
    ("whisper-tiny", "decode"),      # enc-dec with cross-attention
    ("llava-next-mistral-7b", "prefill"),  # vlm stub merge
    ("starcoder2-15b", "prefill"),   # GQA kv<tp
]


@pytest.mark.parametrize("arch,kind", CASES)
def test_small_dryrun(arch, kind):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"  # don't probe for real TPUs (see test_topilu_multidevice)
    rc, out, err = run_checked([sys.executable, SCRIPT, arch, kind], env=env, timeout=600)
    assert rc == 0, f"stdout:{out}\nstderr:{err[-1500:]}"
    assert "OK" in out
