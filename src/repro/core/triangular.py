"""Level-scheduled sparse triangular solves — applying the preconditioner.

Solving M x = b with M = L·U is the per-iteration cost of the preconditioned
solver (the reason the paper cares about ILU at all). A sparse triangular
solve is sequential row-to-row, but rows whose L-entries all hit previous
*levels* can run together: the classical wavefront/level schedule.

The schedule is host-side planning (like Phase I) and is built **once** per
factorization by :func:`build_triangular_plan` — fully vectorized NumPy, no
per-row Python loops. Besides the row-major ELL factors it precomputes a
*level-major* layout: rows are permuted so that each wavefront occupies one
contiguous, padded slot. The device sweep then needs no row gathers and no
scatters — per level it is one ``x[cols]`` gather, one masked lane-ordered
reduction (:func:`repro.core.bitmath.masked_lane_sum`, bit-deterministic by
construction), and one ``dynamic_update_slice``. On the 16k-row Poisson
benchmark this is ~4x faster per apply than the row-major scatter sweep.

:class:`PrecondApply` caches the plan, the device-resident arrays, and the
jitted fused L-then-U sweep (the Pallas wavefront kernel, with a jnp
fallback) so factorizations reuse one compiled apply across solves,
restarts, and RHS batches.

Also provided: a fixed-sweep Jacobi triangular solve (`jacobi_sweeps>0`) —
the TPU-friendly approximate substitution many production preconditioners
use when wavefronts are too shallow; off by default (not bit-faithful to
the exact solve).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .bitmath import masked_lane_sum
from .planner import COL_SENTINEL, wavefront_schedule_ell
from .sparse import ILUPattern


@dataclasses.dataclass
class TriangularPlan:
    """Padded wavefront schedule + ELL factors for L and U.

    Row-major fields (``l_cols`` … ``u_levels``) describe the classical
    schedule; the ``*_lm`` fields are the level-major execution layout:
    row ``l_levels[l, i]`` lives at slot ``l * maxr + i`` of the sweep
    vector, column indices are pre-remapped into slot space (padding points
    at the scratch slot ``n_slots``), and the right-hand side is fetched via
    one precomputed gather.
    """

    n: int
    # unit-lower factor rows (strictly-below-diagonal entries)
    l_cols: np.ndarray  # (n, WL) int32, sentinel-padded
    l_vals: np.ndarray  # (n, WL) f32
    # upper factor rows (above-diagonal entries) + diagonal
    u_cols: np.ndarray  # (n, WU) int32
    u_vals: np.ndarray  # (n, WU) f32
    diag: np.ndarray  # (n,) f32
    l_levels: np.ndarray  # (nl_levels, max_rows) int32, n-padded
    u_levels: np.ndarray  # (nu_levels, max_rows) int32, n-padded

    # --- level-major execution layout (see class docstring) ---------------
    nl_slots: int  # nl_levels * l_max_rows
    nu_slots: int
    l_cols_lm: np.ndarray  # (nl_levels, max_rows, WL) int32, slot-space, nl_slots-padded
    l_vals_lm: np.ndarray  # (nl_levels, max_rows, WL) f32
    l_rhs_idx: np.ndarray  # (nl_levels, max_rows) int32 into b_ext (padding -> n)
    u_cols_lm: np.ndarray  # (nu_levels, max_rows, WU) int32, slot-space, nu_slots-padded
    u_vals_lm: np.ndarray  # (nu_levels, max_rows, WU) f32
    u_diag_lm: np.ndarray  # (nu_levels, max_rows) f32, 1-padded
    u_rhs_idx: np.ndarray  # (nu_levels, max_rows) int32 into the L sweep vector
    u_out_perm: np.ndarray  # (n,) int32: x[j] = x_u_sweep[u_out_perm[j]]

    @property
    def depth(self) -> int:
        return self.l_levels.shape[0] + self.u_levels.shape[0]

    def device_arrays(self) -> dict:
        """The jnp arrays the fused wavefront sweep consumes, in call order."""
        return {
            "l_cols": jnp.asarray(self.l_cols_lm),
            "l_vals": jnp.asarray(self.l_vals_lm),
            "l_rhs_idx": jnp.asarray(self.l_rhs_idx),
            "u_cols": jnp.asarray(self.u_cols_lm),
            "u_vals": jnp.asarray(self.u_vals_lm),
            "u_diag": jnp.asarray(self.u_diag_lm),
            "u_rhs_idx": jnp.asarray(self.u_rhs_idx),
            "out_perm": jnp.asarray(self.u_out_perm),
        }


def _split_lu_ell(pattern: ILUPattern, vals: np.ndarray):
    """Vectorized CSR -> (L, U, diag) sentinel-padded ELL split."""
    n = pattern.n
    nnz = pattern.nnz
    indptr = pattern.indptr
    rowlen = np.diff(indptr)
    row_of = np.repeat(np.arange(n), rowlen)
    pos = np.arange(nnz, dtype=np.int64) - indptr[row_of]
    dpos = pattern.diag_ptr[row_of].astype(np.int64)
    lmask = pos < dpos
    umask = pos > dpos
    diag = vals[indptr[:-1] + pattern.diag_ptr].astype(np.float32)
    WL = max(int(pattern.diag_ptr.max(initial=0)), 1)
    WU = max(int((rowlen - pattern.diag_ptr - 1).max(initial=0)), 1)
    l_cols = np.full((n, WL), COL_SENTINEL, np.int32)
    l_vals = np.zeros((n, WL), np.float32)
    u_cols = np.full((n, WU), COL_SENTINEL, np.int32)
    u_vals = np.zeros((n, WU), np.float32)
    l_cols[row_of[lmask], pos[lmask]] = pattern.indices[lmask]
    l_vals[row_of[lmask], pos[lmask]] = vals[lmask]
    upos = pos - dpos - 1
    u_cols[row_of[umask], upos[umask]] = pattern.indices[umask]
    u_vals[row_of[umask], upos[umask]] = vals[umask]
    return l_cols, l_vals, u_cols, u_vals, diag


def _level_major(levels: np.ndarray, cols: np.ndarray, vals: np.ndarray, n: int):
    """Gather row-major ELL rows into the (nlev, maxr, W) level-major layout.
    Padding rows get all-sentinel columns and zero values."""
    pad = levels >= n
    rows_c = np.minimum(levels, max(n - 1, 0))
    c = np.where(pad[:, :, None], COL_SENTINEL, cols[rows_c]).astype(np.int32)
    v = np.where(pad[:, :, None], 0.0, vals[rows_c]).astype(np.float32)
    return c, v


def _slot_of_row(levels: np.ndarray, n: int) -> np.ndarray:
    """Map row id -> its slot index ``level * maxr + rank`` in the sweep vector."""
    slot = np.zeros(n, dtype=np.int64)
    flat = levels.reshape(-1).astype(np.int64)
    valid = flat < n
    slot[flat[valid]] = np.nonzero(valid)[0]
    return slot


def build_triangular_plan(pattern: ILUPattern, vals: np.ndarray) -> TriangularPlan:
    n = pattern.n
    l_cols, l_vals, u_cols, u_vals, diag = _split_lu_ell(pattern, vals)
    # the shared vectorized Kahn scheduler (repro.core.planner) builds both
    # sweeps' wavefronts — same primitive as the factorization plan
    l_levels = wavefront_schedule_ell(l_cols, n)
    # U solve runs bottom-up; dependencies are the above-diagonal columns
    u_levels = wavefront_schedule_ell(u_cols, n)

    # --- level-major execution layout ------------------------------------
    nl_slots = int(l_levels.size)
    nu_slots = int(u_levels.size)
    slot_l = _slot_of_row(l_levels, n)
    slot_u = _slot_of_row(u_levels, n)

    lc, lv = _level_major(l_levels, l_cols, l_vals, n)
    # remap dependency columns (row ids) into L slot space; sentinel -> scratch
    lc_m = np.where(
        lc < COL_SENTINEL, slot_l[np.minimum(lc, max(n - 1, 0))], nl_slots
    ).astype(np.int32)
    l_rhs_idx = l_levels.astype(np.int32)  # padding slots already hold n (the zero slot)

    uc, uv = _level_major(u_levels, u_cols, u_vals, n)
    uc_m = np.where(
        uc < COL_SENTINEL, slot_u[np.minimum(uc, max(n - 1, 0))], nu_slots
    ).astype(np.int32)
    pad_u = u_levels >= n
    rows_u = np.minimum(u_levels, max(n - 1, 0))
    u_diag_lm = np.where(pad_u, 1.0, diag[rows_u]).astype(np.float32)
    # the U right-hand side is the L sweep output, gathered from L slot space
    u_rhs_idx = np.where(pad_u, nl_slots, slot_l[rows_u]).astype(np.int32)
    u_out_perm = slot_u.astype(np.int32)

    return TriangularPlan(
        n=n, l_cols=l_cols, l_vals=l_vals, u_cols=u_cols, u_vals=u_vals,
        diag=diag, l_levels=l_levels, u_levels=u_levels,
        nl_slots=nl_slots, nu_slots=nu_slots,
        l_cols_lm=lc_m, l_vals_lm=lv, l_rhs_idx=l_rhs_idx,
        u_cols_lm=uc_m, u_vals_lm=uv, u_diag_lm=u_diag_lm,
        u_rhs_idx=u_rhs_idx, u_out_perm=u_out_perm,
    )


class PrecondApply:
    """Cached, device-resident application of M^{-1} = (LU)^{-1}.

    Builds the triangular plan once (vectorized host planning), keeps the
    level-major arrays on device, and exposes

    * ``apply(b)`` / ``__call__`` — jitted fused L-then-U wavefront sweep
      for a single right-hand side, safe to call inside outer jitted code
      (it traces inline, so a whole Krylov solve stays one dispatch);
    * ``batched(B)`` — the same sweep ``vmap``-ped over a batch of RHS.

    ``use_pallas=True`` routes through the fused Pallas wavefront kernel
    (`repro.kernels.ops.tri_solve_wavefront`); the jnp path is the
    bit-identical reference (both reduce via ``masked_lane_sum``).
    """

    def __init__(self, pattern: ILUPattern, vals: np.ndarray,
                 use_pallas: bool = True, plan: Optional[TriangularPlan] = None):
        self.plan = plan if plan is not None else build_triangular_plan(pattern, vals)
        self.n = self.plan.n
        self._dev = self.plan.device_arrays()
        if use_pallas:
            from repro.kernels import ops  # deferred: keep core importable alone

            def _raw(b):
                return ops.tri_solve_wavefront(
                    self._dev["l_cols"], self._dev["l_vals"], self._dev["l_rhs_idx"],
                    self._dev["u_cols"], self._dev["u_vals"], self._dev["u_diag"],
                    self._dev["u_rhs_idx"], self._dev["out_perm"], b,
                )
        else:
            def _raw(b):
                return wavefront_sweeps_jnp(
                    self._dev["l_cols"], self._dev["l_vals"], self._dev["l_rhs_idx"],
                    self._dev["u_cols"], self._dev["u_vals"], self._dev["u_diag"],
                    self._dev["u_rhs_idx"], self._dev["out_perm"], b,
                )
        self._apply = jax.jit(lambda b: _raw(b.astype(jnp.float32)))
        self._batched = jax.jit(jax.vmap(self._apply))

    def __call__(self, b):
        return self._apply(b)

    apply = __call__

    def batched(self, bs):
        """Apply M^{-1} to a (batch, n) stack of right-hand sides."""
        return self._batched(bs)


def wavefront_sweeps_jnp(l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag,
                         u_rhs_idx, out_perm, b):
    """Fused L-then-U level-major wavefront sweep (pure jnp reference).

    The Pallas kernel (`repro.kernels.tri_solve_wavefront`) runs this exact
    computation on values read from refs; both are bit-identical because all
    reductions go through ``masked_lane_sum``.
    """
    nl_lev, maxr_l, _ = l_cols.shape
    nu_lev, maxr_u, _ = u_cols.shape
    nl_slots = nl_lev * maxr_l
    nu_slots = nu_lev * maxr_u
    b = b.astype(jnp.float32)
    b_ext = jnp.concatenate([b, jnp.zeros((1,), jnp.float32)])
    l_rhs = b_ext[l_rhs_idx]  # (nl_lev, maxr_l)

    def l_step(carry, inp):
        x, start = carry
        c, v, r = inp
        gathered = x[c]  # padding -> scratch slot (0)
        acc = masked_lane_sum(c, v, gathered, nl_slots)
        x = jax.lax.dynamic_update_slice(x, r - acc, (start,))
        return (x, start + maxr_l), None

    x_l = jnp.zeros(nl_slots + 1, jnp.float32)
    (x_l, _), _ = jax.lax.scan(l_step, (x_l, 0), (l_cols, l_vals, l_rhs))

    u_rhs = x_l[u_rhs_idx]  # (nu_lev, maxr_u) — y gathered from L slot space

    def u_step(carry, inp):
        x, start = carry
        c, v, r, d = inp
        gathered = x[c]
        acc = masked_lane_sum(c, v, gathered, nu_slots)
        x = jax.lax.dynamic_update_slice(x, (r - acc) / d, (start,))
        return (x, start + maxr_u), None

    x_u = jnp.zeros(nu_slots + 1, jnp.float32)
    (x_u, _), _ = jax.lax.scan(u_step, (x_u, 0), (u_cols, u_vals, u_rhs, u_diag))
    return x_u[out_perm]


def make_triangular_solver(pattern: ILUPattern, vals: np.ndarray,
                           use_pallas: bool = False) -> Callable:
    """Returns jitted ``solve(b) -> x`` applying (LU)^{-1} by substitution.

    Kept as the sequential-reference entry point (exact substitution order);
    prefer :class:`PrecondApply` when the solver will be applied repeatedly —
    it is the same computation with the plan and compilation cached.
    """
    return PrecondApply(pattern, vals, use_pallas=use_pallas)


def make_jacobi_triangular_solver(pattern: ILUPattern, vals: np.ndarray, sweeps: int = 8) -> Callable:
    """Approximate triangular solve by Jacobi iteration (x <- D^{-1}(b - R x)).

    Converges because triangular Jacobi iteration is nilpotent; ``sweeps``
    bounds the wavefront depth it can resolve. TPU-friendly: no wavefront
    schedule, every sweep is one dense-vector pass.
    """
    plan = build_triangular_plan(pattern, vals)
    n = plan.n
    l_cols = jnp.asarray(plan.l_cols)
    l_vals = jnp.asarray(plan.l_vals)
    u_cols = jnp.asarray(plan.u_cols)
    u_vals = jnp.asarray(plan.u_vals)
    diag = jnp.asarray(plan.diag)

    def _iterate(cols, vals_m, rhs, divide):
        def body(_, x):
            xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
            gathered = xg[jnp.minimum(cols, n)]
            acc = masked_lane_sum(cols, vals_m, gathered, COL_SENTINEL)
            new = rhs - acc
            if divide:
                new = new / diag
            return new
        return jax.lax.fori_loop(0, sweeps, body, jnp.zeros_like(rhs))

    @jax.jit
    def solve(b):
        b = b.astype(jnp.float32)
        y = _iterate(l_cols, l_vals, b, divide=False)
        x = _iterate(u_cols, u_vals, y, divide=True)
        return x

    return solve
