"""TOP-ILU — task-oriented parallel ILU(k) over a device mesh (paper §IV).

Maps the paper's distributed-memory algorithm onto JAX SPMD, re-emitted
(PR 2) over the *band superstep schedule* from the planner:

* bands → round-robin ownership over the mesh axis (static load balancing,
  §IV-D; device ``d`` owns bands ``{b : b ≡ d (mod D)}``),
* the frontier loop → ``lax.fori_loop`` over band-dependency *wavefronts*
  inside one jitted step: bands whose dependencies are satisfied factor
  concurrently (each device vmaps over the members it owns), pulling
  inter-band pivot rows from the replicated finalized values,
* the Fig-4 ring pipeline → ONE collective per superstep — an XLA ring
  ``all_gather`` of the bands each device finished (``broadcast='psum'``
  is accepted as the historical alias for this fast path) or an explicit
  ``ppermute`` directed ring (``broadcast='ring'``) — merging every band
  finished in the superstep, instead of one broadcast per band,
* dynamic load balancing (master/worker) → intentionally absent from the
  SPMD fast path; the paper itself measures static LB as strictly better
  (Table I). It survives as the fault-tolerance reassignment path in
  ``repro.runtime``.

Structure (column indices, destination-lane maps, the schedule itself) is
static planning output and never communicated: 4 bytes/entry on the wire
instead of the paper's 8 — see §V-E and DESIGN.md §3. Values are held
replicated during factorization (n_pad×W f32 per device); sharding the
value storage over the mesh is an open ROADMAP item.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from .planner import NumericPlan, make_plan
from .numeric_jax import make_superstep_factorizer, plan_device_arrays
from .sparse import CSRMatrix, ILUPattern

AXIS = "band"

_ARG_ORDER = ("vals", "sched", "piv_rows", "piv_dlane", "piv_dst", "n_piv")


def _values_to_csr_order(plan: NumericPlan, pattern: ILUPattern, vals_rm: np.ndarray) -> np.ndarray:
    """Padded row-major values -> CSR-aligned flat values (one gather)."""
    vals_rm = np.asarray(vals_rm)
    rowlen = np.diff(pattern.indptr).astype(np.int64)
    row_of = np.repeat(np.arange(pattern.n, dtype=np.int64), rowlen)
    lane = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[row_of]
    return vals_rm[row_of, lane].astype(np.float32)


def topilu_numeric(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int = 32,
    mesh: Optional[Mesh] = None,
    broadcast: str = "psum",
) -> np.ndarray:
    """Parallel numeric factorization. Returns CSR-aligned values.

    With ``mesh=None`` uses every available device on a 1-D mesh; pass an
    explicit 1-D mesh to control the device set.
    """
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (AXIS,))
    d = mesh.devices.size
    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=d)
    arrays = plan_device_arrays(plan)
    fac = make_superstep_factorizer(plan, axis_name=AXIS if d > 1 else None, broadcast=broadcast)
    args = tuple(arrays[k] for k in _ARG_ORDER)

    if d == 1:
        vals = jax.jit(fac)(*args)
        return _values_to_csr_order(plan, pattern, np.asarray(vals))

    # every input is replicated; device identity comes from the axis index,
    # and the superstep collective merges each wave of finished bands
    smapped = shard_map(
        fac,
        mesh=mesh,
        in_specs=(P(),) * len(args),
        out_specs=P(),
        check_vma=False,
    )
    vals = jax.jit(smapped)(*args)
    return _values_to_csr_order(plan, pattern, np.asarray(vals))


def lower_topilu(
    a: CSRMatrix,
    pattern: ILUPattern,
    band_rows: int,
    mesh: Mesh,
    broadcast: str = "psum",
):
    """AOT-lower the parallel factorization (for dry-runs / HLO inspection)."""
    d = mesh.devices.size
    plan = make_plan(a, pattern, band_rows=band_rows, n_devices=d)
    arrays = plan_device_arrays(plan)
    fac = make_superstep_factorizer(plan, axis_name=AXIS, broadcast=broadcast)
    smapped = shard_map(
        fac,
        mesh=mesh,
        in_specs=(P(),) * len(_ARG_ORDER),
        out_specs=P(),
        check_vma=False,
    )
    args = [
        jax.ShapeDtypeStruct(arrays[k].shape, arrays[k].dtype) for k in _ARG_ORDER
    ]
    return jax.jit(smapped).lower(*args), plan
