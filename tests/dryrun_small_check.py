"""Subprocess body: miniature dry-run — reduced configs, (2,4) host mesh.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python tests/dryrun_small_check.py <arch> <kind>
kind: train | decode | prefill
Exits 0 on successful lower+compile with finite cost analysis.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    arch, kind = sys.argv[1], sys.argv[2]
    import dataclasses
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import ShardingRules
    from repro.models import model as M
    from repro.models.common import logical_mesh
    from repro.optim import adamw
    from repro.train.step import make_prefill_step, make_serve_step, make_train_step

    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, q_chunk=16, kv_chunk=16)
    mesh = make_host_mesh(2, 4)
    rules = ShardingRules(cfg, mesh)
    B, S = 4, 64

    params_shapes = jax.eval_shape(lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    p_shard = rules.params_shardings(params_shapes)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_patches, cfg.d_model), cfg.act_dtype
        )
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), cfg.act_dtype)
    b_shard = rules.batch_shardings(batch)

    with logical_mesh(mesh):
        if kind == "train":
            opt_shapes = jax.eval_shape(adamw.init, params_shapes)
            o_shard = rules.opt_shardings(opt_shapes, zero1=True)
            step = make_train_step(cfg, adamw.AdamWConfig())
            lowered = jax.jit(
                step, in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None), donate_argnums=(0, 1),
            ).lower(params_shapes, opt_shapes, batch)
        elif kind == "prefill":
            lowered = jax.jit(
                make_prefill_step(cfg), in_shardings=(p_shard, b_shard)
            ).lower(params_shapes, batch)
        else:
            cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, B, 32))
            c_shard = rules.cache_shardings(cache_shapes, B)
            step = make_serve_step(cfg)
            in_sh = [p_shard, c_shard, b_shard["tokens"]]
            args = [params_shapes, cache_shapes, jax.ShapeDtypeStruct((B, 1), jnp.int32)]
            if cfg.family == "audio":
                in_sh.append(b_shard["frames"])
                args.append(batch["frames"])
            lowered = jax.jit(
                step, in_shardings=tuple(in_sh), out_shardings=(None, None, c_shard),
            ).lower(*args)
        compiled = lowered.compile()
    from repro.roofline.analysis import cost_analysis_dict

    ca = cost_analysis_dict(compiled)
    assert ca.get("flops", 0) > 0, ca
    print(f"OK {arch} {kind}: flops/dev={ca['flops']:.3g}")


if __name__ == "__main__":
    main()
