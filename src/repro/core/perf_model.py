"""Calibrated cluster performance model for TOP-ILU (paper §V).

The container has one CPU core, so the paper's 60–100-node speedup tables
cannot be *measured*; they are reproduced with a model that is calibrated
against real single-core measurements of this implementation and uses the
paper's own communication accounting (§V-E):

* compute: measured sequential Phase-I/Phase-II times, divided by P under
  static round-robin band ownership (§IV-D),
* communication: every node receives every finished band => per-node
  traffic is ``8 * n_f`` bytes (column + value per final entry, the paper's
  figure); the Fig-4 ring pipeline achieves aggregate bandwidth, so the
  per-node wire time is ``8 n_f / BW`` and overlaps compute,
* latency: one ring hop per band per edge-node; Grid runs (Fig 9) add
  ``inter_latency`` on the (clusters) edge links, paid once per band per
  edge because forwarding pipelines behind the slowest link,
* PILU(1): Phase I parallelizes with zero communication (§IV-F).

This mirrors the structure of the paper's own analysis (§V-E: "the
communication overhead is about 8 n_f B per node"; "to increase bandwidth
is one solution").
"""
from __future__ import annotations

import dataclasses
from typing import Dict

GIG_E = 125e6  # 1 Gbit/s in bytes/s
INFINIBAND = 1.25e9  # 10 Gbit/s
INTRA_LAT = 50e-6  # typical cluster MPI latency (paper: "a few us")


@dataclasses.dataclass
class ClusterSpec:
    bandwidth: float = GIG_E  # bytes/s per link
    latency: float = INTRA_LAT  # per message, intra-cluster
    n_clusters: int = 1
    inter_latency: float = 0.0  # per message across clusters (Fig 9)


@dataclasses.dataclass
class WorkloadStats:
    n: int
    n_f: int  # final entries after symbolic factorization
    t_symbolic: float  # measured sequential seconds (this implementation)
    t_numeric: float
    n_bands: int
    k: int


def predict_times(w: WorkloadStats, p: int, spec: ClusterSpec,
                  dynamic_lb: bool = False) -> Dict[str, float]:
    """Predict (t_sym, t_num, speedup) for P nodes."""
    # ---- Phase I ----
    if w.k == 1:
        t_sym = w.t_symbolic / p  # PILU(1): embarrassingly parallel, no comm
    else:
        sym_comm = 8.0 * w.n_f / spec.bandwidth  # band pipeline, same traffic
        t_sym = max(w.t_symbolic / p, sym_comm) if p > 1 else w.t_symbolic
    # ---- Phase II ----
    t_comp = w.t_numeric / p
    bytes_per_node = 8.0 * w.n_f  # column+value per final entry (§V-E)
    if dynamic_lb:
        # master/worker broadcasts every partial reduction: a band is
        # re-sent once per frontier step it is still unfinished — ~P/2
        # extra copies per band on average for P in-flight tasks.
        bytes_per_node *= 1.0 + p / 2.0
    t_comm = bytes_per_node / spec.bandwidth if p > 1 else 0.0
    # Latency: the frontier's critical path is one ring hop per band (the
    # next band's owner is the ring successor under round-robin ownership);
    # the full (D-1)-hop broadcast of each band pipelines behind it (Fig 4).
    # A band pays the inter-cluster latency only when its successor sits
    # across a cluster boundary: n_clusters boundary hops per ring
    # revolution => fraction n_clusters/P of bands.
    per_band_lat = spec.latency
    if p > 1 and spec.n_clusters > 1:
        per_band_lat += spec.inter_latency * spec.n_clusters / p
    t_lat = w.n_bands * per_band_lat if p > 1 else 0.0
    # latency partially hides behind the per-band computation (Alg 2)
    hidden = min(t_lat * 0.5, t_comp * 0.5)
    t_num = max(t_comp, t_comm) + t_lat - hidden
    t_total = t_sym + t_num
    t_seq = w.t_symbolic + w.t_numeric
    return {
        "t_symbolic": t_sym,
        "t_numeric": t_num,
        "t_total": t_total,
        "speedup": t_seq / t_total,
        "comm_bound": t_comm > t_comp,
    }


def speedup_curve(w: WorkloadStats, ps, spec: ClusterSpec, dynamic_lb=False):
    return {p: predict_times(w, p, spec, dynamic_lb)["speedup"] for p in ps}


# --- modern-fabric projection: TOP-ILU at pod scale (1000+ chips) ----------
TPU_ICI = 50e9  # bytes/s per link
TPU_DCN = 6.25e9  # ~50 Gbit/s per host across pods
ICI_HOP_LAT = 1e-6


def tpu_scaling_projection(w: WorkloadStats, chips_list, pods: int = 1):
    """Project TOP-ILU (psum-broadcast variant: 2(D-1)/D ring volume, values
    only = 4 B/entry) onto TPU pods. Cross-pod hops ride DCN — the 2026
    version of the paper's Grid 'edge node' study (§V-F)."""
    out = {}
    for chips in chips_list:
        spec = ClusterSpec(bandwidth=TPU_ICI, latency=ICI_HOP_LAT,
                           n_clusters=pods,
                           inter_latency=50e-6 if pods > 1 else 0.0)
        # psum ring: 2(D-1)/D x and structure never transmitted (4B vs 8B)
        eff = dataclasses.replace(spec, bandwidth=spec.bandwidth * (8.0 / 4.0) / 2.0)
        out[chips] = predict_times(w, chips, eff)["speedup"]
    return out
