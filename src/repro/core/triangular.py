"""Level-scheduled sparse triangular solves — applying the preconditioner.

Solving M x = b with M = L·U is the per-iteration cost of the preconditioned
solver (the reason the paper cares about ILU at all). A sparse triangular
solve is sequential row-to-row, but rows whose L-entries all hit previous
*levels* can run together: the classical wavefront/level schedule.

The schedule is host-side planning (like Phase I) and is built **once** per
factorization by :func:`build_triangular_plan` — fully vectorized NumPy, no
per-row Python loops. Besides the row-major ELL factors it precomputes a
*level-major* layout: rows are permuted so that each wavefront occupies one
contiguous, padded slot. The device sweep then needs no row gathers and no
scatters — per level it is one ``x[cols]`` gather, one masked lane-ordered
reduction (:func:`repro.core.bitmath.masked_lane_sum`, bit-deterministic by
construction), and one ``dynamic_update_slice``. On the 16k-row Poisson
benchmark this is ~4x faster per apply than the row-major scatter sweep.

:class:`PrecondApply` caches the plan, the device-resident arrays, and the
jitted fused L-then-U sweep (the Pallas wavefront kernel, with a jnp
fallback) so factorizations reuse one compiled apply across solves,
restarts, and RHS batches.

Also provided: a fixed-sweep Jacobi triangular solve (`jacobi_sweeps>0`) —
the TPU-friendly approximate substitution many production preconditioners
use when wavefronts are too shallow; off by default (not bit-faithful to
the exact solve).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .bitmath import masked_lane_sum
from .planner import (
    COL_SENTINEL,
    SweepEpochSchedule,
    ragged_group,
    sweep_epoch_schedule,
    wavefront_schedule_ell,
)
from .sparse import ILUPattern


@dataclasses.dataclass
class TriangularPlan:
    """Padded wavefront schedule + ELL factors for L and U.

    Row-major fields (``l_cols`` … ``u_levels``) describe the classical
    schedule; the ``*_lm`` fields are the level-major execution layout:
    row ``l_levels[l, i]`` lives at slot ``l * maxr + i`` of the sweep
    vector, column indices are pre-remapped into slot space (padding points
    at the scratch slot ``n_slots``), and the right-hand side is fetched via
    one precomputed gather.
    """

    n: int
    # unit-lower factor rows (strictly-below-diagonal entries)
    l_cols: np.ndarray  # (n, WL) int32, sentinel-padded
    l_vals: np.ndarray  # (n, WL) f32
    # upper factor rows (above-diagonal entries) + diagonal
    u_cols: np.ndarray  # (n, WU) int32
    u_vals: np.ndarray  # (n, WU) f32
    diag: np.ndarray  # (n,) f32
    l_levels: np.ndarray  # (nl_levels, max_rows) int32, n-padded
    u_levels: np.ndarray  # (nu_levels, max_rows) int32, n-padded

    # --- level-major execution layout (see class docstring) ---------------
    nl_slots: int  # nl_levels * l_max_rows
    nu_slots: int
    l_cols_lm: np.ndarray  # (nl_levels, max_rows, WL) int32, slot-space, nl_slots-padded
    l_vals_lm: np.ndarray  # (nl_levels, max_rows, WL) f32
    l_rhs_idx: np.ndarray  # (nl_levels, max_rows) int32 into b_ext (padding -> n)
    u_cols_lm: np.ndarray  # (nu_levels, max_rows, WU) int32, slot-space, nu_slots-padded
    u_vals_lm: np.ndarray  # (nu_levels, max_rows, WU) f32
    u_diag_lm: np.ndarray  # (nu_levels, max_rows) f32, 1-padded
    u_rhs_idx: np.ndarray  # (nu_levels, max_rows) int32 into the L sweep vector
    u_out_perm: np.ndarray  # (n,) int32: x[j] = x_u_sweep[u_out_perm[j]]

    @property
    def depth(self) -> int:
        return self.l_levels.shape[0] + self.u_levels.shape[0]

    def device_arrays(self) -> dict:
        """The jnp arrays the fused wavefront sweep consumes, in call order."""
        return {
            "l_cols": jnp.asarray(self.l_cols_lm),
            "l_vals": jnp.asarray(self.l_vals_lm),
            "l_rhs_idx": jnp.asarray(self.l_rhs_idx),
            "u_cols": jnp.asarray(self.u_cols_lm),
            "u_vals": jnp.asarray(self.u_vals_lm),
            "u_diag": jnp.asarray(self.u_diag_lm),
            "u_rhs_idx": jnp.asarray(self.u_rhs_idx),
            "out_perm": jnp.asarray(self.u_out_perm),
        }


def _split_lu_ell(pattern: ILUPattern, vals: np.ndarray):
    """Vectorized CSR -> (L, U, diag) sentinel-padded ELL split."""
    n = pattern.n
    nnz = pattern.nnz
    indptr = pattern.indptr
    rowlen = np.diff(indptr)
    row_of = np.repeat(np.arange(n), rowlen)
    pos = np.arange(nnz, dtype=np.int64) - indptr[row_of]
    dpos = pattern.diag_ptr[row_of].astype(np.int64)
    lmask = pos < dpos
    umask = pos > dpos
    diag = vals[indptr[:-1] + pattern.diag_ptr].astype(np.float32)
    WL = max(int(pattern.diag_ptr.max(initial=0)), 1)
    WU = max(int((rowlen - pattern.diag_ptr - 1).max(initial=0)), 1)
    l_cols = np.full((n, WL), COL_SENTINEL, np.int32)
    l_vals = np.zeros((n, WL), np.float32)
    u_cols = np.full((n, WU), COL_SENTINEL, np.int32)
    u_vals = np.zeros((n, WU), np.float32)
    l_cols[row_of[lmask], pos[lmask]] = pattern.indices[lmask]
    l_vals[row_of[lmask], pos[lmask]] = vals[lmask]
    upos = pos - dpos - 1
    u_cols[row_of[umask], upos[umask]] = pattern.indices[umask]
    u_vals[row_of[umask], upos[umask]] = vals[umask]
    return l_cols, l_vals, u_cols, u_vals, diag


def _level_major(levels: np.ndarray, cols: np.ndarray, vals: np.ndarray, n: int):
    """Gather row-major ELL rows into the (nlev, maxr, W) level-major layout.
    Padding rows get all-sentinel columns and zero values."""
    pad = levels >= n
    rows_c = np.minimum(levels, max(n - 1, 0))
    c = np.where(pad[:, :, None], COL_SENTINEL, cols[rows_c]).astype(np.int32)
    v = np.where(pad[:, :, None], 0.0, vals[rows_c]).astype(np.float32)
    return c, v


def _slot_of_row(levels: np.ndarray, n: int) -> np.ndarray:
    """Map row id -> its slot index ``level * maxr + rank`` in the sweep vector."""
    slot = np.zeros(n, dtype=np.int64)
    flat = levels.reshape(-1).astype(np.int64)
    valid = flat < n
    slot[flat[valid]] = np.nonzero(valid)[0]
    return slot


def build_triangular_plan(pattern: ILUPattern, vals: np.ndarray) -> TriangularPlan:
    n = pattern.n
    l_cols, l_vals, u_cols, u_vals, diag = _split_lu_ell(pattern, vals)
    # the shared vectorized Kahn scheduler (repro.core.planner) builds both
    # sweeps' wavefronts — same primitive as the factorization plan
    l_levels = wavefront_schedule_ell(l_cols, n)
    # U solve runs bottom-up; dependencies are the above-diagonal columns
    u_levels = wavefront_schedule_ell(u_cols, n)

    # --- level-major execution layout ------------------------------------
    nl_slots = int(l_levels.size)
    nu_slots = int(u_levels.size)
    slot_l = _slot_of_row(l_levels, n)
    slot_u = _slot_of_row(u_levels, n)

    lc, lv = _level_major(l_levels, l_cols, l_vals, n)
    # remap dependency columns (row ids) into L slot space; sentinel -> scratch
    lc_m = np.where(
        lc < COL_SENTINEL, slot_l[np.minimum(lc, max(n - 1, 0))], nl_slots
    ).astype(np.int32)
    l_rhs_idx = l_levels.astype(np.int32)  # padding slots already hold n (the zero slot)

    uc, uv = _level_major(u_levels, u_cols, u_vals, n)
    uc_m = np.where(
        uc < COL_SENTINEL, slot_u[np.minimum(uc, max(n - 1, 0))], nu_slots
    ).astype(np.int32)
    pad_u = u_levels >= n
    rows_u = np.minimum(u_levels, max(n - 1, 0))
    u_diag_lm = np.where(pad_u, 1.0, diag[rows_u]).astype(np.float32)
    # the U right-hand side is the L sweep output, gathered from L slot space
    u_rhs_idx = np.where(pad_u, nl_slots, slot_l[rows_u]).astype(np.int32)
    u_out_perm = slot_u.astype(np.int32)

    return TriangularPlan(
        n=n, l_cols=l_cols, l_vals=l_vals, u_cols=u_cols, u_vals=u_vals,
        diag=diag, l_levels=l_levels, u_levels=u_levels,
        nl_slots=nl_slots, nu_slots=nu_slots,
        l_cols_lm=lc_m, l_vals_lm=lv, l_rhs_idx=l_rhs_idx,
        u_cols_lm=uc_m, u_vals_lm=uv, u_diag_lm=u_diag_lm,
        u_rhs_idx=u_rhs_idx, u_out_perm=u_out_perm,
    )


def rebind_triangular_values(plan: TriangularPlan, pattern: ILUPattern, vals: np.ndarray):
    """Recompute a plan's level-major *value* arrays for new factor values
    on the same structure (the refactorize→serve path).

    The wavefront schedule, the slot maps, and every column/index array are
    pure structure — only ``l_vals_lm`` / ``u_vals_lm`` / ``u_diag_lm``
    depend on the numbers. This redoes just the value scatter (vectorized
    NumPy, no scheduling, no compilation), so a serving cache can rebind a
    background refactorization onto an already-compiled sweep whose value
    operands ride as runtime arguments. Returns
    ``(l_vals_lm, u_vals_lm, u_diag_lm)`` aligned with ``plan``.
    """
    n = plan.n
    l_cols, l_vals, u_cols, u_vals, diag = _split_lu_ell(pattern, vals)
    if l_cols.shape != plan.l_cols.shape or u_cols.shape != plan.u_cols.shape:
        raise ValueError(
            "rebind_triangular_values: pattern structure does not match the "
            f"plan (L {l_cols.shape} vs {plan.l_cols.shape}, "
            f"U {u_cols.shape} vs {plan.u_cols.shape})")
    _, lv = _level_major(plan.l_levels, l_cols, l_vals, n)
    _, uv = _level_major(plan.u_levels, u_cols, u_vals, n)
    pad_u = plan.u_levels >= n
    rows_u = np.minimum(plan.u_levels, max(n - 1, 0))
    u_diag_lm = np.where(pad_u, 1.0, diag[rows_u]).astype(np.float32)
    return lv, uv, u_diag_lm


class PrecondApply:
    """Cached, device-resident application of M^{-1} = (LU)^{-1}.

    Builds the triangular plan once (vectorized host planning), keeps the
    level-major arrays on device, and exposes

    * ``apply(b)`` / ``__call__`` — jitted fused L-then-U wavefront sweep
      for a single right-hand side, safe to call inside outer jitted code
      (it traces inline, so a whole Krylov solve stays one dispatch);
    * ``batched(B)`` — the same sweep ``vmap``-ped over a batch of RHS.

    ``use_pallas=True`` routes through the fused Pallas wavefront kernel
    (`repro.kernels.ops.tri_solve_wavefront`); the jnp path is the
    bit-identical reference (both reduce via ``masked_lane_sum``).
    """

    def __init__(self, pattern: ILUPattern, vals: np.ndarray,
                 use_pallas: bool = True, plan: Optional[TriangularPlan] = None):
        self.plan = plan if plan is not None else build_triangular_plan(pattern, vals)
        self.n = self.plan.n
        self._dev = self.plan.device_arrays()
        if use_pallas:
            from repro.kernels import ops  # deferred: keep core importable alone

            def _raw(b):
                return ops.tri_solve_wavefront(
                    self._dev["l_cols"], self._dev["l_vals"], self._dev["l_rhs_idx"],
                    self._dev["u_cols"], self._dev["u_vals"], self._dev["u_diag"],
                    self._dev["u_rhs_idx"], self._dev["out_perm"], b,
                )
        else:
            def _raw(b):
                return wavefront_sweeps_jnp(
                    self._dev["l_cols"], self._dev["l_vals"], self._dev["l_rhs_idx"],
                    self._dev["u_cols"], self._dev["u_vals"], self._dev["u_diag"],
                    self._dev["u_rhs_idx"], self._dev["out_perm"], b,
                )
        self._apply = jax.jit(lambda b: _raw(b.astype(jnp.float32)))
        self._batched = jax.jit(jax.vmap(self._apply))
        self._aot = {}

    def __call__(self, b):
        ex = self._aot.get(1)
        if ex is not None and not isinstance(b, jax.core.Tracer):
            return ex(jnp.asarray(b, jnp.float32))
        return self._apply(b)

    apply = __call__

    def batched(self, bs):
        """Apply M^{-1} to a (batch, n) stack of right-hand sides. If
        ``warm`` prepared a bucket >= batch, the stack is zero-padded to it
        (vmap lanes are independent — padding never changes a real lane)."""
        if isinstance(bs, jax.core.Tracer):
            return self._batched(bs)
        bs = jnp.asarray(bs, jnp.float32)
        nb = bs.shape[0]
        fit = [w for w in self._aot if w != 1 and w >= nb]
        if not fit:
            return self._batched(bs)
        tgt = min(fit)
        if tgt > nb:
            bs = jnp.concatenate([bs, jnp.zeros((tgt - nb, self.n), jnp.float32)])
        return self._aot[tgt](bs)[:nb]

    def warm(self, batch_sizes=(1,)):
        """AOT-compile the apply for the given RHS batch sizes (1 = the
        single-RHS apply) and keep the executables for the hot path; with
        ``REPRO_JIT_CACHE`` set the compilations persist across processes.
        Returns {batch_size: compile_seconds}."""
        import time

        from .api import enable_jit_cache

        enable_jit_cache()
        out = {}
        for nb in batch_sizes:
            t0 = time.perf_counter()
            if nb not in self._aot:
                if nb == 1:
                    sds = jax.ShapeDtypeStruct((self.n,), jnp.float32)
                    self._aot[1] = self._apply.lower(sds).compile()
                else:
                    sds = jax.ShapeDtypeStruct((nb, self.n), jnp.float32)
                    self._aot[nb] = self._batched.lower(sds).compile()
            out[nb] = time.perf_counter() - t0
        return out


def wavefront_sweeps_jnp(l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag, u_rhs_idx, out_perm, b):
    """Fused L-then-U level-major wavefront sweep (pure jnp reference).

    The Pallas kernel (`repro.kernels.tri_solve_wavefront`) runs this exact
    computation on values read from refs; both are bit-identical because all
    reductions go through ``masked_lane_sum``.
    """
    nl_lev, maxr_l, _ = l_cols.shape
    nu_lev, maxr_u, _ = u_cols.shape
    nl_slots = nl_lev * maxr_l
    nu_slots = nu_lev * maxr_u
    b = b.astype(jnp.float32)
    b_ext = jnp.concatenate([b, jnp.zeros((1,), jnp.float32)])
    l_rhs = b_ext[l_rhs_idx]  # (nl_lev, maxr_l)

    def l_step(carry, inp):
        x, start = carry
        c, v, r = inp
        gathered = x[c]  # padding -> scratch slot (0)
        acc = masked_lane_sum(c, v, gathered, nl_slots)
        x = jax.lax.dynamic_update_slice(x, r - acc, (start,))
        return (x, start + maxr_l), None

    x_l = jnp.zeros(nl_slots + 1, jnp.float32)
    (x_l, _), _ = jax.lax.scan(l_step, (x_l, 0), (l_cols, l_vals, l_rhs))

    u_rhs = x_l[u_rhs_idx]  # (nu_lev, maxr_u) — y gathered from L slot space

    def u_step(carry, inp):
        x, start = carry
        c, v, r, d = inp
        gathered = x[c]
        acc = masked_lane_sum(c, v, gathered, nu_slots)
        x = jax.lax.dynamic_update_slice(x, (r - acc) / d, (start,))
        return (x, start + maxr_u), None

    x_u = jnp.zeros(nu_slots + 1, jnp.float32)
    (x_u, _), _ = jax.lax.scan(u_step, (x_u, 0), (u_cols, u_vals, u_rhs, u_diag))
    return x_u[out_perm]


# --------------------------------------------------------------------------
# band-partitioned triangular plan + sharded preconditioner apply
# --------------------------------------------------------------------------
def epoch_sweep_jnp(x, cols, vals, rhs, diag, start, limit):
    """Device-local level-major scan over one collective epoch.

    ``cols``/``vals``: (L_e, maxr, W) device-local dependency addresses +
    values; ``rhs``: (L_e, maxr); ``diag``: (L_e, maxr) or None (L sweep —
    unit diagonal); ``x``: the device-local sweep vector
    ``[local | halo | scratch]``; ``start``: first write offset (= first
    level × maxr); ``limit``: the scratch address (lanes at or past it are
    padding and masked out of the reduction). Shared verbatim by the jnp
    engine path and the Pallas epoch kernel
    (`repro.kernels.tri_sweep_epoch`) so the two cannot drift; all
    reductions go through ``masked_lane_sum`` — the same lanes in the same
    order as the single-device sweep, hence bitwise equal.
    """
    maxr = cols.shape[1]

    def step(carry, inp):
        x, s = carry
        if diag is None:
            c, v, r = inp
            y = r - masked_lane_sum(c, v, x[c], limit)
        else:
            c, v, r, d = inp
            y = (r - masked_lane_sum(c, v, x[c], limit)) / d
        x = jax.lax.dynamic_update_slice(x, y, (s,))
        return (x, s + maxr), None

    inp = (cols, vals, rhs) if diag is None else (cols, vals, rhs, diag)
    (x, _), _ = jax.lax.scan(step, (x, jnp.int32(start)), inp)
    return x


@dataclasses.dataclass
class ShardedTriangularPlan:
    """Device-grouped level-major schedule over band-owned rows (DESIGN.md §5).

    The wavefront levels are the same as :class:`TriangularPlan`'s; within
    each level, rows are grouped by their *band owner* (``(j // R) % D``),
    so the slot space is ``level × device × rank`` and every per-row table
    carries a leading device axis that shards over the mesh. L/U **values
    are never materialized on the host**: each device extracts its own
    level-major L/U/diag shards from its local factorization ELL block via
    the ``*_src`` / ``*_lane`` gathers (the ones-lane trick supplies the
    unit padding diagonal), so the factors stay sharded end-to-end.

    Communication follows the **epoch/read-set schedule** (DESIGN.md §5.5,
    ``planner.sweep_epoch_schedule``): the sweep vector is *device-local*
    (``[local slots | ingress halo | scratch]``, never replicated),
    consecutive levels whose cross-device reads all resolve in earlier
    epochs fuse into one collective epoch, and each epoch ends in ONE
    exchange of exactly the slots some other device reads downstream. The
    U right-hand side (the L sweep output at the same row) is always
    device-local by construction, and the final output assembly ships only
    the rows *not* already broadcast by an epoch exchange. Every
    distributed step is a copy of finished f32 values — no arithmetic on
    the wire — so the result is bitwise equal to the single-device apply.
    """

    n: int
    n_devices: int
    band_rows: int
    s_loc: int  # local factor-ELL rows per device
    width: int  # W — the factorization ELL width
    nl_levels: int
    maxr_l: int  # rows per (level, device), L sweep
    nu_levels: int
    maxr_u: int
    WL: int
    WU: int

    # per-device tables, leading axis D (sharded over the mesh's band axis)
    l_src: np.ndarray  # (D, nl, maxr_l) int32 — local ELL row (pad -> s_loc)
    l_lane: np.ndarray  # (D, nl, maxr_l, WL) int32 — ELL lane (pad -> W: zeros)
    l_cols: np.ndarray  # (D, nl, maxr_l, WL) int32 — global-slot deps (pad -> nl_slots)
    l_rhs: np.ndarray  # (D, nl, maxr_l) int32 — into b_ext (pad -> n)
    u_src: np.ndarray  # (D, nu, maxr_u) int32
    u_lane: np.ndarray  # (D, nu, maxr_u, WU) int32
    u_cols: np.ndarray  # (D, nu, maxr_u, WU) int32 — global-slot deps (pad -> nu_slots)
    u_dlane: np.ndarray  # (D, nu, maxr_u) int32 — diag ELL lane (pad -> W+1: ones)
    u_rhs: np.ndarray  # (D, nu, maxr_u) int32 — into L slot space (pad -> nl_slots)
    out_perm: np.ndarray  # (n,) int32: x[j] = x_u_sweep[out_perm[j]] (replicated)

    # --- epoch/read-set communication schedule (DESIGN.md §5.5) -----------
    l_sched: "SweepEpochSchedule"  # L-sweep epochs + exact egress/ingress
    u_sched: "SweepEpochSchedule"
    u_rhs_loc: np.ndarray  # (D, nu, maxr_u) int32 — device-LOCAL L addrs
    fin_src: np.ndarray  # (D, F) int32 — local U addrs of never-exchanged out rows
    fin_slots: np.ndarray  # (D, F) int64 — their global U slots (pad -> -1)

    @property
    def nl_slots(self) -> int:
        return self.nl_levels * self.n_devices * self.maxr_l

    @property
    def nu_slots(self) -> int:
        return self.nu_levels * self.n_devices * self.maxr_u

    def per_device_factor_bytes(self) -> int:
        """f32 bytes of L/U/diag value storage each device holds."""
        return 4 * (self.nl_levels * self.maxr_l * self.WL
                    + self.nu_levels * self.maxr_u * (self.WU + 1))

    # --- sweep communication model (asserted against compiled HLO) --------
    def sweep_collectives_per_apply(self, broadcast: str = "gather") -> int:
        """Collectives per preconditioner apply: one exchange per non-empty
        epoch (L + U) plus the final output assembly — versus the
        ``nl_levels + nu_levels`` per-level gathers of the unfused sweep.
        The explicit ring runs D-1 ``ppermute`` hops per exchange."""
        if self.n_devices == 1:
            return 0
        ex = (self.l_sched.exchange_count() + self.u_sched.exchange_count()
              + (1 if self.fin_src.shape[1] else 0))
        if broadcast == "ring":
            return ex * (self.n_devices - 1)
        return ex

    def sweep_payload_slots(self) -> int:
        """f32 slots shipped per device per apply: the exact epoch read
        sets plus the final-assembly rows not already broadcast."""
        return (self.l_sched.exchanged_slot_count()
                + self.u_sched.exchanged_slot_count()
                + self.fin_src.shape[1])

    def sweep_bytes_per_apply(self, nb: int = 1) -> int:
        """Wire bytes per device per apply of a (nb, n) RHS batch — the
        ring-algorithm model for both collective variants; every collective
        is amortized across the whole batch."""
        if self.n_devices == 1:
            return 0
        return (self.n_devices - 1) * self.sweep_payload_slots() * 4 * nb

    def sweep_bytes_per_apply_unfused(self, nb: int = 1) -> int:
        """The PR-3 baseline: one padded (maxr,) all_gather per level."""
        if self.n_devices == 1:
            return 0
        return (self.n_devices - 1) * 4 * nb * (
            self.nl_levels * self.maxr_l + self.nu_levels * self.maxr_u)

    def comm_summary(self) -> dict:
        """The modeled solve-side communication record — what the ordering
        layer scores candidate permutations/ownerships with
        (``repro.core.ordering.sweep_comm_model``) and what
        ``tests/test_sharded_memory.py`` pins against compiled HLO."""
        return {
            "band_rows": int(self.band_rows),
            "n_devices": int(self.n_devices),
            "levels": int(self.nl_levels + self.nu_levels),
            "epochs": int(self.l_sched.n_epochs + self.u_sched.n_epochs),
            "collectives_per_apply": int(self.sweep_collectives_per_apply()),
            "payload_slots_per_apply": int(self.sweep_payload_slots()),
            "bytes_per_apply": int(self.sweep_bytes_per_apply()),
        }


def build_sharded_triangular_plan(pattern: ILUPattern, band_rows: int,
                                  n_devices: int) -> ShardedTriangularPlan:
    """Structure-only host planning for the band-partitioned sweeps.

    Consumes no values — the value gathers it emits are resolved on device
    against each device's local factorization ELL block, so building the
    solve plan never pulls the factors off the mesh.
    """
    n = pattern.n
    D, R = n_devices, band_rows
    bands = -(-n // R)
    bands = -(-bands // D) * D
    s_loc = (bands // D) * R

    rowlen = np.diff(pattern.indptr).astype(np.int64)
    dp = pattern.diag_ptr.astype(np.int64)
    W = max(int(rowlen.max(initial=0)), 1)
    WL = max(int(dp.max(initial=0)), 1)
    WU = max(int((rowlen - dp - 1).max(initial=0)), 1)

    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    pos = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[row_of]
    lmask = pos < dp[row_of]
    umask = pos > dp[row_of]
    l_cols_rm = np.full((n, WL), COL_SENTINEL, np.int32)
    l_lane_rm = np.full((n, WL), W, np.int32)  # pad -> the zeros lane
    l_cols_rm[row_of[lmask], pos[lmask]] = pattern.indices[lmask]
    l_lane_rm[row_of[lmask], pos[lmask]] = pos[lmask]
    upos = pos - dp[row_of] - 1
    u_cols_rm = np.full((n, WU), COL_SENTINEL, np.int32)
    u_lane_rm = np.full((n, WU), W, np.int32)
    u_cols_rm[row_of[umask], upos[umask]] = pattern.indices[umask]
    u_lane_rm[row_of[umask], upos[umask]] = pos[umask]

    l_levels = wavefront_schedule_ell(l_cols_rm, n)
    u_levels = wavefront_schedule_ell(u_cols_rm, n)

    rows_all = np.arange(n, dtype=np.int64)
    owner = (rows_all // R) % D
    loc = (rows_all // R // D) * R + rows_all % R

    def group(levels):
        """Within each level, group rows by owning device; slot =
        ``level * (D*maxr) + device * maxr + rank``."""
        nlev = levels.shape[0]
        lv, rk = np.nonzero(levels < n)
        rows = levels[lv, rk].astype(np.int64)
        own = owner[rows]
        order = np.lexsort((rows, own, lv))
        lv_s, own_s, rows_s = lv[order], own[order], rows[order]
        key = lv_s * D + own_s
        cnt = np.bincount(key, minlength=nlev * D)
        maxr = max(int(cnt.max(initial=0)), 1)
        start = np.zeros(nlev * D, np.int64)
        np.cumsum(cnt[:-1], out=start[1:])
        rank = np.arange(rows_s.size, dtype=np.int64) - start[key]
        table = np.full((D, nlev, maxr), np.int64(n), np.int64)
        table[own_s, lv_s, rank] = rows_s
        slot_of = np.zeros(n, np.int64)
        slot_of[rows_s] = lv_s * (D * maxr) + own_s * maxr + rank
        return table, slot_of, maxr

    l_tab, slot_l, maxr_l = group(l_levels)
    u_tab, slot_u, maxr_u = group(u_levels)
    nl, nu = l_levels.shape[0], u_levels.shape[0]
    nl_slots = nl * D * maxr_l
    nu_slots = nu * D * maxr_u

    pad_l = l_tab >= n
    rows_l = np.minimum(l_tab, max(n - 1, 0))
    l_src = np.where(pad_l, s_loc, loc[rows_l]).astype(np.int32)
    l_rhs = np.where(pad_l, n, l_tab).astype(np.int32)
    lc = np.where(pad_l[..., None], COL_SENTINEL, l_cols_rm[rows_l])
    l_cols = np.where(
        lc < COL_SENTINEL, slot_l[np.minimum(lc, max(n - 1, 0))], nl_slots
    ).astype(np.int32)
    l_lane = np.where(pad_l[..., None], W, l_lane_rm[rows_l]).astype(np.int32)

    pad_u = u_tab >= n
    rows_u = np.minimum(u_tab, max(n - 1, 0))
    u_src = np.where(pad_u, s_loc, loc[rows_u]).astype(np.int32)
    uc = np.where(pad_u[..., None], COL_SENTINEL, u_cols_rm[rows_u])
    u_cols = np.where(
        uc < COL_SENTINEL, slot_u[np.minimum(uc, max(n - 1, 0))], nu_slots
    ).astype(np.int32)
    u_lane = np.where(pad_u[..., None], W, u_lane_rm[rows_u]).astype(np.int32)
    u_dlane = np.where(pad_u, W + 1, dp[rows_u]).astype(np.int32)  # pad -> ones
    u_rhs = np.where(pad_u, nl_slots, slot_l[rows_u]).astype(np.int32)

    # --- epoch/read-set communication schedule (planner primitive) --------
    l_sched = sweep_epoch_schedule(l_cols, D)
    u_sched = sweep_epoch_schedule(u_cols, D)

    # the U right-hand side reads the L output of the *same row*, whose L
    # slot is owned by the same device — always a device-local address
    urg = slot_l[rows_u]
    assert pad_u.all() or (
        ((urg // maxr_l) % D)[~pad_u]
        == np.broadcast_to(np.arange(D)[:, None, None], pad_u.shape)[~pad_u]
    ).all(), "U rhs crossed a device boundary (ownership mismatch)"
    u_rhs_loc = np.where(
        pad_u, l_sched.scratch, (urg // (D * maxr_l)) * maxr_l + urg % maxr_l
    ).astype(np.int32)

    # final output assembly: ship only the U slots of real rows that no
    # epoch exchange already broadcast (an all_gather leaves its payload
    # replicated on every device)
    need = np.zeros(nu_slots, bool)
    need[slot_u] = True
    need &= ~u_sched.slot_was_exchanged()
    ns = np.nonzero(need)[0]
    fin_slots, _ = ragged_group((ns // maxr_u) % D, ns, D, -1)
    fin_src = np.where(
        fin_slots >= 0,
        (fin_slots // (D * maxr_u)) * maxr_u + fin_slots % maxr_u,
        np.int64(u_sched.scratch),
    ).astype(np.int32)

    return ShardedTriangularPlan(
        n=n, n_devices=D, band_rows=R, s_loc=s_loc, width=W,
        nl_levels=nl, maxr_l=maxr_l, nu_levels=nu, maxr_u=maxr_u, WL=WL, WU=WU,
        l_src=l_src, l_lane=l_lane, l_cols=l_cols, l_rhs=l_rhs,
        u_src=u_src, u_lane=u_lane, u_cols=u_cols, u_dlane=u_dlane,
        u_rhs=u_rhs, out_perm=slot_u.astype(np.int32),
        l_sched=l_sched, u_sched=u_sched, u_rhs_loc=u_rhs_loc,
        fin_src=fin_src, fin_slots=fin_slots,
    )


class ShardedTriangularEngine:
    """Structure-only compiled machinery for the band-partitioned sweeps.

    Owns the placed (sharded) schedule tables and two jitted shard_maps:
    ``extract`` (local factor ELL block -> level-major L/U/diag value
    shards, on device) and ``sweep`` — the **epoch-fused** L-then-U sweep
    over a *device-local* sweep vector ``[local slots | ingress halo |
    scratch]``. Per collective epoch the device runs its levels locally,
    then ONE exchange (XLA ring ``all_gather``, or the explicit ``ppermute``
    directed ring with ``broadcast="ring"`` — both pure copies) ships
    exactly the slots some other device reads downstream; the final output
    assembly ships only the rows no epoch already broadcast. ``sweep``
    takes a ``(nb, n)`` RHS *batch* and vmaps the per-RHS sweep, so every
    collective carries the whole batch — one exchange per epoch regardless
    of how many right-hand sides ride on it.

    Built once per structure and cached on the factorization engine entry —
    refactorizations with new values rebind through the same executables
    (:class:`ShardedPrecondApply`), retrace-free.
    """

    AXIS = "band"

    def __init__(self, plan: ShardedTriangularPlan, mesh,
                 broadcast: str = "gather", use_pallas: bool = False):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.compat import shard_map
        from repro.launch.sharding import band_put

        if broadcast == "psum":  # historical alias for the XLA fast path
            broadcast = "gather"
        assert broadcast in ("gather", "ring")
        self.plan = plan
        self.mesh = mesh
        self.broadcast = broadcast
        self.use_pallas = use_pallas
        ax = self.AXIS
        D, s_loc, W = plan.n_devices, plan.s_loc, plan.width
        nu_slots = plan.nu_slots
        maxr_l, maxr_u = plan.maxr_l, plan.maxr_u
        ls, us = plan.l_sched, plan.u_sched

        def put(x, rank):
            return band_put(mesh, ax, x, rank)

        l_src, u_src = put(plan.l_src, 3), put(plan.u_src, 3)
        l_lane, u_lane = put(plan.l_lane, 4), put(plan.u_lane, 4)
        u_dlane = put(plan.u_dlane, 3)

        def extract(loc, lsrc, ll, usrc, ul, ud):
            # local ELL block + a zeros lane (W) and a ones lane (W+1) so
            # padded gathers land on the right neutral element
            ext = jnp.zeros((s_loc + 1, W + 2), jnp.float32)
            ext = ext.at[:s_loc, :W].set(loc[0])
            ext = ext.at[:, W + 1].set(1.0)
            lv = ext[lsrc[0][..., None], ll[0]]  # (nl, maxr_l, WL)
            uv = ext[usrc[0][..., None], ul[0]]  # (nu, maxr_u, WU)
            dg = ext[usrc[0], ud[0]]  # (nu, maxr_u); pads -> 1.0
            return lv[None], uv[None], dg[None]

        sm_extract = shard_map(
            extract, mesh=mesh,
            in_specs=(P(ax, None, None), P(ax, None, None), P(ax, None, None, None),
                      P(ax, None, None), P(ax, None, None, None), P(ax, None, None)),
            out_specs=(P(ax, None, None, None), P(ax, None, None, None),
                       P(ax, None, None)),
            check_vma=False,
        )
        self.extract = jax.jit(lambda loc: sm_extract(loc, l_src, l_lane, u_src, u_lane, u_dlane))

        # --- epoch-fused sweep: placed schedule tables --------------------
        # (egress/ingress are ragged per epoch — the epoch loop is unrolled,
        # so every payload has its exact read-set shape, never a global max)
        def rep32(x, dump):
            return jnp.asarray(np.where(x >= 0, x, dump).reshape(-1).astype(np.int32))

        tabs = dict(
            l_cols=put(ls.cols_local, 4), l_rhs=put(plan.l_rhs, 3),
            u_cols=put(us.cols_local, 4), u_rhs=put(plan.u_rhs_loc, 3),
            fin_src=put(plan.fin_src, 2),
            l_eg=[put(e, 2) for e in ls.egress if e is not None],
            l_ing=[put(i, 3) for i in ls.ingress if i is not None],
            u_eg=[put(e, 2) for e in us.egress if e is not None],
            u_ing=[put(i, 3) for i in us.ingress if i is not None],
            u_rep=[rep32(s, nu_slots) for s in us.egress_slots if s is not None],
            fin_rep=rep32(plan.fin_slots, nu_slots),
            out_perm=jnp.asarray(plan.out_perm),
        )

        def sp(rank):
            return P(ax, *([None] * (rank - 1)))

        tab_specs = dict(
            l_cols=sp(4), l_rhs=sp(3), u_cols=sp(4), u_rhs=sp(3), fin_src=sp(2),
            l_eg=[sp(2)] * len(tabs["l_eg"]), l_ing=[sp(3)] * len(tabs["l_ing"]),
            u_eg=[sp(2)] * len(tabs["u_eg"]), u_ing=[sp(3)] * len(tabs["u_ing"]),
            u_rep=[P(None)] * len(tabs["u_rep"]), fin_rep=P(None), out_perm=P(None),
        )

        l_bounds = [int(v) for v in ls.epoch_bounds]
        u_bounds = [int(v) for v in us.epoch_bounds]
        l_has = [e is not None for e in ls.egress]
        u_has = [e is not None for e in us.egress]

        if use_pallas:
            from repro.kernels import ops  # deferred: keep core importable alone

            def local_sweep(x, c, v, r, d, start, limit):
                return ops.epoch_sweep(x, c, v, r, d, start=start, limit=limit)
        else:
            local_sweep = epoch_sweep_jnp

        def broadcast_payload(payload, me):
            """All-to-all copy of each device's payload — (D, E), identical
            on every device. No arithmetic touches the wire."""
            if broadcast == "gather":
                return jax.lax.all_gather(payload, ax)
            allp = jnp.zeros((D,) + payload.shape, payload.dtype).at[me].set(payload)
            cur = payload
            perm = [(d, (d + 1) % D) for d in range(D)]
            for hop in range(1, D):  # explicit directed ring (paper Fig 4)
                cur = jax.lax.ppermute(cur, ax, perm)
                allp = allp.at[jnp.mod(me - hop, D)].set(cur)
            return allp

        def sweep(lv, uv, dg, b, t):
            lv, uv, dg = lv[0], uv[0], dg[0]
            lc, lr = t["l_cols"][0], t["l_rhs"][0]
            uc, urh = t["u_cols"][0], t["u_rhs"][0]
            fin0 = t["fin_src"][0]
            l_eg = [e[0] for e in t["l_eg"]]
            l_ing = [i[0] for i in t["l_ing"]]
            u_eg = [e[0] for e in t["u_eg"]]
            u_ing = [i[0] for i in t["u_ing"]]
            me = jax.lax.axis_index(ax)

            def one_rhs(b1):
                b_ext = jnp.concatenate([b1, jnp.zeros((1,), jnp.float32)])
                l_r = b_ext[lr]  # (nl, maxr_l)
                x_l = jnp.zeros(ls.scratch + 1, jnp.float32)
                k = 0
                for e in range(ls.n_epochs):
                    lo, hi = l_bounds[e], l_bounds[e + 1]
                    x_l = local_sweep(x_l, lc[lo:hi], lv[lo:hi], l_r[lo:hi],
                                      None, lo * maxr_l, ls.scratch)
                    if l_has[e] and D > 1:
                        allp = broadcast_payload(x_l[l_eg[k]], me)
                        x_l = x_l.at[l_ing[k].reshape(-1)].set(allp.reshape(-1))
                        k += 1
                u_r = x_l[urh]  # (nu, maxr_u) — own rows' L output, local
                x_u = jnp.zeros(us.scratch + 1, jnp.float32)
                x_rep = jnp.zeros(nu_slots + 1, jnp.float32)
                k = 0
                for e in range(us.n_epochs):
                    lo, hi = u_bounds[e], u_bounds[e + 1]
                    x_u = local_sweep(x_u, uc[lo:hi], uv[lo:hi], u_r[lo:hi],
                                      dg[lo:hi], lo * maxr_u, us.scratch)
                    if u_has[e] and D > 1:
                        allp = broadcast_payload(x_u[u_eg[k]], me)
                        x_u = x_u.at[u_ing[k].reshape(-1)].set(allp.reshape(-1))
                        # epoch payloads are replicated by the exchange:
                        # fold them into the output vector right away so the
                        # final assembly never re-ships them
                        x_rep = x_rep.at[t["u_rep"][k]].set(allp.reshape(-1))
                        k += 1
                if fin0.shape[0]:  # F == 0: every out row already broadcast
                    if D > 1:
                        allf = broadcast_payload(x_u[fin0], me)  # (D, F)
                    else:
                        allf = x_u[fin0][None]
                    x_rep = x_rep.at[t["fin_rep"]].set(allf.reshape(-1))
                return x_rep[t["out_perm"]]

            return jax.vmap(one_rhs)(b.astype(jnp.float32))

        sm_sweep = shard_map(
            sweep, mesh=mesh,
            in_specs=(P(ax, None, None, None), P(ax, None, None, None),
                      P(ax, None, None), P(None, None), tab_specs),
            out_specs=P(None, None),
            check_vma=False,
        )
        self.sweep = jax.jit(lambda lv, uv, dg, b: sm_sweep(lv, uv, dg, b, tabs))

    def sweep_arg_structs(self, nb: int = 1):
        """ShapeDtypeStructs (with shardings) of the sweep arguments for a
        (nb, n) RHS batch — the AOT lowering/warmup entry."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        p = self.plan
        ax = self.AXIS

        def sds(shape, spec):
            return jax.ShapeDtypeStruct(shape, jnp.float32, sharding=NamedSharding(self.mesh, spec))

        return (
            sds((p.n_devices, p.nl_levels, p.maxr_l, p.WL), P(ax, None, None, None)),
            sds((p.n_devices, p.nu_levels, p.maxr_u, p.WU), P(ax, None, None, None)),
            sds((p.n_devices, p.nu_levels, p.maxr_u), P(ax, None, None)),
            sds((nb, p.n), P(None, None)),
        )

    def lower_sweep(self, nb: int = 1):
        """AOT-lower the epoch-fused sweep for a (nb, n) batch (HLO
        inspection: the collective count/bytes tests, and ``warm``)."""
        return self.sweep.lower(*self.sweep_arg_structs(nb))


class ShardedPrecondApply:
    """Band-partitioned, device-resident application of M^{-1} = (LU)^{-1}.

    Consumes the sharded factorization values in place: L/U/diag shards are
    extracted *on device* from each device's local ELL block (one jitted
    shard_map) and stay sharded across every apply. The sweep itself is the
    same level-major wavefront computation as :class:`PrecondApply` — per
    row, the same lanes reduced in the same order through
    ``masked_lane_sum`` — so the result is bitwise equal to the
    single-device apply; the only distributed steps are the per-epoch
    exchanges of exact read-set payloads and one final output assembly, all
    pure copies of finished f32 values (DESIGN.md §5.5).

    Accepts a single ``(n,)`` right-hand side or an ``(nb, n)`` batch
    (``batched``); the batch rides through the same epoch schedule, so
    every collective is amortized across all right-hand sides. ``warm``
    AOT-compiles the sweep for given batch sizes (serving warmup — with
    ``REPRO_JIT_CACHE`` set the compilations persist across processes).
    Callable inside outer jitted code (a whole distributed Krylov solve
    traces into one dispatch). Pass a cached
    :class:`ShardedTriangularEngine` to rebind new values to the existing
    compiled executables (the refactorize→solve serving path).
    """

    def __init__(self, plan: ShardedTriangularPlan, loc_vals, mesh,
                 engine: Optional[ShardedTriangularEngine] = None,
                 broadcast: str = "gather"):
        if engine is None:
            engine = ShardedTriangularEngine(plan, mesh, broadcast=broadcast)
        elif engine.plan is not plan:
            raise ValueError("ShardedPrecondApply: `engine` was compiled for "
                             "a different ShardedTriangularPlan than `plan`")
        self._engine = engine
        self.plan = engine.plan
        self.mesh = mesh
        self.n = self.plan.n
        self._lv, self._uv, self._dg = self._engine.extract(loc_vals)
        self._aot = {}

    def _sweep(self, b2):
        nb = b2.shape[0]
        ex = self._aot.get(nb)
        if ex is not None and not isinstance(b2, jax.core.Tracer):
            return ex(self._lv, self._uv, self._dg, b2)
        return self._engine.sweep(self._lv, self._uv, self._dg, b2)

    def __call__(self, b):
        if getattr(b, "ndim", 1) == 2:
            return self.batched(b)
        if isinstance(b, jax.core.Tracer):
            return self._sweep(b[None, :])[0]
        b2 = jnp.asarray(np.asarray(b, np.float32).reshape(1, -1))
        return self._sweep(b2)[0]

    apply = __call__

    def batched(self, bs):
        """Apply M^{-1} to a (nb, n) stack of right-hand sides — one epoch
        schedule, every collective shared by the whole batch. If ``warm``
        prepared a bucket >= nb, the batch is zero-padded to it (vmap lanes
        are independent, so padding never changes a real lane's bits)."""
        bs = bs if isinstance(bs, jax.core.Tracer) else jnp.asarray(bs, jnp.float32)
        nb = bs.shape[0]
        if not isinstance(bs, jax.core.Tracer):
            fit = [w for w in self._aot if w >= nb]
            if fit and nb not in self._aot:
                tgt = min(fit)
                bs = jnp.concatenate([bs, jnp.zeros((tgt - nb, self.n), jnp.float32)])
        return self._sweep(bs)[:nb]

    def warm(self, batch_sizes=(1,)):
        """AOT-compile the sweep for the given RHS batch sizes and keep the
        executables for the serving hot path. Enables jax's persistent
        compilation cache when ``REPRO_JIT_CACHE`` is set, so a pre-warmed
        shape never pays the first-dispatch compile — not even in a fresh
        process. Returns {batch_size: compile_seconds}."""
        import time

        from .api import enable_jit_cache

        enable_jit_cache()
        out = {}
        for nb in batch_sizes:
            t0 = time.perf_counter()
            if nb not in self._aot:
                self._aot[nb] = self._engine.lower_sweep(nb).compile()
            out[nb] = time.perf_counter() - t0
        return out


def make_triangular_solver(pattern: ILUPattern, vals: np.ndarray,
                           use_pallas: bool = False) -> Callable:
    """Returns jitted ``solve(b) -> x`` applying (LU)^{-1} by substitution.

    Kept as the sequential-reference entry point (exact substitution order);
    prefer :class:`PrecondApply` when the solver will be applied repeatedly —
    it is the same computation with the plan and compilation cached.
    """
    return PrecondApply(pattern, vals, use_pallas=use_pallas)


def make_jacobi_triangular_solver(
    pattern: ILUPattern, vals: np.ndarray, sweeps: int = 8
) -> Callable:
    """Approximate triangular solve by Jacobi iteration (x <- D^{-1}(b - R x)).

    Converges because triangular Jacobi iteration is nilpotent; ``sweeps``
    bounds the wavefront depth it can resolve. TPU-friendly: no wavefront
    schedule, every sweep is one dense-vector pass.
    """
    plan = build_triangular_plan(pattern, vals)
    n = plan.n
    l_cols = jnp.asarray(plan.l_cols)
    l_vals = jnp.asarray(plan.l_vals)
    u_cols = jnp.asarray(plan.u_cols)
    u_vals = jnp.asarray(plan.u_vals)
    diag = jnp.asarray(plan.diag)

    def _iterate(cols, vals_m, rhs, divide):
        def body(_, x):
            xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
            gathered = xg[jnp.minimum(cols, n)]
            acc = masked_lane_sum(cols, vals_m, gathered, COL_SENTINEL)
            new = rhs - acc
            if divide:
                new = new / diag
            return new
        return jax.lax.fori_loop(0, sweeps, body, jnp.zeros_like(rhs))

    @jax.jit
    def solve(b):
        b = b.astype(jnp.float32)
        y = _iterate(l_cols, l_vals, b, divide=False)
        x = _iterate(u_cols, u_vals, y, divide=True)
        return x

    return solve
