"""Roofline + hillclimb for TOP-ILU itself (§Perf hillclimb #3 — the cell
most representative of the paper's technique).

Runs in a subprocess with simulated devices (device count locked at jax
init). For each (band_rows, broadcast) variant it:

  * lowers the shard_map factorization on a D-device ring,
  * extracts per-superstep collective bytes from the compiled HLO
    (the superstep loop is a single `while`; XLA cost_analysis counts the
    body once, so totals are body-costs x n_supersteps — exact here since
    every superstep issues one identically-shaped collective),
  * combines with exact host-side op counts (planner) into the three
    roofline terms on TPU v5e constants,
  * MEASURES wall time on the simulated devices for a small matrix
    (schedule correctness + relative comparison only; 1 CPU core).

Usage:  python benchmarks/bench_topilu_roofline.py [n] [D]
        (spawns itself with XLA_FLAGS when needed)
"""
import os
import sys

if os.environ.get("_TOPILU_CHILD") != "1":
    import subprocess

    d = sys.argv[2] if len(sys.argv) > 2 else "16"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
    env["_TOPILU_CHILD"] = "1"
    sys.exit(subprocess.run([sys.executable] + [__file__] + sys.argv[1:], env=env).returncode)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np

from repro.roofline.analysis import LINK_BW, PEAK_FLOPS, collective_bytes_per_device


def exact_op_counts(a, pattern):
    """Host-side exact multiply-subtract counts of Phase II (planner data)."""
    total = 0
    for j in range(pattern.n):
        s, e = pattern.indptr[j], pattern.indptr[j + 1]
        cols = pattern.indices[s:e]
        d = pattern.diag_ptr[j]
        for i in cols[:d]:
            si, ei = pattern.indptr[i], pattern.indptr[i + 1]
            icols = pattern.indices[si:ei]
            tail = icols[pattern.diag_ptr[i] + 1 :]
            pos = np.searchsorted(cols, tail)
            inb = pos < len(cols)
            total += int(np.sum(cols[pos[inb]] == tail[inb])) + 1  # +1 for l=x/piv
    return total


def main():
    import jax

    from repro.core import matgen, pilu1_symbolic, numeric_ilu_ref
    from repro.core.top_ilu import lower_topilu, topilu_numeric

    n = int(sys.argv[1]) if len(sys.argv) > 1 else 2048
    D = len(jax.devices())
    from repro.compat import make_mesh

    mesh = make_mesh(np.asarray(jax.devices()).reshape(D), ("band",))
    a = matgen(n, density=min(0.02, 16.0 / n), seed=0)
    pat = pilu1_symbolic(a)
    ops = exact_op_counts(a, pat)
    flops = 2.0 * ops  # mul+sub per update
    want = numeric_ilu_ref(a, pat)

    print(f"n={n} nnz={pat.nnz} devices={D} exact_update_ops={ops:.3g}")
    print(f"{'variant':28s} {'bands':>6s} {'coll_B/dev':>12s} {'coll_s':>10s} "
          f"{'comp_s':>10s} {'wall_ms':>9s} bitwise")
    results = []
    for band_rows in (8, 32, 128):
        for broadcast in ("psum", "ring"):
            lowered, plan = lower_topilu(a, pat, band_rows, mesh, broadcast=broadcast)
            compiled = lowered.compile()
            # per-superstep collective bytes (loop body counted once) x n_sup
            step_coll = sum(collective_bytes_per_device(compiled.as_text()).values())
            coll_bytes = step_coll * plan.n_supersteps
            coll_s = coll_bytes / LINK_BW
            comp_s = flops / D / PEAK_FLOPS
            t0 = time.perf_counter()
            got = topilu_numeric(a, pat, band_rows=band_rows, mesh=mesh, broadcast=broadcast)
            wall = (time.perf_counter() - t0) * 1e3
            ok = bool(np.array_equal(got.view(np.int32), want.view(np.int32)))
            name = f"R={band_rows},bcast={broadcast}"
            print(f"{name:28s} {plan.n_bands:6d} {coll_bytes:12.3g} {coll_s:10.3g} "
                  f"{comp_s:10.3g} {wall:9.1f} {ok}")
            results.append((name, coll_bytes, ok))
            assert ok
    best = min(results, key=lambda r: r[1])
    print(f"\nbest-by-collective: {best[0]}  "
          f"({best[1]/max(r[1] for r in results):.2%} of worst)")


if __name__ == "__main__":
    main()
