"""Pallas TPU kernel: trailing-panel LU update  C <- C - A @ B.

This is the FLOP hot-spot of the Block-ILU(k) numeric phase (the MXU
adaptation of the paper's row-merge update, DESIGN.md §3): once fill lives
on 128-aligned tiles, every pivot step is a batch of these panel GEMMs.

Tiling: classic three-loop matmul grid ``(M/bm, N/bn, K/bk)``; the output
block is revisited along k and accumulated in VMEM; the first k-step
initializes from C so the subtraction costs no extra pass over HBM.
VMEM working set per step: bm*bk + bk*bn + bm*bn floats
(128³ tiles -> 192 KiB, far under the ~16 MiB VMEM budget; the default
bm=bn=256, bk=128 uses 384 KiB and keeps the MXU pipeline full).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, c_ref, o_ref):
    k = pl.program_id(2)
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = (c_ref[...].astype(jnp.float32) - acc).astype(o_ref.dtype)

    @pl.when(k > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) - acc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def panel_update(c, a, b, *, bm=256, bn=256, bk=128, interpret=True):
    """C - A @ B for (M,K)x(K,N); M,N,K must be multiples of the block sizes
    (ops.py pads). f32 accumulation regardless of input dtype."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=interpret,
    )(a, b, c)
