"""Sparse matrix containers used by the ILU(k) core.

Two host-side containers:

* :class:`CSRMatrix` — the canonical row-major storage the paper describes
  ("each matrix is an array of rows, each of them is an array of entries").
* :class:`ILUPattern` — the *filled* pattern produced by symbolic
  factorization: CSR structure + per-entry ILU level.

And one device-side container:

* :class:`ELLMatrix` — fixed-width padded rows (static shapes for JAX/TPU).

All column indices are sorted ascending within a row; the diagonal entry is
required to be present (standard ILU(k) breakdown-free assumption under
diagonal dominance, §VI of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class CSRMatrix:
    """Row-major sparse matrix: (indptr, indices, data)."""

    n: int
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32, sorted per row
    data: np.ndarray  # (nnz,) float32

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_scipy(mat) -> "CSRMatrix":
        m = mat.tocsr()
        m.sort_indices()
        return CSRMatrix(
            n=m.shape[0],
            indptr=np.asarray(m.indptr, dtype=np.int64),
            indices=np.asarray(m.indices, dtype=np.int32),
            data=np.asarray(m.data, dtype=np.float32),
        )

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSRMatrix":
        n = a.shape[0]
        indptr = [0]
        indices = []
        data = []
        for j in range(n):
            nz = np.nonzero(a[j])[0]
            indices.append(nz)
            data.append(a[j, nz])
            indptr.append(indptr[-1] + len(nz))
        return CSRMatrix(
            n=n,
            indptr=np.asarray(indptr, dtype=np.int64),
            indices=np.concatenate(indices).astype(np.int32) if indices else np.zeros(0, np.int32),
            data=np.concatenate(data).astype(np.float32) if data else np.zeros(0, np.float32),
        )

    # -- views -------------------------------------------------------------
    def row(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[j], self.indptr[j + 1]
        return self.indices[s:e], self.data[s:e]

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix((self.data, self.indices, self.indptr), shape=(self.n, self.n))

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n), dtype=np.float32)
        for j in range(self.n):
            cols, vals = self.row(j)
            out[j, cols] = vals
        return out

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def density(self) -> float:
        return self.nnz / float(self.n * self.n)

    def has_full_diagonal(self) -> bool:
        for j in range(self.n):
            cols, _ = self.row(j)
            pos = np.searchsorted(cols, j)
            if pos >= len(cols) or cols[pos] != j:
                return False
        return True


@dataclasses.dataclass
class ILUPattern:
    """Filled-matrix pattern: CSR structure + ILU levels per entry.

    ``diag_ptr[j]`` is the offset *within row j* of the diagonal entry.
    """

    n: int
    k: int
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32 sorted per row
    levels: np.ndarray  # (nnz,) int16
    diag_ptr: np.ndarray  # (n,) int32 — local offset of the diagonal in each row

    def row(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[j], self.indptr[j + 1]
        return self.indices[s:e], self.levels[s:e]

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def dense_mask(self) -> np.ndarray:
        mask = np.zeros((self.n, self.n), dtype=bool)
        for j in range(self.n):
            cols, _ = self.row(j)
            mask[j, cols] = True
        return mask

    def validate(self) -> None:
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        for j in range(self.n):
            cols, levs = self.row(j)
            assert np.all(np.diff(cols) > 0), f"row {j} not strictly sorted"
            d = self.diag_ptr[j]
            assert cols[d] == j, f"row {j} missing diagonal"
            assert np.all(levs <= self.k)
            assert np.all(levs >= 0)


@dataclasses.dataclass
class ELLMatrix:
    """Padded fixed-width rows — the static-shape device format.

    ``cols[j, p] == -1`` marks padding; ``vals`` at padding is 0. The extra
    trailing scratch column (index ``width``) absorbs masked scatters.
    """

    n: int
    width: int
    cols: np.ndarray  # (n, width) int32, -1 padded
    vals: np.ndarray  # (n, width) float32
    diag_pos: np.ndarray  # (n,) int32
    row_len: np.ndarray  # (n,) int32

    @staticmethod
    def from_pattern(pattern: ILUPattern, a: CSRMatrix, pad_rows_to: int = 1) -> "ELLMatrix":
        """Scatter A's values onto the filled pattern (fills start at 0)."""
        lens = pattern.row_lengths()
        width = int(lens.max())
        n_pad = ((pattern.n + pad_rows_to - 1) // pad_rows_to) * pad_rows_to
        cols = np.full((n_pad, width), -1, dtype=np.int32)
        vals = np.zeros((n_pad, width), dtype=np.float32)
        diag_pos = np.zeros(n_pad, dtype=np.int32)
        row_len = np.zeros(n_pad, dtype=np.int32)
        for j in range(pattern.n):
            pcols, _ = pattern.row(j)
            m = len(pcols)
            cols[j, :m] = pcols
            acols, avals = a.row(j)
            pos = np.searchsorted(pcols, acols)
            # every A entry must exist in the filled pattern (level-0 entries)
            assert np.all(pcols[pos] == acols)
            vals[j, pos] = avals
            diag_pos[j] = pattern.diag_ptr[j]
            row_len[j] = m
        # padded rows: identity diagonal so divisions stay finite
        for j in range(pattern.n, n_pad):
            cols[j, 0] = j
            vals[j, 0] = 1.0
            diag_pos[j] = 0
            row_len[j] = 1
        return ELLMatrix(
            n=n_pad, width=width, cols=cols, vals=vals, diag_pos=diag_pos, row_len=row_len
        )

    def values_csr(self, pattern: ILUPattern) -> np.ndarray:
        """Flatten padded vals back onto the pattern's CSR layout."""
        out = np.zeros(pattern.nnz, dtype=np.float32)
        for j in range(pattern.n):
            s, e = pattern.indptr[j], pattern.indptr[j + 1]
            out[s:e] = self.vals[j, : e - s]
        return out


def split_lu(pattern: ILUPattern, vals: np.ndarray):
    """Split filled values into scipy L (unit lower) and U (upper) factors."""
    import scipy.sparse as sp

    n = pattern.n
    rows_l, cols_l, data_l = [], [], []
    rows_u, cols_u, data_u = [], [], []
    for j in range(n):
        s, e = pattern.indptr[j], pattern.indptr[j + 1]
        cols = pattern.indices[s:e]
        v = vals[s:e]
        below = cols < j
        rows_l.extend([j] * int(below.sum()))
        cols_l.extend(cols[below].tolist())
        data_l.extend(v[below].tolist())
        rows_l.append(j)
        cols_l.append(j)
        data_l.append(1.0)
        above = cols >= j
        rows_u.extend([j] * int(above.sum()))
        cols_u.extend(cols[above].tolist())
        data_u.extend(v[above].tolist())
    L = sp.csr_matrix((data_l, (rows_l, cols_l)), shape=(n, n))
    U = sp.csr_matrix((data_u, (rows_u, cols_u)), shape=(n, n))
    return L, U
