"""Symbolic factorization — Phase I of ILU(k) (paper Algorithm 1).

Computes the filled pattern and per-entry levels. Two level rules are
supported (paper §III-B):

* ``sum``:  level(j,t) = min over h of level(j,h) + level(h,t) + 1
* ``max``:  level(j,t) = min over h of max(level(j,h), level(h,t)) + 1

Original entries of A have level 0; fill-ins with level <= k are admitted.
(The paper's Alg. 1 line 22 prints ``weight < k``; Definition 3.4 and the
standard ILU(k) literature use ``<= k``, which is what we implement.)

The paper's Phase-I optimization (§III-D) is applied: a pivot entry whose
level already equals k cannot cause any admissible fill (its weight is
>= k+1 under either rule, and cannot lower an existing level), so it is
skipped during the row-merge.

`pilu1_symbolic` is the PILU(1) special case (§IV-F): for k=1 only level-0
(original) entries act as causative entries, so every row's pattern depends
only on rows of *A* — rows are independent and the phase needs **zero
communication**. We exploit exactly that independence with a vectorized
row-at-a-time NumPy computation (and it is what makes the phase
embarrassingly parallel across devices/hosts).

On TPU this phase is the host-side *planning pass* (see DESIGN.md §3): its
output (a static pattern) is what makes the numeric phase jit-able.
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix, ILUPattern


def _row_merge(cols_j, levs_j, j, k, rule, row_cols, row_levs, diag_of):
    """Reduce row j symbolically against all pivot rows i < j.

    cols_j/levs_j: current (sorted) pattern of row j. Returns final arrays.
    """
    ptr = 0
    while ptr < len(cols_j):
        i = cols_j[ptr]
        if i >= j:
            break
        li = levs_j[ptr]
        ptr += 1
        if li >= k:  # paper §III-D optimization — cannot cause admissible fill
            continue
        # tail of pivot row i: entries strictly right of the diagonal
        di = diag_of[i]
        tcols = row_cols[i][di + 1 :]
        tlevs = row_levs[i][di + 1 :]
        if len(tcols) == 0:
            continue
        if rule == "sum":
            weight = li + tlevs + 1
        else:  # max rule
            weight = np.maximum(li, tlevs) + 1
        pos = np.searchsorted(cols_j, tcols)
        in_bounds = pos < len(cols_j)
        present = np.zeros(len(tcols), dtype=bool)
        present[in_bounds] = cols_j[pos[in_bounds]] == tcols[in_bounds]
        # update existing levels
        upd = pos[present]
        levs_j[upd] = np.minimum(levs_j[upd], weight[present])
        # insert admissible fills
        newmask = (~present) & (weight <= k)
        if newmask.any():
            ncols = tcols[newmask]
            nlevs = weight[newmask]
            ipos = np.searchsorted(cols_j, ncols)
            cols_j = np.insert(cols_j, ipos, ncols)
            levs_j = np.insert(levs_j, ipos, nlevs)
            # all inserted columns are > i, so `ptr` (already past i) stays
            # valid, but positions may have shifted for un-scanned pivots:
            # recompute ptr as the index just past column i.
            ptr = int(np.searchsorted(cols_j, i, side="right"))
    return cols_j, levs_j


def symbolic_ilu_k(a: CSRMatrix, k: int, rule: str = "sum") -> ILUPattern:
    """Sequential symbolic ILU(k) — Algorithm 1 of the paper."""
    assert rule in ("sum", "max")
    n = a.n
    row_cols = [None] * n
    row_levs = [None] * n
    diag_of = np.zeros(n, dtype=np.int64)
    for j in range(n):
        acols, _ = a.row(j)
        cols_j = acols.astype(np.int64).copy()
        levs_j = np.zeros(len(cols_j), dtype=np.int64)
        d = np.searchsorted(cols_j, j)
        assert d < len(cols_j) and cols_j[d] == j, f"row {j}: missing diagonal"
        if k > 0:
            cols_j, levs_j = _row_merge(cols_j, levs_j, j, k, rule, row_cols, row_levs, diag_of)
        row_cols[j] = cols_j
        row_levs[j] = levs_j
        diag_of[j] = np.searchsorted(cols_j, j)
    return _pack(n, k, row_cols, row_levs, diag_of)


def pilu1_symbolic(a: CSRMatrix, rule: str = "sum") -> ILUPattern:
    """PILU(1): embarrassingly parallel symbolic factorization for k = 1.

    Row j's final pattern = A's row j plus every t > i reachable through a
    level-0 causative pair (f_{j,i}, f_{i,t}) with i < j — using only rows of
    the *original* A. (Under either rule the weight of such a fill is 1.)
    """
    n = a.n
    row_cols = [None] * n
    row_levs = [None] * n
    diag_of = np.zeros(n, dtype=np.int64)
    # Pre-slice A's rows once (these are the only data any row needs).
    a_cols = [a.row(j)[0].astype(np.int64) for j in range(n)]
    a_diag = [int(np.searchsorted(a_cols[j], j)) for j in range(n)]
    for j in range(n):
        base = a_cols[j]
        pivots = base[base < j]
        fill_blocks = []
        for i in pivots:
            tail = a_cols[i][a_diag[i] + 1 :]
            if len(tail):
                fill_blocks.append(tail)
        if fill_blocks:
            fills = np.unique(np.concatenate(fill_blocks))
            fills = fills[~np.isin(fills, base, assume_unique=True)]
        else:
            fills = np.zeros(0, dtype=np.int64)
        cols_j = np.sort(np.concatenate([base, fills]))
        levs_j = np.zeros(len(cols_j), dtype=np.int64)
        if len(fills):
            levs_j[np.searchsorted(cols_j, fills)] = 1
        row_cols[j] = cols_j
        row_levs[j] = levs_j
        diag_of[j] = np.searchsorted(cols_j, j)
    return _pack(n, 1, row_cols, row_levs, diag_of)


def _pack(n, k, row_cols, row_levs, diag_of) -> ILUPattern:
    lens = np.asarray([len(c) for c in row_cols], dtype=np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    return ILUPattern(
        n=n,
        k=k,
        indptr=indptr,
        indices=np.concatenate(row_cols).astype(np.int32),
        levels=np.concatenate(row_levs).astype(np.int16),
        diag_ptr=diag_of.astype(np.int32),
    )


def symbolic_ilu_k_bruteforce(a: CSRMatrix, k: int, rule: str = "sum") -> np.ndarray:
    """O(n^3) dense level computation straight from Definition 3.4.

    Returns the (n, n) level matrix with np.iinfo.max for non-entries.
    Only for tests on tiny matrices.
    """
    n = a.n
    INF = np.int64(10**9)
    lev = np.full((n, n), INF, dtype=np.int64)
    for j in range(n):
        cols, _ = a.row(j)
        lev[j, cols] = 0
    for h in range(n):
        for i in range(h + 1, n):
            if lev[i, h] > k:  # not an admitted entry -> cannot be causative
                continue
            for t in range(h + 1, n):
                if lev[h, t] > k:
                    continue
                if rule == "sum":
                    w = lev[i, h] + lev[h, t] + 1
                else:
                    w = max(lev[i, h], lev[h, t]) + 1
                if w < lev[i, t] and w <= k:
                    lev[i, t] = w
    lev[lev > k] = INF
    return lev
