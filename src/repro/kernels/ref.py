"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's sweep test asserts against these references across shapes and
dtypes; the references are also what the rest of the system uses when
``REPRO_DISABLE_PALLAS=1``. References that sit on the bit-compatible solve
path (`spmv_ell_ref`, the triangular-substitution refs) share their
reduction primitive (`masked_lane_sum`) with the kernels, so kernel and
reference agree *bitwise*, not just to tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitmath import masked_lane_sum
from repro.core.planner import COL_SENTINEL


def panel_update_ref(c, a, b):
    """Trailing-panel LU update: C - A @ B (f32 accumulation)."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return (c.astype(jnp.float32) - acc).astype(c.dtype)


def trsm_right_upper_ref(a, u):
    """Solve X U = A with U upper-triangular (the BILU L-panel step:
    L_JI = A_JI @ U_II^{-1})."""
    xt = jax.scipy.linalg.solve_triangular(
        u.T.astype(jnp.float32), a.T.astype(jnp.float32), lower=True
    )
    return xt.T.astype(a.dtype)


def trsm_left_unit_lower_ref(l, a):
    """Solve L X = A with L unit-lower (the BILU U-panel step:
    U_IJ = L_II^{-1} @ A_IJ)."""
    x = jax.scipy.linalg.solve_triangular(
        l.astype(jnp.float32), a.astype(jnp.float32), lower=True, unit_diagonal=True
    )
    return x.astype(a.dtype)


def trsm_right_upper_subst_ref(a, u):
    """Substitution-order oracle for ``trsm_right_upper`` — the exact
    column-by-column recurrence the kernel runs, in plain jnp. Use for
    bitwise comparisons; `trsm_right_upper_ref` (LAPACK-style) only to
    tolerance."""
    bs = u.shape[0]
    iota = jax.lax.iota(jnp.int32, bs)
    x = jnp.zeros_like(a)

    def col(c, x):
        ucol = jnp.where(iota < c, u[:, c], 0.0)
        acc = jnp.dot(x, ucol, preferred_element_type=jnp.float32)
        return x.at[:, c].set(((a[:, c] - acc) / u[c, c]).astype(a.dtype))

    return jax.lax.fori_loop(0, bs, col, x)


def trsm_left_unit_lower_subst_ref(l, a):
    """Substitution-order oracle for ``trsm_left_unit_lower`` (row-by-row
    forward recurrence); bitwise counterpart of the kernel."""
    bs = l.shape[0]
    iota = jax.lax.iota(jnp.int32, bs)
    x = jnp.zeros_like(a)

    def row(r, x):
        lrow = jnp.where(iota < r, l[r, :], 0.0)
        acc = jnp.dot(lrow, x, preferred_element_type=jnp.float32)
        return x.at[r, :].set((a[r, :] - acc).astype(a.dtype))

    return jax.lax.fori_loop(0, bs, row, x)


def spmv_ell_ref(cols, vals, x):
    """Row-major ELL SpMV with sentinel-padded columns — fixed lane-order
    accumulation (bit-deterministic, matches the Pallas kernel)."""
    n = x.shape[0]
    xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
    gathered = xg[jnp.minimum(cols, n)]
    return masked_lane_sum(cols, vals, gathered, COL_SENTINEL)


def tri_solve_wavefront_ref(l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag,
                            u_rhs_idx, out_perm, b):
    """Fused wavefront triangular solve, pure jnp (bitwise kernel oracle)."""
    from repro.core.triangular import wavefront_sweeps_jnp

    return wavefront_sweeps_jnp(
        l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag, u_rhs_idx, out_perm, b
    ).astype(b.dtype)
