"""Subprocess body for multi-device TOP-ILU tests.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
         python tests/multidevice_check.py <n> <k> <band_rows> <broadcast> \
             [--solve] [--batch]

Exits 0 iff the multi-device sharded TOP-ILU factorization is bitwise equal
to the sequential oracle AND each device's value shard has the sharded
(s_loc, W) shape, not the replicated (n_pad, W) one. With ``--solve`` it
additionally runs the distributed preconditioner apply + GMRES solve and
asserts both bitwise equal to the single-device path; ``--batch`` further
runs a ragged multi-RHS ``solve_sharded`` (bucketed batch) and asserts
every column bitwise equal to its per-column single-device solve.
(Separate process because the device count is locked at first JAX init.)

``--ordering NAME`` runs the *reordered* pipeline instead (works at any
device count, including 1): resolve the named ordering for this mesh,
assert the sharded ordered factorization bitwise-equal to the sequential
oracle on the permuted matrix, and assert single- and multi-RHS
``solve_sharded(ordering=...)`` bitwise-equal to the single-device
*permuted* solve mapped back through the permutation.

``--inverse`` runs the incomplete-inverse contract instead (any device
count, including 1): over ordering ∈ {natural, rcm, fusion} × k ∈ {0,1,2},
the inverse factors and the distributed SpMV-chain apply (single and
batched RHS) of the permuted system must be bitwise-equal to the
single-threaded inverse oracle of the permuted matrix; plus one
end-to-end ``solve_sharded(precond_method="inverse")`` bitwise vs the
single-device inverse solve mapped back through the permutation.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def check_ordering(n, k, band_rows, broadcast, name):
    import numpy as np
    import jax

    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k, pilu1_symbolic
    from repro.core.api import ilu_sharded
    from repro.core.ordering import make_ordering, permuted_system
    from repro.core.solvers import solve_sharded, solve_with_ilu

    d = len(jax.devices())
    a = matgen(n, density=min(0.08, 12.0 / n), seed=42)
    # one Ordering object shared by the sharded run and the single-device
    # reference: the bitwise contract is relative to a fixed permutation
    ord_ = make_ordering(a, name, n_devices=d, band_rows=band_rows)
    assert ord_ is not None and np.array_equal(
        np.sort(ord_.perm), np.arange(n)), "not a permutation"
    ap = permuted_system(a, ord_)

    # sharded factors == sequential oracle of the permuted matrix
    pat = pilu1_symbolic(ap) if k == 1 else symbolic_ilu_k(ap, k)
    want = numeric_ilu_ref(ap, pat)
    fact = ilu_sharded(a, k, band_rows=band_rows, broadcast=broadcast, ordering=ord_)
    got = fact.values_csr()
    assert np.array_equal(got.view(np.int32), want.view(np.int32)), \
        "ordered sharded factors != sequential oracle on permuted matrix"

    # ordered sharded solve == single-device permuted solve, mapped back
    b = np.random.default_rng(7).standard_normal(n).astype(np.float32)
    r_sh, _ = solve_sharded(a, b, k=k, band_rows=band_rows, tol=1e-6,
                            broadcast=broadcast, fact=fact)
    r_1p, _ = solve_with_ilu(ap, b[ord_.perm], k=k, tol=1e-6, use_pallas=False)
    assert r_sh.converged and r_sh.iterations == r_1p.iterations
    assert np.array_equal(r_sh.x.view(np.int32),
                          r_1p.x[ord_.iperm].view(np.int32)), \
        "ordered distributed solve != single-device permuted solve"

    # multi-RHS through the bucketed batch path: per-column bitwise
    B = np.random.default_rng(8).standard_normal((3, n)).astype(np.float32)
    rs, _ = solve_sharded(a, B, k=k, band_rows=band_rows, tol=1e-6, broadcast=broadcast, fact=fact)
    assert len(rs) == 3
    for i, r in enumerate(rs):
        r1, _ = solve_with_ilu(ap, B[i][ord_.perm], k=k, tol=1e-6, use_pallas=False)
        assert r.converged and r.iterations == r1.iterations, i
        assert np.array_equal(r.x.view(np.int32),
                              r1.x[ord_.iperm].view(np.int32)), \
            f"ordered batched column {i} != single-device permuted solve"

    print(f"OK: n={n} k={k} band_rows={band_rows} broadcast={broadcast} "
          f"devices={d} ordering={name} nnz={pat.nnz} bitwise-equal")


def check_inverse(n, band_rows, broadcast):
    import numpy as np
    import jax

    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k, pilu1_symbolic
    from repro.core.inverse import InversePrecondApply, ShardedInversePrecondApply
    from repro.core.inverse_ref import (
        inverse_apply_ref,
        inverse_pattern_ref,
        inverse_values_ref,
    )
    from repro.core.ordering import make_ordering, permuted_system
    from repro.core.solvers import solve_sharded, solve_with_ilu

    d = len(jax.devices())
    mesh = None
    if d > 1:
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()), ("band",))
    a = matgen(n, density=min(0.08, 12.0 / n), seed=42)
    rng = np.random.default_rng(7)
    b = rng.standard_normal(n).astype(np.float32)
    B = rng.standard_normal((3, n)).astype(np.float32)

    for name in ("natural", "rcm", "fusion"):
        ord_ = make_ordering(a, name, n_devices=d, band_rows=band_rows)
        ap = a if ord_ is None else permuted_system(a, ord_)
        for k in (0, 1, 2):
            # the single-threaded oracle of the *permuted* matrix is the
            # anchor: pattern, values, and applies must all match it bitwise
            pat = pilu1_symbolic(ap) if k == 1 else symbolic_ilu_k(ap, k)
            vals = numeric_ilu_ref(ap, pat)
            wc, zc = inverse_pattern_ref(pat)
            wv, zv = inverse_values_ref(pat, vals, wc, zc)
            if d > 1:
                p = ShardedInversePrecondApply(pat, vals, mesh)
                got_w, got_z = np.asarray(p.base.w_vals), np.asarray(p.base.z_vals)
            else:
                p = InversePrecondApply(pat, vals, use_pallas=False)
                got_w, got_z = np.asarray(p.w_vals), np.asarray(p.z_vals)
            assert np.array_equal(p.plan.w_cols, wc), (name, k)
            assert np.array_equal(p.plan.z_cols, zc), (name, k)
            assert np.array_equal(got_w.view(np.int32), wv.view(np.int32)), \
                f"W values != inverse oracle ({name}, k={k})"
            assert np.array_equal(got_z.view(np.int32), zv.view(np.int32)), \
                f"Z values != inverse oracle ({name}, k={k})"
            want_1 = inverse_apply_ref(wc, wv, zc, zv, b)
            want_B = inverse_apply_ref(wc, wv, zc, zv, B)
            assert np.array_equal(np.asarray(p(b)).view(np.int32),
                                  want_1.view(np.int32)), \
                f"inverse apply != oracle ({name}, k={k}, devices={d})"
            assert np.array_equal(np.asarray(p.batched(B)).view(np.int32),
                                  want_B.view(np.int32)), \
                f"batched inverse apply != oracle ({name}, k={k}, devices={d})"

    # one end-to-end integration config: the full sharded pipeline with
    # precond_method="inverse" == the single-device inverse solve, mapped
    # back through the permutation (single RHS + bucketed 3-RHS batch)
    name = "fusion" if d > 1 else "natural"
    ord_ = make_ordering(a, name, n_devices=d, band_rows=band_rows)
    ap = a if ord_ is None else permuted_system(a, ord_)
    bp = b if ord_ is None else b[ord_.perm]
    r_sh, fact = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6,
                               broadcast=broadcast, ordering=ord_,
                               precond_method="inverse")
    r_1p, _ = solve_with_ilu(ap, bp, k=1, tol=1e-6, use_pallas=False, precond_method="inverse")
    x_sh = r_sh.x if ord_ is None else r_sh.x[ord_.perm]
    assert r_sh.converged and r_sh.iterations == r_1p.iterations
    assert np.array_equal(x_sh.view(np.int32), r_1p.x.view(np.int32)), \
        "inverse-preconditioned distributed solve != single-device solve"
    rs, _ = solve_sharded(a, B, k=1, band_rows=band_rows, tol=1e-6,
                          broadcast=broadcast, fact=fact,
                          precond_method="inverse")
    assert len(rs) == 3
    for i, r in enumerate(rs):
        r1, _ = solve_with_ilu(ap, B[i] if ord_ is None else B[i][ord_.perm],
                               k=1, tol=1e-6, use_pallas=False,
                               precond_method="inverse")
        assert r.converged and r.iterations == r1.iterations, i
        xi = r.x if ord_ is None else r.x[ord_.perm]
        assert np.array_equal(xi.view(np.int32), r1.x.view(np.int32)), \
            f"inverse-preconditioned batched column {i} != single-device solve"

    print(f"OK: n={n} band_rows={band_rows} broadcast={broadcast} devices={d} "
          f"inverse orderings=natural,rcm,fusion k=0,1,2 bitwise-equal")


def main():
    n, k, band_rows, broadcast = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]), sys.argv[4]
    if "--inverse" in sys.argv:
        return check_inverse(n, band_rows, broadcast)
    if "--ordering" in sys.argv:
        return check_ordering(n, k, band_rows, broadcast,
                              sys.argv[sys.argv.index("--ordering") + 1])
    check_solve = "--solve" in sys.argv
    import numpy as np
    import jax

    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k, pilu1_symbolic
    from repro.core.top_ilu import topilu_factor_sharded

    devs = jax.devices()
    assert len(devs) >= 2, f"expected multi-device, got {devs}"
    a = matgen(n, density=min(0.08, 12.0 / n), seed=42)
    pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)
    want = numeric_ilu_ref(a, pat)
    fact = topilu_factor_sharded(a, pat, band_rows=band_rows, broadcast=broadcast)
    got = fact.values_csr()
    mism = np.nonzero(got.view(np.int32) != want.view(np.int32))[0]
    if mism.size:
        print(f"FAIL: {mism.size}/{want.size} bitwise mismatches; first {mism[:5]}")
        print("got ", got[mism[:5]])
        print("want", want[mism[:5]])
        sys.exit(1)

    # sharded storage: every device holds exactly its (s_loc, W) block
    plan = fact.plan
    shapes = {s.data.shape for s in fact.loc_vals.addressable_shards}
    assert shapes == {(1, plan.s_loc, plan.width)}, shapes
    assert plan.s_loc == plan.n_pad // len(devs)
    assert plan.per_device_value_bytes() < plan.replicated_value_bytes()

    check_batch = "--batch" in sys.argv
    if check_solve or check_batch:
        from repro.core.api import ilu
        from repro.core.solvers import solve_with_ilu, solve_sharded

        b = np.random.default_rng(7).standard_normal(n).astype(np.float32)
        ref_fact = ilu(a, k, backend="jax")
        y_ref = np.asarray(ref_fact.precond(use_pallas=False)(b))
        y_sh = np.asarray(fact.precond()(b))
        assert np.array_equal(y_ref.view(np.int32), y_sh.view(np.int32)), \
            "sharded precond apply != single-device apply"
        r_ref, _ = solve_with_ilu(a, b, k=k, tol=1e-6, use_pallas=False)
        r_sh, _ = solve_sharded(a, b, k=k, band_rows=band_rows, tol=1e-6,
                                broadcast=broadcast, fact=fact)
        assert r_sh.converged
        assert np.array_equal(r_ref.x.view(np.int32), r_sh.x.view(np.int32)), \
            "distributed solve solution != single-device solution"

    if check_batch:
        # ragged batch: 3 RHS pad to the 4-bucket; every real column must
        # equal its per-column single-device solve bitwise
        B = np.random.default_rng(8).standard_normal((3, n)).astype(np.float32)
        rs, _ = solve_sharded(a, B, k=k, band_rows=band_rows, tol=1e-6,
                              broadcast=broadcast, fact=fact)
        assert len(rs) == 3
        for i, r in enumerate(rs):
            r1, _ = solve_with_ilu(a, B[i], k=k, tol=1e-6, use_pallas=False)
            assert r.converged and r.iterations == r1.iterations, i
            assert np.array_equal(r.x.view(np.int32), r1.x.view(np.int32)), \
                f"batched sharded column {i} != single-device solve"

    print(f"OK: n={n} k={k} band_rows={band_rows} broadcast={broadcast} "
          f"devices={len(devs)} nnz={pat.nnz} s_loc={plan.s_loc} "
          f"halo={plan.halo_size} solve={check_solve} batch={check_batch} "
          f"bitwise-equal")


if __name__ == "__main__":
    main()
