"""Level-based incomplete inverse factors — the sequential bit-compat oracle.

The paper's headline optimization (§V) replaces the two *triangular sweeps*
of the preconditioner apply with precomputed *incomplete inverse* factors:

    M^{-1} = U^{-1} L^{-1}  ~=  Z W,   W ~= L^{-1},  Z ~= U^{-1}

so every apply becomes a short chain of SpMVs (x = Z (W b)) with **no
wavefront recursion at solve time** — the sweep's level-by-level serial
chain is paid once, when the inverse values are computed, instead of on
every Krylov iteration.

Sparsity of the inverse factors is capped by the *same fill-level rule* as
ILU(k) itself: an inverse entry (i, j) produced by the dependency chain
``i -> m -> ... -> j`` through the factor costs the chain's entry levels
plus one per extra hop (``lev = lev_a + lev_b + 1``, exactly the symbolic
fill rule), and survives iff its cheapest chain costs <= k. Diagonals are
level 0 and always kept. With k=0 the inverse pattern equals the factor
pattern (a structurally-ILU(0)-shaped truncated Neumann inverse).

Bit-compat contract (paper abstract): the incomplete inverse method is NOT
bit-compatible with classical ILU(k) — it is a different (weaker, faster)
approximation of M^{-1} — but it IS bit-compatible with the single-threaded
version of *itself*. This module is that single-threaded version: plain
NumPy float32, every reduction an explicit multiply-then-add in ascending
lane order, mirroring ``repro.core.bitmath.masked_lane_sum`` operation for
operation (masked lanes add a literal +0.0; absent inverse entries gather
0.0 *before* the multiply). The JAX engine (``repro.core.inverse``), the
Pallas chain kernel, and the sharded apply must all reproduce these values
and applies bitwise, on any device count.
"""
from __future__ import annotations

import numpy as np

from .planner import COL_SENTINEL
from .sparse import ILUPattern


def _level_split(pattern: ILUPattern):
    """CSR pattern -> per-row ``(cols, levels)`` of the strict-L / strict-U parts."""
    n = pattern.n
    lower, upper = [], []
    for i in range(n):
        s, e = int(pattern.indptr[i]), int(pattern.indptr[i + 1])
        d = int(pattern.diag_ptr[i])
        cols = pattern.indices[s:e].astype(np.int64)
        levs = pattern.levels[s:e].astype(np.int64)
        lower.append((cols[:d], levs[:d]))
        upper.append((cols[d + 1 :], levs[d + 1 :]))
    return lower, upper


def _closure(rows, order, k: int):
    """Sequential min-plus closure: the level-truncated inverse sparsity.

    ``rows[i] = (cols, levs)`` are row i's strict factor entries (its
    dependencies). Rows are processed in dependency ``order`` (ascending for
    L, descending for U); ``out[m]`` is complete before any i that reads it.
    Pruning at ``> k`` mid-closure is exact: chain costs only grow, so no
    dropped intermediate can support a surviving longer chain.
    """
    out = {}
    for i in order:
        i = int(i)
        best = {i: 0}
        cols, levs = rows[i]
        for m, a in zip(cols.tolist(), levs.tolist()):
            if a <= k and a < best.get(m, k + 1):
                best[m] = a  # the direct entry: the chain i -> m terminates
            for j, b in out[m].items():
                if j == m:
                    continue
                c = a + b + 1  # one extra hop — the ILU(k) fill rule
                if c <= k and c < best.get(j, k + 1):
                    best[j] = c
        out[i] = best
    return [out[i] for i in range(len(rows))]


def inverse_pattern_ref(pattern: ILUPattern, k=None):
    """Level-truncated sparsity of W ~= L^{-1} and Z ~= U^{-1}.

    Returns ``(w_cols, z_cols)`` as sentinel-padded ELL column arrays with
    ascending columns per row; both include the diagonal (W's diagonal
    values are identically 1.0, Z's are 1/U[i,i]). ``k`` defaults to the
    pattern's own fill level.
    """
    k = pattern.k if k is None else int(k)
    n = pattern.n
    lower, upper = _level_split(pattern)
    w = _closure(lower, range(n), k)
    z = _closure(upper, range(n - 1, -1, -1), k)

    def ell(rows):
        wid = max(max((len(r) for r in rows), default=1), 1)
        cols = np.full((n, wid), COL_SENTINEL, np.int32)
        for i, r in enumerate(rows):
            cs = np.sort(np.fromiter(r.keys(), np.int64, len(r)))
            cols[i, : len(cs)] = cs
        return cols

    return ell(w), ell(z)


def inverse_values_ref(
    pattern: ILUPattern, vals: np.ndarray, w_cols: np.ndarray, z_cols: np.ndarray
):
    """Sequential float32 value oracle for the incomplete inverse factors.

    Row i of W solves ``L W = I`` restricted to the truncated pattern:
    ``W[i,j] = d_ij - sum_m L[i,m] W[m,j]`` over row i's strict-L lanes in
    ascending column order (reads outside the pattern gather 0.0); rows
    ascend. Z solves ``U Z = I`` the same way with rows descending and a
    final divide by the diagonal. Arithmetic mirrors ``masked_lane_sum``:
    one f32 rounding per multiply and per add, accumulated in lane order,
    padded lanes contributing a literal +0.0. Returns ``(w_vals, z_vals)``
    aligned with ``w_cols``/``z_cols``; pad lanes hold 0.0.
    """
    from .triangular import _split_lu_ell

    n = pattern.n
    l_cols, l_vals, u_cols, u_vals, diag = _split_lu_ell(pattern, np.asarray(vals, np.float32))

    def sweep(f_cols, f_vals, inv_cols, div, order):
        wid = inv_cols.shape[1]
        out = np.zeros((n, wid), np.float32)
        for i in order:
            i = int(i)
            for t in range(wid):
                j = int(inv_cols[i, t])
                if j >= n:
                    continue  # sentinel pad lane — stays 0.0
                acc = np.float32(0.0)
                for s in range(f_cols.shape[1]):
                    m = int(f_cols[i, s])
                    if m >= n:
                        acc = np.float32(acc + np.float32(0.0))
                        continue
                    p = int(np.searchsorted(inv_cols[m], j))
                    g = out[m, p] if p < wid and inv_cols[m, p] == j else np.float32(0.0)
                    acc = np.float32(acc + np.float32(f_vals[i, s] * g))
                y = np.float32((np.float32(1.0) if j == i else np.float32(0.0)) - acc)
                if div is not None:
                    y = np.float32(y / div[i])
                out[i, t] = y
        return out

    w_vals = sweep(l_cols, l_vals, w_cols, None, range(n))
    z_vals = sweep(u_cols, u_vals, z_cols, diag, range(n - 1, -1, -1))
    return w_vals, z_vals


def inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, b):
    """Sequential oracle apply: ``x = Z (W b)`` — two lane-ordered ELL SpMVs.

    Same lane order and f32 rounding as the engine chain (every device
    count): per row, ``acc += f32(val * x[col])`` ascending lanes, masked
    lanes adding +0.0. Accepts ``b`` of shape (n,) or (nb, n).
    """
    b = np.asarray(b, np.float32)
    if b.ndim == 2:
        return np.stack([inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, bi) for bi in b])

    def spmv(cols, vals_, x):
        n = x.shape[0]
        y = np.zeros(n, np.float32)
        for i in range(n):
            acc = np.float32(0.0)
            for s in range(cols.shape[1]):
                c = int(cols[i, s])
                prod = np.float32(vals_[i, s] * x[c]) if c < n else np.float32(0.0)
                acc = np.float32(acc + prod)
            y[i] = acc
        return y

    return spmv(z_cols, z_vals, spmv(w_cols, w_vals, b))
