"""Pallas TPU kernel: fused L-then-U wavefront triangular solve.

Applies the whole preconditioner M^{-1} = (LU)^{-1} in ONE kernel launch:
both level-scheduled substitution sweeps run back-to-back over the
level-major plan arrays (see ``repro.core.triangular.TriangularPlan``),
with the sweep vector resident the entire time (at the benchmark sizes the
factors fit comfortably in VMEM: 16k rows x ~9 lanes of f32 < 1 MiB).

Per wavefront the kernel does one ``x[cols]`` gather, one masked
lane-ordered reduction, and one contiguous ``dynamic_update_slice`` — no
row gathers, no scatters. The kernel body deliberately *shares* its
implementation with the jnp reference (``wavefront_sweeps_jnp``, all
reductions via ``masked_lane_sum``) so the two cannot drift: bit-identity
with the sequential-order solve is enforced by construction and asserted
against an independent NumPy substitution oracle in the tests.

Caveat: this container runs the kernel in interpret mode
(``REPRO_PALLAS_INTERPRET=1``, the default). The compiled TPU lowering
(``interpret=False``: ``lax.scan`` over the stacked level arrays with
dynamic VMEM gathers + ``dynamic_update_slice``) has not been exercised on
real hardware yet — see ROADMAP. ``REPRO_DISABLE_PALLAS=1`` falls back to
the jnp path everywhere.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(l_cols_ref, l_vals_ref, l_rhs_idx_ref, u_cols_ref, u_vals_ref,
            u_diag_ref, u_rhs_idx_ref, out_perm_ref, b_ref, o_ref):
    from repro.core.triangular import wavefront_sweeps_jnp

    o_ref[...] = wavefront_sweeps_jnp(
        l_cols_ref[...], l_vals_ref[...], l_rhs_idx_ref[...],
        u_cols_ref[...], u_vals_ref[...], u_diag_ref[...],
        u_rhs_idx_ref[...], out_perm_ref[...], b_ref[...],
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tri_solve_wavefront(l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag,
                        u_rhs_idx, out_perm, b, *, interpret=True):
    """x = (LU)^{-1} b over level-major plan arrays.

    ``l_cols``/``l_vals``: (nl_lev, maxr_l, WL) slot-space columns + values;
    ``u_*`` analogous for the backward sweep; ``*_rhs_idx`` are the
    precomputed RHS gathers; ``out_perm`` maps rows to U-sweep slots;
    ``b``: (n,). Returns x with the same dtype as ``b``.
    """
    n = b.shape[0]
    args = (l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag, u_rhs_idx, out_perm, b)
    return pl.pallas_call(
        _kernel,
        in_specs=[pl.BlockSpec(a.shape, lambda *_, s=a.shape: (0,) * len(s))
                  for a in args],
        out_specs=pl.BlockSpec((n,), lambda *_: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=resolve_interpret(interpret),
    )(*args)
