"""Device-side numeric factorization (Phase II) — wavefront + superstep engines.

All functions here are pure JAX and shape-static; they implement exactly the
oracle's arithmetic (divide; barriered multiply-then-subtract; ascending
pivots per row) so the result is **bit-compatible** with
:func:`repro.core.numeric_ref.numeric_ilu_ref`.

Two executors over the same plan-layer contracts (DESIGN.md §3):

* :func:`factor_wavefront_sweeps_jnp` / :func:`make_wavefront_factorizer` —
  the single-device fast path. One ``lax.scan`` over the *pivot-op*
  wavefronts of a :class:`repro.core.factor_plan.FactorPlan`: each round
  applies one pivot to every row whose turn has come (all independent by
  construction), through the precomputed flat destination-lane map — no
  ``searchsorted``, no per-band sequential sweep, and padded work bounded
  by ``n_rounds * max_ops * W`` (exact op count, robust to skewed
  patterns) instead of the old ``n_bands * n_pad * max_piv`` dense partial
  reductions.
* :func:`make_superstep_factorizer` — the banded TOP-ILU executor (paper
  §IV), re-emitted over the *band superstep schedule*: bands whose
  dependencies are satisfied factor concurrently (vmapped per device over
  its members of the superstep), each band *pulling* its inter-band pivot
  rows from the replicated finalized values. One collective per superstep
  (an ``all_gather`` of the bands each device finished — ``broadcast=
  "psum"`` is kept as an alias — or an explicit ``ppermute`` directed ring,
  the paper's Fig-4 pipeline) replaces one broadcast per band. Pivot order
  within a row
  is ascending (earlier-band columns precede in-band columns), so the pull
  formulation is bitwise identical to the oracle by construction.

The same superstep body runs single-device (``axis_name=None``) or under
``shard_map`` with each device computing the bands it owns round-robin
(static load balancing, §IV-D).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .planner import NumericPlan

_PALLAS_DISABLED = os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1"


# --------------------------------------------------------------------------
# row-wavefront executor (single device)
# --------------------------------------------------------------------------
def factor_wavefront_sweeps_jnp(op_row, op_lane, op_piv, op_dlane, op_dst,
                                dst_flat, a_vals_ext):
    """Round-major pivot-op wavefront factorization (pure jnp reference).

    The Pallas kernel (`repro.kernels.panel_update.factor_wavefront`) runs
    this exact computation on values read from refs; both are bit-identical
    because they share this implementation.

    ``a_vals_ext``: (n+1, W) A-values on the pattern + zero scratch row;
    schedule arrays as in :class:`repro.core.factor_plan.FactorPlan`.
    Each round applies at most one pivot per row (rows distinct within a
    round by construction), so the per-round read-modify-write on the
    value array is conflict-free. Returns the factored (n, W) values.
    """
    NR, MO = op_row.shape
    n = a_vals_ext.shape[0] - 1
    idx = jnp.arange(MO)

    def round_step(vals, inp):
        rows, lanes, pivs, dlanes, ids = inp
        valid = rows < n  # padding ops target the scratch row
        x = vals[rows]  # (MO, W)
        pv = vals[pivs]  # (MO, W) — pivot rows, final since earlier rounds
        pdiag = jnp.where(valid, pv[idx, dlanes], jnp.float32(1))
        xp = x[idx, lanes]
        l = xp / pdiag
        # multiply-then-subtract, product rounded to f32 before the add
        # (no FMA contraction) — the oracle's exact arithmetic
        contrib = lax.optimization_barrier(l[:, None] * pv)
        dd = dst_flat[ids]  # (MO, W); pad op -> all lanes dropped
        x = jax.vmap(lambda xr, dr, cr: xr.at[dr].add(-cr, mode="drop"))(x, dd, contrib)
        x = x.at[idx, lanes].set(jnp.where(valid, l, xp))
        return vals.at[rows].set(x), None

    vals, _ = lax.scan(round_step, a_vals_ext, (op_row, op_lane, op_piv, op_dlane, op_dst))
    return vals[:n]


def make_wavefront_factorizer(plan, use_pallas: bool = True):
    """Compiled ``(n+1, W) -> (n, W)`` factorizer over a FactorPlan.

    The schedule arrays live on device (cached on the plan); the returned
    callable is jitted once and reused for every refactorization of the
    same structure. ``use_pallas`` routes through the fused Pallas kernel
    (`repro.kernels.ops.factor_wavefront`); the jnp path is the
    bit-identical reference.
    """
    dev = plan.device_arrays()
    if use_pallas and not _PALLAS_DISABLED:
        from repro.kernels import ops  # deferred: keep core importable alone

        def _raw(vals):
            return ops.factor_wavefront(
                dev["op_row"], dev["op_lane"], dev["op_piv"],
                dev["op_dlane"], dev["op_dst"], dev["dst_flat"], vals,
            )
    else:
        def _raw(vals):
            return factor_wavefront_sweeps_jnp(
                dev["op_row"], dev["op_lane"], dev["op_piv"],
                dev["op_dlane"], dev["op_dst"], dev["dst_flat"], vals,
            )

    return jax.jit(lambda vals: _raw(jnp.asarray(vals, jnp.float32)))


# --------------------------------------------------------------------------
# band superstep executor (TOP-ILU, single- or multi-device, sharded values)
# --------------------------------------------------------------------------
def make_superstep_factorizer(
    plan: NumericPlan,
    axis_name: Optional[str] = None,
    broadcast: str = "psum",
):
    """Build the jit-able band-superstep numeric factorization body.

    Value storage is **sharded**: each device carries only its
    ``[local | halo | scratch]`` state (``s_loc + H + 1`` rows, not
    ``n_pad``) and the schedule/gather tables for the rows it owns. Every
    argument of the returned function is a *device-local block* with a
    leading device axis of 1 (the shape ``shard_map`` hands over when the
    host array is sharded along that axis — see
    :func:`plan_device_arrays` / ``plan_shard_specs``):

    state      (1, s_loc+H+1, W) f32 — band-local A values | halo | scratch
    sched      (n_sup, 1, MPD) i32 — this device's bands per superstep
    piv_addr   (1, s_loc, MP) i32 — device-local pivot-read addresses
    piv_dlane  (1, s_loc, MP) i32 — pivot row's diagonal lane
    piv_dst    (1, s_loc, MP, W) i32 — destination lanes ([0, W]; W = drop)
    n_piv      (1, s_loc) i32 — pivots per row (diag position)
    egress     (n_sup, 1, E) i32 — local addrs of rows to ship per superstep
    ingress    (n_sup, 1, D, E) i32 — halo addrs of received rows (pad=scratch)

    Returns this device's factored local values ``(1, s_loc, W)``.

    Per superstep: finish the owned bands of the wave (in-band pivots pulled
    from the band buffer being built, everything else from local/halo state
    through the precomputed ``piv_addr``), then exchange *only the finalized
    pivot rows some other device consumes* — one ``all_gather`` of the
    (E, W) egress payload (``broadcast="psum"`` kept as the historical alias
    for this XLA-collective fast path) or an explicit ``ppermute`` directed
    ring (the paper's Fig-4 pipeline) that forwards the payload D-1 hops and
    scatters each hop through the sender's ingress row. Both paths only
    *copy* finalized f32 rows (no arithmetic on the wire), so the exchange
    cannot perturb a single bit.
    """
    R = plan.band_rows
    B = plan.n_bands
    D = plan.n_devices
    # a multi-device plan without an axis would silently factor only device
    # 0's bands (me=0, no exchange) — fail fast instead
    assert axis_name is not None or D == 1, \
        f"plan built for {D} devices needs axis_name"
    W = plan.width
    MP = plan.max_piv
    S_loc = plan.s_loc
    H = plan.halo_size
    E = plan.egress_max
    scratch = S_loc + H
    n_sup = plan.n_supersteps
    exchange = axis_name is not None and D > 1 and H > 0
    if broadcast == "psum":  # historical alias: the XLA-collective fast path
        broadcast = "gather"
    assert broadcast in ("gather", "ring")

    def factorize(state, sched, piv_addr, piv_dlane, piv_dst, n_piv, egress, ingress):
        state = state[0]  # (S_loc+H+1, W) — this device's value state
        piv_addr, piv_dlane = piv_addr[0], piv_dlane[0]
        piv_dst, n_piv = piv_dst[0], n_piv[0]
        me = lax.axis_index(axis_name) if axis_name is not None else jnp.int32(0)

        def superstep(s, state):
            my_bands = lax.dynamic_slice(
                sched, (s, 0, 0), (1, 1, sched.shape[2]))[0, 0]  # (MPD,)

            def do_band(b):
                live = b < B
                g = jnp.where(live, b // jnp.int32(D), 0)  # owner-local band
                base = (g * R).astype(jnp.int32)
                rows = base + jnp.arange(R, dtype=jnp.int32)
                buf = state[rows]  # (R, W) — the band's A values

                def row_step(r, buf):
                    x = buf[r]
                    jl = base + r  # device-local row index

                    def piv_step(p, x):
                        addr = piv_addr[jl, p]
                        valid = p < n_piv[jl]
                        li = addr - base
                        in_band = (li >= 0) & (li < R)
                        # pull: in-band pivots from the buffer being built,
                        # finalized rows from local storage or the halo
                        pvals = jnp.where(in_band, buf[jnp.clip(li, 0, R - 1)], state[addr])
                        piv = jnp.where(valid, pvals[piv_dlane[jl, p]], jnp.float32(1))
                        xp = x[jnp.minimum(p, W - 1)]
                        l = xp / piv
                        contrib = lax.optimization_barrier(l * pvals)
                        x = x.at[piv_dst[jl, p]].add(-contrib, mode="drop")
                        return x.at[jnp.minimum(p, W - 1)].set(jnp.where(valid, l, xp))

                    x = lax.fori_loop(0, MP, piv_step, x)
                    return buf.at[r].set(x)

                buf = lax.fori_loop(0, R, row_step, buf)
                # padded bands write into the scratch row (garbage allowed
                # there: scratch reads feed only dropped scatter lanes)
                return buf, jnp.where(live, rows, jnp.int32(scratch))

            # bands of a superstep are independent; a fori (not vmap — the
            # optimization_barrier has no batching rule) fills this device's
            # members, while other devices process theirs concurrently
            def band_loop(gi, carry):
                bufs, wrows = carry
                buf, rw = do_band(my_bands[gi])
                return bufs.at[gi].set(buf), wrows.at[gi].set(rw)

            mpd = my_bands.shape[0]
            bufs, wrows = lax.fori_loop(
                0, mpd, band_loop,
                (jnp.zeros((mpd, R, W), jnp.float32),
                 jnp.full((mpd, R), scratch, jnp.int32)),
            )
            state = state.at[wrows.reshape(-1)].set(bufs.reshape(-1, W))

            if exchange:
                eg = lax.dynamic_slice(egress, (s, 0, 0), (1, 1, E))[0, 0]  # (E,)
                payload = state[eg]  # (E, W) — finalized rows others consume
                ing = lax.dynamic_slice(
                    ingress, (s, 0, 0, 0), (1, 1, D, E))[0, 0]  # (D, E)
                if broadcast == "gather":
                    all_p = lax.all_gather(payload, axis_name)  # (D, E, W)
                    state = state.at[ing.reshape(-1)].set(all_p.reshape(-1, W))
                else:  # explicit directed ring — the paper's Fig-4 pipeline
                    perm = [(d, (d + 1) % D) for d in range(D)]
                    cur = payload
                    for hop in range(1, D):
                        cur = lax.ppermute(cur, axis_name, perm)
                        src = jnp.mod(me - hop, D)  # whose payload we now hold
                        dst = jnp.take(ing, src, axis=0)  # (E,)
                        state = state.at[dst].set(cur)
            return state

        state = lax.fori_loop(0, n_sup, superstep, state)
        return state[None, :S_loc]

    return factorize


def _device_major(plan: NumericPlan, x):
    """(n_pad, ...) row table -> (D, s_loc, ...) device blocks."""
    return plan.rows_device_major(x).reshape((plan.n_devices, plan.s_loc) + x.shape[1:])


def plan_state_array(plan: NumericPlan, a=None):
    """The (D, state_rows, W) initial value state: band-local A values
    (device-major), zero halo, zero scratch. ``a=None`` uses the values
    captured at plan build; passing a matrix with the same structure
    re-scatters its current data (the refactorization path)."""
    vals = plan.a_vals if a is None else plan.scatter_values(a)
    state = np.zeros((plan.n_devices, plan.state_rows, plan.width), np.float32)
    state[:, : plan.s_loc] = _device_major(plan, vals)
    return state


def plan_device_arrays(plan: NumericPlan, keys=None):
    """Host-side inputs of the sharded superstep factorizer.

    Every per-row table is permuted device-major and reshaped to a leading
    device axis, so sharding that axis over the mesh (``plan_shard_specs``)
    gives each device exactly the rows it owns: the value state and the
    per-row gather tables (``piv_*``) are ``O(n_pad/D)`` per device, never
    replicated. (The small per-superstep schedules scale differently —
    ``sched``/``egress`` are O(n_sup·MPD)/O(n_sup·E) per device and
    ``ingress`` O(n_sup·D·E), index entries only.) ``keys`` restricts which
    arrays are built — the value ``state`` is the expensive one and most
    callers rebuild it per factorization from ``plan_state_array``.
    """
    def dm(x):
        return _device_major(plan, x)

    builders = dict(
        state=lambda: plan_state_array(plan),
        sched=lambda: plan.superstep_bands,
        piv_addr=lambda: dm(plan.piv_addr),
        piv_dlane=lambda: dm(plan.piv_dlane),
        piv_dst=lambda: dm(plan.piv_dst),
        n_piv=lambda: dm(plan.diag_pos.astype(np.int32)),
        egress=lambda: plan.egress_idx,
        ingress=lambda: plan.ingress_idx,
    )
    keys = builders.keys() if keys is None else keys
    return {k: builders[k]() for k in keys}


def plan_shard_specs(axis_name: str):
    """``shard_map``/``NamedSharding`` PartitionSpecs for the factorizer
    arguments (device axis of each array in :func:`plan_device_arrays`)."""
    from jax.sharding import PartitionSpec as P

    return dict(
        state=P(axis_name, None, None),
        sched=P(None, axis_name, None),
        piv_addr=P(axis_name, None, None),
        piv_dlane=P(axis_name, None, None),
        piv_dst=P(axis_name, None, None, None),
        n_piv=P(axis_name, None),
        egress=P(None, axis_name, None),
        ingress=P(None, axis_name, None, None),
    )
