"""ILU(k)-preconditioned solver CLI (the paper's workload).

    PYTHONPATH=src python -m repro.launch.solve --n 2000 --k 1 --method gmres \
        [--backend jax|oracle|topilu] [--band-rows 32]
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--density", type=float, default=None)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--method", default="gmres", choices=["gmres", "bicgstab", "cg"])
    ap.add_argument("--backend", default="jax", choices=["jax", "oracle", "topilu"])
    ap.add_argument("--band-rows", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from repro.core import matgen
    from repro.core.solvers import solve_with_ilu

    density = args.density or min(0.08, 20.0 / args.n)
    a = matgen(args.n, density=density, seed=args.seed)
    b = np.random.default_rng(args.seed + 1).standard_normal(args.n).astype(np.float32)
    t0 = time.perf_counter()
    res, fact = solve_with_ilu(
        a, b, k=args.k, method=args.method, backend=args.backend, band_rows=args.band_rows
    )
    dt = time.perf_counter() - t0
    print(f"n={args.n} nnz={a.nnz} k={args.k} backend={args.backend}")
    print(f"fill {a.nnz} -> {fact.nnz}; symbolic {fact.symbolic_seconds:.3f}s "
          f"numeric {fact.numeric_seconds:.3f}s")
    print(f"{args.method}: {res.iterations} iterations, residual {res.residual:.2e}, "
          f"total {dt:.2f}s, converged={res.converged}")


if __name__ == "__main__":
    main()
