"""The solve service: admission → coalesce → bucketed solve → scatter.

:class:`SolveService` wires the serve layer together around a synchronous
tick loop (run :meth:`tick` by hand in tests, or hang a
:class:`~repro.serve.dispatcher.Dispatcher` thread off the service for the
deployment shape):

* :meth:`submit` validates a request, pins the target matrix's *current*
  value binding, and enqueues; every malformed input fails that one
  request with a structured :class:`SolveResponse` — nothing malformed
  ever reaches a batch.
* :meth:`tick` drains the queue, coalesces compatible requests across
  tenants (``coalescer.coalesce``), runs one bucketed multi-RHS solve per
  batch on the pre-warmed engine, and scatters per-lane results back into
  per-request responses (per-request convergence from per-lane residual
  freezing; per-request tolerance rides as a vmapped lane argument).
* :meth:`warmup` AOT-compiles every resident engine for every bucket and
  pins the compile baseline — after it returns, a flat
  ``compiles.after_warmup`` is the service's core SLO invariant.

Degradation ladder (per batch, in order):

1. **Deadline sweep** — requests whose ``expires_at`` passed fail with
   ``DEADLINE_EXCEEDED`` before occupying a lane (and again after the
   solve, if the batch itself blew the budget).
2. **Quarantine** — if the engine *raises* on a multi-lane batch, each
   live request is re-dispatched solo: one poisoned lane costs one
   request, the co-batched survivors still get their (bitwise-identical)
   answers. A solo failure is a structured ``SOLVE_FAILED`` response.
3. **Shift retry** — lanes whose solve classifies as ``breakdown`` or
   ``diverged`` get one bucketed retry against a shifted-preconditioner
   binding (``cache.degraded_binding``); recovered lanes return
   ``degraded=True`` with the shift α, unrecovered lanes fail with a
   structured ``BREAKDOWN`` response.

Bit-compat bar: a healthy response's ``x`` is bitwise identical to solving
that request alone (`solve_with_ilu` / `solve_sharded` on the same values)
— regardless of which batch, bucket, or lane position it was coalesced
into, and regardless of any *other* lane in its tick breaking down.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.sparse import CSRMatrix

from .admission import (
    BREAKDOWN,
    DEADLINE_EXCEEDED,
    SOLVE_FAILED,
    AdmissionError,
    AdmissionQueue,
    SolveRequest,
    SolveResponse,
    validate_deadline,
    validate_request,
)
from .cache import PlanCache
from .coalescer import CoalescedBatch, coalesce
from .engine import DEFAULT_MAXITER, DEFAULT_RESTART, ServeEngine, ShardedServeEngine
from .metrics import ServiceMetrics

#: solver verdicts that trigger the shift retry (everything else — even
#: ``maxiter``/``stagnated`` — returns normally with its verdict attached:
#: a slow solve is the tenant's tolerance problem, not a health problem)
_RETRY_VERDICTS = ("breakdown", "diverged")


@dataclasses.dataclass
class ServeConfig:
    """Service-wide knobs (per-matrix overrides ride on ``register_matrix``)."""

    cache_capacity: int = 8
    max_queue_depth: int = 4096
    tick_drain: Optional[int] = None      # max requests drained per tick
    k: int = 1
    restart: int = DEFAULT_RESTART
    maxiter: int = DEFAULT_MAXITER
    precond_method: str = "sweep"
    use_pallas: bool = True
    buckets: Optional[Sequence[int]] = None
    sharded: bool = False                 # ShardedServeEngine over solve_sharded
    mesh: object = None                   # sharded only
    band_rows: int = 32                   # sharded only
    # -- robustness knobs ---------------------------------------------------
    #: breakdown policy for *register-time* factorization audits
    #: ("raise" | "shift" | "fallback" | "ignore"); solve-time lane retries
    #: are governed by ``retry_on_breakdown`` below
    on_breakdown: str = "shift"
    pivot_tol: Optional[float] = None
    #: one bucketed shift-retry for lanes whose verdict is breakdown/diverged
    retry_on_breakdown: bool = True
    #: deadline applied to requests that don't carry their own (None = none)
    default_deadline_seconds: Optional[float] = None


class SolveService:
    """Multi-tenant front end over the warm bucketed solver stack."""

    def __init__(self, config: Optional[ServeConfig] = None, **kw):
        self.config = config or ServeConfig(**kw)
        self.metrics = ServiceMetrics()
        self.cache = PlanCache(capacity=self.config.cache_capacity,
                               metrics=self.metrics,
                               engine_factory=self._make_engine,
                               on_breakdown=self.config.on_breakdown,
                               pivot_tol=self.config.pivot_tol)
        self.queue = AdmissionQueue(max_depth=self.config.max_queue_depth)
        self._warmed = False
        # ticks must not interleave: the dispatcher thread and any direct
        # tick() caller (tests, drain) serialize here
        self._tick_lock = threading.Lock()

    # -- engine construction -------------------------------------------------
    def _make_engine(self, a, pattern, vals_csr, **knobs):
        cfg = self.config
        common = dict(restart=cfg.restart, maxiter=cfg.maxiter,
                      precond_method=cfg.precond_method, buckets=cfg.buckets)
        common.update(knobs)
        if cfg.sharded:
            return ShardedServeEngine(a, pattern, vals_csr, mesh=cfg.mesh,
                                      band_rows=cfg.band_rows, k=cfg.k, **common)
        return ServeEngine(a, pattern, vals_csr, use_pallas=cfg.use_pallas, **common)

    # -- tenant-facing surface -----------------------------------------------
    def register_matrix(self, matrix_id: str, a: CSRMatrix,
                        k: Optional[int] = None) -> int:
        """Make a matrix solvable; returns the initial value version."""
        entry = self.cache.register(matrix_id, a,
                                    k=self.config.k if k is None else k)
        return entry.version

    def update_matrix_values(self, matrix_id: str, data: np.ndarray,
                             background: bool = True):
        """Push new values (same structure): background refactorization +
        atomic binding swap; other tenants' solves proceed throughout."""
        return self.cache.update_values(matrix_id, data, background=background)

    def submit(self, tenant: str, matrix_id: str, b, tol: float = 1e-5,
               deadline_seconds: Optional[float] = None):
        """Admit one request. Returns the pending :class:`SolveRequest`, or a
        failed :class:`SolveResponse` if any admission check rejects — a
        malformed request costs its tenant one error, nobody else anything."""
        try:
            bv = validate_request(tenant, matrix_id, b, tol,
                                  self.cache.dim_of(matrix_id))
            dl = validate_deadline(deadline_seconds)
            if dl is None:
                dl = self.config.default_deadline_seconds
            entry, binding = self.cache.acquire(matrix_id)  # the pin
            req = SolveRequest(tenant=tenant, matrix_id=matrix_id,
                               b=bv, tol=float(tol), binding=(entry, binding),
                               deadline_seconds=dl)
            if dl is not None:
                req.expires_at = req.submitted_at + dl
            try:
                self.queue.push(req)
            except AdmissionError:
                self.cache.release(matrix_id)
                raise
        except AdmissionError as e:
            self.metrics.record_admission(False, e.reason)
            # rejects count under rejected_by_reason, not the latency
            # histograms — a 0-latency observation would skew every quantile
            return SolveResponse(
                request_id=-1, tenant=tenant, matrix_id=matrix_id, ok=False,
                error=e.detail, error_reason=e.reason)
        self.metrics.record_admission(True)
        return req

    # -- probes ----------------------------------------------------------------
    def healthz(self) -> dict:
        """Liveness: the service object is consistent and can report state."""
        return {
            "ok": True,
            "uptime_seconds": time.time() - self.metrics.started_at,
            "ticks": self.metrics.ticks,
            "queue_depth": len(self.queue),
            "resident_matrices": len(self.cache.resident_ids()),
            "warmed": self._warmed,
        }

    def readyz(self) -> dict:
        """Readiness: warmed engines exist — a request admitted now will hit
        a compiled executable, not an XLA compile."""
        resident = self.cache.resident_ids()
        ready = self._warmed and bool(resident)
        return {"ready": ready, "warmed": self._warmed,
                "resident_matrices": len(resident)}

    # -- the tick loop ---------------------------------------------------------
    def tick(self) -> List[SolveResponse]:
        """One dispatch round: drain → coalesce → solve each batch → scatter."""
        with self._tick_lock:
            t0 = time.perf_counter()
            self.metrics.record_queue_depth(len(self.queue))
            reqs = self.queue.drain(self.config.tick_drain)
            responses: List[SolveResponse] = []
            for batch in coalesce(reqs):
                responses.extend(self._run_batch(batch))
            self.metrics.record_tick(time.perf_counter() - t0)
        return responses

    # -- response builders (every terminal path funnels through these, so
    #    req.finish() always fires and the pin is released exactly once) -----
    def _fail(self, r: SolveRequest, batch, reason: str, detail: str,
              verdict: Optional[str] = None) -> SolveResponse:
        self.cache.release(r.matrix_id)
        lat = time.perf_counter() - r.submitted_at
        self.metrics.record_response(r.tenant, False, lat)
        resp = SolveResponse(
            request_id=r.request_id, tenant=r.tenant, matrix_id=r.matrix_id,
            ok=False, error=detail, error_reason=reason, latency_seconds=lat,
            batch_lanes=batch.bucket, matrix_version=batch.binding.version,
            verdict=verdict)
        r.finish(resp)
        return resp

    def _succeed(self, r: SolveRequest, batch, lane, binding) -> SolveResponse:
        self.cache.release(r.matrix_id)
        lat = time.perf_counter() - r.submitted_at
        self.metrics.record_response(r.tenant, True, lat)
        degraded = bool(getattr(binding, "degraded", False)
                        or getattr(binding, "shift", 0.0))
        if degraded:
            self.metrics.record_robustness("degraded_responses")
        resp = SolveResponse(
            request_id=r.request_id, tenant=r.tenant, matrix_id=r.matrix_id,
            ok=True, x=lane.x, iterations=lane.iterations,
            residual=lane.residual, converged=lane.converged,
            latency_seconds=lat, batch_lanes=batch.bucket,
            matrix_version=batch.binding.version, verdict=lane.verdict,
            degraded=degraded, shift=float(getattr(binding, "shift", 0.0)))
        r.finish(resp)
        return resp

    def _run_batch(self, batch, solo: bool = False) -> List[SolveResponse]:
        out: List[SolveResponse] = []
        # 1) deadline sweep: expired requests never occupy a lane
        now = time.perf_counter()
        live: List[SolveRequest] = []
        for r in batch.requests:
            if r.expires_at < now:
                self.metrics.record_robustness("deadline_expired")
                out.append(self._fail(
                    r, batch, DEADLINE_EXCEEDED,
                    f"deadline of {r.deadline_seconds}s elapsed before dispatch"))
            else:
                live.append(r)
        if not live:
            return out

        bs = np.stack([r.b for r in live])
        tols = np.asarray([r.tol for r in live], np.float32)
        t0 = time.perf_counter()
        try:
            lanes = batch.entry.engine.solve(batch.binding, bs, tols)
        except Exception as e:  # noqa: BLE001 — a batch failure must not kill the service
            dt = time.perf_counter() - t0
            self.metrics.record_batch(batch.matrix_id, 0, batch.bucket, dt)
            if len(live) > 1 and not solo:
                # 2) quarantine: one poisoned lane must not fail its
                # co-batched neighbours — re-dispatch each request alone so
                # only the broken one eats the error
                self.metrics.record_robustness("quarantined_batches")
                for r in live:
                    sub = CoalescedBatch(
                        matrix_id=batch.matrix_id, entry=batch.entry,
                        binding=batch.binding, requests=[r],
                        bucket=batch.entry.engine.bucket_for(1))
                    out.extend(self._run_batch(sub, solo=True))
                return out
            for r in live:
                out.append(self._fail(r, batch, SOLVE_FAILED, str(e)))
            return out
        dt = time.perf_counter() - t0
        self.metrics.record_batch(batch.matrix_id, len(live), batch.bucket, dt)

        # 3) verdict pass: split healthy lanes from breakdown/diverged ones
        now = time.perf_counter()
        retry: List[tuple] = []
        for r, lane in zip(live, lanes):
            if r.expires_at < now:
                self.metrics.record_robustness("deadline_expired")
                out.append(self._fail(
                    r, batch, DEADLINE_EXCEEDED,
                    f"deadline of {r.deadline_seconds}s elapsed during solve",
                    verdict=lane.verdict))
            elif lane.verdict in _RETRY_VERDICTS:
                self.metrics.record_robustness("breakdown_lanes")
                retry.append((r, lane))
            else:
                out.append(self._succeed(r, batch, lane, batch.binding))
        if not retry:
            return out

        # 4) shift retry: one bucketed re-solve of just the broken lanes
        # against a shifted-preconditioner binding for the same values
        dbind = None
        if self.config.retry_on_breakdown and not getattr(
                batch.binding, "shift", 0.0):
            dbind = self.cache.degraded_binding(batch.matrix_id, batch.binding)
        if dbind is None:
            for r, lane in retry:
                out.append(self._fail(
                    r, batch, BREAKDOWN,
                    f"solve verdict {lane.verdict!r}"
                    + ("" if self.config.retry_on_breakdown
                       else " (retry_on_breakdown disabled)"),
                    verdict=lane.verdict))
            return out
        self.metrics.record_robustness("shift_retries")
        bs2 = np.stack([r.b for r, _ in retry])
        tols2 = np.asarray([r.tol for r, _ in retry], np.float32)
        t0 = time.perf_counter()
        try:
            lanes2 = batch.entry.engine.solve(dbind, bs2, tols2)
        except Exception as e:  # noqa: BLE001
            for r, lane in retry:
                out.append(self._fail(
                    r, batch, BREAKDOWN,
                    f"shift retry raised: {e}", verdict=lane.verdict))
            return out
        dt = time.perf_counter() - t0
        self.metrics.record_batch(batch.matrix_id, len(retry),
                                  batch.entry.engine.bucket_for(len(retry)), dt)
        for (r, lane0), lane in zip(retry, lanes2):
            if lane.verdict in _RETRY_VERDICTS:
                out.append(self._fail(
                    r, batch, BREAKDOWN,
                    f"solve verdict {lane0.verdict!r}; shift retry at "
                    f"alpha={dbind.shift:g} verdict {lane.verdict!r}",
                    verdict=lane.verdict))
            else:
                self.metrics.record_robustness("retry_recoveries")
                out.append(self._succeed(r, batch, lane, dbind))
        return out

    def run_until_idle(self, max_ticks: int = 10_000) -> List[SolveResponse]:
        """Tick until the queue drains (bounded); returns all responses."""
        out: List[SolveResponse] = []
        for _ in range(max_ticks):
            if not len(self.queue):
                break
            out.extend(self.tick())
        return out

    # -- lifecycle --------------------------------------------------------------
    def warmup(self, matrix_ids: Optional[Sequence[str]] = None) -> dict:
        """AOT-compile every (engine, bucket) pair for the given (default:
        all resident) matrices, then pin the compile baseline: every later
        ``metrics.compiles.after_warmup`` counts serving-path compiles only.
        Returns {matrix_id: {bucket: seconds}}."""
        out = {}
        for mid in (matrix_ids if matrix_ids is not None else self.cache.resident_ids()):
            e = self.cache.entry(mid)
            if e is not None:
                out[mid] = e.engine.warm(e.binding)
        self.metrics.mark_warm()
        self._warmed = True
        return out

    def drain(self, timeout: Optional[float] = None) -> List[SolveResponse]:
        """Graceful stop: finish queued work, join refactor workers."""
        out = self.run_until_idle()
        self.cache.wait_refactors(timeout)
        return out

    def metrics_snapshot(self) -> dict:
        return self.metrics.snapshot()
