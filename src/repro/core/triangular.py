"""Level-scheduled sparse triangular solves — applying the preconditioner.

Solving M x = b with M = L·U is the per-iteration cost of the preconditioned
solver (the reason the paper cares about ILU at all). A sparse triangular
solve is sequential row-to-row, but rows whose L-entries all hit previous
*levels* can run together: the classical wavefront/level schedule.

The schedule is host-side planning (like Phase I) and is built **once** per
factorization by :func:`build_triangular_plan` — fully vectorized NumPy, no
per-row Python loops. Besides the row-major ELL factors it precomputes a
*level-major* layout: rows are permuted so that each wavefront occupies one
contiguous, padded slot. The device sweep then needs no row gathers and no
scatters — per level it is one ``x[cols]`` gather, one masked lane-ordered
reduction (:func:`repro.core.bitmath.masked_lane_sum`, bit-deterministic by
construction), and one ``dynamic_update_slice``. On the 16k-row Poisson
benchmark this is ~4x faster per apply than the row-major scatter sweep.

:class:`PrecondApply` caches the plan, the device-resident arrays, and the
jitted fused L-then-U sweep (the Pallas wavefront kernel, with a jnp
fallback) so factorizations reuse one compiled apply across solves,
restarts, and RHS batches.

Also provided: a fixed-sweep Jacobi triangular solve (`jacobi_sweeps>0`) —
the TPU-friendly approximate substitution many production preconditioners
use when wavefronts are too shallow; off by default (not bit-faithful to
the exact solve).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .bitmath import masked_lane_sum
from .planner import COL_SENTINEL, wavefront_schedule_ell
from .sparse import ILUPattern


@dataclasses.dataclass
class TriangularPlan:
    """Padded wavefront schedule + ELL factors for L and U.

    Row-major fields (``l_cols`` … ``u_levels``) describe the classical
    schedule; the ``*_lm`` fields are the level-major execution layout:
    row ``l_levels[l, i]`` lives at slot ``l * maxr + i`` of the sweep
    vector, column indices are pre-remapped into slot space (padding points
    at the scratch slot ``n_slots``), and the right-hand side is fetched via
    one precomputed gather.
    """

    n: int
    # unit-lower factor rows (strictly-below-diagonal entries)
    l_cols: np.ndarray  # (n, WL) int32, sentinel-padded
    l_vals: np.ndarray  # (n, WL) f32
    # upper factor rows (above-diagonal entries) + diagonal
    u_cols: np.ndarray  # (n, WU) int32
    u_vals: np.ndarray  # (n, WU) f32
    diag: np.ndarray  # (n,) f32
    l_levels: np.ndarray  # (nl_levels, max_rows) int32, n-padded
    u_levels: np.ndarray  # (nu_levels, max_rows) int32, n-padded

    # --- level-major execution layout (see class docstring) ---------------
    nl_slots: int  # nl_levels * l_max_rows
    nu_slots: int
    l_cols_lm: np.ndarray  # (nl_levels, max_rows, WL) int32, slot-space, nl_slots-padded
    l_vals_lm: np.ndarray  # (nl_levels, max_rows, WL) f32
    l_rhs_idx: np.ndarray  # (nl_levels, max_rows) int32 into b_ext (padding -> n)
    u_cols_lm: np.ndarray  # (nu_levels, max_rows, WU) int32, slot-space, nu_slots-padded
    u_vals_lm: np.ndarray  # (nu_levels, max_rows, WU) f32
    u_diag_lm: np.ndarray  # (nu_levels, max_rows) f32, 1-padded
    u_rhs_idx: np.ndarray  # (nu_levels, max_rows) int32 into the L sweep vector
    u_out_perm: np.ndarray  # (n,) int32: x[j] = x_u_sweep[u_out_perm[j]]

    @property
    def depth(self) -> int:
        return self.l_levels.shape[0] + self.u_levels.shape[0]

    def device_arrays(self) -> dict:
        """The jnp arrays the fused wavefront sweep consumes, in call order."""
        return {
            "l_cols": jnp.asarray(self.l_cols_lm),
            "l_vals": jnp.asarray(self.l_vals_lm),
            "l_rhs_idx": jnp.asarray(self.l_rhs_idx),
            "u_cols": jnp.asarray(self.u_cols_lm),
            "u_vals": jnp.asarray(self.u_vals_lm),
            "u_diag": jnp.asarray(self.u_diag_lm),
            "u_rhs_idx": jnp.asarray(self.u_rhs_idx),
            "out_perm": jnp.asarray(self.u_out_perm),
        }


def _split_lu_ell(pattern: ILUPattern, vals: np.ndarray):
    """Vectorized CSR -> (L, U, diag) sentinel-padded ELL split."""
    n = pattern.n
    nnz = pattern.nnz
    indptr = pattern.indptr
    rowlen = np.diff(indptr)
    row_of = np.repeat(np.arange(n), rowlen)
    pos = np.arange(nnz, dtype=np.int64) - indptr[row_of]
    dpos = pattern.diag_ptr[row_of].astype(np.int64)
    lmask = pos < dpos
    umask = pos > dpos
    diag = vals[indptr[:-1] + pattern.diag_ptr].astype(np.float32)
    WL = max(int(pattern.diag_ptr.max(initial=0)), 1)
    WU = max(int((rowlen - pattern.diag_ptr - 1).max(initial=0)), 1)
    l_cols = np.full((n, WL), COL_SENTINEL, np.int32)
    l_vals = np.zeros((n, WL), np.float32)
    u_cols = np.full((n, WU), COL_SENTINEL, np.int32)
    u_vals = np.zeros((n, WU), np.float32)
    l_cols[row_of[lmask], pos[lmask]] = pattern.indices[lmask]
    l_vals[row_of[lmask], pos[lmask]] = vals[lmask]
    upos = pos - dpos - 1
    u_cols[row_of[umask], upos[umask]] = pattern.indices[umask]
    u_vals[row_of[umask], upos[umask]] = vals[umask]
    return l_cols, l_vals, u_cols, u_vals, diag


def _level_major(levels: np.ndarray, cols: np.ndarray, vals: np.ndarray, n: int):
    """Gather row-major ELL rows into the (nlev, maxr, W) level-major layout.
    Padding rows get all-sentinel columns and zero values."""
    pad = levels >= n
    rows_c = np.minimum(levels, max(n - 1, 0))
    c = np.where(pad[:, :, None], COL_SENTINEL, cols[rows_c]).astype(np.int32)
    v = np.where(pad[:, :, None], 0.0, vals[rows_c]).astype(np.float32)
    return c, v


def _slot_of_row(levels: np.ndarray, n: int) -> np.ndarray:
    """Map row id -> its slot index ``level * maxr + rank`` in the sweep vector."""
    slot = np.zeros(n, dtype=np.int64)
    flat = levels.reshape(-1).astype(np.int64)
    valid = flat < n
    slot[flat[valid]] = np.nonzero(valid)[0]
    return slot


def build_triangular_plan(pattern: ILUPattern, vals: np.ndarray) -> TriangularPlan:
    n = pattern.n
    l_cols, l_vals, u_cols, u_vals, diag = _split_lu_ell(pattern, vals)
    # the shared vectorized Kahn scheduler (repro.core.planner) builds both
    # sweeps' wavefronts — same primitive as the factorization plan
    l_levels = wavefront_schedule_ell(l_cols, n)
    # U solve runs bottom-up; dependencies are the above-diagonal columns
    u_levels = wavefront_schedule_ell(u_cols, n)

    # --- level-major execution layout ------------------------------------
    nl_slots = int(l_levels.size)
    nu_slots = int(u_levels.size)
    slot_l = _slot_of_row(l_levels, n)
    slot_u = _slot_of_row(u_levels, n)

    lc, lv = _level_major(l_levels, l_cols, l_vals, n)
    # remap dependency columns (row ids) into L slot space; sentinel -> scratch
    lc_m = np.where(
        lc < COL_SENTINEL, slot_l[np.minimum(lc, max(n - 1, 0))], nl_slots
    ).astype(np.int32)
    l_rhs_idx = l_levels.astype(np.int32)  # padding slots already hold n (the zero slot)

    uc, uv = _level_major(u_levels, u_cols, u_vals, n)
    uc_m = np.where(
        uc < COL_SENTINEL, slot_u[np.minimum(uc, max(n - 1, 0))], nu_slots
    ).astype(np.int32)
    pad_u = u_levels >= n
    rows_u = np.minimum(u_levels, max(n - 1, 0))
    u_diag_lm = np.where(pad_u, 1.0, diag[rows_u]).astype(np.float32)
    # the U right-hand side is the L sweep output, gathered from L slot space
    u_rhs_idx = np.where(pad_u, nl_slots, slot_l[rows_u]).astype(np.int32)
    u_out_perm = slot_u.astype(np.int32)

    return TriangularPlan(
        n=n, l_cols=l_cols, l_vals=l_vals, u_cols=u_cols, u_vals=u_vals,
        diag=diag, l_levels=l_levels, u_levels=u_levels,
        nl_slots=nl_slots, nu_slots=nu_slots,
        l_cols_lm=lc_m, l_vals_lm=lv, l_rhs_idx=l_rhs_idx,
        u_cols_lm=uc_m, u_vals_lm=uv, u_diag_lm=u_diag_lm,
        u_rhs_idx=u_rhs_idx, u_out_perm=u_out_perm,
    )


class PrecondApply:
    """Cached, device-resident application of M^{-1} = (LU)^{-1}.

    Builds the triangular plan once (vectorized host planning), keeps the
    level-major arrays on device, and exposes

    * ``apply(b)`` / ``__call__`` — jitted fused L-then-U wavefront sweep
      for a single right-hand side, safe to call inside outer jitted code
      (it traces inline, so a whole Krylov solve stays one dispatch);
    * ``batched(B)`` — the same sweep ``vmap``-ped over a batch of RHS.

    ``use_pallas=True`` routes through the fused Pallas wavefront kernel
    (`repro.kernels.ops.tri_solve_wavefront`); the jnp path is the
    bit-identical reference (both reduce via ``masked_lane_sum``).
    """

    def __init__(self, pattern: ILUPattern, vals: np.ndarray,
                 use_pallas: bool = True, plan: Optional[TriangularPlan] = None):
        self.plan = plan if plan is not None else build_triangular_plan(pattern, vals)
        self.n = self.plan.n
        self._dev = self.plan.device_arrays()
        if use_pallas:
            from repro.kernels import ops  # deferred: keep core importable alone

            def _raw(b):
                return ops.tri_solve_wavefront(
                    self._dev["l_cols"], self._dev["l_vals"], self._dev["l_rhs_idx"],
                    self._dev["u_cols"], self._dev["u_vals"], self._dev["u_diag"],
                    self._dev["u_rhs_idx"], self._dev["out_perm"], b,
                )
        else:
            def _raw(b):
                return wavefront_sweeps_jnp(
                    self._dev["l_cols"], self._dev["l_vals"], self._dev["l_rhs_idx"],
                    self._dev["u_cols"], self._dev["u_vals"], self._dev["u_diag"],
                    self._dev["u_rhs_idx"], self._dev["out_perm"], b,
                )
        self._apply = jax.jit(lambda b: _raw(b.astype(jnp.float32)))
        self._batched = jax.jit(jax.vmap(self._apply))

    def __call__(self, b):
        return self._apply(b)

    apply = __call__

    def batched(self, bs):
        """Apply M^{-1} to a (batch, n) stack of right-hand sides."""
        return self._batched(bs)


def wavefront_sweeps_jnp(l_cols, l_vals, l_rhs_idx, u_cols, u_vals, u_diag,
                         u_rhs_idx, out_perm, b):
    """Fused L-then-U level-major wavefront sweep (pure jnp reference).

    The Pallas kernel (`repro.kernels.tri_solve_wavefront`) runs this exact
    computation on values read from refs; both are bit-identical because all
    reductions go through ``masked_lane_sum``.
    """
    nl_lev, maxr_l, _ = l_cols.shape
    nu_lev, maxr_u, _ = u_cols.shape
    nl_slots = nl_lev * maxr_l
    nu_slots = nu_lev * maxr_u
    b = b.astype(jnp.float32)
    b_ext = jnp.concatenate([b, jnp.zeros((1,), jnp.float32)])
    l_rhs = b_ext[l_rhs_idx]  # (nl_lev, maxr_l)

    def l_step(carry, inp):
        x, start = carry
        c, v, r = inp
        gathered = x[c]  # padding -> scratch slot (0)
        acc = masked_lane_sum(c, v, gathered, nl_slots)
        x = jax.lax.dynamic_update_slice(x, r - acc, (start,))
        return (x, start + maxr_l), None

    x_l = jnp.zeros(nl_slots + 1, jnp.float32)
    (x_l, _), _ = jax.lax.scan(l_step, (x_l, 0), (l_cols, l_vals, l_rhs))

    u_rhs = x_l[u_rhs_idx]  # (nu_lev, maxr_u) — y gathered from L slot space

    def u_step(carry, inp):
        x, start = carry
        c, v, r, d = inp
        gathered = x[c]
        acc = masked_lane_sum(c, v, gathered, nu_slots)
        x = jax.lax.dynamic_update_slice(x, (r - acc) / d, (start,))
        return (x, start + maxr_u), None

    x_u = jnp.zeros(nu_slots + 1, jnp.float32)
    (x_u, _), _ = jax.lax.scan(u_step, (x_u, 0), (u_cols, u_vals, u_rhs, u_diag))
    return x_u[out_perm]


# --------------------------------------------------------------------------
# band-partitioned triangular plan + sharded preconditioner apply
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ShardedTriangularPlan:
    """Device-grouped level-major schedule over band-owned rows (DESIGN.md §5).

    The wavefront levels are the same as :class:`TriangularPlan`'s; within
    each level, rows are grouped by their *band owner* (``(j // R) % D``),
    so the slot space is ``level × device × rank`` and every per-row table
    carries a leading device axis that shards over the mesh. L/U **values
    are never materialized on the host**: each device extracts its own
    level-major L/U/diag shards from its local factorization ELL block via
    the ``*_src`` / ``*_lane`` gathers (the ones-lane trick supplies the
    unit padding diagonal), so the factors stay sharded end-to-end. Only
    the O(n) sweep vector is replicated — per level, one ``all_gather`` of
    each device's (maxr,) results extends it, which is a pure copy of f32
    values and therefore bit-transparent.
    """

    n: int
    n_devices: int
    band_rows: int
    s_loc: int  # local factor-ELL rows per device
    width: int  # W — the factorization ELL width
    nl_levels: int
    maxr_l: int  # rows per (level, device), L sweep
    nu_levels: int
    maxr_u: int
    WL: int
    WU: int

    # per-device tables, leading axis D (sharded over the mesh's band axis)
    l_src: np.ndarray  # (D, nl, maxr_l) int32 — local ELL row (pad -> s_loc)
    l_lane: np.ndarray  # (D, nl, maxr_l, WL) int32 — ELL lane (pad -> W: zeros)
    l_cols: np.ndarray  # (D, nl, maxr_l, WL) int32 — slot-space deps (pad -> nl_slots)
    l_rhs: np.ndarray  # (D, nl, maxr_l) int32 — into b_ext (pad -> n)
    u_src: np.ndarray  # (D, nu, maxr_u) int32
    u_lane: np.ndarray  # (D, nu, maxr_u, WU) int32
    u_cols: np.ndarray  # (D, nu, maxr_u, WU) int32 — slot-space (pad -> nu_slots)
    u_dlane: np.ndarray  # (D, nu, maxr_u) int32 — diag ELL lane (pad -> W+1: ones)
    u_rhs: np.ndarray  # (D, nu, maxr_u) int32 — into L slot space (pad -> nl_slots)
    out_perm: np.ndarray  # (n,) int32: x[j] = x_u_sweep[out_perm[j]] (replicated)

    @property
    def nl_slots(self) -> int:
        return self.nl_levels * self.n_devices * self.maxr_l

    @property
    def nu_slots(self) -> int:
        return self.nu_levels * self.n_devices * self.maxr_u

    def per_device_factor_bytes(self) -> int:
        """f32 bytes of L/U/diag value storage each device holds."""
        return 4 * (self.nl_levels * self.maxr_l * self.WL
                    + self.nu_levels * self.maxr_u * (self.WU + 1))


def build_sharded_triangular_plan(pattern: ILUPattern, band_rows: int,
                                  n_devices: int) -> ShardedTriangularPlan:
    """Structure-only host planning for the band-partitioned sweeps.

    Consumes no values — the value gathers it emits are resolved on device
    against each device's local factorization ELL block, so building the
    solve plan never pulls the factors off the mesh.
    """
    n = pattern.n
    D, R = n_devices, band_rows
    bands = -(-n // R)
    bands = -(-bands // D) * D
    s_loc = (bands // D) * R

    rowlen = np.diff(pattern.indptr).astype(np.int64)
    dp = pattern.diag_ptr.astype(np.int64)
    W = max(int(rowlen.max(initial=0)), 1)
    WL = max(int(dp.max(initial=0)), 1)
    WU = max(int((rowlen - dp - 1).max(initial=0)), 1)

    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    pos = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[row_of]
    lmask = pos < dp[row_of]
    umask = pos > dp[row_of]
    l_cols_rm = np.full((n, WL), COL_SENTINEL, np.int32)
    l_lane_rm = np.full((n, WL), W, np.int32)  # pad -> the zeros lane
    l_cols_rm[row_of[lmask], pos[lmask]] = pattern.indices[lmask]
    l_lane_rm[row_of[lmask], pos[lmask]] = pos[lmask]
    upos = pos - dp[row_of] - 1
    u_cols_rm = np.full((n, WU), COL_SENTINEL, np.int32)
    u_lane_rm = np.full((n, WU), W, np.int32)
    u_cols_rm[row_of[umask], upos[umask]] = pattern.indices[umask]
    u_lane_rm[row_of[umask], upos[umask]] = pos[umask]

    l_levels = wavefront_schedule_ell(l_cols_rm, n)
    u_levels = wavefront_schedule_ell(u_cols_rm, n)

    rows_all = np.arange(n, dtype=np.int64)
    owner = (rows_all // R) % D
    loc = (rows_all // R // D) * R + rows_all % R

    def group(levels):
        """Within each level, group rows by owning device; slot =
        ``level * (D*maxr) + device * maxr + rank``."""
        nlev = levels.shape[0]
        lv, rk = np.nonzero(levels < n)
        rows = levels[lv, rk].astype(np.int64)
        own = owner[rows]
        order = np.lexsort((rows, own, lv))
        lv_s, own_s, rows_s = lv[order], own[order], rows[order]
        key = lv_s * D + own_s
        cnt = np.bincount(key, minlength=nlev * D)
        maxr = max(int(cnt.max(initial=0)), 1)
        start = np.zeros(nlev * D, np.int64)
        np.cumsum(cnt[:-1], out=start[1:])
        rank = np.arange(rows_s.size, dtype=np.int64) - start[key]
        table = np.full((D, nlev, maxr), np.int64(n), np.int64)
        table[own_s, lv_s, rank] = rows_s
        slot_of = np.zeros(n, np.int64)
        slot_of[rows_s] = lv_s * (D * maxr) + own_s * maxr + rank
        return table, slot_of, maxr

    l_tab, slot_l, maxr_l = group(l_levels)
    u_tab, slot_u, maxr_u = group(u_levels)
    nl, nu = l_levels.shape[0], u_levels.shape[0]
    nl_slots = nl * D * maxr_l
    nu_slots = nu * D * maxr_u

    pad_l = l_tab >= n
    rows_l = np.minimum(l_tab, max(n - 1, 0))
    l_src = np.where(pad_l, s_loc, loc[rows_l]).astype(np.int32)
    l_rhs = np.where(pad_l, n, l_tab).astype(np.int32)
    lc = np.where(pad_l[..., None], COL_SENTINEL, l_cols_rm[rows_l])
    l_cols = np.where(
        lc < COL_SENTINEL, slot_l[np.minimum(lc, max(n - 1, 0))], nl_slots
    ).astype(np.int32)
    l_lane = np.where(pad_l[..., None], W, l_lane_rm[rows_l]).astype(np.int32)

    pad_u = u_tab >= n
    rows_u = np.minimum(u_tab, max(n - 1, 0))
    u_src = np.where(pad_u, s_loc, loc[rows_u]).astype(np.int32)
    uc = np.where(pad_u[..., None], COL_SENTINEL, u_cols_rm[rows_u])
    u_cols = np.where(
        uc < COL_SENTINEL, slot_u[np.minimum(uc, max(n - 1, 0))], nu_slots
    ).astype(np.int32)
    u_lane = np.where(pad_u[..., None], W, u_lane_rm[rows_u]).astype(np.int32)
    u_dlane = np.where(pad_u, W + 1, dp[rows_u]).astype(np.int32)  # pad -> ones
    u_rhs = np.where(pad_u, nl_slots, slot_l[rows_u]).astype(np.int32)

    return ShardedTriangularPlan(
        n=n, n_devices=D, band_rows=R, s_loc=s_loc, width=W,
        nl_levels=nl, maxr_l=maxr_l, nu_levels=nu, maxr_u=maxr_u, WL=WL, WU=WU,
        l_src=l_src, l_lane=l_lane, l_cols=l_cols, l_rhs=l_rhs,
        u_src=u_src, u_lane=u_lane, u_cols=u_cols, u_dlane=u_dlane,
        u_rhs=u_rhs, out_perm=slot_u.astype(np.int32),
    )


class ShardedTriangularEngine:
    """Structure-only compiled machinery for the band-partitioned sweeps.

    Owns the placed (sharded) schedule tables and two jitted shard_maps:
    ``extract`` (local factor ELL block -> level-major L/U/diag value
    shards, on device) and ``sweep`` (the fused L-then-U level sweep).
    Built once per structure and cached on the factorization engine entry —
    refactorizations with new values rebind through the same executables
    (:class:`ShardedPrecondApply`), retrace-free.
    """

    AXIS = "band"

    def __init__(self, plan: ShardedTriangularPlan, mesh):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.compat import shard_map

        self.plan = plan
        self.mesh = mesh
        ax = self.AXIS
        D, s_loc, W = plan.n_devices, plan.s_loc, plan.width
        nl_slots, nu_slots = plan.nl_slots, plan.nu_slots
        blk_l = D * plan.maxr_l
        blk_u = D * plan.maxr_u

        def put(x, rank):
            spec = P(ax, *([None] * (rank - 1)))
            return jax.device_put(x, NamedSharding(mesh, spec))

        l_src, u_src = put(plan.l_src, 3), put(plan.u_src, 3)
        l_lane, u_lane = put(plan.l_lane, 4), put(plan.u_lane, 4)
        u_dlane = put(plan.u_dlane, 3)
        l_cols, u_cols = put(plan.l_cols, 4), put(plan.u_cols, 4)
        l_rhs, u_rhs = put(plan.l_rhs, 3), put(plan.u_rhs, 3)
        out_perm = jnp.asarray(plan.out_perm)

        def extract(loc, ls, ll, us, ul, ud):
            # local ELL block + a zeros lane (W) and a ones lane (W+1) so
            # padded gathers land on the right neutral element
            ext = jnp.zeros((s_loc + 1, W + 2), jnp.float32)
            ext = ext.at[:s_loc, :W].set(loc[0])
            ext = ext.at[:, W + 1].set(1.0)
            lv = ext[ls[0][..., None], ll[0]]  # (nl, maxr_l, WL)
            uv = ext[us[0][..., None], ul[0]]  # (nu, maxr_u, WU)
            dg = ext[us[0], ud[0]]  # (nu, maxr_u); pads -> 1.0
            return lv[None], uv[None], dg[None]

        sm_extract = shard_map(
            extract, mesh=mesh,
            in_specs=(P(ax, None, None), P(ax, None, None), P(ax, None, None, None),
                      P(ax, None, None), P(ax, None, None, None), P(ax, None, None)),
            out_specs=(P(ax, None, None, None), P(ax, None, None, None),
                       P(ax, None, None)),
            check_vma=False,
        )
        self.extract = jax.jit(lambda loc: sm_extract(
            loc, l_src, l_lane, u_src, u_lane, u_dlane))

        def sweep(lc, lv, lr, uc, uv, dg, ur, perm, b):
            lc, lv, lr = lc[0], lv[0], lr[0]
            uc, uv, dg, ur = uc[0], uv[0], dg[0], ur[0]
            b = b.astype(jnp.float32)
            b_ext = jnp.concatenate([b, jnp.zeros((1,), jnp.float32)])
            l_r = b_ext[lr]  # (nl, maxr_l)

            def l_step(carry, inp):
                x, start = carry
                c, v, r = inp
                acc = masked_lane_sum(c, v, x[c], nl_slots)
                y_all = jax.lax.all_gather(r - acc, ax)  # (D, maxr_l) — copy
                x = jax.lax.dynamic_update_slice(x, y_all.reshape(-1), (start,))
                return (x, start + blk_l), None

            x_l = jnp.zeros(nl_slots + 1, jnp.float32)
            (x_l, _), _ = jax.lax.scan(l_step, (x_l, 0), (lc, lv, l_r))
            u_r = x_l[ur]  # (nu, maxr_u) — y gathered from L slot space

            def u_step(carry, inp):
                x, start = carry
                c, v, r, d = inp
                acc = masked_lane_sum(c, v, x[c], nu_slots)
                y_all = jax.lax.all_gather((r - acc) / d, ax)
                x = jax.lax.dynamic_update_slice(x, y_all.reshape(-1), (start,))
                return (x, start + blk_u), None

            x_u = jnp.zeros(nu_slots + 1, jnp.float32)
            (x_u, _), _ = jax.lax.scan(u_step, (x_u, 0), (uc, uv, u_r, dg))
            return x_u[perm]

        sm_sweep = shard_map(
            sweep, mesh=mesh,
            in_specs=(P(ax, None, None, None), P(ax, None, None, None),
                      P(ax, None, None), P(ax, None, None, None),
                      P(ax, None, None, None), P(ax, None, None),
                      P(ax, None, None), P(None), P(None)),
            out_specs=P(None),
            check_vma=False,
        )
        self.sweep = jax.jit(lambda lv, uv, dg, b: sm_sweep(
            l_cols, lv, l_rhs, u_cols, uv, dg, u_rhs, out_perm,
            b.astype(jnp.float32)))


class ShardedPrecondApply:
    """Band-partitioned, device-resident application of M^{-1} = (LU)^{-1}.

    Consumes the sharded factorization values in place: L/U/diag shards are
    extracted *on device* from each device's local ELL block (one jitted
    shard_map) and stay sharded across every apply. The sweep itself is the
    same level-major wavefront computation as :class:`PrecondApply` — per
    row, the same lanes reduced in the same order through
    ``masked_lane_sum`` — so the result is bitwise equal to the
    single-device apply; the only distributed step is one per-level
    ``all_gather`` of finished f32 slot values (a copy, no arithmetic).

    Callable inside outer jitted code (a whole distributed Krylov solve
    traces into one dispatch). Pass a cached
    :class:`ShardedTriangularEngine` to rebind new values to the existing
    compiled executables (the refactorize→solve serving path).
    """

    def __init__(self, plan: ShardedTriangularPlan, loc_vals, mesh,
                 engine: Optional[ShardedTriangularEngine] = None):
        if engine is None:
            engine = ShardedTriangularEngine(plan, mesh)
        elif engine.plan is not plan:
            raise ValueError("ShardedPrecondApply: `engine` was compiled for "
                             "a different ShardedTriangularPlan than `plan`")
        self._engine = engine
        self.plan = engine.plan
        self.mesh = mesh
        self.n = self.plan.n
        self._lv, self._uv, self._dg = self._engine.extract(loc_vals)

    def __call__(self, b):
        return self._engine.sweep(self._lv, self._uv, self._dg, b)

    apply = __call__


def make_triangular_solver(pattern: ILUPattern, vals: np.ndarray,
                           use_pallas: bool = False) -> Callable:
    """Returns jitted ``solve(b) -> x`` applying (LU)^{-1} by substitution.

    Kept as the sequential-reference entry point (exact substitution order);
    prefer :class:`PrecondApply` when the solver will be applied repeatedly —
    it is the same computation with the plan and compilation cached.
    """
    return PrecondApply(pattern, vals, use_pallas=use_pallas)


def make_jacobi_triangular_solver(pattern: ILUPattern, vals: np.ndarray, sweeps: int = 8) -> Callable:
    """Approximate triangular solve by Jacobi iteration (x <- D^{-1}(b - R x)).

    Converges because triangular Jacobi iteration is nilpotent; ``sweeps``
    bounds the wavefront depth it can resolve. TPU-friendly: no wavefront
    schedule, every sweep is one dense-vector pass.
    """
    plan = build_triangular_plan(pattern, vals)
    n = plan.n
    l_cols = jnp.asarray(plan.l_cols)
    l_vals = jnp.asarray(plan.l_vals)
    u_cols = jnp.asarray(plan.u_cols)
    u_vals = jnp.asarray(plan.u_vals)
    diag = jnp.asarray(plan.diag)

    def _iterate(cols, vals_m, rhs, divide):
        def body(_, x):
            xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
            gathered = xg[jnp.minimum(cols, n)]
            acc = masked_lane_sum(cols, vals_m, gathered, COL_SENTINEL)
            new = rhs - acc
            if divide:
                new = new / diag
            return new
        return jax.lax.fori_loop(0, sweeps, body, jnp.zeros_like(rhs))

    @jax.jit
    def solve(b):
        b = b.astype(jnp.float32)
        y = _iterate(l_cols, l_vals, b, divide=False)
        x = _iterate(u_cols, u_vals, y, divide=True)
        return x

    return solve
