"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig, SHAPES, pad_vocab  # noqa: F401

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "starcoder2-15b": "starcoder2_15b",
    "stablelm-12b": "stablelm_12b",
    "smollm-135m": "smollm_135m",
    "hymba-1.5b": "hymba_1_5b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-tiny": "whisper_tiny",
    "xlstm-125m": "xlstm_125m",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    import dataclasses
    import importlib

    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return dataclasses.replace(mod.CONFIG)  # fresh copy


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
