"""Diagonally-dominant sparse matrix generators.

The paper evaluates on matrices from ``matgen`` (a random generator of
diagonally dominant sparse matrices) plus one real-world matrix (SPARSKIT
Driven Cavity ``e40r3000``, incompressible Navier-Stokes). We reproduce:

* :func:`matgen` — random pattern with controlled density, values in
  ``[-1, 1]``, diagonal set to ``sum(|offdiag|) + margin`` so the matrix is
  strictly diagonally dominant (the paper's standing assumption).
* :func:`convection_diffusion_2d` — a structured nonsymmetric 9-point stencil
  used as an offline surrogate for e40r3000 (the SPARSKIT file is not
  redistributable into this container; density/row-degree are matched).
* :func:`poisson_2d` — 5-point Laplacian, the classical SPD test.
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix


def matgen(n: int, density: float, seed: int = 0, margin: float = 1.0) -> CSRMatrix:
    """Random strictly diagonally dominant matrix in CSR form.

    ``density`` counts all entries (diagonal included), matching the paper's
    reported densities (e.g. n=20K at density 0.003).
    """
    rng = np.random.default_rng(seed)
    per_row = max(int(round(density * n)) - 1, 0)  # off-diagonal entries/row
    indptr = np.zeros(n + 1, dtype=np.int64)
    all_cols = []
    all_vals = []
    for j in range(n):
        m = min(per_row, n - 1)
        if m > 0:
            # sample without replacement, excluding the diagonal
            cols = rng.choice(n - 1, size=m, replace=False).astype(np.int64)
            cols[cols >= j] += 1
            cols = np.sort(cols)
            vals = rng.uniform(-1.0, 1.0, size=m).astype(np.float32)
        else:
            cols = np.zeros(0, dtype=np.int64)
            vals = np.zeros(0, dtype=np.float32)
        diag = np.float32(np.abs(vals).sum() + margin)
        pos = np.searchsorted(cols, j)
        cols = np.insert(cols, pos, j)
        vals = np.insert(vals, pos, diag)
        all_cols.append(cols.astype(np.int32))
        all_vals.append(vals)
        indptr[j + 1] = indptr[j] + len(cols)
    return CSRMatrix(
        n=n,
        indptr=indptr,
        indices=np.concatenate(all_cols),
        data=np.concatenate(all_vals),
    )


def poisson_2d(nx: int) -> CSRMatrix:
    """5-point Laplacian on an nx*nx grid (SPD, diagonally dominant)."""
    import scipy.sparse as sp

    n = nx * nx
    main = 4.0 * np.ones(n)
    side = -np.ones(n - 1)
    side[np.arange(1, n) % nx == 0] = 0.0
    updown = -np.ones(n - nx)
    a = sp.diags(
        [main, side, side, updown, updown],
        [0, 1, -1, nx, -nx],
        format="csr",
        dtype=np.float32,
    )
    return CSRMatrix.from_scipy(a)


# --------------------------------------------------------------------------
# Breakdown fixtures — matrices engineered to break ILU(k) in specific,
# deterministic ways (core/guard.py's audit + escalation ladder is the
# consumer; each fixture keeps a *structural* diagonal in every row so the
# Manteuffel shift `A + α·diag(‖row‖)` stays a pure value edit).
# --------------------------------------------------------------------------
def singular_block_matrix(n: int, density: float = 0.05, seed: int = 0) -> CSRMatrix:
    """Healthy :func:`matgen` matrix with a singular 2x2 leading block.

    Rows 0-1 are exactly ``[[1, 1], [1, 1]]`` (and nothing else), so *any*
    ILU(k) eliminates row 1 to the pivot ``1 - 1·1 = 0`` — a guaranteed,
    position-known zero pivot regardless of level-of-fill or ordering of
    the healthy remainder.
    """
    a = matgen(n, density, seed=seed)
    indptr, indices, data = a.indptr.copy(), a.indices, a.data.copy()
    keep = np.ones(len(indices), bool)
    keep[indptr[0]:indptr[2]] = False  # drop rows 0 and 1 entirely
    block_cols = np.array([0, 1, 0, 1], np.int32)
    block_vals = np.ones(4, np.float32)
    new_indices = np.concatenate([block_cols, indices[keep]])
    new_data = np.concatenate([block_vals, data[keep]])
    new_indptr = indptr.copy()
    new_indptr[1] = 2
    new_indptr[2] = 4
    new_indptr[3:] = indptr[3:] - (indptr[2] - 4)
    return CSRMatrix(n=n, indptr=new_indptr, indices=new_indices, data=new_data)


def zero_diagonal_matrix(n: int, density: float = 0.05, seed: int = 0,
                         row: int = 0) -> CSRMatrix:
    """Healthy :func:`matgen` matrix with one diagonal value zeroed.

    The diagonal entry stays *structurally* present (so shifted
    refactorization is a pure value edit) but its value is 0.0: the first
    elimination that divides by it produces inf/NaN, and the pivot audit
    flags ``row`` as a zero pivot.
    """
    a = matgen(n, density, seed=seed)
    data = a.data.copy()
    lo, hi = a.indptr[row], a.indptr[row + 1]
    dpos = lo + int(np.searchsorted(a.indices[lo:hi], row))
    data[dpos] = 0.0
    return CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices, data=data)


def indefinite_matrix(nx: int, shift: float = 3.9) -> CSRMatrix:
    """Helmholtz-like indefinite operator: 5-point Laplacian minus
    ``shift·I``. For ``shift`` inside the Laplacian's spectrum the matrix
    is symmetric indefinite — ILU pivots shrink or go negative and CG's
    ``p·Ap`` inner product can cross zero (a classic breakdown source).
    """
    a = poisson_2d(nx)
    data = a.data.copy()
    for r in range(a.n):
        lo, hi = a.indptr[r], a.indptr[r + 1]
        dpos = lo + int(np.searchsorted(a.indices[lo:hi], r))
        data[dpos] = np.float32(data[dpos] - shift)
    return CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices, data=data)


def denormal_pivot_matrix(n: int, density: float = 0.05, seed: int = 0,
                          row: int = 0, scale: float = 1e-39) -> CSRMatrix:
    """Healthy :func:`matgen` matrix with one row scaled into the
    float32 subnormal range (default diag ≈ 1e-39 < 2^-126). The pivot is
    nonzero but denormal: products against it flush toward zero and the
    audit's ``n_denormal_pivots`` / ``worst_ratio`` channels must catch it
    even though nothing is exactly 0 or NaN yet.
    """
    a = matgen(n, density, seed=seed)
    data = a.data.copy()
    lo, hi = a.indptr[row], a.indptr[row + 1]
    diag = data[lo + int(np.searchsorted(a.indices[lo:hi], row))]
    data[lo:hi] = (data[lo:hi] * np.float32(scale / float(diag))).astype(np.float32)
    return CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices, data=data)


def convection_diffusion_2d(nx: int, reynolds: float = 40.0, seed: int = 1) -> CSRMatrix:
    """Nonsymmetric convection-diffusion 9-point stencil (e40r3000 surrogate).

    Driven-cavity matrices couple velocity/pressure unknowns with ~32
    entries/row; we mimic the nonsymmetry and bandwidth with a 9-point
    stencil plus a few random couplings, then enforce weak diagonal
    dominance the way preprocessing (e.g. MC64 scaling, [5] in the paper)
    would.
    """
    rng = np.random.default_rng(seed)
    n = nx * nx
    rows, cols, vals = [], [], []

    def add(r, c, v):
        rows.append(r)
        cols.append(c)
        vals.append(v)

    conv = reynolds / nx
    for y in range(nx):
        for x in range(nx):
            r = y * nx + x
            stencil = []
            for dy in (-1, 0, 1):
                for dx in (-1, 0, 1):
                    xx, yy = x + dx, y + dy
                    if 0 <= xx < nx and 0 <= yy < nx and (dx, dy) != (0, 0):
                        # upwinded convection makes it nonsymmetric
                        w = -1.0 + conv * (dx + 0.5 * dy) + 0.05 * rng.standard_normal()
                        stencil.append((yy * nx + xx, w))
            # sprinkle two long-range couplings per row (pressure-like)
            for _ in range(2):
                c = int(rng.integers(0, n))
                if c != r:
                    stencil.append((c, 0.1 * rng.standard_normal()))
            offsum = 0.0
            for c, w in stencil:
                add(r, c, w)
                offsum += abs(w)
            add(r, r, offsum + 1.0)
    import scipy.sparse as sp

    a = sp.csr_matrix((np.asarray(vals, np.float32), (rows, cols)), shape=(n, n))
    a.sum_duplicates()
    return CSRMatrix.from_scipy(a)
