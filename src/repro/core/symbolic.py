"""Symbolic factorization — Phase I of ILU(k) (paper Algorithm 1).

Computes the filled pattern and per-entry levels. Two level rules are
supported (paper §III-B):

* ``sum``:  level(j,t) = min over h of level(j,h) + level(h,t) + 1
* ``max``:  level(j,t) = min over h of max(level(j,h), level(h,t)) + 1

Original entries of A have level 0; fill-ins with level <= k are admitted.
(The paper's Alg. 1 line 22 prints ``weight < k``; Definition 3.4 and the
standard ILU(k) literature use ``<= k``, which is what we implement.)

Three implementations, one contract:

* :func:`symbolic_ilu_k` — the production path: a *planner-style frontier
  computation*. Rows are scheduled into dependency wavefronts by the shared
  vectorized scheduler (:func:`repro.core.planner.wavefront_schedule`, the
  same Kahn frontier that builds triangular and factorization plans) and
  every wave's row-merges execute as one batched NumPy reduction — no
  per-row Python. The causative dependency graph of ILU(k) is the lower
  pattern of ILU(k-1) (see below), so the pattern is grown level-by-level:
  P_0 = pattern(A), then one frontier pass per fill level up to k.
* :func:`symbolic_ilu_k_ref` — the sequential per-row reference
  (Algorithm 1 verbatim); the test oracle for the vectorized path.
* :func:`symbolic_ilu_k_bruteforce` — O(n^3) dense levels from
  Definition 3.4; oracle for the oracle on tiny matrices.

Why the recursion in k is sound: a pivot entry (j,i) is *causative* during
the ILU(k) merge iff its level at merge time is <= k-1 (paper §III-D: a
pivot of level >= k cannot cause admissible fill). Pivot (j,i)'s level is
final by the time pivot i is processed (only pivots h < i can update it),
and under either rule an entry of level <= k-1 can only be produced by
causative pairs of level <= k-2, so the set of entries with level <= k-1 —
and their levels — is identical in ILU(k-1) and ILU(k). Hence the causative
pivots of row j are exactly the lower entries of its ILU(k-1) row: a static
dependency graph, known before the pass runs. Given that graph, the final
row j is a pure min-reduction over its base entries and the tails of its
(finalized) causative pivot rows — rows in the same wavefront share no
dependencies and reduce together.

`pilu1_symbolic` is the PILU(1) special case (§IV-F): for k=1 only level-0
(original) entries act as causative entries, so every row's pattern depends
only on rows of *A* — rows are independent and the phase needs **zero
communication** (and, here, zero waves: it is one vectorized set reduction
over all rows at once).

On TPU this phase is the host-side *planning pass* (see DESIGN.md §3): its
output (a static pattern) is what makes the numeric phase jit-able.

Row order is an *input* to this phase: ILU(k) fill — and every schedule
derived from it — depends on the order rows are given in. The ordering
layer (``repro.core.ordering``, DESIGN.md §9) therefore sits strictly
before Phase I: it permutes the matrix once, and everything here runs on
the permuted system exactly as on any other matrix.
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix, ILUPattern


# --------------------------------------------------------------------------
# shared vectorized helpers
# --------------------------------------------------------------------------
from .planner import expand_spans as _expand_spans  # noqa: E402


def _check_full_diagonal(a: CSRMatrix) -> None:
    n = a.n
    rowlen = np.diff(a.indptr)
    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    below = np.bincount(row_of[a.indices < row_of], minlength=n)
    dpos = a.indptr[:-1] + below
    ok = (dpos < a.indptr[1:]) & (a.indices[np.minimum(dpos, a.nnz - 1)] == np.arange(n))
    assert ok.all(), f"rows missing diagonal: {np.nonzero(~ok)[0][:5]}"


def _pattern_of_a(a: CSRMatrix) -> ILUPattern:
    """ILU(0) pattern: A's structure, every entry at level 0."""
    n = a.n
    rowlen = np.diff(a.indptr)
    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    below = np.bincount(row_of[a.indices < row_of], minlength=n)
    return ILUPattern(
        n=n, k=0,
        indptr=a.indptr.astype(np.int64).copy(),
        indices=a.indices.astype(np.int32).copy(),
        levels=np.zeros(a.nnz, dtype=np.int16),
        diag_ptr=below.astype(np.int32),
    )


# --------------------------------------------------------------------------
# vectorized frontier pass
# --------------------------------------------------------------------------
def _fill_pass(a: CSRMatrix, dep_pat: ILUPattern, k: int, rule: str) -> ILUPattern:
    """One frontier pass: ILU(k) pattern given dep graph = lower(ILU(k-1)).

    Every wavefront is reduced in one shot: candidate (row, col, weight)
    triples from all causative pivot tails are concatenated with the base
    entries of A, sorted by (row, col), and min-reduced per group.
    """
    from .planner import wavefront_schedule

    n = a.n
    # causative edges: strictly-lower entries of the previous-level pattern
    dep_row_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(dep_pat.indptr))
    lower = dep_pat.indices.astype(np.int64) < dep_row_of
    psrc = dep_pat.indices[lower].astype(np.int64)  # pivot row i
    pdst = dep_row_of[lower]  # reduced row j  (nondecreasing: row-major)
    plev = dep_pat.levels[lower].astype(np.int64)
    pcnt = np.bincount(pdst, minlength=n).astype(np.int64)
    pptr = np.zeros(n + 1, np.int64)
    np.cumsum(pcnt, out=pptr[1:])

    waves = wavefront_schedule(psrc, pdst, n)

    # finalized rows live in flat buffers (doubling growth, amortized O(nnz))
    cap = max(2 * a.nnz, 16)
    cols_flat = np.zeros(cap, np.int64)
    levs_flat = np.zeros(cap, np.int64)
    write = 0
    row_start = np.zeros(n, np.int64)
    row_len = np.zeros(n, np.int64)
    diag_of = np.zeros(n, np.int64)
    a_rowlen = np.diff(a.indptr).astype(np.int64)

    for wv in range(waves.shape[0]):
        J = waves[wv]
        J = J[J < n]
        # candidates: tails of every causative pivot row of every row in J
        pidx = _expand_spans(pptr[J], pcnt[J])
        pi = psrc[pidx]
        pli = plev[pidx]
        pj = np.repeat(J.astype(np.int64), pcnt[J])
        tlen = row_len[pi] - diag_of[pi] - 1
        tidx = _expand_spans(row_start[pi] + diag_of[pi] + 1, tlen)
        tcols = cols_flat[tidx]
        tlevs = levs_flat[tidx]
        cj = np.repeat(pj, tlen)
        cli = np.repeat(pli, tlen)
        if rule == "sum":
            w = cli + tlevs + 1
        else:  # max rule
            w = np.maximum(cli, tlevs) + 1
        adm = w <= k
        # base entries: A's rows at level 0
        bj = np.repeat(J.astype(np.int64), a_rowlen[J])
        bcols = a.indices[_expand_spans(a.indptr[J], a_rowlen[J])].astype(np.int64)
        j_all = np.concatenate([bj, cj[adm]])
        t_all = np.concatenate([bcols, tcols[adm]])
        w_all = np.concatenate([np.zeros(len(bj), np.int64), w[adm]])
        # group-min by (row, col)
        key = j_all * n + t_all
        order = np.argsort(key, kind="stable")
        key_s = key[order]
        head = np.ones(len(key_s), bool)
        head[1:] = key_s[1:] != key_s[:-1]
        starts = np.nonzero(head)[0]
        lev_u = np.minimum.reduceat(w_all[order], starts)
        key_u = key_s[starts]
        j_u = key_u // n
        t_u = key_u - j_u * n
        # per-row extents (rows are contiguous in the sorted keys)
        rhead = np.ones(len(j_u), bool)
        rhead[1:] = j_u[1:] != j_u[:-1]
        rstarts = np.nonzero(rhead)[0]
        rows = j_u[rstarts]
        rlens = np.diff(np.append(rstarts, len(j_u)))
        row_start[rows] = write + rstarts
        row_len[rows] = rlens
        diag_of[rows] = np.nonzero(t_u == j_u)[0] - rstarts
        end = write + len(key_u)
        if end > len(cols_flat):
            cap = max(2 * len(cols_flat), end)
            cols_flat = np.concatenate([cols_flat, np.zeros(cap - len(cols_flat), np.int64)])
            levs_flat = np.concatenate([levs_flat, np.zeros(cap - len(levs_flat), np.int64)])
        cols_flat[write:end] = t_u
        levs_flat[write:end] = lev_u
        write = end

    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(row_len, out=indptr[1:])
    gidx = _expand_spans(row_start, row_len)
    return ILUPattern(
        n=n, k=k,
        indptr=indptr,
        indices=cols_flat[gidx].astype(np.int32),
        levels=levs_flat[gidx].astype(np.int16),
        diag_ptr=diag_of.astype(np.int32),
    )


def symbolic_ilu_k(a: CSRMatrix, k: int, rule: str = "sum") -> ILUPattern:
    """Vectorized frontier symbolic ILU(k) — the production Phase I.

    Bit-for-bit the same pattern/levels as :func:`symbolic_ilu_k_ref`
    (Algorithm 1); built level-by-level with one wave-scheduled batched
    pass per fill level (see module docstring for why that is exact).
    """
    assert rule in ("sum", "max")
    _check_full_diagonal(a)
    pat = _pattern_of_a(a)
    for m in range(1, k + 1):
        pat = _fill_pass(a, pat, m, rule)
    if pat.k != k:  # k == 0: keep the requested k on the returned pattern
        pat = ILUPattern(n=pat.n, k=k, indptr=pat.indptr, indices=pat.indices,
                         levels=pat.levels, diag_ptr=pat.diag_ptr)
    return pat


# --------------------------------------------------------------------------
# PILU(1): one-shot vectorized special case (paper §IV-F)
# --------------------------------------------------------------------------
def pilu1_symbolic(a: CSRMatrix, rule: str = "sum") -> ILUPattern:
    """PILU(1): embarrassingly parallel symbolic factorization for k = 1.

    Row j's final pattern = A's row j plus every t reachable through a
    level-0 causative pair (f_{j,i}, f_{i,t}) with i < t — using only rows
    of the *original* A (under either rule such fill has weight 1). All
    rows are independent, so the whole phase is one vectorized set
    reduction: expand every (lower entry, pivot tail) pair, dedupe against
    A's entries, and merge — no per-row Python, no waves.
    """
    assert rule in ("sum", "max")  # rules agree at k=1
    _check_full_diagonal(a)
    n = a.n
    rowlen = np.diff(a.indptr).astype(np.int64)
    row_of = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    cols = a.indices.astype(np.int64)
    below_cnt = np.bincount(row_of[cols < row_of], minlength=n).astype(np.int64)
    # lower entries (j, i): the causative pivots
    lmask = cols < row_of
    pj = row_of[lmask]
    pi = cols[lmask]
    # strict-upper tail span of each pivot row i
    tlen = rowlen[pi] - below_cnt[pi] - 1
    tidx = _expand_spans(a.indptr[pi] + below_cnt[pi] + 1, tlen)
    fill_j = np.repeat(pj, tlen)
    fill_t = cols[tidx]
    # admissible fills = candidate (j,t) pairs not already entries of A
    base_key = row_of * n + cols
    cand_key = np.unique(fill_j * n + fill_t)
    fill_key = np.setdiff1d(cand_key, base_key, assume_unique=True)
    # merge base (level 0) and fills (level 1), sorted by (row, col)
    all_key = np.concatenate([base_key, fill_key])
    all_lev = np.concatenate([np.zeros(len(base_key), np.int16), np.ones(len(fill_key), np.int16)])
    order = np.argsort(all_key, kind="stable")
    key_s = all_key[order]
    j_s = key_s // n
    indices = (key_s - j_s * n).astype(np.int32)
    levels = all_lev[order]
    out_rowlen = np.bincount(j_s, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(out_rowlen, out=indptr[1:])
    diag_ptr = np.bincount(j_s[indices < j_s], minlength=n).astype(np.int32)
    return ILUPattern(n=n, k=1, indptr=indptr, indices=indices, levels=levels, diag_ptr=diag_ptr)


# --------------------------------------------------------------------------
# sequential references (test oracles)
# --------------------------------------------------------------------------
def _row_merge(cols_j, levs_j, j, k, rule, row_cols, row_levs, diag_of):
    """Reduce row j symbolically against all pivot rows i < j.

    cols_j/levs_j: current (sorted) pattern of row j. Returns final arrays.
    """
    ptr = 0
    while ptr < len(cols_j):
        i = cols_j[ptr]
        if i >= j:
            break
        li = levs_j[ptr]
        ptr += 1
        if li >= k:  # paper §III-D optimization — cannot cause admissible fill
            continue
        # tail of pivot row i: entries strictly right of the diagonal
        di = diag_of[i]
        tcols = row_cols[i][di + 1 :]
        tlevs = row_levs[i][di + 1 :]
        if len(tcols) == 0:
            continue
        if rule == "sum":
            weight = li + tlevs + 1
        else:  # max rule
            weight = np.maximum(li, tlevs) + 1
        pos = np.searchsorted(cols_j, tcols)
        in_bounds = pos < len(cols_j)
        present = np.zeros(len(tcols), dtype=bool)
        present[in_bounds] = cols_j[pos[in_bounds]] == tcols[in_bounds]
        # update existing levels
        upd = pos[present]
        levs_j[upd] = np.minimum(levs_j[upd], weight[present])
        # insert admissible fills
        newmask = (~present) & (weight <= k)
        if newmask.any():
            ncols = tcols[newmask]
            nlevs = weight[newmask]
            ipos = np.searchsorted(cols_j, ncols)
            cols_j = np.insert(cols_j, ipos, ncols)
            levs_j = np.insert(levs_j, ipos, nlevs)
            # all inserted columns are > i, so `ptr` (already past i) stays
            # valid, but positions may have shifted for un-scanned pivots:
            # recompute ptr as the index just past column i.
            ptr = int(np.searchsorted(cols_j, i, side="right"))
    return cols_j, levs_j


def symbolic_ilu_k_ref(a: CSRMatrix, k: int, rule: str = "sum") -> ILUPattern:
    """Sequential per-row symbolic ILU(k) — Algorithm 1 of the paper.

    The bit-compatibility oracle for :func:`symbolic_ilu_k`; O(n) Python
    rows, so tests only.
    """
    assert rule in ("sum", "max")
    n = a.n
    row_cols = [None] * n
    row_levs = [None] * n
    diag_of = np.zeros(n, dtype=np.int64)
    for j in range(n):
        acols, _ = a.row(j)
        cols_j = acols.astype(np.int64).copy()
        levs_j = np.zeros(len(cols_j), dtype=np.int64)
        d = np.searchsorted(cols_j, j)
        assert d < len(cols_j) and cols_j[d] == j, f"row {j}: missing diagonal"
        if k > 0:
            cols_j, levs_j = _row_merge(cols_j, levs_j, j, k, rule, row_cols, row_levs, diag_of)
        row_cols[j] = cols_j
        row_levs[j] = levs_j
        diag_of[j] = np.searchsorted(cols_j, j)
    return _pack(n, k, row_cols, row_levs, diag_of)


def _pack(n, k, row_cols, row_levs, diag_of) -> ILUPattern:
    lens = np.asarray([len(c) for c in row_cols], dtype=np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lens, out=indptr[1:])
    return ILUPattern(
        n=n,
        k=k,
        indptr=indptr,
        indices=np.concatenate(row_cols).astype(np.int32),
        levels=np.concatenate(row_levs).astype(np.int16),
        diag_ptr=diag_of.astype(np.int32),
    )


def symbolic_ilu_k_bruteforce(a: CSRMatrix, k: int, rule: str = "sum") -> np.ndarray:
    """O(n^3) dense level computation straight from Definition 3.4.

    Returns the (n, n) level matrix with np.iinfo.max for non-entries.
    Only for tests on tiny matrices.
    """
    n = a.n
    INF = np.int64(10**9)
    lev = np.full((n, n), INF, dtype=np.int64)
    for j in range(n):
        cols, _ = a.row(j)
        lev[j, cols] = 0
    for h in range(n):
        for i in range(h + 1, n):
            if lev[i, h] > k:  # not an admitted entry -> cannot be causative
                continue
            for t in range(h + 1, n):
                if lev[h, t] > k:
                    continue
                if rule == "sum":
                    w = lev[i, h] + lev[h, t] + 1
                else:
                    w = max(lev[i, h], lev[h, t]) + 1
                if w < lev[i, t] and w <= k:
                    lev[i, t] = w
    lev[lev > k] = INF
    return lev
