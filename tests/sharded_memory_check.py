"""Subprocess body for the per-device memory + collective payload tests.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=<D> \
         python tests/sharded_memory_check.py <grid_side> <band_rows>
(matrix is the 2-D Poisson operator on a grid_side x grid_side grid)

Asserts, on the simulated D-device mesh:

* the factorization value state each device materializes has the sharded
  shape ``(s_loc + halo + 1, W)`` with ``s_loc = n_pad/D`` — O(n_pad*W/D +
  halo), not the replicated ``n_pad*W``;
* the per-superstep collective payload in the *compiled HLO* (both
  broadcast variants) equals exactly the host-precomputed halo size
  ``(D-1) * E * W * 4`` bytes — the collective ships the pivot-row halo,
  nothing more;
* the *sweep* (epoch-fused preconditioner apply, DESIGN.md §5.5): compiled
  HLO collective count == the host epoch model (one exchange per non-empty
  epoch + the final assembly, strictly fewer than the ``nl + nu`` per-level
  gathers), and compiled collective bytes == the exact read-set model —
  for a single RHS and for a batch riding the same collectives.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    n, band_rows = int(sys.argv[1]), int(sys.argv[2])
    import jax

    from repro.core import pilu1_symbolic, poisson_2d
    from repro.core.top_ilu import lower_topilu, topilu_factor_sharded
    from repro.launch.mesh import make_band_mesh
    from repro.roofline.analysis import collective_bytes_per_device

    d = len(jax.devices())
    assert d >= 2
    mesh = make_band_mesh()
    a = poisson_2d(n)  # banded PDE matrix: pivot reach (and halo) O(bandwidth)
    pat = pilu1_symbolic(a)

    fact = topilu_factor_sharded(a, pat, band_rows=band_rows, mesh=mesh)
    plan = fact.plan

    # --- per-device memory: sharded, not replicated -----------------------
    assert plan.s_loc == plan.n_pad // d
    assert plan.state_rows == plan.s_loc + plan.halo_size + 1
    # the halo is a strict subset of the foreign rows: far below (D-1)/D n_pad
    assert plan.halo_size < plan.n_pad - plan.s_loc
    assert plan.per_device_value_bytes() < plan.replicated_value_bytes()
    # the device-resident output shards have the band-local shape
    shapes = {s.data.shape for s in fact.loc_vals.addressable_shards}
    assert shapes == {(1, plan.s_loc, plan.width)}, shapes

    # --- collective payload == precomputed halo size ----------------------
    for broadcast in ("psum", "ring"):
        lowered, lplan = lower_topilu(a, pat, band_rows, mesh, broadcast=broadcast)
        hlo = lowered.compile().as_text()
        per_step = sum(collective_bytes_per_device(hlo).values())
        model = lplan.halo_bytes_per_superstep(broadcast)
        assert per_step == model, (broadcast, per_step, model)
        # and it never exceeds the old full-band all-gather payload (equal
        # only when every row of every finished band is consumed downstream)
        assert model <= lplan.replicated_bytes_per_superstep(), broadcast

    # --- sweep: compiled collectives == the epoch/read-set model ----------
    from repro.roofline.analysis import collective_op_counts

    ap = fact.precond()
    tp = ap.plan
    for nb in (1, 3):
        hlo = ap._engine.lower_sweep(nb).compile().as_text()
        got_bytes = sum(collective_bytes_per_device(hlo).values())
        want_bytes = tp.sweep_bytes_per_apply(nb)
        assert got_bytes == want_bytes, ("sweep bytes", nb, got_bytes, want_bytes)
        got_cnt = sum(collective_op_counts(hlo).values())
        want_cnt = tp.sweep_collectives_per_apply()
        assert got_cnt == want_cnt, ("sweep count", nb, got_cnt, want_cnt)
        # fused below the per-level schedule, payloads within the old model
        assert got_cnt < tp.nl_levels + tp.nu_levels
        assert want_bytes <= tp.sweep_bytes_per_apply_unfused(nb)

    print(f"OK: devices={d} n={n} band_rows={band_rows} s_loc={plan.s_loc} "
          f"halo={plan.halo_size} E={plan.egress_max} "
          f"state_bytes={plan.per_device_value_bytes()} "
          f"replicated_bytes={plan.replicated_value_bytes()} "
          f"halo_B/step={plan.halo_bytes_per_superstep()} "
          f"old_B/step={plan.replicated_bytes_per_superstep()} "
          f"sweep_coll={tp.sweep_collectives_per_apply()}/"
          f"{tp.nl_levels + tp.nu_levels} "
          f"sweep_B={tp.sweep_bytes_per_apply()} sharded-memory")


if __name__ == "__main__":
    main()
