"""Pallas TPU kernel: ELL SpMV — y = A x on sentinel-padded fixed-width rows.

The per-iteration matvec of the preconditioned solver. Rows are tiled by the
grid; each step holds a (bm, W) column/value block plus the full x vector in
VMEM (x of n<=2^20 f32 = 4 MiB fits; shard x first for larger n — the
mesh-level solver does exactly that). The inner gather ``x[cols]`` is a 1-D
VMEM dynamic gather (supported natively on TPU v4+; interpret mode on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitmath import masked_lane_sum
from repro.core.planner import COL_SENTINEL

from .config import resolve_interpret


def _kernel(cols_ref, vals_ref, x_ref, o_ref):
    cols = cols_ref[...]
    vals = vals_ref[...]
    x = x_ref[...]
    n = x.shape[0]
    idx = jnp.minimum(cols, n - 1)
    gathered = x[idx]
    o_ref[...] = masked_lane_sum(cols, vals, gathered, COL_SENTINEL).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def spmv_ell(cols, vals, x, *, bm=512, interpret=True):
    """cols/vals: (n, W) sentinel-padded; x: (n,). Returns y = A @ x."""
    n, w = cols.shape
    assert vals.shape == (n, w) and x.shape == (n,)
    bm = min(bm, n)
    assert n % bm == 0
    return pl.pallas_call(
        _kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), vals.dtype),
        interpret=resolve_interpret(interpret),
    )(cols, vals, x)
