"""The README cannot drift from the API: its quickstart snippet must run.

Extracts every fenced ```python block from README.md and executes it (the
quickstart is written to be self-contained and fast). A README edit that
breaks against the real API fails here, not in a user's shell.
"""
import os
import re

README = os.path.join(os.path.dirname(__file__), "..", "README.md")

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks():
    with open(README) as f:
        text = f.read()
    return _BLOCK_RE.findall(text)


def test_readme_exists_and_has_quickstart():
    blocks = _python_blocks()
    assert len(blocks) >= 1, "README.md lost its python quickstart block"
    joined = "\n".join(blocks)
    assert "ilu(" in joined and "solve_with_ilu" in joined


def test_readme_quickstart_runs():
    for i, block in enumerate(_python_blocks()):
        exec(compile(block, f"README.md[python block {i}]", "exec"), {})
