"""xlstm-125m [ssm] — sLSTM + mLSTM blocks, no FFN (d_ff=0), O(1)-state
decode => long_500k runs. [arXiv:2405.04517; unverified].

Block layout: every third block sLSTM (the paper's a:b notation), rest mLSTM.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_real=50304,
    use_rope=False,
    block_types=["m", "m", "s"] * 4,
    scan_layers=False,  # heterogeneous blocks
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
