"""Pivot guard + shifted-refactorization ladder (breakdown hardening).

ILU(k) without pivoting breaks down silently: a zero, denormal, or
relatively tiny pivot factors into Inf/NaN that only surfaces many layers
later as a diverged solve (or a poisoned vmap batch in the serve layer).
The paper's pitch is that TPILU(k) *never* costs the robustness of
sequential ILU(k) — so the reproduction needs the sequential algorithm's
operational safeguards too, without touching a single bit of a healthy
factorization. Three pieces:

**Audit** (:func:`audit_values`, :func:`audit_sharded`) — a pure *read* of
the finished factor: non-finite values, zero/denormal pivots, and
``|piv| < τ·‖row‖_∞`` relative pivot checks, summarized per band for the
sharded TOP-ILU layout. Because the audit never feeds back into the
factorization, guarded and unguarded factors are bitwise identical — the
guard is observability, not a numerical path. The sharded audit reads the
device-resident ``(D, s_loc, W)`` value shards in place (eager jnp
reductions — no host gather of the factor, no per-structure jit compile);
the host audit reads the CSR-aligned values that are already host-resident.

**Escalation ladder** (:func:`run_ladder`) — the Manteuffel fix: refactor
``A + α·diag(‖row‖₁)`` with geometric escalation ``α_j = shift0·2^j``.
The shifted matrix has *identical* sparsity (the diagonal is already
structural), so the shifted refactorization reuses every structure-keyed
compiled engine (``FactorPlan``/TOP-ILU stores ride along via
:func:`shifted_matrix`) — a ladder rung is a value re-scatter plus an
execute, never a compile. Each shifted factor is re-anchored bitwise to
the *sequential oracle of the shifted matrix*: the bit-compat contract is
per-system, and ``A + α·D`` is just another system.

**Fallback chain** — ``ilu(k) → ilu(k, shift·2^j) → identity-precond
GMRES``, selected by ``on_breakdown`` on every factor/solve entry point:

========== =============================================================
"raise"     (default) healthy factors pass untouched; a breakdown raises
            :class:`BreakdownError` naming the offending row
"shift"     escalate through the ladder; raise only if it exhausts
"fallback"  ladder first; on exhaustion return the unshifted factor
            flagged ``degraded`` — its ``precond()`` is the identity, so
            the solve degrades to unpreconditioned GMRES instead of NaN
"ignore"    audit + attach the health report, never escalate or raise
            (the pre-guard behavior, kept for tests and triage)
========== =============================================================
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

#: default relative pivot tolerance τ for ``|piv| < τ·‖row‖_∞``
PIVOT_TOL = 1e-6
#: smallest normal float32 — pivots below this lose all relative precision
TINY_PIVOT = float(np.finfo(np.float32).tiny)
#: floor for row norms when forming |piv|/‖row‖ (an all-zero row is broken
#: regardless; the floor only keeps the ratio finite)
NORM_FLOOR = 1e-30
#: ladder defaults: α_j = SHIFT0 · 2**j, j < MAX_SHIFTS  (α up to ~2.05 —
#: at α ≈ 1 the shifted matrix is diagonally dominant for any A, so the
#: ladder terminates for every finite input)
SHIFT0 = 1e-3
MAX_SHIFTS = 12

_ON_BREAKDOWN = ("raise", "shift", "fallback", "ignore")


@dataclasses.dataclass
class FactorHealth:
    """Structured audit report riding on a factorization (pure diagnosis —
    attaching it never changes the factor's bits)."""

    ok: bool
    n: int
    pivot_tol: float
    n_nonfinite: int = 0          # non-finite entries anywhere in the factor
    n_zero_pivots: int = 0
    n_denormal_pivots: int = 0
    n_small_pivots: int = 0       # |piv| < τ·‖row‖_∞ (includes zeros)
    worst_row: int = -1           # row minimizing |piv|/‖row‖_∞
    worst_pivot: float = 0.0
    worst_ratio: float = float("inf")
    first_nonfinite_row: int = -1
    #: diagonal shift α the returned factor was built with (0 = unshifted)
    shift: float = 0.0
    #: factorizations performed, ladder rungs included
    attempts: int = 1
    #: True ⇒ the ladder exhausted under ``on_breakdown="fallback"`` and
    #: the factorization preconditions with the identity instead
    degraded: bool = False
    #: sharded TOP-ILU only: per-band min |piv|/‖row‖ in global band order
    band_worst_ratio: Optional[np.ndarray] = None

    def summary(self) -> str:
        if self.ok and self.shift == 0.0 and not self.degraded:
            return f"healthy (worst pivot ratio {self.worst_ratio:.3e} at row {self.worst_row})"
        parts = []
        if self.n_nonfinite:
            parts.append(f"{self.n_nonfinite} non-finite entries "
                         f"(first at row {self.first_nonfinite_row})")
        if self.n_zero_pivots:
            parts.append(f"{self.n_zero_pivots} zero pivots")
        if self.n_denormal_pivots:
            parts.append(f"{self.n_denormal_pivots} denormal pivots")
        if self.n_small_pivots:
            parts.append(
                f"{self.n_small_pivots} pivots below tol={self.pivot_tol:g}·‖row‖ "
                f"(worst |{self.worst_pivot:.3e}| at row {self.worst_row}, "
                f"ratio {self.worst_ratio:.3e})")
        if self.shift:
            parts.append(f"recovered with diagonal shift α={self.shift:g} "
                         f"after {self.attempts} factorization(s)")
        if self.degraded:
            parts.append("shift ladder exhausted — degraded to identity preconditioner")
        return "; ".join(parts) if parts else "healthy"


class BreakdownError(RuntimeError):
    """A factorization broke down (and the policy said not to recover)."""

    def __init__(self, health: FactorHealth, exhausted: bool = False):
        self.health = health
        self.exhausted = exhausted
        what = ("shift ladder exhausted after "
                f"{health.attempts} attempts; base factor: " if exhausted else "")
        super().__init__(f"ILU breakdown: {what}{health.summary()}")


class IdentityPrecondApply:
    """The last rung of the fallback chain: M⁻¹ = I.

    Matches the ``PrecondApply`` surface the solvers consume (callable,
    ``batched``, ``warm``) so a degraded factorization drops into every
    solve path unchanged. Identity-preconditioned GMRES through this object
    is bitwise identical to ``precond=None`` — both apply the same no-op.
    """

    def __call__(self, x):
        return x

    def batched(self, bs):
        return bs

    def warm(self, batch_sizes=(1,), *args, **kw) -> dict:
        return {int(nb): 0.0 for nb in batch_sizes}


# --------------------------------------------------------------------------
# audits (pure reads — bit-neutral by construction)
# --------------------------------------------------------------------------
def audit_values(pattern, vals: np.ndarray,
                 pivot_tol: Optional[float] = None) -> FactorHealth:
    """Audit CSR-aligned factor values (host-resident layouts).

    ``vals`` is the filled-pattern value array of an ``ILUFactorization``
    (or a serve-cache binding). O(nnz) vectorized numpy — the values are
    already on the host on these paths, so a device round-trip would cost
    more than the audit."""
    tol = PIVOT_TOL if pivot_tol is None else float(pivot_tol)
    vals = np.asarray(vals)
    n = int(pattern.n)
    indptr = np.asarray(pattern.indptr)
    piv = vals[indptr[:-1] + np.asarray(pattern.diag_ptr)]
    finite = np.isfinite(vals)
    n_nonfinite = int(vals.size - finite.sum())
    first_bad = -1
    if n_nonfinite:
        row_of = np.repeat(np.arange(n), np.diff(indptr))
        first_bad = int(row_of[~finite].min())
    with np.errstate(invalid="ignore"):
        absvals = np.abs(vals)
        # ‖row‖_∞ over the filled pattern; reduceat is safe — every ILU row
        # holds at least its diagonal
        rownorm = np.maximum.reduceat(absvals, indptr[:-1])
        apiv = np.abs(piv)
        ratio = apiv / np.maximum(rownorm, NORM_FLOOR)
    ratio_clean = np.where(np.isfinite(ratio), ratio, np.inf)
    n_zero = int(np.count_nonzero(apiv == 0.0))
    n_denormal = int(np.count_nonzero((apiv > 0.0) & (apiv < TINY_PIVOT)))
    n_small = int(np.count_nonzero(ratio_clean < tol))
    worst = int(np.argmin(ratio_clean))
    ok = (n_nonfinite == 0 and n_zero == 0 and n_denormal == 0 and n_small == 0)
    return FactorHealth(
        ok=ok, n=n, pivot_tol=tol, n_nonfinite=n_nonfinite,
        n_zero_pivots=n_zero, n_denormal_pivots=n_denormal,
        n_small_pivots=n_small, worst_row=worst,
        worst_pivot=float(piv[worst]) if np.isfinite(piv[worst]) else float("nan"),
        worst_ratio=float(ratio_clean[worst]), first_nonfinite_row=first_bad)


def _sharded_audit_maps(fact):
    """Host-side index maps for the device-major audit, cached in the
    factorization's structure-keyed ``_shared`` store."""
    maps = fact._shared.get("audit_maps")
    if maps is None:
        plan = fact.plan
        gid = plan.rows_device_major(np.arange(plan.n_pad, dtype=np.int64))
        dlane = plan.rows_device_major(np.asarray(plan.diag_pos, np.int32))
        # device-major slot p holds one band's R contiguous rows; its global
        # band id recovers from the first row it holds
        slot_band = gid.reshape(-1, plan.band_rows)[:, 0] // plan.band_rows
        maps = fact._shared["audit_maps"] = {
            "gid": gid, "dlane": dlane, "slot_band": slot_band,
            "valid": gid < fact.pattern.n,
        }
    return maps


def audit_sharded(fact, pivot_tol: Optional[float] = None) -> FactorHealth:
    """Audit a ``ShardedILUFactorization`` on device, in place.

    Reads the sharded ``(D, s_loc, W)`` value array with eager jnp
    reductions — the factor never gathers to the host and nothing is
    recompiled per structure; only O(n_bands) scalars/summaries transfer
    back. Adds the per-band worst-pivot summary (global band order) so a
    breakdown localizes to the owning device/band without a gather."""
    import jax.numpy as jnp

    tol = PIVOT_TOL if pivot_tol is None else float(pivot_tol)
    plan = fact.plan
    maps = _sharded_audit_maps(fact)
    n_pad, w = plan.n_pad, plan.width
    v = fact.loc_vals.reshape(n_pad, w)
    valid = jnp.asarray(maps["valid"])
    gid = jnp.asarray(maps["gid"])
    dlane = jnp.asarray(maps["dlane"], jnp.int32)

    finite = jnp.isfinite(v)
    bad_entry = (~finite) & valid[:, None]
    n_nonfinite = int(jnp.sum(bad_entry))
    bad_row = jnp.any(bad_entry, axis=1)
    first_bad = int(jnp.min(jnp.where(bad_row, gid, n_pad)))
    piv = jnp.take_along_axis(v, dlane[:, None], axis=1)[:, 0]
    apiv = jnp.abs(piv)
    rownorm = jnp.max(jnp.abs(jnp.where(valid[:, None] & finite, v, 0.0)), axis=1)
    ratio = apiv / jnp.maximum(rownorm, NORM_FLOOR)
    ratio_clean = jnp.where(jnp.isfinite(ratio) & valid, ratio, jnp.inf)
    n_zero = int(jnp.sum((apiv == 0.0) & valid))
    n_denormal = int(jnp.sum((apiv > 0.0) & (apiv < TINY_PIVOT) & valid))
    n_small = int(jnp.sum(ratio_clean < tol))
    worst_dm = int(jnp.argmin(ratio_clean))
    # per-band worst pivot ratio: device-major rows are contiguous R-blocks
    # per (device, slot); reorder the slot summaries to global band order
    band_worst_dm = np.asarray(jnp.min(
        ratio_clean.reshape(-1, plan.band_rows), axis=1))
    band_worst = np.full(plan.n_bands, np.inf, np.float64)
    band_worst[maps["slot_band"]] = band_worst_dm
    worst_piv = float(np.asarray(piv[worst_dm]))
    ok = (n_nonfinite == 0 and n_zero == 0 and n_denormal == 0 and n_small == 0)
    return FactorHealth(
        ok=ok, n=int(fact.pattern.n), pivot_tol=tol, n_nonfinite=n_nonfinite,
        n_zero_pivots=n_zero, n_denormal_pivots=n_denormal,
        n_small_pivots=n_small, worst_row=int(maps["gid"][worst_dm]),
        worst_pivot=worst_piv if np.isfinite(worst_piv) else float("nan"),
        worst_ratio=float(np.asarray(ratio_clean[worst_dm])),
        first_nonfinite_row=-1 if first_bad >= n_pad else first_bad,
        band_worst_ratio=band_worst)


# --------------------------------------------------------------------------
# the shift ladder
# --------------------------------------------------------------------------
def ladder_alphas(shift0: Optional[float] = None,
                  max_shifts: Optional[int] = None):
    """The deterministic escalation sequence α_j = shift0·2^j."""
    s0 = SHIFT0 if shift0 is None else float(shift0)
    m = MAX_SHIFTS if max_shifts is None else int(max_shifts)
    return [s0 * (2.0 ** j) for j in range(m)]


def shifted_matrix(a, alpha: float):
    """``A + α·diag(‖row‖₁)`` as a fresh CSRMatrix sharing A's structure
    caches.

    The sparsity is identical (the diagonal is structural in every matrix
    this stack factors), so the shifted matrix *adopts* A's structure-keyed
    engine stores — ``FactorPlan`` and the TOP-ILU engine memo both rebuild
    value state from ``.data`` per call — making a ladder rung a pure
    re-execute. Rows whose 1-norm is zero *or subnormal-scale* (below
    ``NORM_FLOOR``) shift by α alone: a relative nudge on such a row would
    itself be denormal, so no rung of the ladder could ever lift its pivot
    into the normal range."""
    from .sparse import CSRMatrix

    indptr = np.asarray(a.indptr)
    lens = np.diff(indptr)
    row_of = np.repeat(np.arange(a.n), lens)
    is_diag = np.asarray(a.indices) == row_of
    dpos = np.nonzero(is_diag)[0]
    if dpos.size != a.n:
        missing = np.setdiff1d(np.arange(a.n), row_of[dpos])
        raise ValueError(
            "shifted_matrix: rows without a structural diagonal cannot be "
            f"shifted (first such row: {int(missing[0])})")
    rownorm = np.add.reduceat(np.abs(np.asarray(a.data, np.float64)), indptr[:-1])
    scale = np.where(rownorm > NORM_FLOOR, rownorm, 1.0)
    data = np.asarray(a.data, np.float32).copy()
    data[dpos] = (data[dpos].astype(np.float64) + alpha * scale).astype(np.float32)
    out = CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices, data=data)
    for key in ("_factor_plans", "_topilu_engines"):
        store = a.__dict__.get(key)
        if store is not None:
            out.__dict__[key] = store  # shared by reference: same structure
    return out


def run_ladder(a, factor: Callable, audit: Callable, on_breakdown: str,
               shift0: Optional[float] = None,
               max_shifts: Optional[int] = None):
    """Drive the fallback chain for one matrix.

    ``factor(mat)`` produces a factorization artifact (a values array or a
    sharded factorization object); ``audit(artifact)`` returns its
    :class:`FactorHealth`. Returns ``(system_matrix, artifact, health)``
    where ``system_matrix`` is ``a`` or the shifted matrix the artifact
    belongs to. Raises :class:`BreakdownError` per the policy table in the
    module docstring."""
    if on_breakdown not in _ON_BREAKDOWN:
        raise ValueError(
            f"on_breakdown must be one of {_ON_BREAKDOWN}, got {on_breakdown!r}")
    art = factor(a)
    health = audit(art)
    health.attempts = 1
    if health.ok or on_breakdown == "ignore":
        return a, art, health
    if on_breakdown == "raise":
        raise BreakdownError(health)
    base_art, base_health = art, health
    attempts = 1
    for alpha in ladder_alphas(shift0, max_shifts):
        a_s = shifted_matrix(a, alpha)
        art = factor(a_s)
        attempts += 1
        h = audit(art)
        if h.ok:
            h.shift = float(alpha)
            h.attempts = attempts
            return a_s, art, h
    base_health.attempts = attempts
    if on_breakdown == "fallback":
        base_health.degraded = True
        return a, base_art, base_health
    raise BreakdownError(base_health, exhausted=True)
