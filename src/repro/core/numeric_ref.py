"""Numeric factorization — Phase II of ILU(k): the bit-compatibility oracle.

In-place row-major IKJ sweep (paper §III-A/III-C): for each row j, for each
pivot entry i < j of the filled pattern in ascending order,

    l        = f[j,i] / f[i,i]
    f[j,i]   = l
    f[j,t]  -= l * f[i,t]   for every t > i in pattern(i) ∩ pattern(j)

Terms falling outside pattern(j) are dropped (that is the "incomplete").

This module is the *oracle* for bit-compatibility: every parallel/JAX/Pallas
numeric path in this repo must reproduce these float32 values **bitwise**
(the paper's §VI guarantee). To keep the arithmetic identical everywhere we
always compute ``l * f[i,t]`` as an explicit multiply followed by an explicit
subtract (no FMA contraction), in ascending-pivot order.
"""
from __future__ import annotations

import numpy as np

from .sparse import CSRMatrix, ILUPattern


def numeric_ilu_ref(a: CSRMatrix, pattern: ILUPattern) -> np.ndarray:
    """Sequential bit-compatibility oracle. Returns CSR-aligned f32 values."""
    n = a.n
    indptr = pattern.indptr
    indices = pattern.indices
    vals = np.zeros(pattern.nnz, dtype=np.float32)
    # scatter A onto the filled pattern
    for j in range(n):
        s, e = indptr[j], indptr[j + 1]
        pcols = indices[s:e]
        acols, avals = a.row(j)
        pos = np.searchsorted(pcols, acols)
        vals[s + pos] = avals
    diag_abs = pattern.indptr[:-1] + pattern.diag_ptr  # absolute diag offsets
    for j in range(n):
        s, e = indptr[j], indptr[j + 1]
        pcols = indices[s:e]
        x = vals[s:e]
        nl = int(pattern.diag_ptr[j])  # entries strictly below the diagonal
        for p in range(nl):
            i = int(pcols[p])
            piv = vals[diag_abs[i]]
            l = np.float32(x[p] / piv)
            x[p] = l
            si, ei = indptr[i], indptr[i + 1]
            icols = indices[si:ei]
            di = int(pattern.diag_ptr[i])
            tcols = icols[di + 1 :]
            tvals = vals[si + di + 1 : ei]
            if len(tcols) == 0:
                continue
            pos = np.searchsorted(pcols, tcols)
            inb = pos < len(pcols)
            hit = np.zeros(len(tcols), dtype=bool)
            hit[inb] = pcols[pos[inb]] == tcols[inb]
            idx = pos[hit]
            # multiply then subtract — two ops, no FMA, fixed order
            contrib = (l * tvals[hit]).astype(np.float32)
            x[idx] = (x[idx] - contrib).astype(np.float32)
        vals[s:e] = x
    return vals


def numeric_ilu_dense_oracle(a_dense: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Dense scalar triple-loop restricted to ``mask`` — independent oracle.

    Mathematically identical to :func:`numeric_ilu_ref`; used in tests to
    validate the sparse oracle on small matrices.
    """
    n = a_dense.shape[0]
    f = np.array(a_dense, dtype=np.float32)
    f[~mask] = 0.0
    for j in range(n):
        for i in range(j):
            if not mask[j, i]:
                continue
            l = np.float32(f[j, i] / f[i, i])
            f[j, i] = l
            for t in range(i + 1, n):
                if mask[i, t] and mask[j, t]:
                    f[j, t] = np.float32(f[j, t] - np.float32(l * f[i, t]))
    return f


def ilu_residual(a: CSRMatrix, pattern: ILUPattern, vals: np.ndarray) -> float:
    """|| (L@U - A) restricted to pattern ||_inf — a correctness measure.

    For exact LU (full pattern) this is ~0; for ILU it is ~0 *on the
    pattern* (the defining property of ILU: (LU)_ij = a_ij for (i,j) in P).
    """
    from .sparse import split_lu

    L, U = split_lu(pattern, vals)
    prod = (L @ U).toarray()
    a_d = a.to_dense()
    m = pattern.dense_mask()
    return float(np.abs((prod - a_d))[m].max())
