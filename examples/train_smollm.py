"""End-to-end training driver: smollm-135m-family model on synthetic data.

Full scale (needs accelerators):
    PYTHONPATH=src python examples/train_smollm.py --full --steps 300

CPU demo (reduced width, same code path — loss visibly drops):
    PYTHONPATH=src python examples/train_smollm.py --steps 60
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.optim import adamw
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true", help="real 135M config")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, d_ff=256)
    print(f"arch={cfg.arch} layers={cfg.n_layers} d={cfg.d_model} "
          f"params~{cfg.param_count()['total']/1e6:.1f}M")
    res = train(
        cfg,
        n_steps=args.steps,
        ckpt_dir=args.ckpt,
        seq_len=args.seq_len,
        global_batch=args.batch,
        opt_cfg=adamw.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
    )
    first = sum(res.losses[:5]) / 5
    last = sum(res.losses[-5:]) / 5
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'IMPROVED ✓' if last < first else 'no improvement ✗'})")
    if res.restored_from is not None:
        print(f"(restored from checkpoint step {res.restored_from})")


if __name__ == "__main__":
    main()
