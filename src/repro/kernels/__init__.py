"""Pallas TPU kernels for the BILU(k) numeric phase + solver matvec.

Layout per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), wrapped by
``ops.py`` (jit + padding + fallbacks), oracled by ``ref.py`` (pure jnp).
Kernels target TPU VMEM/MXU; on CPU they run in interpret mode.
"""

from .ops import (  # noqa: F401
    panel_update,
    spmv_ell,
    tri_solve_wavefront,
    trsm_left_unit_lower,
    trsm_right_upper,
)
