"""Pipeline parallelism (GPipe schedule) over a ``pipe`` mesh axis.

The paper's core scheduling idea — stream completed units of work through a
device ring while every stage keeps computing (Fig 4) — applied to layers
instead of matrix bands. SPMD formulation:

* the layer stack (L leading axis) reshapes to (P, L/P, ...) and shards its
  stage axis over ``pipe``;
* microbatches enter stage 0; activations hop stage->stage with
  `lax.ppermute` (the band broadcast's sibling); a `lax.scan` over
  N + P - 1 ticks realizes the schedule, bubble fraction (P-1)/(N+P-1);
* every device executes its stage every tick (SPMD-uniform; bubble ticks
  compute on garbage and are masked out), exactly like TOP-ILU's redundant
  `finish_band` on non-owners;
* backward differentiates through the scan/ppermute (transpose of a
  permutation is the reverse permutation), giving 1F1B-equivalent traffic.

Composable with the data/model axes: pass a mesh like
``jax.make_mesh((pipe, data, model), ("pipe", "data", "model"))`` and shard
batches/params on the other axes as usual.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from ..models.transformer import layer_forward


def _stage_fn(cfg, stage_layers, x, positions):
    """Apply this device's L/P layers (scan over the local slice)."""

    def body(carry, lp):
        return layer_forward(cfg, lp, carry, positions), None

    out, _ = lax.scan(body, x, stage_layers)
    return out


def make_pipelined_forward(cfg, mesh, n_microbatches: int, axis: str = "pipe"):
    """Returns ``fn(stacked_layers, x, positions) -> y`` running the layer
    stack as a P-stage GPipe pipeline over ``axis``.

    ``stacked_layers`` leaves have leading dim L (divisible by P);
    ``x`` is (B, S, d) with B divisible by n_microbatches.
    """
    Pn = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def pipelined(layers, x, positions):
        B, S, d = x.shape
        N = n_microbatches
        assert B % N == 0
        mb = B // N
        xs = x.reshape(N, mb, S, d)

        def inner(stage_layers, xs_in):
            # stage_layers leaves: (1, L/P, ...) local slice -> drop stage dim
            stage_layers_l = jax.tree.map(lambda t: t[0], stage_layers)
            idx = lax.axis_index(axis)
            T = N + Pn - 1

            def tick(buf, t):
                m = jnp.clip(t, 0, N - 1)
                inject = lax.dynamic_index_in_dim(xs_in, m, keepdims=False)
                inp = jnp.where(idx == 0, inject, buf)
                out = _stage_fn(cfg, stage_layers_l, inp, positions)
                perm = [(i, i + 1) for i in range(Pn - 1)]
                nxt = lax.ppermute(out, axis, perm)
                y_t = jnp.where(idx == Pn - 1, out, jnp.zeros_like(out))
                return nxt, y_t

            buf0 = jnp.zeros((mb, S, d), x.dtype)
            _, ys = lax.scan(tick, buf0, jnp.arange(T))
            # microbatch m exits the last stage at tick m + P - 1; psum
            # replicates the result (other stages contribute zeros)
            return lax.psum(ys[Pn - 1 :], axis)

        # reshape stacked layers (L, ...) -> (P, L/P, ...) sharded on stage
        def to_stages(t):
            L = t.shape[0]
            assert L % Pn == 0, (L, Pn)
            return t.reshape(Pn, L // Pn, *t.shape[1:])

        staged = jax.tree.map(to_stages, layers)
        in_specs = (
            jax.tree.map(lambda _: P(axis), staged),
            P(),  # microbatches replicated in; stage 0 consumes them
        )
        smapped = shard_map(
            inner, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False,
        )
        ys = smapped(staged, xs)
        return ys.reshape(B, S, d)

    return pipelined


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages - 1 + n_microbatches)
