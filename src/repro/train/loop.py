"""Training loop: data -> step -> metrics -> checkpoint, with fault hooks.

This is the driver `examples/train_smollm.py` and `launch/train.py` use on
CPU/small meshes; the same loop body is what a pod launcher would run per
host (the data pipeline and checkpointer are already host-sharded/elastic).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from ..data.pipeline import SyntheticLM
from ..models import model as M
from ..optim import adamw
from ..runtime.fault import StragglerMonitor
from .step import make_train_step


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps: int
    restored_from: Optional[int]
    straggler_steps: int


def train(
    cfg,
    n_steps: int = 50,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    ckpt_dir: Optional[str] = None,
    save_every: int = 20,
    seed: int = 0,
    log_every: int = 10,
    seq_len: int = 128,
    global_batch: int = 8,
    microbatches: int = 1,
) -> TrainResult:
    opt_cfg = opt_cfg or adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=n_steps)
    data = SyntheticLM(cfg.vocab_real, seq_len, global_batch)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw.init(params)
    start = 0
    restored = None
    ck = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), manifest = restore(ckpt_dir, None, (params, opt_state))
        start = manifest["step"]
        restored = start

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatches=microbatches),
                      donate_argnums=(0, 1))
    losses = []
    monitor = StragglerMonitor()
    for step in range(start, n_steps):
        batch = data.batch_at(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        monitor.observe(time.perf_counter() - t0)
        losses.append(loss)
        if log_every and (step % log_every == 0 or step == n_steps - 1):
            print(f"step {step:5d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if ck and ((step + 1) % save_every == 0 or step + 1 == n_steps):
            ck.save_async(step + 1, (params, opt_state))
    if ck:
        ck.wait()
    return TrainResult(
        losses=losses, steps=n_steps - start, restored_from=restored,
        straggler_steps=monitor.slow_steps,
    )
