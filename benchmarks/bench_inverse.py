"""Incomplete-inverse (SpMV-chain) preconditioner trajectory.

    python benchmarks/bench_inverse.py <grid> <devices> [--json PATH]

Spawns itself with ``XLA_FLAGS=--xla_force_host_platform_device_count``
(device count locks at first JAX init). Measures the head-to-head the
inverse method exists for: the sharded sweep pays one collective per fused
epoch (tens per apply on a Poisson structure), while the level-truncated
inverse apply ``x = Z (W b)`` is two ELL SpMVs with exactly two untiled
all-gathers — communication independent of wavefront depth. Per device
count the record holds:

* steady apply wall times — distributed inverse apply (single RHS and an
  8-RHS batch) vs the *fusion-ordered* sweep apply (the best sweep number
  on the committed ``BENCH_sweep.json`` trajectory);
* distributed inverse-preconditioned GMRES on the Poisson fixture —
  iterations, convergence, and the bitwise-vs-single-device anchor — plus
  convergence on the random ``matgen`` fixture;
* the modeled communication both sides of the ``"auto"`` policy see
  (``sweep_comm_model`` vs ``inverse_comm_model``) and the method the
  policy actually picks.

``benchmarks/run.py --emit-json BENCH_inverse.json`` aggregates 1/2/8
devices into the committed trajectory.
"""
import json
import os
import subprocess
import sys

if os.environ.get("_BENCH_INVERSE_CHILD") != "1" and __name__ == "__main__":
    d = sys.argv[2] if len(sys.argv) > 2 else "2"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
    env.setdefault("JAX_PLATFORMS", "cpu")  # don't probe for real TPUs
    env["_BENCH_INVERSE_CHILD"] = "1"
    sys.exit(subprocess.run([sys.executable, __file__] + sys.argv[1:], env=env).returncode)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np


def _steady_apply(apply_fn, arg, reps=20):
    import jax

    np.asarray(apply_fn(arg))  # warm the cached executable
    t0 = time.perf_counter()
    for _ in range(reps):
        out = apply_fn(arg)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def measure(grid: int, band_rows: int = 16, batch: int = 8) -> dict:
    import jax

    from repro.core import matgen, poisson_2d
    from repro.core.inverse import (
        inverse_comm_model,
        modeled_apply_cost,
        resolve_precond_method,
    )
    from repro.core.ordering import make_ordering, sweep_comm_model
    from repro.core.solvers import solve_sharded, solve_with_ilu, warm_solve
    from repro.core.symbolic import pilu1_symbolic

    d = len(jax.devices())
    a = poisson_2d(grid)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n).astype(np.float32)
    bs = rng.standard_normal((batch, a.n)).astype(np.float32)

    # --- serving warmup: inverse-chain compiles land here ------------------
    t0 = time.perf_counter()
    warm_solve(a, k=1, batch_sizes=(1, batch), band_rows=band_rows, tol=1e-6,
               precond_method="inverse")
    warm_seconds = time.perf_counter() - t0

    res, fact = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6, precond_method="inverse")
    assert res.converged

    # bitwise anchor: distributed inverse solve == single-device inverse solve
    res1, _ = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False,
                             precond_method="inverse")
    bitwise = bool(np.array_equal(res.x.view(np.int32), res1.x.view(np.int32)))

    # --- steady apply: inverse chain vs the fusion-ordered sweep -----------
    ap_inv = fact.precond(method="inverse")
    inv_apply = _steady_apply(ap_inv, b)
    inv_apply_batched = _steady_apply(ap_inv.batched, bs)

    if d > 1:
        ordering = make_ordering(a, "fusion", n_devices=d, band_rows=band_rows)
        res_sw, fact_sw = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6, ordering=ordering)
        sweep_ordering = "fusion"
        sw_b = ordering.permute_vector(b)
    else:
        res_sw, fact_sw = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6)
        sweep_ordering = "natural"
        sw_b = b
    assert res_sw.converged
    sweep_apply = _steady_apply(fact_sw.precond(), sw_b)

    t0 = time.perf_counter()
    solve_reps = 3
    for _ in range(solve_reps):
        r2, _ = solve_sharded(a, b, k=1, band_rows=band_rows, tol=1e-6,
                              precond_method="inverse", fact=fact)
    gmres_steady = (time.perf_counter() - t0) / solve_reps
    assert r2.iterations == res.iterations

    # --- the two sides of the "auto" cost model ----------------------------
    pat = pilu1_symbolic(a)
    sweep_model = sweep_comm_model(pat, band_rows, d)
    inv_model = inverse_comm_model(a.n, d)
    plan = ap_inv.plan  # the factorization's own inverse plan (built once)
    auto = resolve_precond_method("auto", pat, n_devices=d, band_rows=band_rows)

    # --- random matgen fixture: the chain still preconditions --------------
    r_mat = matgen(a.n, density=0.006, seed=3)
    br = rng.standard_normal(r_mat.n).astype(np.float32)
    res_r, _ = solve_sharded(r_mat, br, k=1, band_rows=band_rows, tol=1e-6,
                             precond_method="inverse")
    res_r1, _ = solve_with_ilu(r_mat, br, k=1, tol=1e-6, use_pallas=False, precond_method="inverse")
    random_bitwise = bool(np.array_equal(res_r.x.view(np.int32), res_r1.x.view(np.int32)))

    return {
        "devices": d,
        "n": a.n,
        "grid": grid,
        "k": 1,
        "band_rows": band_rows,
        "batch": batch,
        "bitwise_equal_single_device": bitwise,
        "iterations_inverse": res.iterations,
        "iterations_sweep": res_sw.iterations,
        "inverse_nnz": plan.nnz_inverse(),
        "factor_nnz": pat.nnz,
        "value_depth": plan.depth,
        # communication per apply, as the "auto" policy models it
        "sweep_collectives_per_apply": sweep_model["collectives_per_apply"],
        "sweep_bytes_per_apply": sweep_model["bytes_per_apply"],
        "inverse_collectives_per_apply": inv_model["collectives_per_apply"],
        "inverse_bytes_per_apply": inv_model["bytes_per_apply"],
        "modeled_cost_sweep": modeled_apply_cost(sweep_model),
        "modeled_cost_inverse": modeled_apply_cost(inv_model),
        "auto_method": auto,
        # wall times (all D virtual devices time-slice one CPU)
        "warm_seconds": warm_seconds,
        "inverse_apply_steady_seconds": inv_apply,
        "inverse_apply_batched_seconds_per_rhs": inv_apply_batched / batch,
        "sweep_ordering": sweep_ordering,
        "sweep_apply_steady_seconds": sweep_apply,
        "gmres_steady_seconds": gmres_steady,
        # random matgen fixture: convergence + the same bitwise anchor
        "random": {
            "n": r_mat.n,
            "converged": bool(res_r.converged),
            "iterations": res_r.iterations,
            "bitwise_equal_single_device": random_bitwise,
        },
    }


def main():
    grid = int(sys.argv[1]) if len(sys.argv) > 1 else 32
    out = None
    if "--json" in sys.argv:
        out = sys.argv[sys.argv.index("--json") + 1]
    m = measure(grid)
    text = json.dumps(m, indent=2)
    if out:
        with open(out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
