"""Fault tolerance & straggler mitigation.

Three mechanisms, mapped from the paper's master/worker world to SPMD pods
(DESIGN.md §6):

1. **Checkpoint/restart** — `run_with_restarts` wraps a step function; on
   failure it restores the latest checkpoint and continues. Node failures
   on a real pod surface as distributed-runtime errors, which take exactly
   this path after the scheduler re-provisions.
2. **Elastic band re-ownership** (TOP-ILU) — static ownership is
   ``owner(band, epoch) = (band + epoch) % D_alive``: when a worker is
   lost, the factorization restarts from its last completed frontier with
   D-1 devices and ownership re-derives with zero coordination — this is
   the paper's dynamic-load-balancing fallback made deterministic.
3. **Straggler mitigation** — a per-step deadline monitor; steps that
   exceed ``deadline_factor`` x the EWMA step time are reported, and the
   policy hook decides (log / re-dispatch / shrink mesh). On a single
   process this triggers on real CPU contention, which the test exploits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerMonitor:
    deadline_factor: float = 3.0
    ewma_alpha: float = 0.2
    _ewma: Optional[float] = None
    slow_steps: int = 0
    steps: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        slow = self._ewma is not None and dt > self.deadline_factor * self._ewma
        self._ewma = dt if self._ewma is None else (
            self.ewma_alpha * dt + (1 - self.ewma_alpha) * self._ewma
        )
        self.steps += 1
        if slow:
            self.slow_steps += 1
        return slow


def band_owner(band: int, epoch: int, n_alive: int) -> int:
    """Deterministic re-round-robin after failures (mechanism 2)."""
    return (band + epoch) % n_alive


def run_with_restarts(
    make_state: Callable[[], tuple],
    step_fn: Callable,
    save_fn: Callable,
    restore_fn: Callable,
    n_steps: int,
    save_every: int = 10,
    max_restarts: int = 3,
    fail_at: Optional[Callable[[int], bool]] = None,
):
    """Generic checkpointed driver. ``fail_at(step)`` injects faults (tests).

    Returns (state, completed_steps, restarts)."""
    restarts = 0
    state, start = restore_fn()
    if state is None:
        state = make_state()
        start = 0
    step = start
    monitor = StragglerMonitor()
    while step < n_steps:
        try:
            if fail_at is not None and fail_at(step):
                raise RuntimeError(f"injected node failure at step {step}")
            t0 = time.perf_counter()
            state = step_fn(state, step)
            monitor.observe(time.perf_counter() - t0)
            step += 1
            if step % save_every == 0 or step == n_steps:
                save_fn(state, step)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            state, start = restore_fn()
            assert state is not None, "failure before first checkpoint"
            step = start
    return state, step, restarts
