"""repro.launch"""
