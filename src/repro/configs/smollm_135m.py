"""smollm-135m [dense] — llama-arch small; 9 heads (attention TP replicated,
9 % 16 != 0 — DESIGN.md §4). [hf:HuggingFaceTB/SmolLM-135M]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_real=49152,
    rope_theta=10000.0,
    mlp_act="swiglu",
    tie_embeddings=True,
)
