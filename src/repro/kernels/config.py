"""Kernel execution-mode resolution shared by every Pallas wrapper.

``REPRO_PALLAS_FORCE_INTERPRET=1`` forces every ``pallas_call`` into
interpret mode **even when a caller explicitly requested the compiled
lowering** (``interpret=False``). That is what lets the CPU CI leg run the
``pallas_compiled``-marked tests (see ``tests/conftest.py``): the tests'
call paths, schedule plumbing, and bitwise assertions all execute — only
the Mosaic lowering itself is substituted. It is a CI knob, not a perf
knob; on TPU hardware leave it unset and use ``REPRO_PALLAS_INTERPRET=0``.
"""
from __future__ import annotations

import os


def force_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_FORCE_INTERPRET", "0") == "1"


def resolve_interpret(interpret: bool) -> bool:
    """The mode a kernel actually runs in (reads the env at trace time)."""
    return True if force_interpret() else bool(interpret)
