"""Cross-tenant request coalescing: one bucketed multi-RHS solve per group.

A tick drains the admission queue and regroups requests by **compat key**:
the (matrix_id, binding) pair pinned at admission. Same key ⇒ same engine,
same value version ⇒ the requests can ride as lanes of one ``vmap``-batched
solve. Tenant identity is deliberately *not* part of the key — coalescing
across tenants is the point (one tenant's burst fills lanes another
tenant's trickle would have left as padding).

Groups larger than the largest bucket **chunk** into consecutive
largest-bucket batches inside the same tick (FIFO order preserved within
the group): an oversized group costs extra dispatches, never a failure and
never starvation. Bit-compat makes this free — a lane's bits do not depend
on which batch it rode in.
"""
from __future__ import annotations

import dataclasses
from typing import List

from .admission import SolveRequest


@dataclasses.dataclass
class CoalescedBatch:
    """One solver dispatch: requests sharing an engine + value binding."""

    matrix_id: str
    entry: object            # cache.CacheEntry
    binding: object          # engine.EngineBinding the lanes solve against
    requests: List[SolveRequest]
    bucket: int              # padded lane count this batch will compile-hit

    @property
    def real_lanes(self) -> int:
        return len(self.requests)


def coalesce(requests: List[SolveRequest]) -> List[CoalescedBatch]:
    """Group admitted requests into dispatchable batches.

    Grouping is stable (first-seen key order, FIFO within a group) so the
    schedule is deterministic for a deterministic submit order — the soak
    test replays byte-identical traffic and asserts byte-identical
    responses. Returns batches with their bucket sizes resolved; chunking
    at the largest bucket happens here so the service's tick loop is a
    flat ``for batch: solve``.
    """
    groups: dict = {}
    order = []
    for r in requests:
        entry, binding = r.binding
        key = (r.matrix_id, id(binding))
        if key not in groups:
            groups[key] = (entry, binding, [])
            order.append(key)
        groups[key][2].append(r)

    batches: List[CoalescedBatch] = []
    for key in order:
        entry, binding, reqs = groups[key]
        cap = max(entry.engine.buckets)
        for i in range(0, len(reqs), cap):
            chunk = reqs[i:i + cap]
            batches.append(CoalescedBatch(
                matrix_id=key[0], entry=entry, binding=binding,
                requests=chunk, bucket=entry.engine.bucket_for(len(chunk))))
    return batches
