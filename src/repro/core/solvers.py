"""Preconditioned iterative solvers (JAX): GMRES(m), BiCGSTAB, CG.

These are the *consumers* of the ILU(k) preconditioner — the paper's point
is that preconditioning time dominates the solver as processors scale, so a
real system must include the solver to measure anything meaningful
(paper §I, §V-B).

Execution model: **device-resident**. Each solver compiles to a single
jitted computation — the Krylov iteration, the preconditioner application
(fused Pallas wavefront sweep, see ``repro.core.triangular.PrecondApply``),
the SpMV (``repro.kernels.ops.spmv_ell``), and for GMRES the restart logic
and the Givens-rotation least-squares solve all live inside one
``lax.while_loop``. There is exactly one dispatch per solve: no host
round-trips per iteration or per restart, no host ``lstsq``. Residual
histories are recorded into fixed-size device buffers carried through the
loop and trimmed on the host afterwards.

Multi-RHS: ``gmres_batched`` (or a 2-D ``b`` passed to ``solve_with_ilu``)
``vmap``s the same single-RHS engine over a stack of right-hand sides —
one dispatch for the whole batch, with per-lane freezing so already
converged systems stop updating (their iteration counts and histories stay
exact). The batched path shares the cached triangular plan; use it when
amortizing one factorization over many right-hand sides (the serving
scenario), not when RHS arrive one at a time.

All solvers take ``matvec`` (A·x) and ``precond`` (M^{-1}·x, identity if
None) as functions, run in float32, and report iteration counts + residual
history so tests/benches can reproduce the paper's "larger k => fewer
iterations" trade-off (Fig 5 discussion).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from .bitmath import barred, bitdot, bitnorm, masked_lane_sum
from .planner import COL_SENTINEL

def parse_batch_buckets(spec: str, source: str = "REPRO_BATCH_BUCKETS") -> tuple:
    """Parse and validate a comma-separated bucket spec.

    Buckets bound the set of compiled batch shapes, so a malformed spec
    must fail loudly at parse time — a silently-accepted ``0`` or ``-4``
    would only surface later as a bad pad target deep in a solve. Rules:
    every token an integer, every value positive, no duplicates, strictly
    ascending (the canonical form callers and ``bucket_batch`` assume).
    """
    toks = [t.strip() for t in str(spec).split(",") if t.strip()]
    if not toks:
        raise ValueError(f"{source}: empty bucket spec {spec!r} — expected "
                         "comma-separated positive integers, e.g. '1,2,4,8'")
    vals = []
    for t in toks:
        try:
            v = int(t)
        except ValueError:
            raise ValueError(
                f"{source}: bucket token {t!r} is not an integer "
                f"(full spec: {spec!r})") from None
        if v <= 0:
            raise ValueError(
                f"{source}: bucket sizes must be positive, got {v} "
                f"(full spec: {spec!r})")
        vals.append(v)
    if len(set(vals)) != len(vals):
        dupes = sorted({v for v in vals if vals.count(v) > 1})
        raise ValueError(
            f"{source}: duplicate bucket size(s) {dupes} (full spec: {spec!r})")
    if vals != sorted(vals):
        raise ValueError(
            f"{source}: bucket sizes must be ascending — got {vals}, "
            f"expected {sorted(vals)} (full spec: {spec!r})")
    return tuple(vals)


def batch_buckets():
    """RHS batch-size buckets for the serving path — ``REPRO_BATCH_BUCKETS``
    (comma-separated, positive, ascending) or the powers-of-two default.
    Bucketing keeps the number of compiled solver/precond shapes bounded: a
    ragged batch pads up to the nearest bucket (vmap lanes are independent,
    so zero padding never changes a real lane's bits) instead of minting a
    new executable per batch size. A malformed spec raises with the
    offending token — see :func:`parse_batch_buckets`."""
    import os

    return parse_batch_buckets(os.environ.get("REPRO_BATCH_BUCKETS", "1,2,4,8,16,32,64"))


def bucket_batch(nb: int, buckets=None) -> int:
    """Smallest bucket >= nb (nb itself when it exceeds every bucket)."""
    buckets = batch_buckets() if buckets is None else tuple(sorted(buckets))
    for w in buckets:
        if w >= nb:
            return w
    return nb


def _pad_rhs_batch(bs, tgt):
    if bs.shape[0] == tgt:
        return bs
    pad = jnp.zeros((tgt - bs.shape[0], bs.shape[1]), bs.dtype)
    return jnp.concatenate([bs, pad])


def _pad_tols(tol, tgt):
    """Pad a per-lane tol array to the bucket size. Padding lanes get 1.0 —
    their RHS is zero, so ``||b|| = 0`` stops them before any iteration
    regardless of tolerance; 1.0 just keeps the intent obvious."""
    tol_arr = np.asarray(tol, np.float32)
    if tol_arr.ndim == 0 or tol_arr.shape[0] == tgt:
        return tol
    return np.concatenate([tol_arr, np.ones(tgt - tol_arr.shape[0], np.float32)])


def _cached_engine(matvec, M, key, build):
    """Compiled-engine memo stored *on the matvec closure itself*: repeated
    solves with the same (matvec, precond) objects reuse one executable with
    zero retracing, and the engine (plus its captured device arrays) is
    garbage-collected with the closure — no module-level registry, so a
    stream of different matrices cannot grow device memory without bound."""
    try:
        store = matvec.__dict__.setdefault("_repro_engines", {})
    except AttributeError:  # exotic callable without __dict__: no caching
        return build()
    fn = store.get((M, key))
    if fn is None:
        fn = store[(M, key)] = build()
    return fn


# Termination verdict codes carried through the solver while-loops as an
# int32 lane state (0 = still running). Classification rides *outside* the
# iterate arithmetic — adding it changes no bits of x — and replaces the
# bare `(res > tolb) & (it < maxiter)` predicates so the serve layer can
# tell "hit the iteration budget" from "went NaN" from "flatlined".
VERDICT_RUNNING = 0
VERDICT_CONVERGED = 1
VERDICT_MAXITER = 2
VERDICT_STAGNATED = 3
VERDICT_BREAKDOWN = 4
VERDICT_DIVERGED = 5
VERDICTS = ("running", "converged", "maxiter", "stagnated", "breakdown", "diverged")

# stagnation = relative residual improvement below ε for `window`
# consecutive steps; divergence = residual blowing past `factor`·‖b‖.
# GMRES steps are whole restarts (few, substantial), so its window is short;
# CG/BiCGSTAB steps are single iterations with noisy residuals, so theirs is
# wide and the divergence bar higher (BiCGSTAB residuals legitimately spike).
_STAG_EPS = 1e-3
_GMRES_STALL_WINDOW = 5
_GMRES_DIV_FACTOR = 1e5
_KRYLOV_STALL_WINDOW = 25
_KRYLOV_DIV_FACTOR = 1e8


@dataclasses.dataclass
class SolveReport:
    """Per-lane termination report (the serve layer's retry policy keys on
    ``verdict``; ``shift``/``degraded`` are filled in by the solve entry
    points when the factorization came out of the breakdown ladder)."""

    verdict: str
    iterations: int
    residual: float
    converged: bool
    degraded: bool = False  # identity-precond fallback was active
    shift: float = 0.0      # diagonal shift α of the preconditioner's matrix


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray
    iterations: int
    residual: float
    converged: bool
    history: np.ndarray  # residual norm per iteration (GMRES: per restart)
    verdict: str = ""
    report: SolveReport = None

    def __post_init__(self):
        if self.report is None:
            self.report = SolveReport(self.verdict, self.iterations,
                                      self.residual, self.converged)


def make_ell_matvec(cols: jnp.ndarray, vals: jnp.ndarray, n: int) -> Callable:
    """Row-major ELL SpMV — the jnp reference the Pallas kernel must match
    (both reduce through ``masked_lane_sum``, so they agree bitwise)."""
    def matvec(x):
        xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
        gathered = xg[jnp.minimum(cols, n)]
        return masked_lane_sum(cols, vals, gathered, COL_SENTINEL)[:n]
    return matvec


def make_pallas_matvec(cols: jnp.ndarray, vals: jnp.ndarray, n: int) -> Callable:
    """ELL SpMV through the Pallas kernel, whole vector as one block (the
    solve path keeps x VMEM-resident; shard first for n beyond ~2^20)."""
    from repro.kernels import ops

    def matvec(x):
        return ops.spmv_ell(cols, vals, x, bm=n)
    return matvec


def _csr_to_ell_host(a, n_rows=None):
    """CSRMatrix -> host (cols, vals) sentinel-padded ELL arrays, with
    ``n_rows >= a.n`` all-sentinel padding rows (the one ELL scatter every
    matvec variant shares)."""
    n_rows = a.n if n_rows is None else n_rows
    lens = np.diff(a.indptr)
    W = max(int(lens.max(initial=0)), 1)
    cols = np.full((n_rows, W), COL_SENTINEL, np.int32)
    vals = np.zeros((n_rows, W), np.float32)
    row_of = np.repeat(np.arange(a.n), lens)
    pos = np.arange(a.nnz, dtype=np.int64) - a.indptr[row_of]
    cols[row_of, pos] = a.indices
    vals[row_of, pos] = a.data
    return cols, vals


def csr_to_ell_arrays(a):
    """CSRMatrix -> (cols, vals) sentinel-padded ELL arrays (vectorized)."""
    cols, vals = _csr_to_ell_host(a)
    return jnp.asarray(cols), jnp.asarray(vals)


def make_sharded_ell_matvec(a, mesh, axis: str = "band") -> Callable:
    """Row-block sharded ELL SpMV over a 1-D mesh (DESIGN.md §5).

    The ELL storage of A is split into D contiguous row blocks, each placed
    on its device; ``x`` is replicated (it is O(n) — the factors and the
    matrix are the memory hogs). Each device reduces its own rows through
    ``masked_lane_sum`` (the same lanes in the same order as
    :func:`make_ell_matvec`, so every output entry is bitwise identical to
    the single-device SpMV) and one ``all_gather`` of the (nb,) results —
    a copy — assembles the replicated output.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map

    d = int(mesh.devices.size)
    n = a.n
    nb = -(-n // d)
    cols, vals = _csr_to_ell_host(a, n_rows=d * nb)
    W = cols.shape[1]
    sh = NamedSharding(mesh, P(axis, None, None))
    cols_d = jax.device_put(cols.reshape(d, nb, W), sh)
    vals_d = jax.device_put(vals.reshape(d, nb, W), sh)

    def mv(c, v, x):
        xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
        gathered = xg[jnp.minimum(c[0], n)]
        y = masked_lane_sum(c[0], v[0], gathered, COL_SENTINEL)  # (nb,)
        return jax.lax.all_gather(y, axis).reshape(-1)[:n]

    sm = shard_map(
        mv, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None), P(None)),
        out_specs=P(None), check_vma=False,
    )

    def matvec(x):
        return sm(cols_d, vals_d, x.astype(jnp.float32))

    return matvec


def _identity(x):
    return x


def _annotate_reports(res, fact):
    """Copy the factorization's ladder outcome (shift α, degraded flag) onto
    each lane's SolveReport — the serve layer reads these off the response
    instead of re-deriving them from the cache entry."""
    health = getattr(fact, "health", None)
    if health is not None and (health.shift != 0.0 or health.degraded):
        for r in res if isinstance(res, list) else (res,):
            r.report.shift = health.shift
            r.report.degraded = health.degraded
    return res


def _unpermute_results(res, ordering):
    """Map solve output(s) back to original row order — ``x`` is the only
    row-indexed field of a :class:`SolveResult` (pure gather, bitwise-
    neutral). Handles a single result or a multi-RHS result list."""
    for r in res if isinstance(res, list) else (res,):
        r.x = ordering.unpermute_vector(r.x)
    return res


def _trim_history(hist: np.ndarray, it: int, bnorm: float) -> np.ndarray:
    return np.asarray(hist)[:it] / max(bnorm, 1e-30)


# --------------------------------------------------------------------------
# CG (SPD systems — e.g. the Poisson benchmark)
# --------------------------------------------------------------------------
def _init_verdict(bnorm, tolb):
    """Lane verdict before the first iteration: a non-finite ‖b‖ is a
    breakdown on arrival (the quarantine trigger for poisoned requests); a
    ‖b‖ already within tolerance — notably the zero-RHS padding lanes of a
    bucketed batch — is converged at 0 iterations, exactly as the old
    ``res > tolb`` predicates behaved."""
    return jnp.where(
        ~jnp.isfinite(bnorm), jnp.int32(VERDICT_BREAKDOWN),
        jnp.where(bnorm <= tolb, jnp.int32(VERDICT_CONVERGED),
                  jnp.int32(VERDICT_RUNNING)))


def _classify(it, rnorm, stall, bnorm, tolb, window, div_factor, maxiter):
    """Post-step verdict. Later writes win, so the priority (low→high) is
    maxiter < stagnated < diverged < converged < breakdown: a lane that is
    simultaneously at its budget and within tolerance is converged, and a
    non-finite residual is a breakdown no matter what else holds."""
    v = jnp.where(it >= maxiter, jnp.int32(VERDICT_MAXITER),
                  jnp.int32(VERDICT_RUNNING))
    v = jnp.where(stall >= window, jnp.int32(VERDICT_STAGNATED), v)
    v = jnp.where(rnorm > div_factor * jnp.maximum(bnorm, 1e-30),
                  jnp.int32(VERDICT_DIVERGED), v)
    v = jnp.where(rnorm <= tolb, jnp.int32(VERDICT_CONVERGED), v)
    v = jnp.where(~jnp.isfinite(rnorm), jnp.int32(VERDICT_BREAKDOWN), v)
    return v


def _cg_core(matvec, M, b, tol, maxiter):
    bnorm = jnp.linalg.norm(b)
    tolb = tol * bnorm

    def body(carry):
        x, r, z, p, rz, it, _, hist, _v, stall, best = carry
        ap = matvec(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = M(r)
        rz_new = jnp.vdot(r, z)
        p = z + (rz_new / rz) * p
        rnorm = jnp.linalg.norm(r)
        hist = hist.at[it].set(rnorm)
        stall = jnp.where(rnorm < (1.0 - _STAG_EPS) * best, jnp.int32(0), stall + 1)
        best = jnp.minimum(best, rnorm)
        verdict = _classify(it + 1, rnorm, stall, bnorm, tolb,
                            _KRYLOV_STALL_WINDOW, _KRYLOV_DIV_FACTOR, maxiter)
        return x, r, z, p, rz_new, it + 1, rnorm, hist, verdict, stall, best

    def cond(carry):
        return carry[8] == VERDICT_RUNNING

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = M(r0)
    carry = (x0, r0, z0, z0, jnp.vdot(r0, z0), jnp.int32(0),
             jnp.linalg.norm(r0), jnp.zeros(maxiter, jnp.float32),
             _init_verdict(bnorm, tolb), jnp.int32(0), bnorm)
    x, r, *_, it, rnorm, hist, verdict, _s, _b = jax.lax.while_loop(cond, body, carry)
    return x, it, rnorm, bnorm, hist, verdict


def cg(matvec, b, precond=None, tol=1e-5, maxiter=500):
    M = precond or _identity
    b = jnp.asarray(b, jnp.float32)
    run = _cached_engine(matvec, M, ("cg", tol, maxiter), lambda: jax.jit(
        functools.partial(_cg_core, matvec, M, tol=tol, maxiter=maxiter)))
    x, it, rnorm, bnorm, hist, verdict = run(b)
    rel = float(rnorm) / max(float(bnorm), 1e-30)
    return SolveResult(np.asarray(x), int(it), rel, rel <= tol * 1.01,
                       _trim_history(hist, int(it), float(bnorm)),
                       verdict=VERDICTS[int(verdict)])


# --------------------------------------------------------------------------
# BiCGSTAB (general nonsymmetric)
# --------------------------------------------------------------------------
def _bicgstab_core(matvec, M, b, tol, maxiter):
    bnorm = jnp.linalg.norm(b)
    tolb = tol * bnorm

    def body(carry):
        x, r, rhat, p, v, rho, alpha, omega, it, _, hist, _vd, stall, best = carry
        rho_new = jnp.vdot(rhat, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = r + beta * (p - omega * v)
        phat = M(p)
        v = matvec(phat)
        alpha = rho_new / jnp.vdot(rhat, v)
        s = r - alpha * v
        shat = M(s)
        t = matvec(shat)
        omega = jnp.vdot(t, s) / jnp.vdot(t, t)
        x = x + alpha * phat + omega * shat
        r = s - omega * t
        rnorm = jnp.linalg.norm(r)
        hist = hist.at[it].set(rnorm)
        # a ρ/ω collapse (the classic BiCGSTAB breakdown) surfaces as a
        # non-finite rnorm one step later and classifies as BREAKDOWN —
        # strictly more informative than the old bare `isfinite` cut-out
        stall = jnp.where(rnorm < (1.0 - _STAG_EPS) * best, jnp.int32(0), stall + 1)
        best = jnp.minimum(best, rnorm)
        verdict = _classify(it + 1, rnorm, stall, bnorm, tolb,
                            _KRYLOV_STALL_WINDOW, _KRYLOV_DIV_FACTOR, maxiter)
        return (x, r, rhat, p, v, rho_new, alpha, omega, it + 1, rnorm, hist,
                verdict, stall, best)

    def cond(carry):
        return carry[11] == VERDICT_RUNNING

    x0 = jnp.zeros_like(b)
    r0 = b
    carry = (
        x0, r0, r0, jnp.zeros_like(b), jnp.zeros_like(b),
        jnp.float32(1), jnp.float32(1), jnp.float32(1), jnp.int32(0),
        jnp.linalg.norm(r0), jnp.zeros(maxiter, jnp.float32),
        _init_verdict(bnorm, tolb), jnp.int32(0), bnorm,
    )
    out = jax.lax.while_loop(cond, body, carry)
    x, *_, it, rnorm, hist, verdict, _s, _b = out
    return x, it, rnorm, bnorm, hist, verdict


def bicgstab(matvec, b, precond=None, tol=1e-5, maxiter=500):
    M = precond or _identity
    b = jnp.asarray(b, jnp.float32)
    run = _cached_engine(matvec, M, ("bicgstab", tol, maxiter), lambda: jax.jit(
        functools.partial(_bicgstab_core, matvec, M, tol=tol, maxiter=maxiter)))
    x, it, rnorm, bnorm, hist, verdict = run(b)
    rel = float(rnorm) / max(float(bnorm), 1e-30)
    return SolveResult(np.asarray(x), int(it), rel, rel <= tol * 1.01,
                       _trim_history(hist, int(it), float(bnorm)),
                       verdict=VERDICTS[int(verdict)])


# --------------------------------------------------------------------------
# Restarted GMRES(m), right-preconditioned, fully device-resident
# --------------------------------------------------------------------------
def _gmres_core(matvec, M, b, m, tol, maxiter):
    """One jitted computation: Arnoldi + Givens QR of the Hessenberg +
    restarts under a single ``lax.while_loop``.

    The big (n-sized) scan holds only the Arnoldi recurrence. The Givens QR
    runs as a second, m-sized scan over Hessenberg columns: it yields the
    least-squares residual ``|g[j+1]|`` after every inner step, from which
    the number of *useful* steps ``cnt`` is recovered, and the update is
    assembled from the first ``cnt`` columns only (the tail is masked out of
    the back-substitution) — identical to stopping mid-restart. No
    ``lstsq``, no host synchronization anywhere.

    Every reduction (dots, norms, the V·y combination) and every
    multiply-feeding-an-add goes through ``core.bitmath`` (pairwise-tree
    sums, barred products): XLA lowers ``jnp.vdot``/``jnp.sum`` and FMA
    contraction differently per fusion/batching context, so this is what
    makes a ``vmap``-batched lane produce exactly the bits of the same
    solve run alone — the batched-RHS bit-compat contract.
    """
    n = b.shape[0]
    bnorm = bitnorm(b)
    tolb = tol * bnorm

    def inner(x0, r0, beta):
        V0 = jnp.zeros((m + 1, n), jnp.float32).at[0].set(r0 / jnp.maximum(beta, 1e-30))
        H0 = jnp.zeros((m + 1, m), jnp.float32)

        def arnoldi(carry, j):
            V, H = carry
            w = matvec(M(V[j]))

            # modified Gram-Schmidt
            def mgs(i, wh):
                w, h = wh
                hij = bitdot(V[i], w) * (i <= j)
                return w - barred(hij * V[i]), h.at[i].set(hij)

            w, h = jax.lax.fori_loop(0, m + 1, mgs, (w, jnp.zeros(m + 1, jnp.float32)))
            hnext = bitnorm(w)
            V = V.at[j + 1].set(w / jnp.maximum(hnext, 1e-30))
            H = H.at[:, j].set(h.at[j + 1].set(hnext))
            return (V, H), None

        (V, H), _ = jax.lax.scan(arnoldi, (V0, H0), jnp.arange(m))

        # Givens QR over Hessenberg columns (m-sized data, cheap)
        g0 = jnp.zeros(m + 1, jnp.float32).at[0].set(beta)

        def qr_col(carry, inp):
            cs, sn, g = carry
            h, j = inp

            def rot(i, h):
                on = i < j
                hi = barred(cs[i] * h[i]) + barred(sn[i] * h[i + 1])
                hi1 = barred(-sn[i] * h[i]) + barred(cs[i] * h[i + 1])
                return (h.at[i].set(jnp.where(on, hi, h[i]))
                         .at[i + 1].set(jnp.where(on, hi1, h[i + 1])))

            h = jax.lax.fori_loop(0, m, rot, h)
            dsafe = jnp.maximum(jnp.sqrt(barred(h[j] * h[j]) + barred(h[j + 1] * h[j + 1])), 1e-30)
            c, s = h[j] / dsafe, h[j + 1] / dsafe
            hcol = h.at[j].set(barred(c * h[j]) + barred(s * h[j + 1])).at[j + 1].set(0.0)
            g = g.at[j + 1].set(-s * g[j]).at[j].set(c * g[j])
            return (cs.at[j].set(c), sn.at[j].set(s), g), (hcol[:m], jnp.abs(g[j + 1]))

        (_cs, _sn, g), (r_cols, res_seq) = jax.lax.scan(
            qr_col, (jnp.zeros(m, jnp.float32), jnp.zeros(m, jnp.float32), g0),
            (H.T, jnp.arange(m)),
        )
        # useful steps: everything up to (and including) the first step that
        # cleared the tolerance; the masked tail contributes nothing below
        conv = res_seq <= tolb
        cnt = jnp.where(jnp.any(conv), jnp.argmax(conv) + 1, m).astype(jnp.int32)
        kmask = jnp.arange(m) < cnt
        R = r_cols.T * kmask  # zero masked columns; masked rows get unit diag
        g_eff = jnp.where(kmask, g[:m], 0.0)

        def backsub(jj, y):
            j = m - 1 - jj
            rj = R[j] * (jnp.arange(m) > j)
            num = g_eff[j] - bitdot(rj, y)
            den = jnp.where(kmask[j], R[j, j], 1.0)
            return y.at[j].set(num / den)

        y = jax.lax.fori_loop(0, m, backsub, jnp.zeros(m, jnp.float32))

        # u = V[:m].T @ y as a fixed-order sequential combination (a matmul
        # reduces over m in a context-dependent order)
        def axpy(acc, vy):
            vj, yj = vy
            return acc + barred(yj * vj), None

        u, _ = jax.lax.scan(axpy, jnp.zeros_like(r0), (V[:m], y))
        return x0 + M(u), cnt

    def outer_cond(carry):
        return carry[6] == VERDICT_RUNNING

    def outer_body(carry):
        x, r, it, res, hist, tot, verdict, stall = carry
        active = verdict == VERDICT_RUNNING  # freezes terminated vmap lanes
        x2, cnt = inner(x, r, res)
        r2 = b - matvec(x2)
        rtrue = bitnorm(r2)
        # verdict/stall ride outside the iterate arithmetic: x2/r2/rtrue are
        # computed exactly as before, so classification changes no bits
        stall2 = jnp.where(rtrue < (1.0 - _STAG_EPS) * res, jnp.int32(0), stall + 1)
        v2 = _classify(it + 1, rtrue, stall2, bnorm, tolb,
                       _GMRES_STALL_WINDOW, _GMRES_DIV_FACTOR, maxiter)
        new = (x2, r2, it + 1, rtrue, hist.at[it].set(rtrue), tot + cnt, v2, stall2)
        return jax.tree_util.tree_map(lambda nw, old: jnp.where(active, nw, old), new, carry)

    init = (jnp.zeros_like(b), b, jnp.int32(0), bnorm,
            jnp.zeros(maxiter, jnp.float32), jnp.int32(0),
            _init_verdict(bnorm, tolb), jnp.int32(0))
    x, _r, it, res, hist, tot, verdict, _stall = jax.lax.while_loop(
        outer_cond, outer_body, init)
    # non-finite ‖b‖ must surface as a non-finite relative residual: with a
    # bare `bnorm > 0` a NaN b takes the 0.0 branch and the lane would
    # report converged — the exact poison the breakdown verdict exists for
    rel = jnp.where(bnorm > 0, res / jnp.maximum(bnorm, 1e-30),
                    jnp.where(jnp.isfinite(bnorm), 0.0, jnp.nan))
    return x, rel, it, tot, hist, bnorm, verdict


def gmres(matvec, b, precond=None, restart=30, tol=1e-5, maxiter=20):
    """maxiter counts *outer* restarts. Solves A (M^{-1} u) = b, x = M^{-1} u.

    ``iterations`` reports the inner (Arnoldi) steps that did work;
    ``history`` holds the true relative residual after each restart.
    Compilation is cached on the identity of ``matvec``/``precond`` — reuse
    the same closures (e.g. a factorization's ``PrecondApply``) and repeated
    solves skip straight to the compiled engine."""
    M = precond or _identity
    b = jnp.asarray(b, jnp.float32)
    run = _cached_engine(matvec, M, ("gmres", restart, tol, maxiter), lambda: jax.jit(
        functools.partial(_gmres_core, matvec, M, m=restart, tol=tol, maxiter=maxiter)))
    x, rel, it, tot, hist, bnorm, verdict = run(b)
    rel = float(rel)
    return SolveResult(np.asarray(x), int(tot), rel, rel <= tol * 1.01,
                       _trim_history(hist, int(it), float(bnorm)),
                       verdict=VERDICTS[int(verdict)])


def gmres_batched(matvec, bs, precond=None, restart=30, tol=1e-5, maxiter=20) -> List[SolveResult]:
    """GMRES over a (batch, n) stack of right-hand sides in one dispatch.

    ``vmap`` of the single-RHS engine: every lane shares the cached
    triangular plan and SpMV arrays; converged lanes freeze (per-lane
    iteration counts and histories stay exact) while the rest continue.

    ``tol`` may be a scalar or a per-lane ``(batch,)`` array — the serving
    coalescer batches requests with *different* tolerances into one bucketed
    solve. Per-lane tolerances ride as a vmapped runtime argument, so one
    compiled engine serves every tolerance mix (no per-tol executables) and
    a lane's arithmetic is bitwise identical to the same solve run alone
    with its scalar tolerance: ``tol`` only feeds ``tol * ||b||`` (computed
    at runtime either way) and the stopping comparisons — never the
    iterate arithmetic."""
    M = precond or _identity
    bs = jnp.asarray(bs, jnp.float32)
    if bs.ndim != 2:
        raise ValueError(f"gmres_batched expects (batch, n), got shape {bs.shape}")
    tol_arr = np.asarray(tol, np.float32)
    if tol_arr.ndim == 0:
        run = _cached_engine(matvec, M, ("gmres_batched", restart, tol, maxiter), lambda: jax.jit(
            jax.vmap(functools.partial(_gmres_core, matvec, M, m=restart, tol=tol,
                                       maxiter=maxiter))))
        x, rel, it, tot, hist, bnorm, verdict = run(bs)
        tols = np.full(bs.shape[0], float(tol), np.float32)
    else:
        if tol_arr.shape != (bs.shape[0],):
            raise ValueError(
                f"gmres_batched: per-lane tol must have shape ({bs.shape[0]},) "
                f"matching the batch, got {tol_arr.shape}")
        run = _cached_engine(matvec, M, ("gmres_batched_vtol", restart, maxiter), lambda: jax.jit(
            jax.vmap(lambda b, t: _gmres_core(matvec, M, b, m=restart, tol=t, maxiter=maxiter))))
        x, rel, it, tot, hist, bnorm, verdict = run(bs, jnp.asarray(tol_arr))
        tols = tol_arr
    verdict = np.asarray(verdict)
    out = []
    for i in range(bs.shape[0]):
        r = float(rel[i])
        out.append(SolveResult(np.asarray(x[i]), int(tot[i]), r, r <= float(tols[i]) * 1.01,
                               _trim_history(hist[i], int(it[i]), float(bnorm[i])),
                               verdict=VERDICTS[int(verdict[i])]))
    return out


def solve_sharded(a, b, k=1, mesh=None, band_rows=32, rule="sum",
                  broadcast="psum", method="gmres", tol=1e-5, fact=None,
                  bucket=True, ordering=None, precond_method=None,
                  on_breakdown="raise", pivot_tol=None, **kw):
    """Distributed end-to-end solve: sharded TOP-ILU factorize + solve.

    The factorization stays device-resident (``ilu_sharded``), the
    preconditioner applies through the epoch-fused band-partitioned sweeps,
    and the SpMV runs row-block sharded — L/U and A are never re-replicated
    onto one device; only O(n) vectors are. The Krylov iteration itself is
    the same device-resident engine as the single-device path, so with
    identical matvec/precond outputs (both bitwise contracts) the iterates
    — and the solution — are bitwise identical to ``solve_with_ilu``.

    A 2-D ``b`` of shape (nb, n) routes through ``gmres_batched`` over the
    sharded matvec/precond and returns a list of results: the vmapped
    engine batches every sweep-epoch and SpMV collective over all
    right-hand sides (one exchange per epoch for the whole batch). With
    ``bucket=True`` (default) the batch is zero-padded up to the nearest
    ``batch_buckets()`` size, so serving traffic with ragged batch shapes
    reuses a bounded set of compiled engines; padded lanes are independent
    under vmap and are sliced off, leaving every real column bitwise equal
    to its per-column solve.

    Returns ``(SolveResult(s), ShardedILUFactorization)``. Factorization
    and matvec are memoized on the matrix, keyed by mesh devices (and the
    factorization config), like ``solve_with_ilu``'s caches; pass an
    already-built ``fact`` (a ``ShardedILUFactorization`` of the same
    matrix) to reuse it — and its cached precond — directly.

    ``ordering=`` solves the symmetrically permuted system (``"rcm"``,
    ``"fusion"`` — which targets this mesh's band ownership so sweep
    epochs fuse — an ``Ordering``, or a permutation array): ``A`` permutes
    once at plan time, ``b``/``x`` un/permute at this boundary (multi-RHS
    included), and the returned ``fact`` carries the permutation — a
    ``fact=`` round-trip without ``ordering=`` re-adopts it automatically.
    """
    from .api import ilu_sharded
    from .top_ilu import band_mesh

    # --- ordering boundary: solve the permuted system, then gather back ---
    # (a factorization built with an ordering carries it; adopting it here
    # keeps `fact=` reuse consistent instead of silently mixing row orders)
    caller_fact = fact is not None
    if ordering is None and caller_fact:
        ordering = getattr(fact, "ordering", None)
    if ordering is not None:
        from .ordering import make_ordering, permuted_system

        n_dev = int((fact.mesh if fact is not None else band_mesh(mesh)).devices.size)
        ord_ = make_ordering(a, ordering, n_devices=n_dev, band_rows=band_rows)
        if ord_ is not None:
            if caller_fact:
                # a caller-supplied fact must have been factored under this
                # exact permutation — anything else silently mixes row orders
                # (matvec on one system, preconditioner on another)
                fo = getattr(fact, "ordering", None)
                if fo is None or not np.array_equal(fo.perm, ord_.perm):
                    raise ValueError(
                        "solve_sharded: `fact` was factored under a "
                        f"different row ordering than ordering={ord_.name!r}"
                        " — pass the fact's own ordering (or none, to adopt"
                        " it), or refactor under the requested one")
            ap = permuted_system(a, ord_)
            # ordering="natural" stops the recursion from re-adopting the
            # ordering carried by `fact` — `ap` is already permuted
            res, fact = solve_sharded(
                ap, ord_.permute_vector(np.asarray(b, np.float32)), k=k,
                mesh=mesh, band_rows=band_rows, rule=rule, broadcast=broadcast,
                method=method, tol=tol, fact=fact, bucket=bucket,
                ordering="natural", precond_method=precond_method,
                on_breakdown=on_breakdown, pivot_tol=pivot_tol, **kw)
            if not caller_fact and fact is not None and fact.ordering is None:
                fact.ordering = ord_  # so `fact=` round-trips re-adopt it
            return _unpermute_results(res, ord_), fact

    if fact is not None:
        if mesh is not None and not np.array_equal(
            [d.id for d in mesh.devices.flat],
            [d.id for d in fact.mesh.devices.flat],
        ):
            raise ValueError(
                "solve_sharded: `fact` was factored on a different mesh than "
                "`mesh` — the SpMV and the preconditioner must share one mesh")
        mesh = fact.mesh
    else:
        mesh = band_mesh(mesh)
    mesh_key = tuple(dev.id for dev in mesh.devices.flat)
    cache = a.__dict__.setdefault("_solve_cache", {})
    mv_key = ("sharded_matvec", mesh_key)
    if mv_key not in cache:
        cache[mv_key] = make_sharded_ell_matvec(a, mesh)
    matvec = cache[mv_key]
    # precond_method=None defers to the factorization's own default
    # ("sweep" unless it was built with something else); "sweep"/"inverse"/
    # "auto" override per solve — engines for both methods cache on the fact
    precond = None
    if fact is not None:
        precond = fact.precond(broadcast=broadcast, method=precond_method)
    elif k is not None:
        f_key = ("sharded_fact", k, rule, band_rows, broadcast, mesh_key)
        if on_breakdown != "raise" or pivot_tol is not None:
            f_key = f_key + (on_breakdown, pivot_tol)
        if f_key not in cache:
            cache[f_key] = ilu_sharded(a, k, rule=rule, band_rows=band_rows,
                                       mesh=mesh, broadcast=broadcast,
                                       on_breakdown=on_breakdown,
                                       pivot_tol=pivot_tol)
        fact = cache[f_key]
        precond = fact.precond(broadcast=broadcast, method=precond_method)
    b = jnp.asarray(b, jnp.float32)
    if b.ndim == 2:
        if method != "gmres":
            raise ValueError("batched right-hand sides are supported for method='gmres' only")
        nb = b.shape[0]
        if bucket:
            b = _pad_rhs_batch(b, bucket_batch(nb))
        res = gmres_batched(matvec, b, precond,
                            tol=_pad_tols(tol, b.shape[0]), **kw)[:nb]
        return _annotate_reports(res, fact), fact
    if b.ndim != 1:
        raise ValueError(f"solve_sharded expects b of shape (n,) or (batch, n), got {b.shape}")
    fn = {"gmres": gmres, "bicgstab": bicgstab, "cg": cg}[method]
    res = fn(matvec, b, precond, tol=tol, **kw)
    return _annotate_reports(res, fact), fact


def warm_solve(a, k=1, batch_sizes=(1,), mesh=None, band_rows=32, rule="sum",
               broadcast="psum", method="gmres", tol=1e-5, sharded=True,
               ordering=None, precond_method=None,
               on_breakdown="raise", pivot_tol=None, **kw):
    """Serving warmup: pre-compile the whole factorize→precondition→solve
    stack for the given RHS batch-size buckets, so the first real request
    of a pre-warmed shape never pays the ~1–2 s first-dispatch XLA compile.

    Factors ``a`` once (cached on the matrix like ``solve_sharded`` /
    ``solve_with_ilu``), AOT-compiles the preconditioner sweep per bucket
    (``precond.warm``), then drives one zero-RHS solve per bucket through
    the real solver entry so the Krylov engine jits land in the same
    per-matrix caches a live solve will hit. With ``REPRO_JIT_CACHE`` set
    the compilations persist to disk, making warmup a once-per-machine
    cost. Returns {batch_size: warmup_seconds}.
    """
    import time

    from .api import enable_jit_cache

    enable_jit_cache()
    out = {}
    for nb in batch_sizes:
        t0 = time.perf_counter()
        tgt = bucket_batch(nb) if nb > 1 else 1
        zb = np.zeros((tgt, a.n) if nb > 1 else a.n, np.float32)
        if sharded:
            _res, fact = solve_sharded(a, zb, k=k, band_rows=band_rows,
                                       rule=rule, broadcast=broadcast,
                                       method=method, tol=tol, mesh=mesh,
                                       ordering=ordering,
                                       precond_method=precond_method,
                                       on_breakdown=on_breakdown,
                                       pivot_tol=pivot_tol, **kw)
            fact.precond(broadcast=broadcast, method=precond_method).warm((tgt,))
        else:
            _res, fact = solve_with_ilu(a, zb, k=k, band_rows=band_rows,
                                        method=method, tol=tol,
                                        ordering=ordering,
                                        precond_method=precond_method,
                                        on_breakdown=on_breakdown,
                                        pivot_tol=pivot_tol, **kw)
            fact.precond(method=precond_method).warm((tgt,))
        out[nb] = time.perf_counter() - t0
    return out


def solve_with_ilu(a, b, k=1, method="gmres", backend="jax", tol=1e-5,
                   band_rows=32, use_pallas=True, ordering=None,
                   precond_method=None, on_breakdown="raise", pivot_tol=None,
                   **kw):
    """End-to-end: factorize with ILU(k), then solve. Returns (SolveResult, fact).

    ``ordering=`` solves the symmetrically permuted system instead
    (``"rcm"``, ``"fusion"``, an ``Ordering``, or a permutation array):
    ``A`` permutes once at plan time (cached on the matrix), ``b``/``x``
    un/permute at this boundary — including multi-RHS batches — and the
    returned ``fact`` describes the permuted system (its ``ordering``
    field carries the permutation).

    The SpMV runs through the Pallas ELL kernel and the preconditioner
    through the factorization's cached ``PrecondApply`` (fused wavefront
    kernel) — the whole iteration is device-resident. A 2-D ``b`` of shape
    (batch, n) routes through ``gmres_batched`` and returns a list of
    results sharing one factorization.

    ELL arrays, the matvec closure, and the factorization are memoized on
    the matrix object: the solver jits are keyed on (matvec, precond)
    identity, so repeated solves against the same matrix reuse one compiled
    engine instead of retracing (and the jit cache holds one entry per
    matrix, not per call). Mutating ``a`` in place invalidates none of
    this — build a fresh CSRMatrix instead.
    """
    from .api import ilu

    if ordering is not None:
        from .ordering import make_ordering, permuted_system

        ord_ = make_ordering(a, ordering, n_devices=1, band_rows=band_rows)
        if ord_ is not None:
            ap = permuted_system(a, ord_)
            res, fact = solve_with_ilu(
                ap, ord_.permute_vector(np.asarray(b, np.float32)), k=k,
                method=method, backend=backend, tol=tol, band_rows=band_rows,
                use_pallas=use_pallas, precond_method=precond_method,
                on_breakdown=on_breakdown, pivot_tol=pivot_tol, **kw)
            if fact is not None and fact.ordering is None:
                fact.ordering = ord_
            return _unpermute_results(res, ord_), fact

    cache = a.__dict__.setdefault("_solve_cache", {})
    mv_key = ("matvec", bool(use_pallas))
    if mv_key not in cache:
        cols, vals = csr_to_ell_arrays(a)
        mk = make_pallas_matvec if use_pallas else make_ell_matvec
        cache[mv_key] = mk(cols, vals, a.n)
    matvec = cache[mv_key]
    fact = None
    precond = None
    if k is not None:
        f_key = ("fact", k, backend, band_rows)
        if on_breakdown != "raise" or pivot_tol is not None:
            f_key = f_key + (on_breakdown, pivot_tol)
        if f_key not in cache:
            cache[f_key] = ilu(a, k, backend=backend, band_rows=band_rows,
                               on_breakdown=on_breakdown, pivot_tol=pivot_tol)
        fact = cache[f_key]
        precond = fact.precond(use_pallas=use_pallas, method=precond_method)
    b = jnp.asarray(b, jnp.float32)
    if b.ndim == 2:
        if method != "gmres":
            raise ValueError("batched right-hand sides are supported for method='gmres' only")
        res = gmres_batched(matvec, b, precond, tol=tol, **kw)
        return _annotate_reports(res, fact), fact
    fn = {"gmres": gmres, "bicgstab": bicgstab, "cg": cg}[method]
    res = fn(matvec, b, precond, tol=tol, **kw)
    return _annotate_reports(res, fact), fact
