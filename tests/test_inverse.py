"""Level-based incomplete inverse preconditioning: oracle, engine, kernel,
and the ``precond_method`` auto policy.

The bit-compat contract under test (paper abstract, DESIGN.md §Inverse):
the inverse method is NOT bitwise-comparable to classical ILU(k) — it is a
different approximation of M^{-1} — but every execution path (jnp engine,
Pallas chain kernel, precond apply, batched apply, warmed AOT apply) must
be bitwise-equal to the sequential NumPy oracle in
``repro.core.inverse_ref``. The auto-policy tests pin ``"auto"`` against
the modeled communication records with nothing compiled.
"""
import importlib
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import matgen, numeric_ilu_ref, poisson_2d, symbolic_ilu_k  # noqa: E402
from repro.core.inverse import (  # noqa: E402
    AUTO_COLLECTIVE_COST_BYTES,
    InversePrecondApply,
    build_inverse_plan,
    compute_inverse_values,
    inverse_chain_jnp,
    inverse_comm_model,
    modeled_apply_cost,
    resolve_precond_method,
)
from repro.core.inverse_ref import (  # noqa: E402
    inverse_apply_ref,
    inverse_pattern_ref,
    inverse_values_ref,
)
from repro.core.planner import COL_SENTINEL  # noqa: E402


def _assert_bitwise(got, want, msg=""):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (got.shape, want.shape)
    assert np.array_equal(got.view(np.int32), want.view(np.int32)), msg


def _factored(n=64, k=1, seed=0, density=0.12):
    a = matgen(n, density=density, seed=seed)
    pat = symbolic_ilu_k(a, k)
    return a, pat, numeric_ilu_ref(a, pat)


# --------------------------------------------------------------------------
# oracle semantics
# --------------------------------------------------------------------------
def test_inverse_pattern_k0_equals_factor_pattern():
    """With k=0 every chain of length > 1 costs >= 1, so the truncated
    inverse keeps exactly the level-0 factor entries (plus the diagonal) —
    a structurally-ILU(0)-shaped inverse."""
    _a, pat, _vals = _factored(48, 0, seed=3)
    w_cols, z_cols = inverse_pattern_ref(pat)
    n = pat.n
    for i in range(n):
        s, e = int(pat.indptr[i]), int(pat.indptr[i + 1])
        d = int(pat.diag_ptr[i])
        want_w = set(pat.indices[s:e][: d].tolist()) | {i}
        want_z = set(pat.indices[s:e][d + 1 :].tolist()) | {i}
        assert set(w_cols[i][w_cols[i] < n].tolist()) == want_w, i
        assert set(z_cols[i][z_cols[i] < n].tolist()) == want_z, i


def test_inverse_full_fill_is_exact_triangular_inverse():
    """With k large enough to keep every chain, W and Z are the *exact*
    L^{-1} / U^{-1} (up to f32 rounding) — the truncation is the only
    approximation in the method."""
    a, pat, vals = _factored(24, 2, seed=1, density=0.2)
    n = pat.n
    w_cols, z_cols = inverse_pattern_ref(pat, k=n)  # keep everything
    w_vals, z_vals = inverse_values_ref(pat, vals, w_cols, z_cols)

    from repro.core import split_lu

    L, U = (np.asarray(m.todense(), np.float32) for m in split_lu(pat, vals))
    W = np.zeros((n, n), np.float32)
    Z = np.zeros((n, n), np.float32)
    for i in range(n):
        W[i, w_cols[i][w_cols[i] < n]] = w_vals[i][w_cols[i] < n]
        Z[i, z_cols[i][z_cols[i] < n]] = z_vals[i][z_cols[i] < n]
    np.testing.assert_allclose(W @ L, np.eye(n), atol=2e-4)
    np.testing.assert_allclose(Z @ U, np.eye(n), atol=2e-4)


@pytest.mark.parametrize("k", [0, 1, 2])
def test_truncated_inverse_still_preconditions(k):
    """GMRES with the truncated inverse converges on the standard fixtures
    (it may take a few more iterations than the exact sweep — that is the
    trade, not a failure)."""
    from repro.core.solvers import solve_with_ilu

    a = poisson_2d(8)
    b = np.random.default_rng(4).standard_normal(a.n).astype(np.float32)
    res, _ = solve_with_ilu(a, b, k=k, tol=1e-6, use_pallas=False, precond_method="inverse")
    assert res.converged


# --------------------------------------------------------------------------
# engine == oracle, bit for bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k,seed", [(0, 0), (1, 1), (2, 2)])
def test_plan_values_bitwise_vs_oracle(k, seed):
    _a, pat, vals = _factored(56, k, seed=seed)
    w_cols, z_cols = inverse_pattern_ref(pat)
    want_w, want_z = inverse_values_ref(pat, vals, w_cols, z_cols)
    plan = build_inverse_plan(pat, vals)
    assert np.array_equal(plan.w_cols, w_cols)
    assert np.array_equal(plan.z_cols, z_cols)
    got_w, got_z = compute_inverse_values(plan)
    _assert_bitwise(got_w, want_w, "W values != sequential oracle")
    _assert_bitwise(got_z, want_z, "Z values != sequential oracle")


@pytest.mark.parametrize("use_pallas", [False, True])
def test_precond_apply_bitwise_vs_oracle(use_pallas):
    """Single apply, batched apply, and the warmed AOT paths all reproduce
    the oracle chain bitwise (jnp engine and Pallas kernel alike)."""
    _a, pat, vals = _factored(48, 1, seed=5)
    w_cols, z_cols = inverse_pattern_ref(pat)
    w_vals, z_vals = inverse_values_ref(pat, vals, w_cols, z_cols)
    b = np.random.default_rng(6).standard_normal(pat.n).astype(np.float32)
    B = np.random.default_rng(7).standard_normal((3, pat.n)).astype(np.float32)
    want = inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, b)
    want_B = inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, B)

    p = InversePrecondApply(pat, vals, use_pallas=use_pallas)
    _assert_bitwise(p(b), want)
    _assert_bitwise(p.batched(B), want_B)
    p.warm((1, 4))  # AOT single + bucketed batch (3 pads to 4)
    _assert_bitwise(p(b), want)
    _assert_bitwise(p.batched(B), want_B)


def test_api_precond_inverse_bitwise_and_cached():
    """``ILUFactorization.precond(method=...)`` routes and caches per
    (method, use_pallas); D=1 ``"auto"`` resolves to the sweep engine."""
    from repro.core.api import ilu

    a = matgen(64, density=0.1, seed=8)
    fact = ilu(a, 1, backend="jax")
    b = np.random.default_rng(9).standard_normal(a.n).astype(np.float32)
    w_cols, z_cols = inverse_pattern_ref(fact.pattern)
    w_vals, z_vals = inverse_values_ref(fact.pattern, fact.vals, w_cols, z_cols)
    want = inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, b)
    p = fact.precond(use_pallas=False, method="inverse")
    _assert_bitwise(p(b), want)
    assert fact.precond(use_pallas=False, method="inverse") is p
    assert fact.precond(use_pallas=False, method="auto") is fact.precond(
        use_pallas=False, method="sweep")


def test_solve_with_ilu_inverse_converges_and_reuses_fact():
    from repro.core.solvers import solve_with_ilu

    a = matgen(96, density=0.1, seed=11)
    b = np.random.default_rng(1).standard_normal(a.n).astype(np.float32)
    r_sw, f1 = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False)
    r_inv, f2 = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False, precond_method="inverse")
    assert f1 is f2  # one factorization, two apply engines
    assert r_sw.converged and r_inv.converged
    # multi-RHS through gmres_batched with the inverse preconditioner
    B = np.random.default_rng(2).standard_normal((3, a.n)).astype(np.float32)
    rs, _ = solve_with_ilu(a, B, k=1, tol=1e-6, use_pallas=False, precond_method="inverse")
    assert all(r.converged for r in rs)


# --------------------------------------------------------------------------
# the Pallas chain kernel
# --------------------------------------------------------------------------
def test_inverse_chain_kernel_bitwise():
    """Kernel (interpret), jnp reference, and the ops wrapper agree with the
    sequential oracle apply, bit for bit."""
    from repro.kernels import ops
    ic = importlib.import_module("repro.kernels.inverse_chain")

    _a, pat, vals = _factored(64, 1, seed=13)
    w_cols, z_cols = inverse_pattern_ref(pat)
    w_vals, z_vals = inverse_values_ref(pat, vals, w_cols, z_cols)
    b = np.random.default_rng(14).standard_normal(pat.n).astype(np.float32)
    want = inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, b)
    args = tuple(jnp.asarray(x) for x in (w_cols, w_vals, z_cols, z_vals, b))
    _assert_bitwise(ic.inverse_chain(*args, interpret=True), want)
    _assert_bitwise(inverse_chain_jnp(*args), want)
    _assert_bitwise(ops.inverse_chain(*args), want)


@pytest.mark.pallas_compiled
def test_compiled_inverse_chain_bitwise():
    ic = importlib.import_module("repro.kernels.inverse_chain")

    _a, pat, vals = _factored(64, 1, seed=13)
    w_cols, z_cols = inverse_pattern_ref(pat)
    w_vals, z_vals = inverse_values_ref(pat, vals, w_cols, z_cols)
    b = np.random.default_rng(14).standard_normal(pat.n).astype(np.float32)
    want = inverse_apply_ref(w_cols, w_vals, z_cols, z_vals, b)
    args = tuple(jnp.asarray(x) for x in (w_cols, w_vals, z_cols, z_vals, b))
    _assert_bitwise(ic.inverse_chain(*args, interpret=False), want)


def test_disable_pallas_escape_hatch(monkeypatch):
    """REPRO_DISABLE_PALLAS routes ops.inverse_chain to the jnp reference
    (one shared implementation — trivially bitwise)."""
    from repro.kernels import ops

    _a, pat, vals = _factored(40, 1, seed=15)
    w_cols, z_cols = inverse_pattern_ref(pat)
    w_vals, z_vals = inverse_values_ref(pat, vals, w_cols, z_cols)
    b = np.random.default_rng(16).standard_normal(pat.n).astype(np.float32)
    args = tuple(jnp.asarray(x) for x in (w_cols, w_vals, z_cols, z_vals, b))
    monkeypatch.setattr(ops, "_DISABLED", True)
    _assert_bitwise(ops.inverse_chain(*args), inverse_chain_jnp(*args))


# --------------------------------------------------------------------------
# the "auto" selection policy — pinned against the modeled comm records,
# nothing compiled (host-only planning)
# --------------------------------------------------------------------------
def test_inverse_comm_model_fields():
    m = inverse_comm_model(100, 4)
    assert m["collectives_per_apply"] == 2  # one all_gather per SpMV
    assert m["payload_slots_per_apply"] == 2 * 25
    assert m["bytes_per_apply"] == 3 * 2 * 25 * 4
    assert inverse_comm_model(100, 1)["collectives_per_apply"] == 0
    assert modeled_apply_cost(m) == 2 * AUTO_COLLECTIVE_COST_BYTES + m["bytes_per_apply"]


def test_auto_single_device_is_sweep():
    _a, pat, _vals = _factored(48, 1, seed=17)
    assert resolve_precond_method("auto", pat, n_devices=1) == "sweep"
    assert resolve_precond_method("sweep", pat, n_devices=8) == "sweep"
    assert resolve_precond_method("inverse", pat, n_devices=1) == "inverse"
    with pytest.raises(ValueError):
        resolve_precond_method("newton", pat)


def test_auto_picks_inverse_when_epochs_dominate():
    """Natural-ordered Poisson at D=8: the sweep needs one collective per
    epoch (tens of them), the chain needs two — the modeled sweep cost
    dominates and auto must pick the inverse."""
    from repro.core.ordering import sweep_comm_model

    a = poisson_2d(16)  # n=256, natural ordering: deep wavefronts
    pat = symbolic_ilu_k(a, 1)
    sweep = sweep_comm_model(pat, 8, 8)
    assert sweep["collectives_per_apply"] > 2  # the premise of the pin
    assert modeled_apply_cost(sweep) > modeled_apply_cost(inverse_comm_model(pat.n, 8))
    assert resolve_precond_method("auto", pat, n_devices=8, band_rows=8) == "inverse"


def test_auto_picks_sweep_when_chain_is_longer():
    """Block-diagonal system with blocks aligned to device bands: every
    sweep epoch is device-local, so the whole apply fuses to one boundary
    collective with a tiny read set, while the chain still pays its two
    full-slice gathers — auto must keep the sweep."""
    from repro.core.ordering import sweep_comm_model
    from repro.core.sparse import CSRMatrix

    D, rows = 4, 16  # 4 tridiagonal blocks of 16 rows, bands of 16
    n = D * rows
    dense = np.zeros((n, n), np.float32)
    for blk in range(D):
        for i in range(rows):
            g = blk * rows + i
            dense[g, g] = 4.0
            if i > 0:
                dense[g, g - 1] = -1.0
            if i < rows - 1:
                dense[g, g + 1] = -1.0
    a = CSRMatrix.from_dense(dense)
    pat = symbolic_ilu_k(a, 1)
    sweep = sweep_comm_model(pat, rows, D)
    assert sweep["collectives_per_apply"] == 1  # one fused L->U boundary
    assert modeled_apply_cost(sweep) < modeled_apply_cost(inverse_comm_model(n, D))
    assert resolve_precond_method("auto", pat, n_devices=D, band_rows=rows) == "sweep"


def test_auto_respects_precomputed_sweep_summary():
    """``sweep_summary=`` short-circuits the model — the sharded
    factorization path feeds its actual plan's ``comm_summary`` in."""
    _a, pat, _vals = _factored(48, 1, seed=19)
    cheap = {"collectives_per_apply": 0, "bytes_per_apply": 0}
    dear = {"collectives_per_apply": 50, "bytes_per_apply": 10 * AUTO_COLLECTIVE_COST_BYTES}
    assert resolve_precond_method("auto", pat, n_devices=4, sweep_summary=cheap) == "sweep"
    assert resolve_precond_method("auto", pat, n_devices=4, sweep_summary=dear) == "inverse"


def test_plan_pad_lanes_are_positive_zero():
    """Engine pad lanes must be +0.0 exactly (the U sweep's pad arithmetic
    could round to -0.0 through a negative diagonal — the oracle never
    writes pads, so the engine normalizes them)."""
    _a, pat, vals = _factored(48, 2, seed=21)
    plan = build_inverse_plan(pat, vals)
    w, z = (np.asarray(x) for x in compute_inverse_values(plan))
    for cols, vals_ in ((plan.w_cols, w), (plan.z_cols, z)):
        pads = vals_[cols >= pat.n]
        assert np.all(pads.view(np.int32) == 0), "pad lane not +0.0"
    assert np.all(plan.w_cols[plan.w_cols >= pat.n] == COL_SENTINEL)
