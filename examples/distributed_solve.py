"""Distributed device-resident factorize + solve — the paper's §IV story,
end to end, runnable on CPU.

Forces a simulated multi-device mesh (``XLA_FLAGS=
--xla_force_host_platform_device_count``), factors with the sharded TOP-ILU
engine (each device stores only its bands' values + a pivot-row halo),
solves with the epoch-fused band-partitioned preconditioner + row-block
sharded SpMV — L/U and A are never re-replicated onto one device — and
asserts the whole pipeline is **bitwise equal** to the single-device path:
the single solve, and every column of a ragged multi-RHS batch (one
bucketed dispatch, every collective shared by the batch). Ends with the
serving-warmup flow (``warm_solve`` + ``REPRO_JIT_CACHE``).

    python examples/distributed_solve.py [devices] [grid]   # default 4, 24
"""
import os
import subprocess
import sys

if os.environ.get("_DIST_SOLVE_CHILD") != "1":
    import tempfile

    d = sys.argv[1] if len(sys.argv) > 1 else "4"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
    env.setdefault("JAX_PLATFORMS", "cpu")  # don't probe for real TPUs
    # persistent compile cache: the serving setup — every engine jit and
    # every `warm` AOT compile lands here once and is reused by later runs
    # of this example too (stable path, not a fresh tempdir per run)
    cache_dir = os.path.join(tempfile.gettempdir(), "repro-jit-cache")
    os.makedirs(cache_dir, exist_ok=True)
    env.setdefault("REPRO_JIT_CACHE", cache_dir)
    env["_DIST_SOLVE_CHILD"] = "1"
    sys.exit(subprocess.run([sys.executable, __file__] + sys.argv[1:], env=env).returncode)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax

    from repro.core import numeric_ilu_ref, poisson_2d
    from repro.core.api import enable_jit_cache, ilu, ilu_sharded
    from repro.core.solvers import solve_sharded, solve_with_ilu

    enable_jit_cache()  # REPRO_JIT_CACHE set by the parent: compiles persist

    grid = int(sys.argv[2]) if len(sys.argv) > 2 else 24
    devs = jax.devices()
    d = len(devs)
    a = poisson_2d(grid)
    print(f"devices: {d} (simulated mesh) | 2-D Poisson n={a.n} nnz={a.nnz}")

    # -- distributed factorization: values stay sharded on the mesh --------
    fact = ilu_sharded(a, k=1, band_rows=8)
    plan = fact.plan
    print(f"\nsharded TOP-ILU(1): {plan.n_bands} bands x {plan.band_rows} rows, "
          f"{plan.n_supersteps} supersteps")
    print(f"per-device value state : {plan.per_device_value_bytes():6d} B "
          f"(local {plan.s_loc} rows + halo {plan.halo_size} + scratch)")
    print(f"replicated (pre-PR-3)  : {plan.replicated_value_bytes():6d} B")
    print(f"halo exchange          : {plan.halo_bytes_per_superstep():6d} B/superstep "
          f"(old full-band gather: {plan.replicated_bytes_per_superstep()} B)")
    shapes = {s.data.shape for s in fact.loc_vals.addressable_shards}
    assert shapes == {(1, plan.s_loc, plan.width)}, shapes

    # bitwise check: sharded factors == sequential oracle == jax backend
    want = numeric_ilu_ref(a, fact.pattern)
    got = fact.values_csr()
    assert np.array_equal(got.view(np.int32), want.view(np.int32))
    single = ilu(a, k=1, backend="jax")
    assert np.array_equal(got.view(np.int32), single.vals.view(np.int32))
    print("factor values: BITWISE EQUAL to the sequential oracle ✓")

    # -- epoch-fused sweep: the solve-side communication schedule ----------
    tp = fact.precond().plan
    print(f"\nsweep epochs: {tp.l_sched.n_epochs + tp.u_sched.n_epochs} "
          f"(from {tp.nl_levels + tp.nu_levels} wavefront levels) -> "
          f"{tp.sweep_collectives_per_apply()} collectives/apply, "
          f"{tp.sweep_bytes_per_apply()} B/apply "
          f"(per-level unfused: {tp.sweep_bytes_per_apply_unfused()} B)")

    # -- distributed solve: precond + SpMV consume the sharded storage -----
    b = np.random.default_rng(0).standard_normal(a.n).astype(np.float32)
    res_d, _ = solve_sharded(a, b, k=1, band_rows=8, tol=1e-6, fact=fact)
    res_1, _ = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False)
    print(f"\ndistributed GMRES : {res_d.iterations:3d} iters, "
          f"residual {res_d.residual:.2e}, converged={res_d.converged}")
    print(f"single-device     : {res_1.iterations:3d} iters, " f"residual {res_1.residual:.2e}")
    assert res_d.converged
    assert np.array_equal(res_d.x.view(np.int32), res_1.x.view(np.int32))
    print("solution vector: BITWISE EQUAL to the single-device solve ✓")

    # -- multi-RHS: one epoch schedule, every collective shared ------------
    B = np.random.default_rng(1).standard_normal((3, a.n)).astype(np.float32)
    res_b, _ = solve_sharded(a, B, k=1, band_rows=8, tol=1e-6, fact=fact)
    print(f"\nbatched GMRES ({B.shape[0]} ragged RHS -> one bucketed "
          f"dispatch): iters {[r.iterations for r in res_b]}")
    for i, r in enumerate(res_b):
        r1, _ = solve_with_ilu(a, B[i], k=1, tol=1e-6, use_pallas=False)
        assert r.converged
        assert np.array_equal(r.x.view(np.int32), r1.x.view(np.int32))
    print("every batch column: BITWISE EQUAL to its single-device solve ✓")

    # -- serving warmup: pre-warmed shapes never pay the compile -----------
    import time

    from repro.core.solvers import warm_solve

    t0 = time.perf_counter()
    warm_solve(a, k=1, batch_sizes=(1,), band_rows=8, tol=1e-6)
    warm_s = time.perf_counter() - t0
    b2 = np.random.default_rng(2).standard_normal(a.n).astype(np.float32)
    t0 = time.perf_counter()
    res_w, _ = solve_sharded(a, b2, k=1, band_rows=8, tol=1e-6)
    first = time.perf_counter() - t0
    assert res_w.converged
    print(f"\nwarmup {warm_s:.1f}s (set REPRO_JIT_CACHE to persist it); "
          f"first fresh-RHS solve after warmup: {first * 1e3:.0f} ms")

    print(f"\nThe factors lived sharded across {d} devices for the whole "
          "factorize -> precondition -> solve pipeline; only O(n) vectors "
          "were ever replicated (DESIGN.md §5).")


if __name__ == "__main__":
    main()
