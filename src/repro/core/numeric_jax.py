"""Device-side numeric factorization (Phase II) — wavefront + superstep engines.

All functions here are pure JAX and shape-static; they implement exactly the
oracle's arithmetic (divide; barriered multiply-then-subtract; ascending
pivots per row) so the result is **bit-compatible** with
:func:`repro.core.numeric_ref.numeric_ilu_ref`.

Two executors over the same plan-layer contracts (DESIGN.md §3):

* :func:`factor_wavefront_sweeps_jnp` / :func:`make_wavefront_factorizer` —
  the single-device fast path. One ``lax.scan`` over the *pivot-op*
  wavefronts of a :class:`repro.core.factor_plan.FactorPlan`: each round
  applies one pivot to every row whose turn has come (all independent by
  construction), through the precomputed flat destination-lane map — no
  ``searchsorted``, no per-band sequential sweep, and padded work bounded
  by ``n_rounds * max_ops * W`` (exact op count, robust to skewed
  patterns) instead of the old ``n_bands * n_pad * max_piv`` dense partial
  reductions.
* :func:`make_superstep_factorizer` — the banded TOP-ILU executor (paper
  §IV), re-emitted over the *band superstep schedule*: bands whose
  dependencies are satisfied factor concurrently (vmapped per device over
  its members of the superstep), each band *pulling* its inter-band pivot
  rows from the replicated finalized values. One collective per superstep
  (an ``all_gather`` of the bands each device finished — ``broadcast=
  "psum"`` is kept as an alias — or an explicit ``ppermute`` directed ring,
  the paper's Fig-4 pipeline) replaces one broadcast per band. Pivot order
  within a row
  is ascending (earlier-band columns precede in-band columns), so the pull
  formulation is bitwise identical to the oracle by construction.

The same superstep body runs single-device (``axis_name=None``) or under
``shard_map`` with each device computing the bands it owns round-robin
(static load balancing, §IV-D).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .planner import NumericPlan

_PALLAS_DISABLED = os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1"


# --------------------------------------------------------------------------
# row-wavefront executor (single device)
# --------------------------------------------------------------------------
def factor_wavefront_sweeps_jnp(op_row, op_lane, op_piv, op_dlane, op_dst,
                                dst_flat, a_vals_ext):
    """Round-major pivot-op wavefront factorization (pure jnp reference).

    The Pallas kernel (`repro.kernels.panel_update.factor_wavefront`) runs
    this exact computation on values read from refs; both are bit-identical
    because they share this implementation.

    ``a_vals_ext``: (n+1, W) A-values on the pattern + zero scratch row;
    schedule arrays as in :class:`repro.core.factor_plan.FactorPlan`.
    Each round applies at most one pivot per row (rows distinct within a
    round by construction), so the per-round read-modify-write on the
    value array is conflict-free. Returns the factored (n, W) values.
    """
    NR, MO = op_row.shape
    n = a_vals_ext.shape[0] - 1
    idx = jnp.arange(MO)

    def round_step(vals, inp):
        rows, lanes, pivs, dlanes, ids = inp
        valid = rows < n  # padding ops target the scratch row
        x = vals[rows]  # (MO, W)
        pv = vals[pivs]  # (MO, W) — pivot rows, final since earlier rounds
        pdiag = jnp.where(valid, pv[idx, dlanes], jnp.float32(1))
        xp = x[idx, lanes]
        l = xp / pdiag
        # multiply-then-subtract, product rounded to f32 before the add
        # (no FMA contraction) — the oracle's exact arithmetic
        contrib = lax.optimization_barrier(l[:, None] * pv)
        dd = dst_flat[ids]  # (MO, W); pad op -> all lanes dropped
        x = jax.vmap(lambda xr, dr, cr: xr.at[dr].add(-cr, mode="drop"))(x, dd, contrib)
        x = x.at[idx, lanes].set(jnp.where(valid, l, xp))
        return vals.at[rows].set(x), None

    vals, _ = lax.scan(
        round_step, a_vals_ext, (op_row, op_lane, op_piv, op_dlane, op_dst)
    )
    return vals[:n]


def make_wavefront_factorizer(plan, use_pallas: bool = True):
    """Compiled ``(n+1, W) -> (n, W)`` factorizer over a FactorPlan.

    The schedule arrays live on device (cached on the plan); the returned
    callable is jitted once and reused for every refactorization of the
    same structure. ``use_pallas`` routes through the fused Pallas kernel
    (`repro.kernels.ops.factor_wavefront`); the jnp path is the
    bit-identical reference.
    """
    dev = plan.device_arrays()
    if use_pallas and not _PALLAS_DISABLED:
        from repro.kernels import ops  # deferred: keep core importable alone

        def _raw(vals):
            return ops.factor_wavefront(
                dev["op_row"], dev["op_lane"], dev["op_piv"],
                dev["op_dlane"], dev["op_dst"], dev["dst_flat"], vals,
            )
    else:
        def _raw(vals):
            return factor_wavefront_sweeps_jnp(
                dev["op_row"], dev["op_lane"], dev["op_piv"],
                dev["op_dlane"], dev["op_dst"], dev["dst_flat"], vals,
            )

    return jax.jit(lambda vals: _raw(jnp.asarray(vals, jnp.float32)))


# --------------------------------------------------------------------------
# band superstep executor (TOP-ILU, single- or multi-device)
# --------------------------------------------------------------------------
def make_superstep_factorizer(
    plan: NumericPlan,
    axis_name: Optional[str] = None,
    broadcast: str = "psum",
):
    """Build the jit-able band-superstep numeric factorization body.

    Arguments of the returned function (all replicated; device identity
    comes from ``lax.axis_index`` under ``shard_map``):

    vals       (n_pad+1, W) f32 — A values on the pattern + scratch row
    sched      (n_sup, D, MPD) i32 — superstep schedule, band ids, B-padded
    piv_rows   (n_pad, MP) i32 — pivot row per (row, pivot lane)
    piv_dlane  (n_pad, MP) i32 — pivot row's diagonal lane
    piv_dst    (n_pad, MP, W) i32 — destination lanes ([0, W]; W = drop)
    n_piv      (n_pad,) i32 — pivots per row (diag position)

    Returns the fully factored values (n_pad, W), replicated.
    """
    R = plan.band_rows
    B = plan.n_bands
    D = plan.n_devices if axis_name is not None else 1
    W = plan.width
    MP = plan.max_piv
    n_pad = plan.n_pad
    n_sup = plan.n_supersteps
    if broadcast == "psum":  # historical alias: the XLA-collective fast path
        broadcast = "gather"
    assert broadcast in ("gather", "ring")

    def factorize(vals, sched, piv_rows, piv_dlane, piv_dst, n_piv):
        me = lax.axis_index(axis_name) if axis_name is not None else jnp.int32(0)

        def superstep(s, vals):
            all_bands = lax.dynamic_slice_in_dim(sched, s, 1, axis=0)[0]  # (D, MPD)
            my_bands = lax.dynamic_index_in_dim(all_bands, me, axis=0, keepdims=False)

            def do_band(b):
                live = b < B
                base = (jnp.where(live, b, 0) * R).astype(jnp.int32)
                rows = base + jnp.arange(R, dtype=jnp.int32)
                buf = vals[rows]  # (R, W)

                def row_step(r, buf):
                    x = buf[r]
                    j = base + r

                    def piv_step(p, x):
                        i = piv_rows[j, p]
                        valid = p < n_piv[j]
                        i_s = jnp.minimum(i, n_pad - 1)
                        li = i_s - base
                        in_band = (li >= 0) & (li < R)
                        # pull: in-band pivots from the buffer being built,
                        # earlier bands from the replicated finalized values
                        pvals = jnp.where(in_band, buf[jnp.clip(li, 0, R - 1)], vals[i_s])
                        piv = jnp.where(valid, pvals[piv_dlane[j, p]], jnp.float32(1))
                        xp = x[jnp.minimum(p, W - 1)]
                        l = xp / piv
                        contrib = lax.optimization_barrier(l * pvals)
                        x = x.at[piv_dst[j, p]].add(-contrib, mode="drop")
                        return x.at[jnp.minimum(p, W - 1)].set(jnp.where(valid, l, xp))

                    x = lax.fori_loop(0, MP, piv_step, x)
                    return buf.at[r].set(x)

                buf = lax.fori_loop(0, R, row_step, buf)
                return jnp.where(live, buf, jnp.float32(0))

            # bands of a superstep are independent; a fori (not vmap — the
            # optimization_barrier has no batching rule) fills this device's
            # members, while other devices process theirs concurrently
            def band_loop(g, bufs):
                return bufs.at[g].set(do_band(my_bands[g]))

            bufs = lax.fori_loop(
                0, my_bands.shape[0], band_loop,
                jnp.zeros((my_bands.shape[0], R, W), jnp.float32),
            )  # (MPD, R, W)

            if axis_name is not None:
                if broadcast == "gather":
                    # XLA's ring all-gather: each device contributes exactly
                    # its finished bands — no zero-padded (D, ...) temporary
                    all_bufs = lax.all_gather(bufs, axis_name)
                else:  # explicit directed ring all-reduce — the paper's Fig-4 pipeline
                    mine = jnp.zeros((D,) + bufs.shape, jnp.float32).at[me].set(bufs)
                    perm = [(d, (d + 1) % D) for d in range(D)]
                    acc, cur = mine, mine
                    for _ in range(D - 1):
                        cur = lax.ppermute(cur, axis_name, perm)
                        acc = acc + cur
                    all_bufs = acc
            else:
                all_bufs = bufs[None]

            all_rows = jnp.where(
                (all_bands < B)[:, :, None],
                all_bands[:, :, None] * R + jnp.arange(R, dtype=jnp.int32),
                jnp.int32(n_pad),  # padding bands scatter into the scratch row
            )  # (D, MPD, R)
            return vals.at[all_rows.reshape(-1)].set(all_bufs.reshape(-1, W))

        vals = lax.fori_loop(0, n_sup, superstep, vals)
        return vals[:n_pad]

    return factorize


def plan_device_arrays(plan: NumericPlan):
    """Host-side: the replicated inputs of the superstep factorizer."""
    import numpy as np

    vals = np.zeros((plan.n_pad + 1, plan.width), dtype=np.float32)
    vals[: plan.n_pad] = plan.a_vals
    return dict(
        vals=vals,
        sched=plan.superstep_bands,
        piv_rows=plan.piv_rows,
        piv_dlane=plan.piv_dlane,
        piv_dst=plan.piv_dst,
        n_piv=plan.diag_pos.astype(np.int32),
    )
