"""Bounded-LRU multi-tenant plan/factorization cache with pinning.

One :class:`CacheEntry` per registered ``matrix_id``: the canonical matrix
object (``a0`` — the host the structure-keyed ``FactorPlan`` and solver
memos live on), the filled pattern, a (possibly shared) :class:`ServeEngine`,
and the *current* :class:`EngineBinding` (value version). Three protocols:

**LRU + pinning.** Capacity bounds device memory. Every in-flight request
holds a pin on its entry; eviction only reclaims unpinned entries
(least-recently-used first). If the cache is full of pinned entries the
insert fails with ``QUEUE_FULL`` semantics rather than evicting a solve's
data out from under it. An evicted matrix can be re-registered — with the
engine shared by structure, re-admission recompiles nothing if a
structure-mate is still resident.

**Engine sharing.** Engines are keyed by :func:`engine_fingerprint`
(structure + knobs, never values) in a ``WeakValueDictionary``: tenants
with identical sparsity share one compiled engine per bucket; the engine
dies with its last entry.

**Background refactorization.** ``update_values`` refactorizes the new
values through the entry's already-compiled ``FactorPlan`` engine and
binds them to the engine — in a worker thread, so a tenant's value push
never blocks other tenants' solves. The swap is atomic (one reference
assignment under the cache lock); requests admitted before the swap keep
their pinned old binding (``SolveRequest.binding``) and solve against the
values they were admitted under — a racing update can never retarget an
in-flight solve mid-batch.
"""
from __future__ import annotations

import collections
import threading
import weakref
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.sparse import CSRMatrix

from .admission import BREAKDOWN, QUEUE_FULL, UNKNOWN_MATRIX, AdmissionError
from .engine import ServeEngine


def identity_values(pattern) -> np.ndarray:
    """Pattern-aligned factor values of the identity (diag 1, rest 0).

    Swept through the already-compiled triangular executable these apply
    M^{-1} = I exactly — every L lane contributes ``barred(0·y) = 0`` and
    every U diagonal divides by 1.0 — so the serve layer's last-resort
    degradation costs a bind, never a new executable."""
    vals = np.zeros(pattern.nnz, np.float32)
    vals[np.asarray(pattern.indptr[:-1]) + np.asarray(pattern.diag_ptr)] = 1.0
    return vals


class CacheEntry:
    """One resident matrix: canonical host objects + current binding."""

    def __init__(self, matrix_id: str, a0: CSRMatrix, pattern, engine, binding,
                 plan_host: Optional[CSRMatrix] = None):
        self.matrix_id = matrix_id
        self.a0 = a0              # this entry's own matrix (structure + values)
        self.pattern = pattern
        self.engine = engine
        self.binding = binding    # current EngineBinding (atomic-swap target)
        # canonical same-structure matrix the compiled FactorPlan memoizes on
        # (the first registrant of this structure — possibly a0 itself)
        self.plan_host = plan_host if plan_host is not None else a0
        self.pins = 0
        self.version = binding.version
        # lazily built shifted-preconditioner bindings for breakdown
        # retries, keyed by ("shift", base binding version) — one ladder
        # climb per value version, shared by every retrying request
        self.degraded_bindings: dict = {}


class PlanCache:
    """The bounded-LRU store. All public methods are thread-safe; solves,
    submits, and background refactor threads may interleave freely."""

    def __init__(self, capacity: int = 8, metrics=None,
                 engine_factory: Optional[Callable] = None,
                 on_breakdown: str = "shift", pivot_tol: Optional[float] = None):
        if capacity < 1:
            raise ValueError(f"PlanCache capacity must be >= 1, got {capacity}")
        if on_breakdown not in ("raise", "shift", "fallback", "ignore"):
            raise ValueError(f"PlanCache: unknown on_breakdown {on_breakdown!r}")
        self.capacity = capacity
        self.metrics = metrics
        # pivot-guard policy for every factorization this cache performs
        # (serve default "shift": a tenant's broken matrix registers with a
        # shifted preconditioner instead of poisoning its future batches)
        self.on_breakdown = on_breakdown
        self.pivot_tol = pivot_tol
        self._engine_factory = engine_factory or self._default_engine_factory
        self._lock = threading.RLock()
        self._entries: "collections.OrderedDict[str, CacheEntry]" = collections.OrderedDict()
        # structure-keyed engine sharing; weak so engines die with their entries
        self._engines_by_structure = weakref.WeakValueDictionary()
        # structure-keyed canonical factor-plan hosts: FactorPlan memoizes on
        # a matrix object, so same-structure registrations route through the
        # first registrant's matrix and its already-compiled factor engine
        self._factor_hosts = weakref.WeakValueDictionary()
        self._refactor_threads: Dict[str, threading.Thread] = {}

    # -- construction helpers ----------------------------------------------
    @staticmethod
    def _default_engine_factory(a, pattern, vals_csr, **knobs):
        return ServeEngine(a, pattern, vals_csr, **knobs)

    def _factorize(self, entry_a0: CSRMatrix, pattern, a: CSRMatrix) -> np.ndarray:
        """CSR-aligned ILU values of ``a`` via the structure-keyed compiled
        factor engine memoized on the *canonical* matrix: the first call per
        structure compiles, every refactorization after is a pure execute."""
        from repro.core.factor_plan import factor_plan_for

        plan = factor_plan_for(entry_a0, pattern)
        return np.asarray(plan.factorize(a))

    # -- registration -------------------------------------------------------
    def register(self, matrix_id: str, a: CSRMatrix, k: int = 1, **engine_knobs) -> CacheEntry:
        """Insert (or replace) a matrix: symbolic fill, numeric factorize,
        engine lookup/build, value bind. May evict an unpinned LRU entry.
        Same-structure registrations share one compiled factor engine (via
        the structure-keyed plan host) and one solver engine — the second
        tenant of a structure onboards without a single XLA compile."""
        import hashlib

        from repro.core.api import _symbolic

        pattern = _symbolic(a, k, "sum")
        h = hashlib.sha1()
        h.update(a.indptr.tobytes())
        h.update(a.indices.tobytes())
        h.update(pattern.levels.tobytes())
        skey = (a.n, k, h.hexdigest())
        with self._lock:
            host = self._factor_hosts.get(skey)
            if host is None:
                host = self._factor_hosts[skey] = a
        vals_csr = self._factorize(host, pattern, a)
        with self._lock:
            self._evict_for_insert(exclude=matrix_id)
            engine = self._shared_engine(a, pattern, vals_csr, engine_knobs)
            binding = self._guarded_bind(engine, host, pattern, a, vals_csr)
            entry = CacheEntry(matrix_id, a, pattern, engine, binding, plan_host=host)
            self._entries[matrix_id] = entry
            self._entries.move_to_end(matrix_id)
            return entry

    def _guarded_bind(self, engine, host, pattern, a, vals_csr):
        """Audit the fresh factor values and bind per ``on_breakdown``:
        healthy values bind as-is (the audit is a pure read — the binding
        is bitwise what an unguarded bind produces); broken ones climb the
        shift ladder through the same compiled engines, and exhaustion
        either binds the exact identity preconditioner (``"fallback"``,
        single-device) or rejects the matrix with a structured BREAKDOWN."""
        from repro.core.guard import audit_values, ladder_alphas

        if self.on_breakdown == "ignore":
            return engine.bind(a, vals_csr)
        health = audit_values(pattern, vals_csr, self.pivot_tol)
        if health.ok:
            return engine.bind(a, vals_csr)
        if self.metrics is not None:
            self.metrics.record_robustness("broken_factorizations")
        if self.on_breakdown == "raise":
            raise AdmissionError(BREAKDOWN, health.summary())
        def factorize(m):
            return self._factorize(host, pattern, m)
        for alpha in ladder_alphas():
            b2 = engine.bind_degraded(a, alpha, factorize)
            if b2 is not None:
                if self.metrics is not None:
                    self.metrics.record_robustness("shifted_bindings")
                return b2
        if self.on_breakdown == "fallback" and getattr(
                engine, "supports_identity_fallback", False):
            b2 = engine.bind(a, identity_values(pattern))
            b2.degraded = True
            if self.metrics is not None:
                self.metrics.record_robustness("identity_fallbacks")
            return b2
        raise AdmissionError(
            BREAKDOWN, f"shift ladder exhausted: {health.summary()}")

    def _shared_engine(self, a, pattern, vals_csr, knobs):
        probe = self._engine_factory(a, pattern, vals_csr, **knobs)
        fp = getattr(probe, "fingerprint", None)
        if fp is None:
            return probe
        existing = self._engines_by_structure.get(fp)
        if existing is not None:
            if self.metrics is not None:
                self.metrics.record_cache("engine_shared")
            return existing
        self._engines_by_structure[fp] = probe
        return probe

    def _evict_for_insert(self, exclude: str) -> None:
        while len(self._entries) >= self.capacity + (1 if exclude in self._entries else 0):
            victim = None
            for mid, e in self._entries.items():  # OrderedDict: LRU first
                if mid != exclude and e.pins == 0:
                    victim = mid
                    break
            if victim is None:
                raise AdmissionError(
                    QUEUE_FULL,
                    f"plan cache full ({self.capacity} entries, all pinned by "
                    "in-flight solves); retry after current batches drain")
            del self._entries[victim]
            if self.metrics is not None:
                self.metrics.record_cache("evict")

    # -- lookup + pinning ----------------------------------------------------
    def dim_of(self, matrix_id: str) -> Optional[int]:
        with self._lock:
            e = self._entries.get(matrix_id)
            return None if e is None else e.a0.n

    def acquire(self, matrix_id: str):
        """Pin the entry's *current* binding for one request; returns
        ``(entry, binding)``. The pin blocks eviction; the binding reference
        keeps the value arrays alive even across a racing update (the solve
        runs on the version the request was admitted under)."""
        with self._lock:
            e = self._entries.get(matrix_id)
            if e is None:
                if self.metrics is not None:
                    self.metrics.record_cache("miss")
                raise AdmissionError(
                    UNKNOWN_MATRIX, f"matrix_id {matrix_id!r} is not resident")
            e.pins += 1
            self._entries.move_to_end(matrix_id)
            if self.metrics is not None:
                self.metrics.record_cache("hit")
            return e, e.binding

    def release(self, matrix_id: str) -> None:
        with self._lock:
            e = self._entries.get(matrix_id)
            if e is not None and e.pins > 0:
                e.pins -= 1

    # -- value updates -------------------------------------------------------
    def update_values(self, matrix_id: str, data: np.ndarray,
                      background: bool = True) -> threading.Thread:
        """Refactorize ``matrix_id`` with new values (same structure) and
        atomically swap the entry's binding. Runs in a worker thread by
        default — registration lookups and other tenants' solves proceed
        during the numeric factorization; only the final reference swap
        takes the lock. Returns the worker (already joined if
        ``background=False``)."""
        with self._lock:
            e = self._entries.get(matrix_id)
            if e is None:
                raise AdmissionError(
                    UNKNOWN_MATRIX, f"matrix_id {matrix_id!r} is not resident")
            a0, pattern, engine, host = e.a0, e.pattern, e.engine, e.plan_host
            data = np.asarray(data, np.float32)
            if data.shape != a0.data.shape:
                raise ValueError(
                    f"update_values: expected {a0.data.shape[0]} values for the "
                    f"structure of {matrix_id!r}, got {data.shape}")

        def work():
            a_new = CSRMatrix(n=a0.n, indptr=a0.indptr, indices=a0.indices, data=data)
            vals_csr = self._factorize(host, pattern, a_new)
            try:
                binding = self._guarded_bind(engine, host, pattern, a_new, vals_csr)
            except AdmissionError:
                # a value push that breaks down unrecoverably keeps the old
                # binding serving — existing requests stay healthy; the
                # counter records the rejected update
                if self.metrics is not None:
                    self.metrics.record_robustness("rejected_updates")
                return
            with self._lock:
                cur = self._entries.get(matrix_id)
                if cur is not None and cur.engine is engine:
                    cur.binding = binding      # the atomic swap
                    cur.version = binding.version
            if self.metrics is not None:
                self.metrics.record_cache("refactor")

        t = threading.Thread(target=work, name=f"refactor-{matrix_id}", daemon=True)
        with self._lock:
            self._refactor_threads[matrix_id] = t
        t.start()
        if not background:
            t.join()
        return t

    def degraded_binding(self, matrix_id: str, binding) -> Optional["object"]:
        """A shifted-preconditioner binding for retrying breakdown lanes.

        Climbs the α ladder against the *exact matrix of the base binding*
        (``binding.a`` — not the entry's possibly newer values: the retry
        must solve the system the request was admitted under), audits each
        rung, and caches the first healthy binding per base version so one
        ladder climb serves every retrying request of that version. The
        retried solve's matvec still targets the original A — only the
        preconditioner is shifted. Returns None when the ladder exhausts
        (the caller fails the lane with a structured BREAKDOWN)."""
        from repro.core.guard import ladder_alphas

        with self._lock:
            e = self._entries.get(matrix_id)
            if e is None or binding.a is None:
                return None
            key = ("shift", binding.version)
            cached = e.degraded_bindings.get(key)
            if cached is not None:
                return cached
            engine, pattern, host = e.engine, e.pattern, e.plan_host
        def factorize(m):
            return self._factorize(host, pattern, m)
        for alpha in ladder_alphas():
            try:
                b2 = engine.bind_degraded(binding.a, alpha, factorize)
            except Exception:
                return None
            if b2 is not None:
                with self._lock:
                    cur = self._entries.get(matrix_id)
                    if cur is not None:
                        cur.degraded_bindings[key] = b2
                return b2
        return None

    def wait_refactors(self, timeout: Optional[float] = None) -> None:
        """Join all outstanding refactor workers (tests / drain)."""
        with self._lock:
            threads = list(self._refactor_threads.values())
        for t in threads:
            t.join(timeout)

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, matrix_id: str) -> bool:
        with self._lock:
            return matrix_id in self._entries

    def entry(self, matrix_id: str) -> Optional[CacheEntry]:
        with self._lock:
            return self._entries.get(matrix_id)

    def resident_ids(self):
        with self._lock:
            return list(self._entries)
