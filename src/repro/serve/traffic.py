"""Deterministic synthetic traffic for soak tests and the serve benchmark.

Everything derives from one ``numpy`` Generator seed: tenant arrival
order, burst sizes, RHS vectors, tolerance choices, and the optional
malformed-request / value-update injections. Replaying the same seed
against the same service configuration produces byte-identical submits —
which is what lets the soak test assert byte-identical responses and a
deterministic metrics shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .admission import SolveRequest, SolveResponse
from .service import SolveService


@dataclasses.dataclass
class TrafficRecord:
    """One submitted request + everything needed to recompute its solo
    reference solve (the bitwise check the soak runs afterwards)."""

    request_id: int
    tenant: str
    matrix_id: str
    b: np.ndarray
    tol: float
    expected_version: int       # binding version pinned at admission
    kind: str = "solve"         # "solve" | "malformed" | "update"


@dataclasses.dataclass
class TrafficResult:
    records: List[TrafficRecord]
    responses: List[SolveResponse]
    rejected: List[SolveResponse]
    updates: Dict[str, List[np.ndarray]]   # value pushes per matrix (in order)


def run_traffic(service: SolveService, matrix_ids: Sequence[str],
                n_requests: int, seed: int = 0,
                tenants: Sequence[str] = ("t0", "t1", "t2", "t3"),
                tol_choices: Sequence[float] = (1e-4, 1e-5, 1e-6),
                burst_max: int = 8,
                malformed_prob: float = 0.0,
                update_prob: float = 0.0,
                update_values: Optional[Dict[str, List[np.ndarray]]] = None,
                tick_every_burst: bool = True) -> TrafficResult:
    """Drive ``n_requests`` seeded solve submissions through the service.

    Per burst: a tenant, a matrix, a burst size, and per-request (b, tol)
    draws; the burst submits back-to-back (that's what the coalescer sees
    as one tick's worth of compatible lanes). ``malformed_prob`` injects a
    bad request per burst (wrong shape / non-finite b / bad tol — rotated
    deterministically); ``update_prob`` pushes the next queued value array
    from ``update_values`` for the burst's matrix. Runs until every
    admitted request has a response; returns the full audit trail.
    """
    rng = np.random.default_rng(seed)
    dims = {mid: service.cache.entry(mid).a0.n for mid in matrix_ids}
    records: List[TrafficRecord] = []
    responses: List[SolveResponse] = []
    rejected: List[SolveResponse] = []
    updates: Dict[str, List[np.ndarray]] = {mid: [] for mid in matrix_ids}
    update_queues = {mid: list(vs) for mid, vs in (update_values or {}).items()}
    malformed_kind = 0
    submitted = 0

    while submitted < n_requests:
        mid = matrix_ids[int(rng.integers(len(matrix_ids)))]
        n = dims[mid]
        burst = int(rng.integers(1, burst_max + 1))
        burst = min(burst, n_requests - submitted)

        if update_prob > 0 and update_queues.get(mid) and rng.random() < update_prob:
            data = update_queues[mid].pop(0)
            updates[mid].append(data)
            service.update_matrix_values(mid, data, background=True)

        if malformed_prob > 0 and rng.random() < malformed_prob:
            bad = malformed_kind % 3
            malformed_kind += 1
            tenant = tenants[int(rng.integers(len(tenants)))]
            if bad == 0:
                resp = service.submit(tenant, mid, np.ones(n + 3, np.float32))
            elif bad == 1:
                b = np.ones(n, np.float32)
                b[0] = np.nan
                resp = service.submit(tenant, mid, b)
            else:
                resp = service.submit(tenant, mid, np.ones(n, np.float32), tol=-1.0)
            rejected.append(resp)

        for _ in range(burst):
            tenant = tenants[int(rng.integers(len(tenants)))]
            b = rng.standard_normal(n).astype(np.float32)
            tol = float(tol_choices[int(rng.integers(len(tol_choices)))])
            out = service.submit(tenant, mid, b, tol=tol)
            if isinstance(out, SolveRequest):
                records.append(TrafficRecord(
                    request_id=out.request_id, tenant=tenant, matrix_id=mid,
                    b=b, tol=tol, expected_version=out.binding[1].version))
                submitted += 1
            else:
                rejected.append(out)

        if tick_every_burst:
            responses.extend(service.tick())

    responses.extend(service.drain())
    return TrafficResult(records=records, responses=responses,
                         rejected=rejected, updates=updates)
