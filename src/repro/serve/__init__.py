"""Production solve service over the warm bucketed ILU(k) solver stack.

Multi-tenant request coalescing with a bit-compat guarantee: a request
batched into any coalesced solve returns bits identical to solving it
alone. See DESIGN.md §11 for the architecture walk-through.
"""
from .admission import (
    BREAKDOWN,
    DEADLINE_EXCEEDED,
    AdmissionError,
    AdmissionQueue,
    SolveRequest,
    SolveResponse,
    validate_deadline,
    validate_request,
)
from .cache import CacheEntry, PlanCache, identity_values
from .coalescer import CoalescedBatch, coalesce
from .dispatcher import Dispatcher
from .engine import EngineBinding, LaneResult, ServeEngine, ShardedServeEngine
from .metrics import CompileWatch, LatencyHistogram, ServiceMetrics, compile_count
from .service import ServeConfig, SolveService
from .traffic import TrafficRecord, TrafficResult, run_traffic

__all__ = [
    "AdmissionError",
    "AdmissionQueue",
    "BREAKDOWN",
    "CacheEntry",
    "CoalescedBatch",
    "CompileWatch",
    "DEADLINE_EXCEEDED",
    "Dispatcher",
    "EngineBinding",
    "LaneResult",
    "LatencyHistogram",
    "PlanCache",
    "ServeConfig",
    "ServeEngine",
    "ServiceMetrics",
    "ShardedServeEngine",
    "SolveRequest",
    "SolveResponse",
    "SolveService",
    "TrafficRecord",
    "TrafficResult",
    "coalesce",
    "compile_count",
    "identity_values",
    "run_traffic",
    "validate_deadline",
    "validate_request",
]
