"""Per-device memory of the sharded TOP-ILU pipeline (DESIGN.md §5).

Host-side: the halo-exchange schedule invariants (every halo slot filled
exactly once, before first use, addresses in range) and the memory model.
Subprocess-side (device count locks at first JAX init): the value state a
device materializes is ``O(n_pad*W/D + halo)`` on 2 and 4 virtual devices,
and the per-superstep collective payload in the compiled HLO equals the
host-precomputed halo size exactly.
"""
import os
import sys

import numpy as np
import pytest

from subproc import run_checked

from repro.core import matgen, pilu1_symbolic, poisson_2d, symbolic_ilu_k
from repro.core.planner import make_plan

SCRIPT = os.path.join(os.path.dirname(__file__), "sharded_memory_check.py")


def _plan(n=128, k=1, band_rows=8, d=2, seed=11):
    a = matgen(n, density=min(0.08, 12.0 / n), seed=seed)
    pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)
    return make_plan(a, pat, band_rows=band_rows, n_devices=d)


# --------------------------------------------------------------------------
# host-side: halo schedule invariants (no devices needed)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("d", [1, 2, 4])
@pytest.mark.parametrize("k", [1, 2])
def test_halo_schedule_invariants(d, k):
    plan = _plan(k=k, d=d)
    scratch = plan.s_loc + plan.halo_size
    assert plan.s_loc == plan.n_pad // d
    # valid pivots resolve strictly inside [0, scratch); invalid at scratch
    mp = plan.max_piv
    valid = np.arange(mp)[None, :] < plan.diag_pos[:, None]
    assert (plan.piv_addr[valid] < scratch).all()
    assert (plan.piv_addr[~valid] == scratch).all()
    # every halo slot of every device is written exactly once overall
    for dev in range(d):
        written = np.sort(plan.ingress_idx[:, dev][plan.ingress_idx[:, dev] < scratch])
        n_halo = int((plan.halo_rows[dev] < plan.n_pad).sum())
        assert np.array_equal(written, plan.s_loc + np.arange(n_halo))
    # egress addresses point into local storage (or scratch padding)
    assert ((plan.egress_idx < plan.s_loc) | (plan.egress_idx == scratch)).all()


@pytest.mark.parametrize("k", [1, 2])
def test_halo_filled_before_first_use(k):
    """A foreign pivot row must be exchanged in a strictly earlier superstep
    than any superstep that factors a band consuming it."""
    plan = _plan(k=k, d=4)
    d = plan.n_devices
    scratch = plan.s_loc + plan.halo_size
    # superstep each band factors in
    sup_of_band = np.zeros(plan.n_bands, np.int64)
    flat = plan.superstep_bands.reshape(plan.n_supersteps, -1)
    s_of, _ = np.nonzero(flat < plan.n_bands)
    sup_of_band[flat[flat < plan.n_bands]] = s_of
    # superstep each halo slot is written in (per device)
    for dev in range(d):
        write_step = np.full(plan.halo_size, -1, np.int64)
        for s in range(plan.n_supersteps):
            idx = plan.ingress_idx[s, dev]
            slots = idx[idx < scratch] - plan.s_loc
            write_step[slots] = s
        # rows of device `dev` read halo slot `piv_addr - s_loc`
        mp = plan.max_piv
        valid = np.arange(mp)[None, :] < plan.diag_pos[:, None]
        mine = (np.arange(plan.n_pad) // plan.band_rows) % d == dev
        jj, pp = np.nonzero(valid & mine[:, None])
        addr = plan.piv_addr[jj, pp]
        halo_reads = addr >= plan.s_loc
        read_step = sup_of_band[jj[halo_reads] // plan.band_rows]
        slot = addr[halo_reads] - plan.s_loc
        assert (write_step[slot] >= 0).all()
        assert (write_step[slot] < read_step).all()


@pytest.mark.parametrize("d", [1, 2, 4])
@pytest.mark.parametrize("k", [1, 2])
def test_sweep_epoch_schedule_invariants(d, k):
    """Host-side invariants of the epoch/read-set sweep schedule
    (DESIGN.md §5.5): epochs tile the levels, every cross-device read
    resolves in a strictly earlier epoch, every halo slot is written
    exactly once, and the exact read-set payload never exceeds the PR-3
    per-level padded model."""
    from repro.core import matgen, pilu1_symbolic, symbolic_ilu_k
    from repro.core.triangular import build_sharded_triangular_plan

    a = matgen(128, density=0.08, seed=11)
    pat = pilu1_symbolic(a) if k == 1 else symbolic_ilu_k(a, k)
    plan = build_sharded_triangular_plan(pat, 8, d)
    for sched, cols, nlev, maxr in (
        (plan.l_sched, plan.l_cols, plan.nl_levels, plan.maxr_l),
        (plan.u_sched, plan.u_cols, plan.nu_levels, plan.maxr_u),
    ):
        assert sched.epoch_bounds[0] == 0 and sched.epoch_bounds[-1] == nlev
        assert (np.diff(sched.epoch_bounds) > 0).all()
        # cross-device reads come from strictly earlier epochs
        cols64 = cols.astype(np.int64)
        valid = cols64 < sched.n_slots
        own = (cols64 // maxr) % d
        lev = cols64 // (d * maxr)
        rd = np.arange(d)[:, None, None, None]
        cross = valid & (own != rd)
        eol = np.zeros(nlev, np.int64)
        for e in range(sched.n_epochs):
            eol[sched.epoch_bounds[e]:sched.epoch_bounds[e + 1]] = e
        di, li, ri, wi = np.nonzero(cross)
        if li.size:
            assert (eol[lev[di, li, ri, wi]] < eol[li]).all()
        # every halo slot of every device is written exactly once overall
        for dev in range(d):
            written = []
            for ing in sched.ingress:
                if ing is not None:
                    w = ing[dev][ing[dev] < sched.scratch] - sched.n_loc
                    written.extend(w.tolist())
            n_halo = int((sched.halo_slots[dev] < sched.n_slots).sum())
            assert sorted(written) == list(range(n_halo))
        # egress addresses point into local slots (or scratch padding)
        for eg in sched.egress:
            if eg is not None:
                assert ((eg < sched.n_loc) | (eg == sched.scratch)).all()
    if d > 1:
        assert plan.sweep_collectives_per_apply() < plan.nl_levels + plan.nu_levels
        assert plan.sweep_bytes_per_apply() <= plan.sweep_bytes_per_apply_unfused()
    else:
        assert plan.sweep_collectives_per_apply() == 0
        assert plan.sweep_bytes_per_apply() == 0


def test_memory_model_monotone_in_devices():
    """Per-device value bytes shrink as the mesh grows (the §IV point).

    Uses the banded Poisson matrix — the paper's PDE setting — where a
    row's pivot reach is O(bandwidth), so the halo a device buffers decays
    with D instead of swallowing the whole foreign row set (which is what
    happens, correctly, on dense random patterns)."""
    a = poisson_2d(24)
    pat = pilu1_symbolic(a)
    sizes = {}
    for d in (1, 2, 4, 8):
        plan = make_plan(a, pat, band_rows=8, n_devices=d)
        sizes[d] = plan.per_device_value_bytes()
        assert plan.s_loc * d == plan.n_pad  # local block is exactly 1/D
        assert plan.per_device_value_bytes() <= plan.replicated_value_bytes()
    assert sizes[8] < sizes[4] < sizes[2] < sizes[1]
    # at D=8 the halo is small against the foreign row count: the state is
    # a fraction of the replicated buffer, not a constant offset from it
    assert sizes[8] < sizes[1] // 3


# --------------------------------------------------------------------------
# subprocess: real device shards + compiled-HLO collective payloads
# --------------------------------------------------------------------------
@pytest.mark.parametrize("devices", [2, 4])
def test_sharded_state_and_payload(devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"  # don't probe for real TPUs (see test_topilu_multidevice)
    rc, out, err = run_checked(
        [sys.executable, SCRIPT, "16", "8"], env=env, timeout=300,
    )
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "sharded-memory" in out
