"""Batched greedy decoding with a KV cache — the serve_step in action.

    PYTHONPATH=src python examples/serve_decode.py [--arch smollm-135m] [--tokens 16]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.train.step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} (reduced for CPU), batch={args.batch}")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, args.batch, cache_len=args.tokens + 8)
    serve = jax.jit(make_serve_step(cfg))

    rng = np.random.default_rng(0)
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02,
            cfg.act_dtype,
        )
    tok = jnp.asarray(rng.integers(0, cfg.vocab_real, (args.batch, 1)), jnp.int32)
    seqs = [np.asarray(tok)[:, 0]]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        tok, logits, cache = serve(params, cache, tok, frames)
        seqs.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    out = np.stack(seqs, axis=1)
    print(f"decoded {args.tokens} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s, CPU interpret)")
    for b in range(args.batch):
        print(f"  seq[{b}]: {out[b].tolist()}")


if __name__ == "__main__":
    main()
