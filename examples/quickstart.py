"""Quickstart: factor a diagonally-dominant sparse matrix with ILU(k) and
solve Ax=b with preconditioned GMRES — the paper's end-to-end use case.

    PYTHONPATH=src python examples/quickstart.py [n] [k]
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import matgen
from repro.core.api import ilu
from repro.core.solvers import solve_with_ilu


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1000
    k = int(sys.argv[2]) if len(sys.argv) > 2 else 1

    print(f"matgen: n={n}, density={min(0.08, 20.0/n):.4f}")
    a = matgen(n, density=min(0.08, 20.0 / n), seed=0)

    print(f"\n-- ILU({k}) factorization (symbolic=PILU(1) fast path for k=1) --")
    fact = ilu(a, k, backend="jax")
    print(f"entries: {a.nnz} -> {fact.nnz} " f"(fill ratio {fact.nnz / a.nnz:.2f})")
    print(f"symbolic {fact.symbolic_seconds*1e3:.1f} ms, "
          f"numeric {fact.numeric_seconds*1e3:.1f} ms")

    b = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    print("\n-- BiCGSTAB --")
    plain, _ = solve_with_ilu(a, b, k=None, method="bicgstab", maxiter=400)
    pre, _ = solve_with_ilu(a, b, k=k, method="bicgstab", maxiter=400)
    print(f"no preconditioner : {plain.iterations:4d} iters, residual {plain.residual:.2e}")
    print(f"ILU({k})            : {pre.iterations:4d} iters, residual {pre.residual:.2e}")
    assert pre.converged
    print("\nbit-compat check vs sequential oracle ...", end=" ")
    ref = ilu(a, k, backend="oracle")
    assert np.array_equal(fact.vals.view(np.int32), ref.vals.view(np.int32))
    print("BITWISE EQUAL ✓")


if __name__ == "__main__":
    main()
