"""Multi-device TOP-ILU: bitwise equality vs the sequential oracle.

Each case runs in a subprocess because JAX locks the host device count at
first init (the main pytest process must keep seeing 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "multidevice_check.py")


def _run(n, k, band_rows, broadcast, devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run(
        [sys.executable, SCRIPT, str(n), str(k), str(band_rows), broadcast],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-2000:]}"
    assert "bitwise-equal" in res.stdout


@pytest.mark.parametrize("broadcast", ["psum", "ring"])
def test_topilu_8dev_k1(broadcast):
    _run(n=96, k=1, band_rows=8, broadcast=broadcast, devices=8)


def test_topilu_8dev_k2():
    _run(n=96, k=2, band_rows=8, broadcast="psum", devices=8)


def test_topilu_nondivisible_devices():
    """Band count not a multiple of D exercises padding/ownership logic."""
    _run(n=100, k=1, band_rows=4, broadcast="psum", devices=5)


def test_topilu_band_eq_one():
    """R=1: every row is a band — the maximal-parallelism degenerate case."""
    _run(n=64, k=1, band_rows=1, broadcast="psum", devices=4)
