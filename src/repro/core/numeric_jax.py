"""Device-side numeric factorization (Phase II) — band/frontier engine.

All functions here are pure JAX and shape-static; they implement exactly the
oracle's arithmetic (divide; multiply-then-subtract; ascending pivots) so the
result is **bit-compatible** with :func:`repro.core.numeric_ref.numeric_ilu_ref`.

Layout: rows live in band-major tensors ``vals (rows, W)``; a *pivot-band
buffer* ``(R, W)`` carries the currently-finishing band (this is the object
the paper pipelines around the ring, Fig 4). Gathers into pivot rows use
``searchsorted`` on the static column structure instead of precomputed
scatter maps — O(W log W) integer work per pivot in exchange for an O(nnz)
(not O(nnz*W)) plan footprint.

The same body runs single-device (``axis_name=None``) or under
``shard_map`` with each device holding its round-robin shard of bands
(device-major layout from the planner). The finished band is broadcast with
either a masked ``psum`` (XLA's ring all-reduce — the hardware realization
of the paper's aggregate-bandwidth pipeline) or an explicit ``ppermute``
directed ring (paper-faithful message path; ``broadcast='ring'``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .planner import COL_SENTINEL, NumericPlan


def _apply_one_pivot(x, jcols, pos, valid, band_start, buf_vals, cols_all, dpos_all):
    """Apply the pivot at ELL position ``pos`` of row ``x``; the pivot row is
    read from the band buffer. Bitwise-identical to the oracle's update."""
    W = x.shape[0]
    pos_c = jnp.minimum(pos, W - 1)
    i = jcols[pos_c].astype(jnp.int32)  # global pivot column == pivot row id
    i_safe = jnp.where(valid & (i < COL_SENTINEL), i, band_start)
    li = i_safe - band_start  # local row inside the buffer
    piv = buf_vals[li, dpos_all[i_safe]]
    l = x[pos_c] / piv
    icols = cols_all[i_safe]  # (W,) static structure of the pivot row
    ivals = buf_vals[li]  # (W,) current values of the pivot row
    tail = (icols > i_safe) & (icols < COL_SENTINEL) & valid
    dst = jnp.searchsorted(jcols, icols).astype(jnp.int32)
    dst_c = jnp.minimum(dst, W - 1)
    hit = tail & (jcols[dst_c] == icols)
    contrib = jnp.where(hit, l * ivals, jnp.float32(0))
    # multiply-then-subtract; masked lanes scatter out of bounds and drop
    x = x.at[jnp.where(hit, dst, W)].add(-contrib, mode="drop")
    x = x.at[pos_c].set(jnp.where(valid, l, x[pos_c]))
    return x


def _reduce_row_against_band(x, jcols, start, count, max_pivots, band_start, buf_vals, cols_all, dpos_all):
    """Partially reduce one row against the (finished) band in ``buf_vals``."""

    def body(s, x):
        return _apply_one_pivot(
            x, jcols, start + s, s < count, band_start, buf_vals, cols_all, dpos_all
        )

    return lax.fori_loop(0, max_pivots, body, x)


def finish_band(buf_vals, buf_cols, band_start, intra_start, intra_count, max_intra, cols_all, dpos_all):
    """Completely reduce a band, rows top-down (the frontier step, Def 4.1).

    ``buf_vals`` must already be partially reduced against all earlier
    bands; rows use *earlier rows of the same buffer* as pivot rows.
    """
    R = buf_vals.shape[0]

    def row_body(r, buf):
        x = _reduce_row_against_band(
            buf[r], buf_cols[r], intra_start[r], intra_count[r],
            max_intra, band_start, buf, cols_all, dpos_all,
        )
        return buf.at[r].set(x)

    return lax.fori_loop(0, R, row_body, buf_vals)


def make_banded_factorizer(
    plan: NumericPlan,
    axis_name: Optional[str] = None,
    broadcast: str = "psum",
):
    """Build the jit-able band/frontier numeric factorization body.

    Arguments of the returned function (all *device-local*, device-major band
    order, except the two replicated structure arrays):

    vals         (Bl*R, W) f32  — A values on the filled pattern (shard)
    cols         (Bl*R, W) i32  — column structure (shard)
    pivot_start  (Bl*R, B+1) i32
    band_of_row  (Bl*R,) i32
    intra_start  (Bl*R,) i32
    intra_count  (Bl*R,) i32
    cols_all     (n_pad, W) i32 — replicated
    dpos_all     (n_pad,) i32   — replicated

    Returns the factorized values shard (Bl*R, W).
    """
    R = plan.band_rows
    B = plan.n_bands
    D = plan.n_devices if axis_name is not None else 1
    W = plan.width
    Bl = B // D
    assert broadcast in ("psum", "ring")

    def factorize(vals, cols, pivot_start, band_of_row, intra_start, intra_count, cols_all, dpos_all):
        me = lax.axis_index(axis_name) if axis_name is not None else jnp.int32(0)
        vals3 = vals.reshape(Bl, R, W)
        cols3 = cols.reshape(Bl, R, W)
        istart3 = intra_start.reshape(Bl, R)
        icount3 = intra_count.reshape(Bl, R)

        def band_step(p, vals3):
            slot = p // D
            owner = p % D
            band_start = (p * R).astype(jnp.int32)
            # --- finish band p (runs on every device; only the owner's is real)
            buf = lax.dynamic_slice(vals3, (slot, 0, 0), (1, R, W))[0]
            bcols = lax.dynamic_slice(cols3, (slot, 0, 0), (1, R, W))[0]
            ist = lax.dynamic_slice(istart3, (slot, 0), (1, R))[0]
            icn = lax.dynamic_slice(icount3, (slot, 0), (1, R))[0]
            buf = finish_band(
                buf, bcols, band_start, ist, icn, plan.max_intra_pivots, cols_all, dpos_all
            )
            mine = jnp.equal(me, owner)
            if axis_name is not None:
                masked = jnp.where(mine, buf, jnp.zeros_like(buf))
                if broadcast == "psum":
                    buf = lax.psum(masked, axis_name)
                else:  # explicit directed ring — the paper's pipeline (Fig 4)
                    perm = [(d, (d + 1) % D) for d in range(D)]
                    s = masked
                    for _ in range(D - 1):
                        recv = lax.ppermute(s, axis_name, perm)
                        s = jnp.where(mine, s, recv)
                    buf = s
            # the owner writes the finished band back into its shard
            upd = lax.dynamic_update_slice(vals3, buf[None], (slot, 0, 0))
            vals3 = jnp.where(mine, upd, vals3) if axis_name is not None else upd

            # --- partial reduction of local later rows against band p
            flat = vals3.reshape(Bl * R, W)
            se = lax.dynamic_slice_in_dim(pivot_start, p, 2, axis=1)
            starts, ends = se[:, 0], se[:, 1]
            counts = jnp.where(band_of_row > p, ends - starts, 0)

            def one(x, jcols, start, count):
                return _reduce_row_against_band(
                    x, jcols, start, count, plan.max_pivots_per_band,
                    band_start, buf, cols_all, dpos_all,
                )

            flat = jax.vmap(one)(flat, cols, starts, counts)
            return flat.reshape(Bl, R, W)

        vals3 = lax.fori_loop(0, B, band_step, vals3)
        return vals3.reshape(Bl * R, W)

    return factorize


def factorize_single_device(plan: NumericPlan):
    """Single-device jitted banded factorization: full arrays in, CSR-order out."""
    fac = make_banded_factorizer(plan, axis_name=None)

    @jax.jit
    def run(vals_dm, cols_dm, pivot_start_dm, band_of_row_dm, intra_start_dm, intra_count_dm, cols_all, dpos_all):
        return fac(
            vals_dm, cols_dm, pivot_start_dm, band_of_row_dm,
            intra_start_dm, intra_count_dm, cols_all, dpos_all,
        )

    return run


def plan_device_arrays(plan: NumericPlan):
    """Host-side: all device-major inputs for the factorizer (full, unsharded)."""
    import numpy as np

    dm = plan.rows_device_major
    intra_start = plan.pivot_start[np.arange(plan.n_pad), plan.band_of_row].astype(np.int32)
    intra_count = (plan.diag_pos - intra_start).astype(np.int32)
    return dict(
        vals=dm(plan.a_vals),
        cols=dm(plan.cols),
        pivot_start=dm(plan.pivot_start),
        band_of_row=dm(plan.band_of_row),
        intra_start=dm(intra_start),
        intra_count=dm(intra_count),
        cols_all=plan.cols,
        dpos_all=plan.diag_pos,
    )
