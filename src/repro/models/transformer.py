"""Generic decoder/encoder stacks covering 9 of the 10 assigned archs
(xLSTM has its own heterogeneous stack in model.py).

One layer =  [norm -> attention (GQA or MLA) (‖ mamba branch for hymba)] +
             [norm -> MLP or MoE]           with residuals.

Layers are stacked on a leading L axis and driven by `lax.scan` (keeps the
512-device dry-run HLO small and compile times sane) with a configurable
remat policy. Whisper builds an encoder stack (bidirectional) and a decoder
stack with interleaved cross-attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import (
    gqa_attention,
    gqa_decode,
    init_gqa,
    init_mla,
    mla_attention,
    mla_decode,
)
from .common import KeyGen, layer_norm, rms_norm
from .ffn import init_mlp, init_moe, mlp, moe_ffn
from .ssm import init_ssm, ssm_forward


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_norm(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# --------------------------------------------------------------------------
# layer init
# --------------------------------------------------------------------------
def init_layer(key, cfg, cross_attn: bool = False):
    kg = KeyGen(key)
    p = {"attn_norm": init_norm(cfg), "mlp_norm": init_norm(cfg)}
    if cfg.attention == "mla":
        p["attn"] = init_mla(kg(), cfg)
    else:
        p["attn"] = init_gqa(kg(), cfg)
    if cross_attn:
        p["cross_norm"] = init_norm(cfg)
        p["cross"] = init_gqa(kg(), cfg)
    if cfg.hybrid_parallel_ssm:
        p["ssm"] = init_ssm(kg(), cfg)
        p["ssm_norm"] = init_norm(cfg)
    if cfg.n_routed_experts:
        p["moe"] = init_moe(kg(), cfg)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(kg(), cfg)
    return p


def init_stacked_layers(key, cfg, n_layers=None, cross_attn=False):
    """Stack per-layer params on a leading axis (for lax.scan)."""
    n = n_layers or cfg.n_layers
    keys = jax.random.split(key, n)
    leaves = [init_layer(k, cfg, cross_attn=cross_attn) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)


# --------------------------------------------------------------------------
# layer apply (full-sequence)
# --------------------------------------------------------------------------
def layer_forward(cfg, lp, x, positions, enc_kv=None):
    h = apply_norm(cfg, lp["attn_norm"], x)
    if cfg.attention == "mla":
        attn_out = mla_attention(lp["attn"], h, cfg, positions)
    else:
        attn_out = gqa_attention(lp["attn"], h, cfg, positions)
    if cfg.hybrid_parallel_ssm:
        hs = apply_norm(cfg, lp["ssm_norm"], x)
        ssm_out, _ = ssm_forward(lp["ssm"], hs, cfg)
        attn_out = (attn_out + ssm_out) * 0.5  # hymba parallel heads, mean fuse
    x = x + attn_out
    if enc_kv is not None:
        hc = apply_norm(cfg, lp["cross_norm"], x)
        x = x + gqa_attention(lp["cross"], hc, cfg, cross_kv=enc_kv)
    h2 = apply_norm(cfg, lp["mlp_norm"], x)
    if cfg.n_routed_experts:
        y = moe_ffn(lp["moe"], h2, cfg)
    elif cfg.d_ff:
        y = mlp(lp["mlp"], h2, cfg)
    else:
        y = 0.0
    return x + y


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def stack_forward(cfg, layers, x, positions, enc_kv=None):
    """Run the layer stack (scan when homogeneous)."""
    fn = _maybe_remat(cfg, functools.partial(layer_forward, cfg))
    if cfg.scan_layers:
        def body(carry, lp):
            return fn(lp, carry, positions, enc_kv), None

        x, _ = jax.lax.scan(body, x, layers)
        return x
    n = jax.tree.leaves(layers)[0].shape[0]
    for i in range(n):
        lp = jax.tree.map(lambda t: t[i], layers)
        x = fn(lp, x, positions, enc_kv)
    return x


# --------------------------------------------------------------------------
# layer apply (single-token decode, KV cache carried per layer)
# --------------------------------------------------------------------------
def layer_decode(cfg, lp, x, cache, enc_kv=None):
    h = apply_norm(cfg, lp["attn_norm"], x)
    if cfg.attention == "mla":
        attn_out, kv = mla_decode(lp["attn"], h, cfg, cache["kv"])
    else:
        attn_out, kv = gqa_decode(lp["attn"], h, cfg, cache["kv"])
    new_cache = {"kv": kv}
    if cfg.hybrid_parallel_ssm:
        hs = apply_norm(cfg, lp["ssm_norm"], x)
        ssm_out, sst = ssm_forward(lp["ssm"], hs, cfg, state=cache["ssm"])
        attn_out = (attn_out + ssm_out) * 0.5
        new_cache["ssm"] = sst
    x = x + attn_out
    if "cross_k" in cache:  # enc-dec: pre-projected cross K/V, cached once
        from .attention import decode_attention

        B = x.shape[0]
        H, hd = cfg.n_heads, cfg.head_dim
        hc = apply_norm(cfg, lp["cross_norm"], x)
        q = (hc @ lp["cross"]["wq"]).reshape(B, 1, H, hd)
        T = cache["cross_k"].shape[1]
        o = decode_attention(q, cache["cross_k"], cache["cross_v"], jnp.full((B,), T, jnp.int32))
        x = x + o.reshape(B, 1, -1) @ lp["cross"]["wo"]
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]
    elif enc_kv is not None:
        hc = apply_norm(cfg, lp["cross_norm"], x)
        x = x + gqa_attention(lp["cross"], hc, cfg, cross_kv=enc_kv)
    h2 = apply_norm(cfg, lp["mlp_norm"], x)
    if cfg.n_routed_experts:
        y = moe_ffn(lp["moe"], h2, cfg)
    elif cfg.d_ff:
        y = mlp(lp["mlp"], h2, cfg)
    else:
        y = 0.0
    return x + y, new_cache


def stack_decode(cfg, layers, x, caches, enc_kv=None):
    if cfg.scan_layers:
        def body(carry, layer_and_cache):
            lp, c = layer_and_cache
            out, nc = layer_decode(cfg, lp, carry, c, enc_kv)
            return out, nc

        x, new_caches = jax.lax.scan(body, x, (layers, caches))
        return x, new_caches
    n = jax.tree.leaves(layers)[0].shape[0]
    new_list = []
    for i in range(n):
        lp = jax.tree.map(lambda t: t[i], layers)
        c = jax.tree.map(lambda t: t[i], caches)
        x, nc = layer_decode(cfg, lp, x, c, enc_kv)
        new_list.append(nc)
    new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    return x, new_caches


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------
def init_layer_caches(cfg, batch, cache_len, n_layers=None, with_cross=None):
    """Stacked (L-leading) decode caches for the layer stack.

    For enc-dec models (``with_cross`` defaults on for family=='audio'),
    the cache carries the per-layer projected cross-attention K/V so the
    encoder runs ONCE per request, not once per token (§Perf whisper fix):
    fill via :func:`repro.models.model.precompute_cross_kv`.
    """
    L = n_layers or cfg.n_layers
    dt = cfg.act_dtype
    if with_cross is None:
        with_cross = cfg.family == "audio"
    if cfg.attention == "mla":
        kv = {
            "c": jnp.zeros((L, batch, cache_len, cfg.mla_kv_lora), dt),
            "r": jnp.zeros((L, batch, cache_len, cfg.mla_rope_dim), dt),
            "len": jnp.zeros((L, batch), jnp.int32),
        }
    else:
        kv = {
            "k": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, cfg.head_dim), dt),
            "len": jnp.zeros((L, batch), jnp.int32),
        }
    caches = {"kv": kv}
    if cfg.hybrid_parallel_ssm:
        di = cfg.ssm_inner or cfg.d_model
        caches["ssm"] = {
            "h": jnp.zeros((L, batch, di, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((L, batch, 3, di), cfg.param_dtype),
        }
    if with_cross and cfg.encoder_seq:
        caches["cross_k"] = jnp.zeros((L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), dt)
        caches["cross_v"] = jnp.zeros((L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), dt)
    return caches
