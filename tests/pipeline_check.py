"""Subprocess body: 4-stage GPipe pipeline == sequential layer stack."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import make_mesh
    from repro.configs import get_config
    from repro.models.transformer import init_stacked_layers, stack_forward
    from repro.train.pipeline import make_pipelined_forward, pipeline_bubble_fraction

    cfg = get_config("smollm-135m").reduced()
    cfg = dataclasses.replace(cfg, n_layers=8, q_chunk=32, kv_chunk=32, remat="none")
    devs = jax.devices()
    assert len(devs) == 4
    mesh = make_mesh(np.asarray(devs), ("pipe",))

    key = jax.random.PRNGKey(0)
    layers = init_stacked_layers(key, cfg)
    B, S, d = 8, 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), cfg.act_dtype) * 0.1
    positions = jnp.arange(S)

    want = stack_forward(cfg, layers, x, positions)
    pipe = make_pipelined_forward(cfg, mesh, n_microbatches=4)
    got = jax.jit(lambda l, xx: pipe(l, xx, positions))(layers, x)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))
    print("forward max err:", err)
    assert err < 1e-4, err

    # backward: grads through the pipeline must match the sequential stack
    def loss_pipe(l, xx):
        return jnp.sum(pipe(l, xx, positions) ** 2)

    def loss_seq(l, xx):
        return jnp.sum(stack_forward(cfg, l, xx, positions) ** 2)

    g_pipe = jax.jit(jax.grad(loss_pipe))(layers, x)
    g_seq = jax.jit(jax.grad(loss_seq))(layers, x)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        g_pipe, g_seq,
    )
    worst = max(jax.tree.leaves(errs))
    print("grad max err:", worst)
    assert worst < 1e-2, worst
    print(f"bubble fraction @(P=4, N=4): {pipeline_bubble_fraction(4, 4):.2f}")
    print("PIPELINE OK")


if __name__ == "__main__":
    main()
