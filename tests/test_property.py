"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import matgen, numeric_ilu_ref, pilu1_symbolic, symbolic_ilu_k
from repro.core.api import _symbolic, ilu
from repro.core.factor_plan import factor_plan_for
from repro.core.planner import make_plan
from repro.core.solvers import solve_with_ilu
from repro.serve import ServeEngine


matrices = st.builds(
    matgen,
    n=st.integers(min_value=8, max_value=72),
    density=st.floats(min_value=0.03, max_value=0.25),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)


@given(a=matrices, k=st.integers(min_value=0, max_value=3))
@settings(max_examples=25, deadline=None)
def test_pattern_invariants(a, k):
    pat = symbolic_ilu_k(a, k)
    pat.validate()
    # A's pattern is always contained, with level 0
    for j in range(a.n):
        acols, _ = a.row(j)
        pcols, plevs = pat.row(j)
        pos = np.searchsorted(pcols, acols)
        assert np.all(pcols[pos] == acols)
        assert np.all(plevs[pos] == 0)
    # levels bounded by k
    assert pat.levels.max(initial=0) <= k


@given(a=matrices)
@settings(max_examples=15, deadline=None)
def test_pilu1_always_equals_general(a):
    g = symbolic_ilu_k(a, 1)
    f = pilu1_symbolic(a)
    np.testing.assert_array_equal(g.indices, f.indices)
    np.testing.assert_array_equal(g.levels, f.levels)


@given(a=matrices, k=st.integers(min_value=0, max_value=2),
       band_rows=st.integers(min_value=1, max_value=24))
@settings(max_examples=12, deadline=None)
def test_bitcompat_any_banding(a, k, band_rows):
    """The central theorem: band decomposition never changes a single bit."""
    pat = symbolic_ilu_k(a, k)
    want = numeric_ilu_ref(a, pat)
    got = ilu(a, k, backend="jax", band_rows=band_rows).vals
    np.testing.assert_array_equal(got.view(np.int32), want.view(np.int32))


@given(a=matrices, band_rows=st.integers(min_value=1, max_value=16),
       d=st.integers(min_value=1, max_value=6))
@settings(max_examples=15, deadline=None)
def test_planner_invariants(a, band_rows, d):
    pat = symbolic_ilu_k(a, 1)
    plan = make_plan(a, pat, band_rows=band_rows, n_devices=d)
    assert plan.n_bands % d == 0
    assert plan.n_pad == plan.n_bands * plan.band_rows
    assert plan.n_pad >= a.n
    # device-major permutation is a bijection
    x = np.arange(plan.n_pad, dtype=np.int64)
    rt = plan.rows_from_device_major(plan.rows_device_major(x))
    np.testing.assert_array_equal(rt, x)
    # pivot_start is monotone per row, bounded by diag
    assert np.all(np.diff(plan.pivot_start, axis=1) >= 0)
    assert np.all(plan.pivot_start[:, -1] <= plan.diag_pos)


@given(
    a=st.builds(matgen,
                n=st.integers(min_value=12, max_value=40),
                density=st.floats(min_value=0.06, max_value=0.2),
                seed=st.integers(min_value=0, max_value=2**31 - 1)),
    k=st.integers(min_value=0, max_value=2),
    method=st.sampled_from(["sweep", "inverse"]),
    nb=st.integers(min_value=2, max_value=4),
    pos=st.integers(min_value=0, max_value=3),
    data=st.data(),
)
@settings(max_examples=8, deadline=None)
def test_coalescing_never_changes_bits(a, k, method, nb, pos, data):
    """The serving theorem: coalescing a request into *any* batch — any
    bucket, any lane position, any neighbours, any mixed per-lane
    tolerances — returns bits identical to solving it alone."""
    pos = pos % nb
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1),
                     label="rhs_seed")
    rng = np.random.default_rng(seed)
    pattern = _symbolic(a, k, "sum")
    v = np.asarray(factor_plan_for(a, pattern).factorize(a))
    eng = ServeEngine(a, pattern, v, restart=4, maxiter=30,
                      precond_method=method, buckets=(1, 2, 4))
    bind = eng.bind(a, v)

    b = rng.standard_normal(a.n).astype(np.float32)
    tol = 1e-5
    ref, _ = solve_with_ilu(a, b, k=k, tol=tol, restart=4, maxiter=30,
                            use_pallas=False, precond_method=method)
    solo = eng.solve(bind, b[None, :], np.asarray([tol], np.float32))[0]
    np.testing.assert_array_equal(
        np.asarray(solo.x, np.float32).view(np.int32),
        np.asarray(ref.x, np.float32).view(np.int32),
        err_msg=f"solo serve lane != solve_with_ilu (k={k}, {method})")

    B = rng.standard_normal((nb, a.n)).astype(np.float32)
    tols = rng.choice(np.asarray([1e-4, 1e-5, 1e-6], np.float32), size=nb)
    B[pos] = b
    tols[pos] = tol
    lane = eng.solve(bind, B, tols.astype(np.float32))[pos]
    np.testing.assert_array_equal(
        np.asarray(lane.x, np.float32).view(np.int32),
        np.asarray(solo.x, np.float32).view(np.int32),
        err_msg=(f"lane {pos} of a {nb}-request batch (bucket "
                 f"{eng.bucket_for(nb)}) != solo (k={k}, {method})"))
    assert lane.iterations == solo.iterations
