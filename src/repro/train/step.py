"""train_step / serve_step factories — the functions the dry-run lowers.

``make_train_step`` closes over config + optimizer config and returns

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

with optional gradient-accumulation microbatching (a `lax.scan` over
microbatch slices — the standard way to trade HBM for steps) and optional
error-feedback gradient compression applied before the (implicit, XLA-
inserted) data-parallel all-reduce.

``make_serve_step`` returns one greedy decode step:

    serve_step(params, cache, tokens[, frames]) -> (next_tokens, logits, cache)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import model as M
from ..optim import adamw
from ..optim.compression import ef_compress_tree


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, microbatches: int = 1,
                    compress_grads: bool = False):
    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def mb_slice(b, i):
                return jax.tree.map(
                    lambda t: jax.lax.dynamic_slice_in_dim(
                        t, i * (t.shape[0] // microbatches), t.shape[0] // microbatches, 0
                    ),
                    b,
                )

            def acc_body(carry, i):
                gsum, lsum = carry
                mb = mb_slice(batch, i)
                loss, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0)), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        if compress_grads:
            grads, err = ef_compress_tree(grads)  # stateless demo form
        params, opt_state, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg):
    def serve_step(params, cache, tokens, frames=None):
        logits, cache = M.decode_step(cfg, params, cache, tokens, frames=frames)
        next_tok = jnp.argmax(logits[..., : cfg.vocab_real], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return serve_step


def make_prefill_step(cfg):
    """Forward-only lowering used for the prefill_* shapes."""

    def prefill_step(params, batch):
        logits = M.forward(cfg, params, batch)
        # return only the last position's logits (what serving needs)
        return logits[:, -1, :]

    return prefill_step
