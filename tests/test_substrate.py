"""Substrate: checkpointing (atomic/async/elastic), data pipeline,
optimizer, compression, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.optim import adamw
from repro.optim.compression import ef_step, int8_dequantize, int8_quantize, topk_sparsify
from repro.runtime.fault import StragglerMonitor, band_owner, run_with_restarts


# ---------------------------------------------------------------- checkpoint
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    got, manifest = restore(str(tmp_path), None, t)
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 5
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000004", "step_00000005"]


def test_checkpoint_async(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree(1)
    ck.save_async(7, t)
    ck.wait()
    got, m = restore(str(tmp_path), 7, t)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory must never be visible to latest_step/restore."""
    t = _tree()
    save(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_00000009.tmp" / "arrays")
    assert latest_step(str(tmp_path)) == 1


def test_elastic_restore_changes_sharding(tmp_path):
    """Save unsharded, restore onto an explicit (1,1) mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    t = _tree(3)
    save(str(tmp_path), 2, t)
    mesh = make_host_mesh(1, 1)
    sh = jax.tree.map(lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), t)
    got, _ = restore(str(tmp_path), 2, t, shardings=sh)
    assert got["a"].sharding.mesh.shape == {"data": 1, "model": 1}
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


# ---------------------------------------------------------------- data
def test_data_determinism_and_host_sharding():
    a = SyntheticLM(1000, 32, 8, host_index=0, host_count=2, seed=7)
    b = SyntheticLM(1000, 32, 8, host_index=1, host_count=2, seed=7)
    x0, x1 = a.batch_at(3), b.batch_at(3)
    assert x0["tokens"].shape == (4, 32)
    assert not np.array_equal(x0["tokens"], x1["tokens"])  # different slices
    np.testing.assert_array_equal(x0["tokens"], a.batch_at(3)["tokens"])  # deterministic
    # labels are next-token shifted
    np.testing.assert_array_equal(x0["labels"][:, :-1], x0["tokens"][:, 1:])


def test_prefetcher():
    src = SyntheticLM(100, 16, 4)
    pf = Prefetcher(src, start_step=0, prefetch=2)
    b0 = pf.next()
    np.testing.assert_array_equal(b0["tokens"], src.batch_at(0)["tokens"])
    b1 = pf.next()
    np.testing.assert_array_equal(b1["tokens"], src.batch_at(1)["tokens"])
    pf.close()


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    w = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw.init(w)
    c = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    for _ in range(150):
        g = jax.tree.map(lambda p: 2 * p, w)
        w, state, m = adamw.update(c, g, state, w)
    assert float(jnp.abs(w["w"]).max()) < 0.2


def test_adamw_clipping():
    w = {"w": jnp.ones(4)}
    state = adamw.init(w)
    c = adamw.AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=0)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw.update(c, g, state, w)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


# ---------------------------------------------------------------- compression
def test_topk_keeps_largest():
    g = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
    s = topk_sparsify(g, frac=0.1)
    nz = np.nonzero(np.asarray(s))[0]
    assert len(nz) == 10
    assert set(nz) == set(np.argsort(-np.abs(np.asarray(g)))[:10])


def test_error_feedback_preserves_signal():
    """Sum of compressed over steps ~ sum of raw gradients (EF property)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros(256)
    total_raw = np.zeros(256)
    total_sent = np.zeros(256)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(256), jnp.float32)
        sent, err = ef_step(g, err, frac=0.05)
        total_raw += np.asarray(g)
        total_sent += np.asarray(sent)
    resid = np.linalg.norm(total_raw - total_sent) / np.linalg.norm(total_raw)
    assert resid < 0.5, resid  # residual bounded (err carries the rest)


def test_int8_roundtrip():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(512), jnp.float32)
    q, s = int8_quantize(g)
    back = int8_dequantize(q, s)
    assert float(jnp.max(jnp.abs(back - g))) < float(jnp.max(jnp.abs(g))) / 100


# ---------------------------------------------------------------- fault
def test_straggler_monitor():
    m = StragglerMonitor(deadline_factor=2.0)
    for _ in range(10):
        m.observe(0.01)
    assert m.observe(0.1) is True
    assert m.slow_steps == 1


def test_band_owner_rebalances():
    owners_8 = {band_owner(b, 0, 8) for b in range(64)}
    owners_7 = {band_owner(b, 1, 7) for b in range(64)}
    assert owners_8 == set(range(8))
    assert owners_7 == set(range(7))


def test_run_with_restarts_recovers(tmp_path):
    """Inject a failure; driver must restore and complete all steps."""
    store = {}

    def make_state():
        return 0.0

    def step_fn(s, step):
        return s + 1.0

    def save_fn(s, step):
        store["ckpt"] = (s, step)

    def restore_fn():
        if "ckpt" not in store:
            return None, 0
        return store["ckpt"]

    failed = {"done": False}

    def fail_at(step):
        if step == 15 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    state, steps, restarts = run_with_restarts(
        make_state, step_fn, save_fn, restore_fn, n_steps=30, save_every=10,
        fail_at=fail_at,
    )
    assert restarts == 1
    assert steps == 30
    assert state == 30.0  # no lost or duplicated work past the checkpoint
