"""Benchmark harness — one entry per paper table/figure + kernel microbench.

    PYTHONPATH=src python -m benchmarks.run [--full] [--emit-json PATH]
    PYTHONPATH=src python -m benchmarks.run --smoke [--emit-json PATH]

``--smoke`` is the CI gate: validate every committed ``BENCH_*.json``
trajectory against the checked-in schemas (``benchmarks/bench_schema.py``)
without running anything heavy (no jax import), so a malformed trajectory
commit fails CI instead of silently breaking the README tables. With
``--emit-json`` it also writes the validation report.

Prints ``name,us_per_call,derived`` CSV. Paper-table benches report their
headline derived quantity (a speedup or a ratio); kernel benches report
measured interpret-mode microseconds per call (CPU — TPU numbers come from
the roofline, EXPERIMENTS.md §Roofline).

``--emit-json BENCH_solver.json`` additionally serializes the
device-resident solver-engine metrics (preconditioner-apply latency, GMRES
iterations/sec, first/steady solve wall times) so later PRs have a perf
trajectory to compare against. ``--emit-json BENCH_topilu.json`` runs the
*distributed* sharded-TOP-ILU trajectory instead: 1/2/8 simulated devices,
per-device value bytes, and the per-superstep halo collective payload from
the roofline model (cross-checked against compiled HLO). Set ``REPRO_JIT_CACHE=<dir>`` to enable
jax's persistent compilation cache (makes the one-time engine jit a
once-per-machine cost instead of once-per-process).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np


def _t(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_kernels(rows, quick=True):
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    m = 256 if quick else 1024
    a = jnp.asarray(rng.standard_normal((m, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, m)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((m, m)), jnp.float32)
    us, _ = _t(ops.panel_update, c, a, b)
    rows.append(("kernel.panel_update", us, f"gflops={2*m*m*128/us/1e3:.1f}"))

    u = np.triu(rng.standard_normal((128, 128)).astype(np.float32))
    np.fill_diagonal(u, np.abs(u).sum(1) + 1)
    us, _ = _t(ops.trsm_right_upper, a, jnp.asarray(u))
    rows.append(("kernel.trsm_right_upper", us, f"panel={m}x128"))

    n, w = (2048, 16) if quick else (16384, 32)
    cols = np.sort(rng.integers(0, n, (n, w)).astype(np.int32), axis=1)
    vals = rng.standard_normal((n, w)).astype(np.float32)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    us, _ = _t(ops.spmv_ell, jnp.asarray(cols), jnp.asarray(vals), x)
    rows.append(("kernel.spmv_ell", us, f"nnz={n*w}"))


def bench_paper_tables(rows, quick=True):
    from benchmarks import bench_ilu as B

    t0 = time.perf_counter()
    hdr, data, static_wins = B.table1_load_balancing(quick)
    rows.append(("paper.table1_static_vs_dynamic", (time.perf_counter() - t0) * 1e6,
                 f"static_wins={static_wins}"))

    t0 = time.perf_counter()
    hdr, data = B.fig6_symbolic_vs_numeric(quick)
    rows.append(("paper.fig6_sym_vs_num", (time.perf_counter() - t0) * 1e6, f"ratios={data[0][1]}"))

    t0 = time.perf_counter()
    hdr, data = B.tables23_pilu1(quick)
    best = max(r[5] for r in data)
    rows.append(("paper.tables23_pilu1_speedup", (time.perf_counter() - t0) * 1e6,
                 f"best_speedup={best}"))

    t0 = time.perf_counter()
    hdr, data, ib_better, ib_peak = B.fig8_infiniband(quick)
    rows.append(("paper.fig8_infiniband", (time.perf_counter() - t0) * 1e6,
                 f"ib_extends_scaling={ib_better and ib_peak}"))

    t0 = time.perf_counter()
    hdr, data, monotone = B.fig9_grid_latency(quick)
    rows.append(("paper.fig9_grid_latency", (time.perf_counter() - t0) * 1e6,
                 f"graceful_degradation={monotone} {data}"))

    t0 = time.perf_counter()
    hdr, data, seq_ratio, par_ratio = B.fig5_e40r3000(quick)
    rows.append(("paper.fig5_e40r3000", (time.perf_counter() - t0) * 1e6,
                 f"seq_k6/k3={seq_ratio:.1f} par_k6/k3={par_ratio:.1f}"))


def bench_bitcompat(rows, quick=True):
    """Not a paper table but THE paper property: parallel == sequential."""
    from repro.core import matgen, numeric_ilu_ref, pilu1_symbolic
    from repro.core.api import ilu

    n = 256 if quick else 1024
    a = matgen(n, density=0.03, seed=9)
    pat = pilu1_symbolic(a)
    want = numeric_ilu_ref(a, pat)
    t0 = time.perf_counter()
    got = ilu(a, 1, backend="jax", band_rows=16).vals
    us = (time.perf_counter() - t0) * 1e6
    eq = bool(np.array_equal(got.view(np.int32), want.view(np.int32)))
    rows.append(("paper.bitcompat_banded", us, f"bitwise_equal={eq}"))


def bench_factorization(rows, quick=True):
    """Plan→compile→execute factorization pipeline (PR-2 tentpole).

    Always measures the full sizes (n∈{4k,16k}) so BENCH_factor.json
    records the acceptance numbers; ``--full`` only raises solver sizes.
    """
    from benchmarks import bench_ilu as B

    m = B.factorization(quick=False)  # n in {4096, 16384}
    for c in m["cases"]:
        rows.append((f"factor.symbolic_n{c['n']}", c["symbolic_seconds"] * 1e6,
                     f"fill_nnz={c['fill_nnz']}"))
        rows.append((f"factor.plan_build_n{c['n']}", c["plan_build_seconds"] * 1e6,
                     f"rounds={c['rounds']}"))
        rows.append((f"factor.numeric_n{c['n']}", c["numeric_steady_seconds"] * 1e6,
                     f"speedup_vs_oracle={c['steady_speedup_vs_oracle']:.1f} "
                     f"bitwise={c['bitwise_equal_oracle']}"))
    return m


def bench_topilu(rows, devices=(1, 2, 8)):
    """Distributed sharded-TOP-ILU trajectory (PR-3 tentpole).

    Spawns one subprocess per simulated device count (the host device count
    locks at first JAX init) and aggregates the per-device memory +
    collective-payload records from ``benchmarks/bench_topilu.py``. Only
    runs when the ``--emit-json`` basename contains ``topilu`` (the same
    filename convention that selects the factorization payload): the three
    jax subprocesses are too slow to fold into every CSV run.
    """
    import subprocess

    grid = 32  # n=1024 — small enough for the 1-core CI, supersteps > 60
    child = os.path.join(os.path.dirname(__file__), "bench_topilu.py")
    cases = []
    for d in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["_BENCH_TOPILU_CHILD"] = "1"
        out = subprocess.run(
            [sys.executable, child, str(grid)], env=env, capture_output=True,
            text=True, timeout=600,
        )
        if out.returncode != 0:
            raise RuntimeError(f"bench_topilu D={d} failed:\n{out.stderr[-2000:]}")
        m = json.loads(out.stdout)
        cases.append(m)
        rows.append((f"topilu.factor_d{d}", m["factor_steady_seconds"] * 1e6,
                     f"bitwise={m['bitwise_equal_oracle']} "
                     f"per_dev_B={m['per_device_value_bytes']} "
                     f"halo_B_per_step={m['halo_bytes_per_superstep']}"))
    return {"cases": cases, "grid": grid}


def bench_sweep(rows, devices=(1, 2, 8)):
    """Epoch-fused distributed sweep trajectory (PR-4 tentpole).

    One subprocess per simulated device count (the host device count locks
    at first JAX init); aggregates the sweep-communication records from
    ``benchmarks/bench_sweep.py`` (collectives/solve, bytes/solve, steady
    distributed GMRES, serving-warmup latency). Selected by an
    ``--emit-json`` basename containing ``sweep``.
    """
    import subprocess

    grid = 32  # n=1024 — same problem as the BENCH_topilu trajectory
    child = os.path.join(os.path.dirname(__file__), "bench_sweep.py")
    cases = []
    for d in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["_BENCH_SWEEP_CHILD"] = "1"
        out = subprocess.run(
            [sys.executable, child, str(grid)], env=env, capture_output=True,
            text=True, timeout=1800,
        )
        if out.returncode != 0:
            raise RuntimeError(f"bench_sweep D={d} failed:\n{out.stderr[-2000:]}")
        m = json.loads(out.stdout)
        cases.append(m)
        rows.append((f"sweep.gmres_d{d}", m["gmres_steady_seconds"] * 1e6,
                     f"bitwise={m['bitwise_equal_single_device']} "
                     f"coll/apply={m['collectives_per_apply']} "
                     f"(unfused={m['levels_unfused']}) "
                     f"B/apply={m['bytes_per_apply']} "
                     f"(pr3={m['bytes_per_apply_unfused_pr3']})"))
        rows.append((f"sweep.warm_first_solve_d{d}",
                     m["warm_first_solve_seconds"] * 1e6,
                     f"batched_ms_per_rhs="
                     f"{m['gmres_batched_seconds_per_rhs'] * 1e3:.1f}"))
        by_name = {r["ordering"]: r for r in m["orderings"]["poisson"]}
        for name in ("rcm", "fusion"):
            r = by_name[name]
            rows.append((f"sweep.ordering_{name}_d{d}",
                         r["precond_apply_steady_seconds"] * 1e6,
                         f"epochs={r['epochs']} "
                         f"(natural={by_name['natural']['epochs']}) "
                         f"B/apply={r['bytes_per_apply']} "
                         f"bitwise={r['bitwise_equal_single_device_permuted']}"))
    return {"cases": cases, "grid": grid}


def bench_inverse(rows, devices=(1, 2, 8)):
    """Incomplete-inverse SpMV-chain trajectory (PR-6 tentpole).

    One subprocess per simulated device count; aggregates the
    sweep-vs-inverse apply latencies, the modeled communication both sides
    of the ``"auto"`` policy, and the bitwise anchors from
    ``benchmarks/bench_inverse.py``. Selected by an ``--emit-json``
    basename containing ``inverse``.
    """
    import subprocess

    grid = 32  # n=1024 — same problem as the BENCH_sweep trajectory
    child = os.path.join(os.path.dirname(__file__), "bench_inverse.py")
    cases = []
    for d in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["_BENCH_INVERSE_CHILD"] = "1"
        out = subprocess.run(
            [sys.executable, child, str(grid)], env=env, capture_output=True,
            text=True, timeout=1800,
        )
        if out.returncode != 0:
            raise RuntimeError(f"bench_inverse D={d} failed:\n{out.stderr[-2000:]}")
        m = json.loads(out.stdout)
        cases.append(m)
        rows.append((f"inverse.apply_d{d}",
                     m["inverse_apply_steady_seconds"] * 1e6,
                     f"sweep_{m['sweep_ordering']}="
                     f"{m['sweep_apply_steady_seconds'] * 1e6:.0f}us "
                     f"coll/apply={m['inverse_collectives_per_apply']} "
                     f"(sweep={m['sweep_collectives_per_apply']}) "
                     f"bitwise={m['bitwise_equal_single_device']}"))
        rows.append((f"inverse.gmres_d{d}", m["gmres_steady_seconds"] * 1e6,
                     f"iters={m['iterations_inverse']} "
                     f"(sweep={m['iterations_sweep']}) "
                     f"auto={m['auto_method']} "
                     f"random_converged={m['random']['converged']}"))
    return {"cases": cases, "grid": grid}


def bench_serve(rows, quick=True):
    """Multi-tenant coalesced serving trajectory (PR-8 tentpole).

    One subprocess (pinned CPU platform) running the seeded 4-tenant soak
    from ``benchmarks/bench_serve.py``: end-to-end solves/sec, per-tenant
    p50/p99, compile flatness after warmup, and a seeded bitwise sample
    against solo solves. Selected by an ``--emit-json`` basename
    containing ``serve``.
    """
    import subprocess

    child = os.path.join(os.path.dirname(__file__), "bench_serve.py")
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    n_requests = "2000"
    out = subprocess.run(
        [sys.executable, child, n_requests], env=env, capture_output=True,
        text=True, timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(f"bench_serve failed:\n{out.stderr[-2000:]}")
    m = json.loads(out.stdout)
    rows.append(("serve.solves_per_sec", 1e6 / m["solves_per_sec"],
                 f"solves_per_sec={m['solves_per_sec']:.0f} "
                 f"(raw={m['raw_solve_solves_per_sec']:.0f}) "
                 f"occupancy={m['occupancy_mean']:.2f}"))
    rows.append(("serve.p99_latency", m["p99_seconds"] * 1e6,
                 f"p50={m['p50_seconds'] * 1e3:.1f}ms "
                 f"batch_solve={m['mean_batch_solve_seconds'] * 1e3:.1f}ms"))
    rows.append(("serve.compile_flatness", m["warmup_seconds"] * 1e6,
                 f"after_warmup={m['compiles_after_warmup']} "
                 f"refactors={m['refactorizations']} "
                 f"bitwise={m['bitwise_equal_solo']}"))
    rb = m["robustness"]
    rows.append(("serve.robustness", rb["requests_failed"],
                 f"degraded_ok={rb['degraded_ok']} "
                 f"healthy_unaffected={rb['healthy_unaffected']} "
                 f"shifted_bindings={rb['counters']['shifted_bindings']} "
                 f"breakdown_lanes={rb['counters']['breakdown_lanes']} "
                 f"deadline_expired={rb['counters']['deadline_expired']}"))
    for c in m["sharded"]:
        rows.append((f"serve.sharded_d{c['devices']}",
                     1e6 / c["solves_per_sec"],
                     f"solves_per_sec={c['solves_per_sec']:.0f} "
                     f"after_warmup={c['compiles_after_warmup']} "
                     f"bitwise={c['bitwise_equal_solo']}"))
    return m


def bench_solver(rows, quick=True):
    """Device-resident preconditioned Krylov engine (PR-1 tentpole)."""
    from benchmarks import bench_ilu as B

    m = B.solver_engine(quick)
    rows.append(("solver.precond_apply", m["precond_apply_seconds"] * 1e6,
                 f"applies_per_sec={m['precond_applies_per_sec']:.0f}"))
    rows.append(("solver.gmres_steady", m["gmres_steady_solve_seconds"] * 1e6,
                 f"iters_per_sec={m['gmres_iters_per_sec']:.1f}"))
    rows.append(("solver.gmres_first", m["gmres_first_solve_seconds"] * 1e6,
                 f"n={m['problem']['n']} converged={m['converged']} rel={m['residual']:.1e}"))
    rows.append(("solver.gmres_batched", m["batched_steady_seconds_per_rhs"] * 1e6,
                 f"rhs={m['batched_rhs']} all_converged={m['batched_converged']}"))
    return m


def smoke(emit_json=None) -> int:
    """Validate the committed BENCH_*.json trajectories against the
    checked-in schemas. Returns the number of invalid files (CI exit code).
    Deliberately light: no jax import, runs in seconds."""
    from benchmarks.bench_schema import SCHEMAS, validate_file

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    report = {}
    bad = 0
    for name in sorted(SCHEMAS):
        path = os.path.join(root, name)
        errors = validate_file(path)
        report[name] = {"ok": not errors, "errors": errors}
        status = "ok" if not errors else f"INVALID ({len(errors)} errors)"
        print(f"bench-schema,{name},{status}")
        for e in errors[:20]:
            print(f"  {e}", file=sys.stderr)
        bad += bool(errors)
    if emit_json:
        with open(emit_json, "w") as f:
            json.dump({"bench": "schema_smoke", "results": report}, f, indent=2)
        print(f"wrote {emit_json}", file=sys.stderr)
    return bad


def main() -> None:
    argv = sys.argv[1:]
    quick = "--full" not in argv
    emit_json = None
    if "--emit-json" in argv:
        i = argv.index("--emit-json") + 1
        if i >= len(argv) or argv[i].startswith("--"):
            sys.exit("--emit-json requires a file path")
        emit_json = argv[i]
    if "--smoke" in argv:
        sys.exit(smoke(emit_json))
    if os.environ.get("REPRO_JIT_CACHE"):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
        from repro.core.api import enable_jit_cache

        enable_jit_cache()
    rows = []
    topilu_metrics = None
    base = os.path.basename(emit_json) if emit_json else ""
    if "topilu" in base or "sweep" in base or "inverse" in base or "serve" in base:
        # subprocess trajectories only: spawning jax subprocesses is too
        # slow to fold into every CSV run
        if "serve" in base:
            payload = {"bench": "serve_coalescing", "quick": quick, "metrics": bench_serve(rows)}
        elif "inverse" in base:
            payload = {"bench": "inverse_chain", "quick": quick, "metrics": bench_inverse(rows)}
        elif "sweep" in base:
            payload = {"bench": "sweep_epoch_fused", "quick": quick, "metrics": bench_sweep(rows)}
        else:
            topilu_metrics = bench_topilu(rows)
            payload = {"bench": "topilu_sharded", "quick": quick, "metrics": topilu_metrics}
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        with open(emit_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {emit_json}", file=sys.stderr)
        return
    solver_metrics = bench_solver(rows, quick)
    factor_metrics = bench_factorization(rows, quick)
    bench_bitcompat(rows, quick)
    bench_kernels(rows, quick)
    bench_paper_tables(rows, quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if emit_json:
        # BENCH_solver.json-style path keeps the PR-1 shape; any other path
        # (e.g. BENCH_factor.json) gets the factorization trajectory.
        if "factor" in os.path.basename(emit_json):
            payload = {"bench": "factorization", "quick": quick,
                       "metrics": factor_metrics,
                       "solver_engine": solver_metrics}
        else:
            payload = {"bench": "solver_engine", "quick": quick,
                       "metrics": solver_metrics,
                       "factorization": factor_metrics}
        with open(emit_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {emit_json}", file=sys.stderr)


if __name__ == "__main__":
    main()
