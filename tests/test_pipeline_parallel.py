"""Pipeline parallelism: GPipe schedule must match the sequential stack
exactly, forward and backward (subprocess with 4 simulated devices)."""
import os
import sys

from subproc import run_checked

SCRIPT = os.path.join(os.path.dirname(__file__), "pipeline_check.py")


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"  # don't probe for real TPUs (see test_topilu_multidevice)
    rc, out, err = run_checked([sys.executable, SCRIPT], env=env, timeout=600)
    assert rc == 0, f"stdout:{out}\nstderr:{err[-2000:]}"
    assert "PIPELINE OK" in out
