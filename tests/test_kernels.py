"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import importlib

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.planner import COL_SENTINEL
from repro.kernels import ops
from repro.kernels import ref


RNG = np.random.default_rng(0)


def _tri_upper(bs, dtype):
    # diagonally dominant: random triangular matrices are exponentially
    # ill-conditioned, which would make the sweep test meaningless
    u = np.triu(RNG.standard_normal((bs, bs)).astype(dtype))
    np.fill_diagonal(u, np.abs(u).sum(1) + 1.0)
    return u


def _tri_unit_lower(bs, dtype):
    l = np.tril(RNG.standard_normal((bs, bs)).astype(dtype), -1)
    l /= np.maximum(np.abs(l).sum(1, keepdims=True), 1.0) * 1.5
    np.fill_diagonal(l, 1.0)
    return l


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,n,k", [(8, 8, 8), (64, 64, 32), (128, 256, 128), (96, 40, 72), (256, 128, 256)]
)
def test_panel_update_sweep(m, n, k, dtype):
    a = RNG.standard_normal((m, k)).astype(np.float32)
    b = RNG.standard_normal((k, n)).astype(np.float32)
    c = RNG.standard_normal((m, n)).astype(np.float32)
    a, b, c = (jnp.asarray(x, dtype) for x in (a, b, c))
    got = ops.panel_update(c, a, b, bm=64, bn=64, bk=32)
    want = ref.panel_update_ref(c, a, b)
    # blocked-k accumulation reorders the f32 sum; tolerance scales with k
    rtol, atol = (2e-3, 2e-4) if dtype == np.float32 else (5e-2, 1.0)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=atol
    )


@pytest.mark.parametrize("bs", [8, 32, 128])
@pytest.mark.parametrize("m", [8, 64, 200])
def test_trsm_right_upper_sweep(bs, m):
    a = RNG.standard_normal((m, bs)).astype(np.float32)
    u = _tri_upper(bs, np.float32)
    got = np.asarray(ops.trsm_right_upper(jnp.asarray(a), jnp.asarray(u), bm=64))
    want = np.asarray(ref.trsm_right_upper_ref(jnp.asarray(a), jnp.asarray(u)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # X @ U == A
    np.testing.assert_allclose(got @ u, a, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bs", [8, 32, 128])
@pytest.mark.parametrize("n", [8, 64, 200])
def test_trsm_left_unit_lower_sweep(bs, n):
    a = RNG.standard_normal((bs, n)).astype(np.float32)
    l = _tri_unit_lower(bs, np.float32)
    got = np.asarray(ops.trsm_left_unit_lower(jnp.asarray(l), jnp.asarray(a), bn=64))
    want = np.asarray(ref.trsm_left_unit_lower_ref(jnp.asarray(l), jnp.asarray(a)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(l @ got, a, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n,w", [(16, 4), (128, 9), (500, 17), (1024, 33)])
def test_spmv_ell_sweep(n, w):
    cols = np.full((n, w), COL_SENTINEL, np.int32)
    vals = np.zeros((n, w), np.float32)
    for j in range(n):
        m = RNG.integers(1, w + 1)
        c = np.sort(RNG.choice(n, size=m, replace=False)).astype(np.int32)
        cols[j, :m] = c
        vals[j, :m] = RNG.standard_normal(m)
    x = RNG.standard_normal(n).astype(np.float32)
    got = np.asarray(ops.spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x), bm=64))
    want = np.asarray(ref.spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_spmv_matches_csr():
    """Against scipy CSR matvec on a real matrix."""
    from repro.core import matgen
    from repro.core.solvers import csr_to_ell_arrays

    a = matgen(96, density=0.08, seed=1)
    cols, vals = csr_to_ell_arrays(a)
    x = RNG.standard_normal(a.n).astype(np.float32)
    got = np.asarray(ops.spmv_ell(cols, vals, jnp.asarray(x)))
    want = a.to_scipy() @ x
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# Bitwise contracts: kernels vs their jnp references. The solve-path kernels
# share `masked_lane_sum` / the substitution recurrences with the refs, so
# the comparison is exact (int32 view), not allclose — across odd widths,
# fully-padded sentinel rows, and block sizes that do not divide the data.
# --------------------------------------------------------------------------
def _assert_bitwise(got, want):
    np.testing.assert_array_equal(
        np.asarray(got, np.float32).view(np.int32),
        np.asarray(want, np.float32).view(np.int32),
    )


def _rand_ell(n, w, rng, empty_every=5):
    """Sentinel-padded ELL with ragged rows; every ``empty_every``-th row is
    fully padded (pure sentinel) to exercise the masked lanes."""
    cols = np.full((n, w), COL_SENTINEL, np.int32)
    vals = np.zeros((n, w), np.float32)
    for j in range(n):
        if empty_every and j % empty_every == 0:
            continue
        m = int(rng.integers(1, w + 1))
        c = np.sort(rng.choice(n, size=m, replace=False)).astype(np.int32)
        cols[j, :m] = c
        vals[j, :m] = rng.standard_normal(m)
    return cols, vals


@pytest.mark.parametrize(
    "n,w,bm", [(64, 3, 64), (100, 7, 32), (33, 1, 8), (129, 5, 64), (256, 13, 512)]
)
def test_spmv_ell_bitwise_vs_ref(n, w, bm):
    rng = np.random.default_rng(n * 31 + w)
    cols, vals = _rand_ell(n, w, rng)
    x = rng.standard_normal(n).astype(np.float32)
    got = ops.spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x), bm=bm)
    want = ref.spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    _assert_bitwise(got, want)


@pytest.mark.parametrize("bs,m,bm", [(8, 24, 8), (32, 200, 64), (16, 24, 16), (128, 96, 64)])
def test_trsm_right_upper_bitwise_vs_subst_ref(bs, m, bm):
    a = RNG.standard_normal((m, bs)).astype(np.float32)
    u = _tri_upper(bs, np.float32)
    got = ops.trsm_right_upper(jnp.asarray(a), jnp.asarray(u), bm=bm)
    want = ref.trsm_right_upper_subst_ref(jnp.asarray(a), jnp.asarray(u))
    _assert_bitwise(got, want)


@pytest.mark.parametrize("bs,n,bn", [(8, 24, 8), (32, 200, 64), (16, 24, 16), (128, 96, 64)])
def test_trsm_left_unit_lower_bitwise_vs_subst_ref(bs, n, bn):
    a = RNG.standard_normal((bs, n)).astype(np.float32)
    l = _tri_unit_lower(bs, np.float32)
    got = ops.trsm_left_unit_lower(jnp.asarray(l), jnp.asarray(a), bn=bn)
    want = ref.trsm_left_unit_lower_subst_ref(jnp.asarray(l), jnp.asarray(a))
    _assert_bitwise(got, want)


@pytest.mark.parametrize("seed,k", [(0, 1), (2, 2)])
def test_factor_wavefront_kernel_bitwise_vs_oracle(seed, k):
    """The factor-side twin of the tri-solve contract: the fused Pallas
    wavefront factorization == the sequential oracle, bit for bit."""
    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k
    from repro.core.factor_plan import build_factor_plan

    a = matgen(110, density=0.06, seed=seed)
    pat = symbolic_ilu_k(a, k)
    want = numeric_ilu_ref(a, pat)
    plan = build_factor_plan(a, pat)
    dev = plan.device_arrays()
    got = ops.factor_wavefront(
        dev["op_row"], dev["op_lane"], dev["op_piv"], dev["op_dlane"],
        dev["op_dst"], dev["dst_flat"], jnp.asarray(plan.a_vals),
    )
    _assert_bitwise(plan.values_to_csr(np.asarray(got)), want)


# --------------------------------------------------------------------------
# Compiled (non-interpret) lowering: only meaningful on real TPU hardware.
# Gated by the `pallas_compiled` marker + REPRO_PALLAS_INTERPRET=0 toggle
# (see conftest.py) so CPU CI skips them cleanly.
# --------------------------------------------------------------------------
@pytest.mark.pallas_compiled
def test_compiled_panel_update_matches_interpret():
    pu = importlib.import_module("repro.kernels.panel_update")

    a = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    got = pu.panel_update(c, a, b, bm=128, bn=128, bk=128, interpret=False)
    want = pu.panel_update(c, a, b, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.pallas_compiled
def test_compiled_spmv_ell_bitwise():
    sp = importlib.import_module("repro.kernels.spmv_ell")

    cols, vals = _rand_ell(256, 8, np.random.default_rng(7))
    x = np.random.default_rng(8).standard_normal(256).astype(np.float32)
    got = sp.spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x), bm=256, interpret=False)
    want = ref.spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    _assert_bitwise(got, want)


@pytest.mark.pallas_compiled
def test_compiled_factor_wavefront_bitwise():
    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k
    from repro.core.factor_plan import build_factor_plan
    pu = importlib.import_module("repro.kernels.panel_update")

    a = matgen(96, density=0.06, seed=11)
    pat = symbolic_ilu_k(a, 1)
    plan = build_factor_plan(a, pat)
    dev = plan.device_arrays()
    got = pu.factor_wavefront(
        dev["op_row"], dev["op_lane"], dev["op_piv"], dev["op_dlane"],
        dev["op_dst"], dev["dst_flat"], jnp.asarray(plan.a_vals), interpret=False,
    )
    _assert_bitwise(plan.values_to_csr(np.asarray(got)), numeric_ilu_ref(a, pat))


@pytest.mark.parametrize("seed,k", [(0, 1), (3, 2)])
def test_wavefront_kernel_bit_identical_to_triangular_solver(seed, k):
    """Regression for the PR's central claim: the fused Pallas wavefront
    apply == the sequential-order reference solve, bit for bit."""
    from repro.core import matgen, numeric_ilu_ref, symbolic_ilu_k
    from repro.core.triangular import PrecondApply, make_triangular_solver

    a = matgen(120, density=0.06, seed=seed)
    pat = symbolic_ilu_k(a, k)
    vals = numeric_ilu_ref(a, pat)
    b = np.random.default_rng(seed + 1).standard_normal(a.n).astype(np.float32)
    reference = make_triangular_solver(pat, vals)  # jnp sequential-order path
    fused = PrecondApply(pat, vals, use_pallas=True)
    _assert_bitwise(fused(jnp.asarray(b)), reference(jnp.asarray(b)))
    # the raw kernel against its jnp oracle on the same plan arrays
    dev = fused.plan.device_arrays()
    args = (dev["l_cols"], dev["l_vals"], dev["l_rhs_idx"], dev["u_cols"],
            dev["u_vals"], dev["u_diag"], dev["u_rhs_idx"], dev["out_perm"],
            jnp.asarray(b))
    _assert_bitwise(ops.tri_solve_wavefront(*args), ref.tri_solve_wavefront_ref(*args))


def _epoch_args(k=1, seed=5):
    """Real epoch tables from a sharded triangular plan (D=1: one epoch per
    sweep, every address local) + synthetic values."""
    from repro.core import matgen, symbolic_ilu_k
    from repro.core.triangular import build_sharded_triangular_plan

    a = matgen(96, density=0.06, seed=seed)
    pat = symbolic_ilu_k(a, k)
    plan = build_sharded_triangular_plan(pat, 8, 1)
    s = plan.l_sched
    rng = np.random.default_rng(seed + 1)
    cols = jnp.asarray(s.cols_local[0])
    vals = jnp.asarray(rng.standard_normal(cols.shape).astype(np.float32))
    rhs = jnp.asarray(rng.standard_normal(cols.shape[:2]).astype(np.float32))
    diag = jnp.asarray((rng.standard_normal(cols.shape[:2]) + 3).astype(np.float32))
    x0 = jnp.zeros(s.scratch + 1, jnp.float32)
    return x0, cols, vals, rhs, diag, s.scratch


@pytest.mark.parametrize("with_diag", [False, True])
def test_epoch_sweep_kernel_bitwise(with_diag):
    """The epoch-fused sweep kernel == the shared jnp implementation, bit
    for bit, for both the L (unit-diagonal) and U (divide) variants."""
    from repro.core.triangular import epoch_sweep_jnp
    te = importlib.import_module("repro.kernels.tri_sweep_epoch")

    x0, cols, vals, rhs, diag, scratch = _epoch_args()
    d = diag if with_diag else None
    want = epoch_sweep_jnp(x0, cols, vals, rhs, d, 0, scratch)
    got = te.epoch_sweep(x0, cols, vals, rhs, d, start=0, limit=scratch, interpret=True)
    _assert_bitwise(got, want)
    # the ops wrapper (REPRO_DISABLE_PALLAS escape hatch shares the impl)
    _assert_bitwise(ops.epoch_sweep(x0, cols, vals, rhs, d, start=0,
                                    limit=scratch), want)


@pytest.mark.pallas_compiled
@pytest.mark.parametrize("with_diag", [False, True])
def test_compiled_epoch_sweep_bitwise(with_diag):
    from repro.core.triangular import epoch_sweep_jnp
    te = importlib.import_module("repro.kernels.tri_sweep_epoch")

    x0, cols, vals, rhs, diag, scratch = _epoch_args(k=2, seed=9)
    d = diag if with_diag else None
    want = epoch_sweep_jnp(x0, cols, vals, rhs, d, 0, scratch)
    got = te.epoch_sweep(x0, cols, vals, rhs, d, start=0, limit=scratch, interpret=False)
    _assert_bitwise(got, want)
