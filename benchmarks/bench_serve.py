"""Serve-layer trajectory: multi-tenant coalesced solves/sec (PR-8 tentpole).

Drives the production :class:`repro.serve.SolveService` with seeded
4-tenant traffic against an n=1024-class matrix — warmup, then a few
thousand coalesced solves with a mid-stream background value update —
and records the service-level acceptance numbers:

* end-to-end **solves/sec** (admission → coalesce → bucketed solve →
  scatter, ticks included) and raw solve-loop throughput,
* per-tenant p50/p99 latency and the mean batch solve time that should
  dominate it,
* the compile counter split at warmup (``after_warmup`` must be 0),
* cache hit rate + refactorization count,
* a seeded sample of responses re-solved solo
  (``solve_with_ilu(..., use_pallas=False)``) and compared **bitwise** on
  the exact value version each request was admitted under.

Run via ``python -m benchmarks.run --emit-json BENCH_serve.json`` (which
spawns this file as a subprocess with a pinned CPU platform), or directly:

    JAX_PLATFORMS=cpu python benchmarks/bench_serve.py
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# the throughput configuration: matgen(1024, 0.004) converges in ~4 inner
# steps, so a right-sized restart (GMRES always runs the full masked
# restart window per outer iteration) is the solves/sec lever
N = 1024
DENSITY = 0.004
K = 1
RESTART = 4
MAXITER = 40
BUCKETS = (1, 2, 4, 8, 16, 32, 64)
TENANTS = ("t0", "t1", "t2", "t3")
BITWISE_SAMPLE = 24


def serve_trajectory(n_requests: int = 2000, seed: int = 17) -> dict:
    from repro.core.matgen import matgen
    from repro.core.solvers import solve_with_ilu
    from repro.core.sparse import CSRMatrix
    from repro.serve import ServeConfig, SolveService, run_traffic

    a = matgen(N, DENSITY, seed=5)
    svc = SolveService(ServeConfig(buckets=BUCKETS, restart=RESTART,
                                   maxiter=MAXITER, k=K))
    svc.register_matrix("m0", a)
    t0 = time.perf_counter()
    svc.warmup()
    warmup_seconds = time.perf_counter() - t0

    updates = {"m0": [(a.data * 1.1).astype(np.float32)]}
    t0 = time.perf_counter()
    result = run_traffic(svc, ["m0"], n_requests, seed=seed, tenants=TENANTS,
                         burst_max=max(BUCKETS), update_prob=0.01,
                         update_values=updates)
    wall = time.perf_counter() - t0
    snap = svc.metrics_snapshot()  # before reference solves (they compile)

    assert len(result.responses) == n_requests
    assert all(r.ok for r in result.responses)

    # seeded bitwise sample across value versions, buckets, lane positions
    rng = np.random.default_rng(seed)
    ref_mats = {1: a}
    for i, data in enumerate(result.updates["m0"]):
        ref_mats[2 + i] = CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices,
                                    data=data)
    by_id = {r.request_id: r for r in result.responses}
    sample = rng.choice(len(result.records), size=BITWISE_SAMPLE, replace=False)
    bitwise_ok = True
    for i in sample:
        rec = result.records[int(i)]
        resp = by_id[rec.request_id]
        ref, _ = solve_with_ilu(ref_mats[rec.expected_version], rec.b, k=K,
                                tol=rec.tol, restart=RESTART, maxiter=MAXITER,
                                use_pallas=False)
        bitwise_ok &= bool(np.array_equal(
            np.asarray(resp.x, np.float32).view(np.int32),
            np.asarray(ref.x, np.float32).view(np.int32)))

    co, ca, cp = snap["coalescing"], snap["cache"], snap["compiles"]
    lat = [snap["tenants"][t] for t in TENANTS]
    return {
        "n": N,
        "k": K,
        "restart": RESTART,
        "maxiter": MAXITER,
        "buckets": list(BUCKETS),
        "tenants": len(TENANTS),
        "requests": n_requests,
        "wall_seconds": wall,
        "solves_per_sec": n_requests / wall,
        "raw_solve_solves_per_sec": co["solved_lanes"] / co["solve_seconds_total"],
        "batches": co["batches"],
        "occupancy_mean": co["occupancy_mean"],
        "mean_batch_solve_seconds": co["solve_seconds_total"] / co["batches"],
        "warmup_seconds": warmup_seconds,
        "compiles_warmup": cp["warmup"],
        "compiles_after_warmup": cp["after_warmup"],
        "cache_hit_rate": ca["hit_rate"],
        "refactorizations": ca["refactorizations"],
        "p50_seconds": float(np.median([h["p50_seconds"] for h in lat])),
        "p99_seconds": float(max(h["p99_seconds"] for h in lat)),
        "per_tenant": [
            {"tenant": t, "count": snap["tenants"][t]["count"],
             "p50_seconds": snap["tenants"][t]["p50_seconds"],
             "p99_seconds": snap["tenants"][t]["p99_seconds"]}
            for t in TENANTS],
        "bitwise_equal_solo": bitwise_ok,
        "bitwise_checked": int(BITWISE_SAMPLE),
    }


if __name__ == "__main__":
    n_requests = int(sys.argv[1]) if len(sys.argv) > 1 else 2000
    print(json.dumps(serve_trajectory(n_requests)))
