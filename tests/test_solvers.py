"""Preconditioned solvers: convergence + the paper's k-vs-iterations story."""
import numpy as np
import pytest

from repro.core import matgen, poisson_2d
from repro.core.solvers import solve_with_ilu


def _rhs(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _check_residual(a, res, b, tol=5e-4):
    ax = a.to_scipy() @ res.x
    rel = np.linalg.norm(ax - b) / np.linalg.norm(b)
    assert rel < tol, f"relative residual {rel}"


def test_gmres_with_ilu1_converges():
    a = matgen(200, density=0.03, seed=1)
    b = _rhs(a.n)
    res, fact = solve_with_ilu(a, b, k=1, method="gmres", tol=1e-5)
    assert res.converged
    _check_residual(a, res, b)
    assert fact.nnz >= a.nnz


def test_bicgstab_with_ilu1_converges():
    a = matgen(200, density=0.03, seed=2)
    b = _rhs(a.n, 3)
    res, _ = solve_with_ilu(a, b, k=1, method="bicgstab", tol=1e-5)
    assert res.converged
    _check_residual(a, res, b)


def test_cg_poisson_ilu_reduces_iterations():
    """The reason preconditioning exists: fewer iterations with ILU."""
    a = poisson_2d(16)
    b = _rhs(a.n, 4)
    plain, _ = solve_with_ilu(a, b, k=None, method="cg", tol=1e-5, maxiter=2000)
    pre, _ = solve_with_ilu(a, b, k=1, method="cg", tol=1e-5, maxiter=2000)
    assert pre.converged
    assert pre.iterations < plain.iterations, (pre.iterations, plain.iterations)


def test_higher_k_not_worse():
    """Paper SV-B: larger k => better preconditioner (<= iterations)."""
    a = poisson_2d(14)
    b = _rhs(a.n, 5)
    it = {}
    for k in (0, 2):
        res, _ = solve_with_ilu(a, b, k=k, method="cg", tol=1e-6, maxiter=2000)
        assert res.converged
        it[k] = res.iterations
    assert it[2] <= it[0], it


def test_bicgstab_parallel_factorization_same_convergence():
    """Bit-compatibility corollary: solver behaviour is identical when the
    preconditioner is computed by the banded parallel engine."""
    a = matgen(150, density=0.04, seed=6)
    b = _rhs(a.n, 7)
    r_seq, _ = solve_with_ilu(a, b, k=1, method="bicgstab", backend="oracle")
    r_par, _ = solve_with_ilu(a, b, k=1, method="bicgstab", backend="jax")
    assert r_seq.iterations == r_par.iterations
    np.testing.assert_array_equal(r_seq.x, r_par.x)
