"""End-to-end system tests: training improves loss; checkpoint/restart
resumes mid-run; the solver pipeline works through the public API."""
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.optim import adamw
from repro.train.loop import train


def _tiny_cfg():
    cfg = get_config("smollm-135m").reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128, q_chunk=32, kv_chunk=32)


def test_training_reduces_loss():
    cfg = _tiny_cfg()
    res = train(cfg, n_steps=30, seq_len=64, global_batch=4, log_every=0,
                opt_cfg=adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=30))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first, (first, last)


def test_checkpoint_restart_resumes(tmp_path):
    cfg = _tiny_cfg()
    opt = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=40)
    # run 1: stop at step 20 (ckpt every 10)
    r1 = train(cfg, n_steps=20, ckpt_dir=str(tmp_path), save_every=10,
               seq_len=64, global_batch=4, log_every=0, opt_cfg=opt)
    # run 2: resumes from step 20, continues to 40
    r2 = train(cfg, n_steps=40, ckpt_dir=str(tmp_path), save_every=10,
               seq_len=64, global_batch=4, log_every=0, opt_cfg=opt)
    assert r2.restored_from == 20
    assert r2.steps == 20  # only the remaining steps ran
    # uninterrupted reference run must match the resumed run's loss stream
    r_ref = train(cfg, n_steps=40, seq_len=64, global_batch=4, log_every=0,
                  opt_cfg=opt)
    np.testing.assert_allclose(r_ref.losses[20:], r2.losses, rtol=1e-4, atol=1e-4)


def test_solver_public_api():
    from repro.core import matgen
    from repro.core.solvers import solve_with_ilu

    a = matgen(150, density=0.05, seed=0)
    b = np.random.default_rng(0).standard_normal(a.n).astype(np.float32)
    res, fact = solve_with_ilu(a, b, k=1, method="gmres")
    assert res.converged
    assert fact.nnz >= a.nnz
