"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 100 \
        [--reduced] [--ckpt /path] [--seq-len 128] [--batch 8] [--microbatches 2]

On a pod each host runs this same entrypoint; the data pipeline shards by
host and the checkpointer is elastic (DESIGN.md §6).
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.optim import adamw
    from repro.train.loop import train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    res = train(
        cfg, n_steps=args.steps, ckpt_dir=args.ckpt, seq_len=args.seq_len,
        global_batch=args.batch, microbatches=args.microbatches,
        opt_cfg=adamw.AdamWConfig(lr=args.lr, warmup_steps=min(10, args.steps // 5),
                                  total_steps=args.steps),
    )
    print(f"done: {res.steps} steps, final loss {res.losses[-1]:.4f}, "
          f"stragglers {res.straggler_steps}")


if __name__ == "__main__":
    main()
