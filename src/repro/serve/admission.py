"""Request front end: types, validation, and the bounded admission queue.

A request is ``(tenant, matrix_id, b, tol)`` plus solver knobs; admission
is the only place malformed input can enter the service, so every check
lives here and fails **that one request** with a structured reason — never
the coalesced batch it would have ridden in, never the process. Checks:

* ``matrix_id`` registered (and not mid-eviction without a host copy),
* ``b`` a finite 1-D float vector of the matrix's dimension,
* ``tol`` a finite positive float,
* queue depth below the admission bound (load shedding, not OOM).

The queue is a plain FIFO deque; fairness across tenants comes from the
coalescer batching *across* tenants rather than per-tenant queues — a
burst from one tenant fills lanes that would otherwise be padding.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

#: admission-reject / failure reason codes (stable strings — they key the
#: ``rejected_by_reason`` metrics map and the fault-injection tests)
UNKNOWN_MATRIX = "unknown_matrix"
BAD_SHAPE = "bad_shape"
NON_FINITE = "non_finite"
BAD_TOL = "bad_tol"
BAD_DEADLINE = "bad_deadline"
QUEUE_FULL = "queue_full"
SOLVE_FAILED = "solve_failed"
#: the request's deadline elapsed before (or while) its batch solved
DEADLINE_EXCEEDED = "deadline_exceeded"
#: the lane's solve classified as breakdown/diverged and the shift retry
#: (if enabled) did not recover it
BREAKDOWN = "breakdown"


class AdmissionError(ValueError):
    """Raised (and caught at the submit boundary) for a rejected request."""

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail


_req_ids = itertools.count()


@dataclasses.dataclass
class SolveRequest:
    """One admitted solve: fixed at submit time, immutable afterwards."""

    tenant: str
    matrix_id: str
    b: np.ndarray  # (n,) float32, validated finite
    tol: float
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    # bound at admission: the cache-entry binding this request will solve
    # against (a racing value update must not retarget an in-flight solve)
    binding: object = None
    #: wall-clock budget (None = no deadline); checked before dispatch and
    #: again before the response is recorded — an expired request fails with
    #: DEADLINE_EXCEEDED instead of occupying a lane
    deadline_seconds: Optional[float] = None
    expires_at: float = float("inf")
    # async completion: the dispatcher sets `response` then fires `done`;
    # synchronous tick() callers read the returned responses instead
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    response: object = dataclasses.field(default=None, repr=False, compare=False)

    def finish(self, resp) -> None:
        self.response = resp
        self.done.set()

    def result(self, timeout: Optional[float] = None):
        """Block until this request's response exists (async dispatcher
        path). Returns None on timeout."""
        if self.done.wait(timeout):
            return self.response
        return None


@dataclasses.dataclass
class SolveResponse:
    """Terminal state of a request — success or per-request failure."""

    request_id: int
    tenant: str
    matrix_id: str
    ok: bool
    x: Optional[np.ndarray] = None
    iterations: int = 0
    residual: float = float("nan")
    converged: bool = False
    error: Optional[str] = None
    error_reason: Optional[str] = None
    latency_seconds: float = 0.0
    #: bucket the request was coalesced into (lanes incl. padding); 0 = failed pre-solve
    batch_lanes: int = 0
    #: cache-entry version the solve ran against (refactorization audit trail)
    matrix_version: int = -1
    #: solver termination verdict for this lane (solvers.VERDICTS), None
    #: when the request never reached a solve
    verdict: Optional[str] = None
    #: True when the response came from a degraded path: a shift-retry
    #: recovery or an identity-preconditioner fallback
    degraded: bool = False
    #: diagonal shift α of the preconditioner that produced this response
    shift: float = 0.0


def validate_deadline(deadline_seconds) -> Optional[float]:
    """Validate a per-request deadline; returns the float budget or None."""
    if deadline_seconds is None:
        return None
    try:
        d = float(deadline_seconds)
    except (TypeError, ValueError):
        raise AdmissionError(
            BAD_DEADLINE, f"deadline {deadline_seconds!r} is not a float") from None
    if not (np.isfinite(d) and d > 0):
        raise AdmissionError(
            BAD_DEADLINE, f"deadline must be a finite positive float, got {d}")
    return d


def validate_request(tenant: str, matrix_id: str, b, tol, n: Optional[int]) -> np.ndarray:
    """All admission checks; returns the validated float32 RHS or raises
    :class:`AdmissionError`. ``n=None`` means the matrix is unknown."""
    if n is None:
        raise AdmissionError(UNKNOWN_MATRIX, f"matrix_id {matrix_id!r} is not registered")
    try:
        b = np.asarray(b, np.float32)
    except (TypeError, ValueError) as e:
        raise AdmissionError(BAD_SHAPE, f"b is not a numeric array: {e}") from None
    if b.ndim != 1 or b.shape[0] != n:
        raise AdmissionError(
            BAD_SHAPE,
            f"b must have shape ({n},) for matrix {matrix_id!r}, got {b.shape}")
    if not np.all(np.isfinite(b)):
        bad = int(np.sum(~np.isfinite(b)))
        raise AdmissionError(NON_FINITE, f"b contains {bad} non-finite entries")
    try:
        tol = float(tol)
    except (TypeError, ValueError):
        raise AdmissionError(BAD_TOL, f"tol {tol!r} is not a float") from None
    if not (np.isfinite(tol) and tol > 0):
        raise AdmissionError(BAD_TOL, f"tol must be a finite positive float, got {tol}")
    return b


class AdmissionQueue:
    """Bounded FIFO of admitted requests (thread-safe: submits may come
    from tenant threads while the tick loop drains)."""

    def __init__(self, max_depth: int = 4096):
        self.max_depth = max_depth
        self._q: deque = deque()
        self._lock = threading.Lock()

    def push(self, req: SolveRequest) -> None:
        with self._lock:
            if len(self._q) >= self.max_depth:
                raise AdmissionError(
                    QUEUE_FULL,
                    f"admission queue at max depth {self.max_depth}; retry later")
            self._q.append(req)

    def drain(self, limit: Optional[int] = None):
        """Pop up to ``limit`` requests (FIFO). The coalescer calls this
        once per tick and regroups by matrix."""
        out = []
        with self._lock:
            while self._q and (limit is None or len(out) < limit):
                out.append(self._q.popleft())
        return out

    def requeue_front(self, reqs) -> None:
        """Put overflow requests back at the *front*, preserving FIFO order
        (used when a tick's compatible group exceeds the largest bucket)."""
        with self._lock:
            for r in reversed(reqs):
                self._q.appendleft(r)

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)
