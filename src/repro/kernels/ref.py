"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's sweep test asserts allclose against these references across
shapes and dtypes; the references are also what the rest of the system uses
when ``REPRO_DISABLE_PALLAS=1``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.planner import COL_SENTINEL


def panel_update_ref(c, a, b):
    """Trailing-panel LU update: C - A @ B (f32 accumulation)."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return (c.astype(jnp.float32) - acc).astype(c.dtype)


def trsm_right_upper_ref(a, u):
    """Solve X U = A with U upper-triangular (the BILU L-panel step:
    L_JI = A_JI @ U_II^{-1})."""
    xt = jax.scipy.linalg.solve_triangular(
        u.T.astype(jnp.float32), a.T.astype(jnp.float32), lower=True
    )
    return xt.T.astype(a.dtype)


def trsm_left_unit_lower_ref(l, a):
    """Solve L X = A with L unit-lower (the BILU U-panel step:
    U_IJ = L_II^{-1} @ A_IJ)."""
    x = jax.scipy.linalg.solve_triangular(
        l.astype(jnp.float32), a.astype(jnp.float32), lower=True, unit_diagonal=True
    )
    return x.astype(a.dtype)


def spmv_ell_ref(cols, vals, x):
    """Row-major ELL SpMV with sentinel-padded columns."""
    n = x.shape[0]
    xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
    gathered = xg[jnp.minimum(cols, n)]
    return jnp.sum(jnp.where(cols < COL_SENTINEL, vals * gathered, 0.0), axis=1)
