"""deepseek-v2-lite-16b [moe] — MLA + 64 routed/2 shared experts, top-6.

[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite]. The assignment line
lists both "64e top-6" and "160 routed"; 160 is the full V2 — the HF-verified
Lite config is 64 routed + 2 shared, top-6, which we use (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,  # MLA: nope 128 + rope 64
    d_ff=1408,
    vocab_real=102400,
    attention="mla",
    mla_kv_lora=512,
    mla_nope_dim=128,
    mla_rope_dim=64,
    mla_v_dim=128,
    rope_theta=10000.0,
    n_routed_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1408,
    mlp_act="swiglu",
)
