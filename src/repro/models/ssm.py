"""Selective SSM (Mamba-style) branch — used by hymba's parallel heads.

Diagonal selective state space: per channel c and state dim n,

    h_t = exp(dt_t * A) ⊙ h_{t-1} + dt_t * B_t * x_t
    y_t = C_t · h_t + D ⊙ x_t

with input-dependent (selective) dt, B, C. The recurrence runs as a
`lax.scan` over time (O(1) state per step — this is what makes the 512k
decode shape lowerable); decode is a single step.

Simplifications vs the Mamba reference (recorded in DESIGN.md §8): the
depthwise causal conv is kept (kernel 4) but implemented as shifted adds;
no complex-mode A; dt via softplus with low-rank projection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import KeyGen, dense_init


def init_ssm(key, cfg):
    kg = KeyGen(key)
    d = cfg.d_model
    di = cfg.ssm_inner or d
    N = cfg.ssm_state
    dtr = max(d // 16, 1)
    dt = cfg.param_dtype
    return {
        "in_proj": dense_init(kg(), (d, di), dt),
        "conv_w": dense_init(kg(), (4, di), dt, scale=0.5),  # depthwise, k=4
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
        ),  # (di, N), f32
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_bc": dense_init(kg(), (di, 2 * N), dt),
        "w_dt1": dense_init(kg(), (di, dtr), dt),
        "w_dt2": dense_init(kg(), (dtr, di), dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(kg(), (di, d), dt, scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv4(u, w, state=None):
    """Depthwise causal conv, kernel 4, via shifted adds.
    u: (B,S,di), w: (4,di). Returns (y, new_state (B,3,di))."""
    if state is None:
        state = jnp.zeros((u.shape[0], 3, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)  # (B, S+3, di)
    y = (ext[:, 0:-3] * w[0] + ext[:, 1:-2] * w[1] + ext[:, 2:-1] * w[2] + ext[:, 3:] * w[3])
    new_state = ext[:, -3:]
    return y, new_state


def _ssm_scan(u, dt_, B_, C_, a, h0):
    """u,dt_: (B,S,di); B_,C_: (B,S,N); a: (di,N) negative; h0: (B,di,N)."""

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp  # (B,di),(B,di),(B,N),(B,N)
        decay = jnp.exp(dt_t[..., None] * a[None])  # (B,di,N)
        h = h * decay + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(u, 1, 0),
        jnp.moveaxis(dt_, 1, 0),
        jnp.moveaxis(B_, 1, 0),
        jnp.moveaxis(C_, 1, 0),
    )
    from .scan_utils import chunked_remat_scan

    h, ys = chunked_remat_scan(step, h0, xs)
    return h, jnp.moveaxis(ys, 0, 1)  # (B,S,di)


def ssm_forward(p, x, cfg, state=None):
    """x: (B,S,d). Returns (y (B,S,d), new_state dict)."""
    B, S, d = x.shape
    di = cfg.ssm_inner or d
    N = cfg.ssm_state
    u = x @ p["in_proj"]  # (B,S,di)
    conv_state = None if state is None else state["conv"]
    u, conv_state = _causal_conv4(u, p["conv_w"], conv_state)
    u = jax.nn.silu(u)
    bc = (u @ p["w_bc"]).astype(jnp.float32)
    B_, C_ = bc[..., :N], bc[..., N:]
    dt_ = jax.nn.softplus(((u @ p["w_dt1"]) @ p["w_dt2"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])  # (di,N), negative => stable decay
    h0 = (jnp.zeros((B, di, N), jnp.float32) if state is None else state["h"])
    h, y = _ssm_scan(u.astype(jnp.float32), dt_, B_, C_, a, h0)
    y = y + u.astype(jnp.float32) * p["d_skip"]
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, {"h": h, "conv": conv_state}


def ssm_decode(p, x, cfg, state):
    """Single-token step; state: {'h': (B,di,N) f32, 'conv': (B,3,di)}."""
    return ssm_forward(p, x, cfg, state=state)


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    di = cfg.ssm_inner or cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, 3, di), cfg.param_dtype),
    }
