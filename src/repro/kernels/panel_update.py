"""Pallas TPU kernels: the numeric-phase panel updates.

Two kernels, two granularities of the same operation (reducing a panel of
rows against finalized pivot rows):

* :func:`panel_update` — dense trailing-panel LU update ``C <- C - A @ B``,
  the FLOP hot-spot of the Block-ILU(k) numeric phase (the MXU adaptation
  of the paper's row-merge update, DESIGN.md §3): once fill lives on
  128-aligned tiles, every pivot step is a batch of these panel GEMMs.

  Tiling: classic three-loop matmul grid ``(M/bm, N/bn, K/bk)``; the output
  block is revisited along k and accumulated in VMEM; the first k-step
  initializes from C so the subtraction costs no extra pass over HBM.
  VMEM working set per step: bm*bk + bk*bn + bm*bn floats
  (128³ tiles -> 192 KiB, far under the ~16 MiB VMEM budget; the default
  bm=bn=256, bk=128 uses 384 KiB and keeps the MXU pipeline full).

* :func:`factor_wavefront` — the *sparse*, bit-compatible panel update of
  the scalar wavefront factorizer: the whole round-major pivot-op scan of
  a ``FactorPlan`` fused into one kernel launch (each round is one panel of
  independent rows reduced against already-final pivot rows through the
  plan's precomputed destination-lane maps). The kernel body deliberately
  *shares* its implementation with the jnp engine
  (``repro.core.numeric_jax.factor_wavefront_sweeps_jnp``) so the two
  cannot drift — bit-identity with the sequential oracle is enforced by
  construction and asserted in the tests. Dense GEMM cannot express this
  update bit-compatibly (a matmul reorders the oracle's per-row ascending
  pivot recurrence), which is exactly why BILU(k) — where the GEMM kernel
  *is* the panel update — is recorded as a different preconditioner.

Caveat (same as ``tri_solve_wavefront``): this container runs Pallas in
interpret mode (``REPRO_PALLAS_INTERPRET=1`` default); the compiled TPU
lowering keeps the whole value array + schedule in VMEM, which bounds n —
large-n lowering needs per-level HBM DMA (ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(a_ref, b_ref, c_ref, o_ref):
    k = pl.program_id(2)
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = (c_ref[...].astype(jnp.float32) - acc).astype(o_ref.dtype)

    @pl.when(k > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) - acc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def panel_update(c, a, b, *, bm=256, bn=256, bk=128, interpret=True):
    """C - A @ B for (M,K)x(K,N); M,N,K must be multiples of the block sizes
    (ops.py pads). f32 accumulation regardless of input dtype."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=resolve_interpret(interpret),
    )(a, b, c)


# --------------------------------------------------------------------------
# sparse wavefront panel update (scalar ILU(k) numeric phase)
# --------------------------------------------------------------------------
def _factor_kernel(op_row_ref, op_lane_ref, op_piv_ref, op_dlane_ref,
                   op_dst_ref, dst_flat_ref, a_vals_ref, o_ref):
    from repro.core.numeric_jax import factor_wavefront_sweeps_jnp

    o_ref[...] = factor_wavefront_sweeps_jnp(
        op_row_ref[...], op_lane_ref[...], op_piv_ref[...],
        op_dlane_ref[...], op_dst_ref[...], dst_flat_ref[...], a_vals_ref[...],
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def factor_wavefront(op_row, op_lane, op_piv, op_dlane, op_dst, dst_flat,
                     a_vals_ext, *, interpret=True):
    """Round-major pivot-op ILU(k) factorization in one kernel launch.

    ``op_*``: (NR, MO) pivot-op schedule; ``dst_flat``: (n_ops+1, W)
    precomputed destination lanes; ``a_vals_ext``: (n+1, W) A on the
    pattern + scratch row. Returns the factored (n, W) values,
    bit-identical to the jnp engine (shared implementation) and to the
    sequential oracle.
    """
    n = a_vals_ext.shape[0] - 1
    w = a_vals_ext.shape[1]
    args = (op_row, op_lane, op_piv, op_dlane, op_dst, dst_flat, a_vals_ext)
    return pl.pallas_call(
        _factor_kernel,
        in_specs=[pl.BlockSpec(a.shape, lambda *_, s=a.shape: (0,) * len(s))
                  for a in args],
        out_specs=pl.BlockSpec((n, w), lambda *_: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), a_vals_ext.dtype),
        interpret=resolve_interpret(interpret),
    )(*args)
