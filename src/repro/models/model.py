"""Model facade: init / forward / loss / decode for every assigned arch.

    params = init_params(cfg, key)
    logits = forward(cfg, params, batch)           # train / prefill
    loss   = loss_fn(cfg, params, batch)
    cache  = init_cache(cfg, batch_size, cache_len)
    logits, cache = decode_step(cfg, params, cache, tokens, frames=...)

`batch` is a dict: tokens (B,S) int32 [+ labels, vision_embeds, frames].
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import KeyGen, cross_entropy_loss, dense_init, embed_init, maybe_shard
from .ffn import moe_aux_loss
from .transformer import (
    apply_norm,
    init_layer_caches,
    init_norm,
    init_stacked_layers,
    stack_decode,
    stack_forward,
)
from .xlstm import init_mlstm, init_slstm, mlstm_forward, slstm_forward


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg, key):
    kg = KeyGen(key)
    V, d = cfg.vocab, cfg.d_model
    p: Dict = {"embed": embed_init(kg(), (V, d), cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kg(), (d, V), cfg.param_dtype, scale=0.02)
    p["final_norm"] = init_norm(cfg)

    if cfg.family == "ssm":  # xLSTM heterogeneous stack
        blocks = []
        for t in cfg.block_types:
            init = init_mlstm if t == "m" else init_slstm
            blk = dict(init(kg(), cfg))
            blk["pre_norm"] = init_norm(cfg)
            blocks.append(blk)
        p["blocks"] = blocks
        return p

    cross = cfg.family == "audio"
    p["layers"] = init_stacked_layers(kg(), cfg, cross_attn=cross)
    if cfg.family == "audio":  # whisper encoder (bidirectional, no cross)
        import dataclasses

        enc_cfg = dataclasses.replace(
            cfg, hybrid_parallel_ssm=False, n_routed_experts=0, use_rope=False
        )
        p["encoder"] = {
            "layers": init_stacked_layers(kg(), enc_cfg, n_layers=cfg.encoder_layers),
            "final_norm": init_norm(cfg),
        }
    return p


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------
def _embed(cfg, p, tokens):
    x = p["embed"][tokens]  # (B,S,d)
    return x.astype(cfg.act_dtype)


def _head(cfg, p, x):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ w
    return maybe_shard(logits, ("pod", "data"), None, "model")


def _positions(S):
    return jnp.arange(S)


def _sinusoid(S, d, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------
# whisper encoder
# --------------------------------------------------------------------------
def _encode_audio(cfg, p, frames):
    import dataclasses

    enc_cfg = dataclasses.replace(
        cfg, hybrid_parallel_ssm=False, n_routed_experts=0, use_rope=False,
        sliding_window=None,
    )
    B, T, d = frames.shape
    x = frames.astype(cfg.act_dtype) + _sinusoid(T, d, cfg.act_dtype)[None]
    # bidirectional: causal=False via cross_kv trick — self-attention with
    # full visibility. Reuse gqa_attention's cross path on itself.
    from .attention import gqa_project_qkv, chunked_attention
    from .transformer import apply_norm as an, _maybe_remat

    def enc_layer(lp, x):
        h = an(enc_cfg, lp["attn_norm"], x)
        q, k, v = gqa_project_qkv(lp["attn"], h, enc_cfg, _positions(T))
        o = chunked_attention(q, k, v, causal=False,
                              q_chunk=enc_cfg.q_chunk, kv_chunk=enc_cfg.kv_chunk,
                              unroll_prefix=enc_cfg.attn_unroll)
        x = x + o.reshape(B, T, -1) @ lp["attn"]["wo"]
        h2 = an(enc_cfg, lp["mlp_norm"], x)
        from .ffn import mlp

        return x + mlp(lp["mlp"], h2, enc_cfg)

    fn = _maybe_remat(enc_cfg, enc_layer)

    if enc_cfg.scan_layers:
        def body(carry, lp):
            return fn(lp, carry), None

        x, _ = jax.lax.scan(body, x, p["encoder"]["layers"])
    else:
        n = jax.tree.leaves(p["encoder"]["layers"])[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda t: t[i], p["encoder"]["layers"])
            x = fn(lp, x)
    return apply_norm(cfg, p["encoder"]["final_norm"], x)


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------
def forward(cfg, p, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, p, tokens)
    x = maybe_shard(x, ("pod", "data"), None, None)

    if cfg.family == "vlm" and "vision_embeds" in batch:
        Np = cfg.vision_patches
        ve = batch["vision_embeds"].astype(cfg.act_dtype)
        x = jnp.concatenate([ve, x[:, Np:]], axis=1)  # stub anyres merge

    if cfg.family == "ssm":
        return _xlstm_forward(cfg, p, x)

    enc_kv = None
    if cfg.family == "audio":
        enc_out = _encode_audio(cfg, p, batch["frames"])
        # project enc K/V once per layer inside the stack via cross params;
        # pass the encoder output and project with shared decoder-side wk/wv
        enc_kv = enc_out
        x = x + _sinusoid(S, cfg.d_model, cfg.act_dtype)[None]

    positions = _positions(S)
    x = stack_forward(cfg, p["layers"], x, positions, enc_kv=enc_kv)
    x = apply_norm(cfg, p["final_norm"], x)
    return _head(cfg, p, x)


def loss_fn(cfg, p, batch):
    logits = forward(cfg, p, batch)
    labels = batch["labels"]
    if cfg.family == "vlm":
        Np = cfg.vision_patches
        labels = labels.at[:, :Np].set(-100)  # no loss on image positions
    loss = cross_entropy_loss(logits, labels, cfg.vocab_real)
    if cfg.n_routed_experts and cfg.moe_aux_weight:
        # aux loss on first layer's router as representative (cheap proxy)
        first = jax.tree.map(lambda t: t[0], p["layers"])
        x = _embed(cfg, p, batch["tokens"])
        loss = loss + cfg.moe_aux_weight * moe_aux_loss(first["moe"], x, cfg)
    return loss


# --------------------------------------------------------------------------
# xLSTM stack
# --------------------------------------------------------------------------
def _xlstm_forward(cfg, p, x, states=None):
    new_states = []
    for i, blk in enumerate(p["blocks"]):
        st = None if states is None else states[i]
        h = apply_norm(cfg, blk["pre_norm"], x)
        if cfg.block_types[i] == "m":
            y, ns = mlstm_forward(blk, h, cfg, state=st)
        else:
            y, ns = slstm_forward(blk, h, cfg, state=st)
        x = x + y
        new_states.append(ns)
    x = apply_norm(cfg, p["final_norm"], x)
    logits = _head(cfg, p, x)
    if states is None:
        return logits
    return logits, new_states


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_cache(cfg, batch, cache_len):
    if cfg.family == "ssm":
        B = batch
        states = []
        for t in cfg.block_types:
            if t == "m":
                H = cfg.n_heads
                hd = 2 * cfg.d_model // H
                states.append((
                    jnp.zeros((B, H, hd, hd), jnp.float32),
                    jnp.zeros((B, H, hd), jnp.float32),
                    jnp.zeros((B, H), jnp.float32),
                ))
            else:
                z = jnp.zeros((B, cfg.d_model), jnp.float32)
                states.append((z, z, z, z))
        return {"states": states, "len": jnp.zeros((batch,), jnp.int32)}
    return init_layer_caches(cfg, batch, cache_len)


def decode_step(cfg, p, cache, tokens, frames=None):
    """One-token decode. tokens (B,1). Returns (logits (B,1,V), new_cache)."""
    x = _embed(cfg, p, tokens)
    if cfg.family == "ssm":
        logits, states = _xlstm_forward(cfg, p, x, states=cache["states"])
        return logits, {"states": states, "len": cache["len"] + 1}
    enc_kv = None
    if cfg.family == "audio":
        if "cross_k" not in cache:  # no cached cross K/V: encode per step
            enc_kv = _encode_audio(cfg, p, frames)
        # sinusoidal position for the current step
        pos = cache["kv"]["len"][0]  # (B,) — same for all layers
        d = cfg.d_model
        i = jnp.arange(d // 2).astype(jnp.float32)
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)[None]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(x.dtype)
        x = x + pe[:, None, :]
    x, new_caches = stack_decode(cfg, p["layers"], x, cache, enc_kv=enc_kv)
    x = apply_norm(cfg, p["final_norm"], x)
    return _head(cfg, p, x), new_caches


def precompute_cross_kv(cfg, p, cache, frames):
    """Enc-dec serving: run the encoder ONCE per request and project every
    decoder layer's cross K/V into the cache (whisper §Perf fix)."""
    enc = _encode_audio(cfg, p, frames)
    B, T, d = enc.shape
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def proj(lp):
        k = (enc @ lp["cross"]["wk"]).reshape(B, T, Hkv, hd)
        v = (enc @ lp["cross"]["wv"]).reshape(B, T, Hkv, hd)
        return k, v

    ks, vs = jax.vmap(proj)(p["layers"])  # (L, B, T, Hkv, hd)
    cache = dict(cache)
    cache["cross_k"] = ks.astype(cfg.act_dtype)
    cache["cross_v"] = vs.astype(cfg.act_dtype)
    return cache
