"""Shared pytest config: markers + environment gating.

``pallas_compiled`` marks tests that exercise the *compiled* (non-interpret)
Pallas lowering. This container's CPU CI can only run Pallas in interpret
mode, so those tests skip cleanly unless either

* ``REPRO_PALLAS_INTERPRET=0`` — real TPU hardware, the compiled lowering
  is live (the same env toggle the kernel wrappers in
  ``repro.kernels.ops`` consume), or
* ``REPRO_PALLAS_FORCE_INTERPRET=1`` — the CI interpret leg: the marked
  tests *run*, but every ``pallas_call`` (including explicit
  ``interpret=False`` requests) is substituted with interpret mode by
  ``repro.kernels.config.resolve_interpret``. This exercises the compiled
  tests' call paths, schedules, and bitwise assertions on CPU; only the
  Mosaic lowering itself is mocked out.
"""
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "pallas_compiled: requires the compiled (non-interpret) Pallas "
        "lowering; skipped unless REPRO_PALLAS_INTERPRET=0 (TPU hardware) "
        "or REPRO_PALLAS_FORCE_INTERPRET=1 (CI interpret leg).",
    )
    config.addinivalue_line(
        "markers",
        "slow: long-running test (soaks, end-to-end sweeps); always in "
        "tier-1, deselectable with -m 'not slow' for quick local loops.",
    )


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "0":
        return  # hardware run: compiled-mode tests are live
    if os.environ.get("REPRO_PALLAS_FORCE_INTERPRET", "0") == "1":
        return  # CI interpret leg: compiled-mode tests run interpreted
    skip = pytest.mark.skip(
        reason="compiled Pallas lowering unavailable on CPU CI "
        "(set REPRO_PALLAS_INTERPRET=0 on TPU hardware, or "
        "REPRO_PALLAS_FORCE_INTERPRET=1 to run these in interpret mode)"
    )
    for item in items:
        if "pallas_compiled" in item.keywords:
            item.add_marker(skip)
