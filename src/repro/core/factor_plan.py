"""FactorPlan — the plan→compile→execute pipeline for numeric ILU(k).

This is the factorization-side twin of ``TriangularPlan``/``PrecondApply``
(PR 1): one host-side *plan* object per (matrix structure, k) that owns

* the **schedule**: pivot-op wavefronts from the shared vectorized Kahn
  scheduler (:func:`repro.core.planner.wavefront_schedule`). The unit is a
  single pivot application (one lower-pattern entry (j, i): divide by the
  pivot, subtract the scaled pivot-row tail); op (j, p) waits on the
  previous pivot of the same row and on the *last* op of its pivot row.
  Every round therefore executes at most one op per row, all on distinct
  independent rows — exact sizes, no dense (rows × pivots) padding, which
  is what keeps heavily-filled patterns (where max-pivots-per-row and
  rows-per-level both skew badly) from exploding the padded schedule.
* the **gathers**: a flat per-op destination-lane map
  (:func:`repro.core.planner.pivot_dst_flat`) so applying a pivot is two
  row gathers + one lane scatter — no ``searchsorted`` on device, and
  O(nnz(L)·W) plan memory total.
* the **engines**: compiled factorizer executables cached on the plan the
  way ``PrecondApply`` caches the triangular sweep — build once, reuse
  across refactorizations of the same structure (the serving pattern:
  values change, pattern does not).

Bit-compatibility contract (paper §VI): the chain edges force each row's
pivots into ascending column order, each op is an f32 divide then a
barriered multiply-then-subtract — exactly the oracle's arithmetic
(:func:`repro.core.numeric_ref.numeric_ilu_ref`). The wavefront schedule
only reorders ops that share no data (different rows, finalized pivot
rows), where no floating-point op can observe the difference, so the
factor values equal the oracle's bitwise.

Under a row reordering (``repro.core.ordering``) the plan is simply built
for the permuted matrix — the contract, the schedule, and the caches are
all relative to the matrix object handed in, so an ordered pipeline reuses
this module unchanged (the permuted matrix carries its own plan cache).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .planner import (
    ell_from_pattern,
    pivot_dst_flat,
    wavefront_schedule,
)
from .sparse import CSRMatrix, ILUPattern


@dataclasses.dataclass
class FactorPlan:
    """Round-major pivot-op schedule + cached engines.

    Shapes: ``NR`` rounds, ``MO`` ops per round (padded), ``W`` ELL width,
    ``n_ops = nnz(L)`` total pivot applications. Row id ``n`` is the
    scratch row; dst-map row ``n_ops`` is the all-dropped pad op.
    """

    n: int
    width: int  # W
    k: int
    n_ops: int
    n_rounds: int  # NR
    max_ops: int  # MO

    op_row: np.ndarray  # (NR, MO) int32 — reduced row j (n = pad)
    op_lane: np.ndarray  # (NR, MO) int32 — pivot lane p inside row j
    op_piv: np.ndarray  # (NR, MO) int32 — pivot row i (n = pad)
    op_dlane: np.ndarray  # (NR, MO) int32 — diagonal lane of row i
    op_dst: np.ndarray  # (NR, MO) int32 — row of dst_flat (n_ops = pad)
    dst_flat: np.ndarray  # (n_ops+1, W) int32 in [0, W]; W = dropped lane

    a_vals: np.ndarray  # (n+1, W) f32 — A on the pattern + zero scratch row
    cols: np.ndarray  # (n, W) int32 sentinel-padded (structure, host-side)
    row_len: np.ndarray  # (n,) int32
    a_scatter_lane: np.ndarray  # (a.nnz,) lane of each A entry (refactorize)
    csr_row: np.ndarray  # (pattern.nnz,) int64 — CSR flatten gather rows
    csr_lane: np.ndarray  # (pattern.nnz,) int64 — CSR flatten gather lanes

    # compiled executables, keyed by use_pallas — built once, reused across
    # refactorizations of the same structure (see .engine())
    _engines: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)
    _device_arrays: Optional[dict] = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def depth(self) -> int:
        return self.n_rounds

    def device_arrays(self) -> dict:
        """The jnp schedule arrays the factor sweep consumes (cached)."""
        if self._device_arrays is None:
            import jax.numpy as jnp

            self._device_arrays = {
                "op_row": jnp.asarray(self.op_row),
                "op_lane": jnp.asarray(self.op_lane),
                "op_piv": jnp.asarray(self.op_piv),
                "op_dlane": jnp.asarray(self.op_dlane),
                "op_dst": jnp.asarray(self.op_dst),
                "dst_flat": jnp.asarray(self.dst_flat),
            }
        return self._device_arrays

    def engine(self, use_pallas: bool = False):
        """Cached compiled factorizer: ``(n+1, W) A-values -> (n, W) factors``.

        Default is the XLA-compiled jnp engine: on this container the Pallas
        path runs in *interpret* mode, whose per-op Python dispatch is
        pathological for deep pivot-round scans; the two paths share one
        implementation and are bitwise identical, so the choice is pure
        speed. Flip to ``use_pallas=True`` on real TPU hardware
        (``REPRO_PALLAS_INTERPRET=0``)."""
        key = bool(use_pallas)
        if key not in self._engines:
            from .numeric_jax import make_wavefront_factorizer

            self._engines[key] = make_wavefront_factorizer(self, use_pallas=key)
        return self._engines[key]

    # -- host-side conveniences -------------------------------------------
    def scatter_values(self, a: CSRMatrix) -> np.ndarray:
        """New A values (same structure) -> (n+1, W) engine input."""
        vals = np.zeros_like(self.a_vals)
        rowlen = np.diff(a.indptr)
        row_of = np.repeat(np.arange(a.n, dtype=np.int64), rowlen)
        vals[row_of, self.a_scatter_lane] = a.data
        return vals

    def values_to_csr(self, vals_ell: np.ndarray) -> np.ndarray:
        """(n, W) padded factor values -> CSR-aligned flat values."""
        return np.asarray(vals_ell)[self.csr_row, self.csr_lane].astype(np.float32)

    def factorize(self, a: Optional[CSRMatrix] = None, use_pallas: bool = False) -> np.ndarray:
        """Run the cached engine; returns CSR-aligned f32 factor values.

        ``a=None`` reuses the values captured at plan build; passing a new
        matrix with the same structure refactorizes without replanning.
        """
        vals_in = self.a_vals if a is None else self.scatter_values(a)
        out = self.engine(use_pallas=use_pallas)(vals_in)
        return self.values_to_csr(np.asarray(out))


def build_factor_plan(a: CSRMatrix, pattern: ILUPattern) -> FactorPlan:
    """Vectorized host planning: pattern -> round-major pivot-op schedule."""
    n = pattern.n
    cols, vals, diag_pos, row_len, a_lane = ell_from_pattern(pattern, a, max(n, 1))
    W = cols.shape[1]

    # the pivot ops, in row-major ascending order = the lower pattern entries
    row_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(pattern.indptr))
    pos = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[row_of]
    lmask = pos < pattern.diag_ptr[row_of]
    o_row = row_of[lmask]  # reduced row j
    o_lane = pos[lmask]  # pivot lane p (== position among lower entries)
    o_piv = pattern.indices[lmask].astype(np.int64)  # pivot row i
    n_ops = int(o_row.size)
    npv = pattern.diag_ptr.astype(np.int64)  # ops per row
    op_start = np.zeros(n, np.int64)
    np.cumsum(npv[:-1], out=op_start[1:])

    # op DAG: (j,p) waits on (j,p-1) and on the last op of pivot row i
    opid = np.arange(n_ops, dtype=np.int64)
    chain = o_lane > 0
    cross = npv[o_piv] > 0
    src = np.concatenate([opid[chain] - 1, (op_start[o_piv] + npv[o_piv] - 1)[cross]])
    dst = np.concatenate([opid[chain], opid[cross]])
    sched = wavefront_schedule(src, dst, n_ops)  # (NR, MO), n_ops-padded
    NR, MO = sched.shape

    dst_flat = pivot_dst_flat(cols[:n], o_row, o_piv)  # (n_ops+1, W)

    pad = sched >= n_ops
    sid = np.minimum(sched, max(n_ops - 1, 0)).astype(np.int64)
    op_row = np.where(pad, n, o_row[sid]).astype(np.int32)
    op_lane = np.where(pad, 0, o_lane[sid]).astype(np.int32)
    op_piv = np.where(pad, n, o_piv[sid]).astype(np.int32)
    op_dlane = np.where(pad, 0, diag_pos[np.minimum(o_piv[sid], n - 1)]).astype(np.int32)
    op_dst = np.where(pad, n_ops, sid).astype(np.int32)

    a_vals = np.zeros((n + 1, W), dtype=np.float32)
    a_vals[:n] = vals[:n]

    rowlen = np.diff(pattern.indptr).astype(np.int64)
    csr_row = np.repeat(np.arange(n, dtype=np.int64), rowlen)
    csr_lane = np.arange(pattern.nnz, dtype=np.int64) - pattern.indptr[csr_row]

    return FactorPlan(
        n=n, width=W, k=pattern.k,
        n_ops=n_ops, n_rounds=NR, max_ops=MO,
        op_row=op_row, op_lane=op_lane, op_piv=op_piv,
        op_dlane=op_dlane, op_dst=op_dst, dst_flat=dst_flat,
        a_vals=a_vals, cols=cols[:n], row_len=row_len[:n],
        a_scatter_lane=a_lane, csr_row=csr_row, csr_lane=csr_lane,
    )


def _pattern_fingerprint(pattern: ILUPattern) -> tuple:
    """Content key for plan caching: two patterns with the same structure
    and levels produce the same plan, regardless of object identity (the
    public ``ilu()`` path builds a fresh pattern per call)."""
    import hashlib

    h = hashlib.sha1()
    h.update(pattern.indptr.tobytes())
    h.update(pattern.indices.tobytes())
    h.update(pattern.levels.tobytes())
    return (pattern.k, pattern.nnz, h.hexdigest())


def factor_plan_for(a: CSRMatrix, pattern: ILUPattern) -> FactorPlan:
    """Memoized :func:`build_factor_plan`: the plan (and its compiled
    engines) is cached on the matrix object, keyed by the pattern's
    *content* — repeated ``ilu()`` calls on the same matrix (each of which
    builds an equal-but-distinct pattern object) hit one plan and one
    compiled engine. Same lifetime rule as the solver-engine caches (dies
    with the matrix, so a stream of different matrices cannot grow device
    memory); entries per matrix are bounded by the distinct (k, rule)
    combinations used."""
    try:
        store = a.__dict__.setdefault("_factor_plans", {})
    except AttributeError:  # exotic container without __dict__
        return build_factor_plan(a, pattern)
    key = _pattern_fingerprint(pattern)
    plan = store.get(key)
    if plan is None:
        plan = store[key] = build_factor_plan(a, pattern)
    return plan
