"""Bit-deterministic sparse row arithmetic shared by references and kernels.

The paper's bit-compatibility guarantee holds only if every implementation
of the same reduction performs the same floating-point operations in the
same order. XLA breaks that silently in two ways:

* ``jnp.sum(..., axis=1)`` may lower to different reduction trees at
  different shapes / fusion contexts, and
* a ``mul`` feeding an ``add`` may be contracted into an FMA in one
  compilation and not another (observed on CPU between a monolithic jitted
  expression and the identical code inside a Pallas block).

:func:`masked_lane_sum` pins the contract: products are rounded to f32
through an ``optimization_barrier`` (no FMA contraction), then accumulated
left-to-right in lane order. Every sparse row reduction on the solve path —
the jnp references, the Pallas kernels, and the wavefront sweeps — goes
through this helper so they agree bitwise by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _register_barrier_batching() -> None:
    """Give ``optimization_barrier`` a vmap batching rule (jax<=0.4.3x ships
    none, which breaks every barriered reduction under ``vmap`` — e.g. the
    batched-RHS solver on the jnp fallback path). The barrier is an identity
    on values and shapes, so batching is just applying it to the batched
    operands with the dims passed through unchanged."""
    try:
        from jax.interpreters import batching
        from jax._src.lax import lax as _lax_src

        prim = getattr(jax.lax, "optimization_barrier_p", None) or getattr(
            _lax_src, "optimization_barrier_p", None
        )
        if prim is None or prim in batching.primitive_batchers:
            return

        def _rule(args, dims, **params):
            outs = prim.bind(*args, **params)
            if not prim.multiple_results:
                outs, dims = (outs,), dims[0] if isinstance(dims, tuple) else dims
                return outs[0], dims
            return outs, dims

        batching.primitive_batchers[prim] = _rule
    except Exception:  # pragma: no cover — newer jax may rename internals
        pass


_register_barrier_batching()


def pairwise_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Fixed-topology pairwise tree sum over the trailing axis.

    ``jnp.sum`` lowers to an XLA reduce whose accumulation order is
    implementation-defined per shape/layout — a vmapped (batched) solve and
    a single solve can round differently. This tree is built from plain
    elementwise adds with a topology fixed by the input length (zero-padded
    to the next power of two), so the bits are identical in every context:
    jit, vmap lanes, shard_map bodies. Cost is log2(n) elementwise adds.
    """
    n = x.shape[-1]
    p = 1 if n <= 1 else 1 << (n - 1).bit_length()
    if p != n:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, p - n)]
        x = jnp.pad(x, widths)
    while x.shape[-1] > 1:
        x = x[..., ::2] + x[..., 1::2]
    return x[..., 0]


def bitdot(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Bit-reproducible dot product: products rounded to f32 through an
    ``optimization_barrier`` (no FMA contraction), pairwise-tree summed."""
    return pairwise_sum(jax.lax.optimization_barrier(x * y))


def bitnorm(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-reproducible 2-norm over the trailing axis."""
    return jnp.sqrt(bitdot(x, x))


def barred(x: jnp.ndarray) -> jnp.ndarray:
    """Round an intermediate product to f32 before it feeds an add —
    blocks FMA contraction, which XLA applies (or not) per fusion context
    and would otherwise let a vmapped solve round differently from a
    single one."""
    return jax.lax.optimization_barrier(x)


_UNROLL = 16  # lanes unrolled per graph node; wider rows scan over chunks


def _lane_chunk(acc, cols, vals, gathered, limit):
    for lane in range(cols.shape[-1]):
        prod = jax.lax.optimization_barrier(vals[..., lane] * gathered[..., lane])
        acc = acc + jnp.where(cols[..., lane] < limit, prod, 0.0)
    return acc


def masked_lane_sum(
    cols: jnp.ndarray, vals: jnp.ndarray, gathered: jnp.ndarray, limit
) -> jnp.ndarray:
    """Sum ``vals * gathered`` over the trailing lane axis where ``cols < limit``.

    ``cols``/``vals``/``gathered`` share shape ``(..., W)``; returns ``(...,)``.
    Lane order is the accumulation order (matches a sequential sweep over a
    sorted sparse row); each product is barriered so it is rounded to f32
    before the add. Rows wider than ``_UNROLL`` lanes are processed as a
    ``lax.scan`` over fixed-size chunks — identical accumulation order
    (chunk-sequential, lane-sequential within a chunk), so the result is
    bitwise independent of the chunking, with graph size O(_UNROLL) instead
    of O(W).
    """
    w = cols.shape[-1]
    if w <= _UNROLL:
        return _lane_chunk(jnp.zeros(cols.shape[:-1], vals.dtype), cols, vals, gathered, limit)
    pad = (-w) % _UNROLL
    if pad:
        widths = [(0, 0)] * (cols.ndim - 1) + [(0, pad)]
        cols = jnp.pad(cols, widths, constant_values=int(limit))  # masked out
        vals = jnp.pad(vals, widths)
        gathered = jnp.pad(gathered, widths)
    nchunk = cols.shape[-1] // _UNROLL

    def to_chunks(x):
        x = x.reshape(x.shape[:-1] + (nchunk, _UNROLL))
        return jnp.moveaxis(x, -2, 0)  # (nchunk, ..., _UNROLL)

    def body(acc, inp):
        c, v, g = inp
        return _lane_chunk(acc, c, v, g, limit), None

    acc0 = jnp.zeros(cols.shape[:-1], vals.dtype)
    acc, _ = jax.lax.scan(body, acc0, (to_chunks(cols), to_chunks(vals), to_chunks(gathered)))
    return acc
