"""Ordering layer: permutation invariants, symbolic consistency, the
fusion model claim, and the 1/2/4-device bitwise contract.

The contract under reordering (DESIGN.md §Ordering): every pipeline stage
runs on the permuted system ``P A Pᵀ``, where the existing bitwise
contracts hold verbatim — so an ordered factorization must equal the
sequential oracle *of the permuted matrix* bit for bit, and ordered
sharded solves must equal the single-device permuted solve mapped back
through the permutation. Multi-device cases run in subprocesses (JAX
locks the host device count at first init).
"""
import os
import sys

import numpy as np
import pytest

from subproc import run_checked

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import matgen, poisson_2d
from repro.core.ordering import (
    choose_band_rows,
    fusion_aware_ordering,
    make_ordering,
    natural_ordering,
    permute_csr,
    permuted_system,
    rcm_ordering,
    sweep_comm_model,
)
from repro.core.symbolic import pilu1_symbolic, symbolic_ilu_k, symbolic_ilu_k_ref

SCRIPT = os.path.join(os.path.dirname(__file__), "multidevice_check.py")


def _orderings(a, n_devices=2, band_rows=8):
    return [
        rcm_ordering(a),
        fusion_aware_ordering(a, n_devices, band_rows=band_rows),
        fusion_aware_ordering(a, n_devices, band_rows=None),  # block ownership
    ]


# --------------------------------------------------------------------------
# permutation invariants
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,density,seed", [(64, 0.08, 0), (97, 0.06, 3)])
def test_permutation_round_trip(n, density, seed):
    a = matgen(n, density=density, seed=seed)
    for ordering in _orderings(a, n_devices=3, band_rows=5):
        assert np.array_equal(np.sort(ordering.perm), np.arange(n)), ordering.name
        assert np.array_equal(ordering.iperm[ordering.perm], np.arange(n))
        assert np.array_equal(ordering.perm[ordering.iperm], np.arange(n))
        x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
        assert np.array_equal(ordering.unpermute_vector(ordering.permute_vector(x)), x)
        # 2-D (batch) boundary
        xb = np.stack([x, 2 * x])
        assert np.array_equal(ordering.unpermute_vector(ordering.permute_vector(xb)), xb)


def test_permute_csr_matches_dense():
    a = matgen(48, density=0.1, seed=1)
    ordering = rcm_ordering(a)
    ap = permute_csr(a, ordering.perm)
    d = a.to_dense()
    assert np.array_equal(ap.to_dense(), d[np.ix_(ordering.perm, ordering.perm)])
    # permuting back is the inverse permutation
    back = permute_csr(ap, ordering.iperm)
    assert np.array_equal(back.to_dense(), d)
    # CSR invariants the plan builders rely on
    for j in range(ap.n):
        cols, _ = ap.row(j)
        assert np.all(np.diff(cols) > 0)
    assert ap.has_full_diagonal()


def test_make_ordering_resolution_and_cache():
    a = poisson_2d(8)
    assert make_ordering(a, None) is None
    assert make_ordering(a, "natural") is None
    assert make_ordering(a, natural_ordering(a.n)) is None
    assert make_ordering(a, np.arange(a.n)) is None  # identity array
    o1 = make_ordering(a, "rcm")
    assert o1.name == "rcm" and make_ordering(a, "rcm") is o1  # cached
    o2 = make_ordering(a, "fusion", n_devices=2, band_rows=8)
    assert o2.band_rows == 8
    perm = np.random.default_rng(0).permutation(a.n)
    o3 = make_ordering(a, perm)
    assert o3.name == "custom" and np.array_equal(o3.perm, perm)
    with pytest.raises(ValueError):
        make_ordering(a, "nested-dissection")
    # malformed user arrays must raise, not gather garbage downstream
    dup = np.arange(a.n)
    dup[1] = 0  # duplicate entry
    with pytest.raises(ValueError):
        make_ordering(a, dup)
    with pytest.raises(ValueError):
        make_ordering(a, np.arange(a.n - 1))  # wrong length
    with pytest.raises(ValueError):
        make_ordering(a, np.arange(1, a.n + 1))  # out of range
    # the permuted system is cached per permutation
    assert permuted_system(a, o1) is permuted_system(a, o1)


# --------------------------------------------------------------------------
# symbolic consistency on the permuted system
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2])
def test_symbolic_fill_of_permuted_matches_ref(k):
    """Symbolic ILU(k) of the permuted A == Algorithm-1 reference on the
    permuted pattern — the ordering layer hands Phase I a system it treats
    exactly like any other."""
    a = matgen(72, density=0.07, seed=11)
    for ordering in _orderings(a):
        ap = permuted_system(a, ordering)
        got = symbolic_ilu_k(ap, k) if k != 1 else pilu1_symbolic(ap)
        want = symbolic_ilu_k_ref(ap, k)
        assert np.array_equal(got.indptr, want.indptr), ordering.name
        assert np.array_equal(got.indices, want.indices), ordering.name
        assert np.array_equal(got.levels, want.levels), ordering.name
        assert np.array_equal(got.diag_ptr, want.diag_ptr), ordering.name


# --------------------------------------------------------------------------
# the fusion model claim (host-side, nothing compiled)
# --------------------------------------------------------------------------
def test_fusion_ordering_reduces_modeled_epochs_on_poisson():
    """The tentpole claim, on the 2-D Poisson fixture at D=2: the
    fusion-aware ordering's modeled collective-epoch count is no worse
    than natural order (measured: 128 -> 4 at n=1024; asserted on the
    smaller fixture with strict improvement)."""
    a = poisson_2d(16)  # n = 256
    d, r = 2, 8
    nat = sweep_comm_model(pilu1_symbolic(a), r, d)
    ordering = fusion_aware_ordering(a, d, band_rows=r)
    fus = sweep_comm_model(pilu1_symbolic(permuted_system(a, ordering)), r, d)
    assert fus["epochs"] <= nat["epochs"]
    assert fus["epochs"] < nat["epochs"]  # Poisson fuses massively
    assert fus["collectives_per_apply"] <= nat["collectives_per_apply"]


def test_choose_band_rows_scores_candidates():
    a = poisson_2d(12)
    best, scores = choose_band_rows(a, k=1, n_devices=2, candidates=(8, 36))
    assert set(scores) == {8, 36}
    assert best.name == "fusion" and best.band_rows in scores
    best_rec = scores[best.band_rows]
    for rec in scores.values():
        assert (best_rec["epochs"], best_rec["bytes_per_apply"]) <= (
            rec["epochs"], rec["bytes_per_apply"])


# --------------------------------------------------------------------------
# single-device bitwise contract through the public API
# --------------------------------------------------------------------------
@pytest.mark.parametrize("spec", ["rcm", "fusion"])
def test_ordered_factorization_bitwise_oracle_on_permuted(spec):
    from repro.core import numeric_ilu_ref
    from repro.core.api import ilu

    a = matgen(80, density=0.07, seed=5)
    fact = ilu(a, 1, ordering=spec)
    assert fact.ordering is not None and fact.ordering.name == spec
    ap = permuted_system(a, fact.ordering)
    want = numeric_ilu_ref(ap, pilu1_symbolic(ap))
    assert np.array_equal(fact.vals.view(np.int32), want.view(np.int32))


@pytest.mark.parametrize("spec", ["rcm", "fusion"])
def test_ordered_solve_boundary(spec):
    """solve_with_ilu(ordering=...) == the manual permute→solve→unpermute,
    bitwise, for single and batched right-hand sides — and the returned x
    solves the *original* system."""
    from repro.core.solvers import solve_with_ilu

    a = poisson_2d(10)
    rng = np.random.default_rng(2)
    b = rng.standard_normal(a.n).astype(np.float32)
    bs = rng.standard_normal((3, a.n)).astype(np.float32)

    res, fact = solve_with_ilu(a, b, k=1, tol=1e-6, use_pallas=False, ordering=spec)
    ordering = fact.ordering
    ap = permuted_system(a, ordering)
    ref, _ = solve_with_ilu(ap, b[ordering.perm], k=1, tol=1e-6, use_pallas=False)
    assert res.converged and res.iterations == ref.iterations
    assert np.array_equal(res.x.view(np.int32), ref.x[ordering.iperm].view(np.int32))
    r = b - a.to_dense() @ res.x
    assert np.linalg.norm(r) <= 1e-5 * np.linalg.norm(b) * 10

    rs, _ = solve_with_ilu(a, bs, k=1, tol=1e-6, use_pallas=False, ordering=spec)
    refs, _ = solve_with_ilu(ap, bs[:, ordering.perm], k=1, tol=1e-6, use_pallas=False)
    for got, want in zip(rs, refs):
        assert np.array_equal(got.x.view(np.int32), want.x[ordering.iperm].view(np.int32))


def test_solve_sharded_rejects_mismatched_fact_ordering():
    """A caller-supplied fact factored under one row order must not be
    silently combined with a different `ordering=` (matvec and precond
    would run on different systems) — and the fact must not be stamped."""
    from repro.core.solvers import solve_sharded

    a = poisson_2d(8)
    b = np.random.default_rng(4).standard_normal(a.n).astype(np.float32)
    _, nat_fact = solve_sharded(a, b, k=1, band_rows=16, tol=1e-6)
    assert nat_fact.ordering is None
    with pytest.raises(ValueError, match="different row ordering"):
        solve_sharded(a, b, k=1, band_rows=16, tol=1e-6, fact=nat_fact, ordering="rcm")
    assert nat_fact.ordering is None  # unstamped: fact.solve stays natural
    # the legitimate round-trips still work: adopt, or pass the same spec
    _, of = solve_sharded(a, b, k=1, band_rows=16, tol=1e-6, ordering="rcm")
    assert of.ordering is not None
    r1, _ = solve_sharded(a, b, k=1, band_rows=16, tol=1e-6, fact=of)
    r2, _ = solve_sharded(a, b, k=1, band_rows=16, tol=1e-6, fact=of, ordering="rcm")
    assert np.array_equal(r1.x.view(np.int32), r2.x.view(np.int32))


def test_ordered_fact_solve_boundary():
    from repro.core.api import ilu

    a = poisson_2d(8)
    b = np.random.default_rng(3).standard_normal(a.n).astype(np.float32)
    fact = ilu(a, 1, ordering="rcm")
    ref = ilu(permuted_system(a, fact.ordering), 1)
    got = fact.solve(b)
    want = fact.ordering.unpermute_vector(ref.solve(fact.ordering.permute_vector(b)))
    assert np.array_equal(np.asarray(got).view(np.int32), np.asarray(want).view(np.int32))


# --------------------------------------------------------------------------
# 1/2/4-device bitwise contract (subprocess: device count locks at init)
# --------------------------------------------------------------------------
def _run_ordered(devices, ordering, n=96, k=1, band_rows=8, broadcast="psum"):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"  # never probe for a real TPU
    rc, out, err = run_checked(
        [sys.executable, SCRIPT, str(n), str(k), str(band_rows), broadcast,
         "--ordering", ordering],
        env=env, timeout=300,
    )
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "bitwise-equal" in out


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_ordered_sharded_solve_bitwise(devices):
    """Sharded ordered solves == the single-device permuted path, bitwise,
    on 1/2/4 devices (single and bucketed multi-RHS)."""
    _run_ordered(devices, "fusion")


def test_ordered_sharded_solve_bitwise_rcm():
    _run_ordered(2, "rcm", k=2)
