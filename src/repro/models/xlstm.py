"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory + recurrence).

Faithful-in-structure implementation of arXiv:2405.04517 at xlstm-125m scale:

* mLSTM — per head h: matrix memory C ∈ R^{hd×hd}, normalizer n ∈ R^{hd},
  exponential input gate with max-stabilizer m:
      m_t = max(f̃ + m_{t-1}, ĩ)
      C_t = exp(f̃ + m_{t-1} - m_t) C_{t-1} + exp(ĩ - m_t) v k^T
      y_t = C_t q / max(|n_t·q|, 1)
  Recurrence is a `lax.scan`; decode is one step (O(hd²) state — the reason
  xlstm-125m runs the 512k shape).
* sLSTM — scalar memory with per-head block-diagonal recurrent weights on
  h_{t-1} feeding all four gates.

Simplifications (DESIGN.md §8): the pre-mLSTM causal conv is dropped; block
up/down projection factor fixed at 2 (mLSTM) and 4/3-free cell-only sLSTM.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import KeyGen, dense_init, rms_norm


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def init_mlstm(key, cfg):
    kg = KeyGen(key)
    d = cfg.d_model
    H = cfg.n_heads
    di = 2 * d
    hd = di // H
    dt = cfg.param_dtype
    return {
        "up": dense_init(kg(), (d, 2 * di), dt),  # [mlstm input | output gate]
        "wq": dense_init(kg(), (di, di), dt),
        "wk": dense_init(kg(), (di, di), dt),
        "wv": dense_init(kg(), (di, di), dt),
        "w_if": dense_init(kg(), (di, 2 * H), dt, scale=0.01),
        "norm": jnp.ones((di,), dt),
        "down": dense_init(kg(), (di, d), dt, scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _mlstm_scan(q, k, v, i_pre, f_pre, state):
    """q,k,v: (B,S,H,hd); i_pre,f_pre: (B,S,H). state: (C,n,m)."""
    B, S, H, hd = q.shape

    def step(carry, inp):
        C, n, m = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        qt, kt, vt, it, ft = inp
        logf = jax.nn.log_sigmoid(ft)  # stable forget in log space
        m_new = jnp.maximum(logf + m, it)
        fg = jnp.exp(logf + m - m_new)[..., None, None]
        ig = jnp.exp(it - m_new)[..., None, None]
        C = fg * C + ig * jnp.einsum("bhd,bhe->bhde", vt, kt)
        n = fg[..., 0, 0][..., None] * n + ig[..., 0, 0][..., None] * kt
        num = jnp.einsum("bhde,bhe->bhd", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))[..., None], 1.0)
        return (C, n, m_new), num / den

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_pre, f_pre))
    from .scan_utils import chunked_remat_scan

    state, ys = chunked_remat_scan(step, state, xs)
    return state, jnp.moveaxis(ys, 0, 1)  # (B,S,H,hd)


def mlstm_forward(p, x, cfg, state=None):
    B, S, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    hd = di // H
    up = x @ p["up"]
    u, og = up[..., :di], up[..., di:]
    q = (u @ p["wq"]).reshape(B, S, H, hd).astype(jnp.float32) / math.sqrt(hd)
    k = (u @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (u @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    gif = (u @ p["w_if"]).astype(jnp.float32)
    i_pre, f_pre = gif[..., :H], gif[..., H:]
    if state is None:
        state = (
            jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.zeros((B, H), jnp.float32),
        )
    state, y = _mlstm_scan(q, k, v, i_pre, f_pre, state)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(og)
    return y @ p["down"], state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def init_slstm(key, cfg):
    kg = KeyGen(key)
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    dt = cfg.param_dtype
    return {
        "w_gates": dense_init(kg(), (d, 4 * d), dt),  # i,f,z,o from x
        "r_gates": dense_init(kg(), (H, hd, 4 * hd), dt, scale=1.0 / math.sqrt(hd)),
        "norm": jnp.ones((d,), dt),
        "down": dense_init(kg(), (d, d), dt, scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _slstm_scan(gx, r, state, H, hd):
    """gx: (B,S,4d); r: (H,hd,4hd); state: (c,n,h,m) each (B,d)-ish f32."""

    def step(carry, g_t):
        c, n, h, m = carry  # (B,d),(B,d),(B,d),(B,d)
        B = g_t.shape[0]
        hr = h.reshape(B, H, hd)
        rec = jnp.einsum("bhd,hde->bhe", hr, r).reshape(B, H * hd * 4)
        # interleave per-head 4*hd back to 4 gates of d
        rec = rec.reshape(B, H, 4, hd).transpose(0, 2, 1, 3).reshape(B, 4 * H * hd)
        g = g_t + rec
        d_ = H * hd
        i_pre, f_pre, z_pre, o_pre = g[:, :d_], g[:, d_:2*d_], g[:, 2*d_:3*d_], g[:, 3*d_:]
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        ig = jnp.exp(i_pre - m_new)
        fg = jnp.exp(logf + m - m_new)
        z = jnp.tanh(z_pre)
        c = fg * c + ig * z
        n = fg * n + ig
        h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    xs = jnp.moveaxis(gx, 1, 0)
    from .scan_utils import chunked_remat_scan

    state, ys = chunked_remat_scan(step, state, xs)
    return state, jnp.moveaxis(ys, 0, 1)


def slstm_forward(p, x, cfg, state=None):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    gx = (x @ p["w_gates"]).astype(jnp.float32)
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = (z, z, z, z)
    state, y = _slstm_scan(gx, p["r_gates"].astype(jnp.float32), state, H, hd)
    y = rms_norm(y.astype(x.dtype), p["norm"])
    return y @ p["down"], state
