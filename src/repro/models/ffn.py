"""Feed-forward layers: dense SwiGLU/GELU MLP and Mixture-of-Experts.

MoE uses **sort-based dropping dispatch**: assignments are argsorted by
expert, positioned by a cumulative-count trick, and gathered into an
(E, C, d) buffer — gathers/scatters only, so `cost_analysis` FLOPs reflect
real arithmetic (one-hot-matmul dispatch would inflate the compute roofline
term with fake T·E·C·d FLOPs — DESIGN.md §5).

Sharding: experts are laid on the ``model`` axis when E % tp == 0 (EP);
otherwise each expert's hidden dim is sharded (expert-TP) — qwen2-moe's 60
experts on a 16-way axis take that path.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import KeyGen, dense_init, gelu, maybe_shard, mesh_axis_size


# --------------------------------------------------------------------------
# dense MLP
# --------------------------------------------------------------------------
def init_mlp(key, cfg, d_ff: Optional[int] = None):
    kg = KeyGen(key)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if cfg.mlp_act == "swiglu":
        return {
            "w_gate": dense_init(kg(), (d, f), dt),
            "w_up": dense_init(kg(), (d, f), dt),
            "w_down": dense_init(kg(), (f, d), dt, scale=out_scale),
        }
    return {
        "w_up": dense_init(kg(), (d, f), dt),
        "w_down": dense_init(kg(), (f, d), dt, scale=out_scale),
    }


def mlp(p, x, cfg):
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = gelu(x @ p["w_up"])
    h = maybe_shard(h, ("pod", "data"), None, "model")
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------
def padded_experts(cfg) -> int:
    pad = max(cfg.moe_expert_pad, 1)
    return -(-cfg.n_routed_experts // pad) * pad


def init_moe(key, cfg):
    kg = KeyGen(key)
    d = cfg.d_model
    E, f = padded_experts(cfg), cfg.d_expert
    dt = cfg.param_dtype
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "gate": dense_init(kg(), (d, E), jnp.float32),  # router in f32
        "w_gate": dense_init(kg(), (E, d, f), dt),
        "w_up": dense_init(kg(), (E, d, f), dt),
        "w_down": dense_init(kg(), (E, f, d), dt, scale=out_scale),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(kg(), cfg, d_ff=cfg.n_shared_experts * cfg.d_expert)
    return p


def _route_group(xt, gate, cfg, C):
    """Route one dp-group's tokens: returns (tok_for_slot (E*C,), sorted_t,
    sorted_w, keep, slot). Pure gather/scatter bookkeeping — no matmul FLOPs."""
    T, d = xt.shape
    E, K = padded_experts(cfg), cfg.moe_top_k
    logits = xt.astype(jnp.float32) @ gate  # (T, E) — E includes padding
    if E > cfg.n_routed_experts:  # padded experts are unroutable
        logits = jnp.where(jnp.arange(E) >= cfg.n_routed_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)  # (T, K)
    if cfg.moe_norm_topk:
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    flat_e = topi.reshape(-1)  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - starts[sorted_e]
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)  # E*C = drop slot
    tok_for_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        sorted_t.astype(jnp.int32), mode="drop"
    )[: E * C]
    return tok_for_slot, sorted_t, sorted_w, keep, slot


def moe_ffn(p, x, cfg):
    """x: (B, S, d) -> (B, S, d). Top-k routing, **local per dp-group**.

    Tokens are grouped by their data-parallel shard and each group routes
    into its own capacity slice (GShard/Switch-style local dispatch): every
    gather/scatter in the dispatch and combine is then shard-local, so the
    partitioner emits no token all-gathers (§Perf hillclimb #1 — this
    replaced a 13.3 TB/device all-reduce bill on qwen2-moe train_4k).
    Capacity dropping becomes per-group, the standard production semantics.
    """
    B, S, d = x.shape
    T = B * S
    E, K, f = padded_experts(cfg), cfg.moe_top_k, cfg.d_expert
    G = mesh_axis_size("pod") * mesh_axis_size("data")
    while G > 1 and (B % G or (T // G) < 1):
        G //= 2
    Tg = T // G
    xt = x.reshape(T, d)
    xg = x.reshape(G, Tg, d)
    xg = maybe_shard(xg, ("pod", "data"), None, None)

    C = max(int(math.ceil(Tg * K / E * cfg.moe_capacity_factor)), 1)
    route = jax.vmap(lambda xx: _route_group(xx, p["gate"], cfg, C))
    tok_for_slot, sorted_t, sorted_w, keep, slot = route(xg)

    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad, tok_for_slot[..., None].astype(jnp.int32), axis=1
    ).reshape(G, E, C, d)
    ep = E % mesh_axis_size("model") == 0  # EP vs expert-TP (DESIGN.md §5)
    dp = ("pod", "data")
    xe = maybe_shard(xe, dp, "model" if ep else None, None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["w_up"]
    )
    h = maybe_shard(h, dp, "model" if ep else None, None, None if ep else "model")
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G, E, C, d)
    ye = maybe_shard(ye, dp, "model" if ep else None, None, None)

    # combine: gather each kept assignment's expert output, weight, segment-sum
    ye_flat = ye.reshape(G, E * C, d)

    def combine(yef, keep_g, slot_g, w_g, t_g):
        y_assign = jnp.where(
            keep_g[:, None], yef[jnp.minimum(slot_g, E * C - 1)], 0.0
        ) * w_g[:, None].astype(yef.dtype)
        return jnp.zeros((Tg, d), x.dtype).at[t_g].add(y_assign.astype(x.dtype), mode="drop")

    y = jax.vmap(combine)(ye_flat, keep, slot, sorted_w, sorted_t)
    y = maybe_shard(y, dp, None, None).reshape(T, d)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], xt, cfg)
    return y.reshape(B, S, d)


def moe_aux_loss(p, x, cfg):
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt.astype(jnp.float32) @ p["gate"])[:, : cfg.n_routed_experts]
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_routed_experts, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return cfg.n_routed_experts * jnp.sum(frac * mean_p)
