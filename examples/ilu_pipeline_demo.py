"""TOP-ILU on a simulated 8-device ring: the paper's Fig-4 pipeline.

Shows static round-robin band ownership, the psum vs explicit-ring
broadcast variants, and verifies bit-compatibility of both.

    python examples/ilu_pipeline_demo.py          # spawns itself with 8 devices
"""
import os
import subprocess
import sys

if os.environ.get("_ILU_DEMO_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_ILU_DEMO_CHILD"] = "1"
    sys.exit(subprocess.run([sys.executable, __file__], env=env).returncode)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro.core import matgen, numeric_ilu_ref, pilu1_symbolic
from repro.core.planner import make_plan
from repro.core.top_ilu import topilu_numeric


def main():
    devs = jax.devices()
    print(f"devices: {len(devs)} (simulated ring)")
    n, band_rows = 512, 16
    a = matgen(n, density=0.02, seed=3)
    pat = pilu1_symbolic(a)  # PILU(1): zero-communication symbolic phase
    plan = make_plan(a, pat, band_rows=band_rows, n_devices=len(devs))
    print(f"n={n} nnz={pat.nnz}  bands={plan.n_bands} x {band_rows} rows, "
          f"round-robin over {len(devs)} devices")

    want = numeric_ilu_ref(a, pat)
    for broadcast in ("psum", "ring"):
        t0 = time.perf_counter()
        got = topilu_numeric(a, pat, band_rows=band_rows, broadcast=broadcast)
        dt = time.perf_counter() - t0
        ok = np.array_equal(got.view(np.int32), want.view(np.int32))
        print(f"broadcast={broadcast:5s}: {dt*1e3:7.1f} ms  "
              f"bitwise-equal={'YES' if ok else 'NO'}")
        assert ok
    print("\nThe psum broadcast lowers to XLA's ring all-reduce — the same "
          "aggregate-bandwidth pipeline the paper hand-builds (Fig 4).")


if __name__ == "__main__":
    main()
