"""Production mesh builders.

``make_production_mesh()`` is a *function* (not module-level state) so
importing this module never touches jax device state. The single-pod mesh
is 16x16 = 256 chips (TPU v5e pod); multi-pod adds a leading ``pod`` axis
(2 pods = 512 chips) that carries pure data parallelism over DCN — the
modern analogue of the paper's inter-cluster "edge nodes" (§V-F).
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.compat import make_mesh


def _mesh(shape, axes):
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)} (set XLA_FLAGS)"
    return make_mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return _mesh((data, model), ("data", "model"))


def make_band_mesh(n_devices: int = 0):
    """1-D ``(band,)`` mesh for the distributed TOP-ILU pipeline
    (DESIGN.md §5). ``n_devices=0`` takes every available device; bands are
    owned round-robin over this axis (paper §IV-D) and the factorization
    value state is sharded along it."""
    d = n_devices or len(jax.devices())
    return _mesh((d,), ("band",))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_size(mesh) -> int:
    s = mesh_axis_sizes(mesh)
    return s.get("data", 1) * s.get("pod", 1)


def tp_size(mesh) -> int:
    return mesh_axis_sizes(mesh).get("model", 1)
