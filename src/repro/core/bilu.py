"""BILU(k): Block-ILU with fill levels on the 128x128 *tile* graph.

The beyond-paper TPU adaptation (DESIGN.md §3). The paper's scalar
row-merge is memory-bound on any modern machine (§II: "accesses lots of
memory while using relatively little floating-point arithmetic"). On a TPU
the fix is structural: promote the sparsity pattern to MXU-shaped tiles, so
the numeric phase becomes dense tile GEMMs/TRSMs executed by the Pallas
kernels in ``repro.kernels``:

* symbolic phase — *reuses the paper's Algorithm 1 verbatim* on the tile
  adjacency matrix (a tile is an "entry"; levels/fill rules unchanged),
* numeric phase — block right-looking LU restricted to the tile pattern:
    pivot I:  A_II = L_II U_II            (in-tile dense LU, no pivoting)
              L_JI = A_JI U_II^{-1}       (Pallas trsm_right_upper)
              U_IT = L_II^{-1} A_IT       (Pallas trsm_left_unit_lower)
              A_JT -= L_JI @ U_IT         (Pallas panel_update)

BILU(k) is a *different* (denser) preconditioner than scalar ILU(k) — it
keeps every scalar ILU(k) entry plus tile padding, so it is at least as
strong; it is NOT bit-compatible with the scalar algorithm and is recorded
separately in EXPERIMENTS.md §Perf. Band/TOP-ILU parallelization applies
unchanged with "tile row-block" substituted for "row" — the band pipeline
ships finished tile rows instead of scalar rows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .sparse import CSRMatrix, ILUPattern
from .symbolic import symbolic_ilu_k


@dataclasses.dataclass
class BILUFactorization:
    n: int
    bs: int
    n_tiles: int  # tiles per side
    tile_pattern: ILUPattern  # pattern over the tile graph
    tiles: np.ndarray  # (T, bs, bs) f32 — L (strict lower)/U (upper) per tile
    tile_index: Dict[Tuple[int, int], int]

    def to_dense_lu(self):
        """Materialize dense L (unit diag) and U — tests only."""
        nt, bs = self.n_tiles, self.bs
        nd = nt * bs
        L = np.eye(nd, dtype=np.float32)
        U = np.zeros((nd, nd), dtype=np.float32)
        for (i, j), t in self.tile_index.items():
            blk = self.tiles[t]
            ys, xs = i * bs, j * bs
            if i > j:
                L[ys : ys + bs, xs : xs + bs] = blk
            elif i < j:
                U[ys : ys + bs, xs : xs + bs] = blk
            else:
                L[ys : ys + bs, xs : xs + bs] = np.tril(blk, -1) + np.eye(bs, dtype=np.float32)
                U[ys : ys + bs, xs : xs + bs] = np.triu(blk)
        return L[: self.n, : self.n], U[: self.n, : self.n]


def tile_adjacency(a: CSRMatrix, bs: int) -> CSRMatrix:
    """Tile-level adjacency matrix (1 where any scalar entry falls in tile)."""
    nt = -(-a.n // bs)
    import scipy.sparse as sp

    rows = np.repeat(np.arange(a.n), np.diff(a.indptr)) // bs
    cols = a.indices // bs
    m = sp.csr_matrix(
        (np.ones(len(cols), np.float32), (rows, cols.astype(np.int64))), shape=(nt, nt)
    )
    m = m + sp.eye(nt, format="csr", dtype=np.float32)  # diagonal tiles always present
    m.sum_duplicates()
    m.data[:] = 1.0
    return CSRMatrix.from_scipy(m)


def _lu_nopiv(tile):
    """Dense in-tile LU without pivoting (diagonal dominance assumption).
    Returns the packed tile: strict-lower = L, upper = U."""
    bs = tile.shape[0]

    def col(c, t):
        piv = t[c, c]
        col_mask = (jnp.arange(bs) > c).astype(t.dtype)
        l = (t[:, c] / piv) * col_mask
        t = t.at[:, c].set(jnp.where(jnp.arange(bs) > c, l, t[:, c]))
        row = jnp.where(jnp.arange(bs) > c, t[c, :], 0.0)
        t = t - jnp.outer(l, row)
        # outer subtracted the pivot column too (row[c]=0 -> no) and rows <= c (l=0 -> no)
        return t

    return jax.lax.fori_loop(0, bs, col, tile)


def bilu(a: CSRMatrix, k: int, bs: int = 32, rule: str = "sum") -> BILUFactorization:
    """Block-ILU(k) factorization on bs-aligned tiles."""
    adj = tile_adjacency(a, bs)
    tpat = symbolic_ilu_k(adj, k, rule=rule)  # Algorithm 1, tile granularity
    nt = adj.n
    # tile pool
    index: Dict[Tuple[int, int], int] = {}
    for i in range(nt):
        cols, _ = tpat.row(i)
        for c in cols:
            index[(i, int(c))] = len(index)
    tiles = np.zeros((len(index), bs, bs), dtype=np.float32)
    # scatter A (padded rows/cols get identity diagonal to stay nonsingular)
    for j in range(a.n):
        cols, vals = a.row(j)
        ti = j // bs
        for c, v in zip(cols, vals):
            tiles[index[(ti, int(c) // bs)], j % bs, int(c) % bs] = v
    for j in range(a.n, nt * bs):
        tiles[index[(j // bs, j // bs)], j % bs, j % bs] = 1.0

    lu_nopiv = jax.jit(_lu_nopiv)
    tiles_j = [jnp.asarray(t) for t in tiles]

    for i in range(nt):  # pivot tile-row, ascending (right-looking)
        di = index[(i, i)]
        tiles_j[di] = lu_nopiv(tiles_j[di])
        u_ii = jnp.triu(tiles_j[di])
        l_ii = jnp.tril(tiles_j[di], -1) + jnp.eye(bs, dtype=jnp.float32)
        urow_cols, _ = tpat.row(i)
        urow = [int(c) for c in urow_cols if c > i]
        # column panel below the pivot: all J > i with (J, i) in pattern
        below = [j for j in range(i + 1, nt) if (j, i) in index]
        for t in urow:
            tiles_j[index[(i, t)]] = kops.trsm_left_unit_lower(l_ii, tiles_j[index[(i, t)]])
        for jrow in below:
            lj = kops.trsm_right_upper(tiles_j[index[(jrow, i)]], u_ii)
            tiles_j[index[(jrow, i)]] = lj
            for t in urow:
                key = (jrow, t)
                if key in index:  # fill outside the level-k tile pattern is dropped
                    key_idx = index[key]
                    tiles_j[key_idx] = kops.panel_update(
                        tiles_j[key_idx], lj, tiles_j[index[(i, t)]]
                    )
    out = np.stack([np.asarray(t) for t in tiles_j])
    return BILUFactorization(
        n=a.n, bs=bs, n_tiles=nt, tile_pattern=tpat, tiles=out, tile_index=index
    )


def bilu_scalar_pattern(fact: BILUFactorization) -> np.ndarray:
    """Dense boolean mask of the scalar positions BILU keeps — for tests."""
    nd = fact.n_tiles * fact.bs
    m = np.zeros((nd, nd), dtype=bool)
    for (i, j) in fact.tile_index:
        m[i * fact.bs : (i + 1) * fact.bs, j * fact.bs : (j + 1) * fact.bs] = True
    return m[: fact.n, : fact.n]
