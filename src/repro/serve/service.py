"""The solve service: admission → coalesce → bucketed solve → scatter.

:class:`SolveService` wires the serve layer together around a synchronous
tick loop (the test-harness-friendly shape — a deployment would run
:meth:`tick` on a dispatcher thread):

* :meth:`submit` validates a request, pins the target matrix's *current*
  value binding, and enqueues; every malformed input fails that one
  request with a structured :class:`SolveResponse` — nothing malformed
  ever reaches a batch.
* :meth:`tick` drains the queue, coalesces compatible requests across
  tenants (``coalescer.coalesce``), runs one bucketed multi-RHS solve per
  batch on the pre-warmed engine, and scatters per-lane results back into
  per-request responses (per-request convergence from per-lane residual
  freezing; per-request tolerance rides as a vmapped lane argument).
* :meth:`warmup` AOT-compiles every resident engine for every bucket and
  pins the compile baseline — after it returns, a flat
  ``compiles.after_warmup`` is the service's core SLO invariant.

Bit-compat bar: a response's ``x`` is bitwise identical to solving that
request alone (`solve_with_ilu` / `solve_sharded` on the same values) —
regardless of which batch, bucket, or lane position it was coalesced into.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.sparse import CSRMatrix

from .admission import (
    SOLVE_FAILED,
    AdmissionError,
    AdmissionQueue,
    SolveRequest,
    SolveResponse,
    validate_request,
)
from .cache import PlanCache
from .coalescer import coalesce
from .engine import DEFAULT_MAXITER, DEFAULT_RESTART, ServeEngine, ShardedServeEngine
from .metrics import ServiceMetrics


@dataclasses.dataclass
class ServeConfig:
    """Service-wide knobs (per-matrix overrides ride on ``register_matrix``)."""

    cache_capacity: int = 8
    max_queue_depth: int = 4096
    tick_drain: Optional[int] = None      # max requests drained per tick
    k: int = 1
    restart: int = DEFAULT_RESTART
    maxiter: int = DEFAULT_MAXITER
    precond_method: str = "sweep"
    use_pallas: bool = True
    buckets: Optional[Sequence[int]] = None
    sharded: bool = False                 # ShardedServeEngine over solve_sharded
    mesh: object = None                   # sharded only
    band_rows: int = 32                   # sharded only


class SolveService:
    """Multi-tenant front end over the warm bucketed solver stack."""

    def __init__(self, config: Optional[ServeConfig] = None, **kw):
        self.config = config or ServeConfig(**kw)
        self.metrics = ServiceMetrics()
        self.cache = PlanCache(capacity=self.config.cache_capacity,
                               metrics=self.metrics,
                               engine_factory=self._make_engine)
        self.queue = AdmissionQueue(max_depth=self.config.max_queue_depth)
        self._warmed = False

    # -- engine construction -------------------------------------------------
    def _make_engine(self, a, pattern, vals_csr, **knobs):
        cfg = self.config
        common = dict(restart=cfg.restart, maxiter=cfg.maxiter,
                      precond_method=cfg.precond_method, buckets=cfg.buckets)
        common.update(knobs)
        if cfg.sharded:
            return ShardedServeEngine(a, pattern, vals_csr, mesh=cfg.mesh,
                                      band_rows=cfg.band_rows, k=cfg.k, **common)
        return ServeEngine(a, pattern, vals_csr, use_pallas=cfg.use_pallas, **common)

    # -- tenant-facing surface -----------------------------------------------
    def register_matrix(self, matrix_id: str, a: CSRMatrix,
                        k: Optional[int] = None) -> int:
        """Make a matrix solvable; returns the initial value version."""
        entry = self.cache.register(matrix_id, a,
                                    k=self.config.k if k is None else k)
        return entry.version

    def update_matrix_values(self, matrix_id: str, data: np.ndarray,
                             background: bool = True):
        """Push new values (same structure): background refactorization +
        atomic binding swap; other tenants' solves proceed throughout."""
        return self.cache.update_values(matrix_id, data, background=background)

    def submit(self, tenant: str, matrix_id: str, b, tol: float = 1e-5):
        """Admit one request. Returns the pending :class:`SolveRequest`, or a
        failed :class:`SolveResponse` if any admission check rejects — a
        malformed request costs its tenant one error, nobody else anything."""
        try:
            bv = validate_request(tenant, matrix_id, b, tol,
                                  self.cache.dim_of(matrix_id))
            entry, binding = self.cache.acquire(matrix_id)  # the pin
            req = SolveRequest(tenant=tenant, matrix_id=matrix_id,
                               b=bv, tol=float(tol), binding=(entry, binding))
            try:
                self.queue.push(req)
            except AdmissionError:
                self.cache.release(matrix_id)
                raise
        except AdmissionError as e:
            self.metrics.record_admission(False, e.reason)
            # rejects count under rejected_by_reason, not the latency
            # histograms — a 0-latency observation would skew every quantile
            return SolveResponse(
                request_id=-1, tenant=tenant, matrix_id=matrix_id, ok=False,
                error=e.detail, error_reason=e.reason)
        self.metrics.record_admission(True)
        return req

    # -- the tick loop ---------------------------------------------------------
    def tick(self) -> List[SolveResponse]:
        """One dispatch round: drain → coalesce → solve each batch → scatter."""
        self.metrics.record_tick()
        self.metrics.record_queue_depth(len(self.queue))
        reqs = self.queue.drain(self.config.tick_drain)
        responses: List[SolveResponse] = []
        for batch in coalesce(reqs):
            responses.extend(self._run_batch(batch))
        return responses

    def _run_batch(self, batch) -> List[SolveResponse]:
        reqs = batch.requests
        bs = np.stack([r.b for r in reqs])
        tols = np.asarray([r.tol for r in reqs], np.float32)
        t0 = time.perf_counter()
        try:
            lanes = batch.entry.engine.solve(batch.binding, bs, tols)
        except Exception as e:  # noqa: BLE001 — a batch failure must not kill the service
            dt = time.perf_counter() - t0
            self.metrics.record_batch(batch.matrix_id, 0, batch.bucket, dt)
            out = []
            for r in reqs:
                self.cache.release(r.matrix_id)
                lat = time.perf_counter() - r.submitted_at
                self.metrics.record_response(r.tenant, False, lat)
                out.append(SolveResponse(
                    request_id=r.request_id, tenant=r.tenant,
                    matrix_id=r.matrix_id, ok=False, error=str(e),
                    error_reason=SOLVE_FAILED, latency_seconds=lat,
                    batch_lanes=batch.bucket,
                    matrix_version=batch.binding.version))
            return out
        dt = time.perf_counter() - t0
        self.metrics.record_batch(batch.matrix_id, len(reqs), batch.bucket, dt)
        out = []
        for r, lane in zip(reqs, lanes):
            self.cache.release(r.matrix_id)
            lat = time.perf_counter() - r.submitted_at
            self.metrics.record_response(r.tenant, True, lat)
            out.append(SolveResponse(
                request_id=r.request_id, tenant=r.tenant, matrix_id=r.matrix_id,
                ok=True, x=lane.x, iterations=lane.iterations,
                residual=lane.residual, converged=lane.converged,
                latency_seconds=lat, batch_lanes=batch.bucket,
                matrix_version=batch.binding.version))
        return out

    def run_until_idle(self, max_ticks: int = 10_000) -> List[SolveResponse]:
        """Tick until the queue drains (bounded); returns all responses."""
        out: List[SolveResponse] = []
        for _ in range(max_ticks):
            if not len(self.queue):
                break
            out.extend(self.tick())
        return out

    # -- lifecycle --------------------------------------------------------------
    def warmup(self, matrix_ids: Optional[Sequence[str]] = None) -> dict:
        """AOT-compile every (engine, bucket) pair for the given (default:
        all resident) matrices, then pin the compile baseline: every later
        ``metrics.compiles.after_warmup`` counts serving-path compiles only.
        Returns {matrix_id: {bucket: seconds}}."""
        out = {}
        for mid in (matrix_ids if matrix_ids is not None else self.cache.resident_ids()):
            e = self.cache.entry(mid)
            if e is not None:
                out[mid] = e.engine.warm(e.binding)
        self.metrics.mark_warm()
        self._warmed = True
        return out

    def drain(self, timeout: Optional[float] = None) -> List[SolveResponse]:
        """Graceful stop: finish queued work, join refactor workers."""
        out = self.run_until_idle()
        self.cache.wait_refactors(timeout)
        return out

    def metrics_snapshot(self) -> dict:
        return self.metrics.snapshot()
