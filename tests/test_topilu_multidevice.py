"""Multi-device TOP-ILU: bitwise equality vs the sequential oracle.

Each case runs in a subprocess because JAX locks the host device count at
first init (the main pytest process must keep seeing 1 device).
"""
import os
import sys

import pytest

from subproc import run_checked

SCRIPT = os.path.join(os.path.dirname(__file__), "multidevice_check.py")


def _run(n, k, band_rows, broadcast, devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    # force the simulated-CPU backend: without this the child probes for a
    # real TPU (30 GCP-metadata fetch retries, minutes of hang) before
    # falling back — the cause of the flaky/slow seed runs of this file
    env["JAX_PLATFORMS"] = "cpu"
    rc, out, err = run_checked(
        [sys.executable, SCRIPT, str(n), str(k), str(band_rows), broadcast],
        env=env, timeout=300,
    )
    assert rc == 0, f"stdout:\n{out}\nstderr:\n{err[-2000:]}"
    assert "bitwise-equal" in out


@pytest.mark.parametrize("broadcast", ["psum", "ring"])
def test_topilu_8dev_k1(broadcast):
    _run(n=96, k=1, band_rows=8, broadcast=broadcast, devices=8)


def test_topilu_8dev_k2():
    _run(n=96, k=2, band_rows=8, broadcast="psum", devices=8)


def test_topilu_nondivisible_devices():
    """Band count not a multiple of D exercises padding/ownership logic."""
    _run(n=100, k=1, band_rows=4, broadcast="psum", devices=5)


def test_topilu_band_eq_one():
    """R=1: every row is a band — the maximal-parallelism degenerate case."""
    _run(n=64, k=1, band_rows=1, broadcast="psum", devices=4)
