"""Shared pytest config: markers + environment gating.

``pallas_compiled`` marks tests that exercise the *compiled* (non-interpret)
Pallas lowering. This container's CPU CI can only run Pallas in interpret
mode, so those tests skip cleanly unless the operator sets
``REPRO_PALLAS_INTERPRET=0`` (real TPU hardware) — the same env toggle the
kernel wrappers in ``repro.kernels.ops`` consume.
"""
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "pallas_compiled: requires the compiled (non-interpret) Pallas "
        "lowering; skipped unless REPRO_PALLAS_INTERPRET=0 (TPU hardware).",
    )


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "0":
        return  # hardware run: compiled-mode tests are live
    skip = pytest.mark.skip(
        reason="compiled Pallas lowering unavailable on CPU CI "
        "(set REPRO_PALLAS_INTERPRET=0 on TPU hardware to enable)"
    )
    for item in items:
        if "pallas_compiled" in item.keywords:
            item.add_marker(skip)
