"""Level-scheduled sparse triangular solves — applying the preconditioner.

Solving M x = b with M = L·U is the per-iteration cost of the preconditioned
solver (the reason the paper cares about ILU at all). A sparse triangular
solve is sequential row-to-row, but rows whose L-entries all hit previous
*levels* can run together: the classical wavefront/level schedule. The
schedule is host-side planning (like Phase I); the sweep itself is jitted
JAX with one `lax.scan` step per wavefront.

Also provided: a fixed-sweep Jacobi triangular solve (`jacobi_sweeps>0`) —
the TPU-friendly approximate substitution many production preconditioners
use when wavefronts are too shallow; off by default (not bit-faithful to
the exact solve).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .planner import COL_SENTINEL
from .sparse import ILUPattern


@dataclasses.dataclass
class TriangularPlan:
    """Padded wavefront schedule + ELL factors for L and U."""

    n: int
    # unit-lower factor rows (strictly-below-diagonal entries)
    l_cols: np.ndarray  # (n, WL) int32, sentinel-padded
    l_vals: np.ndarray  # (n, WL) f32
    # upper factor rows (above-diagonal entries) + diagonal
    u_cols: np.ndarray  # (n, WU) int32
    u_vals: np.ndarray  # (n, WU) f32
    diag: np.ndarray  # (n,) f32
    l_levels: np.ndarray  # (nl_levels, max_rows) int32, n-padded
    u_levels: np.ndarray  # (nu_levels, max_rows) int32, n-padded


def _wavefronts(dep_lists, n, reverse=False):
    """Group rows into wavefront levels. ``reverse=True`` for the backward
    (U) sweep, whose dependencies point at later rows."""
    level = np.zeros(n, dtype=np.int64)
    order = range(n - 1, -1, -1) if reverse else range(n)
    for j in order:
        deps = dep_lists[j]
        level[j] = 1 + max((level[i] for i in deps), default=-1)
    nlev = int(level.max()) + 1 if n else 0
    groups = [np.nonzero(level == l)[0] for l in range(nlev)]
    maxr = max((len(g) for g in groups), default=1)
    out = np.full((nlev, maxr), n, dtype=np.int32)  # n = scratch row
    for l, g in enumerate(groups):
        out[l, : len(g)] = g
    return out


def build_triangular_plan(pattern: ILUPattern, vals: np.ndarray) -> TriangularPlan:
    n = pattern.n
    l_rows_c, l_rows_v, u_rows_c, u_rows_v = [], [], [], []
    diag = np.zeros(n, dtype=np.float32)
    for j in range(n):
        s, e = pattern.indptr[j], pattern.indptr[j + 1]
        cols = pattern.indices[s:e]
        v = vals[s:e]
        d = pattern.diag_ptr[j]
        l_rows_c.append(cols[:d])
        l_rows_v.append(v[:d])
        u_rows_c.append(cols[d + 1 :])
        u_rows_v.append(v[d + 1 :])
        diag[j] = v[d]
    WL = max((len(c) for c in l_rows_c), default=0) or 1
    WU = max((len(c) for c in u_rows_c), default=0) or 1
    l_cols = np.full((n, WL), COL_SENTINEL, np.int32)
    l_vals = np.zeros((n, WL), np.float32)
    u_cols = np.full((n, WU), COL_SENTINEL, np.int32)
    u_vals = np.zeros((n, WU), np.float32)
    for j in range(n):
        l_cols[j, : len(l_rows_c[j])] = l_rows_c[j]
        l_vals[j, : len(l_rows_v[j])] = l_rows_v[j]
        u_cols[j, : len(u_rows_c[j])] = u_rows_c[j]
        u_vals[j, : len(u_rows_v[j])] = u_rows_v[j]
    l_levels = _wavefronts(l_rows_c, n)
    # U solve runs bottom-up; dependencies are the above-diagonal columns
    u_levels = _wavefronts(u_rows_c, n, reverse=True)
    return TriangularPlan(
        n=n, l_cols=l_cols, l_vals=l_vals, u_cols=u_cols, u_vals=u_vals,
        diag=diag, l_levels=l_levels, u_levels=u_levels,
    )


def make_triangular_solver(pattern: ILUPattern, vals: np.ndarray) -> Callable:
    """Returns jitted ``solve(b) -> x`` applying (LU)^{-1} by substitution."""
    plan = build_triangular_plan(pattern, vals)
    n = plan.n
    l_cols = jnp.asarray(plan.l_cols)
    l_vals = jnp.asarray(plan.l_vals)
    u_cols = jnp.asarray(plan.u_cols)
    u_vals = jnp.asarray(plan.u_vals)
    diag = jnp.asarray(plan.diag)
    l_levels = jnp.asarray(plan.l_levels)
    u_levels = jnp.asarray(plan.u_levels)

    def _sweep(levels, cols, vals_m, rhs, divide):
        # x has one scratch slot at index n
        x = jnp.zeros(n + 1, rhs.dtype)

        def level_step(x, rows):
            rows_c = jnp.minimum(rows, n - 1)
            c = cols[rows_c]  # (maxr, W)
            v = vals_m[rows_c]
            gathered = x[jnp.minimum(c, n)]  # sentinel -> scratch slot (0)
            acc = jnp.sum(jnp.where(c < COL_SENTINEL, v * gathered, 0.0), axis=1)
            val = rhs[rows_c] - acc
            if divide:
                val = val / diag[rows_c]
            x = x.at[jnp.where(rows < n, rows, n)].set(jnp.where(rows < n, val, x[n]), mode="drop")
            return x, None

        x, _ = jax.lax.scan(level_step, x, levels)
        return x[:n]

    @jax.jit
    def solve(b):
        b = b.astype(jnp.float32)
        y = _sweep(l_levels, l_cols, l_vals, b, divide=False)  # L y = b (unit diag)
        x = _sweep(u_levels, u_cols, u_vals, y, divide=True)  # U x = y
        return x

    return solve


def make_jacobi_triangular_solver(pattern: ILUPattern, vals: np.ndarray, sweeps: int = 8) -> Callable:
    """Approximate triangular solve by Jacobi iteration (x <- D^{-1}(b - R x)).

    Converges because triangular Jacobi iteration is nilpotent; ``sweeps``
    bounds the wavefront depth it can resolve. TPU-friendly: no wavefront
    schedule, every sweep is one dense-vector pass.
    """
    plan = build_triangular_plan(pattern, vals)
    n = plan.n
    l_cols = jnp.asarray(plan.l_cols)
    l_vals = jnp.asarray(plan.l_vals)
    u_cols = jnp.asarray(plan.u_cols)
    u_vals = jnp.asarray(plan.u_vals)
    diag = jnp.asarray(plan.diag)

    def _iterate(cols, vals_m, rhs, divide):
        def body(_, x):
            xg = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
            gathered = xg[jnp.minimum(cols, n)]
            acc = jnp.sum(jnp.where(cols < COL_SENTINEL, vals_m * gathered, 0.0), axis=1)
            new = rhs - acc
            if divide:
                new = new / diag
            return new
        return jax.lax.fori_loop(0, sweeps, body, jnp.zeros_like(rhs))

    @jax.jit
    def solve(b):
        b = b.astype(jnp.float32)
        y = _iterate(l_cols, l_vals, b, divide=False)
        x = _iterate(u_cols, u_vals, y, divide=True)
        return x

    return solve
