"""Pallas TPU kernels: dense-tile triangular solves for Block-ILU(k).

Two panel solves appear in the BILU pivot step:

* ``trsm_right_upper``:  L_JI = A_JI @ U_II^{-1}    (X U = A, U upper)
* ``trsm_left_unit_lower``: U_IJ = L_II^{-1} @ A_IJ (L X = A, L unit-lower)

Each runs substitution *inside* the kernel over the tile's 128 columns/rows
(a serial fori — the MXU still vectorizes the (bm,)xbs panel dot each step),
with the panel dimension tiled by the grid. The diagonal tile is broadcast
to every grid step (index_map pins it to block (0,0)); working set per step
= panel block + diagonal tile + output block ≈ 3*bm*bs floats.

Substitution recurrences are sequential in exact arithmetic order, so the
result is deterministic — required for the bit-compatible solve path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _right_upper_kernel(a_ref, u_ref, o_ref):
    bs = u_ref.shape[0]
    o_ref[...] = jnp.zeros_like(o_ref)
    iota = jax.lax.iota(jnp.int32, bs)

    def col(c, _):
        ucol = jnp.where(iota < c, u_ref[:, c], 0.0)  # (bs,)
        acc = jnp.dot(o_ref[...], ucol, preferred_element_type=jnp.float32)
        x_c = (a_ref[:, c] - acc) / u_ref[c, c]
        o_ref[:, pl.ds(c, 1)] = x_c[:, None].astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bs, col, 0)


def _left_unit_lower_kernel(l_ref, a_ref, o_ref):
    bs = l_ref.shape[0]
    o_ref[...] = jnp.zeros_like(o_ref)
    iota = jax.lax.iota(jnp.int32, bs)

    def row(r, _):
        lrow = jnp.where(iota < r, l_ref[r, :], 0.0)  # (bs,)
        acc = jnp.dot(lrow, o_ref[...], preferred_element_type=jnp.float32)
        x_r = a_ref[r, :] - acc
        o_ref[pl.ds(r, 1), :] = x_r[None, :].astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bs, row, 0)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def trsm_right_upper(a, u, *, bm=256, interpret=True):
    """Solve X U = A. a: (M, bs) panel, u: (bs, bs) upper-triangular tile."""
    m, bs = a.shape
    assert u.shape == (bs, bs)
    bm = min(bm, m)
    assert m % bm == 0
    return pl.pallas_call(
        _right_upper_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, bs), lambda i: (i, 0)),
            pl.BlockSpec((bs, bs), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, bs), a.dtype),
        interpret=resolve_interpret(interpret),
    )(a, u)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def trsm_left_unit_lower(l, a, *, bn=256, interpret=True):
    """Solve L X = A. l: (bs, bs) unit-lower tile, a: (bs, N) panel."""
    bs, n = a.shape
    assert l.shape == (bs, bs)
    bn = min(bn, n)
    assert n % bn == 0
    return pl.pallas_call(
        _left_unit_lower_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i: (0, 0)),
            pl.BlockSpec((bs, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bs, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bs, n), a.dtype),
        interpret=resolve_interpret(interpret),
    )(l, a)
