"""Unit tests for the serve layer: admission, buckets, coalescer, cache,
metrics, and the value-rebinding engine's bitwise contract.

The service-level soak lives in test_serve_soak.py; fault injection in
test_serve_faults.py. Everything here is small and fast — tiny matrices,
few buckets, stub engines where compilation isn't the thing under test.
"""
import threading
import types

import numpy as np
import pytest

from repro.core.api import _symbolic
from repro.core.factor_plan import factor_plan_for
from repro.core.matgen import matgen
from repro.core.solvers import batch_buckets, parse_batch_buckets, solve_with_ilu
from repro.core.sparse import CSRMatrix
from repro.serve import (
    AdmissionError,
    AdmissionQueue,
    LatencyHistogram,
    PlanCache,
    ServeConfig,
    ServiceMetrics,
    SolveRequest,
    SolveResponse,
    SolveService,
    coalesce,
    validate_request,
)
from repro.serve.engine import ServeEngine


# --------------------------------------------------------------------------
# batch bucket spec parsing (env hardening)
# --------------------------------------------------------------------------
class TestParseBatchBuckets:
    def test_valid_specs(self):
        assert parse_batch_buckets("1,2,4,8") == (1, 2, 4, 8)
        assert parse_batch_buckets(" 1 , 2 ,4 ") == (1, 2, 4)
        assert parse_batch_buckets("7") == (7,)

    def test_zero_and_negative_rejected(self):
        with pytest.raises(ValueError, match="positive.*0"):
            parse_batch_buckets("0,4,8")
        with pytest.raises(ValueError, match="positive.*-4"):
            parse_batch_buckets("-4,8")

    def test_the_issue_spec_rejected(self):
        # the historically silently-accepted spec must now fail loudly
        with pytest.raises(ValueError, match="REPRO_BATCH_BUCKETS"):
            parse_batch_buckets("0,-4,8")

    def test_non_integer_names_token_and_spec(self):
        with pytest.raises(ValueError, match=r"'two'.*'1,two,4'"):
            parse_batch_buckets("1,two,4")

    def test_duplicates_rejected(self):
        with pytest.raises(ValueError, match=r"duplicate.*\[4\]"):
            parse_batch_buckets("1,4,4,8")

    def test_non_ascending_rejected(self):
        with pytest.raises(ValueError, match="ascending"):
            parse_batch_buckets("8,4,2")

    def test_empty_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            parse_batch_buckets("")
        with pytest.raises(ValueError, match="empty"):
            parse_batch_buckets(" , ,")

    def test_env_path(self, monkeypatch):
        monkeypatch.setenv("REPRO_BATCH_BUCKETS", "2,4,16")
        assert batch_buckets() == (2, 4, 16)
        monkeypatch.setenv("REPRO_BATCH_BUCKETS", "0,-4,8")
        with pytest.raises(ValueError, match="REPRO_BATCH_BUCKETS"):
            batch_buckets()
        monkeypatch.delenv("REPRO_BATCH_BUCKETS")
        assert batch_buckets() == (1, 2, 4, 8, 16, 32, 64)


# --------------------------------------------------------------------------
# admission
# --------------------------------------------------------------------------
class TestAdmission:
    def test_unknown_matrix(self):
        with pytest.raises(AdmissionError) as e:
            validate_request("t", "nope", np.ones(4, np.float32), 1e-5, None)
        assert e.value.reason == "unknown_matrix"

    def test_bad_shape(self):
        for bad in (np.ones(5, np.float32), np.ones((4, 1), np.float32), "junk"):
            with pytest.raises(AdmissionError) as e:
                validate_request("t", "m", bad, 1e-5, 4)
            assert e.value.reason == "bad_shape"

    def test_non_finite(self):
        b = np.ones(4, np.float32)
        b[2] = np.inf
        with pytest.raises(AdmissionError) as e:
            validate_request("t", "m", b, 1e-5, 4)
        assert e.value.reason == "non_finite"

    def test_bad_tol(self):
        for bad in (0.0, -1e-5, np.nan, "x"):
            with pytest.raises(AdmissionError) as e:
                validate_request("t", "m", np.ones(4, np.float32), bad, 4)
            assert e.value.reason == "bad_tol"

    def test_valid_passes_and_casts(self):
        out = validate_request("t", "m", [1, 2, 3, 4], 1e-5, 4)
        assert out.dtype == np.float32 and out.shape == (4,)

    def test_queue_fifo_bound_and_requeue(self):
        q = AdmissionQueue(max_depth=3)
        reqs = [SolveRequest("t", "m", np.zeros(2, np.float32), 1e-5) for _ in range(3)]
        for r in reqs:
            q.push(r)
        with pytest.raises(AdmissionError) as e:
            q.push(SolveRequest("t", "m", np.zeros(2, np.float32), 1e-5))
        assert e.value.reason == "queue_full"
        got = q.drain(2)
        assert [g.request_id for g in got] == [r.request_id for r in reqs[:2]]
        q.requeue_front(got)  # preserves FIFO: requeued go back in front
        assert [g.request_id for g in q.drain(None)] == [r.request_id for r in reqs]


# --------------------------------------------------------------------------
# coalescer
# --------------------------------------------------------------------------
def _stub_entry(buckets=(1, 2, 4)):
    eng = types.SimpleNamespace(
        buckets=tuple(buckets),
        bucket_for=lambda nb, bs=tuple(buckets): next((w for w in bs if w >= nb), nb))
    return types.SimpleNamespace(engine=eng)


def _req(mid, entry, binding):
    r = SolveRequest("t", mid, np.zeros(2, np.float32), 1e-5)
    r.binding = (entry, binding)
    return r


class TestCoalescer:
    def test_groups_by_matrix_and_binding(self):
        e1, e2 = _stub_entry(), _stub_entry()
        b1, b2 = object(), object()
        reqs = [_req("a", e1, b1), _req("b", e2, b2), _req("a", e1, b1)]
        batches = coalesce(reqs)
        assert [(b.matrix_id, b.real_lanes) for b in batches] == [("a", 2), ("b", 1)]
        assert batches[0].bucket == 2 and batches[1].bucket == 1

    def test_value_versions_do_not_mix(self):
        e = _stub_entry()
        old, new = object(), object()
        reqs = [_req("a", e, old), _req("a", e, new), _req("a", e, old)]
        batches = coalesce(reqs)
        assert [(b.binding, b.real_lanes) for b in batches] == [(old, 2), (new, 1)]

    def test_chunks_over_largest_bucket(self):
        e = _stub_entry(buckets=(1, 2, 4))
        b = object()
        batches = coalesce([_req("a", e, b) for _ in range(10)])
        assert [x.real_lanes for x in batches] == [4, 4, 2]
        assert [x.bucket for x in batches] == [4, 4, 2]
        # FIFO preserved across the chunk boundary
        ids = [r.request_id for x in batches for r in x.requests]
        assert ids == sorted(ids)


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------
class TestMetrics:
    def test_histogram_quantiles_and_buckets(self):
        h = LatencyHistogram()
        for v in np.linspace(1e-4, 1e-1, 1000):
            h.observe(float(v))
        d = h.to_dict()
        assert d["count"] == 1000
        assert sum(d["bucket_counts"]) == 1000
        assert d["p50_seconds"] == pytest.approx(0.05, rel=0.05)
        assert d["p99_seconds"] == pytest.approx(0.099, rel=0.05)
        assert d["max_seconds"] <= 0.1

    def test_snapshot_shape_and_counters(self):
        m = ServiceMetrics()
        m.record_admission(True)
        m.record_admission(False, "bad_tol")
        m.record_queue_depth(3)
        m.record_batch("m0", real=3, bucket=4, seconds=0.25)
        m.record_response("tenant-a", True, 0.3)
        m.record_cache("hit")
        m.record_cache("miss")
        m.record_cache("evict")
        m.record_cache("refactor")
        m.record_tick()
        s = m.snapshot()
        assert s["requests"]["admitted"] == 1
        assert s["requests"]["rejected_by_reason"] == {"bad_tol": 1}
        assert s["queue"]["depth_max"] == 3
        assert s["coalescing"]["solved_lanes"] == 3
        assert s["coalescing"]["padded_lanes"] == 1
        assert s["coalescing"]["occupancy_mean"] == pytest.approx(0.75)
        assert s["cache"]["hit_rate"] == pytest.approx(0.5)
        assert s["cache"]["refactorizations"] == 1
        assert "tenant-a" in s["tenants"]
        assert s["compiles"]["after_warmup"] >= 0

    def test_unknown_cache_event_rejected(self):
        with pytest.raises(ValueError):
            ServiceMetrics().record_cache("nope")


# --------------------------------------------------------------------------
# plan cache (stub engines: LRU/pin logic only, no XLA)
# --------------------------------------------------------------------------
class _StubEngine:
    def __init__(self, a, pattern, vals_csr, **kw):
        self.fingerprint = ("stub", a.n, pattern.k)
        self.buckets = (1, 2, 4)
        self._v = 0

    def bind(self, a, vals_csr):
        self._v += 1
        return types.SimpleNamespace(version=self._v, value_args=(), vals_csr=vals_csr,
                                     bound_seconds=0.0)


def _cache(capacity=2):
    return PlanCache(capacity=capacity, metrics=ServiceMetrics(),
                     engine_factory=_StubEngine)


def _mat(n=16, seed=0):
    return matgen(n, 0.2, seed=seed)


class TestPlanCache:
    def test_lru_eviction_of_unpinned(self):
        c = _cache(capacity=2)
        c.register("a", _mat(seed=1))
        c.register("b", _mat(seed=2))
        c.acquire("a")  # refreshes a's recency AND pins it
        c.release("a")
        c.register("c", _mat(seed=3))  # evicts b (LRU, unpinned)
        assert "b" not in c and "a" in c and "c" in c

    def test_pinned_entries_survive_eviction(self):
        c = _cache(capacity=2)
        c.register("a", _mat(seed=1))
        c.register("b", _mat(seed=2))
        c.acquire("b")  # pin b; a becomes the only evictable entry
        c.register("c", _mat(seed=3))
        assert "b" in c and "a" not in c
        c.release("b")

    def test_all_pinned_raises_instead_of_evicting(self):
        c = _cache(capacity=1)
        c.register("a", _mat(seed=1))
        c.acquire("a")
        with pytest.raises(AdmissionError) as e:
            c.register("b", _mat(seed=2))
        assert e.value.reason == "queue_full"
        c.release("a")

    def test_acquire_unknown_raises(self):
        c = _cache()
        with pytest.raises(AdmissionError) as e:
            c.acquire("ghost")
        assert e.value.reason == "unknown_matrix"

    def test_engine_shared_by_structure(self):
        c = _cache(capacity=4)
        a1 = _mat(seed=5)
        a2 = CSRMatrix(n=a1.n, indptr=a1.indptr, indices=a1.indices,
                       data=(a1.data * 3.0).astype(np.float32))
        e1 = c.register("a1", a1)
        e2 = c.register("a2", a2)
        assert e1.engine is e2.engine
        assert c.metrics.snapshot()["cache"]["engines_shared"] == 1
        assert e2.plan_host is a1  # factor plan rides the first registrant

    def test_update_values_swaps_binding_atomically(self):
        c = _cache(capacity=2)
        a = _mat(seed=7)
        e = c.register("a", a)
        _, old = c.acquire("a")
        t = c.update_values("a", (a.data * 1.5).astype(np.float32), background=True)
        t.join()
        assert e.binding.version == old.version + 1
        assert e.binding is not old  # pinned old binding still intact
        c.release("a")

    def test_update_unknown_or_wrong_shape(self):
        c = _cache()
        a = _mat(seed=8)
        c.register("a", a)
        with pytest.raises(AdmissionError):
            c.update_values("ghost", a.data)
        with pytest.raises(ValueError, match="expected"):
            c.update_values("a", np.zeros(3, np.float32))


# --------------------------------------------------------------------------
# engine: bind/rebind bitwise (real XLA, one small matrix)
# --------------------------------------------------------------------------
def test_engine_rebind_is_bitwise_and_version_monotone():
    a = matgen(60, 0.08, seed=21)
    pattern = _symbolic(a, 1, "sum")
    v1 = np.asarray(factor_plan_for(a, pattern).factorize(a))
    eng = ServeEngine(a, pattern, v1, restart=8, buckets=(1, 2))
    b1 = eng.bind(a, v1)

    a2 = CSRMatrix(n=a.n, indptr=a.indptr, indices=a.indices,
                   data=(a.data * 1.25).astype(np.float32))
    v2 = np.asarray(factor_plan_for(a, pattern).factorize(a2))
    b2 = eng.bind(a2, v2)
    assert b2.version == b1.version + 1

    rng = np.random.default_rng(0)
    B = rng.standard_normal((2, a.n)).astype(np.float32)
    tols = np.full(2, 1e-6, np.float32)
    for bind, mat in ((b1, a), (b2, a2)):
        lanes = eng.solve(bind, B, tols)
        for i in range(2):
            ref, _ = solve_with_ilu(mat, B[i], k=1, tol=1e-6, restart=8,
                                    use_pallas=False)
            np.testing.assert_array_equal(
                np.asarray(lanes[i].x, np.float32).view(np.int32),
                np.asarray(ref.x, np.float32).view(np.int32))
            assert lanes[i].iterations == ref.iterations
            assert lanes[i].converged


# --------------------------------------------------------------------------
# seeded coalescing-invariance check (the no-hypothesis fallback for the
# property test in test_property.py — runs everywhere)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed,k,method", [(0, 0, "sweep"), (1, 1, "inverse"),
                                           (2, 2, "sweep")])
def test_coalescing_invariance_seeded(seed, k, method):
    """A request's bits do not depend on batch membership, lane position,
    bucket, or its neighbours' tolerances."""
    rng = np.random.default_rng(seed)
    a = matgen(48, 0.12, seed=seed)
    pattern = _symbolic(a, k, "sum")
    v = np.asarray(factor_plan_for(a, pattern).factorize(a))
    eng = ServeEngine(a, pattern, v, restart=6, maxiter=30,
                      precond_method=method, buckets=(1, 2, 4))
    bind = eng.bind(a, v)

    b = rng.standard_normal(a.n).astype(np.float32)
    tol = 1e-6
    solo = eng.solve(bind, b[None, :], np.asarray([tol], np.float32))[0]
    ref, _ = solve_with_ilu(a, b, k=k, tol=tol, restart=6, maxiter=30,
                            use_pallas=False, precond_method=method)
    np.testing.assert_array_equal(np.asarray(solo.x, np.float32).view(np.int32),
                                  np.asarray(ref.x, np.float32).view(np.int32))

    for nb, pos in ((2, 0), (2, 1), (4, 2), (3, 0)):  # 3 pads up to bucket 4
        B = rng.standard_normal((nb, a.n)).astype(np.float32)
        tols = rng.choice([1e-4, 1e-5, 1e-6], size=nb).astype(np.float32)
        B[pos] = b
        tols[pos] = tol
        lane = eng.solve(bind, B, tols)[pos]
        np.testing.assert_array_equal(
            np.asarray(lane.x, np.float32).view(np.int32),
            np.asarray(solo.x, np.float32).view(np.int32),
            err_msg=f"lane {pos} of batch {nb} != solo (k={k}, {method})")
        assert lane.iterations == solo.iterations


# --------------------------------------------------------------------------
# service-level basics (register / submit / tick / scatter)
# --------------------------------------------------------------------------
def test_service_round_trip_and_scatter():
    a = matgen(60, 0.08, seed=33)
    svc = SolveService(ServeConfig(buckets=(1, 2, 4), restart=8))
    v0 = svc.register_matrix("m0", a, k=1)
    assert v0 == 1
    rng = np.random.default_rng(3)
    bs = [rng.standard_normal(a.n).astype(np.float32) for _ in range(3)]
    reqs = [svc.submit(f"t{i}", "m0", b, tol=1e-5) for i, b in enumerate(bs)]
    assert all(isinstance(r, SolveRequest) for r in reqs)
    resps = svc.tick()
    assert len(resps) == 3
    by_id = {r.request_id: r for r in resps}
    for req, b in zip(reqs, bs):
        r = by_id[req.request_id]  # scatter: response matches its request
        assert r.ok and r.tenant == req.tenant and r.batch_lanes == 4
        ref, _ = solve_with_ilu(a, b, k=1, tol=1e-5, restart=8, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(r.x, np.float32).view(np.int32),
                                      np.asarray(ref.x, np.float32).view(np.int32))
    # pins released: the entry is evictable again
    assert svc.cache.entry("m0").pins == 0
    snap = svc.metrics_snapshot()
    assert snap["requests"]["completed"] == 3
    assert snap["coalescing"]["batches"] == 1


def test_service_rejects_return_failed_response():
    a = matgen(40, 0.1, seed=34)
    svc = SolveService(ServeConfig(buckets=(1, 2), restart=8))
    svc.register_matrix("m0", a, k=1)
    r = svc.submit("t0", "ghost", np.ones(a.n, np.float32))
    assert isinstance(r, SolveResponse) and not r.ok
    assert r.error_reason == "unknown_matrix"
    snap = svc.metrics_snapshot()
    assert snap["requests"]["rejected_by_reason"]["unknown_matrix"] == 1


def test_service_thread_safe_submits():
    a = matgen(40, 0.1, seed=35)
    svc = SolveService(ServeConfig(buckets=(1, 2, 4), restart=8))
    svc.register_matrix("m0", a, k=1)
    rng = np.random.default_rng(0)
    bs = rng.standard_normal((16, a.n)).astype(np.float32)

    def submit_some(lo):
        for i in range(lo, lo + 4):
            svc.submit(f"t{lo}", "m0", bs[i])

    threads = [threading.Thread(target=submit_some, args=(i * 4,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    resps = svc.run_until_idle()
    assert len(resps) == 16 and all(r.ok for r in resps)
